"""SVM inference micro-benchmark: legacy object path vs CompiledMachine.

Times the mixed-signal 'circuit' machine (the paper's deliverable: digital
linear + analog RBF classifiers + encoder) on Balance Scale, at batch sizes
{64, 1024, 4096}, and emits a JSON record for the perf trajectory:

  PYTHONPATH=src python benchmarks/svm_infer.py [--out runs/svm_infer.json]

The object path is the per-classifier Python loop (`MulticlassSVM.predict`);
the compiled path is the single jit-compiled batched program produced by
`repro.api.compile_machine`.  Both compute the same machine — equality is
asserted on every batch before timing.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

BATCH_SIZES = (64, 1024, 4096)


def _median_ms(fn, iters: int) -> float:
    fn()  # warmup (jit compile / BLAS init)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e3)


def run(n_epochs: int = 60, seed: int = 0, target: str = "circuit",
        verbose: bool = True) -> dict:
    from repro.api import MixedKernelSVM
    from repro.data import datasets

    ds = datasets.load("balance")
    est = MixedKernelSVM(n_epochs=n_epochs, seed=seed).fit(
        ds.x_train, ds.y_train)
    bank = est.bank(target)
    machine = est.deploy(target)

    rng = np.random.RandomState(seed)
    rows = {}
    for n in BATCH_SIZES:
        x = ds.x_test[rng.randint(0, len(ds.x_test), n)]
        if not np.array_equal(bank.predict(x), machine.predict(x)):
            raise AssertionError(f"object/compiled mismatch at batch {n}")
        t_obj = _median_ms(lambda: bank.predict(x), iters=5)
        t_cmp = _median_ms(lambda: machine.predict(x), iters=30)
        rows[n] = {
            "object_ms": round(t_obj, 4),
            "compiled_ms": round(t_cmp, 4),
            "speedup": round(t_obj / t_cmp, 2),
        }

    result = {
        "benchmark": "svm_infer",
        "dataset": "balance",
        "target": target,
        "kernel_map": est.kernel_map_,
        "batches": rows,
    }
    if verbose:
        print("batch,object_ms,compiled_ms,speedup")
        for n, r in rows.items():
            print(f"{n},{r['object_ms']},{r['compiled_ms']},{r['speedup']}")
        print(json.dumps(result))
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, help="write JSON here as well")
    ap.add_argument("--target", default="circuit")
    ap.add_argument("--n-epochs", type=int, default=60)
    args = ap.parse_args()
    result = run(n_epochs=args.n_epochs, target=args.target)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)


if __name__ == "__main__":
    main()
