"""Fig. 5 reproduction: analog/digital area + power breakdown of the
mixed-signal designs (paper: digital ~54% of area on average; analog
~89% of power)."""
from __future__ import annotations

import numpy as np

try:
    from benchmarks import _fit_cache
except ImportError:  # run as a script: benchmarks/ itself is sys.path[0]
    import _fit_cache

from repro.core import hwcost
from repro.data import datasets


def run(n_epochs: int = 120, seed: int = 0, verbose: bool = True):
    # Shared cached fits (one Algorithm-1 run per dataset across
    # table2 / fig5 / pareto, see _fit_cache).
    mixed = {
        name: _fit_cache.fitted(name, n_epochs=n_epochs, seed=seed)[1]
        .bank("circuit")
        for name in datasets.DATASETS
    }
    cm = _fit_cache.calibrated_cost_model(n_epochs=n_epochs, seed=seed)

    rows = []
    for name, sys in mixed.items():
        c = hwcost.system_cost(sys, cm)
        rows.append((name, c.analog_area_frac, 1 - c.analog_area_frac,
                     c.analog_power_frac, 1 - c.analog_power_frac))
    mean_dig_area = float(np.mean([r[2] for r in rows]))
    mean_an_power = float(np.mean([r[3] for r in rows]))

    if verbose:
        print("dataset,analog_area_frac,digital_area_frac,"
              "analog_power_frac,digital_power_frac")
        for r in rows:
            print(f"{r[0]},{r[1]:.2f},{r[2]:.2f},{r[3]:.2f},{r[4]:.2f}")
        print(f"mean_digital_area_frac,{mean_dig_area:.2f},paper,0.54")
        print(f"mean_analog_power_frac,{mean_an_power:.2f},paper,0.89")
    return rows, {"mean_digital_area_frac": mean_dig_area,
                  "mean_analog_power_frac": mean_an_power}


if __name__ == "__main__":
    run()
