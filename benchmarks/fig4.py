"""Fig. 4 reproduction: behavioral-model fidelity (nRMSE, pearson r).

Validates each analog component against its ideal software reference,
exactly as the paper's table:

  component                      paper nRMSE   paper r
  Gaussian kernel (V_b = 0.30V)  0.0218        0.997
  product across dims (D = 3)    0.0117        0.998
  alpha multiplier (logistic)    0.0003        0.999
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import analog, kernels as kern


def run(seed: int = 0, verbose: bool = True):
    key = jax.random.PRNGKey(seed)
    p = analog.CircuitParams()
    hw = analog.AnalogRBFModel.from_circuit(p, key=key)

    rows = []

    # 1) Gaussian kernel cell: surrogate-SPICE sweep vs fitted ideal Gaussian
    fit = hw.a0 * np.exp(-hw.gamma0 * (hw.dv_grid - hw.mu) ** 2)
    meas = hw.kernel_curve * float(hw.kernel_curve.max())
    meas_n = meas / meas.max()
    fit_n = fit / fit.max()
    rows.append(("gaussian_kernel", analog.nrmse(meas_n, fit_n),
                 analog.pearson_r(meas_n, fit_n), 0.0218, 0.997))

    # 2) Product across dims (D=3): hardware separable product vs ideal
    #    Gaussian in 3-D (along a diagonal sweep)
    g_star = 4.0
    t = np.linspace(-0.5, 0.5, 101)
    x3 = jnp.asarray(np.stack([t, 0.7 * t, 0.4 * t], 1), jnp.float32)
    z3 = jnp.zeros((1, 3), jnp.float32)
    k_hw = np.asarray(hw.kernel_response(x3, z3, g_star))[:, 0]
    k_id = np.asarray(kern.rbf_kernel(x3, z3, jnp.float32(g_star)))[:, 0]
    rows.append(("product_dims_D3", analog.nrmse(k_id, k_hw),
                 analog.pearson_r(k_id, k_hw), 0.0117, 0.998))

    # 3) Alpha multiplier: measured curve vs fitted logistic
    dva, ratio = analog.dc_sweep_alpha(p, key=key)
    x0, s = analog.fit_logistic(dva, ratio)
    fit_a = 1.0 / (1.0 + np.exp((dva - x0) / s))
    rows.append(("alpha_multiplier", analog.nrmse(ratio, fit_a),
                 analog.pearson_r(ratio, fit_a), 0.0003, 0.999))

    if verbose:
        print("component,nrmse,r,paper_nrmse,paper_r")
        for name, n, r, pn, pr in rows:
            print(f"{name},{n:.4f},{r:.4f},{pn},{pr}")
    return rows


if __name__ == "__main__":
    run()
