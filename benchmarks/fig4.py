"""Fig. 4 reproduction: behavioral-model fidelity (nRMSE, pearson r).

Validates each analog component against its ideal software reference,
exactly as the paper's table:

  component                      paper nRMSE   paper r
  Gaussian kernel (V_b = 0.30V)  0.0218        0.997
  product across dims (D = 3)    0.0117        0.998
  alpha multiplier (logistic)    0.0003        0.999

``--json`` additionally reports the fidelity *distribution* under sampled
process variation: ``--n-variation`` mismatched instances are swept through
the circuit surrogate (independent per-instance keys folded from the seed),
each re-fitted exactly like the nominal instance, and the per-instance
nRMSE / pearson-r statistics are aggregated — Fig. 4 as a distribution,
not a point.  The seed is recorded in the JSON for reproducibility.

  PYTHONPATH=src python benchmarks/fig4.py [--json fig4.json]
                                           [--n-variation 32] [--seed 0]
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import analog, kernels as kern


def _dist(values: list[float]) -> dict:
    a = np.asarray(values, np.float64)
    return {
        "mean": float(a.mean()),
        "std": float(a.std()),
        "min": float(a.min()),
        "max": float(a.max()),
        "p95": float(np.percentile(a, 95)),
    }


def variation_fidelity(hw: analog.AnalogRBFModel, seed: int,
                       n_variation: int) -> dict:
    """Fig.-4 fit fidelity of ``n_variation`` mismatched instances.

    Every instance gets its own key (``fold_in`` of the base key — explicit
    RNG threading, no global state) and two fidelity views are collected:

    * ``*_refit`` — the instance's surrogate sweeps re-fitted with the same
      estimators the nominal calibration uses (per-instance calibration
      quality; nearly constant, since threshold shifts and gain errors are
      absorbed by the fitted ``mu``/``A0``),
    * ``*_nominal_fit`` — the NOMINAL instance's fitted model evaluated
      against the mismatched instance's measured sweep (deploy-one-
      calibration-everywhere error; this is the distribution process
      variation actually induces).
    """
    p = hw.params
    base = jax.random.PRNGKey(seed)
    nom_gauss = hw.a0 * np.exp(-hw.gamma0 * (hw.dv_grid - hw.mu) ** 2)
    nom_gauss = nom_gauss / nom_gauss.max()
    out: dict[str, list[float]] = {
        "gaussian_refit_nrmse": [], "gaussian_refit_r": [],
        "gaussian_nominal_fit_nrmse": [], "gaussian_nominal_fit_r": [],
        "alpha_refit_nrmse": [], "alpha_nominal_fit_nrmse": [],
    }
    for i in range(n_variation):
        kg, ka = jax.random.split(jax.random.fold_in(base, i))
        dv, curve = analog.dc_sweep_gaussian(p, key=kg)
        a0, g0, mu = analog.fit_gaussian(dv, curve)
        fit = a0 * np.exp(-g0 * (dv - mu) ** 2)
        cn, fn = curve / curve.max(), fit / fit.max()
        out["gaussian_refit_nrmse"].append(analog.nrmse(cn, fn))
        out["gaussian_refit_r"].append(analog.pearson_r(cn, fn))
        out["gaussian_nominal_fit_nrmse"].append(analog.nrmse(cn, nom_gauss))
        out["gaussian_nominal_fit_r"].append(analog.pearson_r(cn, nom_gauss))
        dva, ratio = analog.dc_sweep_alpha(p, key=ka)
        x0, s = analog.fit_logistic(dva, ratio)
        fit_a = 1.0 / (1.0 + np.exp((dva - x0) / s))
        nom_a = 1.0 / (1.0 + np.exp((dva - hw.alpha_x0) / hw.alpha_s))
        out["alpha_refit_nrmse"].append(analog.nrmse(ratio, fit_a))
        out["alpha_nominal_fit_nrmse"].append(analog.nrmse(ratio, nom_a))
    return {"n_samples": n_variation, "seed": seed,
            **{k: _dist(v) for k, v in out.items()}}


def run(seed: int = 0, verbose: bool = True, n_variation: int = 0) -> dict:
    key = jax.random.PRNGKey(seed)
    p = analog.CircuitParams()
    hw = analog.AnalogRBFModel.from_circuit(p, key=key)

    rows = []

    # 1) Gaussian kernel cell: surrogate-SPICE sweep vs fitted ideal Gaussian
    fit = hw.a0 * np.exp(-hw.gamma0 * (hw.dv_grid - hw.mu) ** 2)
    meas = hw.kernel_curve * float(hw.kernel_curve.max())
    meas_n = meas / meas.max()
    fit_n = fit / fit.max()
    rows.append(("gaussian_kernel", analog.nrmse(meas_n, fit_n),
                 analog.pearson_r(meas_n, fit_n), 0.0218, 0.997))

    # 2) Product across dims (D=3): hardware separable product vs ideal
    #    Gaussian in 3-D (along a diagonal sweep)
    g_star = 4.0
    t = np.linspace(-0.5, 0.5, 101)
    x3 = jnp.asarray(np.stack([t, 0.7 * t, 0.4 * t], 1), jnp.float32)
    z3 = jnp.zeros((1, 3), jnp.float32)
    k_hw = np.asarray(hw.kernel_response(x3, z3, g_star))[:, 0]
    k_id = np.asarray(kern.rbf_kernel(x3, z3, jnp.float32(g_star)))[:, 0]
    rows.append(("product_dims_D3", analog.nrmse(k_id, k_hw),
                 analog.pearson_r(k_id, k_hw), 0.0117, 0.998))

    # 3) Alpha multiplier: measured curve vs fitted logistic
    dva, ratio = analog.dc_sweep_alpha(p, key=jax.random.split(key)[1])
    x0, s = analog.fit_logistic(dva, ratio)
    fit_a = 1.0 / (1.0 + np.exp((dva - x0) / s))
    rows.append(("alpha_multiplier", analog.nrmse(ratio, fit_a),
                 analog.pearson_r(ratio, fit_a), 0.0003, 0.999))

    if verbose:
        print("component,nrmse,r,paper_nrmse,paper_r")
        for name, n, r, pn, pr in rows:
            print(f"{name},{n:.4f},{r:.4f},{pn},{pr}")

    result = {
        "benchmark": "fig4",
        "seed": seed,
        "components": [
            {"component": name, "nrmse": float(n), "r": float(r),
             "paper_nrmse": pn, "paper_r": pr}
            for name, n, r, pn, pr in rows
        ],
    }
    if n_variation:
        result["variation"] = variation_fidelity(hw, seed, n_variation)
        if verbose:
            v = result["variation"]
            g = v["gaussian_nominal_fit_nrmse"]
            a = v["alpha_nominal_fit_nrmse"]
            print(f"variation (n={n_variation}): nominal-fit gaussian "
                  f"nrmse {g['mean']:.4f} +/- {g['std']:.4f} "
                  f"(p95 {g['p95']:.4f}), nominal-fit alpha nrmse "
                  f"{a['mean']:.4f} +/- {a['std']:.4f}")
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write machine-readable results here")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--n-variation", type=int, default=32,
                    help="variation samples for the fidelity distribution "
                         "(JSON mode; 0 disables)")
    args = ap.parse_args()
    result = run(seed=args.seed,
                 n_variation=args.n_variation if args.json else 0)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(result, f, indent=2)


if __name__ == "__main__":
    main()
