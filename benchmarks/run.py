"""Benchmark orchestrator: one entry per paper table/figure + kernels +
roofline.  Prints ``name,us_per_call,derived`` style CSV blocks.

``--json PATH`` additionally aggregates every machine-readable sub-result
(currently fig4, svm_infer, svm_train, serving, scale, pareto and
montecarlo — including the streaming V=64..1e6 scaling curve; more as
benchmarks grow JSON output) into one file suitable for BENCH_*.json
trajectory tracking.

Table2 / fig5 / pareto share per-dataset Algorithm-1 fits through
``benchmarks._fit_cache`` — each dataset is fitted once per process.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# Script-mode robustness: `python benchmarks/run.py` puts benchmarks/ (not
# the repo root) on sys.path, breaking the `from benchmarks import ...`
# imports that `python -m benchmarks.run` resolves fine.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write aggregated machine-readable results here")
    args = ap.parse_args()

    t0 = time.time()
    results: dict[str, dict] = {}

    print("== Fig. 4: analog behavioral-model fidelity ==")
    from benchmarks import fig4
    results["fig4"] = fig4.run(n_variation=32 if args.json else 0)

    print("\n== Table II: accuracy / area / power ==")
    from benchmarks import table2
    table2.run()

    print("\n== Fig. 5: analog/digital breakdown ==")
    from benchmarks import fig5
    fig5.run()

    print("\n== Pareto: kernel-assignment design-space exploration ==")
    from benchmarks import pareto
    results["pareto"] = pareto.run()

    print("\n== Monte-Carlo: variation-aware yield sweep ==")
    from benchmarks import montecarlo
    results["montecarlo"] = montecarlo.run()
    if args.json:
        # Trajectory files record the full streaming signoff curve
        # (DESIGN.md §10); interactive runs skip the ~15 min V=1e6 leg.
        print("\n== Monte-Carlo: streaming scaling curve V=64..1e6 ==")
        results["montecarlo"]["scaling"] = montecarlo.run_scaling()

    print("\n== SVM inference: object path vs compiled machine ==")
    from benchmarks import svm_infer
    results["svm_infer"] = svm_infer.run()

    print("\n== SVM training: sequential loop vs batched engine ==")
    from benchmarks import svm_train
    results["svm_train"] = svm_train.run()

    print("\n== Serving: streaming engine vs naive per-request dispatch ==")
    from benchmarks import serving
    results["serving"] = serving.run()

    print("\n== Scale-out: K=12 DAG front, lane ladder, portfolio DSE ==")
    from benchmarks import scale
    results["scale"] = scale.run()

    print("\n== Kernel micro-bench (Pallas interpret vs jnp oracle) ==")
    from benchmarks import kernelbench
    kernelbench.run()

    if os.path.isdir("runs/dryrun") and os.listdir("runs/dryrun"):
        print("\n== Roofline (single-pod 16x16) ==")
        from benchmarks import roofline
        roofline.run()
    else:
        print("\n(roofline skipped: run `python -m repro.launch.dryrun "
              "--all --mesh both` first)")
    total = time.time() - t0
    print(f"\ntotal_bench_seconds,{total:.1f}")

    if args.json:
        payload = {"machine": _machine_note(),
                   "total_bench_seconds": round(total, 1), **results}
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"aggregated JSON -> {args.json}")


def _machine_note() -> dict:
    """Reproducibility header for BENCH_*.json trajectory files: where the
    numbers came from and the seed policy.  Every sub-benchmark uses fixed
    seeds internally (RandomState(0)/PRNGKey(0) unless its JSON record
    says otherwise), so a trajectory diff isolates code changes."""
    import platform

    import jax

    return {
        "platform": platform.platform(),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "cpu_count": os.cpu_count(),
        "seed_policy": "fixed seeds (0) per sub-benchmark; explicit "
                       "seeds/keys recorded in each record",
    }


if __name__ == "__main__":
    main()
