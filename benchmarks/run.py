"""Benchmark orchestrator: one entry per paper table/figure + kernels +
roofline.  Prints ``name,us_per_call,derived`` style CSV blocks."""
from __future__ import annotations

import os
import time


def main() -> None:
    t0 = time.time()
    print("== Fig. 4: analog behavioral-model fidelity ==")
    from benchmarks import fig4
    fig4.run()

    print("\n== Table II: accuracy / area / power ==")
    from benchmarks import table2
    table2.run()

    print("\n== Fig. 5: analog/digital breakdown ==")
    from benchmarks import fig5
    fig5.run()

    print("\n== SVM inference: object path vs compiled machine ==")
    from benchmarks import svm_infer
    svm_infer.run()

    print("\n== Kernel micro-bench (Pallas interpret vs jnp oracle) ==")
    from benchmarks import kernelbench
    kernelbench.run()

    if os.path.isdir("runs/dryrun") and os.listdir("runs/dryrun"):
        print("\n== Roofline (single-pod 16x16) ==")
        from benchmarks import roofline
        roofline.run()
    else:
        print("\n(roofline skipped: run `python -m repro.launch.dryrun "
              "--all --mesh both` first)")
    print(f"\ntotal_bench_seconds,{time.time() - t0:.1f}")


if __name__ == "__main__":
    main()
