"""Scale-out benchmark: K >= 10 OvO machines (DESIGN.md §11).

Three records, appended to the BENCH trajectory:

  * **dag_vs_votes** — warm predict throughput of the O(K) DDAG decision
    front vs the dense votes path on the har12 test split (K = 12,
    P = 66), plus their label agreement.  ``--assert-scaling`` gates
    DAG >= 2x votes queries/s and agreement >= 0.99.  A synthetic
    K-ladder (K in {5, 10, 12} float-bit machines) shows how the gap
    opens with P = K(K-1)/2.

  * **lane_ladder** — the size-sharded trainer layout
    (``trainer.shard_lane_layout`` + per-device programs trimmed to their
    shard max) against the seed's global-``n_max`` program, at
    D in {1, 2, 4, 8} shards on 8 virtual XLA host devices (one
    subprocess per rung so ``XLA_FLAGS`` never leaks).  Throughput is
    TRUE lane work per second — sum over pairs of ``n_i^2 * G * C``
    solver-units, identical across rungs — so rung ratios measure
    exactly the padded-work this layout removes.  ``--assert-scaling``
    gates the 8-shard rung >= 3x the 1-shard rung.

    Honesty note: this host pins to ONE physical core, so the 8 virtual
    devices serialize and the >= 3x comes from shard-local padding
    (har12's 198..1582 subset-size spread makes the global-pad layout do
    ~3.9x more solver work than the size-sharded one), not from
    parallel silicon.  On a real multi-core/TPU mesh the same layout
    additionally overlaps shards; the record stores the decomposition
    (``padded_work_units``) so both effects stay separable.

  * **dse_k12** — the portfolio search (greedy/flip + annealing + front
    polish) on a synthetic K = 12, P = 66 space: elapsed, evaluated
    assignments, front size — no 2^P anywhere — plus the small-P oracle
    check: at P = 10 (K = 5) the forced portfolio front must contain
    every exhaustive-front point.

Fit policy: the har12 machine is fitted on a per-class subsample
(``HAR12_FIT_PER_CLASS`` rows/class, ``n_epochs=60``, seed 0) — a
single-core container cannot run 66 pairs x 7 gammas x 6 Cs x 5 folds at
n_max = 1582 in benchmark time; the subsample keeps the full K = 12 /
P = 66 decision structure that this benchmark measures.  All seeds are
in the JSON record.

  PYTHONPATH=src python benchmarks/scale.py --out runs/scale.json \
      --assert-scaling
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap
import time

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

HAR12_FIT_PER_CLASS = 150
HAR12_FIT_EPOCHS = 60
SEED = 0
TRIALS = 3
#: Ladder subsample fraction: keeps har12's relative subset-size spread
#: (the padding-waste ratio) while bounding single-core runtime.
LADDER_FRACTION = 0.25
LADDER_GAMMAS = (0.5, 2.0)
LADDER_CS = (1.0, 10.0)
#: Enough solver epochs that per-rung fixed costs (dispatch, result
#: collection) are amortized against the n^2-scaling lane work the rung
#: ratios are meant to measure.
LADDER_EPOCHS = 30


def _har12_subsample(per_class: int, seed: int = SEED):
    from repro.data import datasets

    ds = datasets.load("har12")
    rng = np.random.default_rng(seed)
    keep = np.concatenate([
        rng.choice(np.flatnonzero(ds.y_train == k),
                   size=min(per_class, int((ds.y_train == k).sum())),
                   replace=False)
        for k in range(ds.n_classes)])
    return ds, ds.x_train[keep], ds.y_train[keep]


def _har12_fraction(fraction: float, seed: int = SEED):
    """Stratified FRACTION subsample of the har12 train split.

    Unlike the per-class cap of :func:`_har12_subsample` (which flattens
    the class-size spread), keeping ``fraction`` of every class preserves
    har12's ~9x spread of OvO pair-subset sizes — the padding-waste
    profile the size-sharded lane layout exists to remove.
    """
    from repro.data import datasets

    ds = datasets.load("har12")
    rng = np.random.default_rng(seed)
    keep = np.concatenate([
        rng.choice(np.flatnonzero(ds.y_train == k),
                   size=max(2, int(round(fraction *
                                         int((ds.y_train == k).sum())))),
                   replace=False)
        for k in range(ds.n_classes)])
    return ds, ds.x_train[keep], ds.y_train[keep]


def _best_of(fn, trials: int = TRIALS) -> float:
    best = None
    for _ in range(trials):
        t0 = time.perf_counter()
        fn()
        wall = time.perf_counter() - t0
        best = wall if best is None else min(best, wall)
    return best


def _float_bit_machine(k, n=400, seed=SEED, **kw):
    """Synthetic deployed machine — decision-path cost without a fit."""
    from repro.api import compile_machine
    from repro.core import ovo, svm as svm_mod

    rng = np.random.RandomState(seed)
    x = rng.rand(n, 3)
    y = rng.randint(0, k, n)
    clfs = []
    for (ci, cj) in ovo.class_pairs(k):
        mask = (y == ci) | (y == cj)
        yy = np.where(y[mask] == ci, 1.0, -1.0)
        m = svm_mod.train_binary(x[mask], yy, "linear", c=1.0, n_epochs=40)
        clfs.append(ovo.FloatBitClassifier(m))
    return compile_machine(clfs, n_classes=k, **kw), x


def run_dag_vs_votes() -> dict:
    """Warm predict throughput: DAG front vs dense votes, K=12 har12."""
    from repro.api import MixedKernelSVM

    ds, xs, ys = _har12_subsample(HAR12_FIT_PER_CLASS)
    t0 = time.perf_counter()
    est = MixedKernelSVM(n_epochs=HAR12_FIT_EPOCHS, seed=SEED).fit(xs, ys)
    fit_s = time.perf_counter() - t0
    xq, yq = ds.x_test, ds.y_test

    m_votes = est.deploy("circuit")
    m_dag = est.deploy("circuit", decider="dag")
    m_votes.predict(xq[:8])                                  # compile
    m_dag.predict(xq[:8])
    t_votes = _best_of(lambda: m_votes.predict(xq))
    t_dag = _best_of(lambda: m_dag.predict(xq))
    lv, ld = m_votes.predict(xq), m_dag.predict(xq)
    agreement = float(np.mean(lv == ld))
    rec = {
        "benchmark": "scale_dag_vs_votes",
        "dataset": "har12",
        "seed": SEED,
        "fit_config": {"per_class": HAR12_FIT_PER_CLASS,
                       "n_epochs": HAR12_FIT_EPOCHS, "fit_s": round(fit_s, 1)},
        "n_queries": int(len(xq)),
        "k": int(ds.n_classes),
        "p": len(est.pairs_),
        "votes_qps": round(len(xq) / t_votes, 1),
        "dag_qps": round(len(xq) / t_dag, 1),
        "dag_speedup": round(t_votes / t_dag, 2),
        "agreement": round(agreement, 4),
        "votes_accuracy": round(float(np.mean(lv == yq)), 4),
        "dag_accuracy": round(float(np.mean(ld == yq)), 4),
        "trials": TRIALS,
    }

    # Table-II-style row for the scale workload (accuracy / area / power
    # per design).  Costs use the DEFAULT cost-model units — the
    # calibrated Table-II units need the three UCI fits, which belong to
    # benchmarks/table2.py; ratios between designs are unit-free anyway.
    from repro.core import hwcost

    cm = hwcost.CostModel()
    row = []
    for design, target in (("linear", "linear"), ("rbf", "rbf"),
                           ("mixed", "circuit")):
        acc = est.score(xq, yq, target=target)
        cost = hwcost.system_cost(est.bank(target), cm)
        row.append({"design": design,
                    "accuracy_pct": round(100 * float(acc), 2),
                    "area_mm2": round(float(cost.area_mm2), 4),
                    "power_mw": round(float(cost.power_mw), 4)})
    rec["table2_row"] = {"dataset": "har12", "designs": row,
                         "cost_model": "default units (uncalibrated)",
                         "fit": rec["fit_config"]}

    # End-to-end closure at K=12 / P=66: pareto (portfolio path — no
    # 2^66 anywhere) and both Monte-Carlo engines (dense + streaming
    # pair-chunked votes fold) on the same fitted estimator.
    xv, yv = xq[:400], yq[:400]
    t0 = time.perf_counter()
    sw = est.pareto(xv, yv)
    pareto_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    mc = est.monte_carlo(xv, yv, n_variants=16)
    stream = est.monte_carlo(xv, yv, n_variants=64, method="iid",
                             mc_chunk=16)
    mc_s = time.perf_counter() - t0
    rec["e2e_k12"] = {
        "pareto_exhaustive": bool(sw.exhaustive),
        "pareto_evaluated": int(sw.assignments.shape[0]),
        "pareto_front_size": int(len(sw.front)),
        "pareto_s": round(pareto_s, 1),
        "mc_dense_mean_acc": round(float(np.mean(mc.accuracy)), 4),
        "mc_stream_mean_acc": round(float(stream.mean), 4),
        "mc_stream_yield": round(float(stream.yield_), 4),
        "mc_s": round(mc_s, 1),
    }

    ladder = []
    for k in (5, 10, 12):
        mv, x = _float_bit_machine(k)
        md, _ = _float_bit_machine(k, decider="dag")
        xq_s = np.tile(x, (4, 1))[:1024]
        mv.predict(xq_s[:8]); md.predict(xq_s[:8])
        tv = _best_of(lambda: mv.predict(xq_s))
        td = _best_of(lambda: md.predict(xq_s))
        ladder.append({"k": k, "p": k * (k - 1) // 2,
                       "votes_qps": round(len(xq_s) / tv, 1),
                       "dag_qps": round(len(xq_s) / td, 1),
                       "dag_speedup": round(tv / td, 2)})
    rec["k_ladder"] = ladder
    return rec


_LADDER_BODY = """
    import os, json, time
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax
    import sys
    sys.path.insert(0, {src!r})
    sys.path.insert(0, {root!r})
    from repro.core import trainer
    from benchmarks.scale import _har12_fraction, LADDER_FRACTION, \\
        LADDER_GAMMAS, LADDER_CS, LADDER_EPOCHS, SEED

    d = {d}
    _, xs, ys = _har12_fraction(LADDER_FRACTION, SEED)
    padded = trainer.pad_pairs(xs, ys, 12, n_folds=5, seed=SEED)
    g = np.asarray(LADDER_GAMMAS); c = np.asarray(LADDER_CS)
    devices = jax.devices()[:d]
    shards = trainer.shard_lane_layout(padded.n_true, d)
    padded_units = sum(
        len(s) * int(max(np.asarray(padded.n_true)[s])) ** 2
        for s in shards) * len(g) * len(c)
    true_units = sum(n * n for n in padded.n_true) * len(g) * len(c)

    def grid():
        return trainer.family_cv_grid_size_sharded(
            padded, "rbf", g, c, LADDER_EPOCHS, devices=devices)

    ref = grid()                                    # compile (per shard)
    best = None
    for _ in range(2):
        t0 = time.perf_counter(); grid()
        w = time.perf_counter() - t0
        best = w if best is None else min(best, w)
    print("RESULT " + json.dumps({{
        "d": d, "n_shards": len(shards), "wall_s": round(best, 3),
        "n_max_global": padded.n_max,
        "shard_maxes": [int(max(np.asarray(padded.n_true)[s]))
                        for s in shards],
        "padded_work_units": int(padded_units),
        "true_work_units": int(true_units),
        "lane_units_per_s": round(true_units / best, 1),
    }}))
"""


def run_lane_ladder() -> dict:
    """D in {1, 2, 4, 8} size-sharded rungs, one subprocess each."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    src = os.path.join(root, "src")
    env = dict(os.environ, PYTHONPATH=src)
    env.pop("XLA_FLAGS", None)
    rungs = []
    for d in (1, 2, 4, 8):
        body = textwrap.dedent(_LADDER_BODY).format(src=src, root=root, d=d)
        res = subprocess.run([sys.executable, "-c", body], env=env,
                             capture_output=True, text=True, timeout=3600)
        if res.returncode != 0:
            raise RuntimeError(
                f"ladder rung d={d} failed:\n{res.stdout}\n{res.stderr}")
        line = [ln for ln in res.stdout.splitlines()
                if ln.startswith("RESULT ")][-1]
        rungs.append(json.loads(line[len("RESULT "):]))
        print(f"  d={d}: wall {rungs[-1]['wall_s']}s, "
              f"lane units/s {rungs[-1]['lane_units_per_s']}, "
              f"padded units {rungs[-1]['padded_work_units']}")
    base = rungs[0]["lane_units_per_s"]
    return {
        "benchmark": "scale_lane_ladder",
        "dataset": "har12",
        "seed": SEED,
        "fraction": LADDER_FRACTION,
        "gammas": list(LADDER_GAMMAS), "cs": list(LADDER_CS),
        "n_epochs": LADDER_EPOCHS,
        "devices_virtual": 8,
        "physical_cores": os.cpu_count(),
        "rungs": rungs,
        "speedup_8v1": round(rungs[-1]["lane_units_per_s"] / base, 2),
        "padding_waste_1shard": round(
            rungs[0]["padded_work_units"] / rungs[0]["true_work_units"], 3),
        "padding_waste_8shard": round(
            rungs[-1]["padded_work_units"] / rungs[-1]["true_work_units"], 3),
        "note": "single physical core: virtual devices serialize; the "
                "speedup is the padded-work reduction of shard-local "
                "trimming (see padded_work_units), which composes with "
                "real device parallelism on multi-core hosts",
    }


def run_dse_k12() -> dict:
    """Portfolio DSE at K=12 (P=66) + the P=10 exhaustive-coverage oracle."""
    from repro.core import dse, hwcost, ovo, trainer
    from repro.core.analog import AnalogBinaryClassifier
    from repro.core.ovo import DigitalLinearClassifier
    from repro.core.svm import SVMModel

    def synthetic_space(k, n_val, seed=SEED):
        rng = np.random.RandomState(seed)
        hw = trainer.default_hw(0)
        gamma = float(trainer.hw_gamma_grid(hw)[3])
        d, m = 3, 6
        cands = []
        for _ in ovo.class_pairs(k):
            w = rng.randn(d)
            lin = SVMModel(kind="linear", support_x=np.zeros((1, d)),
                           support_y=np.ones(1), alpha=np.zeros(1),
                           bias=float(-w.sum() / 2), gamma=1.0, c=1.0, w=w)
            sv = rng.rand(m, d)
            yv = np.where(rng.rand(m) > 0.5, 1.0, -1.0)
            rbf = SVMModel(kind="hw", support_x=sv, support_y=yv,
                           alpha=rng.rand(m) + 0.1,
                           bias=float(rng.randn() * 0.1),
                           gamma=gamma, c=1.0, kernel_fn=hw.kernel_response)
            cands.append((DigitalLinearClassifier.deploy(lin),
                          AnalogBinaryClassifier.deploy(rbf, hw)))
        space = dse.DesignSpace.from_candidates(cands, k, hwcost.CostModel())
        x = rng.rand(n_val, d)
        y = rng.randint(0, k, n_val)
        return space, x, y

    space, x, y = synthetic_space(12, 400)
    t0 = time.perf_counter()
    sw = space.sweep(x, y)
    elapsed = time.perf_counter() - t0
    assert not sw.exhaustive

    space10, x10, y10 = synthetic_space(5, 200)
    ex = space10.sweep(x10, y10)
    po = space10.sweep(x10, y10, max_exhaustive=0)
    ex_front = {tuple(a) for a in np.asarray(ex.assignments[ex.front], bool)}
    po_front = {tuple(a) for a in np.asarray(po.assignments[po.front], bool)}
    covered = not (ex_front - po_front)
    return {
        "benchmark": "scale_dse_k12",
        "seed": SEED,
        "k": 12, "p": 66,
        "evaluated_assignments": int(sw.assignments.shape[0]),
        "front_size": int(len(sw.front)),
        "elapsed_s": round(elapsed, 1),
        "assignments_per_s": round(sw.assignments_per_s, 1),
        "oracle_p10": {
            "exhaustive_front": len(ex_front),
            "portfolio_front": len(po_front),
            "portfolio_covers_exhaustive": bool(covered),
        },
    }


def run(assert_scaling: bool = False) -> dict:
    print("scale: DAG vs votes (K=12 har12 fit + synthetic K ladder)")
    dag = run_dag_vs_votes()
    print(f"  votes {dag['votes_qps']} q/s, dag {dag['dag_qps']} q/s "
          f"({dag['dag_speedup']}x), agreement {dag['agreement']}")
    print("scale: size-sharded lane ladder (8 virtual devices)")
    ladder = run_lane_ladder()
    print(f"  8-shard vs 1-shard lane throughput: {ladder['speedup_8v1']}x")
    print("scale: K=12 portfolio DSE + P=10 oracle coverage")
    k12 = run_dse_k12()
    print(f"  {k12['evaluated_assignments']} assignments in "
          f"{k12['elapsed_s']}s; P=10 oracle covered: "
          f"{k12['oracle_p10']['portfolio_covers_exhaustive']}")
    out = {"dag_vs_votes": dag, "lane_ladder": ladder, "dse_k12": k12}
    if assert_scaling:
        assert_gates(out)
    return out


def assert_gates(out: dict) -> None:
    dag, ladder, k12 = out["dag_vs_votes"], out["lane_ladder"], out["dse_k12"]
    assert dag["dag_speedup"] >= 2.0, \
        f"DAG speedup {dag['dag_speedup']} < 2x"
    assert dag["agreement"] >= 0.99, \
        f"DAG/votes agreement {dag['agreement']} < 0.99"
    assert ladder["speedup_8v1"] >= 3.0, \
        f"8-shard ladder speedup {ladder['speedup_8v1']} < 3x"
    assert k12["oracle_p10"]["portfolio_covers_exhaustive"], \
        "portfolio front missed exhaustive-front points at P=10"
    print("scale: all scaling gates passed")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, metavar="PATH")
    ap.add_argument("--assert-scaling", action="store_true",
                    help="gate DAG >=2x + agreement >=0.99 + ladder >=3x "
                         "+ P=10 oracle coverage")
    args = ap.parse_args()
    res = run()
    if args.out:
        # Written before the gates so a failed run still leaves the
        # numbers behind for diagnosis.
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(res, f, indent=2)
        print(f"JSON -> {args.out}")
    if args.assert_scaling:
        assert_gates(res)


if __name__ == "__main__":
    main()
