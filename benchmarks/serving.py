"""Serving benchmark: sustained throughput of the streaming SVM engine.

Three comparisons at batch-4096-equivalent load (the PR's headline
numbers, appended to the BENCH trajectory):

  * **naive vs micro-batched** — 4096 single queries dispatched one
    device program call at a time (the pre-engine serving story) vs the
    same stream pushed through :class:`repro.serving.SVMEngine`
    closed-loop.  The acceptance gate asserts the engine sustains
    ``>= --assert-speedup`` x the naive queries/s.

  * **open-loop Poisson** — the same engine under a paced arrival process
    (``--rate`` queries/s), reporting achieved throughput, batch
    occupancy and p50/p95/p99 latency from :class:`ServingStats`.

  * **co-batched vs per-model-sequential** — identical mixed-tenant
    micro-batches served either by ONE FleetMachine dispatch per batch or
    by one per-member dispatch per model group (both bucket-padded, both
    labels-only programs).  ``--assert-cobatch`` gates co-batched
    throughput >= the sequential path.

A compile-count gate runs alongside: the engine phases must compile at
most ONE program per padding bucket (no per-request recompiles).

  PYTHONPATH=src python benchmarks/serving.py --out runs/serving.json \
      --assert-speedup 5 --assert-cobatch
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks._fit_cache import fitted                    # noqa: E402
from benchmarks.svm_train import count_compiles             # noqa: E402

N_QUERIES = 4096
MIX_BATCH = 256

#: Throughput phases run best-of-N: the shared container shows transient
#: multi-x slowdown windows (noisy neighbors), and the benchmark measures
#: the engine, not the neighbors.
TRIALS = 3


def _labels_only(machine):
    """The member-machine serving hot path: labels, nothing else."""
    import jax

    return jax.jit(lambda x: machine._forward(x)[2])


def _naive_per_request(machine, queries) -> dict:
    """One ``machine.predict`` call per query — the pre-engine serving
    story: the public compiled path dispatched request-by-request."""
    machine.predict(queries[:1])                            # warmup
    best, out = None, None
    for _ in range(TRIALS):
        t0 = time.perf_counter()
        out = [int(machine.predict(q[None])[0]) for q in queries]
        wall = time.perf_counter() - t0
        best = wall if best is None else min(best, wall)
    return {"wall_s": round(best, 4),
            "queries_per_s": round(len(queries) / best, 1),
            "trials": TRIALS,
            "labels": out}


def _engine_closed_loop(machine, queries, *, max_batch, max_wait_ms) -> dict:
    """Submit every query as fast as possible; measure sustained q/s and
    verify one compiled program per bucket.

    The fleet is built ONCE and shared across trials, so the compile
    gate spans all of them: later trials must be pure cache hits.
    """
    from repro.api import compile_fleet
    from repro.serving import SVMEngine

    with count_compiles() as cc:
        fleet = compile_fleet({"default": machine})
        best = None
        for _ in range(TRIALS):
            with SVMEngine(fleet, max_batch=max_batch,
                           max_wait_ms=max_wait_ms) as eng:
                eng.warmup()
                t0 = time.perf_counter()
                futs = [eng.submit(q) for q in queries]
                out = [f.result(timeout=120.0) for f in futs]
                wall = time.perf_counter() - t0
            if best is None or wall < best[0]:
                best = (wall, eng.stats.summary(), out)
        n_buckets = eng.n_buckets
    wall, summary, out = best
    # The gate counts compiles of the serving program itself (`_labels`);
    # cc.count() alone also sees jnp.zeros/device-constant one-offs.
    return {"wall_s": round(wall, 4),
            "queries_per_s": round(len(queries) / wall, 1),
            "trials": TRIALS,
            "stats": summary,
            "compiles": cc.count("_labels"),
            "compiles_total": cc.count(),
            "n_buckets": n_buckets,
            "labels": out}


def _engine_open_loop(machine, queries, *, rate, max_batch, max_wait_ms,
                      seed) -> dict:
    """Poisson arrivals at ``rate`` queries/s through the engine."""
    from repro.serving import SVMEngine

    rng = np.random.RandomState(seed)
    with SVMEngine(machine, max_batch=max_batch,
                   max_wait_ms=max_wait_ms) as eng:
        eng.warmup()
        futs = []
        next_t = t0 = time.perf_counter()
        for q in queries:
            futs.append(eng.submit(q))
            next_t += rng.exponential(1.0 / rate)
            pause = next_t - time.perf_counter()
            if pause > 0:
                time.sleep(pause)
        for f in futs:
            f.result(timeout=120.0)
        wall = time.perf_counter() - t0
        summary = eng.stats.summary()
    return {"offered_rate": rate,
            "wall_s": round(wall, 4),
            "achieved_queries_per_s": round(len(queries) / wall, 1),
            "stats": summary}


def _cobatch_vs_sequential(fleet, x, idx, *, seed) -> dict:
    """Same mixed micro-batches: one fleet dispatch vs per-model dispatches.

    Both paths are bucket-padded labels-only jitted programs, so the
    measured gap is the co-batching question itself: M small dispatches
    per mixed batch vs one fused dispatch doing every member's banks.
    """
    import jax.numpy as jnp

    from repro.serving import BucketPolicy

    policy = BucketPolicy(max_batch=MIX_BATCH)
    n = x.shape[0]
    member_lab = [_labels_only(m) for m in fleet._members]

    def pad_rows(a, b):
        return np.pad(a, ((0, b - a.shape[0]),) + ((0, 0),) * (a.ndim - 1))

    batches = [(x[o:o + MIX_BATCH], idx[o:o + MIX_BATCH])
               for o in range(0, n, MIX_BATCH)]

    # Warmup every shape either path will touch (group sizes vary per
    # batch, so the sequential path can cross bucket boundaries mid-run).
    fleet._labels_jit(jnp.asarray(batches[0][0]), jnp.asarray(batches[0][1]))
    warmed = set()
    for xb, ib in batches:
        for i, m in enumerate(fleet._members):
            g = xb[ib == i][:, : m.n_features]
            if not len(g):
                continue
            gb = policy.bucket_for(len(g))
            if (i, gb) not in warmed:
                warmed.add((i, gb))
                member_lab[i](jnp.asarray(pad_rows(g, gb)))

    def run_co():
        out = []
        for xb, ib in batches:
            out.append(np.asarray(
                fleet._labels_jit(jnp.asarray(xb), jnp.asarray(ib))))
        return out

    def run_seq():
        outs = []
        for xb, ib in batches:
            out = np.empty(len(ib), np.int32)
            for i, m in enumerate(fleet._members):
                sel = ib == i
                g = xb[sel][:, : m.n_features]
                if not len(g):
                    continue
                gb = policy.bucket_for(len(g))
                lab = np.asarray(member_lab[i](jnp.asarray(pad_rows(g, gb))))
                out[sel] = lab[: len(g)]
            outs.append(out)
        return outs

    t_co = t_seq = None
    for _ in range(TRIALS):
        t0 = time.perf_counter()
        co = run_co()
        dt = time.perf_counter() - t0
        t_co = dt if t_co is None else min(t_co, dt)
        t0 = time.perf_counter()
        seq = run_seq()
        dt = time.perf_counter() - t0
        t_seq = dt if t_seq is None else min(t_seq, dt)

    co = np.concatenate(co)
    seq = np.concatenate(seq)
    np.testing.assert_array_equal(co, seq)   # routing correctness, bit-level
    return {
        "mix_batch": MIX_BATCH,
        "co_batched": {"wall_s": round(t_co, 4),
                       "queries_per_s": round(n / t_co, 1)},
        "per_model_sequential": {"wall_s": round(t_seq, 4),
                                 "queries_per_s": round(n / t_seq, 1)},
        "cobatch_speedup": round(t_seq / t_co, 2),
    }


def run(n_queries: int = N_QUERIES, n_epochs: int = 120, seed: int = 0,
        rate: float = 20000.0, max_batch: int = 256,
        max_wait_ms: float = 2.0, assert_speedup: float | None = None,
        assert_cobatch: bool = False, verbose: bool = True) -> dict:
    from repro.api import compile_fleet
    from repro.data import datasets
    from repro.serving import SVMEngine

    rng = np.random.RandomState(seed)

    # -- single model: naive vs engine, closed and open loop -----------------
    ds, est = fitted("balance", n_epochs=n_epochs, seed=seed)
    machine = est.deploy("circuit")
    pool = np.asarray(ds.x_test, np.float32)
    queries = pool[rng.randint(0, len(pool), n_queries)]

    naive = _naive_per_request(machine, queries)
    closed = _engine_closed_loop(machine, queries, max_batch=max_batch,
                                 max_wait_ms=max_wait_ms)
    np.testing.assert_array_equal(closed.pop("labels"), naive.pop("labels"))
    speedup = round(closed["queries_per_s"] / naive["queries_per_s"], 2)
    open_loop = _engine_open_loop(machine, queries, rate=rate,
                                  max_batch=max_batch,
                                  max_wait_ms=max_wait_ms, seed=seed)

    # -- fleet: mixed-tenant stream, co-batched vs per-model -----------------
    members, pools = {}, {}
    for name in datasets.DATASETS:
        d, e = fitted(name, n_epochs=n_epochs, seed=seed)
        members[name] = e.deploy("circuit")
        pools[name] = np.asarray(d.x_test, np.float32)
    fleet = compile_fleet(members)
    names = list(members)
    idx = rng.randint(0, len(names), n_queries).astype(np.int32)
    xmix = np.zeros((n_queries, fleet.n_features), np.float32)
    for i, name in enumerate(names):
        sel = idx == i
        p = pools[name]
        xmix[sel, : p.shape[1]] = p[rng.randint(0, len(p), int(sel.sum()))]

    cobatch = _cobatch_vs_sequential(fleet, xmix, idx, seed=seed)

    models = [int(i) for i in idx]
    with count_compiles() as cc_fleet:
        best = None
        for _ in range(TRIALS):
            with SVMEngine(fleet, max_batch=max_batch,
                           max_wait_ms=max_wait_ms) as eng:
                eng.warmup()
                t0 = time.perf_counter()
                futs = [eng.submit(xmix[i], models[i])
                        for i in range(n_queries)]
                for f in futs:
                    f.result(timeout=120.0)
                wall = time.perf_counter() - t0
            if best is None or wall < best[0]:
                best = (wall, eng.stats.summary())
        fleet_stream = {"wall_s": round(best[0], 4),
                        "queries_per_s": round(n_queries / best[0], 1),
                        "trials": TRIALS,
                        "stats": best[1],
                        "compiles": cc_fleet.count("_labels"),
                        "compiles_total": cc_fleet.count(),
                        "n_buckets": eng.n_buckets}

    result = {
        "benchmark": "serving",
        "n_queries": n_queries,
        "max_batch": max_batch,
        "max_wait_ms": max_wait_ms,
        "single_model": {
            "dataset": "balance",
            "target": "circuit",
            "naive_per_request": naive,
            "engine_closed_loop": closed,
            "engine_speedup_vs_naive": speedup,
            "engine_open_loop": open_loop,
        },
        "fleet": {
            "models": names,
            "cobatch_vs_sequential": cobatch,
            "engine_mixed_stream": fleet_stream,
        },
    }

    if verbose:
        print("scenario,queries_per_s,p50_ms,p99_ms,occupancy")
        st = closed["stats"]
        print(f"naive_per_request,{naive['queries_per_s']},,,")
        print(f"engine_closed_loop,{closed['queries_per_s']},"
              f"{st['latency_ms']['p50']},{st['latency_ms']['p99']},"
              f"{st['batch_occupancy']}")
        so = open_loop["stats"]
        print(f"engine_open_loop@{rate:g},"
              f"{open_loop['achieved_queries_per_s']},"
              f"{so['latency_ms']['p50']},{so['latency_ms']['p99']},"
              f"{so['batch_occupancy']}")
        sf = fleet_stream["stats"]
        print(f"fleet_mixed_stream,{fleet_stream['queries_per_s']},"
              f"{sf['latency_ms']['p50']},{sf['latency_ms']['p99']},"
              f"{sf['batch_occupancy']}")
        print(f"cobatch_speedup_vs_sequential,"
              f"{cobatch['cobatch_speedup']},,,")
        print(f"engine_speedup_vs_naive,{speedup},,,")

    # -- gates ---------------------------------------------------------------
    for tag, rec in (("single", closed), ("fleet", fleet_stream)):
        if rec["compiles"] > rec["n_buckets"]:
            raise AssertionError(
                f"compile-count gate [{tag}]: {rec['compiles']} compiles "
                f"for {rec['n_buckets']} buckets (>1 program per bucket)")
    if assert_speedup is not None and speedup < assert_speedup:
        raise AssertionError(
            f"engine throughput gate: {speedup}x < required "
            f"{assert_speedup}x vs naive per-request dispatch")
    if assert_cobatch and cobatch["cobatch_speedup"] < 1.0:
        raise AssertionError(
            f"co-batching gate: co-batched {cobatch['co_batched']} slower "
            f"than per-model sequential {cobatch['per_model_sequential']}")
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, help="write JSON here as well")
    ap.add_argument("--n-queries", type=int, default=N_QUERIES)
    ap.add_argument("--n-epochs", type=int, default=120)
    ap.add_argument("--rate", type=float, default=20000.0)
    ap.add_argument("--max-batch", type=int, default=256)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--assert-speedup", type=float, default=None,
                    help="fail unless engine >= this x naive throughput")
    ap.add_argument("--assert-cobatch", action="store_true",
                    help="fail unless co-batched >= per-model sequential")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    result = run(n_queries=args.n_queries, n_epochs=args.n_epochs,
                 seed=args.seed, rate=args.rate, max_batch=args.max_batch,
                 max_wait_ms=args.max_wait_ms,
                 assert_speedup=args.assert_speedup,
                 assert_cobatch=args.assert_cobatch)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"JSON -> {args.out}")


if __name__ == "__main__":
    main()
