"""Serving benchmark: sustained throughput of the streaming SVM engine.

Three comparisons at batch-4096-equivalent load (the PR's headline
numbers, appended to the BENCH trajectory):

  * **naive vs micro-batched** — 4096 single queries dispatched one
    device program call at a time (the pre-engine serving story) vs the
    same stream pushed through :class:`repro.serving.SVMEngine`
    closed-loop.  The acceptance gate asserts the engine sustains
    ``>= --assert-speedup`` x the naive queries/s.

  * **open-loop Poisson** — the same engine under a paced arrival process
    (``--rate`` queries/s), reporting achieved throughput, batch
    occupancy and p50/p95/p99 latency from :class:`ServingStats`.

  * **co-batched vs per-model-sequential** — identical mixed-tenant
    micro-batches served either by ONE FleetMachine dispatch per batch or
    by one per-member dispatch per model group (both bucket-padded, both
    labels-only programs).  ``--assert-cobatch`` gates co-batched
    throughput >= the sequential path.

Two mesh-era phases ride along (PR 10, DESIGN.md §12):

  * **device ladder** — the engine on a ``make_serving_mesh`` at
    d in {1, 2, 4, 8} virtual host devices (one subprocess per rung so
    ``XLA_FLAGS`` never leaks), serving full ``bucket x d`` padded
    batches through the shard_map data-parallel forward.  Each rung
    re-asserts per-device-slice bit identity against the single-device
    program, measures wall throughput AND the per-device slice time, and
    reports ``device_parallel_rows_per_s = G / (serial_overhead +
    t_slice)`` — the critical-path throughput once slices overlap.
    ``--assert-device-scaling`` gates the 8-device rung >= 3x the
    1-device rung on that metric.

    Honesty note (mirrors ``benchmarks/scale.py``): this host pins to
    ONE physical core, so the 8 virtual devices SERIALIZE — measured
    wall throughput cannot scale here and is recorded separately
    (``measured_rows_per_s``).  The gated metric divides the measured
    cycle wall into per-slice execution (bit-identical to the 1-device
    program, so its time is the true per-device cost) and the serial
    dispatch overhead that remains on the critical path when real
    devices run slices concurrently; the JSON keeps the full
    decomposition so both effects stay separable.

  * **goodput under overload** — closed-loop capacity C is measured,
    then a 2C Poisson stream with per-request deadlines drives the
    engine WITH vs WITHOUT admission control (bounded queue + expired
    shedding).  Goodput is deadline-met rows/s; ``--assert-goodput``
    gates the shedding engine strictly above the no-shedding baseline,
    and the record keeps p99-under-overload for both.

A compile-count gate runs alongside: the engine phases must compile at
most ONE program per padding bucket (no per-request recompiles).

  PYTHONPATH=src python benchmarks/serving.py --out runs/serving.json \
      --assert-speedup 5 --assert-cobatch \
      --device-ladder --assert-device-scaling 3 \
      --goodput --assert-goodput
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap
import time

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks._fit_cache import fitted                    # noqa: E402
from benchmarks.svm_train import count_compiles             # noqa: E402

N_QUERIES = 4096
MIX_BATCH = 256

#: Throughput phases run best-of-N: the shared container shows transient
#: multi-x slowdown windows (noisy neighbors), and the benchmark measures
#: the engine, not the neighbors.
TRIALS = 3

#: Device-ladder shape: per-device bucket rows (large enough that slice
#: compute dominates per-device dispatch overhead) and full-batch cycles
#: per rung.
LADDER_BUCKET = 2048
LADDER_CYCLES = 8
LADDER_PASSES = 6
LADDER_DEVICES = (1, 2, 4, 8)

#: Goodput phase: rows per request (keeps the producer loop comfortably
#: faster than the overload), overload factor, deadline, and the offered
#: window in seconds of capacity.
GOODPUT_ROWS = 8
GOODPUT_OVERLOAD = 4.0
GOODPUT_DEADLINE_MS = 25.0
GOODPUT_WINDOW_S = 0.6
# The overload phase caps the engine's dispatch width so the overload is
# STRUCTURAL: at 16 rows per dispatch cycle the engine's service ceiling
# sits far below what the single-threaded Poisson producer can submit
# (~15k requests/s), so offering GOODPUT_OVERLOAD x the measured
# closed-loop capacity genuinely saturates the engine on any runner.  At
# the serving default of 256 the engine outruns the producer and "Nx
# saturation" never materializes (the JSON records submit_wall_s so the
# realized offered rate stays visible next to the nominal one).
GOODPUT_MAX_BATCH = 16


def _labels_only(machine):
    """The member-machine serving hot path: labels, nothing else."""
    import jax

    return jax.jit(lambda x: machine._forward(x)[2])


def _naive_per_request(machine, queries) -> dict:
    """One ``machine.predict`` call per query — the pre-engine serving
    story: the public compiled path dispatched request-by-request."""
    machine.predict(queries[:1])                            # warmup
    best, out = None, None
    for _ in range(TRIALS):
        t0 = time.perf_counter()
        out = [int(machine.predict(q[None])[0]) for q in queries]
        wall = time.perf_counter() - t0
        best = wall if best is None else min(best, wall)
    return {"wall_s": round(best, 4),
            "queries_per_s": round(len(queries) / best, 1),
            "trials": TRIALS,
            "labels": out}


def _engine_closed_loop(machine, queries, *, max_batch, max_wait_ms) -> dict:
    """Submit every query as fast as possible; measure sustained q/s and
    verify one compiled program per bucket.

    The fleet is built ONCE and shared across trials, so the compile
    gate spans all of them: later trials must be pure cache hits.
    """
    from repro.api import compile_fleet
    from repro.serving import SVMEngine

    with count_compiles() as cc:
        fleet = compile_fleet({"default": machine})
        best = None
        for _ in range(TRIALS):
            with SVMEngine(fleet, max_batch=max_batch,
                           max_wait_ms=max_wait_ms) as eng:
                eng.warmup()
                t0 = time.perf_counter()
                futs = [eng.submit(q) for q in queries]
                out = [f.result(timeout=120.0) for f in futs]
                wall = time.perf_counter() - t0
            if best is None or wall < best[0]:
                best = (wall, eng.stats.summary(), out)
        n_buckets = eng.n_buckets
    wall, summary, out = best
    # The gate counts compiles of the serving program itself (`_labels`);
    # cc.count() alone also sees jnp.zeros/device-constant one-offs.
    return {"wall_s": round(wall, 4),
            "queries_per_s": round(len(queries) / wall, 1),
            "trials": TRIALS,
            "stats": summary,
            "compiles": cc.count("_labels"),
            "compiles_total": cc.count(),
            "n_buckets": n_buckets,
            "labels": out}


def _engine_open_loop(machine, queries, *, rate, max_batch, max_wait_ms,
                      seed) -> dict:
    """Poisson arrivals at ``rate`` queries/s through the engine."""
    from repro.serving import SVMEngine

    rng = np.random.RandomState(seed)
    with SVMEngine(machine, max_batch=max_batch,
                   max_wait_ms=max_wait_ms) as eng:
        eng.warmup()
        futs = []
        next_t = t0 = time.perf_counter()
        for q in queries:
            futs.append(eng.submit(q))
            next_t += rng.exponential(1.0 / rate)
            pause = next_t - time.perf_counter()
            if pause > 0:
                time.sleep(pause)
        for f in futs:
            f.result(timeout=120.0)
        wall = time.perf_counter() - t0
        summary = eng.stats.summary()
    return {"offered_rate": rate,
            "wall_s": round(wall, 4),
            "achieved_queries_per_s": round(len(queries) / wall, 1),
            "stats": summary}


def _cobatch_vs_sequential(fleet, x, idx, *, seed) -> dict:
    """Same mixed micro-batches: one fleet dispatch vs per-model dispatches.

    Both paths are bucket-padded labels-only jitted programs, so the
    measured gap is the co-batching question itself: M small dispatches
    per mixed batch vs one fused dispatch doing every member's banks.
    """
    import jax.numpy as jnp

    from repro.serving import BucketPolicy

    policy = BucketPolicy(max_batch=MIX_BATCH)
    n = x.shape[0]
    member_lab = [_labels_only(m) for m in fleet._members]

    def pad_rows(a, b):
        return np.pad(a, ((0, b - a.shape[0]),) + ((0, 0),) * (a.ndim - 1))

    batches = [(x[o:o + MIX_BATCH], idx[o:o + MIX_BATCH])
               for o in range(0, n, MIX_BATCH)]

    # Warmup every shape either path will touch (group sizes vary per
    # batch, so the sequential path can cross bucket boundaries mid-run).
    fleet._labels_jit(jnp.asarray(batches[0][0]), jnp.asarray(batches[0][1]))
    warmed = set()
    for xb, ib in batches:
        for i, m in enumerate(fleet._members):
            g = xb[ib == i][:, : m.n_features]
            if not len(g):
                continue
            gb = policy.bucket_for(len(g))
            if (i, gb) not in warmed:
                warmed.add((i, gb))
                member_lab[i](jnp.asarray(pad_rows(g, gb)))

    def run_co():
        out = []
        for xb, ib in batches:
            out.append(np.asarray(
                fleet._labels_jit(jnp.asarray(xb), jnp.asarray(ib))))
        return out

    def run_seq():
        outs = []
        for xb, ib in batches:
            out = np.empty(len(ib), np.int32)
            for i, m in enumerate(fleet._members):
                sel = ib == i
                g = xb[sel][:, : m.n_features]
                if not len(g):
                    continue
                gb = policy.bucket_for(len(g))
                lab = np.asarray(member_lab[i](jnp.asarray(pad_rows(g, gb))))
                out[sel] = lab[: len(g)]
            outs.append(out)
        return outs

    t_co = t_seq = None
    for _ in range(TRIALS):
        t0 = time.perf_counter()
        co = run_co()
        dt = time.perf_counter() - t0
        t_co = dt if t_co is None else min(t_co, dt)
        t0 = time.perf_counter()
        seq = run_seq()
        dt = time.perf_counter() - t0
        t_seq = dt if t_seq is None else min(t_seq, dt)

    co = np.concatenate(co)
    seq = np.concatenate(seq)
    np.testing.assert_array_equal(co, seq)   # routing correctness, bit-level
    return {
        "mix_batch": MIX_BATCH,
        "co_batched": {"wall_s": round(t_co, 4),
                       "queries_per_s": round(n / t_co, 1)},
        "per_model_sequential": {"wall_s": round(t_seq, 4),
                                 "queries_per_s": round(n / t_seq, 1)},
        "cobatch_speedup": round(t_seq / t_co, 2),
    }


def ladder_fleet(seed: int = 0):
    """Hand-built two-member fleet for the device ladder: heavy enough
    banks (m = 64 support rows, K in {3, 4}) that per-device slice
    compute dominates dispatch overhead, no training required (the fit
    cache is per-process and each rung is a fresh subprocess)."""
    from repro.api import compile_fleet, compile_machine
    from repro.core.svm import SVMModel

    def member(seed, d, m, n_classes):
        gen = np.random.default_rng(seed)
        clfs = []
        for p in range(n_classes * (n_classes - 1) // 2):
            sx = gen.normal(size=(m, d)).astype(np.float32)
            sy = np.where(np.arange(m) % 2 == 0, 1.0, -1.0).astype(
                np.float32)
            alpha = (np.abs(gen.normal(size=m)) + 0.1).astype(np.float32)
            kw = {}
            if p % 2 == 0:
                kw["w"] = ((alpha * sy) @ sx).astype(np.float32)
            clfs.append(SVMModel(
                kind="linear" if p % 2 == 0 else "rbf", support_x=sx,
                support_y=sy, alpha=alpha, bias=float(gen.normal() * 0.1),
                gamma=0.7, c=1.0, **kw))
        return compile_machine(clfs, n_classes=n_classes)

    return compile_fleet({
        "a": member(seed, d=16, m=64, n_classes=3),
        "b": member(seed + 1, d=12, m=64, n_classes=4),
    })


_SERVING_LADDER_BODY = """
    import os, json, time
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {src!r})
    sys.path.insert(0, {root!r})
    import numpy as np
    from benchmarks.serving import ladder_fleet, LADDER_BUCKET, \\
        LADDER_CYCLES, LADDER_PASSES
    from repro.launch.mesh import make_serving_mesh
    from repro.serving import SVMEngine

    d = {d}
    B, G = LADDER_BUCKET, LADDER_BUCKET * d
    fleet = ladder_fleet(seed={seed})
    mesh = make_serving_mesh(d)
    fwd = fleet.shard(mesh)
    gen = np.random.default_rng({seed})
    x = gen.normal(size=(G, fleet.n_features)).astype(np.float32)
    idx = gen.integers(0, fleet.n_models, size=G).astype(np.int32)

    # Per-shard bit identity on this rung's exact batch shape: every
    # device slice of the sharded labels == the single-device program.
    sharded = np.asarray(fwd(x, idx.copy()))
    for dev in range(d):
        s = slice(dev * B, (dev + 1) * B)
        local = np.asarray(fleet._labels_jit(x[s], idx[s].copy()))
        np.testing.assert_array_equal(sharded[s], local)

    # t_slice: the per-device slice cost = the measured single-device
    # program on B rows (bit-identical, so its wall IS the slice cost).
    # MEDIAN of the samples, not min: the decomposition below multiplies
    # t_slice by d, so a lucky minimum would inflate the residual
    # serial_overhead by d x the underestimate — the typical value is
    # the honest estimator for a quantity used subtractively.
    xs, ids = x[:B], idx[:B]
    samples = []
    for _ in range(9):
        t0 = time.perf_counter()
        np.asarray(fleet._labels_jit(xs, ids.copy()))
        samples.append(time.perf_counter() - t0)
    t_slice = float(np.median(samples))

    # Engine closed loop on full G-row padded batches through the mesh.
    # Several SHORT passes with min-selection: the shared container shows
    # transient multi-ms stalls, and one stall inside a long pass poisons
    # its whole average — short passes let the min dodge the stall
    # windows on both the d=1 and d=8 rungs symmetrically.
    wall = None
    with SVMEngine(fleet, max_batch=B, min_bucket=B, max_wait_ms=0.5,
                   mesh=mesh, pipeline_depth=2) as eng:
        eng.warmup()
        for _ in range(LADDER_PASSES):
            eng.stats.reset()
            t0 = time.perf_counter()
            futs = [eng.submit(x, ("a", "b")[i % 2])
                    for i in range(LADDER_CYCLES)]
            for f in futs:
                f.result(timeout=600.0)
            w = time.perf_counter() - t0
            n_batches = eng.stats.summary()["n_batches"]
            assert n_batches == LADDER_CYCLES, n_batches
            wall = w if wall is None else min(wall, w)
    wall_cycle = wall / LADDER_CYCLES
    # Critical path once slices overlap: the serial dispatch overhead
    # (everything beyond the d serialized slice executions) plus ONE
    # slice.  At d=1 this is exactly the measured wall throughput.
    serial_overhead = max(wall_cycle - d * t_slice, 0.0)
    print("RESULT " + json.dumps({{
        "d": d, "rows_global": G, "bucket_per_device": B,
        "cycles": LADDER_CYCLES,
        "wall_s": round(wall, 4),
        "wall_cycle_ms": round(wall_cycle * 1e3, 3),
        "t_slice_ms": round(t_slice * 1e3, 3),
        "serial_overhead_ms": round(serial_overhead * 1e3, 3),
        "measured_rows_per_s": round(G * n_batches / wall, 1),
        "device_parallel_rows_per_s": round(
            G / (serial_overhead + t_slice), 1),
        "bit_identity_slices": d,
    }}))
"""


def run_device_ladder(seed: int = 0) -> dict:
    """d in {1, 2, 4, 8} mesh-sharded engine rungs, one subprocess each."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    src = os.path.join(root, "src")
    env = dict(os.environ, PYTHONPATH=src)
    env.pop("XLA_FLAGS", None)
    rungs = []
    for d in LADDER_DEVICES:
        body = textwrap.dedent(_SERVING_LADDER_BODY).format(
            src=src, root=root, d=d, seed=seed)
        res = subprocess.run([sys.executable, "-c", body], env=env,
                             capture_output=True, text=True, timeout=3600)
        if res.returncode != 0:
            raise RuntimeError(
                f"serving ladder rung d={d} failed:\n{res.stdout}\n"
                f"{res.stderr}")
        line = [ln for ln in res.stdout.splitlines()
                if ln.startswith("RESULT ")][-1]
        rungs.append(json.loads(line[len("RESULT "):]))
        print(f"  d={d}: slice {rungs[-1]['t_slice_ms']}ms, cycle "
              f"{rungs[-1]['wall_cycle_ms']}ms, device-parallel "
              f"{rungs[-1]['device_parallel_rows_per_s']} rows/s "
              f"(measured {rungs[-1]['measured_rows_per_s']})")
    base = rungs[0]["device_parallel_rows_per_s"]
    return {
        "benchmark": "serving_device_ladder",
        "seed": seed,
        "devices_virtual": 8,
        "physical_cores": os.cpu_count(),
        "rungs": rungs,
        "speedup_8v1": round(
            rungs[-1]["device_parallel_rows_per_s"] / base, 2),
        "measured_speedup_8v1": round(
            rungs[-1]["measured_rows_per_s"] /
            rungs[0]["measured_rows_per_s"], 2),
        "note": "single physical core: virtual devices serialize, so "
                "measured wall throughput cannot scale here; the gated "
                "metric is the critical path (serial dispatch overhead + "
                "one slice) with the per-device slice cost measured on "
                "the bit-identical single-device program — the "
                "decomposition (t_slice_ms, serial_overhead_ms, "
                "wall_cycle_ms) keeps serialization and parallel scaling "
                "separable",
    }


def _goodput_run(machine, pool, *, offered_rows_per_s, n_requests, seed,
                 max_batch, max_wait_ms, shed: bool) -> dict:
    """One open-loop Poisson overload run, with or without admission
    control; returns goodput (deadline-met rows/s) and latency stats."""
    from repro.serving import ShedError, SVMEngine

    kw = {}
    if shed:
        kw = dict(shed_expired=True, queue_bound=4 * max_batch)
    rng = np.random.RandomState(seed)
    rate = offered_rows_per_s / GOODPUT_ROWS        # requests/s
    with SVMEngine(machine, max_batch=max_batch, max_wait_ms=max_wait_ms,
                   **kw) as eng:
        eng.warmup()
        futs = []
        next_t = t0 = time.perf_counter()
        for _ in range(n_requests):
            q = pool[rng.randint(0, len(pool), GOODPUT_ROWS)]
            futs.append(eng.submit(q, deadline_ms=GOODPUT_DEADLINE_MS))
            next_t += rng.exponential(1.0 / rate)
            pause = next_t - time.perf_counter()
            if pause > 0:
                time.sleep(pause)
        submit_wall = time.perf_counter() - t0
        n_shed = 0
        for f in futs:
            try:
                f.result(timeout=600.0)
            except ShedError:
                n_shed += 1
        wall = time.perf_counter() - t0
    s = eng.stats.summary()
    met = s.get("deadlines", {}).get("met", 0)
    lat = s.get("latency_ms", {})
    return {
        "shedding": shed,
        "offered_rows_per_s": round(offered_rows_per_s, 1),
        "n_requests": n_requests,
        "rows_per_request": GOODPUT_ROWS,
        "submit_wall_s": round(submit_wall, 4),
        "wall_s": round(wall, 4),
        "served_requests": s["n_requests"],
        "shed_requests": n_shed,
        "shed_detail": s.get("shed"),
        "deadline_met_requests": met,
        "deadline_met_rate_of_offered": round(met / n_requests, 4),
        "goodput_rows_per_s": round(met * GOODPUT_ROWS / wall, 1),
        "p50_ms": lat.get("p50"), "p99_ms": lat.get("p99"),
    }


def run_goodput(machine, pool, *, seed, max_wait_ms,
                max_batch: int = GOODPUT_MAX_BATCH) -> dict:
    """Shed vs no-shed goodput at GOODPUT_OVERLOAD x closed-loop
    saturation."""
    from repro.serving import SVMEngine

    # Capacity: closed-loop rows/s at the goodput request size AND the
    # goodput dispatch width, so the overload multiple is a true overload of the
    # engine as configured for this phase.
    rng = np.random.RandomState(seed)
    with SVMEngine(machine, max_batch=max_batch,
                   max_wait_ms=max_wait_ms) as eng:
        eng.warmup()
        n_cap = 1500
        t0 = time.perf_counter()
        futs = [eng.submit(pool[rng.randint(0, len(pool), GOODPUT_ROWS)])
                for _ in range(n_cap)]
        for f in futs:
            f.result(timeout=600.0)
        cap_wall = time.perf_counter() - t0
    capacity = n_cap * GOODPUT_ROWS / cap_wall
    offered = GOODPUT_OVERLOAD * capacity
    n_requests = max(400, int(offered * GOODPUT_WINDOW_S / GOODPUT_ROWS))
    no_shed = _goodput_run(machine, pool, offered_rows_per_s=offered,
                           n_requests=n_requests, seed=seed,
                           max_batch=max_batch, max_wait_ms=max_wait_ms,
                           shed=False)
    shed = _goodput_run(machine, pool, offered_rows_per_s=offered,
                        n_requests=n_requests, seed=seed,
                        max_batch=max_batch, max_wait_ms=max_wait_ms,
                        shed=True)
    return {
        "benchmark": "serving_goodput",
        "seed": seed,
        "max_batch": max_batch,
        "capacity_rows_per_s": round(capacity, 1),
        "overload_factor": GOODPUT_OVERLOAD,
        "deadline_ms": GOODPUT_DEADLINE_MS,
        "note": "dispatch width capped at GOODPUT_MAX_BATCH so the "
                "single-threaded Poisson producer can sustain a multiple of the "
                "engine's closed-loop capacity; at the serving default "
                "the engine outruns the producer and no overload forms",
        "no_shedding": no_shed,
        "shedding": shed,
        "goodput_gain": round(
            shed["goodput_rows_per_s"] /
            max(no_shed["goodput_rows_per_s"], 1e-9), 2),
    }


def run(n_queries: int = N_QUERIES, n_epochs: int = 120, seed: int = 0,
        rate: float = 20000.0, max_batch: int = 256,
        max_wait_ms: float = 2.0, assert_speedup: float | None = None,
        assert_cobatch: bool = False, device_ladder: bool = False,
        goodput: bool = False, assert_device_scaling: float | None = None,
        assert_goodput: bool = False, core_phases: bool = True,
        verbose: bool = True) -> dict:
    from repro.api import compile_fleet
    from repro.data import datasets
    from repro.serving import SVMEngine

    rng = np.random.RandomState(seed)

    # -- single model: naive vs engine, closed and open loop -----------------
    ds, est = fitted("balance", n_epochs=n_epochs, seed=seed)
    machine = est.deploy("circuit")
    pool = np.asarray(ds.x_test, np.float32)
    queries = pool[rng.randint(0, len(pool), n_queries)]

    result = {
        "benchmark": "serving",
        "n_queries": n_queries,
        "max_batch": max_batch,
        "max_wait_ms": max_wait_ms,
    }

    if not core_phases:
        # Mesh-only leg (CI's 8-virtual-device step): the ladder and
        # goodput phases on the one fitted machine, nothing else.
        if device_ladder or assert_device_scaling is not None:
            print("serving: mesh device ladder (8 virtual devices)")
            result["device_ladder"] = run_device_ladder(seed=seed)
            print(f"  8-dev vs 1-dev device-parallel throughput: "
                  f"{result['device_ladder']['speedup_8v1']}x")
        if goodput or assert_goodput:
            print(f"serving: goodput at {GOODPUT_OVERLOAD:g}x saturation, "
              f"shed vs no-shed")
            result["goodput"] = run_goodput(
                machine, pool, seed=seed, max_wait_ms=max_wait_ms)
            g = result["goodput"]
            print(f"  goodput {g['no_shedding']['goodput_rows_per_s']} -> "
                  f"{g['shedding']['goodput_rows_per_s']} rows/s "
                  f"({g['goodput_gain']}x), p99 "
                  f"{g['no_shedding']['p99_ms']} -> "
                  f"{g['shedding']['p99_ms']}ms")
        _assert_mesh_gates(result, assert_device_scaling, assert_goodput)
        return result

    naive = _naive_per_request(machine, queries)
    closed = _engine_closed_loop(machine, queries, max_batch=max_batch,
                                 max_wait_ms=max_wait_ms)
    np.testing.assert_array_equal(closed.pop("labels"), naive.pop("labels"))
    speedup = round(closed["queries_per_s"] / naive["queries_per_s"], 2)
    open_loop = _engine_open_loop(machine, queries, rate=rate,
                                  max_batch=max_batch,
                                  max_wait_ms=max_wait_ms, seed=seed)

    # -- fleet: mixed-tenant stream, co-batched vs per-model -----------------
    members, pools = {}, {}
    for name in datasets.DATASETS:
        d, e = fitted(name, n_epochs=n_epochs, seed=seed)
        members[name] = e.deploy("circuit")
        pools[name] = np.asarray(d.x_test, np.float32)
    fleet = compile_fleet(members)
    names = list(members)
    idx = rng.randint(0, len(names), n_queries).astype(np.int32)
    xmix = np.zeros((n_queries, fleet.n_features), np.float32)
    for i, name in enumerate(names):
        sel = idx == i
        p = pools[name]
        xmix[sel, : p.shape[1]] = p[rng.randint(0, len(p), int(sel.sum()))]

    cobatch = _cobatch_vs_sequential(fleet, xmix, idx, seed=seed)

    models = [int(i) for i in idx]
    with count_compiles() as cc_fleet:
        best = None
        for _ in range(TRIALS):
            with SVMEngine(fleet, max_batch=max_batch,
                           max_wait_ms=max_wait_ms) as eng:
                eng.warmup()
                t0 = time.perf_counter()
                futs = [eng.submit(xmix[i], models[i])
                        for i in range(n_queries)]
                for f in futs:
                    f.result(timeout=120.0)
                wall = time.perf_counter() - t0
            if best is None or wall < best[0]:
                best = (wall, eng.stats.summary())
        fleet_stream = {"wall_s": round(best[0], 4),
                        "queries_per_s": round(n_queries / best[0], 1),
                        "trials": TRIALS,
                        "stats": best[1],
                        "compiles": cc_fleet.count("_labels"),
                        "compiles_total": cc_fleet.count(),
                        "n_buckets": eng.n_buckets}

    result.update({
        "single_model": {
            "dataset": "balance",
            "target": "circuit",
            "naive_per_request": naive,
            "engine_closed_loop": closed,
            "engine_speedup_vs_naive": speedup,
            "engine_open_loop": open_loop,
        },
        "fleet": {
            "models": names,
            "cobatch_vs_sequential": cobatch,
            "engine_mixed_stream": fleet_stream,
        },
    })

    if device_ladder or assert_device_scaling is not None:
        print("serving: mesh device ladder (8 virtual devices)")
        result["device_ladder"] = run_device_ladder(seed=seed)
        print(f"  8-dev vs 1-dev device-parallel throughput: "
              f"{result['device_ladder']['speedup_8v1']}x")
    if goodput or assert_goodput:
        print(f"serving: goodput at {GOODPUT_OVERLOAD:g}x saturation, "
              f"shed vs no-shed")
        result["goodput"] = run_goodput(
            machine, pool, seed=seed, max_wait_ms=max_wait_ms)
        g = result["goodput"]
        print(f"  goodput {g['no_shedding']['goodput_rows_per_s']} -> "
              f"{g['shedding']['goodput_rows_per_s']} rows/s "
              f"({g['goodput_gain']}x), p99 "
              f"{g['no_shedding']['p99_ms']} -> {g['shedding']['p99_ms']}ms")

    if verbose:
        print("scenario,queries_per_s,p50_ms,p99_ms,occupancy")
        st = closed["stats"]
        print(f"naive_per_request,{naive['queries_per_s']},,,")
        print(f"engine_closed_loop,{closed['queries_per_s']},"
              f"{st['latency_ms']['p50']},{st['latency_ms']['p99']},"
              f"{st['batch_occupancy']}")
        so = open_loop["stats"]
        print(f"engine_open_loop@{rate:g},"
              f"{open_loop['achieved_queries_per_s']},"
              f"{so['latency_ms']['p50']},{so['latency_ms']['p99']},"
              f"{so['batch_occupancy']}")
        sf = fleet_stream["stats"]
        print(f"fleet_mixed_stream,{fleet_stream['queries_per_s']},"
              f"{sf['latency_ms']['p50']},{sf['latency_ms']['p99']},"
              f"{sf['batch_occupancy']}")
        print(f"cobatch_speedup_vs_sequential,"
              f"{cobatch['cobatch_speedup']},,,")
        print(f"engine_speedup_vs_naive,{speedup},,,")

    # -- gates ---------------------------------------------------------------
    for tag, rec in (("single", closed), ("fleet", fleet_stream)):
        if rec["compiles"] > rec["n_buckets"]:
            raise AssertionError(
                f"compile-count gate [{tag}]: {rec['compiles']} compiles "
                f"for {rec['n_buckets']} buckets (>1 program per bucket)")
    if assert_speedup is not None and speedup < assert_speedup:
        raise AssertionError(
            f"engine throughput gate: {speedup}x < required "
            f"{assert_speedup}x vs naive per-request dispatch")
    if assert_cobatch and cobatch["cobatch_speedup"] < 1.0:
        raise AssertionError(
            f"co-batching gate: co-batched {cobatch['co_batched']} slower "
            f"than per-model sequential {cobatch['per_model_sequential']}")
    _assert_mesh_gates(result, assert_device_scaling, assert_goodput)
    return result


def _assert_mesh_gates(result: dict, assert_device_scaling: float | None,
                       assert_goodput: bool) -> None:
    if assert_device_scaling is not None:
        got = result["device_ladder"]["speedup_8v1"]
        if got < assert_device_scaling:
            raise AssertionError(
                f"device-scaling gate: {got}x < required "
                f"{assert_device_scaling}x (8 vs 1 devices, "
                f"device-parallel rows/s on padded work)")
    if assert_goodput:
        g = result["goodput"]
        if not (g["shedding"]["goodput_rows_per_s"] >
                g["no_shedding"]["goodput_rows_per_s"]):
            raise AssertionError(
                f"goodput gate: shedding {g['shedding']} does not "
                f"strictly beat no-shedding {g['no_shedding']}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, help="write JSON here as well")
    ap.add_argument("--n-queries", type=int, default=N_QUERIES)
    ap.add_argument("--n-epochs", type=int, default=120)
    ap.add_argument("--rate", type=float, default=20000.0)
    ap.add_argument("--max-batch", type=int, default=256)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--assert-speedup", type=float, default=None,
                    help="fail unless engine >= this x naive throughput")
    ap.add_argument("--assert-cobatch", action="store_true",
                    help="fail unless co-batched >= per-model sequential")
    ap.add_argument("--device-ladder", action="store_true",
                    help="run the mesh device ladder (d in 1,2,4,8 "
                         "virtual devices, one subprocess per rung)")
    ap.add_argument("--assert-device-scaling", type=float, default=None,
                    metavar="X",
                    help="fail unless 8-device device-parallel rows/s >= "
                         "X times the 1-device rung (implies the ladder)")
    ap.add_argument("--goodput", action="store_true",
                    help="run the 2x-saturation shed vs no-shed phase")
    ap.add_argument("--mesh-only", action="store_true",
                    help="skip the single-device core phases and run only "
                         "the device ladder / goodput legs (CI's "
                         "8-virtual-device step)")
    ap.add_argument("--assert-goodput", action="store_true",
                    help="fail unless shedding goodput strictly beats "
                         "no-shedding (implies the goodput phase)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    result = run(n_queries=args.n_queries, n_epochs=args.n_epochs,
                 seed=args.seed, rate=args.rate, max_batch=args.max_batch,
                 max_wait_ms=args.max_wait_ms,
                 assert_speedup=args.assert_speedup,
                 assert_cobatch=args.assert_cobatch,
                 device_ladder=args.device_ladder,
                 goodput=args.goodput,
                 assert_device_scaling=args.assert_device_scaling,
                 assert_goodput=args.assert_goodput,
                 core_phases=not args.mesh_only)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"JSON -> {args.out}")


if __name__ == "__main__":
    main()
