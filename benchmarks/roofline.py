"""Roofline analysis (deliverable g) from the dry-run artifacts.

Per (arch x shape x mesh) JSON in runs/dryrun/ this derives:

  compute term    = HLO_FLOPs_per_chip / peak_FLOPs_per_chip
  memory term     = HLO_bytes_per_chip / HBM_bw
  collective term = wire_bytes_per_chip / ICI_link_bw

with the scan-trip-count correction (XLA's HloCostAnalysis visits while
bodies once; dryrun.py records per-layer block costs, see block_cost):

  corrected = full_raw - body_scanned + n_layers * body_unrolled

Collective result-bytes become wire bytes with ring-algorithm factors
using each op's replica-group size n:
  all-reduce 2(n-1)/n - all-gather (n-1)/n - reduce-scatter (n-1) -
  all-to-all (n-1)/n - collective-permute 1.

Hardware constants (TPU v5e-class target, per assignment):
  197 TFLOP/s bf16 per chip - 819 GB/s HBM - 50 GB/s/link ICI.

MODEL_FLOPS is 6*N*D (dense train), 6*N_active*D (MoE train), and
2*N(_active)*tokens for inference shapes; the MODEL/HLO ratio flags
remat/dispatch waste.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_RING = {
    "all-reduce": lambda b, n: 2 * b * (n - 1) / max(n, 1),
    "all-gather": lambda b, n: b * (n - 1) / max(n, 1),
    "reduce-scatter": lambda b, n: b * (n - 1),
    "all-to-all": lambda b, n: b * (n - 1) / max(n, 1),
    "collective-permute": lambda b, n: b,
}

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,
    "long_500k": 1,
}


def wire_bytes(colls: dict, default_n: int) -> float:
    total = 0.0
    for op, rec in colls.items():
        fn = _RING.get(op)
        if fn is None:
            continue
        for gs, b in rec.get("by_group", {"?": rec["bytes"]}).items():
            n = int(gs) if gs.isdigit() else default_n
            total += fn(b, n)
    return total


def _coll_sub(a: dict, b: dict, scale_b: float = 1.0) -> dict:
    """a - scale*b per opcode/group (floor 0)."""
    out = {}
    ops_ = set(a) | set(b)
    for op in ops_:
        ra = a.get(op, {"bytes": 0, "count": 0, "by_group": {}})
        rb = b.get(op, {"bytes": 0, "count": 0, "by_group": {}})
        groups = set(ra.get("by_group", {})) | set(rb.get("by_group", {}))
        by_g = {}
        for g in groups:
            v = ra.get("by_group", {}).get(g, 0) - \
                scale_b * rb.get("by_group", {}).get(g, 0)
            by_g[g] = max(v, 0.0)
        out[op] = {"bytes": max(ra["bytes"] - scale_b * rb["bytes"], 0.0),
                   "count": ra["count"], "by_group": by_g}
    return out


def _merge(a: dict, b: dict, scale: float) -> dict:
    out = json.loads(json.dumps(a))
    for op, rb in b.items():
        ra = out.setdefault(op, {"bytes": 0, "count": 0, "by_group": {}})
        ra["bytes"] += scale * rb["bytes"]
        for g, v in rb.get("by_group", {}).items():
            ra["by_group"][g] = ra["by_group"].get(g, 0) + scale * v
    return out


def corrected_cell(rec: dict) -> dict:
    """Apply the scan correction; returns flops/bytes/colls per chip."""
    flops = rec["flops_per_device"]
    bytes_ = rec["bytes_accessed_per_device"]
    colls = rec["collectives"]
    b = rec.get("block_cost") or {}
    if "unrolled" in b:
        L = b["n_layers"]
        flops = flops - b["scanned"]["flops"] + L * b["unrolled"]["flops"]
        bytes_ = bytes_ - b["scanned"]["bytes"] + L * b["unrolled"]["bytes"]
        colls = _merge(_coll_sub(colls, b["scanned"]["collectives"]),
                       b["unrolled"]["collectives"], L)
    return {"flops": flops, "bytes": bytes_, "colls": colls}


def model_flops_per_chip(rec: dict) -> float:
    n_act = rec["active_params"]
    toks = SHAPE_TOKENS[rec["shape"]]
    chips = rec["chips"]
    if rec["arch"].startswith("whisper"):
        # enc-dec: decoder sees S/8 tokens, encoder S/2 frames; fold the
        # encoder (~half the params at 4x the decoder tokens) into an
        # effective decoder-token count.
        toks = toks // 8 + toks // 2
        n_act = n_act // 2
    if rec["kind"] == "train":
        return 6.0 * n_act * toks / chips
    # forward-only: decode batches count B tokens per step
    if rec["kind"] == "decode":
        toks = {"decode_32k": 128, "long_500k": 1}[rec["shape"]]
    return 2.0 * n_act * toks / chips


def analyze(rec: dict) -> dict:
    corr = corrected_cell(rec)
    t_c = corr["flops"] / PEAK_FLOPS
    t_m = corr["bytes"] / HBM_BW
    t_n = wire_bytes(corr["colls"], default_n=16) / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_n),
              key=lambda kv: kv[1])
    mf = model_flops_per_chip(rec)
    hints = {
        "compute": "raise arithmetic efficiency: drop remat recompute "
                   "(remat=dots), larger per-chip batch, bf16-everywhere",
        "memory": "cut HBM traffic: fuse attention (flash), int8 weights "
                  "for decode, 8-bit optimizer states, smaller logits dtype",
        "collective": "reshard: fewer TP boundaries, overlap grad "
                      "all-reduce with microbatch compute, int8 gradient "
                      "compression, keep MoE dispatch within-pod",
    }
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "variant": rec.get("variant", "baseline"),
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
        "bottleneck": dom[0],
        "step_s_lower_bound": max(t_c, t_m, t_n),
        "roofline_frac": (t_c / max(t_c, t_m, t_n)
                          if max(t_c, t_m, t_n) > 0 else 0.0),
        "model_flops_per_chip": mf,
        "hlo_flops_per_chip": corr["flops"],
        "model_over_hlo": mf / corr["flops"] if corr["flops"] else 0.0,
        "temp_gib": rec["memory"]["temp_bytes"] / 2**30,
        "args_gib": rec["memory"]["argument_bytes"] / 2**30,
        "what_moves_it": hints[dom[0]],
    }


def run(dryrun_dir: str = "runs/dryrun", mesh: str = "16x16",
        verbose: bool = True):
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(path))
        if mesh and rec["mesh"] != mesh:
            continue
        rows.append(analyze(rec))
    if verbose:
        print("arch,shape,variant,compute_s,memory_s,collective_s,"
              "bottleneck,roofline_frac,model/hlo,temp_GiB,args_GiB")
        for r in rows:
            print(f"{r['arch']},{r['shape']},{r['variant']},"
                  f"{r['compute_s']:.3e},{r['memory_s']:.3e},"
                  f"{r['collective_s']:.3e},{r['bottleneck']},"
                  f"{r['roofline_frac']:.3f},{r['model_over_hlo']:.3f},"
                  f"{r['temp_gib']:.1f},{r['args_gib']:.1f}")
    return rows


if __name__ == "__main__":
    import sys
    run(mesh=sys.argv[1] if len(sys.argv) > 1 else "16x16")
