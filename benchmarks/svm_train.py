"""SVM training benchmark: sequential Algorithm-1 loop vs batched engine.

Times end-to-end training (Algorithm 1 with hardware-in-the-loop
co-optimization) on Balance Scale, cold-start each path (``jax.clear_caches``
first, so every run pays its own jit compiles), and counts XLA compilations
per path via the ``jax_log_compiles`` log stream.  The batched engine must
compile O(1) programs per kernel family — ``--max-family-compiles`` turns
that into a hard assertion so per-pair recompilation regressions fail CI
loudly.  Emits a JSON record for the perf trajectory:

  PYTHONPATH=src python benchmarks/svm_train.py [--out runs/svm_train.json]

The sequential path is ``selection.train_pairs_sequential`` (2-3 `fit_best`
per OvO pair; every pair's unique subset size forces fresh compiles); the
batched path is ``repro.core.trainer.train_pairs`` (all pairs x folds x
grid in one program per family).  Kernel maps and hyper-parameter
selections are asserted equal before timings are reported.
"""
from __future__ import annotations

import argparse
import contextlib
import json
import logging
import re
import time

import numpy as np

#: Names of the batched engine's jitted entry points; each should compile
#: once per kernel family (3 families), never once per pair.
ENGINE_PROGRAMS = ("_family_program", "_cv_grid_all_pairs",
                   "_refit_all_pairs")

_COMPILE_RE = re.compile(r"Finished XLA compilation of jit\(([^)]*)\)")


class _CompileCounter(logging.Handler):
    def __init__(self):
        super().__init__(level=logging.WARNING)
        self.names: list[str] = []

    def emit(self, record: logging.LogRecord) -> None:
        m = _COMPILE_RE.search(record.getMessage())
        if m:
            self.names.append(m.group(1))

    def count(self, prefix: str | None = None) -> int:
        if prefix is None:
            return len(self.names)
        return sum(1 for n in self.names if n.startswith(prefix))


@contextlib.contextmanager
def count_compiles():
    """Count XLA compilations via the jax_log_compiles WARNING stream."""
    import jax

    handler = _CompileCounter()
    null = logging.NullHandler()
    logger = logging.getLogger("jax._src.dispatch")
    # pxla also logs one "Compiling <name>" WARNING per compile; keep both
    # quiet while counting.  propagate=False alone is not enough — a logger
    # with no handlers routes records to logging.lastResort (stderr), so
    # each gets a NullHandler too.
    loggers = [logger, logging.getLogger("jax._src.interpreters.pxla")]
    prev = jax.config.jax_log_compiles
    prev_propagate = [lg.propagate for lg in loggers]
    jax.config.update("jax_log_compiles", True)
    logger.addHandler(handler)
    for lg in loggers:
        lg.addHandler(null)
        lg.propagate = False
    try:
        yield handler
    finally:
        logger.removeHandler(handler)
        for lg, p in zip(loggers, prev_propagate):
            lg.removeHandler(null)
            lg.propagate = p
        jax.config.update("jax_log_compiles", False if not prev else prev)


def run(n_epochs: int = 200, seed: int = 0, verbose: bool = True,
        max_family_compiles: int | None = None) -> dict:
    import jax

    from repro.core import selection, trainer
    from repro.data import datasets

    ds = datasets.load("balance")
    k = ds.n_classes

    # One throwaway op so backend/BLAS init is not billed to either path.
    np.asarray(jax.numpy.ones((8, 8)) @ jax.numpy.ones((8, 8)))

    jax.clear_caches()
    with count_compiles() as cc_seq:
        t0 = time.perf_counter()
        pairs_seq = selection.train_pairs_sequential(
            ds.x_train, ds.y_train, k, n_epochs=n_epochs, seed=seed)
        t_seq = time.perf_counter() - t0

    jax.clear_caches()
    with count_compiles() as cc_bat:
        t0 = time.perf_counter()
        pairs_bat = trainer.train_pairs(
            ds.x_train, ds.y_train, k, n_epochs=n_epochs, seed=seed)
        t_bat = time.perf_counter() - t0

    map_seq = [p.kernel for p in pairs_seq]
    map_bat = [p.kernel for p in pairs_bat]
    if map_seq != map_bat:
        raise AssertionError(
            f"kernel maps diverge: sequential {map_seq} vs batched {map_bat}")
    for ps, pb in zip(pairs_seq, pairs_bat):
        if (ps.model.gamma, ps.model.c) != (pb.model.gamma, pb.model.c):
            raise AssertionError(
                f"pair {ps.pair}: selected ({ps.model.gamma}, {ps.model.c}) "
                f"vs ({pb.model.gamma}, {pb.model.c})")

    family_compiles = {name: cc_bat.count(name) for name in ENGINE_PROGRAMS}
    result = {
        "benchmark": "svm_train",
        "dataset": "balance",
        "n_epochs": n_epochs,
        "kernel_map": map_bat,
        "sequential_s": round(t_seq, 3),
        "batched_s": round(t_bat, 3),
        "speedup": round(t_seq / t_bat, 2),
        "compiles_sequential": cc_seq.count(),
        "compiles_batched": cc_bat.count(),
        "engine_family_compiles": family_compiles,
    }
    if verbose:
        print("path,seconds,xla_compiles")
        print(f"sequential,{result['sequential_s']},"
              f"{result['compiles_sequential']}")
        print(f"batched,{result['batched_s']},{result['compiles_batched']}")
        print(f"speedup,{result['speedup']}x")
        print(json.dumps(result))

    if max_family_compiles is not None:
        n_fam = sum(family_compiles.values())
        print(f"compile-count assertion: {n_fam} engine-program compiles "
              f"(limit {max_family_compiles}) -> "
              f"{'OK' if n_fam <= max_family_compiles else 'FAIL'}")
        if n_fam > max_family_compiles:
            raise AssertionError(
                f"batched engine compiled {n_fam} family programs "
                f"(> {max_family_compiles}): per-pair recompilation "
                f"regression — check that padding keeps shapes static")
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, help="write JSON here as well")
    ap.add_argument("--n-epochs", type=int, default=200)
    ap.add_argument("--max-family-compiles", type=int, default=None,
                    help="fail if the engine compiles more than this many "
                         "family programs (3 kernel families -> 3 expected)")
    args = ap.parse_args()
    result = run(n_epochs=args.n_epochs,
                 max_family_compiles=args.max_family_compiles)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)


if __name__ == "__main__":
    main()
