"""SVM training benchmark: sequential Algorithm-1 loop vs batched engine.

Times end-to-end training (Algorithm 1 with hardware-in-the-loop
co-optimization) on Balance Scale, cold-start each path (``jax.clear_caches``
first, so every run pays its own jit compiles), and counts XLA compilations
per path via the ``jax_log_compiles`` log stream.  The batched engine must
compile O(1) programs per kernel family — ``--max-family-compiles`` turns
that into a hard assertion so per-pair recompilation regressions fail CI
loudly.  Emits a JSON record for the perf trajectory:

  PYTHONPATH=src python benchmarks/svm_train.py [--out runs/svm_train.json]

The sequential path is ``selection.train_pairs_sequential`` (2-3 `fit_best`
per OvO pair; every pair's unique subset size forces fresh compiles); the
batched path is ``repro.core.trainer.train_pairs`` (all pairs x folds x
grid in one program per family).  Kernel maps and hyper-parameter
selections are asserted equal before timings are reported.

Two further sections cover the fused Pallas solver (DESIGN.md §7): a
reduced-config engine leg with ``use_pallas=True`` (selections asserted
equal to the blocked engine, compile counts under the same O(1) gate) and
``solver_bench`` — lanes/s, HLO-cost peak-memory (fused vs
materialized-Gram baseline) and oracle max-abs-diff, hard-gated by
``--assert-solver-parity``.
"""
from __future__ import annotations

import argparse
import contextlib
import json
import logging
import re
import time

import numpy as np

#: Names of the batched engine's jitted entry points; each should compile
#: once per kernel family (3 families), never once per pair.
ENGINE_PROGRAMS = ("_family_program", "_cv_grid_all_pairs",
                   "_refit_all_pairs")

_COMPILE_RE = re.compile(r"Finished XLA compilation of jit\(([^)]*)\)")


class _CompileCounter(logging.Handler):
    def __init__(self):
        super().__init__(level=logging.WARNING)
        self.names: list[str] = []

    def emit(self, record: logging.LogRecord) -> None:
        m = _COMPILE_RE.search(record.getMessage())
        if m:
            self.names.append(m.group(1))

    def count(self, prefix: str | None = None) -> int:
        if prefix is None:
            return len(self.names)
        return sum(1 for n in self.names if n.startswith(prefix))


@contextlib.contextmanager
def count_compiles():
    """Count XLA compilations via the jax_log_compiles WARNING stream."""
    import jax

    handler = _CompileCounter()
    null = logging.NullHandler()
    logger = logging.getLogger("jax._src.dispatch")
    # pxla also logs one "Compiling <name>" WARNING per compile; keep both
    # quiet while counting.  propagate=False alone is not enough — a logger
    # with no handlers routes records to logging.lastResort (stderr), so
    # each gets a NullHandler too.
    loggers = [logger, logging.getLogger("jax._src.interpreters.pxla")]
    prev = jax.config.jax_log_compiles
    prev_propagate = [lg.propagate for lg in loggers]
    jax.config.update("jax_log_compiles", True)
    logger.addHandler(handler)
    for lg in loggers:
        lg.addHandler(null)
        lg.propagate = False
    try:
        yield handler
    finally:
        logger.removeHandler(handler)
        for lg, p in zip(loggers, prev_propagate):
            lg.removeHandler(null)
            lg.propagate = p
        jax.config.update("jax_log_compiles", False if not prev else prev)


def solver_bench(n_max: int = 256, d: int = 4, n_epochs: int = 40,
                 seed: int = 0, verbose: bool = True,
                 assert_parity: bool = False) -> dict:
    """Micro-bench the fused Pallas solver against the materialized-Gram
    lanes baseline (``kernels.ref.solve_lanes``): lanes/s for both paths,
    an HLO-cost peak-memory estimate per program (argument + output +
    temp bytes from XLA's ``memory_analysis``), and the oracle
    max-abs-diff on the alphas.

    On this CPU container the Pallas path runs in the interpreter, so its
    wall-clock is a numerics-validation figure, not the TPU number; the
    *memory* figures are the point — the fused kernel's program carries no
    (lanes, n, n) Gram temporaries at any ``n_max``, which is the
    acceptance gate (pallas peak strictly below baseline at n_max >= 256).
    """
    import time as _time_mod

    import jax
    import jax.numpy as jnp

    from repro.kernels import ops, ref

    p, g, l = 2, 3, 6
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.rand(p, n_max, d), np.float32)
    y = jnp.asarray(np.where(rng.rand(p, n_max) > 0.5, 1.0, -1.0),
                    np.float32)
    c_box = jnp.asarray(
        rng.rand(p, l, n_max) * 5.0 * (rng.rand(p, l, n_max) > 0.2),
        np.float32)
    gamma = jnp.asarray(rng.rand(p, g) * 4.0 + 0.5, np.float32)
    lanes = p * g * l

    # One jit wrapper per path, compiled once and reused for BOTH the
    # timing loop and the memory_analysis lowering (a fresh lambda would
    # re-compile the expensive Gram-baseline program a second time).
    pallas_fn = jax.jit(lambda xa, ya, ca, ga: ops.solve_lanes(
        xa, ya, ca, ga, kind="rbf", n_epochs=n_epochs))
    base_fn = jax.jit(lambda xa, ya, ca, ga: ref.solve_lanes(
        xa, ya, ca, ga, kind="rbf", n_epochs=n_epochs))

    def timed(fn):
        out = fn(x, y, c_box, gamma)
        out[0].block_until_ready()                      # warm-up/compile
        t0 = _time_mod.perf_counter()
        out = fn(x, y, c_box, gamma)
        out[0].block_until_ready()
        return out, _time_mod.perf_counter() - t0

    def peak_bytes(fn):
        stats = fn.lower(x, y, c_box, gamma).compile().memory_analysis()
        if stats is None:                               # backend w/o stats
            return None
        return {
            "argument_bytes": int(stats.argument_size_in_bytes),
            "output_bytes": int(stats.output_size_in_bytes),
            "temp_bytes": int(stats.temp_size_in_bytes),
            "peak_bytes": int(stats.argument_size_in_bytes
                              + stats.output_size_in_bytes
                              + stats.temp_size_in_bytes),
        }

    (a_pl, _), t_pl = timed(pallas_fn)
    (a_ref, _), t_ref = timed(base_fn)
    maxdiff = float(jnp.max(jnp.abs(a_pl - a_ref)))
    mem_pl = peak_bytes(pallas_fn)
    mem_ref = peak_bytes(base_fn)

    result = {
        "benchmark": "svm_train.solver",
        "n_max": n_max, "d": d, "lanes": lanes, "n_epochs": n_epochs,
        "seed": seed,
        "pallas_interpret": jax.default_backend() != "tpu",
        "pallas_lanes_per_s": round(lanes / t_pl, 1),
        "baseline_lanes_per_s": round(lanes / t_ref, 1),
        "pallas_memory": mem_pl,
        "baseline_memory": mem_ref,
        "oracle_max_abs_diff": maxdiff,
    }
    if verbose:
        print("solver,path,lanes_per_s,peak_bytes")
        print(f"solver,pallas,{result['pallas_lanes_per_s']},"
              f"{mem_pl['peak_bytes'] if mem_pl else 'n/a'}")
        print(f"solver,gram_baseline,{result['baseline_lanes_per_s']},"
              f"{mem_ref['peak_bytes'] if mem_ref else 'n/a'}")
        print(f"solver,oracle_max_abs_diff,{maxdiff:.2e},")
    if assert_parity:
        tol = 5e-4  # f32 round-off over n_epochs of re-associated margins
        ok = maxdiff <= tol
        mem_ok = (mem_pl is None or mem_ref is None
                  or mem_pl["peak_bytes"] < mem_ref["peak_bytes"])
        print(f"solver-parity assertion: max_abs_diff {maxdiff:.2e} "
              f"(tol {tol:g}) -> {'OK' if ok else 'FAIL'}; "
              f"peak-memory pallas < baseline -> "
              f"{'OK' if mem_ok else 'FAIL'}")
        if not ok:
            raise AssertionError(
                f"Pallas solver diverged from the materialized-Gram oracle:"
                f" max|dalpha| = {maxdiff:.3e} > {tol:g}")
        if not mem_ok:
            raise AssertionError(
                f"fused solver peak-memory regression: pallas "
                f"{mem_pl['peak_bytes']} >= baseline "
                f"{mem_ref['peak_bytes']} bytes at n_max={n_max}")
    return result


def run(n_epochs: int = 200, seed: int = 0, verbose: bool = True,
        max_family_compiles: int | None = None,
        assert_solver_parity: bool = False) -> dict:
    import jax

    from repro.core import selection, trainer
    from repro.data import datasets

    ds = datasets.load("balance")
    k = ds.n_classes

    # One throwaway op so backend/BLAS init is not billed to either path.
    np.asarray(jax.numpy.ones((8, 8)) @ jax.numpy.ones((8, 8)))

    jax.clear_caches()
    with count_compiles() as cc_seq:
        t0 = time.perf_counter()
        pairs_seq = selection.train_pairs_sequential(
            ds.x_train, ds.y_train, k, n_epochs=n_epochs, seed=seed)
        t_seq = time.perf_counter() - t0

    jax.clear_caches()
    with count_compiles() as cc_bat:
        t0 = time.perf_counter()
        pairs_bat = trainer.train_pairs(
            ds.x_train, ds.y_train, k, n_epochs=n_epochs, seed=seed)
        t_bat = time.perf_counter() - t0

    map_seq = [p.kernel for p in pairs_seq]
    map_bat = [p.kernel for p in pairs_bat]
    if map_seq != map_bat:
        raise AssertionError(
            f"kernel maps diverge: sequential {map_seq} vs batched {map_bat}")
    for ps, pb in zip(pairs_seq, pairs_bat):
        if (ps.model.gamma, ps.model.c) != (pb.model.gamma, pb.model.c):
            raise AssertionError(
                f"pair {ps.pair}: selected ({ps.model.gamma}, {ps.model.c}) "
                f"vs ({pb.model.gamma}, {pb.model.c})")

    # --- fused Pallas solver engine leg (reduced config) -----------------
    # The Pallas path must reproduce the blocked engine's selections and
    # stay inside the same O(1)-compiles-per-family contract.  On CPU the
    # lanes run in the Pallas *interpreter* (numerics validation, not a
    # speed figure), so this leg subsamples Balance at reduced epochs and
    # compares against the blocked engine at the SAME config.
    n_sub, ep_sub, cv_sub, folds_sub = 160, 60, 30, 3
    rng = np.random.RandomState(seed)
    idx = rng.permutation(len(ds.y_train))[:n_sub]
    xs, ys = ds.x_train[idx], ds.y_train[idx]
    jax.clear_caches()
    pairs_blk = trainer.train_pairs(
        xs, ys, k, n_epochs=ep_sub, cv_epochs=cv_sub, n_folds=folds_sub,
        seed=seed, use_pallas=False)
    jax.clear_caches()
    with count_compiles() as cc_pal:
        t0 = time.perf_counter()
        pairs_pal = trainer.train_pairs(
            xs, ys, k, n_epochs=ep_sub, cv_epochs=cv_sub, n_folds=folds_sub,
            seed=seed, use_pallas=True)
        t_pal = time.perf_counter() - t0
    map_blk = [p.kernel for p in pairs_blk]
    map_pal = [p.kernel for p in pairs_pal]
    if map_blk != map_pal:
        raise AssertionError(
            f"kernel maps diverge with the Pallas solver enabled: "
            f"blocked {map_blk} vs pallas {map_pal}")
    for pb, pp in zip(pairs_blk, pairs_pal):
        if (pb.model.gamma, pb.model.c) != (pp.model.gamma, pp.model.c):
            raise AssertionError(
                f"pair {pb.pair}: blocked selected "
                f"({pb.model.gamma}, {pb.model.c}) vs pallas "
                f"({pp.model.gamma}, {pp.model.c})")
    pallas_family_compiles = {name: cc_pal.count(name)
                              for name in ENGINE_PROGRAMS}

    family_compiles = {name: cc_bat.count(name) for name in ENGINE_PROGRAMS}
    result = {
        "benchmark": "svm_train",
        "dataset": "balance",
        "n_epochs": n_epochs,
        "kernel_map": map_bat,
        "sequential_s": round(t_seq, 3),
        "batched_s": round(t_bat, 3),
        "speedup": round(t_seq / t_bat, 2),
        "compiles_sequential": cc_seq.count(),
        "compiles_batched": cc_bat.count(),
        "engine_family_compiles": family_compiles,
        "pallas_engine": {
            "n_subsample": n_sub, "n_epochs": ep_sub,
            "cv_epochs": cv_sub, "n_folds": folds_sub, "seed": seed,
            "interpret": jax.default_backend() != "tpu",
            "seconds": round(t_pal, 3),
            "kernel_map": map_pal,
            "selections_match_blocked": True,
            "compiles": cc_pal.count(),
            "engine_family_compiles": pallas_family_compiles,
        },
        "solver": solver_bench(seed=seed, verbose=verbose,
                               assert_parity=assert_solver_parity),
    }
    if verbose:
        print("path,seconds,xla_compiles")
        print(f"sequential,{result['sequential_s']},"
              f"{result['compiles_sequential']}")
        print(f"batched,{result['batched_s']},{result['compiles_batched']}")
        print(f"speedup,{result['speedup']}x")
        print(json.dumps(result))

    if max_family_compiles is not None:
        n_fam = sum(family_compiles.values())
        n_fam_pal = sum(pallas_family_compiles.values())
        print(f"compile-count assertion: {n_fam} engine-program compiles "
              f"(blocked), {n_fam_pal} (pallas solver) "
              f"(limit {max_family_compiles}) -> "
              f"{'OK' if max(n_fam, n_fam_pal) <= max_family_compiles else 'FAIL'}")
        if n_fam > max_family_compiles:
            raise AssertionError(
                f"batched engine compiled {n_fam} family programs "
                f"(> {max_family_compiles}): per-pair recompilation "
                f"regression — check that padding keeps shapes static")
        if n_fam_pal > max_family_compiles:
            raise AssertionError(
                f"Pallas-solver engine compiled {n_fam_pal} family programs "
                f"(> {max_family_compiles}): the fused solver path is "
                f"leaking shapes into fresh compiles")
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, help="write JSON here as well")
    ap.add_argument("--n-epochs", type=int, default=200)
    ap.add_argument("--max-family-compiles", type=int, default=None,
                    help="fail if the engine compiles more than this many "
                         "family programs (3 kernel families -> 3 expected); "
                         "applied to the blocked AND Pallas-solver legs")
    ap.add_argument("--assert-solver-parity", action="store_true",
                    help="fail unless the fused Pallas solver matches the "
                         "materialized-Gram oracle to f32 round-off AND its "
                         "HLO-cost peak memory is strictly below the "
                         "baseline's at n_max=256")
    args = ap.parse_args()
    result = run(n_epochs=args.n_epochs,
                 max_family_compiles=args.max_family_compiles,
                 assert_solver_parity=args.assert_solver_parity)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)


if __name__ == "__main__":
    main()
