"""Kernel micro-bench: Pallas (interpret) correctness + jnp-path timing.

On this CPU container the Pallas bodies run in the interpreter (numerics
validation), so wall-clock timing is measured on the pure-jnp oracle —
the same math XLA compiles — to give a stable us_per_call baseline and
to populate run.py's CSV.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, iters=10):
    # Warm up ONCE and reuse the result for the tuple check (the old
    # `isinstance`-on-a-fresh-call pattern evaluated fn twice).
    out = fn(*args)
    (out[0] if isinstance(out, tuple) else out).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run(verbose: bool = True):
    rng = np.random.RandomState(0)
    rows = []

    # RBF kernel matrix (the paper's hot loop)
    x = jnp.asarray(rng.rand(512, 5), jnp.float32)
    z = jnp.asarray(rng.rand(256, 5), jnp.float32)
    f_ref = jax.jit(lambda a, b: ref.rbf_matrix(a, b, 4.0))
    us = _time(f_ref, x, z)
    err = float(jnp.max(jnp.abs(
        ops.rbf_matrix(x, z, 4.0, bm=128, bn=128) - f_ref(x, z))))
    rows.append(("rbf_matrix_512x256x5", us, f"maxerr={err:.2e}"))

    # sech2 hardware kernel
    f_s = jax.jit(lambda a, b: ref.sech2_matrix(a, b, 4.0))
    us = _time(f_s, x, z)
    err = float(jnp.max(jnp.abs(
        ops.rbf_matrix(x, z, 4.0, kind="sech2", bm=128, bn=128) - f_s(x, z))))
    rows.append(("sech2_matrix_512x256x5", us, f"maxerr={err:.2e}"))

    # Fused dual-coordinate-ascent solver lanes (Algorithm 1's hot loop):
    # timing on the materialized-Gram jnp oracle, numerics on the fused
    # Pallas kernel in interpret mode (same layout svm_train.py's solver
    # micro-bench uses for the lanes/s + peak-memory trajectory rows).
    pl_, nl, dl, gl, ll, ep = 2, 96, 4, 2, 4, 30
    xs = jnp.asarray(rng.rand(pl_, nl, dl), jnp.float32)
    ys = jnp.asarray(np.where(rng.rand(pl_, nl) > 0.5, 1.0, -1.0),
                     jnp.float32)
    cb = jnp.asarray(rng.rand(pl_, ll, nl) * 5.0, jnp.float32)
    gm = jnp.asarray(rng.rand(pl_, gl) * 4.0 + 0.5, jnp.float32)
    f_sol = jax.jit(lambda a, b, c, g: ref.solve_lanes(
        a, b, c, g, kind="rbf", n_epochs=ep))
    a_ref, _ = f_sol(xs, ys, cb, gm)        # also serves as the warm-up
    us = _time(f_sol, xs, ys, cb, gm)
    a_pl, _ = ops.solve_lanes(xs, ys, cb, gm, kind="rbf", n_epochs=ep)
    err = float(jnp.max(jnp.abs(a_pl - a_ref)))
    rows.append((f"solver_dca_{pl_*gl*ll}lanes_n{nl}", us,
                 f"maxerr={err:.2e}"))

    # flash attention vs reference
    q = jnp.asarray(rng.randn(1, 4, 256, 64), jnp.float32)
    k = jnp.asarray(rng.randn(1, 2, 256, 64), jnp.float32)
    v = jnp.asarray(rng.randn(1, 2, 256, 64), jnp.float32)
    f_a = jax.jit(lambda a, b, c: ref.attention(a, b, c, causal=True))
    us = _time(f_a, q, k, v)
    err = float(jnp.max(jnp.abs(
        ops.flash_attention(q, k, v, bq=128, bk=128) - f_a(q, k, v))))
    rows.append(("attention_b1h4s256d64", us, f"maxerr={err:.2e}"))

    # SSD scan
    bh, s, dh, ds = 4, 256, 32, 16
    xs = jnp.asarray(rng.randn(bh, s, dh) * 0.3, jnp.float32)
    a = jnp.asarray(-np.abs(rng.randn(bh, s)) * 0.3, jnp.float32)
    bm = jnp.asarray(rng.randn(bh, s, ds) * 0.3, jnp.float32)
    cm = jnp.asarray(rng.randn(bh, s, ds) * 0.3, jnp.float32)
    from repro.models.ssm import ssd_chunked
    # oracle view: batch 1, heads = bh, one state group per head
    f_ssd = jax.jit(lambda x_, a_, b_, c_: ssd_chunked(
        x_.transpose(1, 0, 2)[None], a_.T[None],
        b_.transpose(1, 0, 2)[None], c_.transpose(1, 0, 2)[None],
        chunk=64)[0])
    us = _time(f_ssd, xs, a, bm, cm)
    y_pl, _ = ops.ssd_scan(xs, a, bm, cm, chunk=64)
    y_ref = f_ssd(xs, a, bm, cm)[0].transpose(1, 0, 2)
    err = float(jnp.max(jnp.abs(y_pl - y_ref)))
    rows.append(("ssd_bh4s256", us, f"maxerr={err:.2e}"))

    if verbose:
        print("name,us_per_call,derived")
        for r in rows:
            print(f"{r[0]},{r[1]:.1f},{r[2]}")
    return rows


if __name__ == "__main__":
    run()
