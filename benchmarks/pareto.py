"""Pareto co-optimization benchmark: the kernel-assignment design space.

For each dataset, sweeps ALL 2^P kernel assignments (pair -> linear-digital
vs RBF-analog) through the batched DSE subsystem (``repro.core.dse``):
candidate bits once, accuracy by bit-recombination, cost by one vectorized
pass — and reports the accuracy/area/power Pareto front, the sweep
throughput (assignments/s) and where the Algorithm-1 greedy point lands.

The Algorithm-1 gate (``--assert-alg1``): the greedy point must not be
Pareto-dominated by more than ``--alg1-epsilon`` accuracy.  The strict
selection tie-epsilon (0.005) does NOT hold on this reproduction — the
greedy rule compares *float CV* accuracies per pair, so it is blind to
deployment gaps (e.g. Balance pair (0,1): float tie, but the deployed
analog candidate scores 1.00 on the subset vs 0.926 for the 4-bit
quantized linear), and the DSE legitimately finds strictly better
operating points.  That gap is the subsystem's value; the gate freezes its
magnitude (~3 accuracy points at the reference settings) as a regression
bound, and the JSON records the strict-tie verdict per dataset
(DESIGN.md §5.5).

  PYTHONPATH=src python benchmarks/pareto.py [--out pareto.json]
                                             [--assert-alg1]
"""
from __future__ import annotations

import argparse
import json

import numpy as np

try:
    from benchmarks import _fit_cache
except ImportError:  # run as a script: benchmarks/ itself is sys.path[0]
    import _fit_cache

from repro.core import dse

#: Regression bound on how far the greedy Algorithm-1 point may sit below
#: the Pareto front (see module docstring; measured ~0.03 on Balance).
ALG1_EPSILON = 0.04


def run(n_epochs: int = 120, seed: int = 0, verbose: bool = True,
        alg1_epsilon: float = ALG1_EPSILON) -> dict:
    from repro.data import datasets

    cm = _fit_cache.calibrated_cost_model(n_epochs=n_epochs, seed=seed)
    results = {}
    for name in datasets.DATASETS:
        ds, est = _fit_cache.fitted(name, n_epochs=n_epochs, seed=seed)
        sweep = est.pareto(ds.x_test, ds.y_test, cm=cm)
        alg1 = dse.assignment_from_kernel_map(est.kernel_map_)
        i = sweep.find(alg1)
        margin = sweep.domination_margin(alg1)
        results[name] = {
            "n_pairs": sweep.n_pairs,
            "n_assignments": int(sweep.assignments.shape[0]),
            "exhaustive": sweep.exhaustive,
            "sweep_s": round(sweep.elapsed_s, 4),
            "assignments_per_s": round(sweep.assignments_per_s, 1),
            "front_size": int(len(sweep.front)),
            "front": sweep.front_points(),
            "alg1": {
                "kernel_map": est.kernel_map_,
                "accuracy": float(sweep.accuracy[i]),
                "area_mm2": float(sweep.area[i]),
                "power_mw": float(sweep.power[i]),
                "on_front": bool(i in set(sweep.front.tolist())),
                "domination_margin": round(margin, 6),
                "within_tie_epsilon": bool(margin <= est.tie_margin),
                "within_alg1_epsilon": bool(margin <= alg1_epsilon),
            },
            # accuracy-per-area frontier: best accuracy at or under each
            # front point's area (the curve Fig.-5-style plots would show)
            "accuracy_per_area": [
                {"area_mm2": float(sweep.area[j]),
                 "accuracy": float(np.max(
                     sweep.accuracy[sweep.area <= sweep.area[j]]))}
                for j in sweep.front
            ],
        }

    if verbose:
        print("dataset,n_assignments,sweep_s,assignments_per_s,front_size,"
              "alg1_on_front,alg1_margin")
        for name, r in results.items():
            a = r["alg1"]
            print(f"{name},{r['n_assignments']},{r['sweep_s']},"
                  f"{r['assignments_per_s']},{r['front_size']},"
                  f"{a['on_front']},{a['domination_margin']}")
        for name, r in results.items():
            print(f"-- {name} front (acc, area mm^2, power mW, n_rbf):")
            for p in r["front"]:
                print(f"   {p['accuracy']:.4f}, {p['area_mm2']:.4f}, "
                      f"{p['power_mw']:.4f}, {p['n_rbf']}")
    return {"benchmark": "pareto", "n_epochs": n_epochs,
            "alg1_epsilon": alg1_epsilon, "datasets": results}


def assert_alg1(result: dict) -> None:
    """Hard CI gate: Algorithm 1 stays within epsilon of the front."""
    bad = {
        name: r["alg1"]["domination_margin"]
        for name, r in result["datasets"].items()
        if not r["alg1"]["within_alg1_epsilon"]
    }
    eps = result["alg1_epsilon"]
    print(f"alg1-domination assertion (epsilon {eps}): "
          f"{'FAIL ' + str(bad) if bad else 'OK'}")
    if bad:
        raise AssertionError(
            f"Algorithm-1 design point dominated by more than {eps} "
            f"accuracy on {bad} — greedy selection, deployment or the "
            "cost model regressed")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, help="write JSON here as well")
    ap.add_argument("--n-epochs", type=int, default=120)
    ap.add_argument("--alg1-epsilon", type=float, default=ALG1_EPSILON)
    ap.add_argument("--assert-alg1", action="store_true",
                    help="fail if Algorithm 1 is dominated by more than "
                         "the epsilon on any dataset")
    args = ap.parse_args()
    result = run(n_epochs=args.n_epochs, alg1_epsilon=args.alg1_epsilon)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
    if args.assert_alg1:
        assert_alg1(result)


if __name__ == "__main__":
    main()
