"""Shared cached fits for the paper-reproduction benchmarks.

``table2``, ``fig5`` and ``pareto`` all need the same per-dataset
``MixedKernelSVM`` (Algorithm 1 at the reproduction's reference settings)
and the same Table-II-calibrated cost model.  Each used to refit from
scratch; this module fits each (dataset, n_epochs, seed) combination once
per process so ``benchmarks/run.py`` pays one Algorithm-1 run per dataset
across all three reproductions.
"""
from __future__ import annotations

from repro.api import MixedKernelSVM
from repro.core import hwcost
from repro.data import datasets

_FITS: dict[tuple, tuple] = {}
_CMS: dict[tuple, hwcost.CostModel] = {}


def fitted(name: str, n_epochs: int = 120, seed: int = 0):
    """``(Dataset, fitted MixedKernelSVM)`` for one dataset, cached."""
    key = (name, n_epochs, seed)
    if key not in _FITS:
        ds = datasets.load(name)
        est = MixedKernelSVM(n_epochs=n_epochs, seed=seed).fit(
            ds.x_train, ds.y_train)
        _FITS[key] = (ds, est)
    return _FITS[key]


def calibrated_cost_model(n_epochs: int = 120, seed: int = 0
                          ) -> hwcost.CostModel:
    """The digital cost model calibrated on all three datasets' linear
    columns (the documented Table-II calibration point), cached."""
    key = (n_epochs, seed)
    if key not in _CMS:
        linear_systems = {
            name: fitted(name, n_epochs, seed)[1].bank("linear")
            for name in datasets.DATASETS
        }
        _CMS[key] = hwcost.calibrate_digital(linear_systems)
    return _CMS[key]


def clear() -> None:
    """Drop all cached fits (tests)."""
    _FITS.clear()
    _CMS.clear()
