"""Table II reproduction: accuracy / area / power per dataset x design.

Runs Algorithm 1 on all three datasets, calibrates the digital cost-model
units on the paper's linear column (the documented calibration point),
then reports every design point + the paper's headline ratios:

  * mixed vs all-linear accuracy delta  (paper: +7.7% mean, +20% max)
  * all-RBF-digital / mixed area+power  (paper: 108x, 17x mean)
  * analog RBF vs digital RBF per-classifier (paper: ~109x, ~16x)
"""
from __future__ import annotations

import numpy as np

from repro.core import hwcost, selection
from repro.core.ovo import DigitalRBFClassifier
from repro.data import datasets


def run(n_epochs: int = 120, seed: int = 0, verbose: bool = True):
    results = {}
    linear_systems = {}
    for name in datasets.DATASETS:
        ds = datasets.load(name)
        res = selection.explore(ds.x_train, ds.y_train, ds.n_classes,
                                n_epochs=n_epochs, seed=seed)
        results[name] = (ds, res)
        linear_systems[name] = res.linear_circuit

    cm = hwcost.calibrate_digital(linear_systems)

    rows = []
    deltas, area_gains, power_gains = [], [], []
    for name, (ds, res) in results.items():
        accs = {
            "linear": res.linear_circuit.accuracy(ds.x_test, ds.y_test),
            "rbf": res.rbf_circuit.accuracy(ds.x_test, ds.y_test),
            "mixed": res.mixed_circuit.accuracy(ds.x_test, ds.y_test),
        }
        costs = {
            "linear": hwcost.system_cost(res.linear_circuit, cm),
            "rbf": hwcost.system_cost(res.rbf_circuit, cm),
            "mixed": hwcost.system_cost(res.mixed_circuit, cm),
        }
        for design in ("linear", "rbf", "mixed"):
            c = costs[design]
            n_rbf = res.n_rbf if design == "mixed" else \
                (3 if design == "rbf" else 0)
            paper = hwcost.TABLE2[name][design]
            rows.append((name, design, 100 * accs[design], c.area_mm2,
                         c.power_mw, n_rbf, len(res.kernel_map) - n_rbf,
                         paper))
        deltas.append(accs["mixed"] - accs["linear"])
        area_gains.append(costs["rbf"].area_mm2 / costs["mixed"].area_mm2)
        power_gains.append(costs["rbf"].power_mw / costs["mixed"].power_mw)

    # analog-vs-digital RBF per-classifier comparison
    ad_area, ad_power = [], []
    for name, (ds, res) in results.items():
        for p in res.pairs:
            if p.kernel != "rbf":
                continue
            from repro.core.analog import AnalogBinaryClassifier, AnalogRBFModel
            import jax
            hw = AnalogRBFModel.from_circuit(key=jax.random.PRNGKey(seed))
            a_clf = AnalogBinaryClassifier.deploy(p.model_hw, hw)
            d_clf = DigitalRBFClassifier.deploy(p.model_rbf)
            a_a, a_p = cm.analog_rbf(a_clf)
            d_a, d_p = cm.digital(hwcost.digital_rbf_classifier_ge(d_clf))
            ad_area.append(d_a / a_a)
            ad_power.append(d_p / a_p)

    summary = {
        "mean_acc_delta_pct": 100 * float(np.mean(deltas)),
        "max_acc_delta_pct": 100 * float(np.max(deltas)),
        "mean_area_gain_vs_digital_rbf": float(np.mean(area_gains)),
        "mean_power_gain_vs_digital_rbf": float(np.mean(power_gains)),
        "analog_vs_digital_rbf_area": float(np.mean(ad_area)) if ad_area else 0,
        "analog_vs_digital_rbf_power": float(np.mean(ad_power)) if ad_power else 0,
        "calibrated_area_per_ge_um2": cm.area_per_ge_um2,
        "calibrated_power_per_ge_nw": cm.power_per_ge_nw,
    }

    if verbose:
        print("dataset,design,acc_pct,area_mm2,power_mw,n_rbf,n_linear,"
              "paper(acc,area,power,rbf,lin)")
        for r in rows:
            print(f"{r[0]},{r[1]},{r[2]:.1f},{r[3]:.4f},{r[4]:.4f},"
                  f"{r[5]},{r[6]},{r[7]}")
        for k, v in summary.items():
            print(f"{k},{v:.3f}")
    return rows, summary


if __name__ == "__main__":
    run()
