"""Table II reproduction: accuracy / area / power per dataset x design.

Fits a MixedKernelSVM (Algorithm 1) on all three datasets, calibrates the
digital cost-model units on the paper's linear column (the documented
calibration point), then reports every design point + the paper's headline
ratios:

  * mixed vs all-linear accuracy delta  (paper: +7.7% mean, +20% max)
  * all-RBF-digital / mixed area+power  (paper: 108x, 17x mean)
  * analog RBF vs digital RBF per-classifier (paper: ~109x, ~16x)

Accuracies are evaluated on the compiled machines (`est.deploy`) — the
single batched inference path — while the cost model walks the object banks
(`est.bank`), which carry the per-classifier hardware structure.
"""
from __future__ import annotations

import numpy as np

try:
    from benchmarks import _fit_cache
except ImportError:  # run as a script: benchmarks/ itself is sys.path[0]
    import _fit_cache

from repro.core import hwcost
from repro.core.analog import AnalogBinaryClassifier
from repro.core.ovo import DigitalRBFClassifier
from repro.data import datasets


def run(n_epochs: int = 120, seed: int = 0, verbose: bool = True):
    # Shared cached fits: table2 / fig5 / pareto each need the same
    # Algorithm-1 machines; run.py pays one fit per dataset across them.
    results = {name: _fit_cache.fitted(name, n_epochs=n_epochs, seed=seed)
               for name in datasets.DATASETS}
    cm = _fit_cache.calibrated_cost_model(n_epochs=n_epochs, seed=seed)

    # Table II design -> (accuracy target, cost-model bank target)
    designs = {"linear": "linear", "rbf": "rbf", "mixed": "circuit"}

    rows = []
    deltas, area_gains, power_gains = [], [], []
    for name, (ds, est) in results.items():
        accs = {d: est.score(ds.x_test, ds.y_test, target=t)
                for d, t in designs.items()}
        costs = {d: hwcost.system_cost(est.bank(t), cm)
                 for d, t in designs.items()}
        for design in designs:
            c = costs[design]
            n_rbf = est.n_rbf_ if design == "mixed" else \
                (3 if design == "rbf" else 0)
            paper = hwcost.TABLE2[name][design]
            rows.append((name, design, 100 * accs[design], c.area_mm2,
                         c.power_mw, n_rbf, len(est.kernel_map_) - n_rbf,
                         paper))
        deltas.append(accs["mixed"] - accs["linear"])
        area_gains.append(costs["rbf"].area_mm2 / costs["mixed"].area_mm2)
        power_gains.append(costs["rbf"].power_mw / costs["mixed"].power_mw)

    # analog-vs-digital RBF per-classifier comparison
    ad_area, ad_power = [], []
    for name, (ds, est) in results.items():
        for p in est.pairs_:
            if p.kernel != "rbf":
                continue
            a_clf = AnalogBinaryClassifier.deploy(p.model_hw, est.hw_)
            d_clf = DigitalRBFClassifier.deploy(p.model_rbf)
            a_a, a_p = cm.analog_rbf(a_clf)
            d_a, d_p = cm.digital(hwcost.digital_rbf_classifier_ge(d_clf))
            ad_area.append(d_a / a_a)
            ad_power.append(d_p / a_p)

    summary = {
        "mean_acc_delta_pct": 100 * float(np.mean(deltas)),
        "max_acc_delta_pct": 100 * float(np.max(deltas)),
        "mean_area_gain_vs_digital_rbf": float(np.mean(area_gains)),
        "mean_power_gain_vs_digital_rbf": float(np.mean(power_gains)),
        "analog_vs_digital_rbf_area": float(np.mean(ad_area)) if ad_area else 0,
        "analog_vs_digital_rbf_power": float(np.mean(ad_power)) if ad_power else 0,
        "calibrated_area_per_ge_um2": cm.area_per_ge_um2,
        "calibrated_power_per_ge_nw": cm.power_per_ge_nw,
    }

    if verbose:
        print("dataset,design,acc_pct,area_mm2,power_mw,n_rbf,n_linear,"
              "paper(acc,area,power,rbf,lin)")
        for r in rows:
            print(f"{r[0]},{r[1]},{r[2]:.1f},{r[3]:.4f},{r[4]:.4f},"
                  f"{r[5]},{r[6]},{r[7]}")
        for k, v in summary.items():
            print(f"{k},{v:.3f}")
    return rows, summary


if __name__ == "__main__":
    run()
