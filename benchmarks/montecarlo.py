"""Monte-Carlo variation benchmark: the variant axis end-to-end (§6).

For each paper dataset, runs the variation-aware kernel-assignment sweep
(``MixedKernelSVM.pareto(n_variants=...)``) — every assignment scored with
mean/std/worst-case accuracy and yield over V sampled fabricated instances
— and reports:

* **variants/s** — throughput of the one-jitted-forward
  ``MonteCarloMachine`` at the reference V,
* **compile budget** — the variant axis must cost at most 2 additional jit
  compiles over the nominal DSE path (the MC forward + the batched
  recombination); ``--assert-compiles`` turns the measurement into a gate,
* **nominal bit-identity** — variant 0 (zero offsets) must reproduce the
  nominal ``CandidateMachine`` bits AND scores bit-exactly
  (``--assert-nominal`` gates it; DESIGN.md §6.3),
* **yield-vs-sigma** — the Algorithm-1 circuit's accuracy distribution and
  yield as the process sigmas scale jointly (0.5x .. 4x),
* **nominal vs robust vertex** — where the Algorithm-1 design sits in
  mean/worst/yield terms, and what the robust rule
  (``select(yield_floor=...)``) deploys instead.

All mismatch is drawn from explicit jax PRNG keys derived from
``--mc-seed``; the seed is recorded in the JSON for reproducibility.

  PYTHONPATH=src python benchmarks/montecarlo.py [--out montecarlo.json]
                                                 [--assert-nominal]
                                                 [--assert-compiles]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

try:
    from benchmarks import _fit_cache
    from benchmarks.svm_train import count_compiles
except ImportError:  # run as a script: benchmarks/ itself is sys.path[0]
    import _fit_cache
    from svm_train import count_compiles

#: Reference variant count (the acceptance setting) and sigma ladder.
N_VARIANTS = 64
SIGMA_SCALES = (0.5, 1.0, 2.0, 4.0)

#: The variant axis may cost at most this many extra jit compiles.
MAX_MC_COMPILES = 2

#: Yield floors the robust deployment rule is probed at.
YIELD_FLOORS = (0.5, 0.9)


def run(n_epochs: int = 120, seed: int = 0, mc_seed: int = 0,
        n_variants: int = N_VARIANTS,
        sigma_scales: tuple = SIGMA_SCALES,
        verbose: bool = True) -> dict:
    import jax

    from repro.core import dse
    from repro.data import datasets

    cm = _fit_cache.calibrated_cost_model(n_epochs=n_epochs, seed=seed)
    results = {}
    for name in datasets.DATASETS:
        ds, est = _fit_cache.fitted(name, n_epochs=n_epochs, seed=seed)
        key = jax.random.PRNGKey(mc_seed)
        nominal_acc = est.score(ds.x_test, ds.y_test, target="circuit")
        floor = round(nominal_acc - 0.02, 6)

        # Warm the nominal DSE path, then lower the MC machine OUTSIDE the
        # counted block (lowering runs eager sampling/interp ops); the
        # counted sweep may then add at most the MC forward + the batched
        # recombination program.
        est.pareto(ds.x_test, ds.y_test, cm=cm)
        machine = est.monte_carlo_machine(n_variants, key)
        with count_compiles() as cc:
            sweep = est.pareto(ds.x_test, ds.y_test, cm=cm,
                               n_variants=n_variants, key=key,
                               accuracy_floor=floor)
        mc_compiles = cc.count()

        # Nominal bit-identity: variant 0 vs the nominal candidate machine.
        nominal_machine = est.design_space(cm).machine
        bits_exact = bool(np.array_equal(
            machine.pair_bits(ds.x_test)[0],
            nominal_machine.pair_bits(ds.x_test)))
        scores_exact = bool(np.array_equal(
            machine.pair_scores(ds.x_test)[0],
            nominal_machine.pair_scores(ds.x_test)))

        # Throughput of the jitted MC forward (already warm).
        reps, t0 = 10, time.perf_counter()
        for _ in range(reps):
            machine.pair_bits(ds.x_test)
        per_call = (time.perf_counter() - t0) / reps
        variants_per_s = n_variants / per_call

        # Algorithm-1 vertex: nominal vs robust statistics.
        alg1 = dse.assignment_from_kernel_map(est.kernel_map_)
        i = sweep.find(alg1)
        vertex = {
            "kernel_map": est.kernel_map_,
            "accuracy_nominal": float(sweep.accuracy[i]),
            "acc_mean": float(sweep.acc_mean[i]),
            "acc_std": float(sweep.acc_std[i]),
            "acc_worst": float(sweep.acc_worst[i]),
            "yield_frac": float(sweep.yield_[i]),
            "on_robust_front": bool(i in set(sweep.robust_front.tolist())),
        }

        # Robust deployment at reference yield floors.
        robust_deploys = {}
        for yf in YIELD_FLOORS:
            try:
                j = sweep.select(yield_floor=yf)
                robust_deploys[str(yf)] = {
                    "kernel_map": sweep.kernel_map(j),
                    "acc_mean": float(sweep.acc_mean[j]),
                    "yield_frac": float(sweep.yield_[j]),
                    "area_mm2": float(sweep.area[j]),
                    "power_mw": float(sweep.power[j]),
                }
            except ValueError:
                robust_deploys[str(yf)] = None

        # Yield-vs-sigma: the Algorithm-1 circuit under scaled mismatch.
        sigma_curve = []
        for s in sigma_scales:
            mc = est.monte_carlo(ds.x_test, ds.y_test,
                                 n_variants=n_variants, key=key,
                                 sigma_scale=float(s))
            sigma_curve.append({
                "sigma_scale": float(s),
                "acc_mean": round(mc.mean, 6),
                "acc_std": round(mc.std, 6),
                "acc_worst": round(mc.worst, 6),
                "yield_frac": round(mc.yield_at(floor), 6),
            })

        results[name] = {
            "n_pairs": sweep.n_pairs,
            "n_assignments": int(sweep.assignments.shape[0]),
            "n_variants": int(n_variants),
            "accuracy_floor": floor,
            "mc_compiles": int(mc_compiles),
            "mc_compile_names": cc.names,
            "nominal_bits_exact": bits_exact,
            "nominal_scores_exact": scores_exact,
            "mc_forward_s": round(per_call, 6),
            "variants_per_s": round(variants_per_s, 1),
            "sweep_s": round(sweep.elapsed_s, 4),
            "alg1": vertex,
            "robust_deploys": robust_deploys,
            "robust_front": sweep.front_points(robust=True),
            "yield_vs_sigma": sigma_curve,
        }
        # The yield deploy mutates assignment_; keep the cached fit clean
        # for any benchmark sharing it through _fit_cache.
        est.assignment_ = None

    if verbose:
        print("dataset,mc_compiles,nominal_bits_exact,nominal_scores_exact,"
              "variants_per_s,alg1_yield,alg1_worst")
        for name, r in results.items():
            a = r["alg1"]
            print(f"{name},{r['mc_compiles']},{r['nominal_bits_exact']},"
                  f"{r['nominal_scores_exact']},{r['variants_per_s']},"
                  f"{a['yield_frac']:.3f},{a['acc_worst']:.3f}")
        for name, r in results.items():
            print(f"-- {name} yield vs sigma (floor {r['accuracy_floor']}):")
            for p in r["yield_vs_sigma"]:
                print(f"   x{p['sigma_scale']}: mean {p['acc_mean']:.3f}, "
                      f"worst {p['acc_worst']:.3f}, "
                      f"yield {p['yield_frac']:.3f}")
    return {"benchmark": "montecarlo", "n_epochs": n_epochs, "seed": seed,
            "mc_seed": mc_seed, "n_variants": n_variants,
            "datasets": results}


def assert_nominal(result: dict) -> None:
    """Hard CI gate: the zero-offset variant IS the nominal compiled path."""
    bad = {
        name: {"bits": r["nominal_bits_exact"],
               "scores": r["nominal_scores_exact"]}
        for name, r in result["datasets"].items()
        if not (r["nominal_bits_exact"] and r["nominal_scores_exact"])
    }
    print(f"nominal-variant bit-identity assertion: "
          f"{'FAIL ' + str(bad) if bad else 'OK'}")
    if bad:
        raise AssertionError(
            f"zero-offset Monte-Carlo variant drifted from the nominal "
            f"compiled path on {bad} — the §6.3 bit-identity contract "
            "(structural nominal-subgraph reuse) regressed")


def assert_compiles(result: dict,
                    budget: int = MAX_MC_COMPILES) -> None:
    """Hard CI gate: the variant axis costs <= `budget` extra compiles."""
    bad = {
        name: r["mc_compile_names"]
        for name, r in result["datasets"].items()
        if r["mc_compiles"] > budget
    }
    print(f"mc-compile budget assertion (<= {budget}): "
          f"{'FAIL ' + str(bad) if bad else 'OK'}")
    if bad:
        raise AssertionError(
            f"Monte-Carlo sweep compiled more than {budget} extra "
            f"programs: {bad} — the variant axis is leaking shapes")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, help="write JSON here as well")
    ap.add_argument("--n-epochs", type=int, default=120)
    ap.add_argument("--n-variants", type=int, default=N_VARIANTS)
    ap.add_argument("--mc-seed", type=int, default=0)
    ap.add_argument("--assert-nominal", action="store_true",
                    help="fail unless the zero-offset variant is "
                         "bit-identical to the nominal compiled path")
    ap.add_argument("--assert-compiles", action="store_true",
                    help="fail if the variant axis costs more than "
                         f"{MAX_MC_COMPILES} extra jit compiles")
    args = ap.parse_args()
    result = run(n_epochs=args.n_epochs, mc_seed=args.mc_seed,
                 n_variants=args.n_variants)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
    if args.assert_nominal:
        assert_nominal(result)
    if args.assert_compiles:
        assert_compiles(result)


if __name__ == "__main__":
    main()
