"""Monte-Carlo variation benchmark: the variant axis end-to-end (§6).

For each paper dataset, runs the variation-aware kernel-assignment sweep
(``MixedKernelSVM.pareto(n_variants=...)``) — every assignment scored with
mean/std/worst-case accuracy and yield over V sampled fabricated instances
— and reports:

* **variants/s** — throughput of the one-jitted-forward
  ``MonteCarloMachine`` at the reference V,
* **compile budget** — the variant axis must cost at most 2 additional jit
  compiles over the nominal DSE path (the MC forward + the batched
  recombination); ``--assert-compiles`` turns the measurement into a gate,
* **nominal bit-identity** — variant 0 (zero offsets) must reproduce the
  nominal ``CandidateMachine`` bits AND scores bit-exactly
  (``--assert-nominal`` gates it; DESIGN.md §6.3),
* **yield-vs-sigma** — the Algorithm-1 circuit's accuracy distribution and
  yield as the process sigmas scale jointly (0.5x .. 4x),
* **nominal vs robust vertex** — where the Algorithm-1 design sits in
  mean/worst/yield terms, and what the robust rule
  (``select(yield_floor=...)``) deploys instead.

All mismatch is drawn from explicit jax PRNG keys derived from
``--mc-seed``; the seed is recorded in the JSON for reproducibility.

  PYTHONPATH=src python benchmarks/montecarlo.py [--out montecarlo.json]
                                                 [--assert-nominal]
                                                 [--assert-compiles]

The streaming scaling leg (``--scaling``; DESIGN.md §10) sweeps the
flat-memory engine from V = 64 to ``--v-max`` (default 10^6) on one
dataset and records variants/s, the streamed yield + its confidence
interval, and the XLA ``memory_analysis`` of the one compiled chunk
step.  ``--assert-flat-memory`` gates that every ladder point ran
through that SAME program (zero extra compiles, identical peak temp
bytes); ``--assert-ci-width`` gates the final yield CI width.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

try:
    from benchmarks import _fit_cache
    from benchmarks.svm_train import count_compiles
except ImportError:  # run as a script: benchmarks/ itself is sys.path[0]
    import _fit_cache
    from svm_train import count_compiles

#: Reference variant count (the acceptance setting) and sigma ladder.
N_VARIANTS = 64
SIGMA_SCALES = (0.5, 1.0, 2.0, 4.0)

#: The variant axis may cost at most this many extra jit compiles.
MAX_MC_COMPILES = 2

#: Yield floors the robust deployment rule is probed at.
YIELD_FLOORS = (0.5, 0.9)

#: Streaming scaling-ladder defaults: V multiplies by 16 from 64 up to
#: --v-max; the chunk step is compiled ONCE for every ladder point.
SCALING_DATASET = "balance"
SCALING_CHUNK = 2048
SCALING_X = 64


def run(n_epochs: int = 120, seed: int = 0, mc_seed: int = 0,
        n_variants: int = N_VARIANTS,
        sigma_scales: tuple = SIGMA_SCALES,
        verbose: bool = True) -> dict:
    import jax

    from repro.core import dse
    from repro.data import datasets

    cm = _fit_cache.calibrated_cost_model(n_epochs=n_epochs, seed=seed)
    results = {}
    for name in datasets.DATASETS:
        ds, est = _fit_cache.fitted(name, n_epochs=n_epochs, seed=seed)
        key = jax.random.PRNGKey(mc_seed)
        nominal_acc = est.score(ds.x_test, ds.y_test, target="circuit")
        floor = round(nominal_acc - 0.02, 6)

        # Warm the nominal DSE path, then lower the MC machine OUTSIDE the
        # counted block (lowering runs eager sampling/interp ops); the
        # counted sweep may then add at most the MC forward + the batched
        # recombination program.
        est.pareto(ds.x_test, ds.y_test, cm=cm)
        machine = est.monte_carlo_machine(n_variants, key)
        with count_compiles() as cc:
            sweep = est.pareto(ds.x_test, ds.y_test, cm=cm,
                               n_variants=n_variants, key=key,
                               accuracy_floor=floor)
        mc_compiles = cc.count()

        # Nominal bit-identity: variant 0 vs the nominal candidate machine.
        nominal_machine = est.design_space(cm).machine
        bits_exact = bool(np.array_equal(
            machine.pair_bits(ds.x_test)[0],
            nominal_machine.pair_bits(ds.x_test)))
        scores_exact = bool(np.array_equal(
            machine.pair_scores(ds.x_test)[0],
            nominal_machine.pair_scores(ds.x_test)))

        # Throughput of the jitted MC forward (already warm).
        reps, t0 = 10, time.perf_counter()
        for _ in range(reps):
            machine.pair_bits(ds.x_test)
        per_call = (time.perf_counter() - t0) / reps
        variants_per_s = n_variants / per_call

        # Algorithm-1 vertex: nominal vs robust statistics.
        alg1 = dse.assignment_from_kernel_map(est.kernel_map_)
        i = sweep.find(alg1)
        vertex = {
            "kernel_map": est.kernel_map_,
            "accuracy_nominal": float(sweep.accuracy[i]),
            "acc_mean": float(sweep.acc_mean[i]),
            "acc_std": float(sweep.acc_std[i]),
            "acc_worst": float(sweep.acc_worst[i]),
            "yield_frac": float(sweep.yield_[i]),
            "on_robust_front": bool(i in set(sweep.robust_front.tolist())),
        }

        # Robust deployment at reference yield floors.
        robust_deploys = {}
        for yf in YIELD_FLOORS:
            try:
                j = sweep.select(yield_floor=yf)
                robust_deploys[str(yf)] = {
                    "kernel_map": sweep.kernel_map(j),
                    "acc_mean": float(sweep.acc_mean[j]),
                    "yield_frac": float(sweep.yield_[j]),
                    "area_mm2": float(sweep.area[j]),
                    "power_mw": float(sweep.power[j]),
                }
            except ValueError:
                robust_deploys[str(yf)] = None

        # Yield-vs-sigma: the Algorithm-1 circuit under scaled mismatch.
        sigma_curve = []
        for s in sigma_scales:
            mc = est.monte_carlo(ds.x_test, ds.y_test,
                                 n_variants=n_variants, key=key,
                                 sigma_scale=float(s))
            sigma_curve.append({
                "sigma_scale": float(s),
                "acc_mean": round(mc.mean, 6),
                "acc_std": round(mc.std, 6),
                "acc_worst": round(mc.worst, 6),
                "yield_frac": round(mc.yield_at(floor), 6),
            })

        results[name] = {
            "n_pairs": sweep.n_pairs,
            "n_assignments": int(sweep.assignments.shape[0]),
            "n_variants": int(n_variants),
            "accuracy_floor": floor,
            "mc_compiles": int(mc_compiles),
            "mc_compile_names": cc.names,
            "nominal_bits_exact": bits_exact,
            "nominal_scores_exact": scores_exact,
            "mc_forward_s": round(per_call, 6),
            "variants_per_s": round(variants_per_s, 1),
            "sweep_s": round(sweep.elapsed_s, 4),
            "alg1": vertex,
            "robust_deploys": robust_deploys,
            "robust_front": sweep.front_points(robust=True),
            "yield_vs_sigma": sigma_curve,
        }
        # The yield deploy mutates assignment_; keep the cached fit clean
        # for any benchmark sharing it through _fit_cache.
        est.assignment_ = None

    if verbose:
        print("dataset,mc_compiles,nominal_bits_exact,nominal_scores_exact,"
              "variants_per_s,alg1_yield,alg1_worst")
        for name, r in results.items():
            a = r["alg1"]
            print(f"{name},{r['mc_compiles']},{r['nominal_bits_exact']},"
                  f"{r['nominal_scores_exact']},{r['variants_per_s']},"
                  f"{a['yield_frac']:.3f},{a['acc_worst']:.3f}")
        for name, r in results.items():
            print(f"-- {name} yield vs sigma (floor {r['accuracy_floor']}):")
            for p in r["yield_vs_sigma"]:
                print(f"   x{p['sigma_scale']}: mean {p['acc_mean']:.3f}, "
                      f"worst {p['acc_worst']:.3f}, "
                      f"yield {p['yield_frac']:.3f}")
    return {"benchmark": "montecarlo", "n_epochs": n_epochs, "seed": seed,
            "mc_seed": mc_seed, "n_variants": n_variants,
            "datasets": results}


def assert_nominal(result: dict) -> None:
    """Hard CI gate: the zero-offset variant IS the nominal compiled path."""
    bad = {
        name: {"bits": r["nominal_bits_exact"],
               "scores": r["nominal_scores_exact"]}
        for name, r in result["datasets"].items()
        if not (r["nominal_bits_exact"] and r["nominal_scores_exact"])
    }
    print(f"nominal-variant bit-identity assertion: "
          f"{'FAIL ' + str(bad) if bad else 'OK'}")
    if bad:
        raise AssertionError(
            f"zero-offset Monte-Carlo variant drifted from the nominal "
            f"compiled path on {bad} — the §6.3 bit-identity contract "
            "(structural nominal-subgraph reuse) regressed")


def assert_compiles(result: dict,
                    budget: int = MAX_MC_COMPILES) -> None:
    """Hard CI gate: the variant axis costs <= `budget` extra compiles."""
    bad = {
        name: r["mc_compile_names"]
        for name, r in result["datasets"].items()
        if r["mc_compiles"] > budget
    }
    print(f"mc-compile budget assertion (<= {budget}): "
          f"{'FAIL ' + str(bad) if bad else 'OK'}")
    if bad:
        raise AssertionError(
            f"Monte-Carlo sweep compiled more than {budget} extra "
            f"programs: {bad} — the variant axis is leaking shapes")


def run_scaling(n_epochs: int = 120, seed: int = 0, mc_seed: int = 0,
                v_max: int = 1_000_000, method: str = "sobol",
                mc_chunk: int = SCALING_CHUNK, n_x: int = SCALING_X,
                dataset: str = SCALING_DATASET,
                verbose: bool = True) -> dict:
    """Variants/s scaling curve of the streaming engine, V = 64 -> v_max.

    One donated fixed-shape chunk program serves every ladder point, so
    peak temp memory is V-independent; the record carries the compile
    count across the ladder and the step's XLA memory analysis so
    ``assert_flat_memory`` can gate both.
    """
    import jax

    from repro.core import dse

    ds, est = _fit_cache.fitted(dataset, n_epochs=n_epochs, seed=seed)
    x = np.asarray(ds.x_test[:n_x])
    y = np.asarray(ds.y_test[:n_x])
    key = jax.random.PRNGKey(mc_seed)
    floor = round(est.score(x, y, target="circuit") - 0.02, 6)
    a = dse.assignment_from_kernel_map(est.kernel_map_)[None, :]

    sm = est.stream_machine(key, method=method, mc_chunk=mc_chunk)
    ladder = [64]
    while ladder[-1] * 16 < v_max:
        ladder.append(ladder[-1] * 16)
    if ladder[-1] != v_max:
        ladder.append(int(v_max))

    # Streamed-vs-dense parity oracle at V = 64: the SAME 64 variants
    # through the dense bit tensor + batched recombination.
    bits64 = sm.pair_bits_dense(x, np.arange(64))
    acc64 = dse.assignment_accuracies_mc(bits64, a, y, est.n_classes_)
    warm = sm.stream(x, y, a, n_variants=64, accuracy_floor=floor)
    parity = {
        "mean_abs_err": float(abs(warm["mean"][0] - acc64.mean())),
        "std_abs_err": float(abs(warm["std"][0] - acc64.std())),
        "worst_exact": bool(warm["worst"][0] == acc64.min()),
        "yield_exact": bool(
            warm["yield"][0] == (acc64 >= floor).mean()),
    }

    mem = sm.step_memory_analysis(n_x, 1)
    mem_rec = None if mem is None else {
        "temp_bytes": int(mem.temp_size_in_bytes),
        "argument_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
    }
    dense_temp = None
    try:  # dense V=64 forward, for contrast with the flat streamed step
        m64 = est.monte_carlo_machine(64, jax.random.fold_in(key, 1))
        dm = jax.jit(m64._forward).lower(x).compile().memory_analysis()
        if dm is not None:
            dense_temp = int(dm.temp_size_in_bytes)
    except Exception:
        pass

    points = []
    with count_compiles() as cc:
        for v in ladder:
            t0 = time.perf_counter()
            out = sm.stream(x, y, a, n_variants=v, accuracy_floor=floor)
            dt = time.perf_counter() - t0
            points.append({
                "n_variants": int(v),
                "seconds": round(dt, 4),
                "variants_per_s": round(v / dt, 1),
                "acc_mean": round(float(out["mean"][0]), 6),
                "acc_worst": round(float(out["worst"][0]), 6),
                "yield_frac": round(float(out["yield"][0]), 6),
                "yield_lo": round(float(out["yield_lo"][0]), 6),
                "yield_hi": round(float(out["yield_hi"][0]), 6),
                "ci_width": round(float(out["yield_hi"][0]
                                        - out["yield_lo"][0]), 6),
                "step_temp_bytes": (None if mem_rec is None
                                    else mem_rec["temp_bytes"]),
            })
    result = {
        "dataset": dataset, "method": method, "mc_chunk": int(mc_chunk),
        "n_x": int(n_x), "mc_seed": int(mc_seed),
        "accuracy_floor": floor,
        "parity_vs_dense64": parity,
        "step_memory": mem_rec,
        "dense_v64_temp_bytes": dense_temp,
        "ladder_extra_compiles": cc.count(),
        "ladder_compile_names": cc.names,
        "points": points,
    }
    if verbose:
        print(f"-- streaming scaling ({dataset}, {method}, "
              f"chunk {mc_chunk}, floor {floor}):")
        print("V,seconds,variants_per_s,yield,ci_width")
        for p in points:
            print(f"{p['n_variants']},{p['seconds']},"
                  f"{p['variants_per_s']},{p['yield_frac']:.4f},"
                  f"{p['ci_width']:.5f}")
        print(f"   step temp bytes: "
              f"{None if mem_rec is None else mem_rec['temp_bytes']}"
              f" (dense V=64 forward: {dense_temp}); "
              f"extra compiles across ladder: {cc.count()}")
    return result


def assert_flat_memory(scaling: dict) -> None:
    """Hard CI gate: V = 64 -> v_max reuses ONE fixed-shape chunk step.

    Two checks: the ladder added zero jit compiles after the warm-up
    stream (no V-dependent shapes leak into the step), and every ladder
    point records the same peak temp bytes as the first.
    """
    extra = scaling["ladder_extra_compiles"]
    temps = {p["step_temp_bytes"] for p in scaling["points"]}
    ok = extra == 0 and len(temps) == 1
    print(f"flat-memory assertion: {'OK' if ok else 'FAIL'} "
          f"(extra compiles {extra}, temp bytes {sorted(temps)})")
    if not ok:
        raise AssertionError(
            f"streaming scaling is not flat: {extra} extra compiles "
            f"across the V ladder, temp bytes {sorted(temps)} — the "
            "chunk step's shapes depend on n_variants")


def assert_ci_width(scaling: dict, max_width: float) -> None:
    """Hard CI gate: the final ladder point's yield CI is tight enough."""
    p = scaling["points"][-1]
    ok = p["ci_width"] <= max_width
    print(f"ci-width assertion (<= {max_width} at V={p['n_variants']}): "
          f"{'OK' if ok else 'FAIL'} ({p['ci_width']})")
    if not ok:
        raise AssertionError(
            f"yield CI width {p['ci_width']} at V={p['n_variants']} "
            f"exceeds {max_width} — the streamed exceedance counts (or "
            "the IS effective sample size) regressed")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, help="write JSON here as well")
    ap.add_argument("--n-epochs", type=int, default=120)
    ap.add_argument("--n-variants", type=int, default=N_VARIANTS)
    ap.add_argument("--mc-seed", type=int, default=0)
    ap.add_argument("--assert-nominal", action="store_true",
                    help="fail unless the zero-offset variant is "
                         "bit-identical to the nominal compiled path")
    ap.add_argument("--assert-compiles", action="store_true",
                    help="fail if the variant axis costs more than "
                         f"{MAX_MC_COMPILES} extra jit compiles")
    ap.add_argument("--scaling", action="store_true",
                    help="also run the streaming V=64..--v-max scaling "
                         "curve (DESIGN.md §10)")
    ap.add_argument("--v-max", type=int, default=1_000_000)
    ap.add_argument("--method", default="sobol",
                    help="streaming sampler: iid | sobol | stratified | is")
    ap.add_argument("--mc-chunk", type=int, default=SCALING_CHUNK)
    ap.add_argument("--assert-flat-memory", action="store_true",
                    help="fail unless the whole V ladder reuses one "
                         "fixed-shape chunk step (implies --scaling)")
    ap.add_argument("--assert-ci-width", type=float, default=None,
                    metavar="W",
                    help="fail if the final yield CI is wider than W "
                         "(implies --scaling)")
    args = ap.parse_args()
    result = run(n_epochs=args.n_epochs, mc_seed=args.mc_seed,
                 n_variants=args.n_variants)
    scaling = None
    if args.scaling or args.assert_flat_memory \
            or args.assert_ci_width is not None:
        scaling = run_scaling(n_epochs=args.n_epochs, mc_seed=args.mc_seed,
                              v_max=args.v_max, method=args.method,
                              mc_chunk=args.mc_chunk)
        result["scaling"] = scaling
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
    if args.assert_nominal:
        assert_nominal(result)
    if args.assert_compiles:
        assert_compiles(result)
    if args.assert_flat_memory:
        assert_flat_memory(scaling)
    if args.assert_ci_width is not None:
        assert_ci_width(scaling, args.assert_ci_width)


if __name__ == "__main__":
    main()
