"""Tests for the unified estimator + compiled-machine API (repro.api).

Covers the tentpole guarantees of the redesign:

  * compiled-vs-object-path equivalence on the quickstart dataset for every
    bank (float, circuit, linear, rbf and the float baselines) — BIT-EXACT
    on Balance Scale;
  * on the surrogate datasets, equivalence modulo comparator-metastable
    samples (|score| below f32 noise: the legacy object path itself flips
    those with batch size, see DESIGN.md §1.4);
  * save/load round-trips (estimator and compiled machine) with identical
    predictions and no retraining;
  * lowering from a bare classifier list;
  * the uniform-grid fast interpolation against jnp.interp.
"""
import os

import numpy as np
import pytest

from repro.api import CompiledMachine, MixedKernelSVM, compile_machine
from repro.data import datasets

# Scores this close to the comparator threshold are metastable: the legacy
# per-classifier path itself decides them differently depending on BLAS
# batch shape (f32 accumulation-order noise).
TIE_EPS = 1e-5


@pytest.fixture(scope="module")
def balance():
    ds = datasets.load("balance")
    est = MixedKernelSVM(n_epochs=60, seed=0).fit(ds.x_train, ds.y_train)
    return ds, est


def test_fit_populates_machine(balance):
    _, est = balance
    assert est.n_classes_ == 3
    assert len(est.pairs_) == 3
    assert set(est.kernel_map_) <= {"linear", "rbf"}
    assert est.n_rbf_ >= 1  # Balance's torque boundary needs an RBF pair


@pytest.mark.parametrize("target", ["float", "circuit", "linear", "rbf",
                                    "linear_float", "rbf_float"])
def test_compiled_bit_exact_on_balance(balance, target):
    """The compiled machine reproduces the object path bit-for-bit on the
    quickstart dataset: every pair bit and every label, train and test."""
    ds, est = balance
    bank = est.bank(target)
    machine = est.deploy(target)
    for x in (ds.x_train, ds.x_test):
        np.testing.assert_array_equal(machine.predict_bits(x),
                                      bank.predict_bits(x))
        np.testing.assert_array_equal(machine.predict(x), bank.predict(x))


@pytest.mark.parametrize("name", ["seeds", "vertebral"])
def test_compiled_equivalent_on_surrogates(name):
    """On the surrogate datasets equivalence holds except for samples whose
    decision score is metastable (within TIE_EPS of the comparator
    threshold), where the legacy path is itself batch-shape-dependent."""
    ds = datasets.load(name)
    est = MixedKernelSVM(n_epochs=40, seed=0).fit(ds.x_train, ds.y_train)
    for target in ("float", "circuit", "linear", "rbf"):
        bank = est.bank(target)
        machine = est.deploy(target)
        for x in (ds.x_train, ds.x_test):
            b_obj = bank.predict_bits(x)
            b_cmp = machine.predict_bits(x)
            scores = machine.decision_scores(x)
            stable = np.abs(scores) > TIE_EPS
            np.testing.assert_array_equal(b_cmp[stable], b_obj[stable])


def test_score_matches_object_accuracy(balance):
    ds, est = balance
    assert est.score(ds.x_test, ds.y_test, target="circuit") == \
        pytest.approx(est.bank("circuit").accuracy(ds.x_test, ds.y_test))


def test_compile_from_classifier_list(balance):
    ds, est = balance
    bank = est.bank("circuit")
    machine = compile_machine(list(bank.classifiers), n_classes=3)
    np.testing.assert_array_equal(machine.predict(ds.x_test),
                                  bank.predict(ds.x_test))
    with pytest.raises(ValueError):
        compile_machine(list(bank.classifiers))  # n_classes required


def test_compile_rejects_unknown_classifier():
    with pytest.raises(TypeError):
        compile_machine([object(), object(), object()], n_classes=3)


def test_estimator_save_load_roundtrip(balance, tmp_path):
    ds, est = balance
    path = os.path.join(tmp_path, "machine")
    est.save(path)
    assert os.path.exists(path + ".npz") and os.path.exists(path + ".json")
    est2 = MixedKernelSVM.load(path)
    assert est2.kernel_map_ == est.kernel_map_
    for target in est.targets:
        np.testing.assert_array_equal(
            est2.predict(ds.x_test, target=target),
            est.predict(ds.x_test, target=target))
        np.testing.assert_array_equal(
            est2.predict_bits(ds.x_test, target=target),
            est.predict_bits(ds.x_test, target=target))


def test_compiled_machine_save_load_roundtrip(balance, tmp_path):
    ds, est = balance
    machine = est.deploy("circuit")
    path = os.path.join(tmp_path, "compiled")
    machine.save(path)
    loaded = CompiledMachine.load(path)
    assert loaded.n_classes == machine.n_classes
    assert loaded.kernel_map == machine.kernel_map
    np.testing.assert_array_equal(loaded.predict(ds.x_test),
                                  machine.predict(ds.x_test))
    np.testing.assert_array_equal(loaded.predict_bits(ds.x_test),
                                  machine.predict_bits(ds.x_test))
    np.testing.assert_allclose(loaded.decision_scores(ds.x_test),
                               machine.decision_scores(ds.x_test))


def test_fit_rejects_bad_labels():
    x = np.zeros((6, 2))
    with pytest.raises(ValueError):          # class 1 absent
        MixedKernelSVM().fit(x, np.array([0, 0, 2, 2, 2, 0]))
    with pytest.raises(ValueError):          # single class
        MixedKernelSVM().fit(x, np.zeros(6, np.int64))


def test_unfitted_estimator_raises():
    est = MixedKernelSVM()
    with pytest.raises(RuntimeError):
        est.bank("circuit")
    with pytest.raises(RuntimeError):
        est.predict(np.zeros((2, 4)))


def test_unknown_target_raises(balance):
    _, est = balance
    with pytest.raises(KeyError):
        est.bank("nonsense")


def test_uniform_interp_matches_jnp_interp():
    """The O(1) bin-location interpolation tracks jnp.interp to ~1e-6 (the
    fraction's f32 rounding times the max segment slope) on a calibrated
    DC-sweep grid, including nodes, node neighbourhoods and out-of-range
    clamps."""
    import jax.numpy as jnp

    from repro.api.compiled import _grid_fast_path, _uniform_interp
    from repro.core import analog

    hw = analog.AnalogRBFModel.from_circuit()
    grid = np.asarray(hw.dv_grid, np.float32)
    curve = np.asarray(hw.kernel_curve, np.float32)
    fp = _grid_fast_path(grid)
    assert fp["uniform_grid"]
    rng = np.random.RandomState(0)
    v = np.concatenate([
        rng.uniform(grid[0] * 1.5, grid[-1] * 1.5, 20000).astype(np.float32),
        grid, np.nextafter(grid, np.inf), np.nextafter(grid, -np.inf)])
    ref = jnp.interp(jnp.asarray(v), jnp.asarray(grid), jnp.asarray(curve),
                     left=float(curve[0]), right=float(curve[-1]))
    fast = _uniform_interp(jnp.asarray(v), jnp.asarray(curve),
                           jnp.asarray(grid)[0], jnp.asarray(grid)[-1],
                           float(curve[0]), float(curve[-1]),
                           jnp.float32(fp["inv_step"]))
    np.testing.assert_allclose(np.asarray(fast), np.asarray(ref),
                               atol=1e-6, rtol=0)


def test_pallas_dispatch_agrees_with_jnp_path(balance):
    """use_pallas=True routes rbf banks through the tiled Pallas kernel
    (interpreter off-TPU); bits must agree with the jnp dispatch and the
    object path on a small batch."""
    ds, est = balance
    bank = est.bank("rbf")
    cm_pallas = compile_machine(bank, use_pallas=True)
    cm_jnp = compile_machine(bank, use_pallas=False)
    x = ds.x_test[:32]
    np.testing.assert_array_equal(cm_pallas.predict_bits(x),
                                  cm_jnp.predict_bits(x))
    np.testing.assert_array_equal(cm_pallas.predict_bits(x),
                                  bank.predict_bits(x))


def test_compiled_machine_describe(balance):
    _, est = balance
    text = est.deploy("circuit").describe()
    assert "CompiledMachine(K=3, P=3)" in text
    assert "linear bank" in text and "hw bank" in text


def test_votes_fallback_matches_table():
    """Machines beyond the truth-table regime (P > MAX_TABLE_BITS) decide
    via the votes matmul — same semantics as the packed encoder."""
    from repro.core import ovo, svm as svm_mod

    rng = np.random.RandomState(0)
    k = 6  # 15 pairs > MAX_TABLE_BITS
    x = rng.rand(200, 3)
    y = rng.randint(0, k, 200)
    clfs = []
    for (ci, cj) in ovo.class_pairs(k):
        mask = (y == ci) | (y == cj)
        yy = np.where(y[mask] == ci, 1.0, -1.0)
        m = svm_mod.train_binary(x[mask], yy, "linear", c=1.0, n_epochs=40)
        clfs.append(ovo.FloatBitClassifier(m))
    machine = compile_machine(clfs, n_classes=k)
    assert machine._decider.table is None  # votes path engaged
    bits = machine.predict_bits(x)
    np.testing.assert_array_equal(machine.predict(x),
                                  ovo.decide_votes(bits, k))
