"""Fused Pallas training solver vs its oracles (DESIGN.md §7).

Covers the tentpole's guarantees:

  * the Pallas kernel (interpret mode) reproduces
    ``trainer.dual_coordinate_ascent_blocked`` — the oracle of record —
    to f32 round-off on random lanes (property test), including the
    fused margin output ``f = K' @ (alpha * y)``;
  * awkward shapes: n not a multiple of the coordinate block, d = 1,
    single-sample lanes, single-lane grids;
  * kernel-kind coverage: linear / rbf / sech2 (incl. non-default
    hardware constants) against the pure-jnp lanes oracle
    ``kernels.ref.solve_lanes``;
  * masking: c_box = 0 rows stay exact no-ops (the padding contract);
  * end-to-end: ``trainer.train_pairs(use_pallas=True)`` picks identical
    (gamma, C) and support sets to the blocked engine on a Balance
    subsample, and ``svm.fit_best(use_pallas=True)`` agrees on tiny data;
  * the ``interpret`` override reaches the compiled inference machines
    (``compile_machine(use_pallas=True, interpret=True)`` on CPU).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from _compat import property_test

from repro.core import kernels as kern
from repro.core import svm as svm_mod, trainer
from repro.kernels import ops, ref


def _lanes(seed, p, n, d, g, l, c_hi=5.0):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.rand(p, n, d), jnp.float32)
    y = jnp.asarray(np.where(rng.rand(p, n) > 0.5, 1.0, -1.0), jnp.float32)
    c_box = jnp.asarray(
        rng.rand(p, l, n) * c_hi * (rng.rand(p, l, n) > 0.2), jnp.float32)
    gamma = jnp.asarray(rng.rand(p, g) * 6.0 + 0.3, jnp.float32)
    return x, y, c_box, gamma


# -- parity vs the oracle of record ------------------------------------------


@property_test(
    fixed_examples=[(0, 37, 3, 2.0, 30), (1, 70, 1, 10.0, 40),
                    (2, 16, 5, 0.5, 25), (3, 101, 2, 100.0, 20)],
    strategies=lambda st: (st.integers(0, 50), st.integers(2, 80),
                           st.integers(1, 5), st.floats(0.3, 100.0),
                           st.integers(5, 40)),
    max_examples=15,
)
def test_pallas_matches_blocked_oracle(seed, n, d, c, n_epochs):
    """Random lanes: Pallas (interpret) == dual_coordinate_ascent_blocked
    to f32 round-off, for the rbf Gram the engine actually trains on."""
    x, y, c_box, gamma = _lanes(seed, 1, n, d, 1, 1, c_hi=c)
    a_pl, f_pl = ops.solve_lanes(x, y, c_box, gamma, kind="rbf",
                                 n_epochs=n_epochs, interpret=True)
    kp = kern.kernel_matrix("rbf", x[0], x[0], gamma[0, 0]) + 1.0
    a_or = np.asarray(trainer.dual_coordinate_ascent_blocked(
        kp, y[0], c_box[0, 0], n_epochs))
    scale = max(float(c), 1.0)
    np.testing.assert_allclose(np.asarray(a_pl[0, 0, 0]), a_or,
                               atol=5e-4 * scale, rtol=1e-3)
    f_or = np.asarray(kp @ (jnp.asarray(a_or) * y[0]))
    np.testing.assert_allclose(np.asarray(f_pl[0, 0, 0]), f_or,
                               atol=5e-3 * scale, rtol=1e-3)


@pytest.mark.parametrize("kind,n,d,g,l", [
    ("linear", 50, 3, 1, 4),
    ("rbf", 33, 4, 3, 5),      # n not a multiple of the block
    ("rbf", 7, 1, 2, 2),       # d = 1, n < block
    ("rbf", 1, 2, 1, 1),       # single-sample lane
    ("sech2", 40, 2, 2, 3),
])
def test_lane_grid_matches_ref(kind, n, d, g, l):
    """Multi-lane grids vs the pure-jnp materialized-Gram lanes oracle."""
    x, y, c_box, gamma = _lanes(n + d, 2, n, d, g, l)
    a_pl, f_pl = ops.solve_lanes(x, y, c_box, gamma, kind=kind,
                                 n_epochs=25, interpret=True)
    a_rf, f_rf = ref.solve_lanes(x, y, c_box, gamma, kind=kind, n_epochs=25)
    np.testing.assert_allclose(np.asarray(a_pl), np.asarray(a_rf),
                               atol=5e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(f_pl), np.asarray(f_rf),
                               atol=5e-3, rtol=1e-3)


def test_sech2_nondefault_hardware_constants():
    """Non-default n_slope/v_t/v_scale reach the tile body and match the
    oracle built from the same constants.

    Note the feature-unit gamma parametrization realizes the requested
    width EXACTLY — the input scaling s = sqrt(gamma/gamma0) *
    v_scale/(n*v_t) cancels every hardware constant (dv = 2*sqrt(gamma) *
    dx) — so non-default constants may only differ from the defaults by
    round-off; the contract here is tile-vs-oracle agreement under the
    SAME constants."""
    kw = dict(n_slope=1.7, v_t=0.031, v_scale=0.8)
    x, y, c_box, gamma = _lanes(9, 1, 26, 3, 2, 2)
    a_pl, _ = ops.solve_lanes(x, y, c_box, gamma, kind="sech2",
                              n_epochs=20, interpret=True, **kw)
    a_rf, _ = ref.solve_lanes(x, y, c_box, gamma, kind="sech2",
                              n_epochs=20, **kw)
    np.testing.assert_allclose(np.asarray(a_pl), np.asarray(a_rf),
                               atol=5e-4, rtol=1e-3)
    a_def, _ = ops.solve_lanes(x, y, c_box, gamma, kind="sech2",
                               n_epochs=20, interpret=True)
    np.testing.assert_allclose(np.asarray(a_pl), np.asarray(a_def),
                               atol=5e-4, rtol=1e-3)


def test_masked_rows_exact_noops():
    """c_box = 0 rows keep alpha at exactly 0 and leave the real rows'
    alphas identical to the unpadded solve (the padding contract)."""
    rng = np.random.RandomState(4)
    n, n_pad, d = 21, 12, 3
    x = np.zeros((1, n + n_pad, d), np.float32)
    x[0, :n] = rng.rand(n, d)
    x[0, n:] = rng.rand(n_pad, d) * 7.0          # garbage padding data
    y = np.ones((1, n + n_pad), np.float32)
    y[0, :n] = np.where(rng.rand(n) > 0.5, 1.0, -1.0)
    c_box = np.zeros((1, 1, n + n_pad), np.float32)
    c_box[0, 0, :n] = 3.0
    gamma = np.full((1, 1), 2.5, np.float32)
    a_pad, _ = ops.solve_lanes(jnp.asarray(x), jnp.asarray(y),
                               jnp.asarray(c_box), jnp.asarray(gamma),
                               kind="rbf", n_epochs=30, interpret=True)
    a_ref, _ = ops.solve_lanes(jnp.asarray(x[:, :n]), jnp.asarray(y[:, :n]),
                               jnp.asarray(c_box[:, :, :n]),
                               jnp.asarray(gamma),
                               kind="rbf", n_epochs=30, interpret=True)
    np.testing.assert_array_equal(np.asarray(a_pad[0, 0, 0, n:]), 0.0)
    np.testing.assert_array_equal(np.asarray(a_pad[0, 0, 0, :n]),
                                  np.asarray(a_ref[0, 0, 0]))


# -- training-engine integration ---------------------------------------------


def _balance_subsample(n=150, seed=0):
    from repro.data import datasets

    ds = datasets.load("balance")
    rng = np.random.RandomState(seed)
    idx = rng.permutation(len(ds.y_train))[:n]
    return ds.x_train[idx], ds.y_train[idx], ds.n_classes


def test_train_pairs_pallas_identical_selection():
    """End-to-end Algorithm 1 on a Balance subsample: the fused solver
    picks identical kernels, (gamma, C) and support sets."""
    x, y, k = _balance_subsample()
    kw = dict(n_epochs=40, cv_epochs=20, n_folds=3, seed=0)
    res_blk = trainer.train_pairs(x, y, k, use_pallas=False, **kw)
    res_pal = trainer.train_pairs(x, y, k, use_pallas=True,
                                  interpret=True, **kw)
    for rb, rp in zip(res_blk, res_pal):
        assert rb.kernel == rp.kernel
        assert (rb.model.gamma, rb.model.c) == (rp.model.gamma, rp.model.c)
        for slot in ("model_linear", "model_rbf"):
            mb, mp = getattr(rb, slot), getattr(rp, slot)
            np.testing.assert_array_equal(mb.support_x, mp.support_x)
            np.testing.assert_allclose(mb.alpha, mp.alpha,
                                       atol=5e-4, rtol=1e-3)
        # the hw family always takes the blocked path: bit-identical
        if rb.model_hw is not None:
            np.testing.assert_array_equal(rb.model_hw.alpha,
                                          rp.model_hw.alpha)


def test_fit_best_pallas_identical_selection():
    """svm.fit_best with the fused solver: same (gamma, C) pick and
    support set on a small binary problem."""
    rng = np.random.RandomState(7)
    x = rng.rand(60, 3)
    y = np.where(x[:, 0] + 0.3 * x[:, 1] > 0.7, 1.0, -1.0)
    kw = dict(gammas=np.logspace(-1, 1, 3), cs=np.logspace(-1, 1, 3),
              n_folds=3, n_epochs=40, cv_epochs=20)
    m_blk, acc_blk = svm_mod.fit_best(x, y, "rbf", use_pallas=False, **kw)
    m_pal, acc_pal = svm_mod.fit_best(x, y, "rbf", use_pallas=True,
                                      interpret=True, **kw)
    assert (m_blk.gamma, m_blk.c) == (m_pal.gamma, m_pal.c)
    np.testing.assert_allclose(acc_blk, acc_pal, atol=1e-6)
    np.testing.assert_array_equal(m_blk.support_x, m_pal.support_x)
    np.testing.assert_allclose(m_blk.alpha, m_pal.alpha,
                               atol=5e-4, rtol=1e-3)


def test_family_refit_pallas_matches_blocked():
    """family_refit through the fused solver == blocked refit."""
    x, y, k = _balance_subsample(n=90)
    padded = trainer.pad_pairs(x, y, k, n_folds=3, seed=0)
    g_sel = np.full((padded.n_pairs,), 2.0, np.float32)
    c_sel = np.full((padded.n_pairs,), 5.0, np.float32)
    a_blk = trainer.family_refit(padded, "rbf", g_sel, c_sel, 40,
                                 use_pallas=False)
    a_pal = trainer.family_refit(padded, "rbf", g_sel, c_sel, 40,
                                 use_pallas=True, interpret=True)
    np.testing.assert_allclose(a_pal, a_blk, atol=5e-4, rtol=1e-3)


# -- interpret override through the compiled inference machines --------------


def test_compile_machine_interpret_override():
    """CPU CI can exercise the compiled-mode Pallas path deliberately:
    use_pallas=True + interpret=True must agree with the jnp path."""
    from repro.api import compile_machine

    x, y, k = _balance_subsample(n=90)
    pairs = trainer.train_pairs(x, y, k, n_epochs=30, cv_epochs=15,
                                n_folds=3, seed=0)
    models = [p.model_rbf for p in pairs]           # force kernel banks
    cm_jnp = compile_machine(models, n_classes=k, use_pallas=False)
    cm_pal = compile_machine(models, n_classes=k, use_pallas=True,
                             interpret=True)
    assert cm_pal.interpret is True
    xq = x[:64]
    np.testing.assert_allclose(cm_pal.decision_scores(xq),
                               cm_jnp.decision_scores(xq),
                               atol=2e-5, rtol=1e-5)
    np.testing.assert_array_equal(cm_pal.predict(xq), cm_jnp.predict(xq))
