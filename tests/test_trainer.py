"""Tests for the batched Algorithm-1 training engine (DESIGN.md §4).

Covers the refactor's guarantees:

  * padding/masking contract: trailing ``c_box = 0`` rows are exact no-ops
    of the solver — identical alpha, bias and support set to the unpadded
    solve (the property the whole (P, n_max, d) stacking rests on);
  * the blocked solver reproduces the reference Gauss-Seidel solver to
    f32 round-off (same update sequence, different margin association);
  * engine-vs-sequential equivalence on Balance: same kernel map, same
    selected hyper-parameters, same support sets, CV accuracies equal to
    comparator-tie tolerance;
  * the explicit ``cv_epochs`` knob (satellite: previously a hidden
    ``max(60, n_epochs // 2)`` policy inside ``fit_best``);
  * the shard_map variant over the pair x gamma axis (subprocess with 8
    fake devices) agrees with the single-device program.
"""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from _compat import property_test

from repro.core import kernels as kern
from repro.core import selection, svm as svm_mod, trainer

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# -- padding/masking contract ------------------------------------------------


@property_test(
    fixed_examples=[(20, 2, 7, 1.0), (33, 3, 16, 10.0), (8, 1, 40, 0.5),
                    (25, 4, 3, 100.0)],
    strategies=lambda st: (st.integers(5, 40), st.integers(1, 4),
                           st.integers(1, 48), st.floats(0.5, 100.0)),
    max_examples=20,
)
def test_padded_solve_identical_to_unpadded(n, d, n_pad, c):
    """Trailing c_box=0 padding rows are bit-exact no-ops: same alpha on
    the real rows, exact zeros on the padding, for the reference solver."""
    rng = np.random.RandomState(n * 31 + d * 7 + n_pad)
    x = rng.rand(n + n_pad, d)          # padding rows carry garbage data
    y = np.where(rng.rand(n + n_pad) > 0.5, 1.0, -1.0)
    kp_full = np.asarray(kern.kernel_matrix(
        "rbf", jnp.asarray(x, jnp.float32), jnp.asarray(x, jnp.float32),
        5.0) + 1.0)
    box = np.full((n + n_pad,), c, np.float32)
    box[n:] = 0.0                       # mask the padding
    a_pad = np.asarray(svm_mod.dual_coordinate_ascent(
        jnp.asarray(kp_full), jnp.asarray(y, jnp.float32),
        jnp.asarray(box), 40))
    a_ref = np.asarray(svm_mod.dual_coordinate_ascent(
        jnp.asarray(kp_full[:n, :n]), jnp.asarray(y[:n], jnp.float32),
        jnp.full((n,), c, jnp.float32), 40))
    np.testing.assert_array_equal(a_pad[:n], a_ref)
    np.testing.assert_array_equal(a_pad[n:], 0.0)
    # ... and therefore identical bias and support set.
    sv_pad, sv_ref = a_pad[:n] > 1e-6, a_ref > 1e-6
    np.testing.assert_array_equal(sv_pad, sv_ref)
    assert float(np.sum(a_pad[:n][sv_pad] * y[:n][sv_pad])) == \
        float(np.sum(a_ref[sv_ref] * y[:n][sv_ref]))


def test_blocked_solver_padding_inert():
    """The engine's blocked solver obeys the same padding contract."""
    rng = np.random.RandomState(0)
    n, n_pad = 37, 23
    x = rng.rand(n + n_pad, 3)
    y = np.where(rng.rand(n + n_pad) > 0.5, 1.0, -1.0)
    kp = jnp.asarray(np.asarray(kern.kernel_matrix(
        "rbf", jnp.asarray(x, jnp.float32), jnp.asarray(x, jnp.float32),
        5.0) + 1.0))
    box = np.full((n + n_pad,), 2.0, np.float32)
    box[n:] = 0.0
    a_pad = np.asarray(trainer.dual_coordinate_ascent_blocked(
        kp, jnp.asarray(y, jnp.float32), jnp.asarray(box), 40))
    a_ref = np.asarray(trainer.dual_coordinate_ascent_blocked(
        kp[:n, :n], jnp.asarray(y[:n], jnp.float32),
        jnp.full((n,), 2.0, jnp.float32), 40))
    np.testing.assert_array_equal(a_pad[n:], 0.0)
    # Real rows agree to f32 round-off (block boundaries shift with n).
    np.testing.assert_allclose(a_pad[:n], a_ref, atol=5e-5, rtol=1e-4)


@pytest.mark.parametrize("kind,gamma", [("linear", 1.0), ("rbf", 8.0)])
def test_blocked_solver_matches_reference(kind, gamma):
    """Blocked Gauss-Seidel == reference solver up to f32 round-off: same
    coordinate update sequence, different margin summation association."""
    rng = np.random.RandomState(3)
    n = 70
    x = rng.rand(n, 3)
    y = np.where(x[:, 0] + x[:, 1] > 1.0, 1.0, -1.0)
    kp = jnp.asarray(np.asarray(kern.kernel_matrix(
        kind, jnp.asarray(x, jnp.float32), jnp.asarray(x, jnp.float32),
        gamma) + 1.0))
    box = jnp.full((n,), 5.0, jnp.float32)
    a_ref = np.asarray(svm_mod.dual_coordinate_ascent(
        kp, jnp.asarray(y, jnp.float32), box, 60))
    a_blk = np.asarray(trainer.dual_coordinate_ascent_blocked(
        kp, jnp.asarray(y, jnp.float32), box, 60))
    np.testing.assert_allclose(a_blk, a_ref, atol=5e-4, rtol=1e-3)
    # Box constraints hold exactly.
    assert np.all(a_blk >= 0.0) and np.all(a_blk <= 5.0 + 1e-6)


# -- pad_pairs ---------------------------------------------------------------


def test_pad_pairs_layout():
    rng = np.random.RandomState(1)
    x = rng.rand(60, 4)
    y = rng.randint(0, 3, 60)
    padded = trainer.pad_pairs(x, y, 3, n_folds=5, seed=0)
    assert padded.n_pairs == 3
    assert padded.pairs == [(0, 1), (0, 2), (1, 2)]
    assert padded.x.shape == (3, padded.n_max, 4)
    for i, (ci, cj) in enumerate(padded.pairs):
        n_i = int(np.sum((y == ci) | (y == cj)))
        assert padded.n_true[i] == n_i
        assert padded.valid[i, :n_i].all() and not padded.valid[i, n_i:].any()
        # fold masks: 0 on padding (neither train nor validation side)
        assert not padded.fold_masks[i, :, n_i:].any()
        # fold assignment matches the sequential path's RNG stream
        fold_of = trainer.cv_fold_assignment(n_i, 5, 0)
        np.testing.assert_array_equal(
            padded.fold_masks[i, 2, :n_i], (fold_of != 2).astype(np.float32))
    sub = padded.take([2])
    assert sub.pairs == [(1, 2)] and sub.x.shape[0] == 1


# -- engine vs sequential on Balance ----------------------------------------


@pytest.fixture(scope="module")
def balance_pairs():
    from repro.data import datasets

    ds = datasets.load("balance")
    seq = selection.train_pairs_sequential(
        ds.x_train, ds.y_train, 3, n_epochs=60, seed=0)
    bat = trainer.train_pairs(
        ds.x_train, ds.y_train, 3, n_epochs=60, seed=0)
    return seq, bat


def test_engine_matches_sequential_selection(balance_pairs):
    """Same kernel map, same (gamma, C) picks, CV accuracies within the
    comparator-tie tolerance (DESIGN.md §4.5)."""
    seq, bat = balance_pairs
    assert [p.kernel for p in seq] == [p.kernel for p in bat]
    for ps, pb in zip(seq, bat):
        assert ps.pair == pb.pair
        assert abs(ps.acc_linear - pb.acc_linear) < 1e-3
        assert abs(ps.acc_rbf - pb.acc_rbf) < 1e-3
        assert (ps.model_hw is None) == (pb.model_hw is None)
        for slot in ("model_linear", "model_rbf", "model_hw"):
            ms, mb = getattr(ps, slot), getattr(pb, slot)
            if ms is None:
                continue
            assert (ms.gamma, ms.c) == (mb.gamma, mb.c), (ps.pair, slot)
            assert ms.n_support == mb.n_support, (ps.pair, slot)
            # hw is looser: the engine trains with the uniform-grid fast
            # interpolation (~1e-6 kernel deltas vs jnp.interp), amplified
            # through the coordinate-ascent recurrence.
            tol = dict(atol=5e-3, rtol=5e-3) if slot == "model_hw" \
                else dict(atol=5e-4, rtol=1e-3)
            np.testing.assert_allclose(mb.alpha, ms.alpha, **tol)


def test_engine_banks_match_sequential_accuracy(balance_pairs):
    """The deployed design points built from engine-trained pairs score the
    same as from the sequential path (Table-II contract)."""
    from repro.data import datasets

    ds = datasets.load("balance")
    hw = trainer.default_hw(0)
    seq, bat = balance_pairs
    banks_s = selection.build_banks(seq, 3, hw=hw)
    banks_b = selection.build_banks(bat, 3, hw=hw)
    for target in ("float", "circuit", "linear", "rbf"):
        acc_s = banks_s[target].accuracy(ds.x_test, ds.y_test)
        acc_b = banks_b[target].accuracy(ds.x_test, ds.y_test)
        assert abs(acc_s - acc_b) <= 1.0 / len(ds.y_test) + 1e-9, target


# -- cv_epochs knob ----------------------------------------------------------


def test_cv_epochs_explicit_default():
    """cv_epochs=None keeps the historical max(60, n_epochs // 2) policy."""
    rng = np.random.RandomState(5)
    x = rng.rand(40, 2)
    y = np.where(x[:, 0] > 0.5, 1.0, -1.0)
    m_default, a_default = svm_mod.fit_best(x, y, "rbf", n_epochs=100, seed=0)
    m_explicit, a_explicit = svm_mod.fit_best(x, y, "rbf", n_epochs=100,
                                              seed=0, cv_epochs=60)
    assert a_default == a_explicit
    assert (m_default.gamma, m_default.c) == (m_explicit.gamma, m_explicit.c)
    # and a different cv_epochs actually changes the CV estimates
    _, a_short = svm_mod.fit_best(x, y, "rbf", n_epochs=100, seed=0,
                                  cv_epochs=2)
    assert a_short != a_default or True  # may coincide; just must not crash


def test_cv_epochs_threads_through_engine():
    rng = np.random.RandomState(6)
    x = rng.rand(90, 3)
    y = rng.randint(0, 3, 90)
    a = trainer.train_pairs(x, y, 3, n_epochs=40, cv_epochs=20, seed=0)
    b = trainer.train_pairs(x, y, 3, n_epochs=40, cv_epochs=20, seed=0)
    assert [p.kernel for p in a] == [p.kernel for p in b]
    for pa, pb in zip(a, b):                 # deterministic given cv_epochs
        assert pa.acc_linear == pb.acc_linear
        assert pa.acc_rbf == pb.acc_rbf


def test_estimator_cv_epochs_roundtrip(tmp_path):
    from repro.api import MixedKernelSVM

    rng = np.random.RandomState(7)
    x = rng.rand(80, 3)
    y = rng.randint(0, 2, 80)
    est = MixedKernelSVM(n_epochs=40, cv_epochs=20, seed=0).fit(x, y)
    path = os.path.join(tmp_path, "m")
    est.save(path)
    est2 = MixedKernelSVM.load(path)
    assert est2.cv_epochs == 20
    np.testing.assert_array_equal(est2.predict(x), est.predict(x))


# -- shard_map variant -------------------------------------------------------


def test_trainer_mesh_requires_pairgrid_axis():
    from repro.launch import mesh as mesh_mod

    m = mesh_mod.make_test_mesh(shape=(1,), axes=("data",))
    padded = trainer.pad_pairs(np.random.RandomState(0).rand(30, 2),
                               np.arange(30) % 2, 2)
    with pytest.raises(ValueError, match="pairgrid"):
        trainer.family_cv_grid(padded, "rbf", np.array([1.0]),
                               np.array([1.0]), 5, mesh=m)


def test_sharded_cv_grid_matches_local():
    """shard_map over the pair x gamma axis reproduces the single-device
    CV grid (8 fake devices, subprocess so XLA_FLAGS doesn't leak)."""
    body = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax
        from repro.core import trainer
        from repro.launch import mesh as mesh_mod

        rng = np.random.RandomState(0)
        x = rng.rand(70, 3)
        y = rng.randint(0, 3, 70)
        padded = trainer.pad_pairs(x, y, 3)
        gammas = np.logspace(-1, 1, 3)
        cs = np.logspace(-1, 2, 4)
        mesh = mesh_mod.make_trainer_mesh()
        assert mesh.shape["pairgrid"] == 8
        acc_sh = trainer.family_cv_grid(padded, "rbf", gammas, cs, 15,
                                        mesh=mesh)
        acc_lo = trainer.family_cv_grid(padded, "rbf", gammas, cs, 15)
        assert acc_sh.shape == (3, 3, 4)
        np.testing.assert_allclose(acc_sh, acc_lo, atol=1e-6)
        pairs = trainer.train_pairs(x, y, 3, n_epochs=15, seed=0, mesh=mesh)
        assert len(pairs) == 3
        print("OK")
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", body], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    assert "OK" in res.stdout


# -- size-sharded lane layout (DESIGN.md §11) --------------------------------


def test_seq_gamma_grid_matches_vmap():
    """lax.map over the gamma axis is numerically identical to the vmap
    (the memory heuristic must never change CV selections)."""
    rng = np.random.RandomState(0)
    x = rng.rand(40, 4).astype(np.float32)
    y = np.where(rng.rand(40) > 0.5, 1.0, -1.0).astype(np.float32)
    v = np.ones(40, np.float32)
    fm = np.zeros((4, 40), np.float32)
    for f in range(4):
        fm[f, f::4] = 1.0
    g = jnp.asarray([0.1, 0.5, 1.0], jnp.float32)
    c = jnp.asarray([0.5, 2.0], jnp.float32)
    args = (jnp.asarray(x), jnp.asarray(y), jnp.asarray(fm), jnp.asarray(v),
            g, c, "rbf", 20)
    a_vmap = np.asarray(trainer._pair_cv_grid(*args, seq_gamma=False))
    a_seq = np.asarray(trainer._pair_cv_grid(*args, seq_gamma=True))
    np.testing.assert_allclose(a_seq, a_vmap, atol=1e-6)


def test_seq_gamma_gate_engages_at_scale():
    """The trace-time gate picks vmap at UCI shapes and lax.map at the
    har12 scale-out shapes (P=66, n_max~1582, G=7: a ~4.6 GB Gram stack)."""
    class Shaped:
        def __init__(self, s):
            self.shape = s

    assert not trainer._seq_gamma(Shaped((10, 200, 5)), Shaped((7,)))
    assert trainer._seq_gamma(Shaped((66, 1582, 5)), Shaped((7,)))


def test_shard_lane_layout_partition_properties():
    """Shards are a permutation partition, respect the shard cap, and the
    makespan (count * shard_max^2) never worsens with more shards."""
    sizes = [198, 220, 300, 420, 500, 640, 800, 1000, 1200, 1400, 1582, 1582]

    def makespan(shards):
        return max(len(s) * int(max(np.asarray(sizes)[s])) ** 2
                   for s in shards)

    prev = None
    for d in (1, 2, 4, 8, 20):
        shards = trainer.shard_lane_layout(sizes, d)
        assert 1 <= len(shards) <= max(1, min(d, len(sizes)))
        flat = np.sort(np.concatenate(shards))
        np.testing.assert_array_equal(flat, np.arange(len(sizes)))
        m = makespan(shards)
        if prev is not None:
            assert m <= prev
        prev = m
    assert len(trainer.shard_lane_layout(sizes, 1)) == 1


def test_padded_pairs_trim_shard_local():
    """take().trim() re-pads a shard to its own max; grid values on the
    shard are identical to the globally padded program's."""
    rng = np.random.RandomState(1)
    x = rng.rand(90, 3)
    y = rng.randint(0, 4, 90)
    padded = trainer.pad_pairs(x, y, 4, n_folds=4, seed=0)
    shards = trainer.shard_lane_layout(padded.n_true, 3)
    assert len({padded.take([int(i) for i in s]).trim().n_max
                for s in shards}) > 1  # shard maxima actually differ
    g = np.array([0.5, 2.0])
    c = np.array([1.0, 10.0])
    ref = trainer.family_cv_grid(padded, "rbf", g, c, 15)
    got = trainer.family_cv_grid_size_sharded(padded, "rbf", g, c, 15)
    assert got.shape == ref.shape
    np.testing.assert_allclose(got, ref, atol=1e-6)


def test_size_sharded_grid_8_devices():
    """Size-sharded per-device dispatch on 8 fake devices reproduces the
    single-program grid (subprocess so XLA_FLAGS doesn't leak)."""
    body = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax
        from repro.core import trainer

        assert len(jax.devices()) == 8
        rng = np.random.RandomState(0)
        x = rng.rand(120, 3)
        y = rng.randint(0, 5, 120)
        padded = trainer.pad_pairs(x, y, 5, n_folds=4, seed=0)
        g = np.array([0.5, 2.0]); c = np.array([1.0, 10.0])
        ref = trainer.family_cv_grid(padded, "rbf", g, c, 15)
        got = trainer.family_cv_grid_size_sharded(padded, "rbf", g, c, 15)
        np.testing.assert_allclose(got, ref, atol=1e-6)
        shards = trainer.shard_lane_layout(padded.n_true, 8)
        assert len(shards) <= 8
        print("OK")
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", body], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    assert "OK" in res.stdout
