"""Tests for the streaming tail-yield Monte-Carlo engine (DESIGN.md §10).

Covers the tentpole guarantees:

  * streamed mean/std/min/yield match the dense oracle — the SAME
    fold_in-keyed variants through ``pair_bits_dense`` + the batched
    recombination — at small V (documented tolerance: 1e-4 moments,
    exact extrema/exceedance);
  * chunk edges: V = 1, V = chunk, V = chunk + 1, and chunk-size
    invariance of the whole statistics dict;
  * scrambled-Sobol determinism from the stored key (+ chunk-size
    invariance of the fast-forwarded sequence);
  * importance sampling: ``is_scale = 1`` degenerates to the iid stream
    with unit weights, and the self-normalized streamed yield equals the
    brute-force weighted estimate from the dense oracle;
  * the Wilson / Clopper-Pearson bounds and the fixed-grid quantile
    sketch against closed-form references;
  * the ``shard_map`` leg over ``make_variant_mesh`` reproduces the
    single-device stream (8 fake devices, subprocess);
  * the assignment-chunked recombination (``mc_chunk=``) is a pure
    program-shape knob.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.api import compiled as api
from repro.core import dse, mcstream, trainer
from repro.core.analog import AnalogBinaryClassifier, variant_dim
from repro.core.svm import SVMModel

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")

#: Moment tolerance of the streamed-vs-dense parity contract (f32
#: accumulation order differs between the two programs).
MOMENT_TOL = 1e-4


def _tiny_candidates(m: int = 6, d: int = 3):
    rng = np.random.default_rng(0)
    sx = rng.normal(size=(m, d)).astype(np.float32)
    sy = (np.arange(m) % 2 * -2 + 1).astype(np.float32)
    alpha = (np.abs(rng.normal(size=m)) + 0.1).astype(np.float32)
    w = ((alpha * sy) @ sx).astype(np.float32)
    lin = SVMModel(kind="linear", support_x=sx, support_y=sy, alpha=alpha,
                   bias=0.1, gamma=1.0, c=1.0, w=w)
    rbf = SVMModel(kind="rbf", support_x=sx, support_y=sy, alpha=alpha,
                   bias=-0.05, gamma=0.7, c=1.0)
    hw_clf = AnalogBinaryClassifier.deploy(rbf, trainer.default_hw(0))
    hw_small = AnalogBinaryClassifier.deploy(
        SVMModel(kind="rbf", support_x=sx[:4], support_y=sy[:4],
                 alpha=alpha[:4], bias=0.02, gamma=0.9, c=1.0),
        trainer.default_hw(0))
    return [(lin, rbf), (lin, hw_clf), (lin, hw_small)]


@pytest.fixture(scope="module")
def tiny():
    rng = np.random.default_rng(1)
    cands = _tiny_candidates()
    x = rng.normal(size=(40, 3)).astype(np.float32)
    y = rng.integers(0, 3, size=40).astype(np.int32)
    a = np.ones((1, 3), bool)
    return cands, x, y, a


def _dense_stats(sm, x, y, a, n_variants, floor, chunk=8):
    """Brute-force oracle: the machine's own dense bits, recombined."""
    bits = np.concatenate([
        sm.pair_bits_dense(x, np.arange(s, min(s + chunk, n_variants)))
        for s in range(0, n_variants, chunk)])
    acc = dse.assignment_accuracies_mc(bits, a, y, 3)
    return acc


# -- streamed vs dense parity -------------------------------------------------


def test_streamed_matches_dense_oracle(tiny):
    cands, x, y, a = tiny
    sm = api.compile_mc_stream(cands, n_classes=3,
                               key=jax.random.PRNGKey(0), mc_chunk=8)
    floor = 0.6
    out = sm.stream(x, y, a, n_variants=21, accuracy_floor=floor)
    acc = _dense_stats(sm, x, y, a, 21, floor)
    assert abs(out["mean"][0] - acc.mean()) < MOMENT_TOL
    assert abs(out["std"][0] - acc.std()) < MOMENT_TOL
    assert out["worst"][0] == acc.min()
    assert out["best"][0] == acc.max()
    assert out["yield"][0] == (acc >= floor).mean()
    assert out["count"] == 21.0 and out["n_eff"] == pytest.approx(21.0)


def test_multi_assignment_columns(tiny):
    cands, x, y, _ = tiny
    sm = api.compile_mc_stream(cands, n_classes=3,
                               key=jax.random.PRNGKey(0), mc_chunk=8)
    a = np.array([[1, 1, 1], [0, 0, 0], [1, 0, 1]], bool)
    out = sm.stream(x, y, a, n_variants=17, accuracy_floor=0.5)
    acc = _dense_stats(sm, x, y, a, 17, 0.5)
    np.testing.assert_allclose(out["mean"], acc.mean(0), atol=MOMENT_TOL)
    np.testing.assert_array_equal(out["worst"], acc.min(0))
    np.testing.assert_array_equal(out["yield"], (acc >= 0.5).mean(0))


# -- chunk edges and invariance ----------------------------------------------


@pytest.mark.parametrize("n_variants", [1, 8, 9])
def test_chunk_edges(tiny, n_variants):
    """V = 1, V = chunk, V = chunk + 1: the padded tail stays inert."""
    cands, x, y, a = tiny
    sm = api.compile_mc_stream(cands, n_classes=3,
                               key=jax.random.PRNGKey(0), mc_chunk=8)
    out = sm.stream(x, y, a, n_variants=n_variants, accuracy_floor=0.6)
    acc = _dense_stats(sm, x, y, a, n_variants, 0.6)
    assert out["count"] == float(n_variants)
    assert abs(out["mean"][0] - acc.mean()) < MOMENT_TOL
    assert out["worst"][0] == acc.min()
    assert out["yield"][0] == (acc >= 0.6).mean()


def test_chunk_size_invariance(tiny):
    """The whole statistics dict is a pure function of (key, V)."""
    cands, x, y, a = tiny
    outs = []
    for chunk in (5, 8, 32):
        sm = api.compile_mc_stream(cands, n_classes=3,
                                   key=jax.random.PRNGKey(0),
                                   mc_chunk=chunk)
        outs.append(sm.stream(x, y, a, n_variants=21, accuracy_floor=0.6))
    for out in outs[1:]:
        for k in ("mean", "std", "worst", "best", "yield", "yield_lo"):
            np.testing.assert_allclose(out[k], outs[0][k], atol=2e-6)
        np.testing.assert_allclose(out["hist"], outs[0]["hist"])


def test_stream_rejects_bad_config(tiny):
    cands, x, y, a = tiny
    with pytest.raises(ValueError, match="method"):
        api.compile_mc_stream(cands, n_classes=3,
                              key=jax.random.PRNGKey(0), method="mcmc")
    with pytest.raises(ValueError, match="mc_chunk"):
        api.compile_mc_stream(cands, n_classes=3,
                              key=jax.random.PRNGKey(0), mc_chunk=0)
    sm = api.compile_mc_stream(cands, n_classes=3,
                               key=jax.random.PRNGKey(0), mc_chunk=8)
    with pytest.raises(ValueError, match="n_variants"):
        sm.stream(x, y, a, n_variants=0, accuracy_floor=0.5)


# -- QMC ---------------------------------------------------------------------


def test_sobol_deterministic_from_key(tiny):
    cands, x, y, a = tiny
    mk = lambda key, chunk: api.compile_mc_stream(
        cands, n_classes=3, key=key, method="sobol", mc_chunk=chunk)
    out1 = mk(jax.random.PRNGKey(7), 8).stream(
        x, y, a, n_variants=24, accuracy_floor=0.6)
    out2 = mk(jax.random.PRNGKey(7), 8).stream(
        x, y, a, n_variants=24, accuracy_floor=0.6)
    np.testing.assert_array_equal(out1["hist"], out2["hist"])
    assert out1["mean"][0] == out2["mean"][0]
    # fast_forward makes the sequence chunk-size invariant
    out3 = mk(jax.random.PRNGKey(7), 16).stream(
        x, y, a, n_variants=24, accuracy_floor=0.6)
    np.testing.assert_allclose(out3["mean"], out1["mean"], atol=2e-6)
    # a different key scrambles differently
    out4 = mk(jax.random.PRNGKey(8), 8).stream(
        x, y, a, n_variants=24, accuracy_floor=0.6)
    assert not np.array_equal(out4["hist"], out1["hist"])


def test_sobol_dense_oracle_parity(tiny):
    """pair_bits_dense replays the SAME Sobol draws as the stream."""
    cands, x, y, a = tiny
    sm = api.compile_mc_stream(cands, n_classes=3,
                               key=jax.random.PRNGKey(3), method="sobol",
                               mc_chunk=8)
    out = sm.stream(x, y, a, n_variants=16, accuracy_floor=0.6)
    acc = _dense_stats(sm, x, y, a, 16, 0.6)
    assert abs(out["mean"][0] - acc.mean()) < MOMENT_TOL
    assert out["worst"][0] == acc.min()
    assert out["yield"][0] == (acc >= 0.6).mean()


# -- importance sampling ------------------------------------------------------


def test_is_scale_one_degenerates_to_iid(tiny):
    """is_scale = 1: identical draws to the iid stream, unit weights."""
    cands, x, y, a = tiny
    iid = api.compile_mc_stream(cands, n_classes=3,
                                key=jax.random.PRNGKey(0), mc_chunk=8)
    is1 = api.compile_mc_stream(cands, n_classes=3,
                                key=jax.random.PRNGKey(0), method="is",
                                is_scale=1.0, mc_chunk=8)
    np.testing.assert_allclose(is1.chunk_weights(np.arange(8)), 1.0,
                               atol=1e-5)
    o1 = iid.stream(x, y, a, n_variants=20, accuracy_floor=0.6)
    o2 = is1.stream(x, y, a, n_variants=20, accuracy_floor=0.6)
    np.testing.assert_allclose(o2["mean"], o1["mean"], atol=2e-6)
    np.testing.assert_array_equal(o2["worst"], o1["worst"])
    assert o2["n_eff"] == pytest.approx(o1["n_eff"], rel=1e-4)


def test_is_yield_matches_brute_force_weighted_estimate(tiny):
    """Self-normalized streamed yield == sum(w 1[acc >= floor]) / sum(w)
    with the weights and accuracies both read back densely."""
    cands, x, y, a = tiny
    sm = api.compile_mc_stream(cands, n_classes=3,
                               key=jax.random.PRNGKey(0), method="is",
                               is_scale=1.3, mc_chunk=8)
    floor, v = 0.6, 24
    out = sm.stream(x, y, a, n_variants=v, accuracy_floor=floor)
    acc = np.asarray(_dense_stats(sm, x, y, a, v, floor)[:, 0], np.float64)
    w = np.concatenate([np.asarray(sm.chunk_weights(np.arange(s, s + 8)),
                                   np.float64)
                        for s in range(0, v, 8)])
    assert np.isfinite(w).all() and w.min() > 0
    expect_yield = float((w * (acc >= floor)).sum() / w.sum())
    expect_mean = float((w * acc).sum() / w.sum())
    expect_neff = float(w.sum() ** 2 / (w * w).sum())
    assert out["yield"][0] == pytest.approx(expect_yield, abs=1e-5)
    assert out["mean"][0] == pytest.approx(expect_mean, abs=1e-4)
    assert out["n_eff"] == pytest.approx(expect_neff, rel=1e-3)


# -- the accumulator / bound / sketch layer ----------------------------------


def test_update_stream_matches_numpy_weighted_moments():
    rng = np.random.default_rng(0)
    acc = rng.uniform(0.3, 1.0, size=(48, 2)).astype(np.float32)
    w = rng.uniform(0.1, 3.0, size=48).astype(np.float32)
    state = mcstream.init_stream(2, mcstream.hist_bins(100))
    for s in range(0, 48, 16):
        state = mcstream.update_stream(
            state, acc[s:s + 16], w[s:s + 16],
            np.ones(16, np.float32), np.float32(0.6))
    out = mcstream.finalize(state)
    wm = (w[:, None] * acc).sum(0) / w.sum()
    wv = (w[:, None] * (acc - wm) ** 2).sum(0) / w.sum()
    np.testing.assert_allclose(out["mean"], wm, atol=1e-5)
    np.testing.assert_allclose(out["std"], np.sqrt(wv), atol=1e-5)
    np.testing.assert_allclose(
        out["yield"], (w[:, None] * (acc >= 0.6)).sum(0) / w.sum(),
        atol=1e-5)
    np.testing.assert_allclose(out["worst"], acc.min(0), atol=0)


def test_wilson_and_clopper_pearson_bounds():
    lo, hi = mcstream.wilson_bounds(1.0, 64)
    assert hi == pytest.approx(1.0)
    assert lo == pytest.approx(0.9434, abs=2e-4)  # 3.84/(64+3.84)
    lo0, hi0 = mcstream.wilson_bounds(0.0, 64)
    assert lo0 == 0.0 and hi0 == pytest.approx(1 - 0.9434, abs=2e-4)
    lo5, hi5 = mcstream.wilson_bounds(0.5, 100)
    assert lo5 == pytest.approx(0.404, abs=2e-3)
    assert hi5 == pytest.approx(0.596, abs=2e-3)
    scipy_stats = pytest.importorskip("scipy.stats")
    clo, chi = mcstream.clopper_pearson_bounds(0.9, 100)
    assert clo == pytest.approx(scipy_stats.beta.ppf(0.025, 90, 11),
                                abs=1e-6)
    assert chi == pytest.approx(scipy_stats.beta.ppf(0.975, 91, 10),
                                abs=1e-6)
    clo1, chi1 = mcstream.clopper_pearson_bounds(1.0, 64)
    assert chi1 == 1.0 and clo1 == pytest.approx(0.025 ** (1 / 64),
                                                 abs=1e-4)


def test_hist_quantiles_exact_on_grid():
    """n_bins = n_val + 1 puts every attainable accuracy on a bin center,
    so the sketch's type-1 quantiles are exact."""
    n_val = 20
    acc = np.array([[5, 10, 10, 15, 18]], np.float32).T / n_val  # (5, 1)
    state = mcstream.init_stream(1, mcstream.hist_bins(n_val))
    state = mcstream.update_stream(
        state, acc, np.ones(5, np.float32), np.ones(5, np.float32),
        np.float32(0.5))
    qs = mcstream.hist_quantiles(np.asarray(state.hist),
                                 np.array([0.0, 0.2, 0.5, 1.0]))
    np.testing.assert_allclose(qs[:, 0],
                               [5 / 20, 5 / 20, 10 / 20, 18 / 20],
                               atol=1e-6)


# -- the sharded leg ----------------------------------------------------------


def test_sharded_stream_matches_local():
    """shard_map over the variants axis reproduces the single-device
    stream (8 fake devices, subprocess so XLA_FLAGS doesn't leak)."""
    body = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax
        import sys
        sys.path.insert(0, os.path.join(%r, "tests"))
        from test_mc_streaming import _tiny_candidates
        from repro.api import compiled as api
        from repro.launch import mesh as mesh_mod

        rng = np.random.default_rng(1)
        x = rng.normal(size=(40, 3)).astype(np.float32)
        y = rng.integers(0, 3, size=40).astype(np.int32)
        a = np.ones((1, 3), bool)
        cands = _tiny_candidates()
        sm = api.compile_mc_stream(cands, n_classes=3,
                                   key=jax.random.PRNGKey(0), mc_chunk=12)
        mesh = mesh_mod.make_variant_mesh()
        assert mesh.shape["variants"] == 8
        lo = sm.stream(x, y, a, n_variants=37, accuracy_floor=0.6)
        sh = sm.stream(x, y, a, n_variants=37, accuracy_floor=0.6,
                       mesh=mesh)
        assert sh["count"] == 37.0
        for k in ("mean", "std", "worst", "best", "yield"):
            np.testing.assert_allclose(sh[k], lo[k], atol=1e-5), k
        np.testing.assert_allclose(sh["hist"], lo["hist"], atol=1e-3)
        print("OK")
    """) % os.path.abspath(os.path.join(os.path.dirname(__file__),
                                        os.pardir))
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", body], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, \
        f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    assert "OK" in res.stdout


# -- the assignment-chunk knob (satellite: host loop now in-graph) ------------


def test_assignment_chunk_knob_is_pure_shape(tiny):
    cands, x, y, _ = tiny
    mcm = api.compile_variants(cands, n_classes=3,
                               key=jax.random.PRNGKey(0), n_variants=4)
    bits3 = mcm.pair_bits(x)
    a = np.array([[1, 1, 1], [0, 1, 0], [1, 0, 1], [0, 0, 1],
                  [1, 1, 0]], bool)
    ref = dse.assignment_accuracies_mc(bits3, a, y, 3)
    for chunk in (1, 2, 5, 16):
        got = dse.assignment_accuracies_mc(bits3, a, y, 3, mc_chunk=chunk)
        np.testing.assert_array_equal(got, ref)
    with pytest.raises(ValueError, match="mc_chunk"):
        dse.assignment_accuracies_mc(bits3, a, y, 3, mc_chunk=0)


def test_variant_dim_layout():
    """The flat QMC layout and the fold_in draw agree on the dim count."""
    assert variant_dim(6, 3) == 6 * 3 * 4 + 6 * 2 + 1
    sm = api.compile_mc_stream(_tiny_candidates(), n_classes=3,
                               key=jax.random.PRNGKey(0))
    # two analog banks: one m=6 pair and one m=4 pair padded to m_max
    assert sm.true_dim == variant_dim(6, 3) + variant_dim(4, 3)
    assert sm.mismatch_dim >= sm.true_dim
