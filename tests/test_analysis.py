"""Static analyzer rule fixtures (DESIGN.md §8).

Each rule gets a known-bad snippet proving it fires exactly there, a
known-good twin proving it stays quiet on the repo's sanctioned idioms,
and the whole-repo run must come back clean modulo the committed
baseline — the same invocation CI gates on.
"""
import dataclasses
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import ast_lint, jaxpr_checks, pallas_budget
from repro.analysis.report import (
    Finding,
    Report,
    Waiver,
    dump_baseline,
    load_baseline,
)

REPO = pathlib.Path(__file__).resolve().parent.parent


def lint_snippet(tmp_path, source: str):
    p = tmp_path / "snippet.py"
    p.write_text(source)
    findings, _ = ast_lint.lint_files([str(p)], str(tmp_path))
    return findings


def rules_of(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# KEY-REUSE
# ---------------------------------------------------------------------------


def test_key_reuse_fires_on_double_draw(tmp_path):
    # the exact PR 4 bug shape: one key feeding two sweeps
    findings = lint_snippet(tmp_path, """
import jax

def from_circuit(params, key):
    dv, curve = dc_sweep_gaussian(params, key=key)
    dva, ratio = dc_sweep_alpha(params, key=key)
    return curve, ratio
""")
    assert [f.rule for f in findings] == ["KEY-REUSE"]
    assert findings[0].symbol == "from_circuit"


def test_key_reuse_fires_on_loop_without_rotation(tmp_path):
    findings = lint_snippet(tmp_path, """
import jax

def sample(key, n):
    out = []
    for i in range(n):
        out.append(jax.random.normal(key, (4,)))
    return out
""")
    assert rules_of(findings) == {"KEY-REUSE"}


def test_key_reuse_quiet_on_split_and_rotate(tmp_path):
    findings = lint_snippet(tmp_path, """
import jax

def ok_split(key):
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, (4,))
    b = jax.random.normal(k2, (4,))
    return a + b

def ok_rotate(key, n):
    out = []
    for i in range(n):
        key, sub = jax.random.split(key)
        out.append(jax.random.normal(sub, (4,)))
    return out

def ok_fold(base, n):
    return [jax.random.normal(jax.random.fold_in(base, i), (4,))
            for i in range(n)]

def ok_branches(key, flag):
    if flag:
        return jax.random.normal(key, (4,))
    else:
        return jax.random.uniform(key, (4,))
""")
    assert findings == []


# ---------------------------------------------------------------------------
# INTERPRET-THREAD
# ---------------------------------------------------------------------------


def test_interpret_thread_fires_on_unthreaded_call(tmp_path):
    findings = lint_snippet(tmp_path, """
from repro.kernels import ops

def score(x, z, gamma):
    return ops.rbf_matrix(x, z, gamma, kind="rbf")
""")
    assert [f.rule for f in findings] == ["INTERPRET-THREAD"]
    assert findings[0].symbol == "score"


def test_interpret_thread_fires_on_unthreadable_forward(tmp_path):
    # passes interpret= but has no parameter to thread it from
    findings = lint_snippet(tmp_path, """
from repro.kernels import ops

def score(x, z, gamma):
    return ops.rbf_matrix(x, z, gamma, interpret=interpret)
""")
    assert [f.rule for f in findings] == ["INTERPRET-THREAD"]


def test_interpret_thread_quiet_on_threaded_and_local_names(tmp_path):
    findings = lint_snippet(tmp_path, """
from repro.kernels import ops

def score(x, z, gamma, interpret=None):
    return ops.rbf_matrix(x, z, gamma, interpret=interpret)

def rbf_matrix(x, z, gamma):   # local jnp oracle shadows the entry name
    return x @ z.T

def uses_local(x, z, gamma):
    return rbf_matrix(x, z, gamma)
""")
    assert findings == []


# ---------------------------------------------------------------------------
# PYTREE-REG
# ---------------------------------------------------------------------------


def test_pytree_reg_fires_on_unregistered_dataclass(tmp_path):
    findings = lint_snippet(tmp_path, """
import dataclasses
import jax.numpy as jnp

@dataclasses.dataclass
class Bank:
    w: jnp.ndarray
    b: jnp.ndarray
""")
    assert [f.rule for f in findings] == ["PYTREE-REG"]
    assert findings[0].symbol == "Bank"


def test_pytree_reg_quiet_when_registered(tmp_path):
    findings = lint_snippet(tmp_path, """
import dataclasses
import jax
import jax.numpy as jnp

@dataclasses.dataclass
class Bank:
    w: jnp.ndarray

jax.tree_util.register_dataclass(Bank, data_fields=("w",), meta_fields=())
""")
    assert findings == []


# ---------------------------------------------------------------------------
# BANNED-IN-HOT
# ---------------------------------------------------------------------------


def test_banned_in_hot_fires_on_all_three_classes(tmp_path):
    findings = lint_snippet(tmp_path, """
import time
import jax
import numpy as np

@jax.jit
def hot(x):
    noise = np.random.normal(size=4)
    t0 = time.time()
    s = x.sum().item()
    return x + noise + t0 + s
""")
    assert [f.rule for f in findings] == ["BANNED-IN-HOT"] * 3
    msgs = " ".join(f.message for f in findings)
    assert "np.random" in msgs and "time.time" in msgs and ".item()" in msgs


def test_banned_in_hot_quiet_outside_jit(tmp_path):
    findings = lint_snippet(tmp_path, """
import time
import numpy as np

def host_bench(x):
    t0 = time.time()
    return np.random.normal(size=4), time.time() - t0
""")
    assert findings == []


# ---------------------------------------------------------------------------
# Pass 1: F64-IN-JIT / HOST-CALLBACK / CONST-BAKE / DONATION-DROPPED
# ---------------------------------------------------------------------------


def test_f64_leak_fires():
    with jax.experimental.enable_x64():
        closed = jax.make_jaxpr(
            lambda x: x * np.float64(2.0))(jnp.zeros(3, jnp.float32))
    findings, _ = jaxpr_checks.check_jaxpr(closed, path="fixture",
                                           symbol="f64_leak")
    assert "F64-IN-JIT" in rules_of(findings)


def test_f64_clean_repo_default():
    # with x64 disabled (the repo default) the same program stays f32
    closed = jax.make_jaxpr(
        lambda x: x * np.float64(2.0))(jnp.zeros(3, jnp.float32))
    findings, _ = jaxpr_checks.check_jaxpr(closed, path="fixture",
                                           symbol="f32_ok")
    assert findings == []


def test_host_callback_fires():
    def noisy(x):
        jax.debug.callback(lambda v: None, x)
        return x + 1.0

    closed = jax.make_jaxpr(noisy)(jnp.zeros(3, jnp.float32))
    findings, _ = jaxpr_checks.check_jaxpr(closed, path="fixture",
                                           symbol="noisy")
    assert "HOST-CALLBACK" in rules_of(findings)


def test_const_bake_fires_above_threshold():
    big = jnp.zeros((512, 1024), jnp.float32)   # 2 MiB closed-over weight

    closed = jax.make_jaxpr(lambda x: x @ big)(jnp.zeros(512, jnp.float32))
    findings, _ = jaxpr_checks.check_jaxpr(closed, path="fixture",
                                           symbol="capture")
    assert "CONST-BAKE" in rules_of(findings)
    findings, _ = jaxpr_checks.check_jaxpr(
        closed, path="fixture", symbol="capture",
        max_const_bytes=4 << 20)
    assert findings == []


def test_donation_honored_and_dropped():
    good_j = jax.jit(lambda y: y * 2.0, donate_argnums=(0,))
    findings, info = jaxpr_checks.check_donation(
        good_j, (jnp.ones((64,), jnp.float32),), {},
        path="fixture", symbol="good")
    assert findings == [] and info["honored"] is True

    # nothing the donated i32 buffer can alias: output is a bigger f32
    bad_j = jax.jit(lambda y: jnp.zeros((128,), jnp.float32) + y.sum(),
                    donate_argnums=(0,))
    findings, info = jaxpr_checks.check_donation(
        bad_j, (jnp.ones((4,), jnp.int32),), {},
        path="fixture", symbol="bad")
    assert [f.rule for f in findings] == ["DONATION-DROPPED"]
    assert info["honored"] is False


# ---------------------------------------------------------------------------
# Pass 3: VMEM-BUDGET / GRID-DIVISIBLE / FUSED-VS-ORACLE
# ---------------------------------------------------------------------------


def _record_one(shape, block, budget):
    from jax.experimental import pallas as pl

    with pallas_budget.record_pallas_calls() as recs:
        pl.pallas_call(
            lambda x_ref, o_ref: None,
            grid=(max(1, shape[0] // block[0]),),
            in_specs=[pl.BlockSpec(block, lambda i: (i, 0))],
            out_specs=pl.BlockSpec(block, lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct(shape, jnp.float32),
        )(jnp.zeros(shape, jnp.float32))
    assert len(recs) == 1
    return pallas_budget.analyze_record(recs[0], path="fixture",
                                        symbol="toy", vmem_budget=budget)


def test_vmem_budget_fires_on_oversized_blocks():
    # 2 operands x double-buffered 32x32 f32 blocks = 16 KiB > 1 KiB budget
    info, findings = _record_one((64, 32), (32, 32), budget=1024)
    assert [f.rule for f in findings] == ["VMEM-BUDGET"]
    assert info["vmem_bytes"] == 2 * 2 * 32 * 32 * 4


def test_grid_divisible_fires_on_ragged_shape():
    info, findings = _record_one((100, 32), (16, 32), budget=1 << 30)
    # one finding per ragged operand: the input and the output block spec
    assert rules_of(findings) == {"GRID-DIVISIBLE"} and len(findings) == 2


def test_repo_kernels_within_budget_and_fused_below_oracle():
    findings, info = pallas_budget.check_kernels()
    assert findings == []
    names = {p["symbol"] for p in info["programs"]}
    assert {"dual_ascent_lanes_pallas", "flash_attention",
            "ssd_scan_pallas"} <= names
    contract = info["fused_vs_oracle"]
    assert contract["holds"] is True
    # PR 5's ordering: the fused solver's whole working set is orders of
    # magnitude below the (lanes, n, n) Gram it replaces
    assert contract["fused_vmem_bytes"] < contract["oracle_gram_bytes"] / 100


def test_fused_vs_oracle_gate_fails_on_seeded_regression():
    # shrink the oracle below the fused footprint: the gate must fire
    findings, info = pallas_budget.check_kernels(oracle_bytes=1)
    assert "FUSED-VS-ORACLE" in rules_of(findings)
    assert info["fused_vs_oracle"]["holds"] is False


# ---------------------------------------------------------------------------
# Baseline / report machinery
# ---------------------------------------------------------------------------


def test_waiver_requires_reason(tmp_path):
    p = tmp_path / "baseline.json"
    p.write_text(json.dumps({
        "format": "repro.analysis.baseline", "version": 1,
        "waivers": [{"rule": "KEY-REUSE", "match": "x.py::f",
                     "reason": "  "}]}))
    with pytest.raises(ValueError, match="justification"):
        load_baseline(str(p))


def test_waiver_rejects_todo_placeholder(tmp_path):
    # an unedited placeholder reason must fail loudly, not pass review
    p = tmp_path / "baseline.json"
    for reason in ("TODO: justify this waiver", "todo later"):
        p.write_text(json.dumps({
            "format": "repro.analysis.baseline", "version": 1,
            "waivers": [{"rule": "KEY-REUSE", "match": "x.py::f",
                         "reason": reason}]}))
        with pytest.raises(ValueError, match="placeholder"):
            load_baseline(str(p))


def test_update_baseline_requires_real_reason(tmp_path, monkeypatch):
    # --update-baseline on a new finding must demand --reason and reject
    # TODO placeholders; with a real reason the waiver records it.
    from repro.analysis.__main__ import main

    src = tmp_path / "src"
    src.mkdir()
    (src / "bad.py").write_text(
        "import jax\n\n"
        "def f(key):\n"
        "    a = jax.random.normal(key)\n"
        "    b = jax.random.normal(key)\n"
        "    return a + b\n")
    baseline = tmp_path / "baseline.json"
    args = ["--root", str(tmp_path), "--no-jaxpr", "--no-pallas",
            "--baseline", str(baseline), "--update-baseline"]
    with pytest.raises(SystemExit):
        main(args)                                   # no --reason
    with pytest.raises(SystemExit):
        main(args + ["--reason", "TODO: fill in"])   # placeholder reason
    assert not baseline.exists()
    rc = main(args + ["--reason", "intentional correlated draws"])
    assert rc == 0
    waivers = load_baseline(str(baseline))
    assert waivers and all(
        w.reason == "intentional correlated draws" for w in waivers)
    # prior waivers keep their own justification on re-update
    rc = main(args + ["--reason", "a different reason"])
    assert rc == 0
    assert [w.reason for w in load_baseline(str(baseline))] == \
        [w.reason for w in waivers]


def test_waiver_glob_and_unused_tracking(tmp_path):
    f = Finding(rule="KEY-REUSE", path="benchmarks/fig4.py", symbol="run",
                message="m")
    report = Report(findings=[f], waivers=[
        Waiver(rule="KEY-REUSE", match="benchmarks/*", reason="r"),
        Waiver(rule="VMEM-BUDGET", match="never/*", reason="r"),
    ])
    assert report.new_findings == []
    assert len(report.waived_findings) == 1
    assert [w.rule for w in report.unused_waivers()] == ["VMEM-BUDGET"]
    # round-trip
    p = tmp_path / "b.json"
    dump_baseline(str(p), report.waivers)
    assert [dataclasses.asdict(w) for w in load_baseline(str(p))] == \
        [dataclasses.asdict(w) for w in report.waivers]


def test_finding_key_is_line_stable():
    a = Finding(rule="R", path="p.py", symbol="f", message="m", line=10)
    b = Finding(rule="R", path="p.py", symbol="f", message="m", line=99)
    assert a.key == b.key


# ---------------------------------------------------------------------------
# The CI gate: repo is clean modulo the committed baseline
# ---------------------------------------------------------------------------


def test_repo_clean_modulo_baseline(tmp_path):
    from repro.analysis.__main__ import main

    out = tmp_path / "report.json"
    rc = main(["--root", str(REPO),
               "--baseline", str(REPO / "analysis_baseline.json"),
               "--json", str(out)])
    assert rc == 0
    report = json.loads(out.read_text())
    assert report["summary"]["new"] == 0
    assert report["summary"]["unused_waivers"] == []
    # the report carries the regression-gate payloads
    contract = report["info"]["pallas_budget"]["fused_vs_oracle"]
    assert contract["holds"] is True
    donations = [e.get("donation") for e in
                 report["info"]["jaxpr_checks"]["entrypoints"]
                 if e.get("donation")]
    assert donations and all(d["honored"] for d in donations)


def test_gate_fails_without_baseline():
    from repro.analysis.__main__ import build_report

    report = build_report(str(REPO), run_jaxpr=False, run_pallas=False)
    # the deliberate exceptions exist, so an empty baseline must gate
    assert len(report.new_findings) > 0
