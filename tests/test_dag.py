"""Tests for the big-multiclass scale-out (DESIGN.md §11).

Covers the PR's guarantees:

  * DAG-vs-votes agreement contract: wherever the vote winner is
    unambiguous (a Condorcet winner — some class won all K-1 of its
    pairs), the O(K) DDAG decision EQUALS the votes decision, at
    K in {3, 5, 10}.  Tie policy: on non-Condorcet samples the two rules
    may legitimately differ — votes breaks ties toward the lowest class
    index, while the DAG resolves them by elimination order — so
    agreement there is measured, not asserted;
  * the compiled DAG front (`decider="dag"`) is bit-identical to the
    ``ovo.decide_dag`` host reference on the machine's own bits, and
    ``predict_votes`` stays bit-identical to the dense votes path;
  * ``decider="votes"`` machines are unchanged from the default build
    (bit-identity with the seed semantics);
  * the ``decider`` survives save/load and threads through
    ``compile_fleet`` / ``SVMEngine``;
  * the har12 scale workload: K=12, P=66, deterministic, registered in
    ``SCALE_DATASETS`` (not ``DATASETS`` — cost-model calibration parity);
  * pair-chunked votes scoring (`dse._votes_accuracy_paired`) is exact
    against the dense recombination, and the streaming MC engine accepts
    P > MAX_TABLE_BITS machines;
  * the portfolio search (greedy/flip + annealing + front polish) covers
    the exhaustive Pareto front on a small space.
"""
import numpy as np
import pytest

from repro.api import compile_machine
from repro.api.compiled import DECIDERS, CompiledMachine
from repro.api.fleet import compile_fleet
from repro.core import dse, ovo, svm as svm_mod
from repro.data import datasets


# -- host reference: DAG vs votes property -----------------------------------


@pytest.mark.parametrize("k", [3, 5, 10])
def test_dag_agrees_with_votes_on_condorcet_samples(k):
    """Exact agreement wherever some class won all its pairs; DAG output
    is always a valid class id everywhere."""
    rng = np.random.RandomState(k)
    p = len(ovo.class_pairs(k))
    bits = rng.randint(0, 2, size=(500, p))
    lv = ovo.decide_votes(bits, k)
    ld = ovo.decide_dag(bits, k)
    mask = ovo.condorcet_mask(bits, k)
    assert mask.any()
    np.testing.assert_array_equal(ld[mask], lv[mask])
    assert ld.min() >= 0 and ld.max() < k


def test_dag_consults_o_k_bits():
    """The DDAG consults exactly K-1 pairs: flipping every bit OUTSIDE the
    consulted path never changes the decision."""
    k = 6
    rng = np.random.RandomState(0)
    p = len(ovo.class_pairs(k))
    pm = ovo.pair_index_matrix(k)
    bits = rng.randint(0, 2, size=(64, p))
    for row in bits:
        lo, hi = 0, k - 1
        consulted = []
        for _ in range(k - 1):
            pr = pm[lo, hi]
            consulted.append(pr)
            if row[pr] == 1:
                hi -= 1
            else:
                lo += 1
        flipped = row.copy()
        untouched = np.setdiff1d(np.arange(p), consulted)
        flipped[untouched] ^= 1
        assert ovo.decide_dag(row[None], k)[0] == \
            ovo.decide_dag(flipped[None], k)[0]


# -- compiled DAG front ------------------------------------------------------


def _float_bit_machine(k, n=200, seed=0, **kw):
    rng = np.random.RandomState(seed)
    x = rng.rand(n, 3)
    y = rng.randint(0, k, n)
    clfs = []
    for (ci, cj) in ovo.class_pairs(k):
        mask = (y == ci) | (y == cj)
        yy = np.where(y[mask] == ci, 1.0, -1.0)
        m = svm_mod.train_binary(x[mask], yy, "linear", c=1.0, n_epochs=40)
        clfs.append(ovo.FloatBitClassifier(m))
    return compile_machine(clfs, n_classes=k, **kw), x, y


def _mixed_bit_machine(k, n=200, seed=0, **kw):
    """Alternating linear/rbf pairs — exercises multi-bank DAG plans."""
    rng = np.random.RandomState(seed)
    x = rng.rand(n, 3)
    y = rng.randint(0, k, n)
    clfs = []
    for pi, (ci, cj) in enumerate(ovo.class_pairs(k)):
        mask = (y == ci) | (y == cj)
        yy = np.where(y[mask] == ci, 1.0, -1.0)
        kind = "linear" if pi % 2 == 0 else "rbf"
        m = svm_mod.train_binary(x[mask], yy, kind, gamma=2.0, c=1.0,
                                 n_epochs=40)
        clfs.append(ovo.FloatBitClassifier(m))
    return compile_machine(clfs, n_classes=k, **kw), x, y


def test_dag_step_plans_reachability_and_slicing():
    """The static per-step plans skip exactly the banks owning no
    reachable pair and slice kernel gathers to the reachable true support
    count."""
    from repro.api.compiled import _dag_step_plans

    k = 6
    machine, _, _ = _mixed_bit_machine(k, decider="dag")
    banks = list(machine._linear_banks) + list(machine._kernel_banks)
    n_lin = len(machine._linear_banks)
    assert machine._kernel_banks, "mixed machine must have a kernel bank"
    plans = machine._step_plans
    assert plans == _dag_step_plans(machine._linear_banks,
                                    machine._kernel_banks, k)
    assert len(plans) == k - 1
    pair_of = ovo.class_pairs(k)
    for t, plan in enumerate(plans):
        assert len(plan) == len(banks)
        gap = k - 1 - t
        reach = {(j, j + gap) for j in range(t + 1)}
        for bi, (bank, entry) in enumerate(zip(banks, plan)):
            owned = {pair_of[int(g)] for g in np.asarray(bank.pair_idx)}
            hit = owned & reach
            if not hit:
                assert entry is None
            elif bi < n_lin:
                assert entry == -1
            else:
                coef = np.abs(np.asarray(bank.coef_pos)) + \
                    np.abs(np.asarray(bank.coef_neg))
                true_m = {pair_of[int(g)]: int((c != 0).sum())
                          for g, c in zip(np.asarray(bank.pair_idx), coef)}
                want = max(max(true_m[p] for p in hit), 1)
                assert entry == want
                assert entry <= bank.sv.shape[1]


def test_planned_dag_bit_identical_to_unplanned():
    """Static step pruning/slicing drops only exact +0.0 terms: the
    planned front equals both the unplanned gather front and the host
    reference on a mixed multi-bank machine."""
    import jax

    from repro.api.compiled import _dag_labels

    k = 6
    machine, x, _ = _mixed_bit_machine(k, decider="dag")
    got = machine.predict(x)
    np.testing.assert_array_equal(
        got, ovo.decide_dag(machine.predict_bits(x), k))
    unplanned = jax.jit(lambda xx: _dag_labels(
        xx, k, machine._pair_matrix, machine._linear_banks,
        machine._kernel_banks, machine._row_maps, None))
    np.testing.assert_array_equal(
        got, np.asarray(unplanned(np.asarray(x, np.float32))))


def test_compiled_dag_matches_host_reference():
    machine, x, _ = _float_bit_machine(6, decider="dag")
    bits = machine.predict_bits(x)
    np.testing.assert_array_equal(machine.predict(x),
                                  ovo.decide_dag(bits, 6))
    np.testing.assert_array_equal(machine.predict_votes(x),
                                  ovo.decide_votes(bits, 6))
    mask = ovo.condorcet_mask(bits, 6)
    agree = np.mean(machine.predict(x)[mask] ==
                    machine.predict_votes(x)[mask])
    assert agree == 1.0
    assert machine.dag_votes_agreement(x) >= \
        float(np.mean(mask))  # disagreement only possible off-Condorcet


def test_votes_decider_bit_identity_with_default():
    """decider='votes' is the default and produces the identical machine
    output — the seed semantics are untouched by the DAG front."""
    m_default, x, _ = _float_bit_machine(5)
    m_votes, _, _ = _float_bit_machine(5, decider="votes")
    m_dag, _, _ = _float_bit_machine(5, decider="dag")
    assert m_default.decider == "votes"
    np.testing.assert_array_equal(m_default.predict(x), m_votes.predict(x))
    np.testing.assert_array_equal(m_default.predict(x),
                                  m_dag.predict_votes(x))


def test_decider_validation_and_votes_oracle_guard():
    with pytest.raises(ValueError, match="decider"):
        _float_bit_machine(3, decider="nope")
    m_votes, x, _ = _float_bit_machine(3)
    with pytest.raises(ValueError):
        m_votes.dag_votes_agreement(x)
    assert set(DECIDERS) == {"votes", "dag"}


def test_decider_save_load_roundtrip(tmp_path):
    m_dag, x, _ = _float_bit_machine(5, decider="dag")
    path = str(tmp_path / "dag_machine")
    m_dag.save(path)
    loaded = CompiledMachine.load(path)
    assert loaded.decider == "dag"
    np.testing.assert_array_equal(loaded.predict(x), m_dag.predict(x))
    as_votes = CompiledMachine.load(path, decider="votes")
    np.testing.assert_array_equal(as_votes.predict(x),
                                  m_dag.predict_votes(x))


def test_fleet_and_engine_thread_decider():
    from repro.serving.svm_engine import SVMEngine

    m_a, x, _ = _float_bit_machine(5, seed=1)
    m_b, _, _ = _float_bit_machine(5, seed=2)
    fleet = compile_fleet({"a": m_a, "b": m_b}, decider="dag")
    assert fleet.decider == "dag"
    idx = np.array([0, 1] * 8, np.int32)
    xq = x[:16]
    labels = fleet.predict(xq, idx)
    dag_a = ovo.decide_dag(m_a.predict_bits(xq), 5)
    dag_b = ovo.decide_dag(m_b.predict_bits(xq), 5)
    np.testing.assert_array_equal(labels,
                                  np.where(idx == 0, dag_a, dag_b))
    np.testing.assert_array_equal(fleet.predict_votes(xq, idx),
                                  np.where(idx == 0,
                                           ovo.decide_votes(
                                               m_a.predict_bits(xq), 5),
                                           ovo.decide_votes(
                                               m_b.predict_bits(xq), 5)))
    with SVMEngine(m_a, max_batch=16, decider="dag") as eng:
        got = eng.predict(xq)
    np.testing.assert_array_equal(got, dag_a)


# -- har12 scale workload ----------------------------------------------------


def test_har12_dataset_contract():
    ds = datasets.load("har12")
    assert ds.n_classes == 12
    assert len(ovo.class_pairs(ds.n_classes)) == 66
    n = len(ds.y_train) + len(ds.y_test)
    assert n >= 5000
    assert ds.x_train.shape[1] == 5  # paper's FE feature budget
    np.testing.assert_array_equal(np.unique(ds.y_train), np.arange(12))
    np.testing.assert_array_equal(np.unique(ds.y_test), np.arange(12))
    ds2 = datasets.load("har12")
    np.testing.assert_array_equal(ds.x_train, ds2.x_train)  # deterministic
    assert "har12" in datasets.SCALE_DATASETS
    assert "har12" not in datasets.DATASETS


def test_har_feature_stage_shapes():
    rng = np.random.RandomState(0)
    w = rng.randn(7, datasets.HAR12_WINDOW, 3)
    feats = datasets.har_feature_stage(w)
    assert feats.shape == (7, 9)
    assert np.isfinite(feats).all()


# -- P > MAX_TABLE_BITS scoring paths ----------------------------------------


def test_paired_votes_scoring_exact_vs_dense():
    """The pair-chunked recombination equals the dense selected-bits path
    bit-for-bit, including at P not divisible by the chunk."""
    import jax.numpy as jnp

    for k, s in ((6, 9), (12, 5)):
        p = len(ovo.class_pairs(k))
        rng = np.random.RandomState(k)
        bits4 = rng.randint(0, 2, size=(2, 40, p, 2)).astype(np.int32)
        a = rng.randint(0, 2, size=(s, p)).astype(np.int32)
        y = rng.randint(0, k, 40).astype(np.int32)
        va, vb = dse._vote_matrices(k)
        got = np.asarray(dse._votes_accuracy_paired(
            jnp.asarray(bits4), jnp.asarray(a), jnp.asarray(y),
            jnp.asarray(va), jnp.asarray(vb)))
        ref = np.stack([np.asarray(dse._votes_accuracy(
            jnp.asarray(bits4[b]), jnp.asarray(a), jnp.asarray(y),
            jnp.asarray(va), jnp.asarray(vb))) for b in range(2)])
        np.testing.assert_array_equal(got, ref)


def test_multiclass_bank_past_table_bits():
    """MulticlassSVM construction at K=6 (P=15 > MAX_TABLE_BITS) no longer
    builds the 2^P table and decides via votes."""
    assert ovo.MAX_TABLE_BITS == 12
    machine, x, _ = _float_bit_machine(6)
    assert machine._decider.table is None
    assert machine.predict(x).shape == x[:, 0].shape


# -- portfolio search covers the exhaustive front ----------------------------


def test_portfolio_front_covers_exhaustive_front():
    """Forced portfolio (max_exhaustive=0) finds every exhaustive-front
    point on a small space — the small-P oracle contract."""
    from repro.core import hwcost, trainer
    from repro.core.analog import AnalogBinaryClassifier
    from repro.core.ovo import DigitalLinearClassifier
    from repro.core.svm import SVMModel

    k, d, m = 4, 3, 6
    rng = np.random.RandomState(0)
    hw = trainer.default_hw(0)
    gamma = float(trainer.hw_gamma_grid(hw)[3])
    cands = []
    for _ in ovo.class_pairs(k):
        w = rng.randn(d)
        lin = SVMModel(kind="linear", support_x=np.zeros((1, d)),
                       support_y=np.ones(1), alpha=np.zeros(1),
                       bias=float(-w.sum() / 2), gamma=1.0, c=1.0, w=w)
        sv = rng.rand(m, d)
        yv = np.where(rng.rand(m) > 0.5, 1.0, -1.0)
        rbf = SVMModel(kind="hw", support_x=sv, support_y=yv,
                       alpha=rng.rand(m) + 0.1,
                       bias=float(rng.randn() * 0.1),
                       gamma=gamma, c=1.0, kernel_fn=hw.kernel_response)
        cands.append((DigitalLinearClassifier.deploy(lin),
                      AnalogBinaryClassifier.deploy(rbf, hw)))
    space = dse.DesignSpace.from_candidates(cands, k, hwcost.CostModel())
    x = rng.rand(120, d)
    y = rng.randint(0, k, 120)
    ex = space.sweep(x, y)
    po = space.sweep(x, y, max_exhaustive=0, rng_seed=0)
    assert ex.exhaustive and not po.exhaustive
    ex_keys = {tuple(a) for a in np.asarray(ex.assignments[ex.front], bool)}
    po_keys = {tuple(a) for a in np.asarray(po.assignments[po.front], bool)}
    missing = ex_keys - po_keys
    assert not missing, f"portfolio missed {len(missing)} front points"
