"""Tests for the kernel-assignment design-space explorer (DESIGN.md §5).

Covers the tentpole guarantees:

  * vectorized ``assignment_costs`` == object-bank ``system_cost`` to f64
    round-off on the all-linear, all-RBF(-analog) and Algorithm-1
    assignments (the cost-layer refactor contract);
  * the candidate bit tensor agrees with the per-candidate object banks;
  * the exhaustive sweep's accuracies agree with compiled machines built
    per assignment; the front is non-dominated;
  * the full 2^P = 1024 exhaustive sweep of the paper's largest FE regime
    (K = 5 -> P = 10; the UCI datasets themselves have K = 3 -> P = 3)
    runs in <= 2 jit compiles and well under the 5 s budget;
  * budgeted ``deploy`` picks from the front, records ``assignment_``,
    round-trips through save/load, and the no-budget ``deploy('circuit')``
    stays exactly the Algorithm-1 machine;
  * the seeded greedy/flip search (forced via ``max_exhaustive``) finds
    the same front as enumeration on a small space.
"""
import os

import numpy as np
import pytest

from repro.api import MixedKernelSVM
from repro.core import dse, hwcost, trainer
from repro.core.analog import AnalogBinaryClassifier
from repro.core.ovo import DigitalLinearClassifier, MulticlassSVM
from repro.core.svm import SVMModel
from repro.data import datasets


@pytest.fixture(scope="module")
def balance():
    ds = datasets.load("balance")
    est = MixedKernelSVM(n_epochs=60, seed=0).fit(ds.x_train, ds.y_train)
    return ds, est


@pytest.fixture(scope="module")
def balance_sweep(balance):
    ds, est = balance
    return est.pareto(ds.x_test, ds.y_test)


def _assignment_banks(est):
    """Object banks for the three reference assignments."""
    cands = est._candidates()
    kmap = est.kernel_map_
    p = len(kmap)

    def bank(kernels):
        clfs = [c[1] if k == "rbf" else c[0]
                for c, k in zip(cands, kernels)]
        return MulticlassSVM(n_classes=est.n_classes_, classifiers=clfs,
                             kernel_map=list(kernels))

    return {
        "all_linear": (np.zeros(p, bool), bank(["linear"] * p)),
        "all_rbf": (np.ones(p, bool), bank(["rbf"] * p)),
        "alg1": (dse.assignment_from_kernel_map(kmap), bank(kmap)),
    }


# -- layer 1: vectorized cost == object-bank shim ----------------------------


def test_assignment_costs_match_system_cost(balance):
    """The equivalence regression of the cost refactor: the one-pass
    vectorized path prices the all-linear, all-RBF and Algorithm-1
    assignments exactly like ``system_cost`` walks the object banks."""
    _, est = balance
    cm = hwcost.CostModel()
    table = est.design_space(cm).cost_table
    for name, (assignment, bank) in _assignment_banks(est).items():
        ref = hwcost.system_cost(bank, cm)
        area, power = hwcost.assignment_costs(table, assignment[None, :])
        np.testing.assert_allclose(area[0], ref.area_mm2, rtol=1e-12,
                                   err_msg=name)
        np.testing.assert_allclose(power[0], ref.power_mw, rtol=1e-12,
                                   err_msg=name)


def test_assignment_costs_from_raw_candidates(balance):
    """The convenience signature (raw candidate pairs + cm) matches the
    prebuilt-table path, and demands a cost model."""
    _, est = balance
    cm = hwcost.CostModel()
    cands = est._candidates()
    a = dse.enumerate_assignments(len(cands))
    ar1, pw1 = hwcost.assignment_costs(cands, a, cm)
    table = hwcost.pair_cost_table(cands, cm)
    ar2, pw2 = hwcost.assignment_costs(table, a)
    np.testing.assert_array_equal(ar1, ar2)
    np.testing.assert_array_equal(pw1, pw2)
    with pytest.raises(ValueError, match="CostModel"):
        hwcost.assignment_costs(cands, a)


def test_all_rbf_assignment_has_no_adc(balance):
    """The all-analog corner drops the ADC bank entirely (the point of the
    mixed-signal architecture), the all-linear corner includes it."""
    _, est = balance
    cm = hwcost.CostModel()
    table = est.design_space(cm).cost_table
    p = table.n_pairs
    (a_lin, a_rbf), _ = hwcost.assignment_costs(
        table, np.stack([np.zeros(p, bool), np.ones(p, bool)]))
    per_clf = table.area[:, 0].sum() + table.encoder_area
    d = est.pairs_[0].model_linear.w.shape[0]
    assert a_lin == pytest.approx(
        per_clf + d * table.adc_area_per_feature, rel=1e-12)
    assert a_rbf == pytest.approx(
        table.area[:, 1].sum() + table.encoder_area, rel=1e-12)


# -- layer 2: the candidate bit tensor ---------------------------------------


def test_pair_bits_match_object_banks(balance):
    """bits[..., 0] reproduces the deployed-linear bank, bits[..., 1] the
    all-analog bank, bit-for-bit on Balance."""
    ds, est = balance
    machine = est.design_space().machine
    banks = _assignment_banks(est)
    for x in (ds.x_train, ds.x_test):
        bits2 = machine.pair_bits(x)
        assert bits2.shape == (len(x), len(est.kernel_map_), 2)
        np.testing.assert_array_equal(
            bits2[:, :, 0], banks["all_linear"][1].predict_bits(x))
        np.testing.assert_array_equal(
            bits2[:, :, 1], banks["all_rbf"][1].predict_bits(x))


# -- exhaustive sweep --------------------------------------------------------


def test_exhaustive_sweep_accuracies(balance, balance_sweep):
    """Every swept assignment's recombined accuracy equals the accuracy of
    the machine compiled for that assignment."""
    ds, est = balance
    sw = balance_sweep
    assert sw.exhaustive and sw.assignments.shape == (8, 3)
    for s in range(sw.assignments.shape[0]):
        machine = est.deploy_assignment(sw.kernel_map(s))
        assert sw.accuracy[s] == pytest.approx(
            machine.accuracy(ds.x_test, ds.y_test), abs=1e-6), s


def test_front_is_nondominated(balance_sweep):
    sw = balance_sweep
    front = set(sw.front.tolist())
    for i in front:
        dominated = (
            (sw.accuracy >= sw.accuracy[i]) & (sw.area <= sw.area[i])
            & (sw.power <= sw.power[i])
            & ((sw.accuracy > sw.accuracy[i]) | (sw.area < sw.area[i])
               | (sw.power < sw.power[i])))
        assert not dominated.any(), i
    # and every non-front point IS dominated by someone
    for i in set(range(sw.assignments.shape[0])) - front:
        dominated = (
            (sw.accuracy >= sw.accuracy[i]) & (sw.area <= sw.area[i])
            & (sw.power <= sw.power[i])
            & ((sw.accuracy > sw.accuracy[i]) | (sw.area < sw.area[i])
               | (sw.power < sw.power[i])))
        assert dominated.any(), i


def test_alg1_vertex_matches_circuit_machine(balance, balance_sweep):
    """The Algorithm-1 assignment is one vertex of the sweep, and its
    recombined accuracy equals the deployed circuit machine's."""
    ds, est = balance
    sw = balance_sweep
    i = sw.find(dse.assignment_from_kernel_map(est.kernel_map_))
    assert i is not None
    assert sw.accuracy[i] == pytest.approx(
        est.score(ds.x_test, ds.y_test, target="circuit"), abs=1e-6)


# -- deployment --------------------------------------------------------------


def test_deploy_no_budget_is_exact_alg1(balance, balance_sweep):
    """Acceptance: after a Pareto sweep, est.deploy('circuit') with no
    budget still reproduces the Algorithm-1 machine bit-for-bit."""
    ds, est = balance
    machine = est.deploy("circuit")
    bank = est.bank("circuit")
    assert machine.kernel_map == est.kernel_map_
    for x in (ds.x_train, ds.x_test):
        np.testing.assert_array_equal(machine.predict(x), bank.predict(x))


def test_budgeted_deploy_and_save_roundtrip(balance, balance_sweep, tmp_path):
    ds, est = balance
    sw = balance_sweep
    # Budget exactly at a mid-front point: selection must meet it.
    j = sw.front[len(sw.front) // 2]
    machine = est.deploy("circuit", area_budget=float(sw.area[j]),
                         power_budget=float(sw.power[j]))
    assert est.assignment_ is not None
    i = sw.find(dse.assignment_from_kernel_map(est.assignment_))
    assert sw.area[i] <= sw.area[j] and sw.power[i] <= sw.power[j]
    assert machine.accuracy(ds.x_test, ds.y_test) == pytest.approx(
        sw.accuracy[i], abs=1e-6)
    # the chosen assignment survives save/load without retraining
    path = os.path.join(tmp_path, "m")
    est.save(path)
    est2 = MixedKernelSVM.load(path)
    assert est2.assignment_ == est.assignment_
    np.testing.assert_array_equal(
        est2.deploy_assignment().predict(ds.x_test),
        machine.predict(ds.x_test))
    # ... and the loaded estimator can sweep again (hw_all candidates
    # round-tripped through the save)
    assert all(p.model_hw is not None for p in est2.pairs_)
    est.assignment_ = None  # restore fixture state


def test_budgeted_deploy_requires_pareto(balance, tmp_path):
    ds, est = balance
    path = os.path.join(tmp_path, "m")
    est.save(path)
    fresh = MixedKernelSVM.load(path)  # no cached sweep
    with pytest.raises(RuntimeError, match="pareto"):
        fresh.deploy("circuit", area_budget=1.0)
    with pytest.raises(ValueError, match="circuit"):
        fresh.deploy("linear", area_budget=1.0)


def test_infeasible_budget_raises(balance, balance_sweep):
    _, est = balance
    with pytest.raises(ValueError, match="budget"):
        est.deploy("circuit", area_budget=1e-9)


# -- the P = 10 exhaustive regime (K = 5) ------------------------------------


def _synthetic_candidates(n_classes, d, m, seed=0):
    """Handcrafted per-pair candidates: deployed linear + analog RBF."""
    from repro.core.ovo import class_pairs

    rng = np.random.RandomState(seed)
    hw = trainer.default_hw(0)
    gamma = float(trainer.hw_gamma_grid(hw)[3])
    cands = []
    for _ in class_pairs(n_classes):
        w = rng.randn(d)
        lin = SVMModel(kind="linear", support_x=np.zeros((1, d)),
                       support_y=np.ones(1), alpha=np.zeros(1),
                       bias=float(-w.sum() / 2), gamma=1.0, c=1.0, w=w)
        sv = rng.rand(m, d)
        yv = np.where(rng.rand(m) > 0.5, 1.0, -1.0)
        rbf = SVMModel(kind="hw", support_x=sv, support_y=yv,
                       alpha=rng.rand(m) + 0.1, bias=float(rng.randn() * 0.1),
                       gamma=gamma, c=1.0, kernel_fn=hw.kernel_response)
        cands.append((DigitalLinearClassifier.deploy(lin),
                      AnalogBinaryClassifier.deploy(rbf, hw)))
    return cands


def test_exhaustive_p10_two_compiles_under_budget():
    """Acceptance: the full 2^10 = 1024-assignment space — accuracy AND
    cost — in <= 2 jit compiles and < 5 s (K = 5, the paper's largest FE
    machine; pair count matches Balance's encoder-table regime bound)."""
    import jax

    from benchmarks.svm_train import count_compiles

    cands = _synthetic_candidates(n_classes=5, d=4, m=6)
    space = dse.DesignSpace.from_candidates(cands, 5, hwcost.CostModel())
    rng = np.random.RandomState(1)
    x = rng.rand(400, 4).astype(np.float32)
    y = rng.randint(0, 5, 400)
    jax.clear_caches()
    with count_compiles() as cc:
        sw = space.sweep(x, y)
    assert sw.exhaustive
    assert sw.assignments.shape == (1024, 10)
    assert cc.count() <= 2, cc.names
    assert sw.elapsed_s < 5.0
    assert sw.assignments_per_s > 1024 / 5.0
    # corners recombine exactly: all-linear / all-rbf rows equal the
    # single-candidate machines
    bits2 = space.machine.pair_bits(x)
    from repro.core.ovo import build_encoder_table, decide_encoder

    table = build_encoder_table(5)
    for row, col in ((0, 0), (1023, 1)):
        labels = decide_encoder(bits2[:, :, col], table)
        assert sw.accuracy[row] == pytest.approx(
            float(np.mean(labels == y)), abs=1e-6)


# -- seeded search beyond the exhaustive regime ------------------------------


def test_seeded_search_matches_enumeration_on_small_space(balance):
    """Forcing the greedy/flip search on Balance's 3-pair space recovers
    the exhaustive front (it visits all corners via seeds + flips)."""
    ds, est = balance
    space = est.design_space()
    ex = space.sweep(ds.x_test, ds.y_test)
    alg1 = dse.assignment_from_kernel_map(est.kernel_map_)
    se = space.sweep(ds.x_test, ds.y_test, max_exhaustive=2,
                     seeds=alg1[None, :], n_random=4)
    assert not se.exhaustive
    assert se.find(alg1) is not None  # the seed itself was evaluated
    # corner seeds are always evaluated
    p = se.n_pairs
    visited = {a.tobytes() for a in se.assignments}
    assert np.zeros(p, bool).tobytes() in visited
    assert np.ones(p, bool).tobytes() in visited

    def front_set(sw):
        return {sw.assignments[i].tobytes() for i in sw.front}

    # any globally-non-dominated point the search visited must be on its
    # front (the search front can only differ on points it never saw)
    assert {b for b in front_set(ex) if b in visited} <= front_set(se)
    assert len(se.front) >= 1


def test_enumerate_assignments_guard():
    with pytest.raises(ValueError, match="refusing"):
        dse.enumerate_assignments(13)
    a = dse.enumerate_assignments(3)
    assert a.shape == (8, 3)
    assert a.sum() == 8 * 3 / 2  # balanced bit counts


def test_votes_fallback_matches_encoder_path(balance):
    """The votes-matmul sweep (P > 12 regime) agrees with the packed
    encoder table on the same bits."""
    ds, est = balance
    bits2 = est.design_space().machine.pair_bits(ds.x_test)
    a = dse.enumerate_assignments(3)
    acc_enc = dse.assignment_accuracies(bits2, a, ds.y_test, 3)
    acc_votes = dse.assignment_accuracies(bits2, a, ds.y_test, 3,
                                          max_table_bits=0)
    np.testing.assert_allclose(acc_votes, acc_enc, atol=1e-7)
