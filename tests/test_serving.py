"""Serving tests: decode == teacher-forced full forward per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as tfm
from repro.models.common import ShardRules
from repro.serving import engine

RULES = ShardRules()


def _ref_logits(cfg, params, tokens, patches=None):
    x = tfm.embed_tokens(cfg, params, tokens)
    if patches is not None:
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
    pos = jnp.arange(x.shape[1])
    x, _ = tfm.run_stack(cfg, RULES, params["layers"], x, pos)
    if patches is not None:
        x = x[:, patches.shape[1]:]
    return tfm.logits_from_x(cfg, params, x, RULES)


@pytest.mark.parametrize("arch", ["granite-20b", "mamba2-2.7b", "hymba-1.5b"])
def test_decode_matches_full_forward(arch):
    cfg = configs.get(arch).reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    B, S, T = 2, 40, 6
    rng = np.random.RandomState(0)
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)))
    state, logits = engine.prefill(cfg, params, {"tokens": tokens[:, :S - T]},
                                   cap=S + 2, rules=RULES)
    ref = _ref_logits(cfg, params, tokens)
    outs = [logits]
    for t in range(S - T, S):
        state, logits = engine.decode_step(cfg, params, state,
                                           tokens[:, t:t + 1], RULES)
        outs.append(logits)
    for i, o in enumerate(outs):
        np.testing.assert_allclose(np.asarray(o),
                                   np.asarray(ref[:, S - T - 1 + i]),
                                   atol=1e-3)


def test_vlm_decode_matches():
    cfg = configs.get("phi-3-vision-4.2b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    B, S, T = 2, 32, 4
    rng = np.random.RandomState(1)
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)))
    patches = jnp.asarray(rng.randn(B, cfg.n_patches, cfg.d_model), jnp.float32)
    cap = S + cfg.n_patches + 2
    state, logits = engine.prefill(
        cfg, params, {"tokens": tokens[:, :S - T], "patch_embeds": patches},
        cap=cap, rules=RULES)
    ref = _ref_logits(cfg, params, tokens, patches)
    outs = [logits]
    for t in range(S - T, S):
        state, logits = engine.decode_step(cfg, params, state,
                                           tokens[:, t:t + 1], RULES)
        outs.append(logits)
    for i, o in enumerate(outs):
        np.testing.assert_allclose(np.asarray(o),
                                   np.asarray(ref[:, S - T - 1 + i]),
                                   atol=1e-3)


def test_whisper_decode_runs_and_is_consistent():
    cfg = configs.get("whisper-medium").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    B, Se, Sd = 2, 24, 12
    rng = np.random.RandomState(2)
    frames = jnp.asarray(rng.randn(B, Se, cfg.d_model), jnp.float32)
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, Sd)))
    # reference: full decoder pass
    enc = tfm.encode_audio(cfg, RULES, params, frames)
    x = tfm.embed_tokens(cfg, params, tokens)
    x, _ = tfm._run_dec_stack_audio(cfg, RULES, params, x,
                                    jnp.arange(Sd), enc)
    ref = tfm.logits_from_x(cfg, params, x, RULES)
    # serve path: cap chosen so cap//enc_seq_divisor >= Se and dec cap >= Sd
    cap = max(Se * cfg.enc_seq_divisor, 8 * cfg.dec_seq_divisor * 8)
    state, logits = engine.prefill_audio(
        cfg, params, {"frames": frames, "tokens": tokens[:, :Sd - 3]},
        cap=cap, rules=RULES)
    # xk/xv capacity may exceed Se; padding keys attend as zeros — mask by
    # comparing only to a reference computed with the same padded length.
    outs = [logits]
    for t in range(Sd - 3, Sd):
        state, logits = engine.decode_step(cfg, params, state,
                                           tokens[:, t:t + 1], RULES)
        outs.append(logits)
    for o in outs + [ref]:
        assert np.all(np.isfinite(np.asarray(o, np.float32)))


def test_ring_buffer_equivalence():
    """SWA ring cache attends to exactly the last W positions."""
    from repro.models import attention as A
    cfg = configs.get("hymba-1.5b").reduced()
    dh, hkv, W = 16, 2, 8
    rng = np.random.RandomState(3)
    cache = A.KVCache.create(1, hkv, W, dh, jnp.float32, ring=True)
    ks = jnp.asarray(rng.randn(20, 1, hkv, 1, dh), jnp.float32)
    vs = jnp.asarray(rng.randn(20, 1, hkv, 1, dh), jnp.float32)
    for pos in range(20):
        cache = A.cache_update(cache, ks[pos], vs[pos], pos)
    q = jnp.asarray(rng.randn(1, 4, 1, dh), jnp.float32)
    got = A.attend_decode(cfg, q, cache, jnp.int32(19), window=W)
    # reference: plain attention over the last W kv
    kfull = jnp.concatenate(list(ks[12:20]), axis=2)
    vfull = jnp.concatenate(list(vs[12:20]), axis=2)
    from repro.kernels import ref as kref
    want = kref.attention(q, kfull, vfull, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_state_shapes_cover_all_families():
    for arch in configs.ARCHS:
        cfg = configs.get(arch).reduced()
        shapes = engine.state_shapes(cfg, batch=2, cap=64)
        assert "pos" in shapes
        st = engine.init_state(cfg, 2, 64)
        for leaf in jax.tree.leaves(st):
            assert np.all(np.asarray(leaf) == 0)
