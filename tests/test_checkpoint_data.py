"""Checkpoint fault-tolerance + data pipeline determinism tests."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.data.pipeline import PipelineConfig, TokenPipeline


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.int32)},
            "step": jnp.int32(7)}


def test_save_restore_roundtrip(tmp_path):
    import jax
    d = str(tmp_path)
    ckpt.save(d, 3, _tree())
    step, back = ckpt.restore(d, _tree())
    assert step == 3
    for x, y in zip(jax.tree.leaves(_tree()), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_latest_step_and_autoresume(tmp_path):
    d = str(tmp_path)
    assert ckpt.latest_step(d) is None
    for s in (1, 5, 3):
        ckpt.save(d, s, _tree())
    assert ckpt.latest_step(d) == 5


def test_crashed_save_is_ignored(tmp_path):
    """A .tmp dir (crash mid-save) must not be discovered."""
    d = str(tmp_path)
    ckpt.save(d, 2, _tree())
    os.makedirs(os.path.join(d, "step_00000009.tmp"))
    assert ckpt.latest_step(d) == 2
    step, _ = ckpt.restore(d, _tree())
    assert step == 2


def test_corruption_detected(tmp_path):
    d = str(tmp_path)
    path = ckpt.save(d, 1, _tree())
    # flip bytes in one leaf
    target = os.path.join(path, "a.npy")
    arr = np.load(target)
    arr[0, 0] += 1000.0
    np.save(target, arr)
    with pytest.raises(IOError, match="corruption"):
        ckpt.restore(d, _tree())


def test_atomic_overwrite(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 4, _tree())
    ckpt.save(d, 4, _tree())  # overwrite same step: no error, still valid
    step, _ = ckpt.restore(d, _tree())
    assert step == 4


# -- data pipeline ------------------------------------------------------------


def test_pipeline_deterministic_and_seekable():
    cfg = PipelineConfig(vocab_size=100, seq_len=32, global_batch=8, seed=1)
    p1 = TokenPipeline(cfg)
    p2 = TokenPipeline(cfg)
    b17a = p1.batch_at(17)
    b17b = p2.batch_at(17)
    np.testing.assert_array_equal(b17a["tokens"], b17b["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b17a["tokens"][:, 1:], b17a["labels"][:, :-1])


def test_pipeline_host_shards_disjoint_and_union():
    base = dict(vocab_size=50, seq_len=16, global_batch=8, seed=3)
    full = TokenPipeline(PipelineConfig(**base)).batch_at(5)["tokens"]
    parts = [
        TokenPipeline(PipelineConfig(**base, host_index=i, host_count=4)
                      ).batch_at(5)["tokens"]
        for i in range(4)
    ]
    np.testing.assert_array_equal(np.concatenate(parts, 0), full)


def test_pipeline_resume_equals_continuous():
    """Auto-resume from step t replays the exact stream (no drift)."""
    cfg = PipelineConfig(vocab_size=64, seq_len=8, global_batch=4, seed=9)
    p = TokenPipeline(cfg)
    cont = [p.batch_at(s)["tokens"] for s in range(6)]
    resumed = [TokenPipeline(cfg).batch_at(s)["tokens"] for s in (3, 4, 5)]
    for a, b in zip(cont[3:], resumed):
        np.testing.assert_array_equal(a, b)
