"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
output shapes + no NaNs (assignment requirement (f))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as tfm
from repro.models.common import ShardRules
from repro.training import optimizer as opt_mod
from repro.training import step as step_mod

RULES = ShardRules()


def _batch(cfg, rng, b=2, s=32):
    if cfg.family == "audio":
        return {
            "frames": jnp.asarray(rng.randn(b, s, cfg.d_model), jnp.float32),
            "tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s // 4))),
            "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s // 4))),
        }
    batch = {
        "tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s))),
        "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s))),
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.randn(b, cfg.n_patches, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = configs.get(arch).reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    batch = _batch(cfg, rng)
    loss, metrics = jax.jit(
        lambda p, b: tfm.forward_train(cfg, p, b, RULES))(params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0
    assert np.isfinite(float(metrics["xent"]))


@pytest.mark.parametrize("arch", ["starcoder2-7b", "granite-moe-1b-a400m",
                                  "mamba2-2.7b", "hymba-1.5b"])
def test_one_train_step_updates_params(arch):
    cfg = configs.get(arch).reduced()
    oc = opt_mod.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state = step_mod.init_train_state(cfg, oc, jax.random.PRNGKey(0))
    ts = jax.jit(step_mod.make_train_step(cfg, RULES, oc))
    rng = np.random.RandomState(0)
    before = jax.tree.leaves(state["params"])[3].copy()
    state, m = ts(state, _batch(cfg, rng))
    after = jax.tree.leaves(state["params"])[3]
    assert np.isfinite(float(m["loss"]))
    assert not np.allclose(np.asarray(before), np.asarray(after))
    assert int(state["step"]) == 1


def test_full_configs_match_assignment_table():
    """Exact dims from the assignment, spot-checked per arch."""
    expect = {
        "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
        "mamba2-2.7b": (64, 2560, 0, 0, 0, 50280),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
    }
    for arch, (L, D, H, KV, F, V) in expect.items():
        cfg = configs.get(arch).make_config()
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, D, H, KV, F, V), arch


def test_param_counts_plausible():
    """Analytic parameter counts land near the advertised sizes."""
    approx = {
        "starcoder2-7b": 7e9, "granite-20b": 20e9, "qwen2.5-32b": 32e9,
        "command-r-35b": 35e9, "kimi-k2-1t-a32b": 1.0e12,
        "granite-moe-1b-a400m": 1.3e9, "hymba-1.5b": 1.5e9,
        "phi-3-vision-4.2b": 4.2e9, "mamba2-2.7b": 2.7e9,
        "whisper-medium": 0.77e9,
    }
    for arch, n in approx.items():
        got = configs.get(arch).make_config().param_count()
        assert 0.5 * n < got < 1.8 * n, (arch, got, n)


def test_moe_aux_loss_present():
    cfg = configs.get("granite-moe-1b-a400m").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    loss, metrics = tfm.forward_train(cfg, params, _batch(cfg, rng), RULES)
    assert "moe_aux" in metrics and float(metrics["moe_aux"]) >= 0


def test_kimi_active_params():
    cfg = configs.get("kimi-k2-1t-a32b").make_config()
    active = cfg.active_param_count()
    assert 20e9 < active < 50e9  # a32b


def test_grouped_moe_equals_flat_when_no_drops():
    """apply_moe_grouped == apply_moe when capacity admits every token
    (the §Perf kimi dispatch optimization is a pure re-layout)."""
    import dataclasses
    import jax.numpy as jnp
    from repro.models import mlp as mlp_mod
    cfg = configs.get("granite-moe-1b-a400m").reduced()
    cfg = dataclasses.replace(cfg, capacity_factor=4.0)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    lp = jax.tree.map(lambda a: a[0], params["layers"])
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(2, 32, cfg.d_model), jnp.float32)
    y1, a1 = mlp_mod.apply_moe(cfg, RULES, lp["moe"], x)
    cfg2 = dataclasses.replace(cfg, moe_groups=4)
    y2, a2 = mlp_mod.apply_moe(cfg2, RULES, lp["moe"], x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)
    np.testing.assert_allclose(float(a1), float(a2), atol=1e-7)
