"""Quantization + OvO/encoder tests (paper Sec. III-C, V-A2).

The property tests use hypothesis when it is installed; on a bare
environment they fall back to a fixed set of representative examples so
`python -m pytest -x -q` still collects and runs.
"""
import numpy as np
import pytest

from _compat import property_test

from repro.core import ovo, quant


# -- quantization -----------------------------------------------------------


@property_test(
    fixed_examples=[([0.0, 1.0, 0.5], 4), ([-2.0, 3.0, 0.3, 0.7], 2),
                    ([0.123, 0.456, 0.789], 8), ([1e-9, 1.0 - 1e-9], 6)],
    strategies=lambda st: (
        st.lists(st.floats(-2.0, 3.0), min_size=1, max_size=40),
        st.integers(2, 8)),
)
def test_quantize_unit_bounds_and_idempotence(vals, bits):
    x = np.asarray(vals)
    q = np.asarray(quant.quantize_unit(x, bits))
    assert np.all(q >= 0) and np.all(q <= 1)
    # idempotence: re-quantizing is a fixed point
    np.testing.assert_allclose(np.asarray(quant.quantize_unit(q, bits)), q,
                               atol=1e-12)
    # max error bound for in-range values
    inr = (x >= 0) & (x <= 1)
    if inr.any():
        lsb = 1.0 / ((1 << bits) - 1)
        # + f32 ulp slack: the ADC model computes in float32
        assert np.max(np.abs(q[inr] - x[inr])) <= lsb / 2 + 1e-6


@property_test(
    fixed_examples=[([-100.0, 100.0, 0.0], 4), ([0.001, -0.002, 0.5], 8),
                    ([99.9, -99.9, 1.0, -1.0], 12), ([3.14159, -2.71828], 6)],
    strategies=lambda st: (
        st.lists(st.floats(-100, 100), min_size=1, max_size=30),
        st.integers(4, 12)),
)
def test_fixed_point_bound(vals, bits):
    x = np.asarray(vals, np.float64)
    xq, fp = quant.quantize_tensor(x, bits)
    if np.max(np.abs(x)) > 0:
        # error bounded by half an LSB at the chosen scale (f32 slack; the
        # subnormal-amax case clamps the scale and rounds tiny x to 0)
        bound = max(fp.scale / 2, np.max(np.abs(x)) * 1e-6) + 1e-12
        assert np.max(np.abs(xq - x)) <= bound


def test_csd_and_hardware_class():
    assert quant.weight_hardware_class(0) == "zero"
    for p in (1, 2, 4, 64):
        assert quant.weight_hardware_class(p) == "pow2"
        assert quant.weight_hardware_class(-p) == "pow2"
    assert quant.weight_hardware_class(3) == "general"
    # CSD: 7 = 8 - 1 -> 2 digits; 5 = 4 + 1 -> 2; 21 = 16+4+1 -> 3
    assert quant.csd_nonzero_digits(7) == 2
    assert quant.csd_nonzero_digits(5) == 2
    assert quant.csd_nonzero_digits(21) == 3
    assert quant.csd_nonzero_digits(1) == 1


def _minimal_signed_digits(c: int, _memo={0: 0, 1: 1}) -> int:
    """Brute-force minimal number of non-zero signed digits representing
    ``c`` as sum of +/- powers of two (the quantity CSD is minimal for).

    Recursion: even c needs exactly what c/2 needs (shift); odd c must
    spend one digit at bit 0, either +1 (leaving c-1) or -1 (leaving c+1);
    both residues halve to strictly smaller values for c >= 3.
    """
    c = abs(int(c))
    if c not in _memo:
        if c % 2 == 0:
            _memo[c] = _minimal_signed_digits(c // 2)
        else:
            _memo[c] = 1 + min(_minimal_signed_digits((c - 1) // 2),
                               _minimal_signed_digits((c + 1) // 2))
    return _memo[c]


def test_csd_digits_minimal_for_all_8bit_codes():
    """``csd_nonzero_digits`` equals the brute-force minimal signed-digit
    count for every 8-bit weight code (the cost model's adder count per
    bespoke constant multiplier rests on this)."""
    for c in range(-255, 256):
        assert quant.csd_nonzero_digits(c) == _minimal_signed_digits(c), c


def test_weight_hardware_class_all_8bit_codes():
    """zero/pow2 codes are exactly the multiplier-free classes: zero is
    code 0, pow2 is a single signed digit at a non-trivial magnitude."""
    for c in range(-255, 256):
        cls = quant.weight_hardware_class(c)
        if c == 0:
            assert cls == "zero"
        elif _minimal_signed_digits(c) == 1:
            # one signed digit <=> |c| is a power of two
            assert cls == "pow2", c
            assert abs(c) & (abs(c) - 1) == 0
        else:
            assert cls == "general", c


# -- OvO encoder ------------------------------------------------------------


@pytest.mark.parametrize("k", [3, 4, 5])
def test_encoder_equals_votes_exhaustive(k):
    """The hardwired encoder (Fig. 1) == majority voting w/ tiebreak, for
    EVERY possible bit pattern (exhaustive truth-table check)."""
    table = ovo.build_encoder_table(k)
    n_bits = len(ovo.class_pairs(k))
    codes = np.arange(1 << n_bits)
    bits = ((codes[:, None] >> np.arange(n_bits)[None]) & 1).astype(np.int32)
    np.testing.assert_array_equal(
        ovo.decide_encoder(bits, table), ovo.decide_votes(bits, k))


def test_unanimous_winner():
    """If one class wins all its pairwise games it must be predicted."""
    k = 4
    pairs = ovo.class_pairs(k)
    for c in range(k):
        bits = np.zeros((len(pairs),), np.int32)
        for p, (i, j) in enumerate(pairs):
            if i == c:
                bits[p] = 1
            elif j == c:
                bits[p] = 0
            else:
                bits[p] = np.random.RandomState(c * 7 + p).randint(2)
        assert ovo.decide_votes(bits, k) == c


def test_digital_linear_classifier_quantized_path():
    rng = np.random.RandomState(0)
    from repro.core import svm as svm_mod
    x = rng.rand(100, 4)
    y = np.where(x @ np.array([1.0, -2.0, 0.5, 0.0]) + 0.3 > 0, 1.0, -1.0)
    m = svm_mod.train_binary(x, y, "linear", c=10.0, n_epochs=200)
    clf = ovo.DigitalLinearClassifier.deploy(m, weight_bits=8, input_bits=4)
    bits = clf.predict_bits(x)
    agree = np.mean(bits == (svm_mod.decision_function(m, x) >= 0))
    assert agree >= 0.9  # 4-bit ADC costs a little accuracy, not much


def test_digital_rbf_classifier_matches_float():
    rng = np.random.RandomState(1)
    from repro.core import svm as svm_mod
    x = rng.rand(120, 3)
    y = np.where(((x - 0.5) ** 2).sum(1) < 0.1, 1.0, -1.0)
    m = svm_mod.train_binary(x, y, "rbf", gamma=8.0, c=10.0, n_epochs=200)
    clf = ovo.DigitalRBFClassifier.deploy(m)
    agree = np.mean(clf.predict_bits(x)
                    == (svm_mod.decision_function(m, x) >= 0))
    assert agree >= 0.93
