"""End-to-end behaviour tests for the paper's system (Table II flow)."""
import numpy as np
import pytest

from repro.api import MixedKernelSVM
from repro.core import hwcost, mixed_precision, selection
from repro.data import datasets


@pytest.fixture(scope="module")
def balance_est():
    ds = datasets.load("balance")
    est = MixedKernelSVM(n_epochs=100, seed=0).fit(ds.x_train, ds.y_train)
    return ds, est


def test_algorithm1_selects_mixed_kernels(balance_est):
    _, est = balance_est
    # Balance has one genuinely non-linear pair (the L/R torque boundary
    # is multiplicative) — Algorithm 1 must keep at least one RBF and at
    # least one linear classifier (Table II: 1/2).
    assert 1 <= est.n_rbf_ <= 2
    assert est.n_rbf_ + sum(k == "linear" for k in est.kernel_map_) == 3


def test_mixed_beats_or_equals_linear(balance_est):
    ds, est = balance_est
    acc_mixed = est.score(ds.x_test, ds.y_test, target="circuit")
    acc_lin = est.score(ds.x_test, ds.y_test, target="linear")
    assert acc_mixed >= acc_lin - 0.01


def test_circuit_tracks_float_within_1pct(balance_est):
    """Paper: circuit accuracy within ~1% of software."""
    ds, est = balance_est
    f = est.score(ds.x_test, ds.y_test, target="float")
    c = est.score(ds.x_test, ds.y_test, target="circuit")
    assert abs(f - c) <= 0.015


def test_cost_ordering_matches_paper(balance_est):
    """linear << mixed << digital-RBF in area; RBF digital is the power
    hog (Table II orderings)."""
    _, est = balance_est
    cm = hwcost.CostModel()
    lin = hwcost.system_cost(est.bank("linear"), cm)
    mix = hwcost.system_cost(est.bank("circuit"), cm)
    rbf = hwcost.system_cost(est.bank("rbf"), cm)
    assert lin.area_mm2 < mix.area_mm2 < rbf.area_mm2
    assert lin.power_mw < mix.power_mw < rbf.power_mw
    assert rbf.area_mm2 / mix.area_mm2 > 20     # paper: ~108x average
    assert rbf.power_mw / mix.power_mw > 5      # paper: ~17x average


def test_analog_power_dominates_mixed(balance_est):
    """Fig. 5: analog RBF dominates mixed power (~89%)."""
    _, est = balance_est
    cm = hwcost.CostModel()
    mix = hwcost.system_cost(est.bank("circuit"), cm)
    if est.n_rbf_:
        assert mix.analog_power_frac > 0.5


def test_deprecated_explore_shim_still_works():
    """The old grab-bag API keeps working (with a DeprecationWarning) and
    agrees with the estimator path."""
    ds = datasets.load("balance")
    with pytest.warns(DeprecationWarning):
        res = selection.explore(ds.x_train, ds.y_train, ds.n_classes,
                                n_epochs=40, seed=0)
    est = MixedKernelSVM(n_epochs=40, seed=0).fit(ds.x_train, ds.y_train)
    assert res.kernel_map == est.kernel_map_
    np.testing.assert_array_equal(
        res.mixed_circuit.predict(ds.x_test),
        est.bank("circuit").predict(ds.x_test))


def test_calibration_improves_fit():
    """calibrate_digital moves the linear column toward Table II."""
    sys_by_ds = {}
    for name in ("balance", "seeds", "vertebral"):
        ds = datasets.load(name)
        est = MixedKernelSVM(n_epochs=60, seed=0).fit(ds.x_train, ds.y_train)
        sys_by_ds[name] = est.bank("linear")
    cm = hwcost.calibrate_digital(sys_by_ds)
    err = 0.0
    for name, sys in sys_by_ds.items():
        got = hwcost.system_cost(sys, cm)
        ref_a, _ = hwcost.TABLE2_LINEAR[name]
        err += abs(np.log(got.area_mm2 / ref_a))
    assert err / 3 < 0.8  # within ~2.2x on average post-calibration


def test_mixed_precision_separation_on_toy():
    """Algorithm-1-style domain assignment: modules that do not matter go
    cheap; the one that matters stays exact."""
    modules = ["m1", "m2", "m3"]

    def quality(domains):
        # m2 in cheap domain costs 0.1 quality; others are free to quantize
        return 1.0 - (0.1 if domains["m2"] == "cheap" else 0.0)

    a = mixed_precision.assign_domains(modules, quality, tolerance=0.01)
    assert a.domain == {"m1": "cheap", "m2": "exact", "m3": "cheap"}
    assert a.n_cheap == 2


def test_quant_tensor_roundtrip():
    import jax.numpy as jnp
    rng = np.random.RandomState(0)
    w = jnp.asarray(rng.randn(64, 32), jnp.float32)
    q = mixed_precision.QuantTensor.quantize(w)
    back = np.asarray(q.dequantize(jnp.float32))
    assert np.max(np.abs(back - np.asarray(w))) < np.abs(np.asarray(w)).max() / 100
    assert q.nbytes < w.size * 4 / 3.5
