"""End-to-end behaviour tests for the paper's system (Table II flow)."""
import jax
import numpy as np
import pytest

from repro.core import hwcost, mixed_precision, selection
from repro.data import datasets


@pytest.fixture(scope="module")
def balance_result():
    ds = datasets.load("balance")
    res = selection.explore(ds.x_train, ds.y_train, ds.n_classes,
                            n_epochs=100, seed=0)
    return ds, res


def test_algorithm1_selects_mixed_kernels(balance_result):
    _, res = balance_result
    # Balance has one genuinely non-linear pair (the L/R torque boundary
    # is multiplicative) — Algorithm 1 must keep at least one RBF and at
    # least one linear classifier (Table II: 1/2).
    assert 1 <= res.n_rbf <= 2
    assert res.n_rbf + sum(k == "linear" for k in res.kernel_map) == 3


def test_mixed_beats_or_equals_linear(balance_result):
    ds, res = balance_result
    acc_mixed = res.mixed_circuit.accuracy(ds.x_test, ds.y_test)
    acc_lin = res.linear_circuit.accuracy(ds.x_test, ds.y_test)
    assert acc_mixed >= acc_lin - 0.01


def test_circuit_tracks_float_within_1pct(balance_result):
    """Paper: circuit accuracy within ~1% of software."""
    ds, res = balance_result
    f = res.mixed_float.accuracy(ds.x_test, ds.y_test)
    c = res.mixed_circuit.accuracy(ds.x_test, ds.y_test)
    assert abs(f - c) <= 0.015


def test_cost_ordering_matches_paper(balance_result):
    """linear << mixed << digital-RBF in area; RBF digital is the power
    hog (Table II orderings)."""
    _, res = balance_result
    cm = hwcost.CostModel()
    lin = hwcost.system_cost(res.linear_circuit, cm)
    mix = hwcost.system_cost(res.mixed_circuit, cm)
    rbf = hwcost.system_cost(res.rbf_circuit, cm)
    assert lin.area_mm2 < mix.area_mm2 < rbf.area_mm2
    assert lin.power_mw < mix.power_mw < rbf.power_mw
    assert rbf.area_mm2 / mix.area_mm2 > 20     # paper: ~108x average
    assert rbf.power_mw / mix.power_mw > 5      # paper: ~17x average


def test_analog_power_dominates_mixed(balance_result):
    """Fig. 5: analog RBF dominates mixed power (~89%)."""
    _, res = balance_result
    cm = hwcost.CostModel()
    mix = hwcost.system_cost(res.mixed_circuit, cm)
    if res.n_rbf:
        assert mix.analog_power_frac > 0.5


def test_calibration_improves_fit():
    """calibrate_digital moves the linear column toward Table II."""
    sys_by_ds = {}
    for name in ("balance", "seeds", "vertebral"):
        ds = datasets.load(name)
        res = selection.explore(ds.x_train, ds.y_train, ds.n_classes,
                                n_epochs=60, seed=0)
        sys_by_ds[name] = res.linear_circuit
    cm = hwcost.calibrate_digital(sys_by_ds)
    err = 0.0
    for name, sys in sys_by_ds.items():
        got = hwcost.system_cost(sys, cm)
        ref_a, _ = hwcost.TABLE2_LINEAR[name]
        err += abs(np.log(got.area_mm2 / ref_a))
    assert err / 3 < 0.8  # within ~2.2x on average post-calibration


def test_mixed_precision_separation_on_toy():
    """Algorithm-1-style domain assignment: modules that do not matter go
    cheap; the one that matters stays exact."""
    modules = ["m1", "m2", "m3"]

    def quality(domains):
        # m2 in cheap domain costs 0.1 quality; others are free to quantize
        return 1.0 - (0.1 if domains["m2"] == "cheap" else 0.0)

    a = mixed_precision.assign_domains(modules, quality, tolerance=0.01)
    assert a.domain == {"m1": "cheap", "m2": "exact", "m3": "cheap"}
    assert a.n_cheap == 2


def test_quant_tensor_roundtrip():
    import jax.numpy as jnp
    rng = np.random.RandomState(0)
    w = jnp.asarray(rng.randn(64, 32), jnp.float32)
    q = mixed_precision.QuantTensor.quantize(w)
    back = np.asarray(q.dequantize(jnp.float32))
    assert np.max(np.abs(back - np.asarray(w))) < np.abs(np.asarray(w)).max() / 100
    assert q.nbytes < w.size * 4 / 3.5
