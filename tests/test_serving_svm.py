"""Tests for the SVM serving stack: FleetMachine co-batching + SVMEngine.

Covers the PR's correctness contracts:

  * FleetMachine outputs are BIT-IDENTICAL to each member CompiledMachine
    (scores compared as raw f32 bit patterns) across ragged model mixes —
    different K, d, bank counts, analog members;
  * per-row routing matches per-member prediction for arbitrary tenant
    mixes;
  * fleet save/load round-trips one npz+json for the whole fleet;
  * the engine's bucket policy and batch assembly at the edges (1 row,
    max_batch rows, max_batch + 1 rows across requests);
  * one compiled program per padding bucket — no per-request recompiles;
  * ServingStats accounting (requests vs queries, occupancy, latency).

All machines are hand-built at tiny shapes (no training), mirroring the
analysis registry's ``_tiny_models`` so the suite stays fast.
"""
import time

import numpy as np
import pytest

from repro.api import FleetMachine, compile_fleet, compile_machine
from repro.core.svm import SVMModel
from repro.serving import BucketPolicy, ServingStats, SVMEngine


def _pair_model(gen, d, m, kind):
    sx = gen.normal(size=(m, d)).astype(np.float32)
    sy = np.where(np.arange(m) % 2 == 0, 1.0, -1.0).astype(np.float32)
    alpha = (np.abs(gen.normal(size=m)) + 0.1).astype(np.float32)
    kw = {}
    if kind == "linear":
        kw["w"] = ((alpha * sy) @ sx).astype(np.float32)
    return SVMModel(kind=kind, support_x=sx, support_y=sy, alpha=alpha,
                    bias=float(gen.normal() * 0.1), gamma=0.7, c=1.0, **kw)


def tiny_machine(seed, d=3, m=6, n_classes=3, analog_pairs=()):
    """Hand-built machine: alternating linear/rbf pairs, optional analog."""
    from repro.core import trainer
    from repro.core.analog import AnalogBinaryClassifier

    gen = np.random.default_rng(seed)
    n_pairs = n_classes * (n_classes - 1) // 2
    clfs = []
    for p in range(n_pairs):
        kind = "linear" if p % 2 == 0 else "rbf"
        model = _pair_model(gen, d, m, kind)
        if p in analog_pairs:
            model = _pair_model(gen, d, m, "rbf")
            model = AnalogBinaryClassifier.deploy(model, trainer.default_hw(0))
        clfs.append(model)
    return compile_machine(clfs, n_classes=n_classes)


@pytest.fixture(scope="module")
def ragged_fleet():
    """Three members with different K, d, m and one analog member."""
    members = {
        "tiny": tiny_machine(0, d=3, m=6, n_classes=3),
        "wide": tiny_machine(1, d=5, m=8, n_classes=4),
        "analog": tiny_machine(2, d=4, m=6, n_classes=3, analog_pairs=(1,)),
    }
    return compile_fleet(members), members


def _queries(gen, n, d):
    return gen.normal(size=(n, d)).astype(np.float32)


# -- FleetMachine ------------------------------------------------------------


def test_fleet_layout(ragged_fleet):
    fleet, members = ragged_fleet
    assert fleet.model_ids == ["tiny", "wide", "analog"]
    assert fleet.n_features == 5            # d_max over members
    assert fleet.n_pairs_total == 3 + 6 + 3
    assert fleet.pair_slice("tiny") == (0, 3)
    assert fleet.pair_slice("wide") == (3, 9)
    assert fleet.pair_slice("analog") == (9, 12)
    assert fleet.member("wide") is members["wide"]
    assert "FleetMachine(3 models" in fleet.describe()


def test_fleet_bit_identical_to_members(ragged_fleet):
    """Scores, bits AND labels from the co-batched forward match each
    member machine bit-for-bit — the contract that lets one fleet program
    replace per-model dispatches without any numeric drift."""
    fleet, members = ragged_fleet
    gen = np.random.default_rng(7)
    for mid, machine in members.items():
        x = _queries(gen, 17, machine.n_features)
        want = machine.decision_scores(x)
        got = fleet.decision_scores(x, mid)
        # Raw f32 bit patterns: stricter than allclose, catches reordered
        # reductions that happen to round the same way most of the time.
        np.testing.assert_array_equal(got.view(np.int32),
                                      want.view(np.int32))
        np.testing.assert_array_equal(fleet.predict_bits(x, mid),
                                      machine.predict_bits(x))
        np.testing.assert_array_equal(fleet.predict(x, mid),
                                      machine.predict(x))


def test_fleet_per_row_routing(ragged_fleet):
    """A mixed batch routed per row gives each row its own member's label."""
    fleet, members = ragged_fleet
    gen = np.random.default_rng(11)
    ids = [fleet.model_ids[i] for i in gen.integers(0, 3, size=29)]
    x = _queries(gen, 29, fleet.n_features)
    got = fleet.predict(x, ids)
    for r, mid in enumerate(ids):
        m = members[mid]
        want = m.predict(x[r:r + 1, : m.n_features])[0]
        assert got[r] == want, f"row {r} ({mid}): {got[r]} != {want}"


def test_fleet_single_member_wraps_machine():
    machine = tiny_machine(3)
    fleet = compile_fleet([machine])           # bare sequence, default ids
    assert fleet.model_ids == ["model0"]
    gen = np.random.default_rng(0)
    x = _queries(gen, 9, machine.n_features)
    np.testing.assert_array_equal(fleet.predict(x, 0), machine.predict(x))


def test_fleet_input_forms_and_errors():
    a, b = tiny_machine(4), tiny_machine(5)
    by_pairs = compile_fleet([("a", a), ("b", b)])
    assert by_pairs.model_ids == ["a", "b"]
    with pytest.raises(ValueError, match="duplicate"):
        FleetMachine(["a", "a"], [a, b])
    with pytest.raises(TypeError, match="CompiledMachine"):
        compile_fleet({"a": a, "bad": object()})
    fleet = compile_fleet({"a": a, "b": b})
    with pytest.raises(KeyError, match="unknown model id"):
        fleet.model_index("missing")
    with pytest.raises(IndexError):
        fleet.model_index(2)
    with pytest.raises(ValueError, match="expected"):
        fleet.predict(np.zeros((4, fleet.n_features + 1), np.float32), "a")


def test_fleet_save_load_round_trip(tmp_path, ragged_fleet):
    fleet, members = ragged_fleet
    path = str(tmp_path / "fleet")
    fleet.save(path)
    back = FleetMachine.load(path)
    assert back.model_ids == fleet.model_ids
    assert back._pair_slices == fleet._pair_slices
    gen = np.random.default_rng(13)
    for mid, machine in members.items():
        x = _queries(gen, 11, machine.n_features)
        np.testing.assert_array_equal(
            back.decision_scores(x, mid).view(np.int32),
            fleet.decision_scores(x, mid).view(np.int32))
        np.testing.assert_array_equal(back.predict(x, mid),
                                      machine.predict(x))


# -- BucketPolicy ------------------------------------------------------------


def test_bucket_policy_edges():
    p = BucketPolicy(max_batch=64, min_bucket=8)
    assert p.buckets == (8, 16, 32, 64)
    assert p.bucket_for(1) == 8
    assert p.bucket_for(8) == 8
    assert p.bucket_for(9) == 16
    assert p.bucket_for(64) == 64
    with pytest.raises(ValueError):
        p.bucket_for(0)
    with pytest.raises(ValueError):
        p.bucket_for(65)
    with pytest.raises(ValueError, match="powers of two"):
        BucketPolicy(max_batch=48)
    with pytest.raises(ValueError, match="min_bucket"):
        BucketPolicy(max_batch=8, min_bucket=16)


# -- SVMEngine ---------------------------------------------------------------


@pytest.fixture(scope="module")
def engine_fleet():
    return compile_fleet({
        "a": tiny_machine(20, d=3, m=6, n_classes=3),
        "b": tiny_machine(21, d=4, m=8, n_classes=3),
    })


def test_engine_routing_matches_members(engine_fleet):
    fleet = engine_fleet
    gen = np.random.default_rng(0)
    with SVMEngine(fleet, max_batch=32, max_wait_ms=1.0) as eng:
        eng.warmup()
        futs = []
        for i in range(100):
            mid = fleet.model_ids[int(gen.integers(0, 2))]
            m = fleet.member(mid)
            x = _queries(gen, 1, m.n_features)[0]     # 1-D -> scalar label
            futs.append((mid, x, eng.submit(x, mid)))
        for mid, x, f in futs:
            want = int(fleet.member(mid).predict(x[None])[0])
            assert f.result(timeout=30.0) == want


def test_engine_bucket_edge_batches(engine_fleet):
    """1 row, exactly max_batch rows, and max_batch + 1 rows (carry into a
    second batch) all produce correct labels."""
    fleet = engine_fleet
    m = fleet.member("a")
    gen = np.random.default_rng(1)
    with SVMEngine(fleet, max_batch=32, max_wait_ms=1.0) as eng:
        eng.warmup()
        for n in (1, 32, 33):
            x = _queries(gen, n, m.n_features)
            want = m.predict(x)
            if n <= 32:                     # one multi-row request
                got = eng.predict(x, "a")
                np.testing.assert_array_equal(np.atleast_1d(got), want)
            # same rows as single-row requests (n=33 spans two batches)
            futs = [eng.submit(x[i], "a") for i in range(n)]
            got = np.asarray([f.result(timeout=30.0) for f in futs])
            np.testing.assert_array_equal(got, want)
        with pytest.raises(ValueError, match="rows"):
            eng.submit(_queries(gen, 33, m.n_features), "a")


def test_engine_one_program_per_bucket(engine_fleet):
    """The padded-bucket contract: after warmup + mixed traffic the jitted
    serving program has exactly one compiled entry per bucket shape."""
    fleet = compile_fleet({"a": tiny_machine(30), "b": tiny_machine(31)})
    gen = np.random.default_rng(2)
    with SVMEngine(fleet, max_batch=32, min_bucket=8,
                   max_wait_ms=0.5) as eng:
        eng.warmup()
        assert eng.n_buckets == 3           # 8, 16, 32
        assert fleet._labels_jit._cache_size() == eng.n_buckets
        futs = [eng.submit(_queries(gen, int(k), 3), "a")
                for k in gen.integers(1, 33, size=40)]
        for f in futs:
            f.result(timeout=30.0)
    assert fleet._labels_jit._cache_size() == eng.n_buckets


def test_engine_stats_accounting(engine_fleet):
    fleet = engine_fleet
    gen = np.random.default_rng(3)
    stats = ServingStats()
    assert stats.summary() == {"n_requests": 0, "n_queries": 0,
                               "n_batches": 0}
    with SVMEngine(fleet, max_batch=16, max_wait_ms=1.0,
                   stats=stats) as eng:
        eng.warmup()
        futs = [eng.submit(_queries(gen, 3, 3), "a") for _ in range(20)]
        for f in futs:
            f.result(timeout=30.0)
    s = stats.summary()
    assert s["n_requests"] == 20
    assert s["n_queries"] == 60             # rows, not requests
    assert 1 <= s["n_batches"] <= 20
    assert 0.0 < s["batch_occupancy"] <= 1.0
    assert s["latency_ms"]["p50"] <= s["latency_ms"]["p99"] \
        <= s["latency_ms"]["max"]
    assert s["queue_wait_ms_p50"] >= 0.0
    stats.reset()
    assert stats.n_requests == 0


def test_engine_lifecycle_and_bare_machine():
    machine = tiny_machine(40)
    eng = SVMEngine(machine, max_batch=8)   # bare machine -> 1-member fleet
    assert eng.fleet.model_ids == ["default"]
    with pytest.raises(RuntimeError, match="not started"):
        eng.submit(np.zeros(3, np.float32))
    with eng:
        with pytest.raises(RuntimeError, match="already started"):
            eng.start()
        lab = eng.predict(np.zeros(3, np.float32))
        assert lab == int(machine.predict(np.zeros((1, 3), np.float32))[0])
    with pytest.raises(RuntimeError):
        eng.submit(np.zeros(3, np.float32))
    with pytest.raises(TypeError, match="cannot serve"):
        SVMEngine(object())


# -- ServingStats memory bound -----------------------------------------------


class _FakeReq:
    def __init__(self, t0, wait, service, n_rows=1, deadline=None):
        import math
        self.t_enqueue = t0
        self.t_dispatch = t0 + wait
        self.t_complete = t0 + wait + service
        self.n_rows = n_rows
        self.deadline = math.inf if deadline is None else deadline


def test_stats_memory_stays_flat_with_tolerable_percentiles():
    """Streaming totals are exact and the latency sample is a fixed-size
    reservoir: feeding 200x the reservoir capacity must not grow memory,
    and reservoir percentiles must track the exact ones."""
    stats = ServingStats(reservoir=512, seed=0)
    gen = np.random.default_rng(0)
    exact = []
    n_total = 512 * 200
    batch = 64
    footprint = stats._res.nbytes
    t = 0.0
    for start in range(0, n_total, batch):
        reqs = []
        for _ in range(batch):
            wait = float(gen.exponential(0.001))
            service = float(gen.exponential(0.002))
            reqs.append(_FakeReq(t, wait, service))
            exact.append((wait + service) * 1e3)
            t += 1e-4
        stats.observe_batch(batch, 64, reqs)
    # memory: the reservoir never grew, and no per-request list exists
    assert stats._res.nbytes == footprint
    assert stats._res.shape == (512, 2)
    assert not any(isinstance(v, list) and len(v) > 1024
                   for v in vars(stats).values())
    s = stats.summary()
    # exact streaming totals
    assert s["n_requests"] == n_total
    assert s["n_queries"] == n_total
    # summary() rounds to 3 decimals; the totals behind it are exact
    assert s["latency_ms"]["mean"] == pytest.approx(
        float(np.mean(exact)), abs=5e-4)
    assert s["latency_ms"]["max"] == pytest.approx(
        float(np.max(exact)), abs=5e-4)
    # reservoir percentiles within sampling tolerance of the exact ones
    for q in (50, 95, 99):
        want = float(np.percentile(exact, q))
        got = s["latency_ms"][f"p{q}"]
        assert got == pytest.approx(want, rel=0.25), (q, got, want)
    assert s["latency_sample_n"] == 512


# -- deadline / priority batch former ----------------------------------------


def _mk_req(eng, seq, *, n_rows=1, priority=0, deadline=None, t0=None):
    """Hand-built queued request for direct batch-former tests."""
    import math
    import time
    from concurrent.futures import Future

    from repro.serving.svm_engine import _Request

    now = time.perf_counter() if t0 is None else t0
    d = eng.fleet.n_features
    return _Request(x=np.zeros((n_rows, d), np.float32), model_idx=0,
                    n_rows=n_rows, scalar=n_rows == 1, future=Future(),
                    t_enqueue=now, priority=priority, seq=seq,
                    deadline=math.inf if deadline is None else deadline)


def test_batch_former_priority_and_edf_backfill(engine_fleet):
    """Selection order: expiring requests EDF across classes (backfill),
    then strictly by priority class; low-priority non-expiring work never
    precedes high-priority work (no inversion)."""
    import time

    eng = SVMEngine(engine_fleet, max_batch=32, max_wait_ms=1.0)
    now = time.perf_counter()
    horizon = eng._horizon(now)
    a = _mk_req(eng, 0, priority=2)                       # high, no deadline
    b = _mk_req(eng, 1, priority=0)                       # low, no deadline
    c = _mk_req(eng, 2, priority=0, deadline=horizon)     # low, expiring
    d = _mk_req(eng, 3, priority=2, deadline=horizon - 1e-4)  # high, expiring
    with eng._cond:
        for r in (a, b, c, d):
            eng._enqueue(r)
        order = [eng._select_locked(now) for _ in range(4)]
    # d, c expiring -> EDF (d earlier); then a (high class); b last
    assert [r.seq for r in order] == [3, 2, 0, 1]

    # equal expiring deadlines tie-break to the higher priority class
    e = _mk_req(eng, 4, priority=0, deadline=horizon)
    f = _mk_req(eng, 5, priority=1, deadline=horizon)
    with eng._cond:
        eng._enqueue(e)
        eng._enqueue(f)
        assert eng._select_locked(now).seq == 5
        assert eng._select_locked(now).seq == 4


def test_batch_former_sheds_expired_when_enabled(engine_fleet):
    import time

    from repro.serving import ShedError

    eng = SVMEngine(engine_fleet, max_batch=32, shed_expired=True)
    now = time.perf_counter()
    dead = _mk_req(eng, 0, deadline=now - 1.0)
    live = _mk_req(eng, 1)
    with eng._cond:
        eng._enqueue(dead)
        eng._enqueue(live)
        assert eng._select_locked(now).seq == 1
        assert eng._select_locked(now) is None
    with pytest.raises(ShedError, match="expired"):
        dead.future.result(timeout=0)
    assert eng.stats.summary()["shed"]["reasons"] == {"expired": 1}

    # without shed_expired the expired request is still served
    eng2 = SVMEngine(engine_fleet, max_batch=32)
    stale = _mk_req(eng2, 0, deadline=now - 1.0)
    with eng2._cond:
        eng2._enqueue(stale)
        assert eng2._select_locked(now).seq == 0


def test_admission_sheds_expired_then_lowest_priority(engine_fleet):
    """Bounded-queue admission: room is made by shedding already-expired
    work first, then strictly lower-priority work (latest deadline
    first); an incoming request with no lower class is itself shed."""
    import time

    from repro.serving import ShedError

    eng = SVMEngine(engine_fleet, max_batch=32, queue_bound=4)
    now = time.perf_counter()
    expired = _mk_req(eng, 0, priority=5, deadline=now - 1.0)
    lo_late = _mk_req(eng, 1, priority=0, deadline=now + 9.0)
    lo_soon = _mk_req(eng, 2, priority=0, deadline=now + 1.0)
    with eng._cond:
        for r in (expired, lo_late, lo_soon):
            eng._enqueue(r)
        # over bound by 2: the expired one goes first ("expired"), then
        # the LATEST-deadline low-priority one ("overflow")
        incoming = _mk_req(eng, 3, priority=1, n_rows=3)
        eng._admit_over_bound(incoming, now)
        assert eng._pending_rows == 4      # lo_soon (1) + incoming (3)
    with pytest.raises(ShedError, match="expired"):
        expired.future.result(timeout=0)
    with pytest.raises(ShedError, match="overflow"):
        lo_late.future.result(timeout=0)
    assert not lo_soon.future.done()
    assert not incoming.future.done()

    # no strictly-lower class left -> the incoming request is shed
    with eng._cond:
        loser = _mk_req(eng, 4, priority=0, n_rows=3)
        eng._admit_over_bound(loser, now)
    with pytest.raises(ShedError, match="overflow"):
        loser.future.result(timeout=0)
    assert not lo_soon.future.done()
    assert eng.stats.summary()["shed"]["reasons"] == \
        {"expired": 1, "overflow": 2}


def test_overload_burst_sheds_only_lowest_priority(engine_fleet):
    """End-to-end overload: a burst larger than the queue bound against a
    slowed-down device sheds SOME priority-0 work and NO priority-1 work;
    everything not shed completes correctly."""
    import time

    from repro.serving import ShedError

    fleet = engine_fleet
    eng = SVMEngine(fleet, max_batch=8, max_wait_ms=0.5, queue_bound=16,
                    shed_expired=True)
    slow, orig = 0.02, eng._forward

    def slow_forward(xbuf, ibuf):
        time.sleep(slow)
        return orig(xbuf, ibuf)

    eng._forward = slow_forward
    gen = np.random.default_rng(5)
    with eng:
        eng.warmup()
        x = _queries(gen, 1, 3)[0]
        lo = [eng.submit(x, "a", priority=0) for _ in range(120)]
        # high-priority burst below the queue bound: admission makes room
        # for every one of these by evicting queued priority-0 work
        hi = [eng.submit(x, "a", priority=1) for _ in range(12)]
        want = int(fleet.member("a").predict(x[None])[0])
        shed_lo = 0
        for f in lo:
            try:
                assert f.result(timeout=60.0) == want
            except ShedError as e:
                assert e.reason in ("overflow", "expired")
                shed_lo += 1
        for f in hi:          # high priority is NEVER shed here
            assert f.result(timeout=60.0) == want
    assert shed_lo > 0
    assert eng.stats.n_shed == shed_lo


def test_backpressure_watermarks(engine_fleet):
    import time

    eng = SVMEngine(engine_fleet, max_batch=8, max_wait_ms=0.5,
                    queue_bound=64, high_watermark=32, low_watermark=8)
    slow, orig = 0.01, eng._forward

    def slow_forward(xbuf, ibuf):
        time.sleep(slow)
        return orig(xbuf, ibuf)

    eng._forward = slow_forward
    gen = np.random.default_rng(6)
    with eng:
        eng.warmup()
        assert eng.backpressure is False
        futs = [eng.submit(_queries(gen, 8, 3), "a") for _ in range(6)]
        assert eng.backpressure is True        # 48 pending rows >= 32
        for f in futs:
            f.result(timeout=60.0)
        deadline = time.monotonic() + 10.0
        while eng.backpressure and time.monotonic() < deadline:
            time.sleep(0.005)
        assert eng.backpressure is False       # drained below low watermark


def test_carry_leads_next_batch_with_original_enqueue(engine_fleet):
    """A request that overflows the forming batch is carried and MUST be
    row 0 of the next dispatch, keeping its original enqueue time (its
    max-wait anchor) — large requests cannot starve behind small ones."""
    dispatched = []
    eng = SVMEngine(engine_fleet, max_batch=8, max_wait_ms=30.0)
    orig = eng._dispatch

    def record(batch, rows):
        dispatched.append(list(batch))
        orig(batch, rows)

    eng._dispatch = record
    gen = np.random.default_rng(7)
    with eng:
        eng.warmup()
        dispatched.clear()
        f1 = eng.submit(_queries(gen, 5, 3), "a")
        time.sleep(0.005)                      # batcher anchors on f1
        f2 = eng.submit(_queries(gen, 6, 3), "a")   # 5 + 6 > 8 -> carry
        r1 = f1.result(timeout=30.0)
        r2 = f2.result(timeout=30.0)
        assert len(r1) == 5 and len(r2) == 6
    assert len(dispatched) >= 2
    assert [r.n_rows for r in dispatched[0]] == [5]
    carry_batch = dispatched[1]
    assert carry_batch[0].n_rows == 6          # carried -> batch[0]
    # original enqueue preserved: it waited across BOTH batches
    assert carry_batch[0].t_enqueue <= dispatched[0][0].t_dispatch


def test_pipeline_depth_k(engine_fleet):
    """pipeline_depth=k keeps k batches in flight over k+1 staging
    buffers and still resolves every request correctly."""
    fleet = engine_fleet
    m = fleet.member("a")
    gen = np.random.default_rng(8)
    with pytest.raises(ValueError, match="pipeline_depth"):
        SVMEngine(fleet, pipeline_depth=0)
    with SVMEngine(fleet, max_batch=16, max_wait_ms=0.2,
                   pipeline_depth=3) as eng:
        assert all(len(bufs) == 4 for bufs in eng._staging.values())
        eng.warmup()
        xs = [_queries(gen, 3, 3) for _ in range(50)]
        futs = [eng.submit(x, "a") for x in xs]
        for x, f in zip(xs, futs):
            np.testing.assert_array_equal(f.result(timeout=30.0),
                                          m.predict(x))


def test_engine_mesh_on_one_device(engine_fleet):
    """mesh= dispatch on a 1-device serving mesh: labels identical to the
    plain engine, buckets become per-device sizes."""
    from repro.launch.mesh import make_serving_mesh

    fleet = engine_fleet
    mesh = make_serving_mesh(1)
    gen = np.random.default_rng(9)
    with SVMEngine(fleet, max_batch=16, max_wait_ms=0.5, mesh=mesh) as eng:
        assert eng.n_devices == 1 and eng.max_rows == 16
        eng.warmup()
        for mid in fleet.model_ids:
            m = fleet.member(mid)
            x = _queries(gen, 11, m.n_features)
            np.testing.assert_array_equal(eng.predict(x, mid), m.predict(x))


# -- mesh-sharded forward (8 virtual devices, subprocess) --------------------


def test_sharded_fleet_forward_bit_identity_subprocess():
    """8-fake-device shard_map serving leg: every per-device slice of the
    sharded labels output is bit-identical to the single-device forward
    on the same rows, on ragged mixed-model batches; the engine serves
    through the mesh end-to-end (subprocess so XLA_FLAGS doesn't leak)."""
    import os
    import subprocess
    import sys
    import textwrap

    body = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np
        from tests.test_serving_svm import tiny_machine, _queries
        from repro.api import compile_fleet
        from repro.launch.mesh import make_serving_mesh
        from repro.serving import SVMEngine

        fleet = compile_fleet({
            "tiny": tiny_machine(0, d=3, m=6, n_classes=3),
            "wide": tiny_machine(1, d=5, m=8, n_classes=4),
            "analog": tiny_machine(2, d=4, m=6, n_classes=3,
                                   analog_pairs=(1,)),
        })
        mesh = make_serving_mesh()
        fwd = fleet.shard(mesh)
        assert fwd.n_devices == 8
        gen = np.random.default_rng(0)

        # ragged mixed-model batch, whole per-device slices (8 x 16 rows)
        n = fwd.global_rows(16)
        x = fleet._pad_features(_queries(gen, n, fleet.n_features))
        idx = fleet._resolve_idx(
            [fleet.model_ids[i] for i in gen.integers(0, 3, size=n)], n)
        sharded = np.asarray(fwd(x, idx.copy()))
        local = np.asarray(fleet._labels_jit(x, idx.copy()))
        # global AND per-device-slice bit identity (i32 labels)
        np.testing.assert_array_equal(sharded, local)
        per = n // 8
        for dev in range(8):
            s = slice(dev * per, (dev + 1) * per)
            np.testing.assert_array_equal(
                sharded[s],
                np.asarray(fleet._labels_jit(x[s], idx[s].copy())))

        # ragged row count: predict pads to whole slices and trims
        x27 = _queries(gen, 27, 4)
        np.testing.assert_array_equal(fwd.predict(x27, "analog"),
                                      fleet.predict(x27, "analog"))

        # engine end-to-end through the mesh, mixed models + deadlines
        with SVMEngine(fleet, max_batch=16, max_wait_ms=1.0,
                       mesh=mesh) as eng:
            assert eng.max_rows == 16 * 8
            eng.warmup()
            futs = []
            for i in range(40):
                mid = fleet.model_ids[i % 3]
                m = fleet.member(mid)
                q = _queries(gen, 3, m.n_features)
                futs.append((mid, q, eng.submit(q, mid, deadline_ms=5e3)))
            for mid, q, f in futs:
                np.testing.assert_array_equal(
                    f.result(timeout=60.0), fleet.member(mid).predict(q))
        print("OK")
    """)
    env = dict(os.environ,
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src"),
                    os.path.join(os.path.dirname(__file__), "..")]))
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", body], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    assert "OK" in res.stdout


def test_serving_mesh_requires_batch_axis(engine_fleet):
    from repro.launch import mesh as mesh_mod

    m = mesh_mod.make_test_mesh(shape=(1,), axes=("data",))
    with pytest.raises(ValueError, match="batch"):
        engine_fleet.shard(m)
