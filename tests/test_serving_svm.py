"""Tests for the SVM serving stack: FleetMachine co-batching + SVMEngine.

Covers the PR's correctness contracts:

  * FleetMachine outputs are BIT-IDENTICAL to each member CompiledMachine
    (scores compared as raw f32 bit patterns) across ragged model mixes —
    different K, d, bank counts, analog members;
  * per-row routing matches per-member prediction for arbitrary tenant
    mixes;
  * fleet save/load round-trips one npz+json for the whole fleet;
  * the engine's bucket policy and batch assembly at the edges (1 row,
    max_batch rows, max_batch + 1 rows across requests);
  * one compiled program per padding bucket — no per-request recompiles;
  * ServingStats accounting (requests vs queries, occupancy, latency).

All machines are hand-built at tiny shapes (no training), mirroring the
analysis registry's ``_tiny_models`` so the suite stays fast.
"""
import numpy as np
import pytest

from repro.api import FleetMachine, compile_fleet, compile_machine
from repro.core.svm import SVMModel
from repro.serving import BucketPolicy, ServingStats, SVMEngine


def _pair_model(gen, d, m, kind):
    sx = gen.normal(size=(m, d)).astype(np.float32)
    sy = np.where(np.arange(m) % 2 == 0, 1.0, -1.0).astype(np.float32)
    alpha = (np.abs(gen.normal(size=m)) + 0.1).astype(np.float32)
    kw = {}
    if kind == "linear":
        kw["w"] = ((alpha * sy) @ sx).astype(np.float32)
    return SVMModel(kind=kind, support_x=sx, support_y=sy, alpha=alpha,
                    bias=float(gen.normal() * 0.1), gamma=0.7, c=1.0, **kw)


def tiny_machine(seed, d=3, m=6, n_classes=3, analog_pairs=()):
    """Hand-built machine: alternating linear/rbf pairs, optional analog."""
    from repro.core import trainer
    from repro.core.analog import AnalogBinaryClassifier

    gen = np.random.default_rng(seed)
    n_pairs = n_classes * (n_classes - 1) // 2
    clfs = []
    for p in range(n_pairs):
        kind = "linear" if p % 2 == 0 else "rbf"
        model = _pair_model(gen, d, m, kind)
        if p in analog_pairs:
            model = _pair_model(gen, d, m, "rbf")
            model = AnalogBinaryClassifier.deploy(model, trainer.default_hw(0))
        clfs.append(model)
    return compile_machine(clfs, n_classes=n_classes)


@pytest.fixture(scope="module")
def ragged_fleet():
    """Three members with different K, d, m and one analog member."""
    members = {
        "tiny": tiny_machine(0, d=3, m=6, n_classes=3),
        "wide": tiny_machine(1, d=5, m=8, n_classes=4),
        "analog": tiny_machine(2, d=4, m=6, n_classes=3, analog_pairs=(1,)),
    }
    return compile_fleet(members), members


def _queries(gen, n, d):
    return gen.normal(size=(n, d)).astype(np.float32)


# -- FleetMachine ------------------------------------------------------------


def test_fleet_layout(ragged_fleet):
    fleet, members = ragged_fleet
    assert fleet.model_ids == ["tiny", "wide", "analog"]
    assert fleet.n_features == 5            # d_max over members
    assert fleet.n_pairs_total == 3 + 6 + 3
    assert fleet.pair_slice("tiny") == (0, 3)
    assert fleet.pair_slice("wide") == (3, 9)
    assert fleet.pair_slice("analog") == (9, 12)
    assert fleet.member("wide") is members["wide"]
    assert "FleetMachine(3 models" in fleet.describe()


def test_fleet_bit_identical_to_members(ragged_fleet):
    """Scores, bits AND labels from the co-batched forward match each
    member machine bit-for-bit — the contract that lets one fleet program
    replace per-model dispatches without any numeric drift."""
    fleet, members = ragged_fleet
    gen = np.random.default_rng(7)
    for mid, machine in members.items():
        x = _queries(gen, 17, machine.n_features)
        want = machine.decision_scores(x)
        got = fleet.decision_scores(x, mid)
        # Raw f32 bit patterns: stricter than allclose, catches reordered
        # reductions that happen to round the same way most of the time.
        np.testing.assert_array_equal(got.view(np.int32),
                                      want.view(np.int32))
        np.testing.assert_array_equal(fleet.predict_bits(x, mid),
                                      machine.predict_bits(x))
        np.testing.assert_array_equal(fleet.predict(x, mid),
                                      machine.predict(x))


def test_fleet_per_row_routing(ragged_fleet):
    """A mixed batch routed per row gives each row its own member's label."""
    fleet, members = ragged_fleet
    gen = np.random.default_rng(11)
    ids = [fleet.model_ids[i] for i in gen.integers(0, 3, size=29)]
    x = _queries(gen, 29, fleet.n_features)
    got = fleet.predict(x, ids)
    for r, mid in enumerate(ids):
        m = members[mid]
        want = m.predict(x[r:r + 1, : m.n_features])[0]
        assert got[r] == want, f"row {r} ({mid}): {got[r]} != {want}"


def test_fleet_single_member_wraps_machine():
    machine = tiny_machine(3)
    fleet = compile_fleet([machine])           # bare sequence, default ids
    assert fleet.model_ids == ["model0"]
    gen = np.random.default_rng(0)
    x = _queries(gen, 9, machine.n_features)
    np.testing.assert_array_equal(fleet.predict(x, 0), machine.predict(x))


def test_fleet_input_forms_and_errors():
    a, b = tiny_machine(4), tiny_machine(5)
    by_pairs = compile_fleet([("a", a), ("b", b)])
    assert by_pairs.model_ids == ["a", "b"]
    with pytest.raises(ValueError, match="duplicate"):
        FleetMachine(["a", "a"], [a, b])
    with pytest.raises(TypeError, match="CompiledMachine"):
        compile_fleet({"a": a, "bad": object()})
    fleet = compile_fleet({"a": a, "b": b})
    with pytest.raises(KeyError, match="unknown model id"):
        fleet.model_index("missing")
    with pytest.raises(IndexError):
        fleet.model_index(2)
    with pytest.raises(ValueError, match="expected"):
        fleet.predict(np.zeros((4, fleet.n_features + 1), np.float32), "a")


def test_fleet_save_load_round_trip(tmp_path, ragged_fleet):
    fleet, members = ragged_fleet
    path = str(tmp_path / "fleet")
    fleet.save(path)
    back = FleetMachine.load(path)
    assert back.model_ids == fleet.model_ids
    assert back._pair_slices == fleet._pair_slices
    gen = np.random.default_rng(13)
    for mid, machine in members.items():
        x = _queries(gen, 11, machine.n_features)
        np.testing.assert_array_equal(
            back.decision_scores(x, mid).view(np.int32),
            fleet.decision_scores(x, mid).view(np.int32))
        np.testing.assert_array_equal(back.predict(x, mid),
                                      machine.predict(x))


# -- BucketPolicy ------------------------------------------------------------


def test_bucket_policy_edges():
    p = BucketPolicy(max_batch=64, min_bucket=8)
    assert p.buckets == (8, 16, 32, 64)
    assert p.bucket_for(1) == 8
    assert p.bucket_for(8) == 8
    assert p.bucket_for(9) == 16
    assert p.bucket_for(64) == 64
    with pytest.raises(ValueError):
        p.bucket_for(0)
    with pytest.raises(ValueError):
        p.bucket_for(65)
    with pytest.raises(ValueError, match="powers of two"):
        BucketPolicy(max_batch=48)
    with pytest.raises(ValueError, match="min_bucket"):
        BucketPolicy(max_batch=8, min_bucket=16)


# -- SVMEngine ---------------------------------------------------------------


@pytest.fixture(scope="module")
def engine_fleet():
    return compile_fleet({
        "a": tiny_machine(20, d=3, m=6, n_classes=3),
        "b": tiny_machine(21, d=4, m=8, n_classes=3),
    })


def test_engine_routing_matches_members(engine_fleet):
    fleet = engine_fleet
    gen = np.random.default_rng(0)
    with SVMEngine(fleet, max_batch=32, max_wait_ms=1.0) as eng:
        eng.warmup()
        futs = []
        for i in range(100):
            mid = fleet.model_ids[int(gen.integers(0, 2))]
            m = fleet.member(mid)
            x = _queries(gen, 1, m.n_features)[0]     # 1-D -> scalar label
            futs.append((mid, x, eng.submit(x, mid)))
        for mid, x, f in futs:
            want = int(fleet.member(mid).predict(x[None])[0])
            assert f.result(timeout=30.0) == want


def test_engine_bucket_edge_batches(engine_fleet):
    """1 row, exactly max_batch rows, and max_batch + 1 rows (carry into a
    second batch) all produce correct labels."""
    fleet = engine_fleet
    m = fleet.member("a")
    gen = np.random.default_rng(1)
    with SVMEngine(fleet, max_batch=32, max_wait_ms=1.0) as eng:
        eng.warmup()
        for n in (1, 32, 33):
            x = _queries(gen, n, m.n_features)
            want = m.predict(x)
            if n <= 32:                     # one multi-row request
                got = eng.predict(x, "a")
                np.testing.assert_array_equal(np.atleast_1d(got), want)
            # same rows as single-row requests (n=33 spans two batches)
            futs = [eng.submit(x[i], "a") for i in range(n)]
            got = np.asarray([f.result(timeout=30.0) for f in futs])
            np.testing.assert_array_equal(got, want)
        with pytest.raises(ValueError, match="rows"):
            eng.submit(_queries(gen, 33, m.n_features), "a")


def test_engine_one_program_per_bucket(engine_fleet):
    """The padded-bucket contract: after warmup + mixed traffic the jitted
    serving program has exactly one compiled entry per bucket shape."""
    fleet = compile_fleet({"a": tiny_machine(30), "b": tiny_machine(31)})
    gen = np.random.default_rng(2)
    with SVMEngine(fleet, max_batch=32, min_bucket=8,
                   max_wait_ms=0.5) as eng:
        eng.warmup()
        assert eng.n_buckets == 3           # 8, 16, 32
        assert fleet._labels_jit._cache_size() == eng.n_buckets
        futs = [eng.submit(_queries(gen, int(k), 3), "a")
                for k in gen.integers(1, 33, size=40)]
        for f in futs:
            f.result(timeout=30.0)
    assert fleet._labels_jit._cache_size() == eng.n_buckets


def test_engine_stats_accounting(engine_fleet):
    fleet = engine_fleet
    gen = np.random.default_rng(3)
    stats = ServingStats()
    assert stats.summary() == {"n_requests": 0, "n_queries": 0,
                               "n_batches": 0}
    with SVMEngine(fleet, max_batch=16, max_wait_ms=1.0,
                   stats=stats) as eng:
        eng.warmup()
        futs = [eng.submit(_queries(gen, 3, 3), "a") for _ in range(20)]
        for f in futs:
            f.result(timeout=30.0)
    s = stats.summary()
    assert s["n_requests"] == 20
    assert s["n_queries"] == 60             # rows, not requests
    assert 1 <= s["n_batches"] <= 20
    assert 0.0 < s["batch_occupancy"] <= 1.0
    assert s["latency_ms"]["p50"] <= s["latency_ms"]["p99"] \
        <= s["latency_ms"]["max"]
    assert s["queue_wait_ms_p50"] >= 0.0
    stats.reset()
    assert stats.n_requests == 0


def test_engine_lifecycle_and_bare_machine():
    machine = tiny_machine(40)
    eng = SVMEngine(machine, max_batch=8)   # bare machine -> 1-member fleet
    assert eng.fleet.model_ids == ["default"]
    with pytest.raises(RuntimeError, match="not started"):
        eng.submit(np.zeros(3, np.float32))
    with eng:
        with pytest.raises(RuntimeError, match="already started"):
            eng.start()
        lab = eng.predict(np.zeros(3, np.float32))
        assert lab == int(machine.predict(np.zeros((1, 3), np.float32))[0])
    with pytest.raises(RuntimeError):
        eng.submit(np.zeros(3, np.float32))
    with pytest.raises(TypeError, match="cannot serve"):
        SVMEngine(object())
