"""Per-kernel interpret-mode validation: shape/dtype sweeps vs ref.py."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("n,m,d", [(8, 8, 1), (100, 33, 4), (256, 128, 5),
                                   (130, 257, 3)])
@pytest.mark.parametrize("kind", ["rbf", "sech2"])
def test_rbf_matrix_sweep(n, m, d, kind):
    rng = np.random.RandomState(n + m + d)
    x = jnp.asarray(rng.rand(n, d), jnp.float32)
    z = jnp.asarray(rng.rand(m, d), jnp.float32)
    gamma = 4.2
    got = ops.rbf_matrix(x, z, gamma, kind=kind, bm=64, bn=64)
    want = (ref.rbf_matrix if kind == "rbf" else ref.sech2_matrix)(x, z, gamma)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=5e-6, rtol=1e-5)


@pytest.mark.parametrize("n,m,d", [
    (100, 33, 1),     # d = 1 (single-feature datasets)
    (1, 64, 3),       # single-row query
    (64, 1, 3),       # single support vector
    (1, 1, 1),        # fully degenerate
    (97, 130, 2),     # n AND m off the (bm, bn) grid simultaneously
])
@pytest.mark.parametrize("kind", ["rbf", "sech2"])
def test_rbf_matrix_awkward_shapes(n, m, d, kind):
    """Shapes off the tile grid: d=1, single-row operands, double ragged."""
    rng = np.random.RandomState(11 * n + m + d)
    x = jnp.asarray(rng.rand(n, d), jnp.float32)
    z = jnp.asarray(rng.rand(m, d), jnp.float32)
    got = ops.rbf_matrix(x, z, 2.7, kind=kind, bm=64, bn=64)
    want = (ref.rbf_matrix if kind == "rbf" else ref.sech2_matrix)(x, z, 2.7)
    assert got.shape == (n, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=5e-6, rtol=1e-5)


@pytest.mark.parametrize("n_slope,v_t,v_scale", [
    (1.38, 0.02585, 0.5),     # the defaults, explicitly
    (1.7, 0.031, 0.8),        # non-default hardware constants
    (1.1, 0.02585, 1.0),
])
def test_sech2_matrix_hardware_constants(n_slope, v_t, v_scale):
    """Non-default n_slope/v_t/v_scale thread through to the tile body and
    match the oracle evaluated with the SAME constants (the feature-unit
    gamma parametrization makes the result constant-invariant up to
    round-off, so the oracle must be built from matching values)."""
    rng = np.random.RandomState(5)
    x = jnp.asarray(rng.rand(40, 3), jnp.float32)
    z = jnp.asarray(rng.rand(25, 3), jnp.float32)
    got = ops.rbf_matrix(x, z, 4.0, kind="sech2", bm=32, bn=32,
                         n_slope=n_slope, v_t=v_t, v_scale=v_scale)
    want = ref.sech2_matrix(x, z, 4.0, n_slope=n_slope, v_t=v_t,
                            v_scale=v_scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=5e-6, rtol=1e-5)


@pytest.mark.parametrize("gamma", [0.1, 1.0, 30.0])
def test_rbf_matrix_gamma_sweep(gamma):
    rng = np.random.RandomState(7)
    x = jnp.asarray(rng.rand(64, 4), jnp.float32)
    got = ops.rbf_matrix(x, x, gamma, bm=32, bn=32)
    # f32 distance-decomposition cancellation scales with gamma
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.rbf_matrix(x, x, gamma)),
                               atol=max(5e-6, gamma * 2e-6))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 64),
                                           (False, None)])
def test_flash_attention_sweep(dtype, causal, window):
    rng = np.random.RandomState(3)
    b, hq, hkv, s, dh = 2, 4, 2, 128, 32
    q = jnp.asarray(rng.randn(b, hq, s, dh), dtype)
    k = jnp.asarray(rng.randn(b, hkv, s, dh), dtype)
    v = jnp.asarray(rng.randn(b, hkv, s, dh), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              bq=64, bk=64)
    want = ref.attention(q.astype(jnp.float32), k.astype(jnp.float32),
                         v.astype(jnp.float32), causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), atol=tol, rtol=tol)


@pytest.mark.parametrize("sq", [64, 100])
def test_flash_attention_ragged(sq):
    rng = np.random.RandomState(4)
    b, h, dh = 1, 2, 32
    q = jnp.asarray(rng.randn(b, h, sq, dh), jnp.float32)
    k = jnp.asarray(rng.randn(b, h, sq, dh), jnp.float32)
    v = jnp.asarray(rng.randn(b, h, sq, dh), jnp.float32)
    got = ops.flash_attention(q, k, v, bq=64, bk=64)
    want = ref.attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("s,chunk", [(128, 32), (256, 64), (256, 128)])
def test_ssd_sweep(s, chunk):
    rng = np.random.RandomState(s + chunk)
    b, h, dh, g, ds = 2, 4, 16, 2, 8
    x = jnp.asarray(rng.randn(b, s, h, dh) * 0.3, jnp.float32)
    a = jnp.asarray(-np.abs(rng.randn(b, s, h)) * 0.3, jnp.float32)
    bm = jnp.asarray(rng.randn(b, s, g, ds) * 0.3, jnp.float32)
    cm = jnp.asarray(rng.randn(b, s, g, ds) * 0.3, jnp.float32)
    y_ref, s_ref = ref.ssd(x, a, bm, cm)
    rep = h // g
    xf = jnp.moveaxis(x, 2, 1).reshape(b * h, s, dh)
    af = jnp.moveaxis(a, 2, 1).reshape(b * h, s)
    bf = jnp.moveaxis(jnp.repeat(bm, rep, 2), 2, 1).reshape(b * h, s, ds)
    cf = jnp.moveaxis(jnp.repeat(cm, rep, 2), 2, 1).reshape(b * h, s, ds)
    y, s_fin = ops.ssd_scan(xf, af, bf, cf, chunk=chunk)
    y = jnp.moveaxis(y.reshape(b, h, s, dh), 1, 2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_fin.reshape(b, h, dh, ds)),
                               np.asarray(s_ref), atol=1e-4)


def test_ssd_jnp_chunked_matches_ref():
    """The model's pure-jnp chunked path (used for lowering) vs the
    sequential oracle."""
    from repro.models.ssm import ssd_chunked
    rng = np.random.RandomState(11)
    b, s, h, dh, g, ds = 1, 192, 2, 8, 1, 16
    x = jnp.asarray(rng.randn(b, s, h, dh) * 0.3, jnp.float32)
    a = jnp.asarray(-np.abs(rng.randn(b, s, h)) * 0.2, jnp.float32)
    bm = jnp.asarray(rng.randn(b, s, g, ds) * 0.3, jnp.float32)
    cm = jnp.asarray(rng.randn(b, s, g, ds) * 0.3, jnp.float32)
    y_ref, s_ref = ref.ssd(x, a, bm, cm)
    y, s_fin = ssd_chunked(x, a, bm, cm, chunk=64)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_fin), np.asarray(s_ref), atol=1e-4)


def test_scan_attention_matches_full():
    """models.attention.attend_scan (jnp flash) vs attend_full."""
    from repro.models import attention as A
    rng = np.random.RandomState(12)
    b, hq, hkv, s, dh = 1, 4, 2, 160, 16
    q = jnp.asarray(rng.randn(b, hq, s, dh), jnp.float32)
    k = jnp.asarray(rng.randn(b, hkv, s, dh), jnp.float32)
    v = jnp.asarray(rng.randn(b, hkv, s, dh), jnp.float32)
    for w in (None, 48):
        got = A.attend_scan(q, k, v, causal=True, window=w, block=64)
        want = A.attend_full(q, k, v, causal=True, window=w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5)
