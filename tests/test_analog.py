"""Analog surrogate + behavioral model tests (paper Sec. III-B, IV-A, Fig. 4)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import analog, kernels as kern, svm as svm_mod


def test_ideal_circuit_matches_eq4():
    """With zero non-idealities the surrogate IS Eq. (4)."""
    p = analog.CircuitParams(sigma_vth=0.0, mirror_err=0.0, lambda_ds=0.0)
    dv = jnp.linspace(-0.3, 0.3, 101)
    out = analog.gaussian_cell_circuit(dv, p)
    x = dv / (p.n * p.v_t)
    ref = 1.0 / ((1.0 + jnp.exp(-x)) * (1.0 + jnp.exp(x)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


def test_gaussian_fit_quality_fig4():
    """Fig. 4 validation: fitted ideal Gaussian vs measured curve —
    nRMSE and r in the paper's reported ballpark (<= 0.05, >= 0.99)."""
    hw = analog.AnalogRBFModel.from_circuit(key=jax.random.PRNGKey(0))
    fit = hw.a0 * np.exp(-hw.gamma0 * (hw.dv_grid - hw.mu) ** 2)
    meas = hw.kernel_curve * hw.kernel_curve.max()  # un-normalised scale ok
    n = analog.nrmse(meas / meas.max(), fit / fit.max())
    r = analog.pearson_r(meas, fit)
    assert n < 0.05, n
    assert r > 0.99, r


def test_alpha_logistic_fit_roundtrip():
    """Eq. (9): desired alpha -> control voltage -> realised alpha."""
    hw = analog.AnalogRBFModel.from_circuit(key=jax.random.PRNGKey(1))
    want = jnp.asarray([0.05, 0.2, 0.5, 0.8, 0.95])
    got = hw.alpha_realized(hw.alpha_control_voltage(want))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0.02)


def test_alpha_fit_nrmse_fig4():
    """Alpha multiplier logistic fit quality (paper: nRMSE 0.0003)."""
    p = analog.CircuitParams()
    dva, ratio = analog.dc_sweep_alpha(p, key=jax.random.PRNGKey(2))
    x0, s = analog.fit_logistic(dva, ratio)
    fit = 1.0 / (1.0 + np.exp((dva - x0) / s))
    assert analog.nrmse(ratio, fit) < 0.01


def test_input_scaling_realizes_gamma():
    """Eq. (8): scaling inputs by sqrt(g*/g0) realises kernel width g*."""
    hw = analog.AnalogRBFModel.from_circuit(key=jax.random.PRNGKey(3))
    for g_star in (2.0, 8.0):
        # near-core sweep: Eq. (5)'s Taylor matching holds around the
        # origin; the sech2 tails legitimately exceed the Gaussian.
        x = jnp.asarray(np.linspace(0, 0.15, 8)[:, None], jnp.float32)
        z = jnp.zeros((1, 1), jnp.float32)
        k_hw = np.asarray(hw.kernel_response(x, z, g_star))[:, 0]
        k_ideal = np.asarray(kern.rbf_kernel(x, z, g_star))[:, 0]
        np.testing.assert_allclose(k_hw, k_ideal, atol=0.06)


def test_product_across_dims_separable():
    """Eq. (6): D-dim response == product of 1-D responses."""
    hw = analog.AnalogRBFModel.from_circuit(key=jax.random.PRNGKey(4))
    g = 4.0
    x = jnp.asarray([[0.1, 0.3, 0.2]], jnp.float32)
    z = jnp.zeros((1, 3), jnp.float32)
    kd = float(hw.kernel_response(x, z, g)[0, 0])
    k1 = 1.0
    for d in range(3):
        k1 *= float(hw.kernel_response(x[:, d:d + 1], z[:, :1], g)[0, 0])
    assert abs(kd - k1) < 1e-5


def test_deployment_bit_agreement():
    """Hardware-in-the-loop trained classifier deployed on the analog
    model agrees with its float decision on >= 97% of points (the paper's
    'within 1% of software accuracy' operating regime)."""
    rng = np.random.RandomState(5)
    x = rng.rand(150, 3)
    y = np.where((x[:, 0] - 0.5) ** 2 + (x[:, 1] - 0.5) ** 2 < 0.08, 1.0, -1.0)
    hw = analog.AnalogRBFModel.from_circuit(key=jax.random.PRNGKey(5))
    m = svm_mod.train_binary(x, y, hw.kernel_response, gamma=8.0, c=10.0,
                             n_epochs=200)
    clf = analog.AnalogBinaryClassifier.deploy(m, hw)
    bits_hw = clf.predict_bits(x)
    bits_float = (svm_mod.decision_function(m, x) >= 0).astype(np.int32)
    assert np.mean(bits_hw == bits_float) >= 0.97


def test_deploy_prunes_sub_dac_alphas():
    rng = np.random.RandomState(6)
    hw = analog.AnalogRBFModel.from_circuit(key=jax.random.PRNGKey(6))
    m = svm_mod.SVMModel(
        kind="rbf", support_x=rng.rand(4, 2), support_y=np.ones(4),
        alpha=np.array([1.0, 0.5, 1e-5, 1e-6]), bias=0.0, gamma=2.0, c=1.0)
    clf = analog.AnalogBinaryClassifier.deploy(m, hw)
    assert clf.n_support == 2


# ---------------------------------------------------------------------------
# Alpha-floor pruning bound (property test) and Monte-Carlo variation (§6)
# ---------------------------------------------------------------------------

import pytest  # noqa: E402

from _compat import property_test  # noqa: E402

_PRUNE_EXAMPLES = [(0,), (1,), (2,), (5,), (11,), (23,)]


def _random_rbf_model(seed: int):
    """A random RBF model whose alphas span sub-DAC to dominant scales."""
    rng = np.random.RandomState(seed)
    m = rng.randint(4, 40)
    d = rng.randint(1, 5)
    alpha = np.abs(rng.randn(m)) * 10.0 ** rng.uniform(-6, 1, m)
    return svm_mod.SVMModel(
        kind="rbf", support_x=rng.rand(m, d),
        support_y=np.where(rng.rand(m) > 0.5, 1.0, -1.0),
        alpha=alpha, bias=float(rng.randn() * 0.2),
        gamma=float(10.0 ** rng.uniform(-0.5, 1.0)), c=1.0), rng


@property_test(_PRUNE_EXAMPLES,
               strategies=lambda st: (st.integers(0, 10_000),),
               max_examples=25)
def test_deploy_pruning_perturbation_within_documented_bound(seed):
    """``AnalogBinaryClassifier.deploy`` documents that the decision-
    function perturbation from alpha-floor pruning stays below ``m *
    floor`` (in units of I_in): each pruned cell's realised alpha is below
    ``floor / 1.05`` and its kernel response is at most ~1, so the pruned
    rail mass — and hence the comparator-input change — is bounded by the
    cell count times the floor.  Property-tested on random models."""
    model, rng = _random_rbf_model(seed)
    hw = analog.AnalogRBFModel.from_circuit(key=jax.random.PRNGKey(0))
    floor = 1.0 / 256.0
    pruned = analog.AnalogBinaryClassifier.deploy(model, hw,
                                                  alpha_floor_rel=floor)
    full = analog.AnalogBinaryClassifier.deploy(model, hw,
                                                alpha_floor_rel=0.0)
    assert pruned.n_support <= full.n_support == model.alpha.shape[0]
    x = rng.rand(48, model.support_x.shape[1])

    def decision(clf):
        i_plus, i_minus = clf.rail_currents(x)
        return np.asarray(i_plus - i_minus)

    err = np.max(np.abs(decision(pruned) - decision(full)))
    assert err <= model.alpha.shape[0] * floor, (err, model.alpha.shape[0])


def _deployed(seed=5, n=120):
    rng = np.random.RandomState(seed)
    x = rng.rand(n, 3)
    y = np.where((x[:, 0] - 0.5) ** 2 + (x[:, 1] - 0.5) ** 2 < 0.08,
                 1.0, -1.0)
    hw = analog.AnalogRBFModel.from_circuit(key=jax.random.PRNGKey(seed))
    m = svm_mod.train_binary(x, y, hw.kernel_response, gamma=8.0, c=10.0,
                             n_epochs=150)
    return x, analog.AnalogBinaryClassifier.deploy(m, hw)


def test_sample_variants_shapes_keys_and_nominal_row():
    x, clf = _deployed()
    v = clf.sample_variants(jax.random.PRNGKey(1), 6)
    assert v.n_variants == 6
    assert v.gauss.shape == (6, clf.n_support, clf.n_features,
                             analog.N_GAUSS_OFFSETS)
    assert v.alpha.shape == (6, clf.n_support, analog.N_ALPHA_OFFSETS)
    assert v.comparator.shape == (6,)
    # row 0 is the zero-offset nominal instance
    assert not np.asarray(v.gauss[0]).any()
    assert not np.asarray(v.alpha[0]).any()
    # explicit keys: same key reproduces, different keys differ
    v2 = clf.sample_variants(jax.random.PRNGKey(1), 6)
    np.testing.assert_array_equal(np.asarray(v.gauss), np.asarray(v2.gauss))
    v3 = clf.sample_variants(jax.random.PRNGKey(2), 6)
    assert not np.array_equal(np.asarray(v.gauss), np.asarray(v3.gauss))
    # sigma_scale scales the draws linearly
    v4 = clf.sample_variants(jax.random.PRNGKey(1), 6, sigma_scale=2.0)
    np.testing.assert_allclose(np.asarray(v4.gauss),
                               2.0 * np.asarray(v.gauss), rtol=1e-6)
    # without the nominal row every instance is a draw
    v5 = clf.sample_variants(jax.random.PRNGKey(1), 2,
                             include_nominal=False)
    assert np.asarray(v5.gauss[0]).any()
    with pytest.raises(ValueError, match="n_variants"):
        clf.sample_variants(jax.random.PRNGKey(0), 1)


def test_variant_transfer_params_nominal_is_exact():
    """The zero-offset reduction lands on exact f32 identities (shift 0,
    gain 1, slope 1, nominal comparator offset) — the arithmetic basis of
    the bit-identity contract."""
    x, clf = _deployed()
    v = clf.sample_variants(jax.random.PRNGKey(3), 4)
    t = analog.variant_transfer_params(v, clf.hw.params)
    assert not np.asarray(t.shift[0]).any()
    assert (np.asarray(t.gain[0]) == 1.0).all()
    assert not np.asarray(t.alpha_shift[0]).any()
    assert (np.asarray(t.alpha_slope[0]) == 1.0).all()
    p = clf.hw.params
    assert np.asarray(t.comp_offset)[0] == np.float32(
        p.comparator_offset / p.i_bias)


def test_decision_mc_nominal_bit_identity_and_spread():
    """Variant 0 of the object-path Monte-Carlo evaluation reproduces the
    nominal rails bit for bit; sampled variants actually move."""
    x, clf = _deployed()
    v = clf.sample_variants(jax.random.PRNGKey(4), 8)
    scores = np.asarray(clf.decision_mc(x, v))
    i_plus, i_minus = clf.rail_currents(x)
    off = clf.hw.params.comparator_offset / clf.hw.params.i_bias
    nominal = np.asarray(i_plus - i_minus + off)
    np.testing.assert_array_equal(scores[0], nominal)
    np.testing.assert_array_equal(clf.predict_bits_mc(x, v)[0],
                                  clf.predict_bits(x))
    assert np.abs(scores[1:] - nominal[None, :]).max() > 0
    # sigma_scale=0 collapses every instance onto the nominal one
    v0 = clf.sample_variants(jax.random.PRNGKey(5), 3, sigma_scale=0.0)
    s0 = np.asarray(clf.decision_mc(x, v0))
    for row in s0:
        np.testing.assert_array_equal(row, nominal)


def test_analog_models_are_registered_pytrees():
    """AnalogRBFModel / AnalogBinaryClassifier / VariantSet flatten and
    rebuild through jax.tree_util (the batchable-model contract)."""
    x, clf = _deployed()
    leaves, treedef = jax.tree_util.tree_flatten(clf)
    assert len(leaves) > 5
    clf2 = jax.tree_util.tree_unflatten(treedef, leaves)
    np.testing.assert_array_equal(clf2.predict_bits(x), clf.predict_bits(x))
    v = clf.sample_variants(jax.random.PRNGKey(0), 3)
    v2 = jax.tree_util.tree_unflatten(*reversed(
        jax.tree_util.tree_flatten(v)))
    np.testing.assert_array_equal(np.asarray(v2.gauss), np.asarray(v.gauss))


def test_from_circuit_splits_calibration_keys():
    """The Gaussian and alpha sweeps draw INDEPENDENT mismatch: the model
    calibrated with a key differs from one whose alpha sweep reused the
    Gaussian key (the pre-fix behavior would make them identical)."""
    key = jax.random.PRNGKey(7)
    hw = analog.AnalogRBFModel.from_circuit(key=key)
    dva_reused, ratio_reused = analog.dc_sweep_alpha(
        analog.CircuitParams(), key=key)
    assert not np.array_equal(hw.alpha_curve, ratio_reused)
    # and the gaussian sweep is the first split of the key
    kg = jax.random.split(key)[0]
    dv, curve = analog.dc_sweep_gaussian(analog.CircuitParams(), key=kg)
    np.testing.assert_array_equal(hw.kernel_curve, curve / curve.max())
