"""Analog surrogate + behavioral model tests (paper Sec. III-B, IV-A, Fig. 4)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import analog, kernels as kern, svm as svm_mod


def test_ideal_circuit_matches_eq4():
    """With zero non-idealities the surrogate IS Eq. (4)."""
    p = analog.CircuitParams(sigma_vth=0.0, mirror_err=0.0, lambda_ds=0.0)
    dv = jnp.linspace(-0.3, 0.3, 101)
    out = analog.gaussian_cell_circuit(dv, p)
    x = dv / (p.n * p.v_t)
    ref = 1.0 / ((1.0 + jnp.exp(-x)) * (1.0 + jnp.exp(x)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


def test_gaussian_fit_quality_fig4():
    """Fig. 4 validation: fitted ideal Gaussian vs measured curve —
    nRMSE and r in the paper's reported ballpark (<= 0.05, >= 0.99)."""
    hw = analog.AnalogRBFModel.from_circuit(key=jax.random.PRNGKey(0))
    fit = hw.a0 * np.exp(-hw.gamma0 * (hw.dv_grid - hw.mu) ** 2)
    meas = hw.kernel_curve * hw.kernel_curve.max()  # un-normalised scale ok
    n = analog.nrmse(meas / meas.max(), fit / fit.max())
    r = analog.pearson_r(meas, fit)
    assert n < 0.05, n
    assert r > 0.99, r


def test_alpha_logistic_fit_roundtrip():
    """Eq. (9): desired alpha -> control voltage -> realised alpha."""
    hw = analog.AnalogRBFModel.from_circuit(key=jax.random.PRNGKey(1))
    want = jnp.asarray([0.05, 0.2, 0.5, 0.8, 0.95])
    got = hw.alpha_realized(hw.alpha_control_voltage(want))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0.02)


def test_alpha_fit_nrmse_fig4():
    """Alpha multiplier logistic fit quality (paper: nRMSE 0.0003)."""
    p = analog.CircuitParams()
    dva, ratio = analog.dc_sweep_alpha(p, key=jax.random.PRNGKey(2))
    x0, s = analog.fit_logistic(dva, ratio)
    fit = 1.0 / (1.0 + np.exp((dva - x0) / s))
    assert analog.nrmse(ratio, fit) < 0.01


def test_input_scaling_realizes_gamma():
    """Eq. (8): scaling inputs by sqrt(g*/g0) realises kernel width g*."""
    hw = analog.AnalogRBFModel.from_circuit(key=jax.random.PRNGKey(3))
    for g_star in (2.0, 8.0):
        # near-core sweep: Eq. (5)'s Taylor matching holds around the
        # origin; the sech2 tails legitimately exceed the Gaussian.
        x = jnp.asarray(np.linspace(0, 0.15, 8)[:, None], jnp.float32)
        z = jnp.zeros((1, 1), jnp.float32)
        k_hw = np.asarray(hw.kernel_response(x, z, g_star))[:, 0]
        k_ideal = np.asarray(kern.rbf_kernel(x, z, g_star))[:, 0]
        np.testing.assert_allclose(k_hw, k_ideal, atol=0.06)


def test_product_across_dims_separable():
    """Eq. (6): D-dim response == product of 1-D responses."""
    hw = analog.AnalogRBFModel.from_circuit(key=jax.random.PRNGKey(4))
    g = 4.0
    x = jnp.asarray([[0.1, 0.3, 0.2]], jnp.float32)
    z = jnp.zeros((1, 3), jnp.float32)
    kd = float(hw.kernel_response(x, z, g)[0, 0])
    k1 = 1.0
    for d in range(3):
        k1 *= float(hw.kernel_response(x[:, d:d + 1], z[:, :1], g)[0, 0])
    assert abs(kd - k1) < 1e-5


def test_deployment_bit_agreement():
    """Hardware-in-the-loop trained classifier deployed on the analog
    model agrees with its float decision on >= 97% of points (the paper's
    'within 1% of software accuracy' operating regime)."""
    rng = np.random.RandomState(5)
    x = rng.rand(150, 3)
    y = np.where((x[:, 0] - 0.5) ** 2 + (x[:, 1] - 0.5) ** 2 < 0.08, 1.0, -1.0)
    hw = analog.AnalogRBFModel.from_circuit(key=jax.random.PRNGKey(5))
    m = svm_mod.train_binary(x, y, hw.kernel_response, gamma=8.0, c=10.0,
                             n_epochs=200)
    clf = analog.AnalogBinaryClassifier.deploy(m, hw)
    bits_hw = clf.predict_bits(x)
    bits_float = (svm_mod.decision_function(m, x) >= 0).astype(np.int32)
    assert np.mean(bits_hw == bits_float) >= 0.97


def test_deploy_prunes_sub_dac_alphas():
    rng = np.random.RandomState(6)
    hw = analog.AnalogRBFModel.from_circuit(key=jax.random.PRNGKey(6))
    m = svm_mod.SVMModel(
        kind="rbf", support_x=rng.rand(4, 2), support_y=np.ones(4),
        alpha=np.array([1.0, 0.5, 1e-5, 1e-6]), bias=0.0, gamma=2.0, c=1.0)
    clf = analog.AnalogBinaryClassifier.deploy(m, hw)
    assert clf.n_support == 2
