"""Unit tests for the SVM solver and kernel math (paper Sec. II)."""
import jax.numpy as jnp
import numpy as np

from _compat import property_test

from repro.core import kernels as kern
from repro.core import svm as svm_mod


def test_linear_separable_exact():
    """Perfectly separable 2-D data: solver must classify perfectly and
    the primal view w (Eq. 3) must agree with the dual decision."""
    rng = np.random.RandomState(0)
    x = rng.randn(80, 2)
    y = np.where(x[:, 0] + 2 * x[:, 1] > 0, 1.0, -1.0)
    m = svm_mod.train_binary(x, y, "linear", c=10.0, n_epochs=300)
    assert svm_mod.accuracy(m, x, y) >= 0.98  # soft-margin near-boundary slack
    f_dual = kern.kernel_matrix("linear", jnp.asarray(x, jnp.float32),
                                jnp.asarray(m.support_x, jnp.float32))
    f_dual = np.asarray(f_dual) @ (m.alpha * m.support_y) + m.bias
    f_primal = x @ m.w + m.bias
    np.testing.assert_allclose(f_primal, f_dual, rtol=1e-4, atol=1e-4)


def test_rbf_solves_xor():
    """XOR is the canonical linear-failure case (paper's motivation for
    mixed kernels)."""
    rng = np.random.RandomState(1)
    x = rng.rand(200, 2)
    y = np.where((x[:, 0] > 0.5) ^ (x[:, 1] > 0.5), 1.0, -1.0)
    m_lin = svm_mod.train_binary(x, y, "linear", c=10.0, n_epochs=200)
    m_rbf = svm_mod.train_binary(x, y, "rbf", gamma=20.0, c=10.0, n_epochs=200)
    assert svm_mod.accuracy(m_rbf, x, y) > 0.95
    assert svm_mod.accuracy(m_rbf, x, y) > svm_mod.accuracy(m_lin, x, y) + 0.2


def test_dual_satisfies_box_constraints():
    rng = np.random.RandomState(2)
    x = rng.rand(60, 3)
    y = np.where(rng.rand(60) > 0.5, 1.0, -1.0)
    kp = kern.kernel_matrix("rbf", jnp.asarray(x, jnp.float32),
                            jnp.asarray(x, jnp.float32), 5.0) + 1.0
    c = 2.5
    alpha = np.asarray(svm_mod.dual_coordinate_ascent(
        kp, jnp.asarray(y, jnp.float32), jnp.full((60,), c), 100))
    assert np.all(alpha >= 0.0) and np.all(alpha <= c + 1e-6)


def test_masked_samples_stay_zero():
    """C_i = 0 freezes a sample (the CV-fold masking mechanism)."""
    rng = np.random.RandomState(3)
    x = rng.rand(40, 2)
    y = np.where(rng.rand(40) > 0.5, 1.0, -1.0)
    kp = kern.kernel_matrix("rbf", jnp.asarray(x, jnp.float32),
                            jnp.asarray(x, jnp.float32), 5.0) + 1.0
    box = np.full((40,), 1.0, np.float32)
    box[::2] = 0.0
    alpha = np.asarray(svm_mod.dual_coordinate_ascent(
        kp, jnp.asarray(y, jnp.float32), jnp.asarray(box), 50))
    assert np.all(alpha[::2] == 0.0)


@property_test(
    fixed_examples=[(1, 1, 0.1), (30, 5, 50.0), (7, 3, 5.0), (16, 2, 1.0)],
    strategies=lambda st: (st.integers(1, 30), st.integers(1, 5),
                           st.floats(0.1, 50.0)),
    max_examples=25,
)
def test_rbf_kernel_properties(n, d, gamma):
    """K symmetric, K(x,x)=1, 0 < K <= 1 (hypothesis property test)."""
    rng = np.random.RandomState(n * 7 + d)
    x = jnp.asarray(rng.rand(n, d), jnp.float32)
    k = np.asarray(kern.rbf_kernel(x, x, gamma))
    # f32 matmul cancellation scales with gamma * |x|^2 ulps
    tol = max(1e-5, gamma * 2e-5)
    np.testing.assert_allclose(k, k.T, atol=tol)
    np.testing.assert_allclose(np.diag(k), 1.0, atol=tol)
    # >= 0: exp underflows to exact 0 for gamma * d^2 > ~88 in f32
    assert np.all(k >= 0) and np.all(k <= 1 + tol)


@property_test(
    fixed_examples=[(2, 1), (20, 5), (9, 3), (12, 2)],
    strategies=lambda st: (st.integers(2, 20), st.integers(1, 5)),
    max_examples=25,
)
def test_rbf_kernel_psd(n, d):
    rng = np.random.RandomState(n * 13 + d)
    x = jnp.asarray(rng.rand(n, d), jnp.float32)
    k = np.asarray(kern.rbf_kernel(x, x, 3.0), np.float64)
    w = np.linalg.eigvalsh((k + k.T) / 2)
    assert w.min() > -1e-5


def test_sech2_matches_gaussian_near_origin():
    """Eq. (5): Taylor matching — sech2 cell ~ Gaussian for small dv."""
    gamma = 5.0
    x = jnp.asarray(np.linspace(0, 0.08, 9)[:, None], jnp.float32)
    z = jnp.zeros((1, 1), jnp.float32)
    k_hw = np.asarray(kern.sech2_kernel(x, z, gamma))
    k_id = np.asarray(kern.rbf_kernel(x, z, gamma))
    np.testing.assert_allclose(k_hw, k_id, atol=5e-3)


def test_sech2_fatter_tails():
    """Far from origin the hardware kernel exceeds the ideal Gaussian —
    the 'inherent functional approximation' the paper discusses."""
    gamma = 10.0
    x = jnp.asarray([[1.0]], jnp.float32)
    z = jnp.zeros((1, 1), jnp.float32)
    assert float(kern.sech2_kernel(x, z, gamma)[0, 0]) > float(
        kern.rbf_kernel(x, z, gamma)[0, 0])


def test_gamma_subthreshold_value():
    """gamma0 = 1/(4 n^2 V_T^2), Eq. (5)."""
    g = kern.gamma_subthreshold(1.38, 0.02585)
    assert abs(g - 1.0 / (4 * 1.38**2 * 0.02585**2)) < 1e-9


def test_cv_grid_shapes_and_range():
    rng = np.random.RandomState(4)
    x = rng.rand(50, 3)
    y = np.where(x[:, 0] > 0.5, 1.0, -1.0)
    acc = svm_mod.cv_grid_accuracy(x, y, "rbf", np.array([1.0, 10.0]),
                                   np.array([1.0, 10.0, 100.0]),
                                   n_folds=3, n_epochs=30)
    assert acc.shape == (2, 3)
    assert np.all(acc >= 0) and np.all(acc <= 1)
