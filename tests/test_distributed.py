"""Multi-device tests on 8 fake CPU devices (subprocess isolation so the
XLA device-count flag never leaks into other tests)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import partition

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

try:
    from jax.sharding import AxisType  # noqa: F401  (feature probe)

    _HAS_AXIS_TYPE = True
except ImportError:  # older jax: explicit-axis-type mesh API not available
    _HAS_AXIS_TYPE = False

needs_axis_types = pytest.mark.skipif(
    not _HAS_AXIS_TYPE,
    reason="jax.sharding.AxisType / jax.set_mesh unavailable on this jax")


def run_multidevice(body: str):
    """Run `body` in a fresh python with 8 fake devices."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    return res.stdout


# -- pure-python spec logic (no devices needed) ------------------------------


def test_fit_spec_divisibility():
    sizes = {"data": 16, "model": 16}
    assert partition.fit_spec(P("model", "data"), (49155, 1024), sizes) \
        == P(None, "data")
    assert partition.fit_spec(P("model", "data"), (49152, 1024), sizes) \
        == P("model", "data")
    assert partition.fit_spec(P(("pod", "data"), None), (64, 7),
                              {"pod": 2, "data": 16, "model": 16}) \
        == P(("pod", "data"), None)
    assert partition.fit_spec(P(("pod", "data"),), (31,),
                              {"pod": 2, "data": 16}) == P(None)


def test_param_specs_cover_every_leaf():
    import jax
    from repro import configs
    from repro.models import transformer as tfm
    from repro.models.common import ShardRules
    for arch in ("qwen2.5-32b", "kimi-k2-1t-a32b", "mamba2-2.7b",
                 "whisper-medium", "hymba-1.5b"):
        cfg = configs.get(arch).make_config()
        sds = jax.eval_shape(lambda c=cfg: tfm.init_params(
            c, jax.random.PRNGKey(0)))
        specs = partition.param_specs(cfg, sds, ShardRules())
        flat_sds = jax.tree.leaves(sds)
        flat_sp = jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_sds) == len(flat_sp)
        # every big matrix is sharded on at least one axis
        for sd, sp in zip(flat_sds, flat_sp):
            if np.prod(sd.shape) > 4e6:
                assert any(a is not None for a in sp), (arch, sd.shape, sp)


# -- 8-device shard_map behaviours -------------------------------------------


@needs_axis_types
def test_compressed_allreduce_mean_and_feedback():
    run_multidevice("""
        from jax.sharding import AxisType
        from repro.distributed import compression
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(AxisType.Auto,))
        ar = compression.make_compressed_allreduce(mesh, "data", block=64)
        rng = np.random.RandomState(0)
        g = jnp.asarray(rng.randn(8, 32, 16), jnp.float32)  # per-shard grads
        e = jnp.zeros_like(g)
        grads = {"w": g}
        errs = {"w": e}
        mean1, errs = ar(grads, errs)
        true_mean = np.asarray(g).mean(0)
        err1 = np.abs(np.asarray(mean1["w"]) - true_mean).max()
        assert err1 < 0.05, err1              # int8 quantization error bound
        # error feedback: repeating the SAME grads, the running average of
        # the compressed means converges to the true mean (unbiasedness)
        acc = np.zeros_like(true_mean)
        for i in range(20):
            m, errs = ar(grads, errs)
            acc += np.asarray(m["w"])
        err20 = np.abs(acc / 20 - true_mean).max()
        assert err20 < err1 / 2, (err20, err1)
        print("OK", err1, err20)
    """)


@needs_axis_types
def test_pipeline_matches_sequential():
    run_multidevice("""
        from jax.sharding import AxisType
        from repro.distributed.pipeline import pipeline_forward
        mesh = jax.make_mesh((8,), ("stage",), axis_types=(AxisType.Auto,))
        rng = np.random.RandomState(0)
        n_stages, n_micro, mb, d = 8, 4, 2, 16
        ws = jnp.asarray(rng.randn(n_stages, d, d) * 0.3, jnp.float32)
        def stage_fn(p, x):
            return jnp.tanh(x @ p["w"])
        params = {"w": ws}
        mbs = jnp.asarray(rng.randn(n_micro, mb, d), jnp.float32)
        f = pipeline_forward(mesh, stage_fn, "stage")
        got = f(params, mbs)
        # sequential reference
        want = mbs
        for s in range(n_stages):
            want = jnp.tanh(want @ ws[s])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)
        print("OK")
    """)


@needs_axis_types
def test_elastic_reshard_roundtrip():
    run_multidevice("""
        from jax.sharding import AxisType, NamedSharding
        from repro.distributed import elastic
        m1 = jax.make_mesh((4, 2), ("data", "model"),
                           axis_types=(AxisType.Auto,) * 2)
        m2 = jax.make_mesh((2, 4), ("data", "model"),
                           axis_types=(AxisType.Auto,) * 2)
        rng = np.random.RandomState(0)
        tree = {"w": jnp.asarray(rng.randn(16, 8), jnp.float32),
                "b": jnp.asarray(rng.randn(8), jnp.float32)}
        specs = {"w": P("data", "model"), "b": P(None)}
        on1 = elastic.reshard(tree, specs, m1)
        on2 = elastic.rescale_checkpoint(
            jax.tree.map(np.asarray, on1), specs, m2)
        np.testing.assert_allclose(np.asarray(on2["w"]),
                                   np.asarray(tree["w"]))
        assert on2["w"].sharding.mesh.shape["data"] == 2
        print("OK")
    """)


@needs_axis_types
def test_small_mesh_train_step_shards():
    """A reduced model train step under a (2, 4) mesh with real
    in_shardings — the miniature of the production dry-run."""
    run_multidevice("""
        from jax.sharding import AxisType, NamedSharding
        from repro import configs
        from repro.distributed import partition
        from repro.models.common import ShardRules
        from repro.training import optimizer as opt_mod, step as step_mod
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(AxisType.Auto,) * 2)
        cfg = configs.get("granite-moe-1b-a400m").reduced()
        import dataclasses
        cfg = dataclasses.replace(cfg, d_model=64, d_ff=32, vocab_size=512,
                                  n_heads=4, n_kv_heads=2)
        rules = ShardRules()
        oc = opt_mod.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
        state = step_mod.init_train_state(cfg, oc, jax.random.PRNGKey(0))
        sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                           state)
        sizes = {"data": 2, "model": 4}
        p_specs = partition.fit_tree(
            partition.param_specs(cfg, sds["params"], rules),
            sds["params"], sizes)
        st_specs = {"params": p_specs,
                    "opt": partition.fit_tree(
                        partition.opt_specs(cfg, p_specs, sds["opt"], rules),
                        sds["opt"], sizes),
                    "step": P()}
        rngn = np.random.RandomState(0)
        batch = {"tokens": jnp.asarray(rngn.randint(0, 512, (8, 16))),
                 "labels": jnp.asarray(rngn.randint(0, 512, (8, 16)))}
        b_specs = {"tokens": P(("data",), None), "labels": P(("data",), None)}
        ts = step_mod.make_train_step(cfg, rules, oc)
        sh = lambda t: jax.tree.map(
            lambda s: NamedSharding(mesh, s), t,
            is_leaf=lambda x: isinstance(x, P))
        with jax.set_mesh(mesh):
            state = jax.device_put(state, sh(st_specs))
            batch = jax.device_put(batch, sh(b_specs))
            f = jax.jit(ts, in_shardings=(sh(st_specs), sh(b_specs)))
            state, m = f(state, batch)
            state, m = f(state, batch)
        assert np.isfinite(float(m["loss"]))
        print("OK loss", float(m["loss"]))
    """)
