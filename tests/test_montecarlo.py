"""Tests for the Monte-Carlo variant axis (DESIGN.md §6).

Covers the tentpole guarantees:

  * the compiled ``MonteCarloMachine`` agrees with the object-path
    ``AnalogBinaryClassifier.predict_bits_mc`` reference, variant for
    variant, under the same per-pair key split;
  * the zero-offset variant is BIT-IDENTICAL (scores and bits) to the
    nominal ``CandidateMachine`` — the acceptance contract;
  * evaluating V = 64 variants on a paper dataset costs at most 2
    additional jit compiles (MC forward + batched recombination);
  * the batched bit-recombination: ``assignment_accuracies_mc`` equals a
    per-variant loop of the nominal recombination, on both the encoder
    and the votes fallback, through the assignment-chunked path;
  * yield/robust-front semantics: ``pareto_front(yield_=...)``,
    ``SweepResult.select(yield_floor=...)``, ``deploy(yield_floor=...)``;
  * serialization: the chosen assignment + MC key/config and the
    ``CircuitParams`` override round-trip through save/load.
"""
import os

import jax
import numpy as np
import pytest

from repro.api import (
    CircuitParams,
    MixedKernelSVM,
    compile_candidates,
    compile_variants,
)
from repro.core import dse, mcstream
from repro.data import datasets

N_VARIANTS = 64  # the acceptance setting


@pytest.fixture(scope="module")
def balance():
    ds = datasets.load("balance")
    est = MixedKernelSVM(n_epochs=60, seed=0).fit(ds.x_train, ds.y_train)
    return ds, est


@pytest.fixture(scope="module")
def mc_machine(balance):
    _, est = balance
    return est.monte_carlo_machine(N_VARIANTS, jax.random.PRNGKey(0))


# -- the compiled variant machine --------------------------------------------


def test_pair_bits_shape_and_reproducibility(balance, mc_machine):
    ds, est = balance
    bits3 = mc_machine.pair_bits(ds.x_test)
    assert bits3.shape == (N_VARIANTS, len(ds.x_test),
                           len(est.kernel_map_), 2)
    # cached machine: same config returns the same compiled object
    assert est.monte_carlo_machine(
        N_VARIANTS, jax.random.PRNGKey(0)) is mc_machine
    # same key -> same draws -> same bits through a fresh lowering
    fresh = compile_variants(est._candidates(), est.n_classes_,
                             key=jax.random.PRNGKey(0),
                             n_variants=N_VARIANTS)
    np.testing.assert_array_equal(fresh.pair_bits(ds.x_test), bits3)


def test_nominal_variant_bit_identical_to_candidate_machine(
        balance, mc_machine):
    """ACCEPTANCE: zero-offset MC variant == nominal compiled path,
    bit for bit, scores included."""
    ds, est = balance
    nominal = compile_candidates(est._candidates(), est.n_classes_)
    for x in (ds.x_train, ds.x_test):
        np.testing.assert_array_equal(mc_machine.pair_scores(x)[0],
                                      nominal.pair_scores(x))
        np.testing.assert_array_equal(mc_machine.pair_bits(x)[0],
                                      nominal.pair_bits(x))


def test_linear_lanes_are_variation_free(balance, mc_machine):
    ds, _ = balance
    scores = mc_machine.pair_scores(ds.x_test)
    for v in range(1, scores.shape[0]):
        np.testing.assert_array_equal(scores[v, :, :, 0], scores[0, :, :, 0])
    # ... while the analog lanes actually move
    assert np.abs(scores[1:, :, :, 1] - scores[:1, :, :, 1]).max() > 0


def test_compiled_matches_object_path_reference(balance):
    """Every variant of every analog lane reproduces the behavioral-model
    reference (`predict_bits_mc`) under the same per-pair key split."""
    ds, est = balance
    key, v = jax.random.PRNGKey(11), 8
    machine = compile_variants(est._candidates(), est.n_classes_, key=key,
                               n_variants=v)
    bits3 = machine.pair_bits(ds.x_test)
    keys = jax.random.split(key, len(est.kernel_map_))
    for p, (_, clf) in enumerate(est._candidates()):
        variants = clf.sample_variants(keys[p], v)
        np.testing.assert_array_equal(
            bits3[:, :, p, 1], clf.predict_bits_mc(ds.x_test, variants))


def test_mc_sweep_two_additional_compiles(balance):
    """ACCEPTANCE: V = 64 variants on a paper dataset in <= 2 additional
    jit compiles (the MC forward + the batched recombination)."""
    from benchmarks.svm_train import count_compiles

    ds, est = balance
    est.pareto(ds.x_test, ds.y_test)           # warm the nominal DSE path
    key = jax.random.PRNGKey(42)
    est.monte_carlo_machine(N_VARIANTS, key)   # lowering outside the count
    with count_compiles() as cc:
        sweep = est.pareto(ds.x_test, ds.y_test, n_variants=N_VARIANTS,
                           key=key, accuracy_floor=0.85)
    assert cc.count() <= 2, cc.names
    assert sweep.is_monte_carlo and sweep.n_variants == N_VARIANTS


# -- the batched recombination ------------------------------------------------


def test_accuracies_mc_match_per_variant_loop(balance, mc_machine):
    ds, est = balance
    bits3 = mc_machine.pair_bits(ds.x_test)
    a = dse.enumerate_assignments(len(est.kernel_map_))
    acc_vs = dse.assignment_accuracies_mc(bits3, a, ds.y_test,
                                          est.n_classes_)
    assert acc_vs.shape == (N_VARIANTS, a.shape[0])
    for v in range(0, N_VARIANTS, 13):
        np.testing.assert_allclose(
            acc_vs[v],
            dse.assignment_accuracies(bits3[v], a, ds.y_test,
                                      est.n_classes_),
            atol=1e-12)
    # votes fallback agrees with the encoder path
    acc_votes = dse.assignment_accuracies_mc(bits3, a, ds.y_test,
                                             est.n_classes_,
                                             max_table_bits=0)
    np.testing.assert_allclose(acc_votes, acc_vs, atol=1e-7)


def test_accuracies_mc_chunked_path():
    """The fixed-shape assignment chunking (S > MC_CHUNK) returns the same
    matrix as one unchunked call would."""
    rng = np.random.RandomState(0)
    v, n, p, k = 3, 60, 10, 5
    bits3 = rng.randint(0, 2, (v, n, p, 2)).astype(np.int32)
    y = rng.randint(0, k, n)
    a = dse.enumerate_assignments(p)           # 1024 > MC_CHUNK = 512
    assert a.shape[0] > dse.MC_CHUNK
    acc = dse.assignment_accuracies_mc(bits3, a, y, k)
    for v_i in range(v):
        np.testing.assert_allclose(
            acc[v_i], dse.assignment_accuracies(bits3[v_i], a, y, k),
            atol=1e-12)


def test_mc_statistics_and_yield():
    acc_vs = np.array([[0.9, 0.5], [0.8, 0.5], [0.7, 0.5]])
    s = dse.mc_statistics(acc_vs, accuracy_floor=0.75)
    np.testing.assert_allclose(s["mean"], [0.8, 0.5])
    np.testing.assert_allclose(s["worst"], [0.7, 0.5])
    np.testing.assert_allclose(s["yield"], [2 / 3, 0.0])
    np.testing.assert_allclose(s["std"][1], 0.0)


def test_pareto_front_robust_mode():
    """The yield objective keeps a lower-accuracy, higher-yield point that
    three-objective domination would discard."""
    acc = np.array([0.95, 0.90])
    area = np.array([1.0, 1.0])
    power = np.array([1.0, 1.0])
    assert dse.pareto_front(acc, area, power).tolist() == [0]
    yld = np.array([0.2, 0.99])
    assert sorted(dse.pareto_front(acc, area, power,
                                   yield_=yld).tolist()) == [0, 1]


# -- sweep + selection + deployment ------------------------------------------


@pytest.fixture(scope="module")
def mc_sweep(balance, mc_machine):
    ds, est = balance
    return est.pareto(ds.x_test, ds.y_test, n_variants=N_VARIANTS,
                      key=jax.random.PRNGKey(0), accuracy_floor=0.85)


def test_mc_sweep_fields(balance, mc_sweep):
    ds, est = balance
    sw = mc_sweep
    assert sw.is_monte_carlo and sw.exhaustive
    assert sw.accuracy_mc.shape == (N_VARIANTS, 8)
    # the nominal column IS the zero-offset variant's row
    np.testing.assert_array_equal(sw.accuracy, sw.accuracy_mc[0])
    assert (sw.acc_worst <= sw.acc_mean + 1e-12).all()
    assert ((0.0 <= sw.yield_) & (sw.yield_ <= 1.0)).all()
    # the all-linear corner is variation-free: zero spread, yield 0 or 1
    i = sw.find(np.zeros(sw.n_pairs, bool))
    assert sw.acc_std[i] == 0.0 and sw.yield_[i] in (0.0, 1.0)
    # yields are monotone in the floor
    assert (sw.yield_at(0.5) >= sw.yield_).all()
    # MC provenance recorded on the sweep and the estimator
    assert sw.n_variants == N_VARIANTS and sw.mc_key_data is not None
    assert est.mc_state_["n_variants"] == N_VARIANTS
    assert est.mc_state_["accuracy_floor"] == pytest.approx(0.85)


def test_robust_selection_rule(mc_sweep):
    sw = mc_sweep
    feasible = sw.yield_[sw.robust_front]
    floor = float(np.sort(feasible)[len(feasible) // 2])
    i = sw.select(yield_floor=floor)
    assert sw.yield_[i] >= floor
    # cheapest-first: no other feasible robust-front point is cheaper
    others = [j for j in sw.robust_front
              if sw.yield_[j] >= floor and j != i]
    assert all(sw.area[i] <= sw.area[j] + 1e-15 for j in others)
    with pytest.raises(ValueError, match="yield"):
        sw.select(yield_floor=1.1)


def test_yield_floor_requires_mc(balance):
    ds, est = balance
    nominal = est.design_space().sweep(ds.x_test, ds.y_test)
    with pytest.raises(RuntimeError, match="Monte-Carlo"):
        nominal.select(yield_floor=0.9)
    with pytest.raises(ValueError, match="accuracy_floor"):
        est.design_space().sweep(ds.x_test, ds.y_test,
                                 mc_machine=est.monte_carlo_machine(
                                     8, jax.random.PRNGKey(0)))
    with pytest.raises(ValueError, match="n_variants"):
        est.pareto(ds.x_test, ds.y_test, accuracy_floor=0.9)


def test_monte_carlo_result(balance):
    ds, est = balance
    mc = est.monte_carlo(ds.x_test, ds.y_test, n_variants=16,
                         key=jax.random.PRNGKey(5))
    assert mc.accuracy.shape == (16,)
    assert mc.nominal == pytest.approx(
        est.score(ds.x_test, ds.y_test, target="circuit"), abs=1e-6)
    assert mc.worst <= mc.mean <= 1.0
    assert mc.yield_at(0.0) == 1.0
    assert mc.yield_at(0.5) >= mc.yield_at(0.9)
    assert mc.key_data and mc.n_variants == 16
    # sigma_scale=0 collapses the distribution onto the nominal machine
    mc0 = est.monte_carlo(ds.x_test, ds.y_test, n_variants=4,
                          key=jax.random.PRNGKey(5), sigma_scale=0.0)
    assert mc0.std == 0.0 and mc0.mean == mc0.nominal


def test_yield_deploy_and_roundtrip(balance, mc_sweep, tmp_path):
    ds, est = balance
    sw = mc_sweep
    # historical point-estimate rule, explicitly requested
    pt_floor = float(sw.yield_[sw.robust_front].max())
    est.deploy("circuit", yield_floor=pt_floor, yield_confidence=None)
    i = sw.find(dse.assignment_from_kernel_map(est.assignment_))
    assert sw.yield_[i] >= pt_floor
    assert est.mc_state_["yield_confidence"] is None
    # default rule: the Wilson LOWER bound at 95% must clear the floor —
    # a point estimate alone no longer deploys (evidence-backed yield)
    lcbs = [mcstream.wilson_bounds(float(sw.yield_[j]), N_VARIANTS)[0]
            for j in sw.robust_front]
    floor = float(max(lcbs))
    with pytest.raises(ValueError, match="LCB"):
        est.deploy("circuit", yield_floor=pt_floor)  # LCB < point est.
    machine = est.deploy("circuit", yield_floor=floor)
    assert est.assignment_ is not None
    i = sw.find(dse.assignment_from_kernel_map(est.assignment_))
    assert mcstream.wilson_bounds(
        float(sw.yield_[i]), N_VARIANTS)[0] >= floor - 1e-12
    assert est.mc_state_["yield_floor"] == pytest.approx(floor)
    assert est.mc_state_["yield_confidence"] == pytest.approx(0.95)
    # chosen assignment + MC seed/config survive save/load
    path = os.path.join(tmp_path, "m")
    est.save(path)
    est2 = MixedKernelSVM.load(path)
    assert est2.assignment_ == est.assignment_
    assert est2.mc_state_ == est.mc_state_
    np.testing.assert_array_equal(
        est2.deploy_assignment().predict(ds.x_test),
        machine.predict(ds.x_test))
    # the loaded estimator reproduces the exact variant set from the key
    key = np.asarray(est2.mc_state_["key_data"], np.uint32)
    m2 = est2.monte_carlo_machine(est2.mc_state_["n_variants"],
                                  jax.numpy.asarray(key))
    np.testing.assert_array_equal(
        m2.pair_bits(ds.x_test),
        est.monte_carlo_machine(N_VARIANTS,
                                jax.random.PRNGKey(0)).pair_bits(ds.x_test))
    est.assignment_ = None  # restore fixture state


# -- CircuitParams through the public API -------------------------------------


def test_circuit_params_override_and_roundtrip(tmp_path):
    ds = datasets.load("balance")
    circuit = CircuitParams(sigma_vth=6e-3, comparator_sigma=2e-10)
    est = MixedKernelSVM(n_epochs=30, seed=1, circuit=circuit).fit(
        ds.x_train, ds.y_train)
    assert est.hw_.params.sigma_vth == pytest.approx(6e-3)
    base = MixedKernelSVM(n_epochs=30, seed=1)
    # a different process corner calibrates a different instance
    assert not np.array_equal(
        est.hw_.kernel_curve,
        base.fit(ds.x_train, ds.y_train).hw_.kernel_curve)
    path = os.path.join(tmp_path, "m")
    est.save(path)
    est2 = MixedKernelSVM.load(path)
    assert est2.circuit == circuit
    np.testing.assert_array_equal(est2.hw_.kernel_curve,
                                  est.hw_.kernel_curve)
    np.testing.assert_array_equal(
        est2.deploy("circuit").predict(ds.x_test),
        est.deploy("circuit").predict(ds.x_test))
