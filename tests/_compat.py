"""Hypothesis compatibility shim for bare environments.

The tier-1 command (`python -m pytest -x -q`) must collect and run on an
environment without ``hypothesis`` installed.  Property tests use
:func:`property_test` below: under hypothesis they run as real ``@given``
property tests; without it they degrade to a parametrized sweep over a
hand-picked set of representative/edge-case examples.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # bare environment — fixed-example fallback
    HAVE_HYPOTHESIS = False
    given = settings = st = None


def property_test(fixed_examples, strategies=None, max_examples=50):
    """Property-test decorator with a fixed-example fallback.

    ``strategies`` is a callable ``st -> tuple of strategies`` (lazy, so the
    module imports cleanly when hypothesis is absent).  ``fixed_examples`` is
    a list of argument tuples exercised instead when hypothesis is missing.
    """

    def wrap(fn):
        if HAVE_HYPOTHESIS and strategies is not None:
            return settings(max_examples=max_examples, deadline=None)(
                given(*strategies(st))(fn)
            )

        def runner(case):
            fn(*case)

        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        return pytest.mark.parametrize("case", list(fixed_examples))(runner)

    return wrap
