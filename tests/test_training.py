"""Optimizer / train-step tests."""
import jax
import jax.numpy as jnp
import numpy as np

from _compat import property_test

from repro.training import optimizer as opt_mod
from repro.training import step as step_mod


def test_adamw_quadratic_convergence():
    """AdamW minimises a quadratic."""
    oc = opt_mod.AdamWConfig(lr=0.1, warmup_steps=1, total_steps=200,
                             weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = opt_mod.init_state(oc, params)
    target = jnp.asarray([1.0, 1.0])
    for _ in range(150):
        g = {"w": 2 * (params["w"] - target)}
        params, opt = opt_mod.apply_updates(oc, params, opt, g)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


@property_test(
    fixed_examples=[(1, 1e-8), (500, 1e3), (64, 1.0), (100, 1e-3)],
    strategies=lambda st: (st.integers(1, 500), st.floats(1e-8, 1e3)),
    max_examples=20,
)
def test_quant8_roundtrip_multiplicative_bound(n, scale):
    """Log-domain code: multiplicative error bounded per entry."""
    rng = np.random.RandomState(n)
    x = jnp.asarray(rng.randn(n) * scale)
    q = opt_mod.Quant8.encode(x, block=64)
    back = np.asarray(q.decode())
    xs = np.asarray(x)
    nz = np.abs(xs) > 1e-12
    if nz.any():
        ratio = back[nz] / xs[nz]
        assert np.all(ratio > 0), "sign must be preserved"
        # range/127 in log space, range <= log(max)-LOG_TINY ~ 40 -> e^0.33
        assert np.all(ratio < 1.6) and np.all(ratio > 0.6)


def test_quant8_zero_is_exact():
    q = opt_mod.Quant8.encode(jnp.zeros((100,)), block=32)
    assert np.all(np.asarray(q.decode()) == 0.0)


def test_lr_schedule_warmup_and_decay():
    oc = opt_mod.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                             min_lr_frac=0.1)
    lrs = [float(opt_mod.lr_schedule(oc, jnp.int32(s))) for s in range(101)]
    assert lrs[0] < lrs[5] < lrs[9]          # warmup rising
    assert abs(lrs[10] - 1.0) < 0.01         # peak
    assert lrs[50] < lrs[10]                 # decaying
    assert abs(lrs[100] - 0.1) < 0.01        # floor


def test_clip_by_global_norm():
    tree = {"a": jnp.asarray([3.0, 4.0])}     # norm 5
    clipped, norm = step_mod.clip_by_global_norm(tree, 1.0)
    assert abs(float(norm) - 5.0) < 1e-5
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5
    # under the limit: untouched
    same, _ = step_mod.clip_by_global_norm(tree, 10.0)
    np.testing.assert_allclose(np.asarray(same["a"]), [3.0, 4.0])


def test_grad_accum_equals_full_batch():
    """grad_accum=k on batch == single step on the same batch (linear loss
    in batch dim => identical gradients)."""
    from repro import configs
    from repro.models.common import ShardRules
    cfg = configs.get("granite-20b").reduced()
    oc = opt_mod.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    rng = np.random.RandomState(0)
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 17)))
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    outs = []
    for ga in (1, 2):
        state = step_mod.init_train_state(cfg, oc, jax.random.PRNGKey(0))
        ts = jax.jit(step_mod.make_train_step(cfg, ShardRules(), oc,
                                              grad_accum=ga))
        state, m = ts(state, batch)
        outs.append(jax.tree.leaves(state["params"])[4])
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[1]),
                               atol=2e-5)
