"""Algorithm 1 on an LM: separation-driven precision-domain assignment.

The paper's exploration assigns each OvO classifier to the cheapest
hardware domain (analog RBF vs digital linear) that preserves its
accuracy contribution.  DESIGN.md §3 maps this to TPU serving: assign
each MODULE CLASS of a transformer to the cheapest precision domain
(int8 = "analog", bf16/f32 = "digital") that preserves LM loss — using
exactly the same probe-one-module-at-a-time rule
(repro.core.mixed_precision.assign_domains).

  PYTHONPATH=src python examples/precision_domains.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import mixed_precision as mp
from repro.models import transformer as tfm
from repro.models.common import ShardRules


def main():
    cfg = configs.get("qwen2.5-32b").reduced()
    rules = ShardRules()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))

    rng = np.random.RandomState(0)
    batch = {
        "tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 64))),
        "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 64))),
    }

    modules = ["embed", "attn", "mlp", "unembed"]

    def domain_of_path(mods):
        def f(path):
            key = "/".join(path)
            if "attn" in key and mods.get("attn") == "cheap":
                return "cheap"
            if ("wg" in key or "wu" in key or "wd" in key) \
                    and mods.get("mlp") == "cheap":
                return "cheap"
            if path and path[0] == "embed" and mods.get("embed") == "cheap":
                return "cheap"
            if path and path[0] == "unembed" and mods.get("unembed") == "cheap":
                return "cheap"
            return "exact"
        return f

    def quality(mods):
        q = mp.quantize_tree_where(params, domain_of_path(mods))
        deq = jax.tree.map(
            lambda l: l.dequantize(jnp.float32)
            if isinstance(l, mp.QuantTensor) else l, q,
            is_leaf=lambda l: isinstance(l, mp.QuantTensor))
        loss, _ = tfm.forward_train(cfg, deq, batch, rules)
        return -float(loss)

    assign = mp.assign_domains(modules, quality, tolerance=0.002)
    print("module  -> domain      (quality if cheap / exact)")
    for m in modules:
        print(f"{m:8s} -> {assign.domain[m]:6s}  "
              f"({assign.quality_cheap[m]:.4f} / {assign.quality_exact[m]:.4f})")
    print(f"\n{assign.n_cheap}/{len(modules)} module classes go int8 — the "
          f"same separation rule that kept {2}-{3} of 3 OvO classifiers "
          f"linear in the paper's Table II.")


if __name__ == "__main__":
    main()
