"""End-to-end LM training driver (deliverable (b)).

Default preset trains a tiny model in ~a minute on CPU; --preset 100m is
the assignment's "~100M model for a few hundred steps" configuration
(run it on real hardware, or be patient).

  PYTHONPATH=src python examples/train_lm.py                  # tiny
  PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["tiny", "100m"], default="tiny")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="runs/train_lm")
    args = ap.parse_args()

    if args.preset == "tiny":
        argv = ["--arch", "granite-20b", "--reduced",
                "--steps", str(args.steps or 60),
                "--global-batch", "8", "--seq-len", "128",
                "--ckpt-dir", args.ckpt_dir, "--lr", "1e-3"]
    else:  # ~100M params: 12L x 768 x 3072, 50k vocab
        argv = ["--arch", "granite-20b", "--reduced",
                "--d-model", "768", "--d-ff", "3072", "--n-layers", "12",
                "--steps", str(args.steps or 300),
                "--global-batch", "32", "--seq-len", "512",
                "--grad-accum", "4",
                "--ckpt-dir", args.ckpt_dir]
    losses = train_mod.main(argv)
    assert losses[-1] < losses[0], "loss must decrease"
    print("OK: loss decreased", losses[0], "->", losses[-1])


if __name__ == "__main__":
    main()
