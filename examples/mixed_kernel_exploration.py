"""Full paper reproduction in one script: Fig. 4 + Table II + Fig. 5.

  python examples/mixed_kernel_exploration.py      (after `pip install -e .`)
  PYTHONPATH=src python examples/mixed_kernel_exploration.py
"""
import sys

try:
    import repro  # noqa: F401
except ImportError:  # running from a checkout without `pip install -e .`
    sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks import fig4, fig5, table2


def main():
    print("== Fig. 4: analog model fidelity ==")
    fig4.run()
    print("\n== Table II ==")
    table2.run()
    print("\n== Fig. 5: breakdown ==")
    fig5.run()


if __name__ == "__main__":
    main()
