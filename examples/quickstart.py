"""Quickstart: the paper's full flow on Balance Scale in ~30 seconds.

1. load the dataset (exactly regenerated from its published rule),
2. run Algorithm 1 (separation-driven mixed-kernel exploration, with
   hardware-in-the-loop training of the analog-bound classifiers),
3. deploy: linear -> bespoke digital, RBF -> analog behavioral model,
4. report Table-II-style accuracy + area/power.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import hwcost, selection
from repro.data import datasets


def main():
    ds = datasets.load("balance")
    print(f"dataset=balance train={ds.x_train.shape} test={ds.x_test.shape} "
          f"classes={ds.n_classes}")

    res = selection.explore(ds.x_train, ds.y_train, ds.n_classes,
                            n_epochs=120)
    print(f"\nAlgorithm 1 kernel map (per OvO pair): {res.kernel_map}")
    for p in res.pairs:
        print(f"  pair {p.pair}: linear_cv={p.acc_linear:.3f} "
              f"rbf_cv={p.acc_rbf:.3f} -> {p.kernel}")

    cm = hwcost.CostModel()
    print("\ndesign            acc%   area mm^2   power mW")
    for name, sys_ in [("all-linear (dig)", res.linear_circuit),
                       ("all-RBF (dig)", res.rbf_circuit),
                       ("mixed (ours)", res.mixed_circuit)]:
        acc = 100 * sys_.accuracy(ds.x_test, ds.y_test)
        c = hwcost.system_cost(sys_, cm)
        print(f"{name:16s}  {acc:5.1f}   {c.area_mm2:9.4f}   {c.power_mw:8.4f}")

    mix = hwcost.system_cost(res.mixed_circuit, cm)
    rbf = hwcost.system_cost(res.rbf_circuit, cm)
    print(f"\nmixed vs digital-RBF: {rbf.area_mm2 / mix.area_mm2:.0f}x area, "
          f"{rbf.power_mw / mix.power_mw:.0f}x power  "
          f"(paper: 108x / 17x averages)")


if __name__ == "__main__":
    main()
