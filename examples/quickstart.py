"""Quickstart: the paper's full flow on Balance Scale in ~30 seconds.

1. load the dataset (exactly regenerated from its published rule),
2. fit a MixedKernelSVM: Algorithm 1 (separation-driven mixed-kernel
   exploration, with hardware-in-the-loop training of the analog-bound
   classifiers),
3. deploy: linear -> bespoke digital, RBF -> analog behavioral model,
   compiled to ONE batched JAX inference path,
4. report Table-II-style accuracy + area/power, and round-trip the trained
   machine through save/load without retraining.

  python examples/quickstart.py            (after `pip install -e .`)
  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile

try:
    import repro  # noqa: F401
except ImportError:  # running from a checkout without `pip install -e .`
    sys.path.insert(0, "src")

from repro.api import MixedKernelSVM
from repro.core import hwcost
from repro.data import datasets


def main():
    ds = datasets.load("balance")
    print(f"dataset=balance train={ds.x_train.shape} test={ds.x_test.shape} "
          f"classes={ds.n_classes}")

    est = MixedKernelSVM(n_epochs=120).fit(ds.x_train, ds.y_train)
    print(f"\nAlgorithm 1 kernel map (per OvO pair): {est.kernel_map_}")
    for p in est.pairs_:
        print(f"  pair {p.pair}: linear_cv={p.acc_linear:.3f} "
              f"rbf_cv={p.acc_rbf:.3f} -> {p.kernel}")

    cm = hwcost.CostModel()
    print("\ndesign            acc%   area mm^2   power mW")
    for name, target in [("all-linear (dig)", "linear"),
                         ("all-RBF (dig)", "rbf"),
                         ("mixed (ours)", "circuit")]:
        acc = 100 * est.score(ds.x_test, ds.y_test, target=target)
        c = hwcost.system_cost(est.bank(target), cm)
        print(f"{name:16s}  {acc:5.1f}   {c.area_mm2:9.4f}   {c.power_mw:8.4f}")

    mix = hwcost.system_cost(est.bank("circuit"), cm)
    rbf = hwcost.system_cost(est.bank("rbf"), cm)
    print(f"\nmixed vs digital-RBF: {rbf.area_mm2 / mix.area_mm2:.0f}x area, "
          f"{rbf.power_mw / mix.power_mw:.0f}x power  "
          f"(paper: 108x / 17x averages)")

    # The deployed machine is ONE compiled artifact: a single jit-compiled
    # batched predict, and it serializes without retraining.
    machine = est.deploy("circuit")
    print(f"\n{machine.describe()}")
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "balance_machine")
        est.save(path)
        est2 = MixedKernelSVM.load(path)
        same = (est2.predict(ds.x_test, target="circuit")
                == machine.predict(ds.x_test)).all()
        print(f"save/load round-trip predictions identical: {bool(same)}")


if __name__ == "__main__":
    main()
