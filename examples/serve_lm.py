"""Batched serving example across architecture families (deliverable (b)).

Prefills a batch of prompts and decodes with sampling, for a dense, an
SSM, and the hybrid arch — exercising full caches, recurrent states and
SWA ring buffers on CPU.

  PYTHONPATH=src python examples/serve_lm.py
"""
import sys

sys.path.insert(0, "src")

from repro.launch import serve as serve_mod


def main():
    for arch in ("granite-20b", "mamba2-2.7b", "hymba-1.5b"):
        print(f"\n=== {arch} (reduced) ===")
        serve_mod.main(["--arch", arch, "--reduced", "--batch", "4",
                        "--prompt-len", "24", "--gen", "16"])


if __name__ == "__main__":
    main()
