"""Pallas TPU kernel for the Mamba2 SSD (state-space duality) chunked scan.

The SSD decomposition (Dao & Gu, 2024) splits the linear recurrence

    S_t = exp(a_t) S_{t-1} + x_t B_t^T,      y_t = S_t C_t

into chunks of length L: within a chunk the output is a *masked matmul*
(quadratic in L — MXU work), and across chunks only the (dh x ds) state is
carried.  This is the TPU-native form: the sequential dependency collapses
from S steps to S/L steps, and each chunk is dense matmul work.

Grid: (batch*heads, n_chunks), chunk axis sequential; the running state
lives in VMEM scratch persisted across chunk iterations (re-initialised at
chunk 0).  The final state is emitted for serving (prefill -> decode
handoff).

Within a chunk (cum = inclusive cumsum of a):
    y_intra = ((C B^T) * decay) @ x        decay[t,j] = exp(cum_t - cum_j), j<=t
    y_inter = (C * exp(cum)) @ S_prev^T
    S_new   = exp(cum_L) S_prev + x^T @ (B * exp(cum_L - cum))
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, sfin_ref, state_scr,
                *, chunk: int):
    ci = pl.program_id(1)
    n_chunks = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)          # (L, dh)
    a = a_ref[0].astype(jnp.float32)          # (L,)
    bmat = b_ref[0].astype(jnp.float32)       # (L, ds)
    cmat = c_ref[0].astype(jnp.float32)       # (L, ds)

    cum = jnp.cumsum(a)                        # inclusive
    total = cum[-1]

    # --- intra-chunk (quadratic, MXU) ---
    g = jax.lax.dot_general(                   # C @ B^T : (L, L)
        cmat, bmat, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    j_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = j_idx <= t_idx
    logdecay = cum[:, None] - cum[None, :]     # cum_t - cum_j
    decay = jnp.where(causal, jnp.exp(jnp.minimum(logdecay, 0.0)), 0.0)
    y_intra = jax.lax.dot(
        (g * decay).astype(jnp.float32), x, preferred_element_type=jnp.float32
    )                                          # (L, dh)

    # --- inter-chunk (carried state) ---
    s_prev = state_scr[...]                    # (dh, ds)
    y_inter = jax.lax.dot_general(             # (L, dh)
        cmat * jnp.exp(cum)[:, None], s_prev,
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    )
    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # --- state update ---
    w = jnp.exp(total - cum)[:, None]          # (L, 1)
    s_new = jnp.exp(total) * s_prev + jax.lax.dot_general(
        x, bmat * w, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                          # (dh, ds)
    state_scr[...] = s_new

    @pl.when(ci == n_chunks - 1)
    def _emit():
        sfin_ref[0] = s_new.astype(sfin_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(
    x: jnp.ndarray,       # (bh, s, dh)  — batch*heads flattened
    a: jnp.ndarray,       # (bh, s)
    bmat: jnp.ndarray,    # (bh, s, ds)  — already group-expanded to heads
    cmat: jnp.ndarray,    # (bh, s, ds)
    chunk: int = 128,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y: (bh, s, dh), final_state: (bh, dh, ds))."""
    bh, s, dh = x.shape
    ds = bmat.shape[-1]
    assert s % chunk == 0, "pad sequence to a chunk multiple upstream"
    grid = (bh, s // chunk)

    y, sfin = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
            pl.BlockSpec((1, chunk, ds), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, ds), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, dh, ds), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, dh), x.dtype),
            jax.ShapeDtypeStruct((bh, dh, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dh, ds), jnp.float32)],
        interpret=interpret,
    )(x, a, bmat, cmat)
    return y, sfin
