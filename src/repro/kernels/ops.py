"""Public jit'd entry points for the Pallas kernels, with automatic
interpret-mode dispatch (CPU containers run the kernel bodies in the
Pallas interpreter; on TPU the same calls compile to Mosaic).

Each op has a ``ref`` twin in repro.kernels.ref used for validation and
as the default in dry-run lowering (DESIGN.md: roofline terms are derived
from the jnp path so HLO cost analysis reflects the algorithm, while the
Pallas path is validated for numerics separately).
"""
from __future__ import annotations

import jax

from repro.kernels import ref  # noqa: F401  (re-exported oracle)
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.rbf import kernel_matrix_pallas as _rbf
from repro.kernels.solver import dual_ascent_lanes_pallas as _solver
from repro.kernels.ssd import ssd_scan_pallas as _ssd


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def rbf_matrix(x, z, gamma, kind: str = "rbf", interpret: bool | None = None,
               **kw):
    """Tiled RBF / sech2 kernel matrix (paper hot loop)."""
    if interpret is None:
        interpret = _interpret_default()
    return _rbf(x, z, gamma, kind=kind, interpret=interpret, **kw)


def flash_attention(q, k, v, causal=True, window=None, q_offset=0,
                    interpret: bool | None = None, **kw):
    """Online-softmax attention; GQA via index maps; O(S*W) for windows."""
    if interpret is None:
        interpret = _interpret_default()
    return _flash(q, k, v, causal=causal, window=window, q_offset=q_offset,
                  interpret=interpret, **kw)


def solve_lanes(x, y, c_box, gamma, kind: str = "rbf", n_epochs: int = 200,
                block: int = 16, interpret: bool | None = None, **kw):
    """Fused dual-coordinate-ascent over (pair, gamma, C-lane) solver
    lanes with on-the-fly Gram tiles -> (alpha, f), each (P, G, L, n)."""
    if interpret is None:
        interpret = _interpret_default()
    return _solver(x, y, c_box, gamma, kind=kind, n_epochs=n_epochs,
                   block=block, interpret=interpret, **kw)


def ssd_scan(x, a, bmat, cmat, chunk: int = 128,
             interpret: bool | None = None):
    """Chunked Mamba2 SSD scan -> (y, final_state)."""
    if interpret is None:
        interpret = _interpret_default()
    return _ssd(x, a, bmat, cmat, chunk=chunk, interpret=interpret)
