"""Pure-jnp oracles for every Pallas kernel (the `ref.py` contract).

These are the semantic ground truth: simple, obviously-correct
implementations used by tests (assert_allclose vs the kernels in
interpret mode) and as the fallback compute path on platforms without
Pallas support.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# RBF kernel matrix (the paper's hot loop)
# ---------------------------------------------------------------------------


def rbf_matrix(x: jnp.ndarray, z: jnp.ndarray, gamma: float) -> jnp.ndarray:
    """K[i, j] = exp(-gamma * ||x_i - z_j||^2);  x: (n, d), z: (m, d)."""
    d2 = (
        jnp.sum(x * x, -1)[:, None]
        + jnp.sum(z * z, -1)[None, :]
        - 2.0 * (x @ z.T)
    )
    return jnp.exp(-gamma * jnp.maximum(d2, 0.0))


def sech2_matrix(
    x: jnp.ndarray, z: jnp.ndarray, gamma: float,
    n_slope: float = 1.38, v_t: float = 0.02585, v_scale: float = 0.5,
) -> jnp.ndarray:
    """Hardware separable kernel (Eq. 6): product of per-dim sech2 cells."""
    gamma0 = 1.0 / (4.0 * n_slope**2 * v_t**2) * v_scale**2
    s = jnp.sqrt(gamma / gamma0)
    dv = v_scale * s * (x[:, None, :] - z[None, :, :]) / (n_slope * v_t)
    cell = 4.0 / ((1.0 + jnp.exp(-dv)) * (1.0 + jnp.exp(dv)))
    return jnp.prod(cell, axis=-1)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def attention(
    q: jnp.ndarray,          # (b, hq, sq, dh)
    k: jnp.ndarray,          # (b, hkv, skv, dh)
    v: jnp.ndarray,          # (b, hkv, skv, dh)
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Plain GQA attention with optional causal/sliding-window masking.

    ``q_offset`` positions the query block within the kv sequence (for
    decode: sq == 1, q_offset == cache length - 1).
    """
    b, hq, sq, dh = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    qg = q.reshape(b, hkv, group, sq, dh)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k) / jnp.sqrt(float(dh))
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((sq, k.shape[2]), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, v)
    return out.reshape(b, hq, sq, dh)


# ---------------------------------------------------------------------------
# Mamba2 SSD (state-space duality), chunk-free sequential reference
# ---------------------------------------------------------------------------


def ssd(
    x: jnp.ndarray,      # (b, s, h, dh)     inputs (already gated/projected)
    a: jnp.ndarray,      # (b, s, h)         log-decay per step (a = -softplus)
    bmat: jnp.ndarray,   # (b, s, g, ds)     input->state projection ("B")
    cmat: jnp.ndarray,   # (b, s, g, ds)     state->output projection ("C")
    init_state: jnp.ndarray | None = None,  # (b, h, dh, ds)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential scan reference of SSD:  S_t = exp(a_t) S_{t-1} + x_t B_t^T.

    Heads h are grouped over state groups g (h % g == 0), mirroring GQA.
    Returns (y, final_state) with y: (b, s, h, dh).
    """
    b, s, h, dh = x.shape
    g = bmat.shape[2]
    rep = h // g
    bm = jnp.repeat(bmat, rep, axis=2)  # (b, s, h, ds)
    cm = jnp.repeat(cmat, rep, axis=2)
    ds = bm.shape[-1]
    s0 = init_state if init_state is not None else jnp.zeros((b, h, dh, ds), x.dtype)

    def step(state, t):
        xt, at, bt, ct = t
        state = jnp.exp(at)[..., None, None] * state + xt[..., None] * bt[:, :, None, :]
        yt = jnp.einsum("bhds,bhs->bhd", state, ct)
        return state, yt

    xs = (
        jnp.moveaxis(x, 1, 0), jnp.moveaxis(a, 1, 0),
        jnp.moveaxis(bm, 1, 0), jnp.moveaxis(cm, 1, 0),
    )
    final, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1), final
