"""Pure-jnp oracles for every Pallas kernel (the `ref.py` contract).

These are the semantic ground truth: simple, obviously-correct
implementations used by tests (assert_allclose vs the kernels in
interpret mode) and as the fallback compute path on platforms without
Pallas support.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# RBF kernel matrix (the paper's hot loop)
# ---------------------------------------------------------------------------


def rbf_matrix(x: jnp.ndarray, z: jnp.ndarray, gamma: float) -> jnp.ndarray:
    """K[i, j] = exp(-gamma * ||x_i - z_j||^2);  x: (n, d), z: (m, d)."""
    d2 = (
        jnp.sum(x * x, -1)[:, None]
        + jnp.sum(z * z, -1)[None, :]
        - 2.0 * (x @ z.T)
    )
    return jnp.exp(-gamma * jnp.maximum(d2, 0.0))


def sech2_matrix(
    x: jnp.ndarray, z: jnp.ndarray, gamma: float,
    n_slope: float = 1.38, v_t: float = 0.02585, v_scale: float = 0.5,
) -> jnp.ndarray:
    """Hardware separable kernel (Eq. 6): product of per-dim sech2 cells."""
    gamma0 = 1.0 / (4.0 * n_slope**2 * v_t**2) * v_scale**2
    s = jnp.sqrt(gamma / gamma0)
    dv = v_scale * s * (x[:, None, :] - z[None, :, :]) / (n_slope * v_t)
    cell = 4.0 / ((1.0 + jnp.exp(-dv)) * (1.0 + jnp.exp(dv)))
    return jnp.prod(cell, axis=-1)


# ---------------------------------------------------------------------------
# Dual coordinate ascent over solver lanes (the training hot loop)
# ---------------------------------------------------------------------------


def dual_ascent_blocked(
    kp: jnp.ndarray,      # (n, n) Gram WITH bias folded in (K + 1)
    y: jnp.ndarray,       # (n,) labels in {-1, +1}
    c_box: jnp.ndarray,   # (n,) per-sample box (0 masks a sample out)
    n_epochs: int,
    block: int = 16,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Blocked Gauss-Seidel dual ascent on a MATERIALIZED Gram matrix.

    The semantic twin of the fused Pallas solver
    (``repro.kernels.solver.dual_ascent_lanes_pallas``): the coordinate
    update sequence is identical to
    ``repro.core.trainer.dual_coordinate_ascent_blocked`` — same block
    visit order, fresh per-block margins via one GEMM — which stays the
    oracle of record.  Returns ``(alpha, f)`` with the final margins
    ``f = K' @ (alpha * y)`` appended (the Pallas kernel emits both).
    """
    n = kp.shape[0]
    block = int(min(block, n))
    n_pad = -(-n // block) * block
    if n_pad != n:
        kp = jnp.pad(kp, ((0, n_pad - n), (0, n_pad - n)))
        y = jnp.pad(y, (0, n_pad - n), constant_values=1.0)
        c_box = jnp.pad(c_box, (0, n_pad - n))
    qdiag = jnp.clip(jnp.diag(kp), 1e-12, None)
    n_blocks = n_pad // block

    def block_body(b, alpha):
        j0 = b * block
        rows = jax.lax.dynamic_slice(kp, (j0, 0), (block, n_pad))
        kbb = jax.lax.dynamic_slice(rows, (0, j0), (block, block))
        yb = jax.lax.dynamic_slice(y, (j0,), (block,))
        cb = jax.lax.dynamic_slice(c_box, (j0,), (block,))
        qb = jax.lax.dynamic_slice(qdiag, (j0,), (block,))
        ab = jax.lax.dynamic_slice(alpha, (j0,), (block,))
        fb = rows @ (alpha * y)

        def coord(i, carry):
            ab, fb = carry
            g = 1.0 - yb[i] * fb[i]
            a_new = jnp.clip(ab[i] + g / qb[i], 0.0, cb[i])
            d = a_new - ab[i]
            fb = fb + d * yb[i] * kbb[:, i]
            return ab.at[i].set(a_new), fb

        ab, _ = jax.lax.fori_loop(0, block, coord, (ab, fb))
        return jax.lax.dynamic_update_slice(alpha, ab, (j0,))

    def epoch(_, alpha):
        return jax.lax.fori_loop(0, n_blocks, block_body, alpha)

    alpha = jax.lax.fori_loop(0, n_epochs, epoch,
                              jnp.zeros((n_pad,), kp.dtype))
    f = kp @ (alpha * y)
    return alpha[:n], f[:n]


def solve_lanes(
    x: jnp.ndarray,       # (P, n, d) per-pair inputs
    y: jnp.ndarray,       # (P, n)
    c_box: jnp.ndarray,   # (P, L, n) gamma-independent box lanes
    gamma: jnp.ndarray,   # (P, G)
    kind: str = "rbf",
    n_epochs: int = 200,
    block: int = 16,
    n_slope: float = 1.38,
    v_t: float = 0.02585,
    v_scale: float = 1.0,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Pure-jnp lanes oracle: materialized per-(pair, gamma) Gram + the
    blocked update sequence, vmapped over (P, G, L).  Returns ``(alpha,
    f)``, each (P, G, L, n) — exactly the fused solver's outputs, with
    the Gram built once per (pair, gamma) and shared across the C x fold
    lanes that close over it (the XLA baseline the Pallas kernel trades
    HBM traffic against)."""

    def kmat(xp, g):
        if kind == "linear":
            k = xp @ xp.T
        elif kind == "rbf":
            k = rbf_matrix(xp, xp, g)
        elif kind == "sech2":
            k = sech2_matrix(xp, xp, g, n_slope, v_t, v_scale)
        else:
            raise ValueError(f"no lanes oracle for kernel kind {kind!r}")
        return k + 1.0  # bias-as-feature

    def per_pair(xp, yp, cl, gg):
        def per_gamma(g):
            kp = kmat(xp, g)
            return jax.vmap(
                lambda cb: dual_ascent_blocked(kp, yp, cb, n_epochs, block)
            )(cl)
        return jax.vmap(per_gamma)(gg)

    return jax.vmap(per_pair)(x, y, c_box, gamma)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def attention(
    q: jnp.ndarray,          # (b, hq, sq, dh)
    k: jnp.ndarray,          # (b, hkv, skv, dh)
    v: jnp.ndarray,          # (b, hkv, skv, dh)
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Plain GQA attention with optional causal/sliding-window masking.

    ``q_offset`` positions the query block within the kv sequence (for
    decode: sq == 1, q_offset == cache length - 1).
    """
    b, hq, sq, dh = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    qg = q.reshape(b, hkv, group, sq, dh)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k) / jnp.sqrt(float(dh))
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((sq, k.shape[2]), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, v)
    return out.reshape(b, hq, sq, dh)


# ---------------------------------------------------------------------------
# Mamba2 SSD (state-space duality), chunk-free sequential reference
# ---------------------------------------------------------------------------


def ssd(
    x: jnp.ndarray,      # (b, s, h, dh)     inputs (already gated/projected)
    a: jnp.ndarray,      # (b, s, h)         log-decay per step (a = -softplus)
    bmat: jnp.ndarray,   # (b, s, g, ds)     input->state projection ("B")
    cmat: jnp.ndarray,   # (b, s, g, ds)     state->output projection ("C")
    init_state: jnp.ndarray | None = None,  # (b, h, dh, ds)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential scan reference of SSD:  S_t = exp(a_t) S_{t-1} + x_t B_t^T.

    Heads h are grouped over state groups g (h % g == 0), mirroring GQA.
    Returns (y, final_state) with y: (b, s, h, dh).
    """
    b, s, h, dh = x.shape
    g = bmat.shape[2]
    rep = h // g
    bm = jnp.repeat(bmat, rep, axis=2)  # (b, s, h, ds)
    cm = jnp.repeat(cmat, rep, axis=2)
    ds = bm.shape[-1]
    s0 = init_state if init_state is not None else jnp.zeros((b, h, dh, ds), x.dtype)

    def step(state, t):
        xt, at, bt, ct = t
        state = jnp.exp(at)[..., None, None] * state + xt[..., None] * bt[:, :, None, :]
        yt = jnp.einsum("bhds,bhs->bhd", state, ct)
        return state, yt

    xs = (
        jnp.moveaxis(x, 1, 0), jnp.moveaxis(a, 1, 0),
        jnp.moveaxis(bm, 1, 0), jnp.moveaxis(cm, 1, 0),
    )
    final, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1), final
