"""Pallas TPU kernels for the compute hot-spots (+ pure-jnp oracles).

  rbf.py              paper hot loop: tiled RBF / sech2 kernel matrix (MXU)
  solver.py           fused dual-coordinate-ascent training solver: lane-
                      resident state + on-the-fly Gram tiles (DESIGN.md §7)
  flash_attention.py  online-softmax attention, causal/sliding-window, GQA
  ssd.py              Mamba2 SSD chunked scan
  ops.py              jit'd wrappers w/ interpret-mode dispatch
  ref.py              pure-jnp oracles (ground truth for tests)
"""
from repro.kernels import ops, ref  # noqa: F401
