"""Pallas TPU flash attention (online-softmax, causal + sliding window).

Standard 3-axis grid (batch*head, q_blocks, kv_blocks) with the kv axis
innermost and sequential; running max / denominator / accumulator live in
VMEM scratch that persists across kv iterations.  GQA is handled in the
index maps (kv head = q head // group) so K/V are never materialized per
q-head.  Fully-masked kv blocks are skipped with ``pl.when`` — for sliding
window attention this is what makes long-context cost O(S*W) instead of
O(S^2).

The S x S score matrix never exists in HBM: one (bq, bk) tile of logits
lives in VMEM per iteration — this is the memory-roofline win over naive
attention; FLOPs are unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref,
    m_scr, l_scr, acc_scr,
    *, bq: int, bk: int, n_kv_blocks: int, kv_len: int,
    causal: bool, window: int | None, q_offset: int, scale: float,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # block-level skip: can any (qpos, kpos) in this tile be unmasked?
    q_lo = qi * bq + q_offset
    q_hi = q_lo + bq - 1
    k_lo = ki * bk
    k_hi = k_lo + bk - 1
    live = k_lo < kv_len        # padded kv blocks are fully dead
    if causal:
        live &= k_lo <= q_hi
    if window is not None:
        live &= k_hi > q_lo - window

    @pl.when(live)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, dh)
        k = k_ref[0].astype(jnp.float32)                  # (bk, dh)
        v = v_ref[0].astype(jnp.float32)                  # (bk, dh)
        logits = jax.lax.dot_general(                     # (bq, bk) on MXU
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < kv_len    # kv padding
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_scr[...]                               # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(logits, -1, keepdims=True))
        p = jnp.exp(logits - m_new) * mask                # zero masked lanes
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, -1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "bq", "bk", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,            # (b, hq, sq, dh)
    k: jnp.ndarray,            # (b, hkv, skv, dh)
    v: jnp.ndarray,            # (b, hkv, skv, dh)
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    b, hq, sq, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = 1.0 / float(dh) ** 0.5

    # flatten heads into the leading grid axis
    qf = q.reshape(b * hq, sq, dh)
    kf = k.reshape(b * hkv, skv, dh)
    vf = v.reshape(b * hkv, skv, dh)

    sq_p = -(-sq // bq) * bq
    skv_p = -(-skv // bk) * bk
    if sq_p != sq:
        qf = jnp.pad(qf, ((0, 0), (0, sq_p - sq), (0, 0)))
    if skv_p != skv:
        # padded kv keys sit at positions >= skv; causal masking with
        # q_offset < skv keeps them dead as long as padding >= real span.
        kf = jnp.pad(kf, ((0, 0), (0, skv_p - skv), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, skv_p - skv), (0, 0)))

    n_kv_blocks = skv_p // bk
    grid = (b * hq, sq_p // bq, n_kv_blocks)

    kernel = functools.partial(
        _flash_kernel,
        bq=bq, bk=bk, n_kv_blocks=n_kv_blocks, kv_len=skv,
        causal=causal, window=window, q_offset=q_offset, scale=scale,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, dh),
                         lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
            pl.BlockSpec((1, bk, dh),
                         lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq_p, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running denominator
            pltpu.VMEM((bq, dh), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out[:, :sq, :].reshape(b, hq, sq, dh)
