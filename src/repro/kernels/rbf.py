"""Pallas TPU kernel for the RBF kernel-matrix hot loop (DESIGN.md §3.1).

The paper's analog circuit evaluates K(x, s) = exp(-gamma ||x - s||^2) one
support vector at a time via cascaded current-mode cells.  On TPU the same
separable kernel is restructured so the dominant term is an MXU matmul:

    ||x - z||^2 = ||x||^2 + ||z||^2 - 2 x . z

An (bm x bn) tile of K plus its (bm x d) / (bn x d) operand slabs live in
VMEM; the exp (VPU) fuses into the same kernel so K never round-trips to
HBM between the distance and the nonlinearity.  The hardware sech2 variant
(`sech2_mm`) evaluates the cascaded-pair transfer exactly (Eq. 4) in
log-space, accumulated across dimensions (Eq. 6) — blocking replaces the
analog current chain.

Grid: (n/bm, m/bn); each program writes one output tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def linear_tile(x: jnp.ndarray, z: jnp.ndarray, gamma=None) -> jnp.ndarray:
    """One (bm, bn) linear-kernel tile x @ z.T on the MXU; gamma ignored."""
    return jax.lax.dot_general(
        x, z, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )


def rbf_tile(x: jnp.ndarray, z: jnp.ndarray, gamma) -> jnp.ndarray:
    """One (bm, bn) RBF tile: distance via MXU matmul + fused exp.

    Pure-value tile body shared by the kernel-matrix grid below and the
    fused training solver (``repro.kernels.solver``), which evaluates Gram
    tiles on the fly instead of materializing the full matrix.
    """
    xx = jnp.sum(x * x, axis=-1, keepdims=True)          # (bm, 1)
    zz = jnp.sum(z * z, axis=-1, keepdims=True).T        # (1, bn)
    xz = linear_tile(x, z)                               # MXU
    d2 = jnp.maximum(xx + zz - 2.0 * xz, 0.0)
    return jnp.exp(-gamma * d2)


def sech2_tile(x: jnp.ndarray, z: jnp.ndarray, gamma, *,
               n_slope: float, v_t: float, v_scale: float) -> jnp.ndarray:
    """One (bm, bn) tile of the hardware kernel: log-space product (Eq. 6)."""
    gamma0 = 1.0 / (4.0 * n_slope**2 * v_t**2) * v_scale**2
    s = jnp.sqrt(gamma / gamma0) * v_scale / (n_slope * v_t)
    acc = jnp.zeros((x.shape[0], z.shape[0]), jnp.float32)
    for k in range(x.shape[1]):  # d <= 5 in the paper's hardware; unrolled
        dv = (x[:, k:k + 1] - z[:, k:k + 1].T) * s
        # log cell = log 4 - log(1+e^-dv) - log(1+e^dv); stable softplus form
        acc += jnp.log(4.0) - jax.nn.softplus(-dv) - jax.nn.softplus(dv)
    return jnp.exp(acc)


def tile_body(kind: str, n_slope: float = 1.38, v_t: float = 0.02585,
              v_scale: float = 1.0):
    """Resolve a pure-value tile body ``(x, z, gamma) -> (bm, bn)``.

    The shared dispatch for every consumer of the fused tile math: the
    kernel-matrix grid here and the dual-ascent solver grid
    (``repro.kernels.solver``).  Note the v_scale default of 1.0 matches
    ``core.kernels.sech2_kernel`` (feature-unit gamma); the kernel-matrix
    entry point below keeps its historical 0.5 default.
    """
    if kind == "linear":
        return linear_tile
    if kind == "rbf":
        return rbf_tile
    if kind == "sech2":
        return functools.partial(sech2_tile, n_slope=n_slope, v_t=v_t,
                                 v_scale=v_scale)
    raise ValueError(f"no tile body for kernel kind {kind!r}")


def _rbf_kernel(x_ref, z_ref, g_ref, o_ref):
    """One (bm, bn) tile: distance via MXU matmul + fused exp."""
    x = x_ref[...].astype(jnp.float32)          # (bm, d)
    z = z_ref[...].astype(jnp.float32)          # (bn, d)
    o_ref[...] = rbf_tile(x, z, g_ref[0]).astype(o_ref.dtype)


def _sech2_kernel(x_ref, z_ref, g_ref, o_ref, *,
                  n_slope: float, v_t: float, v_scale: float):
    """One (bm, bn) tile of the hardware kernel: log-space product (Eq. 6)."""
    x = x_ref[...].astype(jnp.float32)
    z = z_ref[...].astype(jnp.float32)
    o_ref[...] = sech2_tile(x, z, g_ref[0], n_slope=n_slope, v_t=v_t,
                            v_scale=v_scale).astype(o_ref.dtype)


def _pad_to(a: jnp.ndarray, mult: int, axis: int) -> jnp.ndarray:
    rem = a.shape[axis] % mult
    if rem == 0:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, mult - rem)
    return jnp.pad(a, pad)


@functools.partial(
    jax.jit,
    static_argnames=("kind", "bm", "bn", "interpret", "n_slope", "v_t", "v_scale"),
)
def kernel_matrix_pallas(
    x: jnp.ndarray,           # (n, d)
    z: jnp.ndarray,           # (m, d)
    gamma,
    kind: str = "rbf",        # 'rbf' | 'sech2'
    bm: int = 128,
    bn: int = 128,
    interpret: bool = True,
    n_slope: float = 1.38,
    v_t: float = 0.02585,
    v_scale: float = 0.5,
) -> jnp.ndarray:
    """Tiled kernel matrix K: (n, m).  Pads to block multiples, slices back."""
    n, d = x.shape
    m = z.shape[0]
    xp = _pad_to(x, bm, 0)
    zp = _pad_to(z, bn, 0)
    g = jnp.reshape(jnp.asarray(gamma, jnp.float32), (1,))
    grid = (xp.shape[0] // bm, zp.shape[0] // bn)

    if kind == "rbf":
        body = _rbf_kernel
    elif kind == "sech2":
        body = functools.partial(
            _sech2_kernel, n_slope=n_slope, v_t=v_t, v_scale=v_scale
        )
    else:
        raise ValueError(kind)

    out = pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec(memory_space=pl.ANY),   # gamma: tiny, whole array
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], zp.shape[0]), jnp.float32),
        interpret=interpret,
    )(xp, zp, g)
    return out[:n, :m]
