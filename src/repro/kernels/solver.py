"""Fused Pallas training solver: lane-resident dual coordinate ascent with
on-the-fly Gram tiles (DESIGN.md §7).

Algorithm 1's compute is ``dual_coordinate_ascent_blocked`` swept over all
solver *lanes* — OvO pair x CV fold x (C, gamma) grid cell.  Under the
XLA vmap formulation every lane's (n_max, n_max) Gram matrix K' is
materialized in HBM and its row blocks are re-read once per block per
epoch, so the sweep is HBM-bound.  This kernel inverts the trade: the
grid iterates over lanes, each program keeps its lane's state — ``alpha``,
the (n_max, d) inputs, labels and C-box — resident in VMEM for the whole
epoch loop, and *recomputes* each (block, n_max) Gram row slab on the fly
from the inputs with the very same tile bodies the kernel-matrix grid
uses (``repro.kernels.rbf.tile_body``).  The (lanes, n_max, n_max) Gram
tensor is never materialized anywhere: O(n^2) HBM traffic per lane-epoch
becomes O(n*d) VMEM-resident FLOPs, a trade that favors compute-rich
hardware by orders of magnitude for the paper's d <= 32 workloads.

Update-sequence contract
------------------------
The coordinate update sequence is IDENTICAL to
``repro.core.trainer.dual_coordinate_ascent_blocked`` (the oracle): same
block visit order, fresh per-block margins from one GEMM against the
current alphas, Gauss-Seidel inside the block against the diagonal
(block, block) tile.  Only the Gram values' provenance differs (tile
recompute vs materialized matrix), so alphas agree to f32 round-off.
Masked samples (``c_box = 0``) remain exact no-ops, which keeps trailing
padding rows inert exactly as in the blocked solver.

Lane layout
-----------
``x (P, n, d)`` / ``y (P, n)`` are per-*pair*; ``gamma (P, G)`` spans the
width grid; ``c_box (P, L, n)`` spans the gamma-independent C x fold
lanes (the box already folds the train-mask and validity in).  The grid
is ``(P, G, L)`` — row-major iteration revisits the same pair block for
all its (G, L) lanes, so Pallas's pipelining keeps the pair inputs hot.
Outputs are ``alpha (P, G, L, n)`` and the final margins ``f (P, G, L,
n)`` (``f_j = sum_i K'_ji alpha_i y_i``), computed by one extra fused
pass over the row slabs so CV validation never needs the Gram either.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.rbf import tile_body

#: Coordinate-block size; matches ``repro.core.trainer.SOLVER_BLOCK``.
DEFAULT_BLOCK = 16


def _solver_kernel(x_ref, y_ref, c_ref, g_ref, alpha_ref, f_ref, *,
                   block: int, n_epochs: int, tile):
    """One lane: full dual-coordinate-ascent epoch loop, VMEM-resident."""
    x = x_ref[0]                      # (n_pad, d)
    yv = y_ref[...]                   # (1, n_pad)
    cv = c_ref[0]                     # (1, n_pad)
    gamma = g_ref[0, 0]
    n_pad, d = x.shape
    n_blocks = n_pad // block

    def rows_at(j0):
        """Fresh (block, n_pad) Gram row slab K'[j0:j0+block, :] + bias."""
        xb = jax.lax.dynamic_slice(x, (j0, 0), (block, d))
        return tile(xb, x, gamma) + 1.0          # bias-as-feature

    def block_body(b, alpha):
        j0 = b * block
        rows = rows_at(j0)
        kbb = jax.lax.dynamic_slice(rows, (0, j0), (block, block))
        yb = jax.lax.dynamic_slice(yv, (0, j0), (1, block))
        cb = jax.lax.dynamic_slice(cv, (0, j0), (1, block))
        # The oracle's qdiag values: K'(x_i, x_i), same tile math.
        qb = jnp.clip(jnp.diagonal(kbb), 1e-12, None)
        ab = jax.lax.dynamic_slice(alpha, (0, j0), (1, block))
        # Fresh block margins from the current alphas: ONE (1, n) x
        # (block, n)^T contraction — the blocked oracle's `rows @ (a*y)`.
        fb = jax.lax.dot_general(
            alpha * yv, rows, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)  # (1, block)

        def coord(i, carry):
            ab, fb = carry
            g = 1.0 - yb[0, i] * fb[0, i]
            a_new = jnp.clip(ab[0, i] + g / qb[i], 0.0, cb[0, i])
            dlt = a_new - ab[0, i]
            col = jax.lax.dynamic_slice(kbb, (0, i), (block, 1))
            fb = fb + dlt * yb[0, i] * col.reshape(1, block)
            ab = jax.lax.dynamic_update_slice(
                ab, a_new.reshape(1, 1), (0, i))
            return ab, fb

        ab, _ = jax.lax.fori_loop(0, block, coord, (ab, fb))
        return jax.lax.dynamic_update_slice(alpha, ab, (0, j0))

    def epoch(_, alpha):
        return jax.lax.fori_loop(0, n_blocks, block_body, alpha)

    alpha = jax.lax.fori_loop(0, n_epochs, epoch,
                              jnp.zeros((1, n_pad), jnp.float32))

    # Final margins f = K' @ (alpha * y), one more fused pass over the
    # row slabs — CV validation consumes f directly, Gram-free.
    ay = alpha * yv

    def final_block(b, f):
        j0 = b * block
        fb = jax.lax.dot_general(
            ay, rows_at(j0), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        return jax.lax.dynamic_update_slice(f, fb, (0, j0))

    f = jax.lax.fori_loop(0, n_blocks, final_block,
                          jnp.zeros((1, n_pad), jnp.float32))
    alpha_ref[...] = alpha.reshape(alpha_ref.shape)
    f_ref[...] = f.reshape(f_ref.shape)


@functools.partial(
    jax.jit,
    static_argnames=("kind", "n_epochs", "block", "interpret",
                     "n_slope", "v_t", "v_scale"),
)
def dual_ascent_lanes_pallas(
    x: jnp.ndarray,       # (P, n, d) per-pair inputs
    y: jnp.ndarray,       # (P, n) labels in {-1, +1}
    c_box: jnp.ndarray,   # (P, L, n) per-lane box (0 masks a sample out)
    gamma: jnp.ndarray,   # (P, G) kernel widths
    kind: str = "rbf",    # 'linear' | 'rbf' | 'sech2'
    n_epochs: int = 200,
    block: int = DEFAULT_BLOCK,
    interpret: bool = True,
    n_slope: float = 1.38,
    v_t: float = 0.02585,
    v_scale: float = 1.0,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Solve every (pair, gamma, C-lane) in one fused grid.

    Returns ``(alpha, f)``, each ``(P, G, L, n)``.  ``v_scale`` defaults
    to 1.0 — feature-unit gamma, matching ``core.kernels.sech2_kernel``.
    """
    p, n, d = x.shape
    g_count = gamma.shape[1]
    l_count = c_box.shape[1]
    blk = int(min(block, n))
    n_pad = -(-n // blk) * blk
    if n_pad != n:
        # Padding rows are inert: zero box ==> alpha frozen at 0 ==> they
        # contribute exact zeros to every margin contraction.
        x = jnp.pad(x, ((0, 0), (0, n_pad - n), (0, 0)))
        y = jnp.pad(y, ((0, 0), (0, n_pad - n)), constant_values=1.0)
        c_box = jnp.pad(c_box, ((0, 0), (0, 0), (0, n_pad - n)))
    tile = tile_body(kind, n_slope=n_slope, v_t=v_t, v_scale=v_scale)
    body = functools.partial(_solver_kernel, block=blk,
                             n_epochs=int(n_epochs), tile=tile)
    out_shape = jax.ShapeDtypeStruct((p, g_count, l_count, n_pad),
                                     jnp.float32)
    alpha, f = pl.pallas_call(
        body,
        grid=(p, g_count, l_count),
        in_specs=[
            pl.BlockSpec((1, n_pad, d), lambda i, j, k: (i, 0, 0)),
            pl.BlockSpec((1, n_pad), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, 1, n_pad), lambda i, j, k: (i, k, 0)),
            pl.BlockSpec((1, 1), lambda i, j, k: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, n_pad), lambda i, j, k: (i, j, k, 0)),
            pl.BlockSpec((1, 1, 1, n_pad), lambda i, j, k: (i, j, k, 0)),
        ],
        out_shape=[out_shape, out_shape],
        interpret=interpret,
    )(x, y, c_box, jnp.asarray(gamma, jnp.float32))
    return alpha[..., :n], f[..., :n]
