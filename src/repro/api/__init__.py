"""repro.api — the unified estimator + compiled-machine API (DESIGN.md §1).

Two first-class objects replace the old ``selection.explore`` grab-bag:

* :class:`MixedKernelSVM` — sklearn-style estimator: ``fit`` runs the
  paper's Algorithm 1 (with hardware-in-the-loop co-optimization),
  ``deploy(target)`` lowers any Table-II design point, ``save``/``load``
  round-trip a trained machine without retraining.

* :class:`CompiledMachine` — a bank of OvO bit-classifiers lowered by
  :func:`compile_machine` into padded, stacked arrays with ONE jit-compiled
  batched ``predict``: linear pairs in one fused matmul, RBF/sech2 pairs in
  the tiled Pallas kernel (TPU) or its identical-math jnp path (CPU), the
  analog pairs through the calibrated measured-curve kernel, and the packed
  decision encoder — a single device round-trip per batch.

The training side is the batched Algorithm-1 engine (DESIGN.md §4),
re-exported here from ``repro.core.trainer``: :func:`train_pairs` runs all
OvO pairs x CV folds x (C, gamma) grid cells in one compiled program per
kernel family; :func:`pad_pairs` / :class:`PaddedPairs` expose the padded
pair stack it operates on.

The kernel-assignment design space (DESIGN.md §5) is exposed through
:meth:`MixedKernelSVM.pareto` / budgeted ``deploy``, with the building
blocks re-exported: :func:`compile_candidates` / :class:`CandidateMachine`
(the assignment-independent ``(n, P, 2)`` pair-bit tensor) and
:class:`DesignSpace` / :class:`SweepResult` from ``repro.core.dse``.

Process variation (DESIGN.md §6) rides the same lowering:
:func:`compile_variants` / :class:`MonteCarloMachine` evaluate every
candidate under ``V`` sampled fabricated instances in one jitted forward
(``pair_bits(x) -> (V, n, P, 2)``, variant 0 nominal and bit-identical to
the un-varied path); :meth:`MixedKernelSVM.monte_carlo` returns per-variant
accuracy stats, ``pareto(n_variants=...)`` runs the yield-aware sweep, and
``deploy(yield_floor=...)`` picks the cheapest in-spec design.
"""
from repro.api.compiled import (
    CandidateMachine,
    CompiledMachine,
    MonteCarloMachine,
    compile_candidates,
    compile_machine,
    compile_variants,
)
from repro.api.estimator import MixedKernelSVM, MonteCarloResult
from repro.api.fleet import FleetMachine, compile_fleet
from repro.core.analog import CircuitParams, VariantSet
from repro.core.dse import DesignSpace, SweepResult
from repro.core.trainer import PaddedPairs, PairResult, pad_pairs, train_pairs

__all__ = [
    "CandidateMachine", "CircuitParams", "CompiledMachine", "DesignSpace",
    "FleetMachine", "MixedKernelSVM", "MonteCarloMachine", "MonteCarloResult",
    "PaddedPairs", "PairResult", "SweepResult", "VariantSet",
    "compile_candidates", "compile_fleet", "compile_machine",
    "compile_variants", "pad_pairs", "train_pairs",
]
