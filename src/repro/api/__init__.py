"""repro.api — the unified estimator + compiled-machine API (DESIGN.md §1).

Two first-class objects replace the old ``selection.explore`` grab-bag:

* :class:`MixedKernelSVM` — sklearn-style estimator: ``fit`` runs the
  paper's Algorithm 1 (with hardware-in-the-loop co-optimization),
  ``deploy(target)`` lowers any Table-II design point, ``save``/``load``
  round-trip a trained machine without retraining.

* :class:`CompiledMachine` — a bank of OvO bit-classifiers lowered by
  :func:`compile_machine` into padded, stacked arrays with ONE jit-compiled
  batched ``predict``: linear pairs in one fused matmul, RBF/sech2 pairs in
  the tiled Pallas kernel (TPU) or its identical-math jnp path (CPU), the
  analog pairs through the calibrated measured-curve kernel, and the packed
  decision encoder — a single device round-trip per batch.
"""
from repro.api.compiled import CompiledMachine, compile_machine
from repro.api.estimator import MixedKernelSVM

__all__ = ["CompiledMachine", "compile_machine", "MixedKernelSVM"]
