"""MixedKernelSVM: the sklearn-style estimator wrapping Algorithm 1.

The paper's deliverable is a *machine*: a bank of OvO classifiers (digital
linear, digital RBF, analog sech2) feeding a decision encoder.  This module
exposes it as one first-class object:

    est = MixedKernelSVM(n_epochs=120).fit(x_train, y_train)
    est.score(x_test, y_test)                    # float (software) accuracy
    machine = est.deploy("circuit")              # CompiledMachine, one jit path
    machine.predict(x)                           # batched labels
    est.save("models/balance")                   # npz + json, no retraining
    est2 = MixedKernelSVM.load("models/balance")

``fit`` runs the separation-driven mixed-kernel exploration (Algorithm 1,
``selection.train_pairs``) with hardware-in-the-loop co-optimization of the
analog-bound classifiers, then assembles every Table-II design point
(``selection.build_banks``).  ``bank(target)`` returns the legacy object bank
(used by the hardware cost model); ``deploy(target)`` lowers it to a
:class:`~repro.api.compiled.CompiledMachine` (cached per target).

Targets: ``'float'`` (mixed software), ``'circuit'`` (mixed deployed:
digital linear + analog RBF), ``'linear'`` (all-digital-linear baseline),
``'rbf'`` (all-digital-RBF baseline), plus ``'linear_float'``/``'rbf_float'``.

Beyond Algorithm 1's single design point, the estimator fronts the batched
kernel-assignment design space (``repro.core.dse``, DESIGN.md §5):

    front = est.pareto(x_val, y_val)             # accuracy/area/power front
    machine = est.deploy("circuit",
                         area_budget=0.1,        # mm^2
                         power_budget=0.05)      # mW -> cheapest point in budget
    est.assignment_                              # chosen per-pair kernel map
    est.save("models/balance")                   # assignment round-trips

``deploy("circuit")`` with no budget remains exactly the Algorithm-1
machine.

Process variation (DESIGN.md §6) is a first-class axis:

    mc = est.monte_carlo(x_val, y_val, n_variants=64,
                         key=jax.random.PRNGKey(7))   # per-variant stats
    mc.mean, mc.worst, mc.yield_at(0.9)
    front = est.pareto(x_val, y_val, n_variants=64)   # robust sweep
    machine = est.deploy("circuit", yield_floor=0.95) # cheapest in-spec
    est.save("models/balance")                        # assignment + MC key

and ``MixedKernelSVM(circuit=CircuitParams(sigma_vth=...))`` overrides the
analog process corner without touching internals (serialized, since the hw
model stays deterministic in ``(seed, circuit)``).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

import jax
import numpy as np

from repro.api.compiled import (
    MC_STREAM_CHUNK,
    CompiledMachine,
    MonteCarloMachine,
    StreamingMCMachine,
    _key_data,
    _strip_ext,
    compile_candidates,
    compile_machine,
    compile_mc_stream,
    compile_variants,
)
from repro.core import dse as dse_mod
from repro.core import hwcost, mcstream, selection
from repro.core.analog import (
    AnalogBinaryClassifier,
    AnalogRBFModel,
    CircuitParams,
)
from repro.core.ovo import DigitalLinearClassifier, MulticlassSVM
from repro.core.svm import SVMModel

# v2: config gained "hw_all", meta gained "assignment" (the chosen kernel
# map of a budgeted deploy).  v3: config gained "circuit" (CircuitParams
# overrides) and meta gained "monte_carlo" (the MC key/config of a
# variation-aware sweep).  Older saves load fine (missing keys default).
_FORMAT_VERSION = 3

_MODEL_SLOTS = ("model_linear", "model_rbf", "model_hw")
_MODEL_ARRAYS = ("support_x", "support_y", "alpha", "w")


@dataclasses.dataclass(frozen=True)
class MonteCarloResult:
    """Per-variant accuracy of one deployed assignment (DESIGN.md §6.5).

    ``accuracy[0]`` is the nominal (zero-offset) instance; the remaining
    rows are sampled fabricated instances.  ``key_data`` is the raw jax
    PRNG key the mismatch was drawn with — enough to reproduce the exact
    variant set.
    """

    accuracy: np.ndarray      # (V,) per-variant validation accuracy
    assignment: list          # per-pair kernel map evaluated
    n_variants: int
    sigma_scale: float
    key_data: list

    @property
    def nominal(self) -> float:
        return float(self.accuracy[0])

    @property
    def mean(self) -> float:
        return float(np.mean(self.accuracy))

    @property
    def std(self) -> float:
        return float(np.std(self.accuracy))

    @property
    def worst(self) -> float:
        return float(np.min(self.accuracy))

    def yield_at(self, accuracy_floor: float) -> float:
        """Fraction of instances at or above the accuracy floor."""
        return float(np.mean(self.accuracy >= accuracy_floor))


#: Dense Monte-Carlo above this many variants silently switches to the
#: flat-memory streaming engine (DESIGN.md §10): the dense ``(V, n, P, 2)``
#: bit tensor it would otherwise materialize stops fitting long before 10^6.
STREAM_AUTO_VARIANTS = 4096


@dataclasses.dataclass(frozen=True)
class StreamingMCResult:
    """Streamed tail-yield statistics of one assignment (DESIGN.md §10).

    Produced by :meth:`MixedKernelSVM.monte_carlo` when the streaming
    engine runs (``method=`` given, or ``n_variants`` above
    :data:`STREAM_AUTO_VARIANTS`).  Unlike :class:`MonteCarloResult` the
    per-variant accuracies are never materialized: ``mean``/``std`` are
    streamed Welford moments, ``yield_`` carries a ``(yield_lo,
    yield_hi)`` confidence interval, and quantiles come from a fixed-grid
    histogram sketch (exact to the accuracy grid of the validation set).
    """

    mean: float
    std: float
    worst: float              # streamed min over sampled variants
    best: float
    yield_: float             # point estimate (IS: self-normalized)
    yield_lo: float           # lower/upper confidence bound at `confidence`
    yield_hi: float
    n_eff: float              # effective sample size (== V unless IS)
    accuracy_floor: float
    confidence: float
    ci: str                   # "wilson" | "clopper-pearson"
    n_variants: int
    method: str               # "iid" | "sobol" | "stratified" | "is"
    sigma_scale: float
    is_scale: float
    assignment: list
    key_data: list
    hist: np.ndarray          # (n_bins,) weighted accuracy histogram

    def quantile(self, q) -> np.ndarray:
        """Accuracy quantile(s) from the streamed histogram sketch."""
        qs = np.atleast_1d(np.asarray(q, np.float64))
        out = mcstream.hist_quantiles(self.hist[None, :], qs)[:, 0]
        return out if np.ndim(q) else out[0]


class MixedKernelSVM:
    """Mixed-kernel mixed-signal OvO SVM (paper Algorithm 1 + deployment).

    Parameters mirror the old ``selection.explore`` signature.  ``hw`` may be
    a pre-calibrated :class:`AnalogRBFModel`; by default one is calibrated
    from the circuit surrogate with ``seed`` (deterministic, and therefore
    serializable — ``save`` requires the default construction).
    """

    def __init__(
        self,
        weight_bits: int = 8,
        input_bits: int = 4,
        n_epochs: int = 200,
        seed: int = 0,
        tie_margin: float = 0.005,
        alpha_floor_rel: float = 1.0 / 256.0,
        cv_epochs: Optional[int] = None,
        hw: Optional[AnalogRBFModel] = None,
        use_pallas: Optional[bool] = None,
        interpret: Optional[bool] = None,
        mesh=None,
        hw_all: bool = True,
        circuit: Optional[CircuitParams] = None,
    ):
        self.weight_bits = weight_bits
        self.input_bits = input_bits
        self.n_epochs = n_epochs
        self.seed = seed
        self.tie_margin = tie_margin
        self.alpha_floor_rel = alpha_floor_rel
        # Epochs used when training CV folds during the hyper-parameter
        # search; None keeps the historical max(60, n_epochs // 2) policy.
        self.cv_epochs = cv_epochs
        self.use_pallas = use_pallas
        # Pallas-interpreter override for the compiled paths (None = the
        # kernels.ops backend default); runtime-only, like `use_pallas`.
        self.interpret = interpret
        # Optional device mesh for the batched trainer's shard_map variant
        # (runtime-only, like `hw`/`use_pallas`: not serialized).
        self.mesh = mesh
        # Keep the hardware co-optimized candidate for EVERY pair (free in
        # the batched engine) so the kernel-assignment design space has an
        # RBF-analog candidate per pair; False restores the lean saves.
        self.hw_all = hw_all
        # Circuit-parameter overrides for the analog behavioral model
        # (sigma sweeps, bias studies) WITHOUT touching internals: the hw
        # model is calibrated from `(seed, circuit)` deterministically, so
        # — unlike a user-supplied `hw` object — it serializes.
        self.circuit = circuit
        self._custom_hw = hw is not None
        self.hw_ = hw
        self.pairs_: Optional[list[selection.PairResult]] = None
        self.n_classes_: Optional[int] = None
        self._banks: Optional[dict[str, MulticlassSVM]] = None
        self._compiled: dict[str, CompiledMachine] = {}
        # DSE state: the chosen per-pair kernel map of a budgeted deploy
        # (serialized), the cached sweep result and design space (not).
        self.assignment_: Optional[list[str]] = None
        self.pareto_: Optional[dse_mod.SweepResult] = None
        self._dse: Optional[dse_mod.DesignSpace] = None
        self._dse_cm: Optional[hwcost.CostModel] = None
        self._candidate_cache = None
        self._candidate_machine = None
        # Monte-Carlo state: compiled variant machines keyed by their
        # sampling config (cached per fit), plus the serialized MC config
        # of the last variation-aware sweep (key data, n_variants, ...).
        self._mc_machines: dict[tuple, MonteCarloMachine] = {}
        self._stream_machines: dict[tuple, StreamingMCMachine] = {}
        self.mc_state_: Optional[dict] = None

    # -- fitting --------------------------------------------------------------

    def fit(self, x: np.ndarray, y: np.ndarray) -> "MixedKernelSVM":
        """Run Algorithm 1 and deploy every design point.

        Labels must be contiguous integers 0..K-1 with every class present
        (an absent class would silently train its OvO pairs on empty
        subsets).
        """
        y = np.asarray(y)
        classes = np.unique(y)
        if classes.size < 2 or not np.array_equal(
                classes, np.arange(classes.size)):
            raise ValueError(
                "labels must be contiguous integers 0..K-1 with K >= 2 and "
                f"every class present; got classes {classes.tolist()}")
        self.n_classes_ = int(classes.size)
        if self.hw_ is None:
            self.hw_ = selection.default_hw(self.seed, self.circuit)
        self.pairs_ = selection.train_pairs(
            np.asarray(x), y, self.n_classes_, hw=self.hw_,
            n_epochs=self.n_epochs, seed=self.seed,
            tie_margin=self.tie_margin, cv_epochs=self.cv_epochs,
            mesh=self.mesh, hw_all=self.hw_all,
            use_pallas=self.use_pallas, interpret=self.interpret)
        self.assignment_ = None
        self.pareto_ = None
        self.mc_state_ = None
        self._build()
        return self

    def _build(self) -> None:
        """(Re)assemble the object banks from trained pairs."""
        self._banks = selection.build_banks(
            self.pairs_, self.n_classes_, hw=self.hw_,
            weight_bits=self.weight_bits, input_bits=self.input_bits,
            seed=self.seed, alpha_floor_rel=self.alpha_floor_rel)
        self._compiled = {}
        self._dse = None
        self._dse_cm = None
        self._candidate_cache = None
        self._candidate_machine = None
        self._mc_machines = {}
        self._stream_machines = {}

    def _check_fitted(self) -> None:
        if self._banks is None:
            raise RuntimeError("MixedKernelSVM is not fitted; call fit(x, y)")

    # -- introspection ---------------------------------------------------------

    @property
    def kernel_map_(self) -> list[str]:
        self._check_fitted()
        return [p.kernel for p in self.pairs_]

    @property
    def n_rbf_(self) -> int:
        return sum(k == "rbf" for k in self.kernel_map_)

    @property
    def targets(self) -> tuple[str, ...]:
        return selection.BANK_TARGETS

    # -- deployment ------------------------------------------------------------

    def bank(self, target: str = "float") -> MulticlassSVM:
        """The legacy per-classifier object bank for ``target`` (used by the
        hardware cost model and as the reference path in tests)."""
        self._check_fitted()
        if target not in self._banks:
            raise KeyError(
                f"unknown target {target!r}; one of {selection.BANK_TARGETS}")
        return self._banks[target]

    def deploy(
        self,
        target: str = "float",
        area_budget: Optional[float] = None,
        power_budget: Optional[float] = None,
        yield_floor: Optional[float] = None,
        yield_confidence: Optional[float] = 0.95,
        decider: str = "votes",
    ) -> CompiledMachine:
        """Lower ``target``'s bank to one batched jit inference path.

        ``decider="dag"`` compiles the O(K) DDAG decision front instead of
        the dense votes path (DESIGN.md §11) — same banks, K-1 pair
        evaluations per sample at predict time.

        With an ``area_budget`` (mm^2) and/or ``power_budget`` (mW) —
        ``'circuit'`` target only — the deployment instead picks the
        cheapest Pareto point of the kernel-assignment design space that
        meets the budget (requires a prior :meth:`pareto` sweep), records
        its per-pair kernel map in ``assignment_`` (serialized by
        ``save``), and compiles that machine.  With no budget the
        Algorithm-1 machine is returned unchanged.

        ``yield_floor`` (requires a prior Monte-Carlo :meth:`pareto`
        sweep, ``n_variants=...``) switches to the robust rule: the
        CHEAPEST budget-feasible design whose yield — fraction of sampled
        fabricated instances at or above the sweep's accuracy floor —
        meets the floor (``SweepResult.select``).  The gate is the
        Wilson LOWER confidence bound of the sampled yield at
        ``yield_confidence`` (default 95%), so a design only deploys when
        the evidence — not just the point estimate — supports the floor;
        ``yield_confidence=None`` restores the historical point-estimate
        rule.
        """
        if area_budget is None and power_budget is None \
                and yield_floor is None:
            key = target if decider == "votes" else f"{target}@{decider}"
            if key not in self._compiled:
                self._compiled[key] = compile_machine(
                    self.bank(target), use_pallas=self.use_pallas,
                    interpret=self.interpret, decider=decider)
            return self._compiled[key]
        if target != "circuit":
            raise ValueError(
                "budget-constrained deployment explores the circuit design "
                f"space; got target {target!r}")
        if self.pareto_ is None:
            raise RuntimeError(
                "no Pareto front available: call est.pareto(x_val, y_val) "
                "before deploying against a budget")
        i = self.pareto_.select(area_budget=area_budget,
                                power_budget=power_budget,
                                yield_floor=yield_floor,
                                confidence=yield_confidence)
        self.assignment_ = self.pareto_.kernel_map(i)
        if yield_floor is not None and self.mc_state_ is not None:
            self.mc_state_["yield_floor"] = float(yield_floor)
            self.mc_state_["yield_confidence"] = (
                None if yield_confidence is None else float(yield_confidence))
        return self.deploy_assignment(self.assignment_, decider=decider)

    # -- kernel-assignment design space (DESIGN.md §5) -------------------------

    def _candidates(self) -> list[tuple]:
        """Per-pair (linear-digital, RBF-analog) deployed candidates — the
        same constructions ``build_banks`` uses, so the Algorithm-1
        assignment reproduces the ``'circuit'`` bank classifier-for-
        classifier.  Cached per fit (deployment re-quantizes weights)."""
        self._check_fitted()
        if self._candidate_cache is None:
            missing = [p.pair for p in self.pairs_ if p.model_hw is None]
            if missing:
                raise RuntimeError(
                    f"pairs {missing} have no hardware co-optimized "
                    "candidate; fit with hw_all=True (the default) to "
                    "explore the assignment space")
            self._candidate_cache = [
                (DigitalLinearClassifier.deploy(
                    p.model_linear, self.weight_bits, self.input_bits),
                 AnalogBinaryClassifier.deploy(
                    p.model_hw, self.hw_,
                    alpha_floor_rel=self.alpha_floor_rel))
                for p in self.pairs_
            ]
        return self._candidate_cache

    def design_space(
        self, cm: Optional[hwcost.CostModel] = None
    ) -> dse_mod.DesignSpace:
        """The batched design space over per-pair kernel assignments.

        The jitted candidate machine is cost-model-independent and cached
        per fit; only the (numpy) cost table is rebuilt when ``cm``
        changes, so re-sweeping under a recalibrated cost model is cheap.
        """
        cm = cm or hwcost.CostModel()
        if self._dse is None or self._dse_cm != cm:
            if self._candidate_machine is None:
                self._candidate_machine = compile_candidates(
                    self._candidates(), self.n_classes_,
                    use_pallas=self.use_pallas, interpret=self.interpret)
            table = hwcost.pair_cost_table(self._candidates(), cm,
                                           n_classes=self.n_classes_)
            self._dse = dse_mod.DesignSpace(
                self._candidate_machine, table, self.n_classes_)
            self._dse_cm = cm
        return self._dse

    def pareto(
        self,
        x_val: np.ndarray,
        y_val: np.ndarray,
        cm: Optional[hwcost.CostModel] = None,
        n_variants: Optional[int] = None,
        key: Optional[jax.Array] = None,
        sigma_scale: float = 1.0,
        accuracy_floor: Optional[float] = None,
        **sweep_kwargs,
    ) -> dse_mod.SweepResult:
        """Sweep the kernel-assignment space on validation data and return
        the accuracy/area/power Pareto front (cached in ``pareto_``).

        Exhaustive ``2^P`` for ``P <= 12`` (two jit compiles: the candidate
        bit tensor + the bit-recombination program); seeded greedy/flip
        search beyond, seeded with the Algorithm-1 assignment.

        Monte-Carlo mode (``n_variants=``): every assignment additionally
        gets mean/std/worst-case accuracy and yield over ``n_variants``
        sampled fabricated instances, and the result carries the robust
        four-objective front — still two jit compiles (the MC forward +
        the batched recombination).  ``key`` is the explicit mismatch
        PRNG key (default ``PRNGKey(self.seed)``); ``accuracy_floor``
        defaults to two points below the nominal Algorithm-1 circuit
        accuracy on the given validation set.  The MC config (key data,
        ``n_variants``, ``sigma_scale``, floor) is recorded in
        ``mc_state_`` and serialized by :meth:`save`.
        """
        space = self.design_space(cm)
        seeds = sweep_kwargs.pop("seeds", dse_mod.assignment_from_kernel_map(
            self.kernel_map_)[None, :])
        mc_machine = None
        if n_variants is not None:
            if key is None:
                key = jax.random.PRNGKey(self.seed)
            if accuracy_floor is None:
                accuracy_floor = self.score(x_val, y_val,
                                            target="circuit") - 0.02
            mc_machine = self.monte_carlo_machine(
                n_variants, key, sigma_scale=sigma_scale)
        elif accuracy_floor is not None:
            raise ValueError(
                "accuracy_floor only applies to Monte-Carlo sweeps; pass "
                "n_variants=... as well")
        self.pareto_ = space.sweep(np.asarray(x_val), np.asarray(y_val),
                                   seeds=seeds, mc_machine=mc_machine,
                                   accuracy_floor=accuracy_floor,
                                   **sweep_kwargs)
        if mc_machine is not None:
            self.mc_state_ = {
                "key_data": np.asarray(mc_machine.key_data).tolist(),
                "n_variants": int(n_variants),
                "sigma_scale": float(sigma_scale),
                "accuracy_floor": float(accuracy_floor),
            }
        return self.pareto_

    # -- Monte-Carlo variation (DESIGN.md §6) -----------------------------------

    def monte_carlo_machine(
        self,
        n_variants: int,
        key: jax.Array,
        sigma_scale: float = 1.0,
    ) -> MonteCarloMachine:
        """The compiled variant machine for this estimator's candidates:
        ``pair_bits(x) -> (V, n, P, 2)`` in one jitted forward, variant 0
        nominal.  Cached per ``(n_variants, key, sigma_scale)`` so repeated
        sweeps/evaluations with one config compile once."""
        self._check_fitted()
        cache_key = (int(n_variants),
                     _key_data(key).tobytes(), float(sigma_scale))
        if cache_key not in self._mc_machines:
            self._mc_machines[cache_key] = compile_variants(
                self._candidates(), self.n_classes_, key=key,
                n_variants=n_variants, sigma_scale=sigma_scale,
                use_pallas=self.use_pallas, interpret=self.interpret)
        return self._mc_machines[cache_key]

    def stream_machine(
        self,
        key: jax.Array,
        method: str = "iid",
        mc_chunk: int = MC_STREAM_CHUNK,
        sigma_scale: float = 1.0,
        is_scale: float = 2.0,
    ) -> StreamingMCMachine:
        """The flat-memory streaming MC engine for this estimator's
        candidates (DESIGN.md §10): one compiled donated step regardless
        of the variant count.  Cached per sampling config so repeated
        calls with one config compile once."""
        self._check_fitted()
        cache_key = (_key_data(key).tobytes(), str(method), int(mc_chunk),
                     float(sigma_scale), float(is_scale))
        if cache_key not in self._stream_machines:
            self._stream_machines[cache_key] = compile_mc_stream(
                self._candidates(), self.n_classes_, key=key,
                method=method, mc_chunk=mc_chunk, sigma_scale=sigma_scale,
                is_scale=is_scale, use_pallas=self.use_pallas,
                interpret=self.interpret)
        return self._stream_machines[cache_key]

    def monte_carlo(
        self,
        x: np.ndarray,
        y: np.ndarray,
        n_variants: int = 64,
        key: Optional[jax.Array] = None,
        sigma_scale: float = 1.0,
        assignment: Optional[list] = None,
        method: Optional[str] = None,
        mc_chunk: Optional[int] = None,
        accuracy_floor: Optional[float] = None,
        is_scale: float = 2.0,
        confidence: float = 0.95,
        ci: str = "wilson",
        mesh=None,
    ) -> object:
        """Accuracy of ONE deployed assignment under sampled process
        variation.

        ``assignment`` defaults to the estimator's current circuit
        assignment (``assignment_`` from a budgeted/yield deploy if set,
        else the Algorithm-1 kernel map).  ``key`` is the explicit
        mismatch key (default ``PRNGKey(self.seed)``); the key data is
        recorded in the result for reproducibility.

        Two engines sit behind this call (DESIGN.md §10):

        * **dense** (default for small ``n_variants``): one jitted
          forward materializes every variant's pair bits and returns a
          :class:`MonteCarloResult` with the raw ``(V,)`` accuracy
          vector (variant 0 nominal).
        * **streaming** (``method="iid" | "sobol" | "stratified" |
          "is"``, or any ``n_variants`` above
          :data:`STREAM_AUTO_VARIANTS`): fixed-shape chunks of
          ``mc_chunk`` variants are generated on the fly and folded into
          constant-size accumulators, so ``n_variants=10**6`` runs in
          the same device memory as 64.  Returns a
          :class:`StreamingMCResult` with Wilson/Clopper-Pearson yield
          bounds against ``accuracy_floor`` (default: two points below
          the nominal circuit accuracy on ``(x, y)``).  ``mesh`` (from
          :func:`repro.launch.mesh.make_variant_mesh`) shards each chunk
          over a 1-D ``"variants"`` device axis.
        """
        self._check_fitted()
        if key is None:
            key = jax.random.PRNGKey(self.seed)
        if assignment is None:
            assignment = self.assignment_ or self.kernel_map_
        kmap = [k if isinstance(k, str) else ("rbf" if k else "linear")
                for k in list(assignment)]
        streaming = (method is not None or mc_chunk is not None
                     or mesh is not None
                     or int(n_variants) > STREAM_AUTO_VARIANTS)
        if not streaming:
            machine = self.monte_carlo_machine(n_variants, key,
                                               sigma_scale=sigma_scale)
            bits3 = machine.pair_bits(np.asarray(x))
            a = dse_mod.assignment_from_kernel_map(kmap)
            acc = dse_mod.assignment_accuracies_mc(
                bits3, a[None, :], np.asarray(y), self.n_classes_)[:, 0]
            return MonteCarloResult(
                accuracy=acc, assignment=kmap, n_variants=int(n_variants),
                sigma_scale=float(sigma_scale),
                key_data=np.asarray(machine.key_data).tolist())
        else:
            if accuracy_floor is None:
                accuracy_floor = self.score(x, y, target="circuit") - 0.02
            sm = self.stream_machine(
                key, method=method or "iid",
                mc_chunk=MC_STREAM_CHUNK if mc_chunk is None else mc_chunk,
                sigma_scale=sigma_scale, is_scale=is_scale)
        a = dse_mod.assignment_from_kernel_map(kmap)
        out = sm.stream(np.asarray(x), np.asarray(y), a[None, :],
                        n_variants=int(n_variants),
                        accuracy_floor=float(accuracy_floor),
                        mesh=mesh, confidence=confidence, ci=ci)
        return StreamingMCResult(
            mean=float(out["mean"][0]), std=float(out["std"][0]),
            worst=float(out["worst"][0]), best=float(out["best"][0]),
            yield_=float(out["yield"][0]),
            yield_lo=float(out["yield_lo"][0]),
            yield_hi=float(out["yield_hi"][0]),
            n_eff=float(out["n_eff"]),
            accuracy_floor=float(accuracy_floor),
            confidence=float(confidence), ci=str(out["ci"]),
            n_variants=int(n_variants), method=sm.method,
            sigma_scale=float(sigma_scale), is_scale=float(is_scale),
            assignment=kmap,
            key_data=np.asarray(sm.key_data).tolist(),
            hist=np.asarray(out["hist"][0]))

    def deploy_assignment(
        self, assignment: Optional[list] = None, decider: str = "votes"
    ) -> CompiledMachine:
        """Compile the machine for an explicit per-pair kernel assignment
        (default: the stored ``assignment_`` of a budgeted deploy)."""
        self._check_fitted()
        if assignment is None:
            assignment = self.assignment_
        if assignment is None:
            raise RuntimeError(
                "no assignment chosen yet: pass one explicitly or deploy "
                "with a budget after est.pareto(...)")
        kmap = [k if isinstance(k, str) else ("rbf" if k else "linear")
                for k in list(assignment)]
        key = "assignment:" + "".join("r" if k == "rbf" else "l"
                                      for k in kmap)
        if decider != "votes":
            key += f"@{decider}"
        if key not in self._compiled:
            self._compiled[key] = compile_machine(
                self._assignment_bank(kmap), use_pallas=self.use_pallas,
                interpret=self.interpret, decider=decider)
        return self._compiled[key]

    def _assignment_bank(self, kmap: list[str]) -> MulticlassSVM:
        if len(kmap) != len(self.pairs_):
            raise ValueError(
                f"assignment has {len(kmap)} pairs, machine has "
                f"{len(self.pairs_)}")
        cands = self._candidates()
        classifiers = [c[1] if k == "rbf" else c[0]
                       for c, k in zip(cands, kmap)]
        return MulticlassSVM(n_classes=self.n_classes_,
                             classifiers=classifiers, kernel_map=kmap)

    # -- prediction ------------------------------------------------------------

    def predict(self, x: np.ndarray, target: str = "float") -> np.ndarray:
        return self.deploy(target).predict(x)

    def predict_bits(self, x: np.ndarray, target: str = "float") -> np.ndarray:
        return self.deploy(target).predict_bits(x)

    def score(self, x: np.ndarray, y: np.ndarray,
              target: str = "float") -> float:
        return float(np.mean(self.predict(x, target) == np.asarray(y)))

    # -- serialization (npz arrays + json structure) ----------------------------

    def save(self, path: str) -> None:
        """Write ``<path>.npz`` + ``<path>.json``; round-trips without
        retraining (deployments are rebuilt deterministically on load)."""
        self._check_fitted()
        if self._custom_hw:
            raise ValueError(
                "cannot serialize an estimator built around a user-supplied "
                "AnalogRBFModel; use the default hw (calibrated from `seed`)")
        path = _strip_ext(path)
        arrays: dict[str, np.ndarray] = {}
        meta_pairs = []
        for i, p in enumerate(self.pairs_):
            entry = {
                "pair": list(p.pair), "kernel": p.kernel,
                "acc_linear": p.acc_linear, "acc_rbf": p.acc_rbf,
                "models": {},
            }
            for slot in _MODEL_SLOTS:
                m: Optional[SVMModel] = getattr(p, slot)
                if m is None:
                    continue
                entry["models"][slot] = {
                    "kind": m.kind, "bias": m.bias, "gamma": m.gamma,
                    "c": m.c, "has_w": m.w is not None,
                }
                for name in _MODEL_ARRAYS:
                    a = getattr(m, name)
                    if a is not None:
                        arrays[f"p{i}.{slot}.{name}"] = np.asarray(a)
            meta_pairs.append(entry)
        meta = {
            "format": "repro.api.MixedKernelSVM",
            "version": _FORMAT_VERSION,
            "n_classes": self.n_classes_,
            "config": {
                "weight_bits": self.weight_bits,
                "input_bits": self.input_bits,
                "n_epochs": self.n_epochs,
                "seed": self.seed,
                "tie_margin": self.tie_margin,
                "alpha_floor_rel": self.alpha_floor_rel,
                "cv_epochs": self.cv_epochs,
                "hw_all": self.hw_all,
                "circuit": (None if self.circuit is None
                            else dataclasses.asdict(self.circuit)),
            },
            "assignment": self.assignment_,
            "monte_carlo": self.mc_state_,
            "pairs": meta_pairs,
        }
        np.savez(path + ".npz", **arrays)
        with open(path + ".json", "w") as f:
            json.dump(meta, f, indent=2)

    @classmethod
    def load(cls, path: str, use_pallas: Optional[bool] = None,
             interpret: Optional[bool] = None) -> "MixedKernelSVM":
        path = _strip_ext(path)
        with open(path + ".json") as f:
            meta = json.load(f)
        if meta.get("format") != "repro.api.MixedKernelSVM":
            raise ValueError(f"{path}.json is not a MixedKernelSVM save")
        if int(meta.get("version", 0)) > _FORMAT_VERSION:
            raise ValueError(
                f"{path}.json is format version {meta['version']}; this "
                f"build reads up to version {_FORMAT_VERSION} — upgrade "
                "the library to load it")
        npz = np.load(path + ".npz")
        config = dict(meta["config"])
        if config.get("circuit"):
            config["circuit"] = CircuitParams(**config["circuit"])
        est = cls(use_pallas=use_pallas, interpret=interpret, **config)
        est.n_classes_ = int(meta["n_classes"])
        est.hw_ = selection.default_hw(est.seed, est.circuit)

        def rebuild(i: int, slot: str, m_meta: dict) -> SVMModel:
            def arr(name):
                key = f"p{i}.{slot}.{name}"
                return npz[key] if key in npz else None

            kind = m_meta["kind"]
            return SVMModel(
                kind=kind,
                support_x=arr("support_x"), support_y=arr("support_y"),
                alpha=arr("alpha"), bias=float(m_meta["bias"]),
                gamma=float(m_meta["gamma"]), c=float(m_meta["c"]),
                w=arr("w") if m_meta["has_w"] else None,
                # hardware-in-the-loop models carry the calibrated kernel
                kernel_fn=est.hw_.kernel_response if kind == "hw" else None,
            )

        pairs = []
        for i, entry in enumerate(meta["pairs"]):
            models = {
                slot: rebuild(i, slot, m_meta)
                for slot, m_meta in entry["models"].items()
            }
            kernel = entry["kernel"]
            m_hw = models.get("model_hw")
            pairs.append(selection.PairResult(
                pair=tuple(entry["pair"]), kernel=kernel,
                model=m_hw if kernel == "rbf" else models["model_linear"],
                acc_linear=float(entry["acc_linear"]),
                acc_rbf=float(entry["acc_rbf"]),
                model_linear=models["model_linear"],
                model_rbf=models["model_rbf"], model_hw=m_hw,
            ))
        est.pairs_ = pairs
        assignment = meta.get("assignment")
        est.assignment_ = list(assignment) if assignment else None
        mc = meta.get("monte_carlo")
        est.mc_state_ = dict(mc) if mc else None
        est._build()
        return est

