"""FleetMachine: many CompiledMachines co-batched into ONE jitted forward.

A deployed near-sensor installation is a *fleet*: many trained machines
(different datasets, circuit corners, tenants) answering continuous
small-query streams.  Serving them as separate ``CompiledMachine`` objects
means one device program per model per batch — a request mix of M models
costs M dispatches even when the total row count is tiny.

``compile_fleet`` concatenates member machines into one super-bank machine
with a single jitted forward

    ``forward(x (n, d_max) f32, model_idx (n,) i32)
        -> (labels (n,) i32, scores (n, P_total) f32)``

so ONE dispatch serves a batch whose rows belong to *any* mix of members.
Layout (DESIGN.md §9):

* **Shared padded input layout** — rows are padded on the feature axis to
  ``d_max = max(member.n_features)``; member ``m``'s banks read only
  ``x[:, :d_m]``, so the padding columns are dead for its own rows (and
  rows belonging to other members produce don't-care columns that the
  routing select discards).

* **Per-member pair/class slices** — every member's banks are carried
  VERBATIM (same grouping, same padded ``M``, same ``inv_perm``), and its
  score columns occupy the contiguous slice ``pair_slice(model_id)`` of
  the concatenated ``(n, P_total)`` tensor.  This is the bit-identity
  contract: re-grouping banks across members would change contraction
  padding and therefore f32 summation order, so the fleet instead
  replicates each member's exact forward subgraph and concatenates the
  results.  ``FleetMachine.predict(x, model)`` is bit-identical to
  ``member.predict(x)`` — scores, bits and labels.

* **Routing** — per-member labels are computed for all rows (the decision
  encoder is O(n) next to the kernel banks) and one
  ``take_along_axis(labels_stack, model_idx)`` selects each row's own
  member.  Un-padding on return is the serving engine's job.

The serving hot path is the labels-only program ``_labels_jit``, jitted
with ``donate_argnums=(1,)``: the ``model_idx`` input buffer (i32, (n,))
is donated and reused for the label output (i32, (n,)) — the donation the
static analyzer verifies (``DONATION-DROPPED``, DESIGN.md §8) and the
double-buffered engine staging relies on (``repro.serving.svm_engine``).
"""
from __future__ import annotations

import json
from typing import Mapping, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.compiled import (
    DECIDERS,
    CompiledMachine,
    _all_scores,
    _bank_arrays,
    _banks_from_entries,
    _dag_labels,
    _dag_row_maps,
    _dag_step_plans,
    _Decider,
    _strip_ext,
)
from repro.core.ovo import pair_index_matrix

_FLEET_FORMAT = "repro.api.FleetMachine"
_FLEET_VERSION = 1

ModelRef = Union[str, int]


class FleetMachine:
    """Co-batched multi-model machine (see module docstring).

    Construct via :func:`compile_fleet` or :meth:`FleetMachine.load`.
    """

    def __init__(self, model_ids: Sequence[str],
                 machines: Sequence[CompiledMachine],
                 use_pallas: Optional[bool] = None,
                 interpret: Optional[bool] = None,
                 decider: str = "votes"):
        if len(model_ids) != len(machines) or not machines:
            raise ValueError("need one model id per member machine (>= 1)")
        if len(set(model_ids)) != len(model_ids):
            raise ValueError(f"duplicate model ids: {list(model_ids)}")
        self.model_ids = [str(m) for m in model_ids]
        self._members = list(machines)
        self._index = {m: i for i, m in enumerate(self.model_ids)}
        self.n_models = len(self._members)
        self.n_features = max(m.n_features for m in self._members)
        self.n_pairs_total = sum(m.n_pairs for m in self._members)

        # Per-member column slices into the concatenated score tensor.
        offs = np.cumsum([0] + [m.n_pairs for m in self._members])
        self._pair_slices = [(int(offs[i]), int(offs[i + 1]))
                             for i in range(self.n_models)]

        # Inherit member dispatch settings when they agree (the common
        # case and what the bit-identity contract assumes); an explicit
        # argument or the backend default otherwise.
        if use_pallas is None:
            vals = {m.use_pallas for m in self._members}
            use_pallas = vals.pop() if len(vals) == 1 else \
                jax.default_backend() == "tpu"
        self.use_pallas = bool(use_pallas)
        if interpret is None:
            ivals = {m.interpret for m in self._members}
            interpret = ivals.pop() if len(ivals) == 1 else None
        self.interpret = interpret

        self._deciders = [_Decider.build(m.n_classes) for m in self._members]

        # Decision front of the labels path: dense votes (seed semantics)
        # or the per-member O(K) DAG elimination front.  The scores path
        # (`decision_scores`/`predict_bits`) always runs dense — it is
        # the bit-identity oracle either way.
        if decider not in DECIDERS:
            raise ValueError(
                f"unknown decider {decider!r}; one of {DECIDERS}")
        self.decider = decider
        if decider == "dag":
            self._pair_matrices = [
                jnp.asarray(pair_index_matrix(m.n_classes))
                for m in self._members]
            self._row_maps = [
                _dag_row_maps(m._linear_banks, m._kernel_banks, m.n_pairs)
                for m in self._members]
            self._step_plans = [
                _dag_step_plans(m._linear_banks, m._kernel_banks,
                                m.n_classes)
                for m in self._members]

        self._forward_jit = jax.jit(self._forward)
        # Serving hot path: labels only, model_idx donated -> label buffer.
        self._labels_jit = jax.jit(self._labels, donate_argnums=(1,))
        # Data-parallel serving legs, one per mesh (DESIGN.md §12.1).
        self._sharded: dict = {}

    # -- introspection -------------------------------------------------------

    def member(self, model: ModelRef) -> CompiledMachine:
        return self._members[self.model_index(model)]

    def model_index(self, model: ModelRef) -> int:
        if isinstance(model, str):
            try:
                return self._index[model]
            except KeyError:
                raise KeyError(
                    f"unknown model id {model!r}; fleet serves "
                    f"{self.model_ids}") from None
        i = int(model)
        if not 0 <= i < self.n_models:
            raise IndexError(f"model index {i} out of range "
                             f"[0, {self.n_models})")
        return i

    def pair_slice(self, model: ModelRef) -> tuple[int, int]:
        """Column slice of this member in the ``(n, P_total)`` tensor."""
        return self._pair_slices[self.model_index(model)]

    def describe(self) -> str:
        parts = [f"FleetMachine({self.n_models} models, "
                 f"P_total={self.n_pairs_total}, d_max={self.n_features})"]
        for mid, m, (lo, hi) in zip(self.model_ids, self._members,
                                    self._pair_slices):
            parts.append(f"  [{mid}] cols {lo}:{hi} K={m.n_classes} "
                         f"P={m.n_pairs} d={m.n_features}")
        return "\n".join(parts)

    # -- the single co-batched forward --------------------------------------

    def _member_scores(self, i: int, x: jnp.ndarray) -> jnp.ndarray:
        """Member ``i``'s exact forward subgraph on its feature slice."""
        m = self._members[i]
        xm = x[:, : m.n_features] if m.n_features != x.shape[1] else x
        return _all_scores(xm, m._linear_banks, m._kernel_banks,
                           m._inv_perm, self.use_pallas,
                           interpret=self.interpret)

    def _forward(self, x: jnp.ndarray, model_idx: jnp.ndarray):
        """x (n, d_max), model_idx (n,) -> (labels (n,), scores (n, P_tot))."""
        cols, labels = [], []
        for i in range(self.n_models):
            scores = self._member_scores(i, x)
            bits = (scores >= 0.0).astype(jnp.int32)
            labels.append(self._deciders[i](bits).astype(jnp.int32))
            cols.append(scores)
        lab = jnp.stack(labels, axis=0)                      # (M, n)
        routed = jnp.take_along_axis(
            lab, model_idx[None, :].astype(jnp.int32), axis=0)[0]
        return routed, jnp.concatenate(cols, axis=1)

    def _labels(self, x: jnp.ndarray, model_idx: jnp.ndarray) -> jnp.ndarray:
        """Serving hot path: routed labels only.

        ``decider="votes"``: the forward's scores concat is DCE'd, labels
        come from the dense per-member decision encoders.  ``"dag"``:
        each member runs its K-1-step elimination front — O(n*K) pair
        evaluations per member instead of O(n*K^2).
        """
        if self.decider == "dag":
            labels = []
            for i, m in enumerate(self._members):
                xm = x[:, : m.n_features] \
                    if m.n_features != x.shape[1] else x
                labels.append(_dag_labels(
                    xm, m.n_classes, self._pair_matrices[i],
                    m._linear_banks, m._kernel_banks,
                    self._row_maps[i], self._step_plans[i]).astype(jnp.int32))
            lab = jnp.stack(labels, axis=0)                  # (M, n)
            return jnp.take_along_axis(
                lab, model_idx[None, :].astype(jnp.int32), axis=0)[0]
        return self._forward(x, model_idx)[0]

    # -- data-parallel serving leg (DESIGN.md §12.1) -------------------------

    def shard(self, mesh) -> "ShardedFleetForward":
        """The mesh-sharded labels program for ``mesh`` (cached per mesh).

        ``mesh`` is a 1-D ``launch.mesh.make_serving_mesh`` mesh; the
        returned :class:`ShardedFleetForward` runs this fleet's exact
        ``_labels`` program on each device's row slice (banks replicated,
        batch axis sharded, no collectives), so every per-device slice is
        bit-identical to the single-device forward on the same rows.
        """
        fwd = self._sharded.get(mesh)
        if fwd is None:
            fwd = ShardedFleetForward(self, mesh)
            self._sharded[mesh] = fwd
        return fwd

    # -- host API ------------------------------------------------------------

    def _pad_features(self, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x, np.float32)
        if x.ndim != 2 or x.shape[1] > self.n_features:
            raise ValueError(
                f"expected (n, <= {self.n_features}) inputs, got {x.shape}")
        if x.shape[1] < self.n_features:
            x = np.pad(x, ((0, 0), (0, self.n_features - x.shape[1])))
        return x

    def _resolve_idx(self, model, n: int) -> np.ndarray:
        if isinstance(model, (str, int, np.integer)):
            return np.full((n,), self.model_index(model), np.int32)
        idx = np.asarray([self.model_index(m) for m in model], np.int32)
        if idx.shape != (n,):
            raise ValueError(f"{idx.shape[0]} model ids for {n} rows")
        return idx

    def _run(self, x: np.ndarray, model):
        x = self._pad_features(x)
        idx = self._resolve_idx(model, x.shape[0])
        return self._forward_jit(jnp.asarray(x), jnp.asarray(idx))

    def predict(self, x: np.ndarray, model) -> np.ndarray:
        """Routed class labels (n,) via the compiled decision front.
        ``model`` is one id (str/int) for the whole batch or a per-row
        sequence of ids."""
        if self.decider == "dag":
            x = self._pad_features(x)
            idx = self._resolve_idx(model, x.shape[0])
            return np.asarray(
                self._labels_jit(jnp.asarray(x), jnp.asarray(idx)))
        return np.asarray(self._run(x, model)[0])

    def predict_votes(self, x: np.ndarray, model) -> np.ndarray:
        """Routed labels via the dense votes oracle, regardless of the
        compiled ``decider``."""
        return np.asarray(self._run(x, model)[0])

    def decision_scores(self, x: np.ndarray, model: ModelRef) -> np.ndarray:
        """ONE member's raw pair scores (n, P_m) out of the co-batched
        forward — the bit-identity probe against ``member.decision_scores``.
        """
        lo, hi = self.pair_slice(model)
        return np.asarray(self._run(x, model)[1][:, lo:hi])

    def predict_bits(self, x: np.ndarray, model: ModelRef) -> np.ndarray:
        """ONE member's comparator bits (n, P_m) from the co-batched pass."""
        return (self.decision_scores(x, model) >= 0.0).astype(np.int32)

    def accuracy(self, x: np.ndarray, y: np.ndarray, model: ModelRef) -> float:
        return float(np.mean(self.predict(x, model) == np.asarray(y)))

    # -- serialization (one npz + json for the whole fleet) ------------------

    def save(self, path: str) -> None:
        """Write ``<path>.npz`` + ``<path>.json`` packing every member."""
        path = _strip_ext(path)
        arrays: dict[str, np.ndarray] = {}
        members = []
        for i, (mid, m) in enumerate(zip(self.model_ids, self._members)):
            arr, meta_banks = _bank_arrays(
                m._linear_banks, m._kernel_banks, prefix=f"m{i}.")
            arrays.update(arr)
            members.append({"model_id": mid, "n_classes": m.n_classes,
                            "kernel_map": m.kernel_map, "banks": meta_banks})
        meta = {"format": _FLEET_FORMAT, "version": _FLEET_VERSION,
                "decider": self.decider, "members": members}
        np.savez(path + ".npz", **arrays)
        with open(path + ".json", "w") as f:
            json.dump(meta, f, indent=2)

    @classmethod
    def load(cls, path: str, use_pallas: Optional[bool] = None,
             interpret: Optional[bool] = None,
             decider: Optional[str] = None) -> "FleetMachine":
        path = _strip_ext(path)
        with open(path + ".json") as f:
            meta = json.load(f)
        if meta.get("format") != _FLEET_FORMAT:
            raise ValueError(f"{path}.json is not a FleetMachine save")
        npz = np.load(path + ".npz")
        ids, machines = [], []
        for entry in meta["members"]:
            linear_banks, kernel_banks = _banks_from_entries(
                entry["banks"], npz)
            ids.append(entry["model_id"])
            machines.append(CompiledMachine(
                entry["n_classes"], linear_banks, kernel_banks,
                kernel_map=entry.get("kernel_map"), use_pallas=use_pallas,
                interpret=interpret))
        if decider is None:
            decider = meta.get("decider", "votes")
        return cls(ids, machines, use_pallas=use_pallas, interpret=interpret,
                   decider=decider)


class ShardedFleetForward:
    """Data-parallel fleet labels over a ``make_serving_mesh`` (DESIGN.md §12.1).

    ``shard_map`` splits the ``(n, d_max)`` batch across the mesh's
    ``"batch"`` axis; banks are replicated (they are closed-over
    constants of the member subgraphs) and there are NO collectives, so
    each device executes the *identical* single-device ``_labels``
    program on its ``n / n_devices`` row slice — the PR 7 bit-identity
    contract extends per shard.  The jit keeps the serving hot path's
    donation: ``model_idx`` (i32 ``(n,)``) is donated and reused for the
    label output, verified by the analyzer on a 1-device mesh
    (``FleetMachine._labels[sharded]`` entry point).

    Callers pass HOST numpy arrays whose row count is a multiple of
    ``n_devices`` (the engine rounds buckets to whole per-device slices
    and validity-masks the tail padding); jit commits them straight to
    the sharded layout — no per-dispatch ``device_put`` round trip.
    """

    def __init__(self, fleet: FleetMachine, mesh):
        from jax.experimental.shard_map import shard_map
        from jax.sharding import NamedSharding, PartitionSpec

        from repro.launch.mesh import SERVING_AXIS

        if tuple(mesh.axis_names) != (SERVING_AXIS,):
            raise ValueError(
                f"serving mesh needs the 1-D axis ({SERVING_AXIS!r},) "
                f"(launch.mesh.make_serving_mesh); got {mesh.axis_names}")
        self.fleet = fleet
        self.mesh = mesh
        self.n_devices = int(mesh.shape[SERVING_AXIS])
        spec = PartitionSpec(SERVING_AXIS)
        self._sharding = NamedSharding(mesh, spec)
        self._labels_jit = jax.jit(
            shard_map(fleet._labels, mesh=mesh, in_specs=(spec, spec),
                      out_specs=spec),
            in_shardings=(self._sharding, self._sharding),
            out_shardings=self._sharding,
            donate_argnums=(1,))

    def global_rows(self, per_device_rows: int) -> int:
        """Whole-slice rounding: the global batch for one device bucket."""
        return int(per_device_rows) * self.n_devices

    def __call__(self, x, model_idx) -> jnp.ndarray:
        """Async sharded dispatch; rows must divide evenly over devices."""
        n = x.shape[0]
        if n % self.n_devices:
            raise ValueError(
                f"{n} rows not divisible into {self.n_devices} device "
                f"slices; pad to whole per-device slices first")
        return self._labels_jit(x, model_idx)

    def predict(self, x: np.ndarray, model) -> np.ndarray:
        """Blocking convenience wrapper: pads the tail to whole per-device
        slices (zeros, model 0 — computed and discarded), trims on return."""
        x = self.fleet._pad_features(x)
        idx = self.fleet._resolve_idx(model, x.shape[0])
        n = x.shape[0]
        pad = -n % self.n_devices
        if pad:
            x = np.pad(x, ((0, pad), (0, 0)))
            idx = np.pad(idx, (0, pad))
        return np.asarray(self(x, idx))[:n]


def compile_fleet(
    machines: Union[Mapping[str, CompiledMachine],
                    Sequence[tuple[str, CompiledMachine]],
                    Sequence[CompiledMachine]],
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
    decider: str = "votes",
) -> FleetMachine:
    """Concatenate compiled machines into one co-batched :class:`FleetMachine`.

    ``machines`` is a ``{model_id: CompiledMachine}`` mapping (insertion
    order fixes the member order), a sequence of ``(model_id, machine)``
    pairs, or a bare sequence of machines (ids default to ``"model<i>"``).
    A single-member fleet is valid — it is how the serving engine wraps a
    lone :class:`CompiledMachine`.
    """
    if isinstance(machines, Mapping):
        items = list(machines.items())
    else:
        items = []
        for i, it in enumerate(machines):
            if isinstance(it, tuple) and len(it) == 2:
                items.append((str(it[0]), it[1]))
            else:
                items.append((f"model{i}", it))
    ids = [i for i, _ in items]
    members = [m for _, m in items]
    for m in members:
        if not isinstance(m, CompiledMachine):
            raise TypeError(
                f"compile_fleet takes CompiledMachine members, got "
                f"{type(m).__name__}; lower with compile_machine first")
    return FleetMachine(ids, members, use_pallas=use_pallas,
                        interpret=interpret, decider=decider)
