"""CompiledMachine: one batched JAX inference path for an OvO classifier bank.

The legacy object path (``MulticlassSVM.predict_bits``) is a host-side Python
loop: one ``predict_bits`` call per pair classifier, each with its own device
dispatches and host round-trips.  ``compile_machine`` *lowers* any bank of
bit-classifiers into padded, stacked arrays grouped into a small number of
homogeneous "banks", and ``CompiledMachine.predict`` evaluates the whole
machine — every pair score, the comparator bits, and the decision encoder —
inside a single jit-compiled function: one device round-trip per batch.

Pytree layout (DESIGN.md §1.2)
------------------------------
Pairs are grouped by datapath; each group is one bank of stacked arrays:

* ``_LinearBank``  — all pairs whose score is an affine form.  One fused
  matmul ``x_q @ W.T + b`` scores every linear pair at once.
  Fields: ``w (P, d)``, ``b (P,)``; static: ``input_bits``, ``pair_idx``.

* ``_KernelBank``  — kernel pairs sharing (kernel kind, input quantization,
  transfer curve).  Support vectors are padded to the bank max ``M`` and
  stacked; padded slots carry coefficient 0 so they contribute exactly
  nothing.  Fields: ``sv (P, M, d)``, ``coef_pos/coef_neg (P, M)``,
  ``bias_pos/bias_neg/offset/gamma/scale (P,)`` plus the measured transfer
  curve (``grid``, ``curve``) for the analog 'hw' kind.  The pos/neg split
  mirrors the analog rails: ``f = (K @ c+ + b+) - (K @ c- + b-) + offset``
  reproduces the comparator's current difference bit-for-bit; digital and
  float pairs simply keep the negative rail empty.

Kernel dispatch: 'rbf' and 'sech2' banks go to the tiled Pallas kernel
(``repro.kernels.ops.rbf_matrix``) when ``use_pallas`` is on (default: only
on TPU, where the tiles compile to Mosaic; the CPU container would run the
Pallas interpreter, so it uses the identical-math jnp path instead).  The
'hw' kind evaluates the calibrated measured-curve kernel (interp + product)
exactly as the behavioral model does.

The decision encoder is the packed truth table of ``build_encoder_table``
for P <= 12 pair bits (the paper's K <= 5 regime); larger machines fall back
to the equivalent votes-matmul + argmax (lowest-index tiebreak).

``compile_candidates`` / ``CandidateMachine`` reuse the same lowering and
bank evaluation to expose the assignment-independent per-pair candidate
bit tensor ``pair_bits(x) -> (n, P, 2)`` that the kernel-assignment
design-space explorer (``repro.core.dse``, DESIGN.md §5) recombines into
every candidate machine's output without re-evaluating any classifier.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dse
from repro.core import kernels as kern
from repro.core import mcstream, quant
from repro.core.analog import (
    N_ALPHA_OFFSETS,
    N_GAUSS_OFFSETS,
    AnalogBinaryClassifier,
    VariantSet,
    variant_dim,
    variant_set_from_flat,
    variant_transfer_params,
)
from repro.core.ovo import (
    MAX_TABLE_BITS,
    DigitalLinearClassifier,
    DigitalRBFClassifier,
    MulticlassSVM,
    build_encoder_table,
    class_pairs,
    pair_index_matrix,
)
from repro.core.svm import SVMModel

_FORMAT_VERSION = 1

# MAX_TABLE_BITS (re-exported above from repro.core.ovo): encoder truth
# tables are materialised up to that many pair bits (2^12 = 4096 entries);
# beyond it the votes matmul — or the O(K) DAG front — is used.


# ---------------------------------------------------------------------------
# Per-pair lowering specs (host-side, produced by compile_machine)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _LinearSpec:
    pair: int
    input_bits: int          # 0 = float input, else ADC bits
    w: np.ndarray            # (d,)
    b: float


@dataclasses.dataclass
class _KernelSpec:
    pair: int
    kind: str                # 'rbf' | 'sech2' | 'hw'
    input_bits: int
    sv: np.ndarray           # (m, d)
    coef_pos: np.ndarray     # (m,)
    coef_neg: np.ndarray     # (m,)
    bias_pos: float
    bias_neg: float
    offset: float            # comparator offset (analog), else 0
    gamma: float             # rbf/sech2 width; unused for 'hw'
    scale: float             # 'hw': prefolded v_scale * input_scale(gamma*)
    shift: float = 0.0       # 'hw': fitted center offset mu (kernel_1d query)
    grid: Optional[np.ndarray] = None    # 'hw': measured sweep abscissa (V)
    curve: Optional[np.ndarray] = None   # 'hw': measured transfer, peak 1
    left: float = 0.0        # interp clamp values
    right: float = 0.0


def _f32(a) -> np.ndarray:
    return np.asarray(a, np.float32)


def _lower_svm_model(idx: int, model: SVMModel) -> _LinearSpec | _KernelSpec:
    """Lower a float SVMModel (a FloatBitClassifier's payload)."""
    if model.kind == "linear" and model.w is not None:
        return _LinearSpec(pair=idx, input_bits=0, w=_f32(model.w),
                           b=float(model.bias))
    coef = _f32(model.alpha * model.support_y)
    base = dict(pair=idx, input_bits=0, sv=_f32(model.support_x),
                coef_pos=coef, coef_neg=np.zeros_like(coef),
                bias_pos=float(model.bias), bias_neg=0.0, offset=0.0)
    if model.kind in ("rbf", "sech2"):
        return _KernelSpec(kind=model.kind, gamma=float(model.gamma),
                           scale=1.0, **base)
    if model.kind == "hw":
        hw = getattr(model.kernel_fn, "__self__", None)
        if hw is None:
            raise TypeError(
                "cannot lower kind='hw' model: kernel_fn is not a bound "
                "AnalogRBFModel.kernel_response method")
        # Prefold the Eq.-8 input scaling exactly as kernel_response does:
        # dv = (v_scale * s) * (x - sv), with the product taken in f32.
        scale = float(jnp.float32(hw.v_scale)
                      * hw.input_scale(jnp.float32(model.gamma)))
        curve = _f32(hw.kernel_curve)
        return _KernelSpec(kind="hw", gamma=float(model.gamma), scale=scale,
                           shift=float(hw.mu), grid=_f32(hw.dv_grid),
                           curve=curve, left=float(hw.kernel_curve[0]),
                           right=float(hw.kernel_curve[-1]), **base)
    raise TypeError(f"cannot lower SVMModel of kind {model.kind!r}")


def _lower_classifier(idx: int, clf) -> _LinearSpec | _KernelSpec:
    """Lower one bit-classifier object into its stacked-array spec."""
    if isinstance(clf, DigitalLinearClassifier):
        return _LinearSpec(pair=idx, input_bits=clf.input_bits,
                           w=_f32(clf.w_q), b=float(clf.b_q))
    if isinstance(clf, DigitalRBFClassifier):
        coef = _f32(clf.coef)
        return _KernelSpec(
            pair=idx, kind="rbf", input_bits=clf.input_bits,
            sv=_f32(clf.support_x), coef_pos=coef,
            coef_neg=np.zeros_like(coef), bias_pos=float(clf.bias),
            bias_neg=0.0, offset=0.0, gamma=float(clf.gamma), scale=1.0)
    if isinstance(clf, AnalogBinaryClassifier):
        hw = clf.hw
        # Freeze the alpha path at compile time with the very same f32 ops
        # the behavioral model runs per call: desired alpha -> control
        # voltage (Eq. 9) -> realised alpha (measured sweep).
        dva = hw.alpha_control_voltage(jnp.asarray(clf.alpha_hw, jnp.float32))
        a = _f32(hw.alpha_realized(dva))
        pos = (clf.support_y > 0)
        scale = float(jnp.float32(hw.v_scale)
                      * hw.input_scale(jnp.float32(clf.gamma_star)))
        return _KernelSpec(
            pair=idx, kind="hw", input_bits=0, sv=_f32(clf.support_x),
            coef_pos=a * pos, coef_neg=a * (~pos),
            bias_pos=float(max(clf.bias_hw, 0.0)),
            bias_neg=float(max(-clf.bias_hw, 0.0)),
            offset=float(hw.params.comparator_offset / hw.params.i_bias),
            gamma=float(clf.gamma_star), scale=scale, shift=float(hw.mu),
            grid=_f32(hw.dv_grid), curve=_f32(hw.kernel_curve),
            left=float(hw.kernel_curve[0]), right=float(hw.kernel_curve[-1]))
    if isinstance(clf, SVMModel):
        return _lower_svm_model(idx, clf)
    model = getattr(clf, "model", None)   # FloatBitClassifier & duck-typed
    if isinstance(model, SVMModel):
        return _lower_svm_model(idx, model)
    raise TypeError(f"cannot lower classifier of type {type(clf).__name__}")


# ---------------------------------------------------------------------------
# Banks: grouped, padded, stacked arrays
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _LinearBank:
    input_bits: int
    pair_idx: np.ndarray     # (P,)
    w: jnp.ndarray           # (P, d)
    b: jnp.ndarray           # (P,)

    @classmethod
    def build(cls, specs: list[_LinearSpec]) -> "_LinearBank":
        return cls(
            input_bits=specs[0].input_bits,
            pair_idx=np.asarray([s.pair for s in specs]),
            w=jnp.asarray(np.stack([s.w for s in specs])),
            b=jnp.asarray(np.asarray([s.b for s in specs], np.float32)),
        )


@dataclasses.dataclass
class _KernelBank:
    kind: str
    input_bits: int
    pair_idx: np.ndarray     # (P,)
    sv: jnp.ndarray          # (P, M, d), zero-padded to bank max M
    coef_pos: jnp.ndarray    # (P, M), 0 on padded slots
    coef_neg: jnp.ndarray    # (P, M)
    bias_pos: jnp.ndarray    # (P,)
    bias_neg: jnp.ndarray    # (P,)
    offset: jnp.ndarray      # (P,)
    gamma: jnp.ndarray       # (P,)
    scale: jnp.ndarray       # (P,)
    shift: jnp.ndarray = None  # (P,) 'hw' center offsets
    grid: Optional[jnp.ndarray] = None
    curve: Optional[jnp.ndarray] = None
    left: float = 0.0
    right: float = 0.0
    # Uniform-grid fast path for the measured-curve interpolation (derived
    # from `grid` at build/load time, not serialized).
    uniform_grid: bool = False
    inv_step: float = 0.0

    @classmethod
    def build(cls, specs: list[_KernelSpec]) -> "_KernelBank":
        m_max = max(s.sv.shape[0] for s in specs)

        def pad(a):
            out = np.zeros((m_max,) + a.shape[1:], np.float32)
            out[: a.shape[0]] = a
            return out

        s0 = specs[0]
        return cls(
            kind=s0.kind, input_bits=s0.input_bits,
            pair_idx=np.asarray([s.pair for s in specs]),
            sv=jnp.asarray(np.stack([pad(s.sv) for s in specs])),
            coef_pos=jnp.asarray(
                np.stack([pad(s.coef_pos) for s in specs])),
            coef_neg=jnp.asarray(
                np.stack([pad(s.coef_neg) for s in specs])),
            bias_pos=jnp.asarray(
                np.asarray([s.bias_pos for s in specs], np.float32)),
            bias_neg=jnp.asarray(
                np.asarray([s.bias_neg for s in specs], np.float32)),
            offset=jnp.asarray(
                np.asarray([s.offset for s in specs], np.float32)),
            gamma=jnp.asarray(
                np.asarray([s.gamma for s in specs], np.float32)),
            scale=jnp.asarray(
                np.asarray([s.scale for s in specs], np.float32)),
            shift=jnp.asarray(
                np.asarray([s.shift for s in specs], np.float32)),
            grid=None if s0.grid is None else jnp.asarray(s0.grid),
            curve=None if s0.curve is None else jnp.asarray(s0.curve),
            left=s0.left, right=s0.right,
            **_grid_fast_path(s0.grid),
        )


# Uniform-grid fast-path helpers now live in repro.core.kernels (the batched
# trainer uses them for hardware-in-the-loop training too); re-exported here
# for existing call sites and tests.
from repro.core.kernels import (  # noqa: E402  (re-export)
    _grid_fast_path,
    _grid_is_uniform,
    _uniform_interp,
)


# The banks are genuine pytrees: array fields are leaves, everything that
# selects a compiled program (kind, bit widths, grid geometry) is static
# aux data.  Today the machines close over the banks as jit constants;
# registration is what lets them cross a jit boundary as *arguments*
# instead (the bank-donation refactor ROADMAP item 2 needs) without the
# trace treating them as opaque objects.  pair_idx is host-side build
# metadata — it rides in aux as a hashable tuple.

def _linear_bank_flatten(b: _LinearBank):
    return (b.w, b.b), (b.input_bits, tuple(b.pair_idx.tolist()))


def _linear_bank_unflatten(aux, children) -> _LinearBank:
    input_bits, pair_idx = aux
    w, b = children
    return _LinearBank(input_bits=input_bits,
                       pair_idx=np.asarray(pair_idx), w=w, b=b)


jax.tree_util.register_pytree_node(
    _LinearBank, _linear_bank_flatten, _linear_bank_unflatten)

_KERNEL_BANK_DATA = ("sv", "coef_pos", "coef_neg", "bias_pos", "bias_neg",
                     "offset", "gamma", "scale", "shift", "grid", "curve")
_KERNEL_BANK_AUX = ("kind", "input_bits", "left", "right", "uniform_grid",
                    "inv_step")


def _kernel_bank_flatten(b: _KernelBank):
    aux = tuple(getattr(b, f) for f in _KERNEL_BANK_AUX) \
        + (tuple(b.pair_idx.tolist()),)
    return tuple(getattr(b, f) for f in _KERNEL_BANK_DATA), aux


def _kernel_bank_unflatten(aux, children) -> _KernelBank:
    kw = dict(zip(_KERNEL_BANK_DATA, children))
    kw.update(zip(_KERNEL_BANK_AUX, aux))
    kw["pair_idx"] = np.asarray(aux[-1])
    return _KernelBank(**kw)


jax.tree_util.register_pytree_node(
    _KernelBank, _kernel_bank_flatten, _kernel_bank_unflatten)


def _kernel_group_key(s: _KernelSpec):
    curve_key = None
    if s.grid is not None:
        curve_key = (s.grid.shape[0], hash(s.grid.tobytes()),
                     hash(s.curve.tobytes()))
    return (s.kind, s.input_bits, curve_key)


# ---------------------------------------------------------------------------
# Bank evaluation: shared by CompiledMachine and CandidateMachine
# ---------------------------------------------------------------------------


def _bank_cell(bank: _KernelBank, dv: jnp.ndarray) -> jnp.ndarray:
    """The bank's measured 1-D transfer (shared nominal/variant code path)."""
    return kern.measured_cell(dv, bank.grid, bank.curve, bank.left,
                              bank.right, bank.uniform_grid,
                              jnp.float32(bank.inv_step))


def _pair_kernel(bank: _KernelBank, xv: jnp.ndarray, sv: jnp.ndarray,
                 gamma, scale, shift, use_pallas: bool,
                 vshift=None, vgain=None,
                 interpret: Optional[bool] = None) -> jnp.ndarray:
    """(n, M) kernel matrix of ONE pair (vmapped over the bank).

    ``vshift``/``vgain`` (M, d), when given, evaluate ONE Monte-Carlo
    variant's per-cell perturbed transfers (DESIGN.md §6.2):
    ``gain * curve(dv + mu - vshift)``.  The zero-offset variant subtracts
    an exact 0 and multiplies by an exact 1 around the very same
    ``_bank_cell`` interpolation the nominal path runs — bit-identical.
    """
    if bank.kind == "hw":
        d = int(bank.sv.shape[-1])
        # Per-dimension accumulation: (n, M) temporaries instead of one
        # (n, M, d) tensor — same sequential multiply order as jnp.prod,
        # far less memory traffic.  d <= 5 in hardware.
        acc = None
        for k in range(d):
            dv = scale * (xv[:, k:k + 1] - sv[None, :, k]) + shift
            if vshift is not None:
                dv = dv - vshift[None, :, k]
            k1 = _bank_cell(bank, dv)
            if vgain is not None:
                k1 = k1 * vgain[None, :, k]
            acc = k1 if acc is None else acc * k1
        return acc
    if use_pallas:
        from repro.kernels import ops

        return ops.rbf_matrix(xv, sv, gamma, kind=bank.kind, v_scale=1.0,
                              interpret=interpret)
    return kern.kernel_matrix(bank.kind, xv, sv, gamma)


def _bank_scores(bank: _KernelBank, xv: jnp.ndarray,
                 use_pallas: bool,
                 interpret: Optional[bool] = None) -> jnp.ndarray:
    """(n, P) decision scores for one kernel bank, kernel + contraction
    fused per pair: the (n, M) kernel tile feeds one (M, 2) GEMM for the
    +/- rails while it is still hot."""

    def one(sv, gamma, scale, shift, cpos, cneg, bpos, bneg, off):
        k = _pair_kernel(bank, xv, sv, gamma, scale, shift, use_pallas,
                         interpret=interpret)
        rails = k @ jnp.stack([cpos, cneg], axis=1)      # (n, 2)
        return (rails[:, 0] + bpos) - (rails[:, 1] + bneg) + off

    return jax.vmap(one, out_axes=1)(
        bank.sv, bank.gamma, bank.scale, bank.shift,
        bank.coef_pos, bank.coef_neg,
        bank.bias_pos, bank.bias_neg, bank.offset)


def _all_scores(x: jnp.ndarray, linear_banks, kernel_banks,
                inv_perm: jnp.ndarray, use_pallas: bool,
                interpret: Optional[bool] = None) -> jnp.ndarray:
    """x (n, d) f32 -> scores (n, P) in lowering (pair-index) order.

    Input quantization is computed once per distinct ADC width and shared
    across banks; the bank columns are concatenated and un-permuted back to
    pair order through ``inv_perm``.
    """
    xq_cache: dict[int, jnp.ndarray] = {}

    def xq(bits: int) -> jnp.ndarray:
        if bits not in xq_cache:
            xq_cache[bits] = x if bits == 0 else quant.quantize_unit(x, bits)
        return xq_cache[bits]

    cols = []
    for bank in linear_banks:
        cols.append(xq(bank.input_bits) @ bank.w.T + bank.b[None, :])
    for bank in kernel_banks:
        cols.append(_bank_scores(bank, xq(bank.input_bits), use_pallas,
                                 interpret=interpret))
    return jnp.concatenate(cols, axis=1)[:, inv_perm]


def _group_specs(
    specs: list,
) -> tuple[list[list[_LinearSpec]], list[list[_KernelSpec]]]:
    """Group lowered specs by datapath (the bank partition)."""
    linear_groups: dict[int, list[_LinearSpec]] = {}
    kernel_groups: dict[tuple, list[_KernelSpec]] = {}
    for s in specs:
        if isinstance(s, _LinearSpec):
            linear_groups.setdefault(s.input_bits, []).append(s)
        else:
            kernel_groups.setdefault(_kernel_group_key(s), []).append(s)
    return list(linear_groups.values()), list(kernel_groups.values())


def _build_banks(specs: list) -> tuple[list[_LinearBank], list[_KernelBank]]:
    """Group lowered specs by datapath into padded stacked banks."""
    linear_groups, kernel_groups = _group_specs(specs)
    return ([_LinearBank.build(g) for g in linear_groups],
            [_KernelBank.build(g) for g in kernel_groups])


def _inverse_perm(linear_banks, kernel_banks, n_total: int) -> jnp.ndarray:
    """Column order after bank concatenation -> lowering order inversion."""
    order = np.concatenate(
        [b.pair_idx for b in linear_banks]
        + [b.pair_idx for b in kernel_banks]).astype(np.int64)
    if order.shape[0] != n_total:
        raise ValueError(
            f"{order.shape[0]} lowered columns != {n_total} expected")
    inv = np.empty_like(order)
    inv[order] = np.arange(n_total)
    return jnp.asarray(inv)


def _bank_feature_dim(linear_banks, kernel_banks) -> int:
    dims = {int(b.w.shape[1]) for b in linear_banks} | \
        {int(b.sv.shape[2]) for b in kernel_banks}
    if len(dims) > 1:
        raise ValueError(f"inconsistent feature counts across banks: {dims}")
    return dims.pop() if dims else 0


# ---------------------------------------------------------------------------
# Decision encoder: packed truth table or votes matmul
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Decider:
    """Pair bits ``(..., P)`` -> class labels ``(...,)``.

    The packed truth table of ``build_encoder_table`` in the FE regime
    (``P <= MAX_TABLE_BITS``), the equivalent votes matmul + argmax
    (lowest-index tiebreak) beyond it.  Shared by :class:`CompiledMachine`
    and the multi-model :class:`~repro.api.fleet.FleetMachine`, so the
    fleet's per-member decision subgraph is literally the member's own.
    """

    table: Optional[jnp.ndarray]        # (2^P,) packed labels, or None
    bit_weights: Optional[jnp.ndarray]  # (P,) 1 << arange(P), or None
    vote_a: Optional[jnp.ndarray]       # (P, K) votes for class i of pair
    vote_b: Optional[jnp.ndarray]       # (P, K) votes for class j of pair

    @classmethod
    def build(cls, n_classes: int) -> "_Decider":
        pairs = class_pairs(n_classes)
        n_pairs = len(pairs)
        if n_pairs <= MAX_TABLE_BITS:
            return cls(
                table=jnp.asarray(build_encoder_table(n_classes)),
                bit_weights=jnp.asarray(
                    (1 << np.arange(n_pairs)).astype(np.int32)),
                vote_a=None, vote_b=None)
        a = np.zeros((n_pairs, n_classes), np.int32)
        b = np.zeros((n_pairs, n_classes), np.int32)
        for p, (i, j) in enumerate(pairs):
            a[p, i] = 1
            b[p, j] = 1
        return cls(table=None, bit_weights=None,
                   vote_a=jnp.asarray(a), vote_b=jnp.asarray(b))

    def __call__(self, bits: jnp.ndarray) -> jnp.ndarray:
        if self.table is not None:
            return jnp.take(self.table, bits @ self.bit_weights)
        votes = bits @ self.vote_a + (1 - bits) @ self.vote_b
        return jnp.argmax(votes, axis=-1)


# Registered so a decider can cross jit boundaries as an argument (None
# fields are empty subtrees); which of the two paths runs is decided at
# trace time by the table's presence.
jax.tree_util.register_dataclass(
    _Decider,
    data_fields=("table", "bit_weights", "vote_a", "vote_b"),
    meta_fields=())


# ---------------------------------------------------------------------------
# DAG decision front: O(K) pair evaluations per sample (DESIGN.md §11)
# ---------------------------------------------------------------------------

#: Decision fronts a machine can be compiled with.  ``"votes"`` is the
#: seed semantics (every pair evaluated, encoder table / votes argmax);
#: ``"dag"`` is the DDAG elimination front — K-1 pair evaluations per
#: sample, exactly equal to votes wherever a Condorcet winner exists
#: (``repro.core.ovo.decide_dag`` states and proves the contract).
DECIDERS = ("votes", "dag")


def _dag_row_maps(linear_banks, kernel_banks, n_pairs: int):
    """Host-built global-pair -> bank-row gather maps, one per bank.

    For each bank (linear banks first, then kernel banks — the DAG front
    iterates them in the same order) returns ``(rows, mask)``: ``rows
    (P,)`` int32 maps a global pair index to the bank row holding it
    (clamped to 0 where the bank does not own the pair) and ``mask (P,)``
    f32 is 1.0 exactly on the owned pairs.  A sample's per-step score is
    the masked sum over banks, so every pair is scored by precisely the
    datapath that owns it.
    """
    maps = []
    for b in list(linear_banks) + list(kernel_banks):
        rows = np.zeros(n_pairs, np.int32)
        mask = np.zeros(n_pairs, np.float32)
        for r, g in enumerate(np.asarray(b.pair_idx)):
            rows[int(g)] = r
            mask[int(g)] = 1.0
        maps.append((jnp.asarray(rows), jnp.asarray(mask)))
    return maps


def _dag_step_plans(linear_banks, kernel_banks, n_classes: int):
    """Static per-step work plans for the DAG gather front.

    At step ``t`` the carried interval satisfies ``hi - lo == K-1-t``, so
    the only pairs a sample can visit are ``{(j, j + K-1-t) : j <= t}`` —
    a set known at trace time.  For every bank and step this precomputes:

    * ``None`` — the bank owns no reachable pair: skip it entirely (its
      masked contribution would be an exact ``0.0`` for every sample);
    * ``-1`` (linear banks) — participate, nothing to slice;
    * ``m_t > 0`` (kernel banks) — participate, and statically slice the
      support axis to the max TRUE support count over the reachable owned
      pairs.  Padded slots carry zero coefficients, so dropping them
      removes exact ``+0.0`` terms from the score sum — bit-identical
      labels, ``sum_t m_t`` kernel evaluations per sample instead of
      ``(K-1) * M``.

    On mixed Algorithm-1 designs this is a large static win: far-apart
    class pairs (the early, large-gap steps) are typically linear, so
    whole kernel banks drop out of the first steps, and the hard
    small-gap pairs that stay analog rarely all share the bank-wide
    padded ``M``.
    """
    pairs = class_pairs(n_classes)
    idx = {p: i for i, p in enumerate(pairs)}
    n_lin = len(linear_banks)
    owned = []
    for bi, b in enumerate(list(linear_banks) + list(kernel_banks)):
        if bi < n_lin:
            owned.append({int(g): 0 for g in np.asarray(b.pair_idx)})
        else:
            coef = np.abs(np.asarray(b.coef_pos)) \
                + np.abs(np.asarray(b.coef_neg))           # (P, M)
            true_m = (coef != 0.0).sum(axis=1)
            owned.append({int(g): int(mm)
                          for g, mm in zip(np.asarray(b.pair_idx), true_m)})
    plans = []
    for t in range(n_classes - 1):
        gap = n_classes - 1 - t
        reach = [idx[(j, j + gap)] for j in range(t + 1)]
        plan = []
        for bi, o in enumerate(owned):
            ms = [o[p] for p in reach if p in o]
            if not ms:
                plan.append(None)
            elif bi < n_lin:
                plan.append(-1)
            else:
                plan.append(max(max(ms), 1))
        plans.append(tuple(plan))
    return plans


def _gather_pair_scores(p, linear_banks, kernel_banks, row_maps, xq_cache,
                        plan=None):
    """Decision scores of ONE (per-sample dynamic) pair: ``p (n,) -> (n,)``.

    The gather sibling of ``_all_scores``: instead of evaluating every
    bank column, each sample gathers the parameters of the single pair
    ``p[i]`` from the bank that owns it and evaluates just that one
    classifier.  Kernel banks run the per-sample kernel through the SAME
    ``_pair_kernel`` arithmetic as the dense path (``use_pallas=False``
    deliberately: the Pallas tile kernels are per-pair-column programs and
    would degenerate under the per-sample vmap; the jnp lowering is
    bit-identical math).

    ``plan`` (one entry of :func:`_dag_step_plans`) statically skips
    banks that own no reachable pair this step and slices kernel-bank
    gathers to the reachable true support count — both exact.
    """
    total = jnp.zeros(p.shape[0], jnp.float32)
    mi = 0
    for bank in linear_banks:
        rows, mask = row_maps[mi]
        step = None if plan is None else plan[mi]
        mi += 1
        if plan is not None and step is None:
            continue
        r = rows[p]                                        # (n,)
        xv = xq_cache[bank.input_bits]
        s = jnp.sum(xv * bank.w[r], axis=-1) + bank.b[r]
        total = total + mask[p] * s
    for bank in kernel_banks:
        rows, mask = row_maps[mi]
        step = None if plan is None else plan[mi]
        mi += 1
        if plan is not None and step is None:
            continue
        m_t = bank.sv.shape[1] if (plan is None or step == -1) else int(step)
        r = rows[p]

        def one(xi, sv, gamma, scale, shift, cpos, cneg, bpos, bneg, off):
            k = _pair_kernel(bank, xi[None, :], sv, gamma, scale, shift,
                             False)[0]                     # (m_t,)
            return (jnp.dot(k, cpos) + bpos) \
                - (jnp.dot(k, cneg) + bneg) + off

        s = jax.vmap(one)(
            xq_cache[bank.input_bits], bank.sv[:, :m_t][r], bank.gamma[r],
            bank.scale[r], bank.shift[r], bank.coef_pos[:, :m_t][r],
            bank.coef_neg[:, :m_t][r], bank.bias_pos[r], bank.bias_neg[r],
            bank.offset[r])
        total = total + mask[p] * s
    return total


def _dag_labels(x, n_classes: int, pair_matrix, linear_banks, kernel_banks,
                row_maps, step_plans=None):
    """DDAG elimination front: ``x (n, d) -> labels (n,)`` in O(n*K).

    An unrolled loop of K-1 steps carries the per-sample candidate
    interval ``(lo, hi)``; each step evaluates pair ``(lo, hi)`` through
    the gather front and eliminates one endpoint (bit 1 = the lower class
    wins, matching the ``class_pairs`` bit convention and the numpy
    reference ``repro.core.ovo.decide_dag``).  The loop is a trace-time
    Python loop (not ``lax.scan``) because each step runs a DIFFERENT
    static plan from :func:`_dag_step_plans` — banks with no reachable
    pair drop out of the step, kernel gathers slice to the reachable true
    support count.  Total pair evaluations: ``n * (K-1)`` instead of the
    dense path's ``n * K(K-1)/2``, and the kernel-bank work shrinks
    further to ``n * sum_t m_t``.
    """
    xq_cache: dict[int, jnp.ndarray] = {}
    for bank in list(linear_banks) + list(kernel_banks):
        bits = bank.input_bits
        if bits not in xq_cache:
            xq_cache[bits] = x if bits == 0 else quant.quantize_unit(x, bits)
    n = x.shape[0]
    lo = jnp.zeros(n, jnp.int32)
    hi = jnp.full(n, n_classes - 1, jnp.int32)
    for t in range(n_classes - 1):
        p = pair_matrix[lo, hi]                            # (n,)
        plan = None if step_plans is None else step_plans[t]
        s = _gather_pair_scores(p, linear_banks, kernel_banks, row_maps,
                                xq_cache, plan)
        win = s >= 0.0                                     # lower class wins
        lo = jnp.where(win, lo, lo + 1)
        hi = jnp.where(win, hi - 1, hi)
    return lo


# ---------------------------------------------------------------------------
# The compiled machine
# ---------------------------------------------------------------------------


class CompiledMachine:
    """A bank of OvO bit-classifiers lowered to one jit-compiled predict.

    Construct via :func:`compile_machine` (from live classifier objects) or
    :meth:`CompiledMachine.load` (from an ``.npz`` + ``.json`` pair).
    """

    def __init__(
        self,
        n_classes: int,
        linear_banks: list[_LinearBank],
        kernel_banks: list[_KernelBank],
        kernel_map: Optional[list[str]] = None,
        use_pallas: Optional[bool] = None,
        interpret: Optional[bool] = None,
        decider: str = "votes",
    ):
        self.n_classes = int(n_classes)
        self._linear_banks = linear_banks
        self._kernel_banks = kernel_banks
        self.n_pairs = sum(len(b.pair_idx) for b in linear_banks) + \
            sum(len(b.pair_idx) for b in kernel_banks)
        expect = len(class_pairs(self.n_classes))
        if self.n_pairs != expect:
            raise ValueError(
                f"{self.n_pairs} lowered pairs for {self.n_classes} classes "
                f"(expected {expect})")
        self.kernel_map = list(kernel_map) if kernel_map is not None else None
        self.n_features = _bank_feature_dim(linear_banks, kernel_banks)
        if use_pallas is None:
            use_pallas = jax.default_backend() == "tpu"
        self.use_pallas = bool(use_pallas)
        # None follows the kernels.ops backend default (interpreter off
        # TPU); a bool forces it, so CPU CI can exercise the compiled-mode
        # Pallas path deliberately (DESIGN.md SS7.5).
        self.interpret = interpret

        # Column order after bank concatenation -> pair order inversion.
        self._inv_perm = _inverse_perm(linear_banks, kernel_banks,
                                       self.n_pairs)

        # Decision encoder: packed truth table in the FE regime, votes
        # matmul beyond it (identical semantics, see ovo.decide_votes).
        self._decider = _Decider.build(self.n_classes)

        # Decision front for `predict`: the dense votes path (seed
        # semantics, always compiled — it stays the oracle behind
        # `predict_votes`/`decision_scores`/`predict_bits`), optionally
        # shadowed by the O(K) DAG elimination front.
        if decider not in DECIDERS:
            raise ValueError(
                f"unknown decider {decider!r}; one of {DECIDERS}")
        self.decider = decider
        self._pair_matrix = None
        self._row_maps = None
        self._step_plans = None
        self._labels_dag_jit = None
        if decider == "dag":
            self._pair_matrix = jnp.asarray(
                pair_index_matrix(self.n_classes))
            self._row_maps = _dag_row_maps(linear_banks, kernel_banks,
                                           self.n_pairs)
            self._step_plans = _dag_step_plans(linear_banks, kernel_banks,
                                               self.n_classes)
            self._labels_dag_jit = jax.jit(self._labels_dag)

        self._forward_jit = jax.jit(self._forward)

    # -- construction-time summary -----------------------------------------

    @property
    def n_linear_pairs(self) -> int:
        return sum(len(b.pair_idx) for b in self._linear_banks)

    @property
    def n_kernel_pairs(self) -> int:
        return sum(len(b.pair_idx) for b in self._kernel_banks)

    def describe(self) -> str:
        parts = [f"CompiledMachine(K={self.n_classes}, P={self.n_pairs})"]
        for b in self._linear_banks:
            parts.append(f"  linear bank: {len(b.pair_idx)} pairs, "
                         f"d={b.w.shape[1]}, input_bits={b.input_bits}")
        for b in self._kernel_banks:
            parts.append(f"  {b.kind} bank: {len(b.pair_idx)} pairs, "
                         f"M={b.sv.shape[1]}, d={b.sv.shape[2]}, "
                         f"input_bits={b.input_bits}")
        return "\n".join(parts)

    # -- the single batched forward pass ------------------------------------

    def _forward(self, x: jnp.ndarray):
        """x (n, d) f32 -> (scores (n, P), bits (n, P), labels (n,))."""
        scores = _all_scores(x, self._linear_banks, self._kernel_banks,
                             self._inv_perm, self.use_pallas,
                             interpret=self.interpret)
        bits = (scores >= 0.0).astype(jnp.int32)
        return scores, bits, self._decider(bits)

    def _labels_dag(self, x: jnp.ndarray):
        """x (n, d) f32 -> labels (n,) via the O(K) DAG front."""
        return _dag_labels(x, self.n_classes, self._pair_matrix,
                           self._linear_banks, self._kernel_banks,
                           self._row_maps, self._step_plans)

    # -- host API ------------------------------------------------------------

    def _as_input(self, x: np.ndarray) -> jnp.ndarray:
        x = jnp.asarray(np.asarray(x), jnp.float32)
        if x.ndim != 2 or (self.n_features and x.shape[1] != self.n_features):
            raise ValueError(
                f"expected (n, {self.n_features}) inputs, got shape {x.shape}")
        return x

    def _run(self, x: np.ndarray):
        return self._forward_jit(self._as_input(x))

    def decision_scores(self, x: np.ndarray) -> np.ndarray:
        """Raw per-pair decision scores (n, P) — pre-comparator."""
        return np.asarray(self._run(x)[0])

    def predict_bits(self, x: np.ndarray) -> np.ndarray:
        """Comparator bits (n, P), pair order of ``class_pairs``."""
        return np.asarray(self._run(x)[1])

    def predict(self, x: np.ndarray) -> np.ndarray:
        """Class labels (n,) via the compiled decision front.

        ``decider="votes"`` (default): every pair is evaluated and the
        packed encoder table / votes argmax decides — bit-identical to the
        seed.  ``decider="dag"``: the DDAG elimination front evaluates
        K-1 pairs per sample; equal to the votes labels wherever the vote
        winner is unambiguous (Condorcet), measured via
        :meth:`dag_votes_agreement` elsewhere.
        """
        if self.decider == "dag":
            return np.asarray(self._labels_dag_jit(self._as_input(x)))
        return np.asarray(self._run(x)[2])

    def predict_votes(self, x: np.ndarray) -> np.ndarray:
        """Class labels (n,) via the dense votes path, regardless of the
        compiled ``decider`` — the oracle the DAG front is checked
        against."""
        return np.asarray(self._run(x)[2])

    def dag_votes_agreement(self, x: np.ndarray) -> float:
        """Fraction of samples where the DAG front and the votes oracle
        agree (requires ``decider="dag"``)."""
        if self.decider != "dag":
            raise ValueError("machine was compiled with decider='votes'")
        return float(np.mean(self.predict(x) == self.predict_votes(x)))

    def accuracy(self, x: np.ndarray, y: np.ndarray) -> float:
        return float(np.mean(self.predict(x) == np.asarray(y)))

    score = accuracy

    # -- serialization (npz arrays + json structure) -------------------------

    def save(self, path: str) -> None:
        """Write ``<path>.npz`` (arrays) + ``<path>.json`` (structure)."""
        path = _strip_ext(path)
        arrays, meta_banks = _bank_arrays(
            self._linear_banks, self._kernel_banks)
        meta = {
            "format": "repro.api.CompiledMachine",
            "version": _FORMAT_VERSION,
            "n_classes": self.n_classes,
            "kernel_map": self.kernel_map,
            "decider": self.decider,
            "banks": meta_banks,
        }
        np.savez(path + ".npz", **arrays)
        with open(path + ".json", "w") as f:
            json.dump(meta, f, indent=2)

    @classmethod
    def load(cls, path: str, use_pallas: Optional[bool] = None,
             interpret: Optional[bool] = None,
             decider: Optional[str] = None) -> "CompiledMachine":
        path = _strip_ext(path)
        with open(path + ".json") as f:
            meta = json.load(f)
        if meta.get("format") != "repro.api.CompiledMachine":
            raise ValueError(f"{path}.json is not a CompiledMachine save")
        npz = np.load(path + ".npz")
        linear_banks, kernel_banks = _banks_from_entries(meta["banks"], npz)
        if decider is None:
            decider = meta.get("decider", "votes")
        return cls(meta["n_classes"], linear_banks, kernel_banks,
                   kernel_map=meta.get("kernel_map"), use_pallas=use_pallas,
                   interpret=interpret, decider=decider)


def _strip_ext(path: str) -> str:
    for ext in (".npz", ".json"):
        if path.endswith(ext):
            return path[: -len(ext)]
    return path


def _bank_arrays(linear_banks, kernel_banks, prefix: str = ""
                 ) -> tuple[dict[str, np.ndarray], list[dict]]:
    """Serialize banks to ``{npz key: array}`` + JSON bank entries.

    ``prefix`` namespaces the npz keys so multiple machines (the fleet
    save format, DESIGN.md §9) pack into one archive without collisions.
    """
    arrays: dict[str, np.ndarray] = {}
    meta_banks: list[dict] = []
    for i, b in enumerate(linear_banks):
        bid = f"{prefix}lin{i}"
        arrays[f"{bid}.w"] = np.asarray(b.w)
        arrays[f"{bid}.b"] = np.asarray(b.b)
        arrays[f"{bid}.pair_idx"] = b.pair_idx
        meta_banks.append({"type": "linear", "id": bid,
                           "input_bits": b.input_bits})
    for i, b in enumerate(kernel_banks):
        bid = f"{prefix}ker{i}"
        for name in ("sv", "coef_pos", "coef_neg", "bias_pos", "bias_neg",
                     "offset", "gamma", "scale", "shift"):
            arrays[f"{bid}.{name}"] = np.asarray(getattr(b, name))
        arrays[f"{bid}.pair_idx"] = b.pair_idx
        entry = {"type": "kernel", "id": bid, "kind": b.kind,
                 "input_bits": b.input_bits, "left": b.left,
                 "right": b.right}
        if b.grid is not None:
            arrays[f"{bid}.grid"] = np.asarray(b.grid)
            arrays[f"{bid}.curve"] = np.asarray(b.curve)
        meta_banks.append(entry)
    return arrays, meta_banks


def _banks_from_entries(entries: list[dict], npz
                        ) -> tuple[list[_LinearBank], list[_KernelBank]]:
    """Rebuild bank lists from JSON bank entries + an open npz archive."""
    linear_banks, kernel_banks = [], []
    for entry in entries:
        bid = entry["id"]
        if entry["type"] == "linear":
            linear_banks.append(_LinearBank(
                input_bits=int(entry["input_bits"]),
                pair_idx=npz[f"{bid}.pair_idx"],
                w=jnp.asarray(npz[f"{bid}.w"]),
                b=jnp.asarray(npz[f"{bid}.b"])))
        else:
            has_grid = f"{bid}.grid" in npz
            kernel_banks.append(_KernelBank(
                kind=entry["kind"], input_bits=int(entry["input_bits"]),
                pair_idx=npz[f"{bid}.pair_idx"],
                sv=jnp.asarray(npz[f"{bid}.sv"]),
                coef_pos=jnp.asarray(npz[f"{bid}.coef_pos"]),
                coef_neg=jnp.asarray(npz[f"{bid}.coef_neg"]),
                bias_pos=jnp.asarray(npz[f"{bid}.bias_pos"]),
                bias_neg=jnp.asarray(npz[f"{bid}.bias_neg"]),
                offset=jnp.asarray(npz[f"{bid}.offset"]),
                gamma=jnp.asarray(npz[f"{bid}.gamma"]),
                scale=jnp.asarray(npz[f"{bid}.scale"]),
                shift=jnp.asarray(npz[f"{bid}.shift"]),
                grid=jnp.asarray(npz[f"{bid}.grid"]) if has_grid else None,
                curve=jnp.asarray(npz[f"{bid}.curve"]) if has_grid else None,
                left=float(entry["left"]), right=float(entry["right"]),
                **_grid_fast_path(
                    npz[f"{bid}.grid"] if has_grid else None)))
    return linear_banks, kernel_banks


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------


def compile_machine(
    machine: MulticlassSVM | Sequence,
    n_classes: Optional[int] = None,
    kernel_map: Optional[list[str]] = None,
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
    decider: str = "votes",
) -> CompiledMachine:
    """Lower a bank of bit-classifiers to a single batched inference path.

    ``machine`` is either a :class:`~repro.core.ovo.MulticlassSVM` or a
    plain sequence of per-pair classifiers (``DigitalLinearClassifier``,
    ``DigitalRBFClassifier``, ``AnalogBinaryClassifier``, float ``SVMModel``
    or any object exposing a ``.model`` SVMModel) in ``class_pairs`` order;
    in the latter case ``n_classes`` is required.

    The compiled result is numerically equivalent to calling each object's
    ``predict_bits`` and the encoder in turn, but runs as ONE jit-compiled
    device program (see module docstring for the bank layout).
    """
    if isinstance(machine, MulticlassSVM):
        classifiers = list(machine.classifiers)
        n_classes = machine.n_classes
        kernel_map = list(machine.kernel_map)
    else:
        classifiers = list(machine)
        if n_classes is None:
            raise ValueError("n_classes is required for a bare classifier list")

    specs = [_lower_classifier(i, c) for i, c in enumerate(classifiers)]
    linear_banks, kernel_banks = _build_banks(specs)
    return CompiledMachine(n_classes, linear_banks, kernel_banks,
                           kernel_map=kernel_map, use_pallas=use_pallas,
                           interpret=interpret, decider=decider)


# ---------------------------------------------------------------------------
# Candidate machine: assignment-independent per-pair bit tensor (DSE layer 2)
# ---------------------------------------------------------------------------


class CandidateMachine:
    """BOTH per-pair candidates lowered into one jit-compiled pass.

    The kernel-assignment design space (``repro.core.dse``) exploits that
    the comparator bit of each candidate classifier is *assignment-
    independent*: pair ``p``'s linear-digital bit and RBF bit do not change
    when some other pair's assignment flips.  This machine therefore lowers
    the two candidate classifiers of every pair — ``2P`` classifiers in
    total — into the same padded stacked banks as :class:`CompiledMachine`
    and evaluates all of them in ONE jitted forward:

        ``pair_bits(x) -> (n, P, 2)`` int32
        (``[..., 0]`` = linear-digital candidate bit, ``[..., 1]`` = RBF
        candidate bit, pair order of ``class_pairs``)

    Any candidate assignment's machine output is then a pure
    *bit-recombination*: select one bit per pair, feed the decision
    encoder — no classifier is ever re-evaluated per assignment
    (DESIGN.md §5.3).
    """

    def __init__(self, n_classes: int, linear_banks, kernel_banks,
                 use_pallas: Optional[bool] = None,
                 interpret: Optional[bool] = None):
        self.n_classes = int(n_classes)
        self.n_pairs = len(class_pairs(self.n_classes))
        self._linear_banks = linear_banks
        self._kernel_banks = kernel_banks
        self.n_features = _bank_feature_dim(linear_banks, kernel_banks)
        if use_pallas is None:
            use_pallas = jax.default_backend() == "tpu"
        self.use_pallas = bool(use_pallas)
        self.interpret = interpret
        # Lowering indices: candidate 0 of pair p is column p, candidate 1
        # is column P + p; the inverse permutation restores that order.
        self._inv_perm = _inverse_perm(linear_banks, kernel_banks,
                                       2 * self.n_pairs)
        self._forward_jit = jax.jit(self._forward)

    def _forward(self, x: jnp.ndarray):
        """x (n, d) f32 -> (scores (n, P, 2), bits (n, P, 2))."""
        flat = _all_scores(x, self._linear_banks, self._kernel_banks,
                           self._inv_perm, self.use_pallas,
                           interpret=self.interpret)            # (n, 2P)
        scores = jnp.stack(
            [flat[:, : self.n_pairs], flat[:, self.n_pairs:]], axis=-1)
        return scores, (scores >= 0.0).astype(jnp.int32)

    def _run(self, x: np.ndarray):
        x = jnp.asarray(np.asarray(x), jnp.float32)
        if x.ndim != 2 or (self.n_features and x.shape[1] != self.n_features):
            raise ValueError(
                f"expected (n, {self.n_features}) inputs, got shape {x.shape}")
        return self._forward_jit(x)

    def pair_scores(self, x: np.ndarray) -> np.ndarray:
        """Raw candidate decision scores ``(n, P, 2)`` — pre-comparator."""
        return np.asarray(self._run(x)[0])

    def pair_bits(self, x: np.ndarray) -> np.ndarray:
        """Candidate comparator bits ``(n, P, 2)`` in one device pass."""
        return np.asarray(self._run(x)[1])


def compile_candidates(
    candidates: Sequence,
    n_classes: int,
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> CandidateMachine:
    """Lower per-pair candidate classifiers to one :class:`CandidateMachine`.

    ``candidates`` is a sequence of ``(linear_clf, rbf_clf)`` per OvO pair
    in ``class_pairs`` order — the same classifier objects the legacy banks
    would hold, so the bit tensor agrees column-for-column with the
    corresponding :class:`CompiledMachine` outputs.
    """
    pairs = class_pairs(n_classes)
    if len(candidates) != len(pairs):
        raise ValueError(
            f"{len(candidates)} candidate pairs for {n_classes} classes "
            f"(expected {len(pairs)})")
    p = len(pairs)
    specs = []
    for i, (lin_clf, rbf_clf) in enumerate(candidates):
        specs.append(_lower_classifier(i, lin_clf))
        specs.append(_lower_classifier(p + i, rbf_clf))
    linear_banks, kernel_banks = _build_banks(specs)
    return CandidateMachine(n_classes, linear_banks, kernel_banks,
                            use_pallas=use_pallas, interpret=interpret)


# ---------------------------------------------------------------------------
# Monte-Carlo machine: the candidate bit tensor under process variation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _VariantSpec:
    """Variant tensors of ONE analog candidate (pre-padding, DESIGN.md §6).

    ``shift``/``gain (V, m, d)`` perturb the per-cell Gaussian transfers,
    ``coef_pos``/``coef_neg (V, m)`` are the per-variant *realised* alpha
    coefficients (the alpha-multiplier mismatch is folded at lowering time,
    exactly as the nominal lowering freezes the nominal alpha path), and
    ``offset (V,)`` the per-variant comparator offset.  Row 0 carries the
    zero-offset instance and reproduces the nominal spec bit for bit.
    """

    pair: int
    shift: np.ndarray
    gain: np.ndarray
    coef_pos: np.ndarray
    coef_neg: np.ndarray
    offset: np.ndarray


def _lower_analog_variants(
    idx: int,
    clf: AnalogBinaryClassifier,
    key: jax.Array,
    n_variants: int,
    include_nominal: bool,
    sigma_scale: float,
) -> _VariantSpec:
    """Sample + reduce mismatch for one deployed analog classifier."""
    variants = clf.sample_variants(
        key, n_variants, include_nominal=include_nominal,
        sigma_scale=sigma_scale)
    t = variant_transfer_params(variants, clf.hw.params)
    # Per-variant alpha path, frozen with the same f32 ops as the nominal
    # lowering: desired alpha -> control voltage -> mismatched realised
    # alpha ((dva - shift) / slope queries the measured sweep).
    dva = clf.hw.alpha_control_voltage(jnp.asarray(clf.alpha_hw, jnp.float32))
    a = _f32(clf.hw.alpha_realized(
        (dva[None, :] - t.alpha_shift) / t.alpha_slope))        # (V, m)
    pos = (clf.support_y > 0)
    return _VariantSpec(
        pair=idx, shift=_f32(t.shift), gain=_f32(t.gain),
        coef_pos=a * pos[None, :], coef_neg=a * (~pos)[None, :],
        offset=_f32(t.comp_offset))


@dataclasses.dataclass
class _BankVariants:
    """Per-bank stacked variant tensors (padded to the bank max M).

    Padded SV slots carry gain 0 AND coefficient 0, so they contribute an
    exact 0 to the rail GEMM for every variant — the same inertness
    argument as the nominal bank padding.
    """

    shift: jnp.ndarray     # (V, P, M, d)
    gain: jnp.ndarray      # (V, P, M, d)
    coef_pos: jnp.ndarray  # (V, P, M)
    coef_neg: jnp.ndarray  # (V, P, M)
    offset: jnp.ndarray    # (V, P)

    @classmethod
    def build(cls, vspecs: list[_VariantSpec], m_max: int) -> "_BankVariants":
        def pad(a: np.ndarray) -> np.ndarray:
            out = np.zeros(a.shape[:1] + (m_max,) + a.shape[2:], np.float32)
            out[:, : a.shape[1]] = a
            return out

        return cls(
            shift=jnp.asarray(np.stack([pad(s.shift) for s in vspecs], 1)),
            gain=jnp.asarray(np.stack([pad(s.gain) for s in vspecs], 1)),
            coef_pos=jnp.asarray(
                np.stack([pad(s.coef_pos) for s in vspecs], 1)),
            coef_neg=jnp.asarray(
                np.stack([pad(s.coef_neg) for s in vspecs], 1)),
            offset=jnp.asarray(np.stack([s.offset for s in vspecs], 1)),
        )

    @property
    def n_variants(self) -> int:
        return int(self.shift.shape[0])


# All-array dataclass: register with field order as the flatten order so
# variant tensors cross jit boundaries as a plain pytree (see the
# _LinearBank/_KernelBank registration note).
jax.tree_util.register_dataclass(
    _BankVariants,
    data_fields=("shift", "gain", "coef_pos", "coef_neg", "offset"),
    meta_fields=())


def _key_data(key: jax.Array) -> np.ndarray:
    """Raw uint32 data of a jax PRNG key — typed or legacy."""
    try:
        return np.asarray(jax.random.key_data(key))
    except TypeError:  # legacy raw uint32 keys
        return np.asarray(key)


def _bank_scores_mc(bank: _KernelBank, bv: _BankVariants, xv: jnp.ndarray,
                    use_pallas: bool, include_nominal: bool,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """(V, n, P) decision scores of one analog bank under variation.

    Only the variant-dependent tensors carry the leading V axis; the bank
    constants and the input batch broadcast (closed over / in_axes=None),
    so XLA sees one fused program over the whole (V, P) lane grid.

    With ``include_nominal``, variant 0 does NOT go through the perturbed
    lanes at all: it IS the nominal ``_bank_scores`` subgraph, concatenated
    in front of the ``V - 1`` sampled lanes.  Subtracting a runtime 0 and
    multiplying by a runtime 1 are exact IEEE ops, but their mere presence
    changes XLA's fusion/codegen of the surrounding interpolation chain
    (observed ~4e-6 drift on CPU), so structural reuse of the nominal
    expression is the only way the bit-identity contract survives jit.
    """
    if bank.kind != "hw":
        raise TypeError(
            f"variant lanes require the 'hw' measured-curve kind, got "
            f"{bank.kind!r}")

    def one(sv, gamma, scale, shift, cpos, cneg, bpos, bneg, off,
            vshift, vgain):
        k = _pair_kernel(bank, xv, sv, gamma, scale, shift, use_pallas,
                         vshift=vshift, vgain=vgain, interpret=interpret)
        rails = k @ jnp.stack([cpos, cneg], axis=1)      # (n, 2)
        return (rails[:, 0] + bpos) - (rails[:, 1] + bneg) + off

    def one_variant(vshift, vgain, vcpos, vcneg, voff):
        return jax.vmap(one, out_axes=1)(
            bank.sv, bank.gamma, bank.scale, bank.shift,
            vcpos, vcneg, bank.bias_pos, bank.bias_neg, voff,
            vshift, vgain)

    lo = 1 if include_nominal else 0
    var = jax.vmap(one_variant)(
        bv.shift[lo:], bv.gain[lo:], bv.coef_pos[lo:],
        bv.coef_neg[lo:], bv.offset[lo:])
    if not include_nominal:
        return var
    nom = _bank_scores(bank, xv, use_pallas, interpret=interpret)
    return jnp.concatenate([nom[None], var], axis=0)


def _all_scores_mc(x: jnp.ndarray, linear_banks, kernel_banks,
                   bank_variants, inv_perm: jnp.ndarray, n_variants: int,
                   include_nominal: bool, use_pallas: bool,
                   interpret: Optional[bool] = None) -> jnp.ndarray:
    """x (n, d) f32 -> scores (V, n, C) in lowering (pair-index) order.

    Variation-free lanes (linear-digital, digital-RBF) are evaluated ONCE
    and broadcast over the variant axis; only banks with attached
    ``_BankVariants`` vmap over it.
    """
    xq_cache: dict[int, jnp.ndarray] = {}

    def xq(bits: int) -> jnp.ndarray:
        if bits not in xq_cache:
            xq_cache[bits] = x if bits == 0 else quant.quantize_unit(x, bits)
        return xq_cache[bits]

    cols = []
    for bank in linear_banks:
        c = xq(bank.input_bits) @ bank.w.T + bank.b[None, :]
        cols.append(jnp.broadcast_to(c[None], (n_variants,) + c.shape))
    for bank, bv in zip(kernel_banks, bank_variants):
        if bv is None:
            c = _bank_scores(bank, xq(bank.input_bits), use_pallas,
                             interpret=interpret)
            cols.append(jnp.broadcast_to(c[None], (n_variants,) + c.shape))
        else:
            cols.append(_bank_scores_mc(bank, bv, xq(bank.input_bits),
                                        use_pallas, include_nominal,
                                        interpret=interpret))
    return jnp.concatenate(cols, axis=2)[:, :, inv_perm]


class MonteCarloMachine:
    """BOTH candidates of every pair under ``V`` mismatch instances.

    The Monte-Carlo sibling of :class:`CandidateMachine`: the same padded
    stacked banks, but every analog lane is evaluated for ``V`` sampled
    fabricated instances (per-SV-cell Gaussian/alpha/comparator mismatch,
    ``repro.core.analog.VariantSet``) with the variant axis vmapped INSIDE
    the one jitted forward —

        ``pair_bits(x) -> (V, n, P, 2)`` int32

    (candidate axis as in :class:`CandidateMachine`; variant axis leading).
    Digital lanes are variation-free and broadcast.  With the default
    ``include_nominal`` sampling, variant 0 is the zero-offset instance
    and its lanes reuse the literal nominal subgraph (see
    ``_bank_scores_mc``), so its slice is bit-identical to the nominal
    ``CandidateMachine`` scores — the contract
    ``benchmarks/montecarlo.py --assert-nominal`` freezes.
    """

    def __init__(self, n_classes: int, linear_banks, kernel_banks,
                 bank_variants, n_variants: int, include_nominal: bool,
                 sigma_scale: float, key_data: Optional[np.ndarray] = None,
                 use_pallas: Optional[bool] = None,
                 interpret: Optional[bool] = None):
        self.n_classes = int(n_classes)
        self.n_pairs = len(class_pairs(self.n_classes))
        self.n_variants = int(n_variants)
        self.include_nominal = bool(include_nominal)
        self.sigma_scale = float(sigma_scale)
        self.key_data = None if key_data is None else np.asarray(key_data)
        self._linear_banks = linear_banks
        self._kernel_banks = kernel_banks
        self._bank_variants = bank_variants
        self.n_features = _bank_feature_dim(linear_banks, kernel_banks)
        if use_pallas is None:
            use_pallas = jax.default_backend() == "tpu"
        self.use_pallas = bool(use_pallas)
        self.interpret = interpret
        self._inv_perm = _inverse_perm(linear_banks, kernel_banks,
                                       2 * self.n_pairs)
        self._forward_jit = jax.jit(self._forward)

    def _forward(self, x: jnp.ndarray):
        """x (n, d) f32 -> (scores (V, n, P, 2), bits (V, n, P, 2))."""
        flat = _all_scores_mc(x, self._linear_banks, self._kernel_banks,
                              self._bank_variants, self._inv_perm,
                              self.n_variants, self.include_nominal,
                              self.use_pallas, interpret=self.interpret)
        scores = jnp.stack(
            [flat[..., : self.n_pairs], flat[..., self.n_pairs:]], axis=-1)
        return scores, (scores >= 0.0).astype(jnp.int32)

    def _run(self, x: np.ndarray):
        x = jnp.asarray(np.asarray(x), jnp.float32)
        if x.ndim != 2 or (self.n_features and x.shape[1] != self.n_features):
            raise ValueError(
                f"expected (n, {self.n_features}) inputs, got shape {x.shape}")
        return self._forward_jit(x)

    def pair_scores(self, x: np.ndarray) -> np.ndarray:
        """Per-variant candidate decision scores ``(V, n, P, 2)``."""
        return np.asarray(self._run(x)[0])

    def pair_bits(self, x: np.ndarray) -> np.ndarray:
        """Per-variant candidate comparator bits ``(V, n, P, 2)`` — every
        variant of every candidate of every pair in ONE device pass."""
        return np.asarray(self._run(x)[1])


def compile_variants(
    candidates: Sequence,
    n_classes: int,
    key: jax.Array,
    n_variants: int = 64,
    include_nominal: bool = True,
    sigma_scale: float = 1.0,
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> MonteCarloMachine:
    """Lower per-pair candidates + sampled process variation to ONE machine.

    ``candidates`` is the same per-pair ``(linear_clf, rbf_clf)`` sequence
    :func:`compile_candidates` takes.  ``key`` is an explicit ``jax.random``
    key (no hidden RNG state): it is split once per pair, so every analog
    candidate's circuit draws independent per-SV-cell mismatch
    (``AnalogBinaryClassifier.sample_variants``).  Non-analog RBF
    candidates (e.g. digital RBF) are accepted and treated as
    variation-free broadcast lanes.

    With ``include_nominal`` (default) variant 0 is the zero-offset
    instance — bit-identical to :func:`compile_candidates` on the same
    candidates — and ``n_variants - 1`` random instances are drawn.
    """
    pairs = class_pairs(n_classes)
    if len(candidates) != len(pairs):
        raise ValueError(
            f"{len(candidates)} candidate pairs for {n_classes} classes "
            f"(expected {len(pairs)})")
    p = len(pairs)
    keys = jax.random.split(key, p)
    specs = []
    vspecs: dict[int, _VariantSpec] = {}
    for i, (lin_clf, rbf_clf) in enumerate(candidates):
        specs.append(_lower_classifier(i, lin_clf))
        specs.append(_lower_classifier(p + i, rbf_clf))
        if isinstance(rbf_clf, AnalogBinaryClassifier):
            vspecs[p + i] = _lower_analog_variants(
                p + i, rbf_clf, keys[i], n_variants, include_nominal,
                sigma_scale)
    linear_groups, kernel_groups = _group_specs(specs)
    linear_banks = [_LinearBank.build(g) for g in linear_groups]
    kernel_banks, bank_variants = [], []
    for g in kernel_groups:
        bank = _KernelBank.build(g)
        kernel_banks.append(bank)
        in_group = [s.pair in vspecs for s in g]
        if not any(in_group):
            bank_variants.append(None)
            continue
        if not all(in_group):  # cannot happen: 'hw' curves group apart
            raise ValueError(
                "bank mixes variant and variant-free lanes; grouping bug")
        bank_variants.append(_BankVariants.build(
            [vspecs[s.pair] for s in g], int(bank.sv.shape[1])))
    return MonteCarloMachine(
        n_classes, linear_banks, kernel_banks, bank_variants,
        n_variants=n_variants, include_nominal=include_nominal,
        sigma_scale=sigma_scale, key_data=_key_data(key),
        use_pallas=use_pallas, interpret=interpret)


# ---------------------------------------------------------------------------
# Streaming Monte-Carlo machine: flat-memory variant pipelining (DESIGN.md §10)
# ---------------------------------------------------------------------------

#: Default variant chunk of the streaming engine.  A config knob like
#: ``dse.MC_CHUNK``: larger chunks amortize dispatch overhead, smaller
#: ones shrink the peak temp footprint; either way ONE program compiles
#: regardless of the total variant count.
MC_STREAM_CHUNK = 256

#: In-graph assignment-axis chunk of the streamed recombination (bounds
#: the ``(B, n, CHUNK)`` codes tensor exactly as ``dse.MC_CHUNK`` bounds
#: the dense sweep's).
_RECOMBINE_CHUNK = 512

STREAM_METHODS = ("iid", "sobol", "stratified", "is")


@dataclasses.dataclass
class _StreamBankConst:
    """Per-analog-bank constants for on-the-fly variant generation.

    Everything the chunk step needs to draw + lower a variant IN-GRAPH,
    on the bank's padded ``(Pb, M)`` slot grid: per-pair mismatch keys,
    the frozen alpha control voltages, rail/validity masks (padded slots
    have both rail masks 0, so their coefficients are exact zeros — the
    same inertness argument as the dense ``_BankVariants`` padding), and
    the per-pair measured alpha sweeps for the in-graph realised-alpha
    interpolation.  Static aux: the shared ``CircuitParams``, the bank's
    slice of the flat QMC block (``u_offset``/``u_width``, padded grid)
    and its TRUE mismatch dimension (unpadded — the ``D`` that enters
    importance-sampling log-weights).
    """

    pair_keys: jax.Array      # (Pb,) typed mismatch keys (fold_in per variant)
    dva: jnp.ndarray          # (Pb, M) alpha control voltages, 0 on pads
    pos_mask: jnp.ndarray     # (Pb, M) f32: valid slot on the + rail
    neg_mask: jnp.ndarray     # (Pb, M) f32: valid slot on the - rail
    slot_valid: jnp.ndarray   # (Pb, M) f32: any valid slot
    alpha_grid: jnp.ndarray   # (Pb, Ga) ascending measured alpha abscissa
    alpha_curve: jnp.ndarray  # (Pb, Ga) measured alpha multiplier
    alpha_left: jnp.ndarray   # (Pb,) clamp below the sweep
    alpha_right: jnp.ndarray  # (Pb,) clamp above the sweep
    params: object = None     # shared CircuitParams (static)
    u_offset: int = 0         # flat QMC block slice start (padded dims)
    u_width: int = 0          # flat QMC block slice width (padded dims)
    true_dim: int = 0         # unpadded mismatch dims across the bank


jax.tree_util.register_dataclass(
    _StreamBankConst,
    data_fields=["pair_keys", "dva", "pos_mask", "neg_mask", "slot_valid",
                 "alpha_grid", "alpha_curve", "alpha_left", "alpha_right"],
    meta_fields=["params", "u_offset", "u_width", "true_dim"])


def _recombine_acc(bits4, assignments, y, table, weights,
                   s_chunk: int = _RECOMBINE_CHUNK):
    """Streamed bit-recombination: ``bits4 (B, n, P, 2) -> acc (B, S)`` f32.

    The chunk-axis sibling of ``dse._encoder_accuracy``: the packed
    encoder table scores every assignment for every variant of the chunk.
    Beyond ``s_chunk`` assignments the assignment axis runs under an
    in-graph ``lax.map`` (loop-carried buffer, codes tensor bounded at
    ``(B, n, s_chunk)``) — no host round-trips, one compiled program.
    """
    lin = bits4[..., 0]                                    # (B, n, P)
    diff = (bits4[..., 1] - lin) * weights[None, None, :]
    base = lin @ weights                                   # (B, n)
    yy = y[None, :, None]
    s = assignments.shape[0]

    def score(a_block):
        codes = base[..., None] + diff @ a_block.T         # (B, n, C)
        labels = jnp.take(table, codes)
        return jnp.mean((labels == yy).astype(jnp.float32), axis=1)

    if s <= s_chunk:
        return score(assignments)
    pad = -s % s_chunk
    a = assignments
    if pad:
        a = jnp.concatenate(
            [a, jnp.broadcast_to(a[:1], (pad,) + a.shape[1:])])
    chunks = a.reshape(-1, s_chunk, a.shape[1])
    acc = jax.lax.map(score, chunks)                       # (n_chunks, B, C)
    return jnp.moveaxis(acc, 0, 1).reshape(bits4.shape[0], -1)[:, :s]


class StreamingMCMachine:
    """Tail-yield Monte-Carlo at production signoff scale (DESIGN.md §10).

    Where :class:`MonteCarloMachine` materializes all ``V`` variants —
    banks ``(V, P, M, d)``, bits ``(V, n, P, 2)`` — this machine never
    holds more than ONE fixed-size chunk of ``mc_chunk`` variants:

    1. **Generate**: variant ``v``'s mismatch is drawn in-graph from
       ``fold_in(pair_key, v)`` (``method='iid'``/``'is'``) or from
       coordinate ``v`` of a scrambled-Sobol/Latin-hypercube point set
       (``'sobol'``/``'stratified'``, inverse-CDF transformed in-graph) —
       a pure function of the global index, never of the chunking.
    2. **Score**: the chunk's banks run through the SAME
       ``_all_scores_mc`` lanes as the dense machine (digital lanes
       broadcast), then the packed-encoder (or, past ``MAX_TABLE_BITS``,
       the pair-chunked votes) recombination scores every
       assignment (``_recombine_acc``).
    3. **Fold**: the ``(B, S)`` chunk accuracies collapse into the
       donated :class:`~repro.core.mcstream.StreamStats` accumulator —
       weighted Welford mean/M2, floor exceedance, extrema, histogram
       sketch — and the chunk's buffers are reused.

    One compiled program serves every ``V`` (the step's shapes depend on
    ``mc_chunk``, never on ``V``), so peak memory is flat from V=64 to
    V=10^6 — the property ``benchmarks/montecarlo.py
    --assert-flat-memory`` gates via XLA ``memory_analysis``.  With
    ``method='is'``, draws are widened by ``is_scale`` and carry
    self-normalized importance weights through the accumulators
    (rare-event tail sharpening; ``finalize`` reports the effective
    sample size the confidence bounds use).  ``stream(mesh=)`` shards
    the chunk's variant axis across a ``launch.mesh.make_variant_mesh``
    with one psum/pmin/pmax merge per chunk.
    """

    def __init__(self, n_classes: int, linear_banks, kernel_banks,
                 stream_consts, method: str, mc_chunk: int,
                 sigma_scale: float, is_scale: float,
                 key_data: Optional[np.ndarray] = None,
                 use_pallas: Optional[bool] = None,
                 interpret: Optional[bool] = None):
        self.n_classes = int(n_classes)
        self.n_pairs = len(class_pairs(self.n_classes))
        if method not in STREAM_METHODS:
            raise ValueError(
                f"unknown sampling method {method!r}; one of "
                f"{STREAM_METHODS}")
        if mc_chunk < 1:
            raise ValueError(f"mc_chunk must be >= 1, got {mc_chunk}")
        self.method = method
        self.mc_chunk = int(mc_chunk)
        self.sigma_scale = float(sigma_scale)
        self.is_scale = float(is_scale)
        self.key_data = None if key_data is None else np.asarray(key_data)
        self._linear_banks = linear_banks
        self._kernel_banks = kernel_banks
        self._stream_consts = stream_consts   # aligned with kernel_banks
        self.n_features = _bank_feature_dim(linear_banks, kernel_banks)
        if use_pallas is None:
            use_pallas = jax.default_backend() == "tpu"
        self.use_pallas = bool(use_pallas)
        self.interpret = interpret
        self._inv_perm = _inverse_perm(linear_banks, kernel_banks,
                                       2 * self.n_pairs)
        # Recombination constants: packed encoder table in the FE regime,
        # the pair-chunked votes matmul (dse._votes_accuracy_paired)
        # beyond it — same flat-memory contract either way.
        dec = _Decider.build(self.n_classes)
        self._table, self._weights = dec.table, dec.bit_weights
        self._vote_a, self._vote_b = dec.vote_a, dec.vote_b
        #: Flat mismatch dims over the padded slot grids (the QMC block
        #: width) and over the true circuits (the IS log-weight D).
        self.mismatch_dim = sum(
            c.u_width for c in stream_consts if c is not None)
        self.true_dim = sum(
            c.true_dim for c in stream_consts if c is not None)
        self._sampler = None
        if method in ("sobol", "stratified"):
            self._sampler = mcstream.QMCSampler(
                method, self.mismatch_dim, self.key_data)
        self._step_jit = jax.jit(self._step, donate_argnums=(0,))
        self._bits_jit = jax.jit(self._bits)
        self._sharded_steps: dict = {}

    # -- in-graph chunk generation ------------------------------------------

    def _bank_chunk(self, bc: _StreamBankConst, bank: _KernelBank,
                    v_idx: jnp.ndarray, u: jnp.ndarray):
        """Lower ONE analog bank's variant chunk: draws -> _BankVariants.

        Returns ``(chunk, raw_sumsq (B,))`` where ``raw_sumsq`` is the
        masked squared norm of the UNSCALED standard-normal draws (what
        importance-sampling log-weights integrate; padded slots excluded).
        """
        m_max = int(bc.dva.shape[1])
        d = int(bank.sv.shape[2])
        if self.method in ("iid", "is"):
            def draw(k, idx):
                kg, ka, kc = jax.random.split(jax.random.fold_in(k, idx), 3)
                return (jax.random.normal(kg, (m_max, d, N_GAUSS_OFFSETS)),
                        jax.random.normal(ka, (m_max, N_ALPHA_OFFSETS)),
                        jax.random.normal(kc, ()))

            gz, az, cz = jax.vmap(jax.vmap(draw, in_axes=(0, None)),
                                  in_axes=(None, 0))(bc.pair_keys, v_idx)
        else:
            ub = u[:, bc.u_offset: bc.u_offset + bc.u_width]
            z = mcstream.uniform_to_normal(ub).reshape(
                v_idx.shape[0], len(bc.pair_keys), variant_dim(m_max, d))
            raw = variant_set_from_flat(z, m_max, d, 1.0)
            gz, az, cz = raw.gauss, raw.alpha, raw.comparator
        sumsq = (
            jnp.sum(gz * gz * bc.slot_valid[None, :, :, None, None],
                    axis=(1, 2, 3, 4))
            + jnp.sum(az * az * bc.slot_valid[None, :, :, None],
                      axis=(1, 2, 3))
            + jnp.sum(cz * cz, axis=1))
        scale = self.sigma_scale * (
            self.is_scale if self.method == "is" else 1.0)
        s = jnp.float32(scale)
        vs = VariantSet(gauss=s * gz, alpha=s * az, comparator=s * cz)
        t = variant_transfer_params(vs, bc.params)    # leading (B, Pb)
        # In-graph realised alpha: the SAME frozen-alpha arithmetic as
        # `_lower_analog_variants`, per pair against its measured sweep.
        query = (bc.dva[None] - t.alpha_shift) / t.alpha_slope  # (B, Pb, M)

        def interp_pair(q, g, c, lo, hi):
            return jnp.interp(q, g, c, left=lo, right=hi)

        a = jax.vmap(interp_pair, in_axes=(1, 0, 0, 0, 0), out_axes=1)(
            query, bc.alpha_grid, bc.alpha_curve,
            bc.alpha_left, bc.alpha_right)                      # (B, Pb, M)
        # Padded slots: both rail masks are 0, so their coefficients are
        # exact zeros and the rail GEMM ignores whatever the padded draws
        # did to shift/gain — the dense path's zero-padding, streamed.
        chunk = _BankVariants(
            shift=t.shift, gain=t.gain,
            coef_pos=a * bc.pos_mask[None],
            coef_neg=a * bc.neg_mask[None],
            offset=t.comp_offset)
        return chunk, sumsq

    def _chunk_banks(self, v_idx: jnp.ndarray, u: jnp.ndarray):
        """All banks' variant chunks + the chunk's sampling weights (B,)."""
        bank_variants, sumsq = [], jnp.zeros(v_idx.shape[0], jnp.float32)
        for bank, bc in zip(self._kernel_banks, self._stream_consts):
            if bc is None:
                bank_variants.append(None)
                continue
            chunk, ss = self._bank_chunk(bc, bank, v_idx, u)
            bank_variants.append(chunk)
            sumsq = sumsq + ss
        if self.method == "is":
            s = self.is_scale
            # Log-weight CENTERED at its analytic mean under the proposal
            # (E[sumsq] = D): logw - D(log s - (s^2-1)/2) = (s^2-1)/2 *
            # (D - sumsq).  Raw log-weights sit hundreds of nats below
            # zero in realistic mismatch spaces (D in the hundreds), so
            # weights are materialized RELATIVE to the chunk max — always
            # in (0, 1] — and the accumulators carry the scale in
            # StreamStats.log_ref (streaming logsumexp; a fixed clip
            # either zeroes the stream or ties a macroscopic fraction of
            # draws at the clip, silently inflating n_eff).  Padded tail
            # rows have finite log-weights too, so the max needs no
            # validity mask — any consistent scale works, and the
            # weighted sums drop invalid rows downstream.
            logw = (jnp.float32((s * s - 1.0) / 2.0)
                    * (jnp.float32(self.true_dim) - sumsq))
            log_ref = jnp.max(logw)
            w = jnp.exp(logw - log_ref)
        else:
            w = jnp.ones(v_idx.shape[0], jnp.float32)
            log_ref = jnp.zeros((), jnp.float32)
        return bank_variants, w, log_ref

    def _chunk_acc(self, x, v_idx, assignments, y, u):
        """One chunk end to end: draws -> scores -> bits -> acc (B, S)."""
        bank_variants, w, log_ref = self._chunk_banks(v_idx, u)
        flat = _all_scores_mc(
            x, self._linear_banks, self._kernel_banks, bank_variants,
            self._inv_perm, int(v_idx.shape[0]), False, self.use_pallas,
            interpret=self.interpret)                       # (B, n, 2P)
        scores = jnp.stack(
            [flat[..., : self.n_pairs], flat[..., self.n_pairs:]], axis=-1)
        bits = (scores >= 0.0).astype(jnp.int32)            # (B, n, P, 2)
        if self._table is not None:
            acc = _recombine_acc(bits, assignments, y, self._table,
                                 self._weights)
        else:
            acc = dse._votes_accuracy_paired(
                bits, assignments, y, self._vote_a, self._vote_b)
        return acc, w, log_ref, bits

    def _step(self, state, x, v_idx, valid, floor, assignments, y, u):
        """THE streamed chunk program: state is donated, shapes depend on
        ``mc_chunk`` and the assignment matrix only — one compile per
        machine regardless of the total variant count."""
        acc, w, log_ref, _ = self._chunk_acc(x, v_idx, assignments, y, u)
        return mcstream.update_stream(state, acc, w, valid, floor,
                                      log_ref=log_ref)

    def _bits(self, x, v_idx, u):
        """Chunk bits oracle (un-donated): ``(B, n, P, 2)`` + weights
        relative to the chunk's own log-scale (also returned)."""
        bank_variants, w, log_ref = self._chunk_banks(v_idx, u)
        flat = _all_scores_mc(
            x, self._linear_banks, self._kernel_banks, bank_variants,
            self._inv_perm, int(v_idx.shape[0]), False, self.use_pallas,
            interpret=self.interpret)
        scores = jnp.stack(
            [flat[..., : self.n_pairs], flat[..., self.n_pairs:]], axis=-1)
        return (scores >= 0.0).astype(jnp.int32), w, log_ref

    # -- sharded step (variant axis over a mesh) -----------------------------

    def _make_sharded_step(self, mesh):
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        from repro.launch.mesh import VARIANTS_AXIS

        if VARIANTS_AXIS not in mesh.axis_names:
            raise ValueError(
                f"mesh axes {mesh.axis_names} lack '{VARIANTS_AXIS}'; "
                f"build one with launch.mesh.make_variant_mesh()")
        rep, shd = P(), P(VARIANTS_AXIS)

        def local_step(state, x, v_idx, valid, floor, assignments, y, u):
            # Each device draws + scores its slice of the chunk; the
            # LINEAR aggregates (centered on the replicated running mean)
            # merge with one psum, the extrema with pmin/pmax, and every
            # device applies the identical merge — the state stays
            # replicated without a broadcast.  Per-device log-scales are
            # aligned to their pmax before the psum (factor is the
            # literal 1.0 on every device whenever the scales agree —
            # always, for non-IS methods — so those sums stay bit-exact).
            acc, w, log_ref, _ = self._chunk_acc(x, v_idx, assignments, y, u)
            agg = mcstream.chunk_aggregates(
                state.mean, acc, w, valid, floor, state.hist.shape[1],
                log_ref=log_ref)
            ax = VARIANTS_AXIS
            ref = jax.lax.pmax(agg.log_ref, ax)
            f = jnp.where(agg.log_ref == ref, jnp.float32(1.0),
                          jnp.exp(agg.log_ref - ref))
            agg = mcstream.ChunkAgg(
                n_c=jax.lax.psum(agg.n_c, ax),
                w_c=jax.lax.psum(f * agg.w_c, ax),
                w2_c=jax.lax.psum(f * f * agg.w2_c, ax),
                s1=jax.lax.psum(f * agg.s1, ax),
                s2=jax.lax.psum(f * agg.s2, ax),
                exceed=jax.lax.psum(f * agg.exceed, ax),
                amin=jax.lax.pmin(agg.amin, ax),
                amax=jax.lax.pmax(agg.amax, ax),
                hist=jax.lax.psum(f * agg.hist, ax),
                log_ref=ref)
            return mcstream.merge_stream(state, agg)

        fn = shard_map(
            local_step, mesh=mesh,
            in_specs=(rep, rep, shd, shd, rep, rep, rep, shd),
            out_specs=rep, check_rep=False)
        return jax.jit(fn, donate_argnums=(0,))

    def _sharded_step(self, mesh):
        if mesh not in self._sharded_steps:
            self._sharded_steps[mesh] = self._make_sharded_step(mesh)
        return self._sharded_steps[mesh]

    # -- host driver ---------------------------------------------------------

    def _chunk_size(self, mesh=None) -> int:
        b = self.mc_chunk
        if mesh is not None:
            nd = int(np.prod(list(mesh.shape.values())))
            b = -(-b // nd) * nd      # round up to a whole per-device slice
        return b

    def _chunk_inputs(self, start: int, b: int, n_variants: int):
        # Host numpy on purpose: eager jnp ops bake `start` in as a
        # constant and would compile one tiny program per distinct chunk
        # start; numpy arrays cross the jit boundary with zero compiles.
        v_idx = np.arange(start, start + b, dtype=np.int32)
        valid = (np.arange(start, start + b) < n_variants).astype(np.float32)
        if self._sampler is not None:
            u = self._sampler.chunk(start, b)
        else:
            u = np.zeros((b, 0), np.float32)
        return v_idx, valid, u

    def _prep(self, x, y, assignments):
        x = jnp.asarray(np.asarray(x), jnp.float32)
        if x.ndim != 2 or (self.n_features and x.shape[1] != self.n_features):
            raise ValueError(
                f"expected (n, {self.n_features}) inputs, got shape {x.shape}")
        y = jnp.asarray(np.asarray(y), jnp.int32)
        a = np.atleast_2d(np.asarray(assignments)).astype(np.int32)
        if a.shape[1] != self.n_pairs:
            raise ValueError(
                f"assignments have {a.shape[1]} pairs, machine has "
                f"{self.n_pairs}")
        return x, y, jnp.asarray(a)

    def stream(self, x, y, assignments, n_variants: int,
               accuracy_floor: float, mesh=None,
               confidence: float = mcstream.DEFAULT_CONFIDENCE,
               ci: str = "wilson") -> dict:
        """Stream ``n_variants`` mismatch instances through the donated
        chunk step and return ``mcstream.finalize``'s statistics dict
        (per-assignment mean/std/worst/yield + binomial bounds + the raw
        histogram sketch under ``"hist"``).

        ``mesh``: a ``make_variant_mesh`` shards each chunk's variant
        axis across devices (chunk size rounds up to a whole per-device
        slice; the validity mask keeps the padded tail inert).
        """
        if n_variants < 1:
            raise ValueError(f"n_variants must be >= 1, got {n_variants}")
        x, y, a = self._prep(x, y, assignments)
        b = self._chunk_size(mesh)
        step = self._step_jit if mesh is None else self._sharded_step(mesh)
        floor = jnp.float32(accuracy_floor)
        state = mcstream.init_stream(
            int(a.shape[0]), mcstream.hist_bins(int(x.shape[0])))
        for start in range(0, n_variants, b):
            v_idx, valid, u = self._chunk_inputs(start, b, n_variants)
            state = step(state, x, v_idx, valid, floor, a, y, u)
        out = mcstream.finalize(state, confidence, ci)
        out["hist"] = np.asarray(state.hist, np.float64)
        out["n_variants"] = int(n_variants)
        out["method"] = self.method
        out["accuracy_floor"] = float(accuracy_floor)
        return out

    def pair_bits_dense(self, x, v_idx) -> np.ndarray:
        """Dense oracle: the bit tensor ``(B, n, P, 2)`` of the GLOBAL
        variant indices ``v_idx`` — the exact bits the streamed chunks
        fold away.  Small-V parity tests recombine these through the
        dense ``dse.assignment_accuracies_mc`` path and compare against
        the streamed accumulators."""
        x = jnp.asarray(np.asarray(x), jnp.float32)
        v_idx = np.asarray(v_idx, np.int32)
        if self._sampler is not None:
            if not np.array_equal(
                    v_idx, np.arange(v_idx[0], v_idx[0] + len(v_idx))):
                raise ValueError(
                    "QMC methods need a contiguous v_idx range (the "
                    "low-discrepancy stream is indexed, not keyed)")
            u = jnp.asarray(self._sampler.chunk(int(v_idx[0]), len(v_idx)))
        else:
            u = jnp.zeros((len(v_idx), 0), jnp.float32)
        bits, _, _ = self._bits_jit(x, jnp.asarray(v_idx), u)
        return np.asarray(bits)

    def chunk_weights(self, v_idx) -> np.ndarray:
        """ABSOLUTE (mean-centered) sampling weights of the given global
        variants (1 unless ``method='is'``) — the IS-estimator tests'
        hook.  The in-graph weights are chunk-relative; folding the
        chunk's log-scale back in happens here in host f64, so weights
        from different chunks of one stream are mutually comparable
        (introspection only — huge banks can overflow even f64)."""
        d = self.n_features
        x = jnp.zeros((1, d), jnp.float32)
        v_idx = np.asarray(v_idx, np.int32)
        if self._sampler is not None:
            u = jnp.asarray(self._sampler.chunk(int(v_idx[0]), len(v_idx)))
        else:
            u = jnp.zeros((len(v_idx), 0), jnp.float32)
        _, w, log_ref = self._bits_jit(x, jnp.asarray(v_idx), u)
        return np.asarray(w, np.float64) * np.exp(float(log_ref))

    def step_memory_analysis(self, n_val: int, n_assignments: int = 1,
                             mesh=None):
        """XLA ``memory_analysis`` of the compiled chunk step at the given
        validation/assignment shapes — the object the flat-memory CI gate
        inspects.  Returns None when the backend does not report one."""
        b = self._chunk_size(mesh)
        x = jnp.zeros((int(n_val), self.n_features), jnp.float32)
        y = jnp.zeros((int(n_val),), jnp.int32)
        a = jnp.zeros((int(n_assignments), self.n_pairs), jnp.int32)
        state = mcstream.init_stream(
            int(n_assignments), mcstream.hist_bins(int(n_val)))
        v_idx, valid, u = self._chunk_inputs(0, b, b)
        step = self._step_jit if mesh is None else self._sharded_step(mesh)
        lowered = step.lower(state, x, v_idx, valid,
                             jnp.float32(0.5), a, y, u)
        return lowered.compile().memory_analysis()


def compile_mc_stream(
    candidates: Sequence,
    n_classes: int,
    key: jax.Array,
    method: str = "iid",
    mc_chunk: int = MC_STREAM_CHUNK,
    sigma_scale: float = 1.0,
    is_scale: float = 2.0,
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> StreamingMCMachine:
    """Lower per-pair candidates to a flat-memory streaming MC machine.

    ``candidates``/``n_classes``/``key`` as :func:`compile_variants` —
    the key is split once per pair exactly the same way, and variant
    ``v`` of pair ``p`` derives from ``fold_in(keys[p], v)``, so the
    stream is a pure function of ``(key, v)``: chunk-size invariant,
    restartable, and shardable.  (The *sequence* of draws differs from
    :func:`compile_variants`'s one-shot ``(V, ...)`` sampling — the
    streamed engine's dense oracle is its own
    :meth:`StreamingMCMachine.pair_bits_dense`, not the old machine.)

    ``method``: ``'iid'`` Gaussian draws, ``'sobol'`` scrambled Sobol' /
    ``'stratified'`` Latin-hypercube over the reduced mismatch space
    (inverse-CDF to normals in-graph), or ``'is'`` importance sampling
    with draws widened by ``is_scale`` and self-normalized weights
    (DESIGN.md §10.3).  Streaming always samples WITHOUT the nominal
    row — parity with the nominal machine is a tolerance contract via
    the accumulators, not a bit-identity row (the dense machines keep
    that contract).
    """
    pairs = class_pairs(n_classes)
    if len(candidates) != len(pairs):
        raise ValueError(
            f"{len(candidates)} candidate pairs for {n_classes} classes "
            f"(expected {len(pairs)})")
    p = len(pairs)
    keys = jax.random.split(key, p)
    specs = []
    analog_clfs: dict[int, AnalogBinaryClassifier] = {}
    analog_rows: dict[int, int] = {}
    for i, (lin_clf, rbf_clf) in enumerate(candidates):
        specs.append(_lower_classifier(i, lin_clf))
        specs.append(_lower_classifier(p + i, rbf_clf))
        if isinstance(rbf_clf, AnalogBinaryClassifier):
            analog_clfs[p + i] = rbf_clf
            analog_rows[p + i] = i
    # Row i of the one split table belongs to analog pair p+i (the
    # compile_variants convention).  Gather each pair's key here, ONCE,
    # so the per-bank constant builder never re-reads the table.
    analog_keys = {q: keys[i] for q, i in analog_rows.items()}
    linear_groups, kernel_groups = _group_specs(specs)
    linear_banks = [_LinearBank.build(g) for g in linear_groups]
    kernel_banks, stream_consts = [], []
    u_offset = 0
    for g in kernel_groups:
        bank = _KernelBank.build(g)
        kernel_banks.append(bank)
        in_group = [s.pair in analog_clfs for s in g]
        if not any(in_group):
            stream_consts.append(None)
            continue
        if not all(in_group):  # cannot happen: 'hw' curves group apart
            raise ValueError(
                "bank mixes variant and variant-free lanes; grouping bug")
        bc = _stream_bank_const(
            g, analog_clfs, analog_keys, int(bank.sv.shape[1]),
            int(bank.sv.shape[2]), u_offset)
        u_offset += bc.u_width
        stream_consts.append(bc)
    return StreamingMCMachine(
        n_classes, linear_banks, kernel_banks, stream_consts,
        method=method, mc_chunk=mc_chunk, sigma_scale=sigma_scale,
        is_scale=is_scale, key_data=_key_data(key),
        use_pallas=use_pallas, interpret=interpret)


def _stream_bank_const(group, analog_clfs, analog_keys, m_max: int, d: int,
                       u_offset: int) -> _StreamBankConst:
    """Build one bank's generation constants from its lowered specs."""
    n_pairs_bank = len(group)
    dva = np.zeros((n_pairs_bank, m_max), np.float32)
    pos = np.zeros((n_pairs_bank, m_max), np.float32)
    neg = np.zeros((n_pairs_bank, m_max), np.float32)
    valid = np.zeros((n_pairs_bank, m_max), np.float32)
    grids, curves, lefts, rights, pair_key_list = [], [], [], [], []
    params = None
    true_dim = 0
    for j, spec in enumerate(group):
        clf = analog_clfs[spec.pair]
        if params is None:
            params = clf.hw.params
        elif clf.hw.params != params:
            raise ValueError(
                "analog candidates in one bank carry different "
                "CircuitParams; the streaming generator assumes one "
                "process corner per bank")
        m = clf.n_support
        dva[j, :m] = np.asarray(clf.hw.alpha_control_voltage(
            jnp.asarray(clf.alpha_hw, jnp.float32)), np.float32)
        pos[j, :m] = (clf.support_y > 0).astype(np.float32)
        neg[j, :m] = (clf.support_y <= 0).astype(np.float32)
        valid[j, :m] = 1.0
        order = np.argsort(clf.hw.dva_grid)
        grids.append(np.asarray(clf.hw.dva_grid, np.float32)[order])
        curves.append(np.asarray(clf.hw.alpha_curve, np.float32)[order])
        lefts.append(curves[-1][0])
        rights.append(curves[-1][-1])
        pair_key_list.append(analog_keys[spec.pair])
        true_dim += variant_dim(m, clf.n_features)
    if len({g.shape[0] for g in grids}) != 1:
        raise ValueError("analog alpha sweeps in one bank differ in length")
    return _StreamBankConst(
        pair_keys=jnp.stack(pair_key_list),
        dva=jnp.asarray(dva), pos_mask=jnp.asarray(pos),
        neg_mask=jnp.asarray(neg), slot_valid=jnp.asarray(valid),
        alpha_grid=jnp.asarray(np.stack(grids)),
        alpha_curve=jnp.asarray(np.stack(curves)),
        alpha_left=jnp.asarray(np.asarray(lefts, np.float32)),
        alpha_right=jnp.asarray(np.asarray(rights, np.float32)),
        params=params, u_offset=u_offset,
        u_width=n_pairs_bank * variant_dim(m_max, d), true_dim=true_dim)
