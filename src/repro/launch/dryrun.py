import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:
  * build the step fn + ShapeDtypeStruct inputs + sharding specs
    (repro.launch.steps),
  * jit(...).lower(...).compile() under the production mesh,
  * record memory_analysis(), cost_analysis(), and the collective
    schedule parsed from the post-SPMD optimized HLO,
  * dump one JSON per cell into --out (default runs/dryrun/).

This is deliverable (e): compile failures (sharding mismatch, OOM at
compile, unsupported collective) are bugs.  benchmarks/roofline.py
consumes the JSONs for deliverable (g).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-32b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import re
import time
import traceback

import jax
from jax.sharding import NamedSharding

from repro import configs
from repro.launch import mesh as mesh_mod
from repro.launch import steps as steps_mod

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^\s]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in filter(None, dims.split(",")):
        n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> dict:
    """Per-opcode result-bytes totals from the partitioned HLO.

    Shapes in the post-SPMD module are per-partition, so the totals are
    per-device traffic proxies; roofline.py applies the ring factors
    (all-reduce 2(n-1)/n, all-gather/reduce-scatter (n-1)/n, all-to-all
    (n-1)/n) using each op's replica-group size, parsed here too.
    """
    out: dict[str, dict] = {}
    for m in _COLL_RE.finditer(hlo_text):
        tuple_body, dtype, dims, op = m.groups()
        if tuple_body:  # tuple result (e.g. all-reduce of N operands)
            nbytes = sum(_shape_bytes(t, d)
                         for t, d in _SHAPE_RE.findall(tuple_body))
        else:
            nbytes = _shape_bytes(dtype, dims)
        # replica group size: iota format [g,s]<=[n] or explicit {{...}}
        tail = hlo_text[m.end():m.end() + 400]
        gsize = None
        mg = re.search(r"replica_groups=\[(\d+),(\d+)\]", tail)
        if mg:
            gsize = int(mg.group(2))
        else:
            mg = re.search(r"replica_groups=\{\{([0-9, ]*)\}", tail)
            if mg:
                gsize = len(mg.group(1).split(","))
        rec = out.setdefault(op, {"count": 0, "bytes": 0, "by_group": {}})
        rec["count"] += 1
        rec["bytes"] += nbytes
        key = str(gsize or "?")
        rec["by_group"][key] = rec["by_group"].get(key, 0) + nbytes
    return out


def block_cost(arch: str, shape_name: str, multi_pod: bool, mesh,
               variant: str = "") -> dict:
    """Per-layer marginal cost, for scan trip-count correction.

    XLA's HloCostAnalysis visits while-loop bodies ONCE, so the full
    module undercounts the layer scan by ~L x.  We lower one layer block
    standalone (train cells: fwd+bwd under the same remat policy) twice —
    scanned (matching what the full module counted) and fully unrolled
    (true per-layer cost) — and roofline.py reconstructs:

        total = full_raw - body_scanned + L * body_unrolled
    """
    import dataclasses as dc

    from repro.models import transformer as tfm
    from repro.distributed import partition
    import jax.numpy as jnp

    cell = steps_mod.build_cell(arch, shape_name, multi_pod, variant)
    cfg, rules, kind = cell["cfg"], cell["rules"], cell["kind"]
    if kind == "decode":
        return {}  # decode layers are unrolled in production: already exact

    sh = {"train_4k": (4096, 256), "prefill_32k": (32768, 32)}[shape_name]
    s, b = sh
    if cfg.family == "vlm":
        s += cfg.n_patches
    if cfg.family == "audio":
        s = s // cfg.dec_seq_divisor
    dt = cfg.compute_dtype

    params_sds = jax.eval_shape(
        lambda: tfm.init_params(cfg, jax.random.PRNGKey(0)))
    lp_sds = jax.tree.map(
        lambda sd: jax.ShapeDtypeStruct(sd.shape[1:], sd.dtype),
        params_sds["layers"])
    axis_sizes = ({"pod": 2, "data": 16, "model": 16} if multi_pod
                  else {"data": 16, "model": 16})
    lp_specs = partition.fit_tree(
        jax.tree.map(lambda sp: jax.sharding.PartitionSpec(*sp[1:]),
                     partition.param_specs(cfg, params_sds, rules)["layers"],
                     is_leaf=lambda x: isinstance(
                         x, jax.sharding.PartitionSpec)),
        lp_sds, axis_sizes)
    x_sds = jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)
    x_spec = jax.sharding.PartitionSpec(rules.dp, None, None)

    out = {"n_layers": cfg.n_layers, "n_enc_layers": cfg.n_enc_layers}
    for tag, unroll in (("scanned", False), ("unrolled", True)):
        c = dc.replace(cfg, scan_unroll=unroll)
        pos = jnp.arange(s)

        def raw_block(lp, x):
            y, _ = tfm.block_forward(c, rules, lp, x, pos)
            return y

        # same remat policy as the production scan body, so the correction
        # counts the backward recompute the real module pays for.
        rematted = tfm._remat(c, raw_block)

        def block_fn(lp, x):
            return jnp.sum(rematted(lp, x).astype(jnp.float32))

        fn = jax.grad(block_fn, argnums=(0, 1)) if kind == "train" \
            else block_fn
        shardings = jax.tree.map(
            lambda spec: NamedSharding(mesh, spec), (lp_specs, x_spec),
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        with jax.set_mesh(mesh):
            compiled = jax.jit(fn, in_shardings=shardings).lower(
                lp_sds, x_sds).compile()
        ca = compiled.cost_analysis() or {}
        out[tag] = {
            "flops": ca.get("flops", 0.0),
            "bytes": ca.get("bytes accessed", 0.0),
            "collectives": parse_collectives(compiled.as_text()),
        }
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             variant: str = "", save_hlo: str | None = None) -> dict:
    t0 = time.time()
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    cell = steps_mod.build_cell(arch, shape_name, multi_pod, variant)

    shardings = jax.tree.map(
        lambda spec: NamedSharding(mesh, spec), cell["in_specs"],
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))

    with jax.set_mesh(mesh):
        lowered = jax.jit(cell["fn"], in_shardings=shardings).lower(
            *cell["args_sds"])
        compiled = lowered.compile()

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    colls = parse_collectives(hlo)
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)

    try:
        block = block_cost(arch, shape_name, multi_pod, mesh, variant)
    except Exception as e:  # noqa: BLE001 — block correction is best-effort
        block = {"error": repr(e)}

    cfg = cell["cfg"]
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "pod2x16x16" if multi_pod else "16x16",
        "variant": variant or "baseline",
        "kind": cell["kind"],
        "compile_s": round(time.time() - t0, 1),
        "chips": 512 if multi_pod else 256,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "flops_per_device": ca.get("flops", 0.0),
        "bytes_accessed_per_device": ca.get("bytes accessed", 0.0),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "code_bytes": ma.generated_code_size_in_bytes,
        },
        "collectives": colls,
        "block_cost": block,
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--variant", default="")
    ap.add_argument("--all", action="store_true",
                    help="run the full assigned grid")
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    if args.all:
        cells = [(a, s) for a in configs.ARCHS for s in configs.shapes_for(a)]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}_{shape}_{'multi' if mp else 'single'}"
            if args.variant:
                tag += "_" + re.sub(r"[^A-Za-z0-9]+", "-", args.variant)
            try:
                rec = run_cell(arch, shape, mp, args.variant, args.save_hlo)
                path = os.path.join(args.out, tag + ".json")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                coll_b = sum(v["bytes"] for v in rec["collectives"].values())
                print(f"OK   {tag:60s} compile={rec['compile_s']:6.1f}s "
                      f"flops/dev={rec['flops_per_device']:.3e} "
                      f"coll_bytes/dev={coll_b:.3e} "
                      f"temp={rec['memory']['temp_bytes']/2**30:.2f}GiB",
                      flush=True)
            except Exception as e:  # noqa: BLE001 — report, continue grid
                failures.append((tag, repr(e)))
                print(f"FAIL {tag}: {e}", flush=True)
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} cells failed: "
                         + ", ".join(t for t, _ in failures))
    print("all cells compiled")


if __name__ == "__main__":
    main()
