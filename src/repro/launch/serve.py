"""Serving driver: prefill + batched decode with sampling.

Demonstrates the full serve path (the same prefill/decode_step the
dry-run lowers at 32k/500k): a batch of prompts is prefetched through
``engine.prefill`` and decoded step-locked with temperature sampling.

  PYTHONPATH=src python -m repro.launch.serve --arch hymba-1.5b \
      --reduced --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import transformer as tfm
from repro.models.common import ShardRules
from repro.serving import engine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hymba-1.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    mod = configs.get(args.arch)
    cfg = mod.reduced() if args.reduced else mod.make_config()
    rules = ShardRules()
    params = tfm.init_params(cfg, jax.random.PRNGKey(args.seed))

    rng = np.random.RandomState(args.seed)
    prompts = jnp.asarray(
        rng.randint(0, cfg.vocab_size, (args.batch, args.prompt_len)))
    cap = args.prompt_len + args.gen + 8

    t0 = time.time()
    state, logits = engine.prefill(cfg, params, {"tokens": prompts}, cap,
                                   rules)
    print(f"prefill {args.batch}x{args.prompt_len} in "
          f"{time.time() - t0:.2f}s")

    # Decode step with sampling fused in-graph.  The state pytree is
    # DONATED: without it the jit holds input and output caches alive
    # simultaneously — two full KV-cache copies per step.  The per-token
    # key is folded from the decode position inside the graph, replacing
    # the host-side jax.random.split that synced the stream every step.
    def _decode_sample(p, s, t, key):
        s, logits = engine.decode_step(cfg, p, s, t, rules)
        sub = jax.random.fold_in(key, s["pos"])
        t = jax.random.categorical(
            sub, logits / args.temperature, -1)[:, None]
        return s, t

    decode = jax.jit(_decode_sample, donate_argnums=(1,))
    key = jax.random.PRNGKey(args.seed + 1)
    tok = jnp.argmax(logits, -1)[:, None]
    outs = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        state, tok = decode(params, state, tok, key)
        outs.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(outs, axis=1)
    print(f"decoded {args.gen} tokens x {args.batch} seqs in {dt:.2f}s "
          f"({args.gen * args.batch / dt:.1f} tok/s)")
    print("sample:", np.asarray(gen[0])[:16])
    return gen


if __name__ == "__main__":
    main()
