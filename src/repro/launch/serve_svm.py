"""SVM fleet serving driver: stream near-sensor queries through the engine.

Fits one machine per dataset (Algorithm 1), co-batches them into a
:class:`~repro.api.FleetMachine`, and drives an open-loop Poisson query
stream through :class:`~repro.serving.SVMEngine` — the deployed-fleet
picture of ROADMAP item 2: many tenants, continuous small queries, one
device program per padded bucket.

PR 10 controls: ``--deadline-ms``/``--priority-classes`` attach deadlines
and priority classes to the stream (the batch former serves EDF with
cross-class backfill), ``--queue-bound``/``--shed-expired`` switch on
admission control (shed requests resolve with ``ShedError`` and are
reported, backpressure throttles the producer), ``--mesh-devices``
dispatches through the shard_map data-parallel forward on a
``make_serving_mesh``, and ``--pipeline-depth`` sets how many batches
overlap staging and compute.

  PYTHONPATH=src python -m repro.launch.serve_svm \
      --datasets balance,seeds --rate 5000 --n-queries 4000 \
      --deadline-ms 25 --priority-classes 2 --queue-bound 2048 \
      --shed-expired --pipeline-depth 2
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", default="balance,seeds",
                    help="comma-separated fleet tenants")
    ap.add_argument("--target", default="circuit")
    ap.add_argument("--n-epochs", type=int, default=60)
    ap.add_argument("--n-queries", type=int, default=4000)
    ap.add_argument("--rate", type=float, default=5000.0,
                    help="open-loop Poisson arrival rate (queries/s)")
    ap.add_argument("--max-batch", type=int, default=256,
                    help="per-device max bucket rows")
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request completion deadline (default: none)")
    ap.add_argument("--priority-classes", type=int, default=1,
                    help="spread requests uniformly over this many "
                         "priority classes (0 = lowest)")
    ap.add_argument("--queue-bound", type=int, default=None,
                    help="pending-row bound; overflow sheds expired then "
                         "lowest-priority work (default: unbounded)")
    ap.add_argument("--shed-expired", action="store_true",
                    help="drop queued requests whose deadline passed "
                         "instead of serving them")
    ap.add_argument("--pipeline-depth", type=int, default=1,
                    help="batches in flight before blocking on the oldest")
    ap.add_argument("--mesh-devices", type=int, default=None,
                    help="serve through a make_serving_mesh over this "
                         "many devices (default: single-device dispatch)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.api import MixedKernelSVM, compile_fleet
    from repro.data import datasets
    from repro.serving import ShedError, SVMEngine

    names = [n.strip() for n in args.datasets.split(",") if n.strip()]
    members, pools = {}, {}
    for name in names:
        ds = datasets.load(name)
        t0 = time.time()
        est = MixedKernelSVM(n_epochs=args.n_epochs, seed=args.seed).fit(
            ds.x_train, ds.y_train)
        members[name] = est.deploy(args.target)
        pools[name] = np.asarray(ds.x_test, np.float32)
        print(f"fit+deploy [{name}] in {time.time() - t0:.1f}s "
              f"(K={members[name].n_classes}, d={members[name].n_features})")
    fleet = compile_fleet(members)
    print(fleet.describe())

    mesh = None
    if args.mesh_devices is not None:
        from repro.launch.mesh import make_serving_mesh

        mesh = make_serving_mesh(args.mesh_devices)
        print(f"mesh: {args.mesh_devices} device(s) on the "
              f"'{mesh.axis_names[0]}' axis")

    rng = np.random.RandomState(args.seed)
    with SVMEngine(fleet, max_batch=args.max_batch,
                   max_wait_ms=args.max_wait_ms, mesh=mesh,
                   pipeline_depth=args.pipeline_depth,
                   queue_bound=args.queue_bound,
                   shed_expired=args.shed_expired) as eng:
        eng.warmup()
        futures = []
        next_t = time.perf_counter()
        t0 = next_t
        backpressured = 0
        for i in range(args.n_queries):
            name = names[rng.randint(len(names))]
            pool = pools[name]
            x = pool[rng.randint(len(pool))]
            prio = int(rng.randint(args.priority_classes)) \
                if args.priority_classes > 1 else 0
            if eng.backpressure:
                backpressured += 1
            futures.append((name, x, eng.submit(
                x, name, deadline_ms=args.deadline_ms, priority=prio)))
            next_t += rng.exponential(1.0 / args.rate)
            pause = next_t - time.perf_counter()
            if pause > 0:
                time.sleep(pause)
        labels, n_shed = [], 0
        for _, _, f in futures:
            try:
                labels.append(f.result(timeout=60.0))
            except ShedError:
                labels.append(None)
                n_shed += 1
        wall = time.perf_counter() - t0

    # Spot-check routing against the member machines' direct predictions.
    for (name, x, _), lab in list(zip(futures, labels))[:: max(
            1, args.n_queries // 64)]:
        if lab is None:
            continue
        want = int(fleet.member(name).predict(x[None])[0])
        assert lab == want, f"routing mismatch for {name}: {lab} != {want}"

    summary = eng.stats.summary()
    summary["wall_s"] = round(wall, 3)
    summary["offered_rate"] = args.rate
    if n_shed or args.queue_bound is not None or args.shed_expired:
        summary["shed_futures"] = n_shed
        summary["backpressured_submits"] = backpressured
    print(json.dumps(summary, indent=2))
    return summary


if __name__ == "__main__":
    main()
