"""SVM fleet serving driver: stream near-sensor queries through the engine.

Fits one machine per dataset (Algorithm 1), co-batches them into a
:class:`~repro.api.FleetMachine`, and drives an open-loop Poisson query
stream through :class:`~repro.serving.SVMEngine` — the deployed-fleet
picture of ROADMAP item 2: many tenants, continuous small queries, one
device program per padded bucket.

  PYTHONPATH=src python -m repro.launch.serve_svm \
      --datasets balance,seeds --rate 5000 --n-queries 4000
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", default="balance,seeds",
                    help="comma-separated fleet tenants")
    ap.add_argument("--target", default="circuit")
    ap.add_argument("--n-epochs", type=int, default=60)
    ap.add_argument("--n-queries", type=int, default=4000)
    ap.add_argument("--rate", type=float, default=5000.0,
                    help="open-loop Poisson arrival rate (queries/s)")
    ap.add_argument("--max-batch", type=int, default=256)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.api import MixedKernelSVM, compile_fleet
    from repro.data import datasets
    from repro.serving import SVMEngine

    names = [n.strip() for n in args.datasets.split(",") if n.strip()]
    members, pools = {}, {}
    for name in names:
        ds = datasets.load(name)
        t0 = time.time()
        est = MixedKernelSVM(n_epochs=args.n_epochs, seed=args.seed).fit(
            ds.x_train, ds.y_train)
        members[name] = est.deploy(args.target)
        pools[name] = np.asarray(ds.x_test, np.float32)
        print(f"fit+deploy [{name}] in {time.time() - t0:.1f}s "
              f"(K={members[name].n_classes}, d={members[name].n_features})")
    fleet = compile_fleet(members)
    print(fleet.describe())

    rng = np.random.RandomState(args.seed)
    with SVMEngine(fleet, max_batch=args.max_batch,
                   max_wait_ms=args.max_wait_ms) as eng:
        eng.warmup()
        futures = []
        next_t = time.perf_counter()
        t0 = next_t
        for i in range(args.n_queries):
            name = names[rng.randint(len(names))]
            pool = pools[name]
            x = pool[rng.randint(len(pool))]
            futures.append((name, x, eng.submit(x, name)))
            next_t += rng.exponential(1.0 / args.rate)
            pause = next_t - time.perf_counter()
            if pause > 0:
                time.sleep(pause)
        labels = [f.result(timeout=60.0) for _, _, f in futures]
        wall = time.perf_counter() - t0

    # Spot-check routing against the member machines' direct predictions.
    for (name, x, _), lab in list(zip(futures, labels))[:: max(
            1, args.n_queries // 64)]:
        want = int(fleet.member(name).predict(x[None])[0])
        assert lab == want, f"routing mismatch for {name}: {lab} != {want}"

    summary = eng.stats.summary()
    summary["wall_s"] = round(wall, 3)
    summary["offered_rate"] = args.rate
    print(json.dumps(summary, indent=2))
    return summary


if __name__ == "__main__":
    main()
