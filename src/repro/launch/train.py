"""Training driver: data pipeline -> train_step -> checkpoints, resumable.

Runs on anything from 1 CPU (reduced configs) to the production mesh
(full configs under pjit; the sharding specs come from the same
partition rules the dry-run proves).  Fault tolerance: atomic
checkpoints every --ckpt-every steps, --resume auto picks up the latest
complete one, and the stateless data pipeline replays the exact stream
from any step.

  PYTHONPATH=src python -m repro.launch.train --arch granite-20b \
      --reduced --steps 50 --global-batch 8 --seq-len 128
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro import configs
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.models.common import ShardRules
from repro.training import optimizer as opt_mod
from repro.training import step as step_mod


def build(args):
    mod = configs.get(args.arch)
    cfg = mod.reduced() if args.reduced else mod.make_config()
    if args.d_model:
        cfg = dataclasses.replace(
            cfg, d_model=args.d_model, d_ff=args.d_ff or 4 * args.d_model,
            n_layers=args.n_layers or cfg.n_layers)
    oc = opt_mod.AdamWConfig(lr=args.lr, warmup_steps=args.warmup,
                             total_steps=args.steps,
                             quantize_state=args.opt8)
    return cfg, oc


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-20b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--opt8", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", choices=["auto", "none"], default="auto")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    # overrides for the ~100M example preset
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--d-ff", type=int, default=0)
    ap.add_argument("--n-layers", type=int, default=0)
    args = ap.parse_args(argv)

    cfg, oc = build(args)
    rules = ShardRules()
    print(f"arch={cfg.name} params~{cfg.param_count():,} "
          f"steps={args.steps} gb={args.global_batch} seq={args.seq_len}")

    pipe = TokenPipeline(PipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.global_batch, seed=args.seed))

    state = step_mod.init_train_state(cfg, oc, jax.random.PRNGKey(args.seed))
    start = 0
    if args.ckpt_dir and args.resume == "auto":
        latest = ckpt.latest_step(args.ckpt_dir)
        if latest is not None:
            start, state = ckpt.restore(args.ckpt_dir, state)
            print(f"resumed from step {start}")

    ts = jax.jit(step_mod.make_train_step(cfg, rules, oc,
                                          grad_accum=args.grad_accum))
    t0 = time.time()
    losses = []
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
        state, m = ts(state, batch)
        losses.append(float(m["loss"]))
        if (step + 1) % args.log_every == 0:
            dt = (time.time() - t0) / max(step + 1 - start, 1)
            print(f"step {step + 1:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(m['grad_norm']):.2f} "
                  f"lr {float(m['lr']):.2e} {dt:.2f}s/step", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, step + 1, state)
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps, state)
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
