"""Step builders + sharding spec trees for the dry-run and drivers.

For each cell (arch x shape) this produces:
  fn            the jittable step (train_step / prefill_step / serve_step)
  args_sds      ShapeDtypeStruct pytree of its inputs
  in_specs      PartitionSpec pytree matching args_sds
  out_specs     PartitionSpec pytree (or None -> let SPMD choose)

Variants (the §Perf hillclimb knobs) are config/rule transformations
applied before building: remat policy, fsdp on/off, 8-bit optimizer,
int8 weights for decode, scan-attention block size, MoE capacity.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.configs import base as cfg_base
from repro.distributed import partition
from repro.models import transformer as tfm
from repro.models.common import ModelConfig, ShardRules
from repro.serving import engine
from repro.training import optimizer as opt_mod
from repro.training import step as step_mod


def apply_variant(cfg: ModelConfig, rules: ShardRules, opt_cfg,
                  variant: str):
    """Parse 'k=v,k=v' variant strings into config/rule overrides."""
    quant_weights = False
    extras = {"castbf16": False, "kvtp": False}
    for item in filter(None, (variant or "").split(",")):
        k, _, v = item.partition("=")
        if k == "remat":
            cfg = dataclasses.replace(cfg, remat=v)
        elif k == "fsdp":
            rules = dataclasses.replace(
                rules, fsdp=None if v in ("none", "off") else v)
        elif k == "sp":
            rules = dataclasses.replace(
                rules, sp=None if v in ("none", "off") else v)
        elif k == "opt8":
            opt_cfg = dataclasses.replace(opt_cfg, quantize_state=v == "on")
        elif k == "attn_block":
            cfg = dataclasses.replace(cfg, attn_block=int(v))
        elif k == "cap":
            cfg = dataclasses.replace(cfg, capacity_factor=float(v))
        elif k == "wq":
            quant_weights = v == "int8"
        elif k == "dtype":
            cfg = dataclasses.replace(cfg, dtype=v)
        elif k == "castbf16":
            extras["castbf16"] = v == "on"
        elif k == "kvtp":
            extras["kvtp"] = v == "on"
        elif k == "moegroups":
            cfg = dataclasses.replace(cfg, moe_groups=int(v))
        elif k == "moe2d":
            cfg = dataclasses.replace(cfg, moe_two_d=v == "on")
        elif k == "kv":
            cfg = dataclasses.replace(cfg, kv_dtype=v)
        elif k == "unroll":
            cfg = dataclasses.replace(cfg, scan_unroll=v == "on")
        elif k == "scan_attn":
            cfg = dataclasses.replace(cfg, use_scan_attention=v == "on")
        else:
            raise ValueError(f"unknown variant key {k!r}")
    return cfg, rules, opt_cfg, quant_weights, extras


def _sds_of(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype)
        if not isinstance(x, jax.ShapeDtypeStruct) else x, tree)


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               variant: str = ""):
    """Returns dict(fn, args_sds, in_specs, kind, cfg)."""
    mod = configs.get(arch)
    cfg = mod.make_config()
    rules = ShardRules(dp=("pod", "data") if multi_pod else ("data",))
    opt_cfg = opt_mod.AdamWConfig()
    cfg, rules, opt_cfg, quant_w, extras = apply_variant(
        cfg, rules, opt_cfg, variant)

    sh = cfg_base.SHAPES[shape_name]
    kind = sh["kind"]
    specs_in = cfg_base.input_specs(cfg, shape_name)

    axis_sizes = ({"pod": 2, "data": 16, "model": 16} if multi_pod
                  else {"data": 16, "model": 16})

    # parameter skeleton via eval_shape (no allocation)
    params_sds = jax.eval_shape(lambda: tfm.init_params(cfg, jax.random.PRNGKey(0)))
    if kind != "train":
        # serving runs in compute dtype (bf16) and optionally int8 weights
        def cast(sd):
            if sd.dtype == jnp.float32 and len(sd.shape) >= 2:
                return jax.ShapeDtypeStruct(
                    sd.shape, jnp.int8 if quant_w else cfg.compute_dtype)
            return sd
        serve_params_sds = jax.tree.map(cast, params_sds)
    p_specs = partition.fit_tree(
        partition.param_specs(cfg, params_sds, rules), params_sds, axis_sizes)

    if kind == "train":
        opt_sds = jax.eval_shape(
            lambda: opt_mod.init_state(opt_cfg, params_sds))
        state_sds = {"params": params_sds, "opt": opt_sds,
                     "step": jax.ShapeDtypeStruct((), jnp.int32)}
        state_specs = {
            "params": p_specs,
            "opt": partition.fit_tree(
                partition.opt_specs(cfg, p_specs, opt_sds, rules),
                opt_sds, axis_sizes),
            "step": P(),
        }
        batch_sds = specs_in["batch"]
        batch_specs = partition.fit_tree(
            partition.batch_specs(batch_sds, rules), batch_sds, axis_sizes)
        fn = step_mod.make_train_step(
            cfg, rules, opt_cfg, cast_params_bf16=extras["castbf16"])
        return dict(fn=fn, args_sds=(state_sds, batch_sds),
                    in_specs=(state_specs, batch_specs), kind=kind,
                    cfg=cfg, rules=rules)

    if kind == "prefill":
        batch_sds = specs_in["batch"]
        batch_specs = partition.fit_tree(
            partition.batch_specs(batch_sds, rules), batch_sds, axis_sizes)
        cap = sh["seq_len"] + (cfg.n_patches if cfg.family == "vlm" else 0)

        if cfg.family == "audio":
            def fn(params, batch):
                return engine.prefill_audio(cfg, params, batch, cap, rules)
        else:
            def fn(params, batch):
                return engine.prefill(cfg, params, batch, cap, rules)
        return dict(fn=fn, args_sds=(serve_params_sds, batch_sds),
                    in_specs=(p_specs, batch_specs), kind=kind,
                    cfg=cfg, rules=rules)

    # decode
    state_sds = specs_in["state"]
    tok_sds = specs_in["tokens"]
    dp_size = 32 if multi_pod else 16
    st_specs = partition.fit_tree(
        partition.serve_state_specs(cfg, state_sds, rules,
                                    dp_size=dp_size, tp_size=16,
                                    kv_len_tp=extras["kvtp"]),
        state_sds, axis_sizes)
    b = tok_sds.shape[0]
    tok_spec = P(rules.dp, None) if b % dp_size == 0 else P(None, None)

    def fn(params, state, tokens):
        return engine.decode_step(cfg, params, state, tokens, rules)

    return dict(fn=fn, args_sds=(serve_params_sds, state_sds, tok_sds),
                in_specs=(p_specs, st_specs, tok_spec), kind=kind,
                cfg=cfg, rules=rules)
