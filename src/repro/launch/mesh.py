"""Production mesh construction (DESIGN.md §6, assignment §dry-run).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialisation).

``jax.sharding.AxisType`` only exists on newer jax; older builds construct
the same mesh without explicit axis types (Auto is their only behavior).
"""
from __future__ import annotations

import jax

try:
    from jax.sharding import AxisType

    def _axis_kwargs(n_axes: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n_axes}
except ImportError:  # pre-AxisType jax: meshes are implicitly Auto

    def _axis_kwargs(n_axes: int) -> dict:
        return {}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for multi-device CPU tests (8 fake devices)."""
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))


def make_trainer_mesh(n_devices: int | None = None):
    """1-D mesh for the batched SVM trainer's shard_map variant.

    The single axis is named ``"pairgrid"`` (`trainer.PAIRGRID_AXIS`): the
    flattened OvO-pair x gamma axis of the CV-grid program shards across
    it with no collectives (DESIGN.md §4.4).
    """
    n = int(n_devices) if n_devices is not None else len(jax.devices())
    return jax.make_mesh((n,), ("pairgrid",), **_axis_kwargs(1))


#: Mesh axis the streaming Monte-Carlo chunk shards over (DESIGN.md §10).
VARIANTS_AXIS = "variants"


def make_variant_mesh(n_devices: int | None = None):
    """1-D mesh for the streaming Monte-Carlo engine's shard_map leg.

    The single axis is named ``"variants"`` (:data:`VARIANTS_AXIS`): each
    device generates and scores its slice of a variant chunk, the
    psum-able accumulator sums merge with one collective per chunk, and
    the running :class:`~repro.core.mcstream.StreamStats` state stays
    replicated (DESIGN.md §10.4).
    """
    n = int(n_devices) if n_devices is not None else len(jax.devices())
    return jax.make_mesh((n,), (VARIANTS_AXIS,), **_axis_kwargs(1))


#: Mesh axis the serving engine's data-parallel forward shards over
#: (DESIGN.md §12).
SERVING_AXIS = "batch"


def make_serving_mesh(n_devices: int | None = None):
    """1-D mesh for the fleet serving engine's data-parallel forward.

    The single axis is named ``"batch"`` (:data:`SERVING_AXIS`): the
    engine's padded dispatch batch shards across it (banks replicated,
    no collectives — each device runs the exact single-device labels
    program on its row slice, DESIGN.md §12.1).  A 1-device mesh is
    valid and is how the analyzer verifies the sharded program's
    donation contract on single-device CI.
    """
    n = int(n_devices) if n_devices is not None else len(jax.devices())
    return jax.make_mesh((n,), (SERVING_AXIS,), **_axis_kwargs(1))


def dp_axes(multi_pod: bool) -> tuple:
    return ("pod", "data") if multi_pod else ("data",)
