"""The paper's three UCI datasets (Sec. V-A1), reproducible offline.

* **Balance Scale** — generated BIT-EXACTLY from its published generative
  rule: 4 features (left-weight, left-distance, right-weight,
  right-distance) each in {1..5}, 625 rows, class = sign of the torque
  difference LW*LD - RW*RD (L / B / R).  This is the dataset's actual
  definition (it is a synthetic psychology dataset), so our copy is the
  UCI copy.

* **Seeds** and **Vertebral (3 classes)** — physical measurements that
  cannot be regenerated; we ship *surrogates*: Gaussian class-conditional
  generators calibrated to the published per-class feature statistics
  (UCI documentation / source papers).  Honesty note in DESIGN.md §2:
  absolute accuracies land close to Table II but are not bit-identical;
  the claims we validate are the relative ones.

Common preprocessing per the paper: normalize features to [0, 1], drop
non-sensor features (none in these three), 70/30 train/test split, and
F-score feature selection down to <= 5 features (the analog chain limit,
Sec. III-B2).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Dataset:
    name: str
    x_train: np.ndarray
    y_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray
    n_classes: int
    feature_idx: np.ndarray  # selected original feature indices

    @property
    def n_features(self) -> int:
        return int(self.x_train.shape[1])


# ---------------------------------------------------------------------------
# Raw generators
# ---------------------------------------------------------------------------


def _balance_raw() -> tuple[np.ndarray, np.ndarray]:
    """Exact Balance Scale: 625 rows, classes {0: L, 1: B, 2: R}."""
    rows, labels = [], []
    for lw in range(1, 6):
        for ld in range(1, 6):
            for rw in range(1, 6):
                for rd in range(1, 6):
                    left, right = lw * ld, rw * rd
                    lab = 0 if left > right else (1 if left == right else 2)
                    rows.append([lw, ld, rw, rd])
                    labels.append(lab)
    return np.asarray(rows, np.float64), np.asarray(labels, np.int64)


# Published per-class feature means/stds used to calibrate the surrogates.
# Seeds (Charytanowicz et al., 2010): area, perimeter, compactness, kernel
# length, kernel width, asymmetry coefficient, groove length; classes:
# Kama / Rosa / Canadian, 70 rows each.
_SEEDS_STATS = {
    # Stds carry a 1.3-1.6x inflation over the published per-class values:
    # the real classes are NOT Gaussian (skewed, heavy-tailed), and matching
    # the published stds under a Gaussian makes the task too separable.  The
    # inflation (1.6x for Kama, the middle class that overlaps both
    # neighbours in the real data; 1.3x for Rosa/Canadian) is calibrated so
    # linear OvO accuracy lands at the paper's ~92% operating point (see
    # DESIGN.md §2 honesty notes).
    0: ([14.33, 14.29, 0.8800, 5.508, 3.245, 2.667, 5.087],
        [1.946, 0.923, 0.0256, 0.371, 0.285, 1.850, 0.422]),
    1: ([18.33, 16.14, 0.8835, 6.148, 3.677, 3.645, 6.021],
        [1.8707, 0.8021, 0.0211, 0.3484, 0.2418, 1.5366, 0.3302]),
    2: ([11.87, 13.25, 0.8494, 5.230, 2.854, 4.788, 5.116],
        [0.9399, 0.442, 0.0286, 0.1794, 0.1924, 1.7368, 0.2106]),
}
# Feature-pair correlations in seeds are strong (area~perimeter etc.);
# a single shared correlation template keeps the surrogate realistic.
_SEEDS_CORR = np.array([
    [1.00, 0.99, 0.61, 0.95, 0.97, -0.23, 0.86],
    [0.99, 1.00, 0.53, 0.97, 0.94, -0.22, 0.89],
    [0.61, 0.53, 1.00, 0.37, 0.76, -0.33, 0.23],
    [0.95, 0.97, 0.37, 1.00, 0.86, -0.17, 0.93],
    [0.97, 0.94, 0.76, 0.86, 1.00, -0.26, 0.75],
    [-0.23, -0.22, -0.33, -0.17, -0.26, 1.00, -0.01],
    [0.86, 0.89, 0.23, 0.93, 0.75, -0.01, 1.00],
])

# Vertebral column (3 classes): pelvic incidence, pelvic tilt, lumbar
# lordosis angle, sacral slope, pelvic radius, spondylolisthesis grade.
# Classes: Hernia (60), Spondylolisthesis (150), Normal (100).
_V3C_STATS = {
    0: ([47.6, 17.4, 35.5, 30.2, 116.5, 2.5],
        [10.7, 7.0, 9.7, 7.6, 9.3, 5.4]),
    1: ([71.5, 20.7, 64.1, 50.8, 114.5, 51.9],
        [15.1, 11.5, 16.4, 12.3, 15.6, 40.0]),
    2: ([51.7, 12.8, 43.5, 38.9, 123.9, 2.2],
        [12.4, 6.8, 12.4, 9.6, 9.0, 6.3]),
}
_V3C_COUNTS = {0: 60, 1: 150, 2: 100}
_V3C_CORR = np.array([
    [1.00, 0.63, 0.72, 0.81, -0.25, 0.64],
    [0.63, 1.00, 0.43, 0.06, 0.03, 0.40],
    [0.72, 0.43, 1.00, 0.60, -0.08, 0.53],
    [0.81, 0.06, 0.60, 1.00, -0.34, 0.52],
    [-0.25, 0.03, -0.08, -0.34, 1.00, -0.03],
    [0.64, 0.40, 0.53, 0.52, -0.03, 1.00],
])


def _gaussian_surrogate(stats, corr, counts, seed) -> tuple[np.ndarray, np.ndarray]:
    rng = np.random.RandomState(seed)
    # nearest-PSD guard for the hand-copied correlation templates
    w, v = np.linalg.eigh(corr)
    corr_psd = (v * np.clip(w, 1e-3, None)) @ v.T
    d = np.sqrt(np.diag(corr_psd))
    corr_psd = corr_psd / np.outer(d, d)
    chol = np.linalg.cholesky(corr_psd)
    xs, ys = [], []
    for cls, (mu, sd) in stats.items():
        n = counts[cls] if isinstance(counts, dict) else counts
        z = rng.randn(n, len(mu)) @ chol.T
        xs.append(np.asarray(mu) + z * np.asarray(sd))
        ys.append(np.full((n,), cls, np.int64))
    x = np.concatenate(xs)
    y = np.concatenate(ys)
    perm = rng.permutation(len(y))
    return x[perm], y[perm]


def _seeds_raw(seed: int = 7) -> tuple[np.ndarray, np.ndarray]:
    return _gaussian_surrogate(_SEEDS_STATS, _SEEDS_CORR, 70, seed)


def _vertebral_raw(seed: int = 11) -> tuple[np.ndarray, np.ndarray]:
    return _gaussian_surrogate(_V3C_STATS, _V3C_CORR, _V3C_COUNTS, seed)


# ---------------------------------------------------------------------------
# HAR-12: the big-multiclass scale-out workload (ROADMAP item 4)
# ---------------------------------------------------------------------------

#: Per-activity generator calibration: (count, tilt_deg, f_hz, amp_g, noise_g).
#: Counts are long-tailed on purpose (sedentary activities dominate real HAR
#: logs), which is what gives the OvO pair subsets their realistic 8x size
#: spread — the padding-waste scenario the size-sharded trainer layout exists
#: for.  Postures are separated by gravity orientation (tilt), locomotion
#: activities by dominant cadence and vertical bob amplitude; the values are
#: calibrated to the ranges published for body-worn accelerometer HAR
#: benchmarks (walking ~1.4-2.0 Hz cadence, running ~2.5-3.2 Hz, RMS
#: intensities 0.1-1.5 g) rather than to any single dataset's per-class
#: statistics — none publishes them for 12 classes (honesty note, DESIGN.md
#: §2/§11).
_HAR12_CLASSES = {
    0:  ("lying",        1200, 88.0, 0.0, 0.00, 0.030),
    1:  ("sitting",      1050, 24.0, 0.0, 0.00, 0.040),
    2:  ("standing",      900,  3.0, 0.0, 0.00, 0.050),
    3:  ("walking",       780,  6.0, 1.8, 0.35, 0.100),
    4:  ("walking_up",    600, 10.0, 1.5, 0.42, 0.120),
    5:  ("walking_down",  450,  7.0, 2.1, 0.50, 0.130),
    6:  ("jogging",       330,  4.5, 2.7, 0.95, 0.180),
    7:  ("cycling",       270, 16.0, 1.1, 0.22, 0.090),
    8:  ("vacuuming",     210, 12.0, 0.8, 0.18, 0.150),
    9:  ("ironing",       180, 14.0, 0.5, 0.10, 0.070),
    10: ("rope_jumping",  150,  2.0, 3.3, 1.45, 0.250),
    11: ("running",       130,  1.0, 3.0, 1.20, 0.220),
}

HAR12_WINDOW = 64       #: samples per window
HAR12_FS = 32.0         #: Hz — window covers 2 s of 3-axis accelerometer


def har_feature_stage(windows: np.ndarray) -> np.ndarray:
    """The deterministic on-device feature-extraction stage: windows
    ``(n, T, 3)`` of raw 3-axis accelerometer samples -> features ``(n, 9)``.

    Pure integer-free streaming DSP (means, mean-abs first differences,
    energies) — exactly the accumulator arithmetic a near-sensor FE
    front-end computes in fixed point before the SVM sees anything.  Kept
    a separate public function so the classifier benchmarks measure the
    SVM on the features this stage defines, not on privileged raw access.
    """
    w = np.asarray(windows, np.float64)
    if w.ndim != 3 or w.shape[-1] != 3:
        raise ValueError(f"expected (n, T, 3) windows, got {w.shape}")
    mean = w.mean(axis=1)                                    # (n, 3)
    std = w.std(axis=1)                                      # (n, 3)
    jerk = np.abs(np.diff(w, axis=1)).mean(axis=1)           # (n, 3)
    mag = np.sqrt((w * w).sum(axis=-1))                      # (n, T)
    sma = np.abs(w).sum(axis=-1).mean(axis=1)                # signal mag area
    return np.column_stack([
        mean[:, 0], mean[:, 2],                  # gravity orientation
        std[:, 2], std[:, 0],                    # bob / sway intensity
        jerk[:, 2], jerk[:, 0],                  # cadence-weighted intensity
        mag.std(axis=1), sma, mag.mean(axis=1),
    ])


def _har12_windows(seed: int = 13) -> tuple[np.ndarray, np.ndarray]:
    """Raw windows (n, T, 3) + labels for all 12 activities."""
    rng = np.random.RandomState(seed)
    t = np.arange(HAR12_WINDOW) / HAR12_FS
    xs, ys = [], []
    for cls, (_, n, tilt, f_hz, amp, noise) in _HAR12_CLASSES.items():
        tilt_r = np.deg2rad(tilt + rng.randn(n, 1) * 3.0)
        g_z = np.cos(tilt_r)
        g_x = np.sin(tilt_r)
        w = rng.randn(n, HAR12_WINDOW, 3) * noise
        w[..., 0] += g_x
        w[..., 2] += g_z
        if f_hz > 0.0:
            f = f_hz * np.exp(rng.randn(n, 1) * 0.06)
            a = amp * np.exp(rng.randn(n, 1) * 0.15)
            ph = rng.rand(n, 2) * 2.0 * np.pi
            # vertical bob: fundamental + first harmonic of the gait cycle
            w[..., 2] += a * (np.sin(2 * np.pi * f * t + ph[:, :1])
                              + 0.4 * np.sin(4 * np.pi * f * t + ph[:, 1:]))
            # lateral sway at half the cadence
            w[..., 0] += 0.45 * a * np.sin(np.pi * f * t + ph[:, :1])
            w[..., 1] += 0.30 * a * np.sin(np.pi * f * t + ph[:, 1:])
        xs.append(w)
        ys.append(np.full((n,), cls, np.int64))
    x = np.concatenate(xs)
    y = np.concatenate(ys)
    perm = rng.permutation(len(y))
    return x[perm], y[perm]


def _har12_raw(seed: int = 13) -> tuple[np.ndarray, np.ndarray]:
    """HAR-12 feature rows: windows through the on-device feature stage."""
    w, y = _har12_windows(seed)
    return har_feature_stage(w), y


# ---------------------------------------------------------------------------
# Preprocessing (paper Sec. V-A1)
# ---------------------------------------------------------------------------


def fscore_select(x: np.ndarray, y: np.ndarray, k: int) -> np.ndarray:
    """ANOVA F-score feature ranking (scikit-learn's f_classif, from scratch)."""
    classes = np.unique(y)
    n, d = x.shape
    grand = x.mean(axis=0)
    ss_between = np.zeros(d)
    ss_within = np.zeros(d)
    for c in classes:
        xc = x[y == c]
        ss_between += len(xc) * (xc.mean(axis=0) - grand) ** 2
        ss_within += ((xc - xc.mean(axis=0)) ** 2).sum(axis=0)
    df_b = len(classes) - 1
    df_w = n - len(classes)
    f = (ss_between / df_b) / np.maximum(ss_within / df_w, 1e-12)
    return np.argsort(-f)[:k]


def load(name: str, max_features: int = 5, test_frac: float = 0.3,
         seed: int = 0) -> Dataset:
    """Load + normalize to [0,1] + 70/30 split + F-score selection (<=5)."""
    if name in ("balance", "bal"):
        x, y = _balance_raw()
        name = "balance"
    elif name == "seeds":
        x, y = _seeds_raw()
    elif name in ("vertebral", "v3c"):
        x, y = _vertebral_raw()
        name = "vertebral"
    elif name == "har12":
        x, y = _har12_raw()
    else:
        raise ValueError(f"unknown dataset {name!r}")

    rng = np.random.RandomState(seed)
    perm = rng.permutation(len(y))
    x, y = x[perm], y[perm]
    n_test = int(round(test_frac * len(y)))
    x_tr, y_tr = x[n_test:], y[n_test:]
    x_te, y_te = x[:n_test], y[:n_test]

    # normalize with train statistics
    lo = x_tr.min(axis=0)
    hi = x_tr.max(axis=0)
    span = np.maximum(hi - lo, 1e-12)
    x_tr = np.clip((x_tr - lo) / span, 0.0, 1.0)
    x_te = np.clip((x_te - lo) / span, 0.0, 1.0)

    idx = np.arange(x.shape[1])
    if x.shape[1] > max_features:
        idx = np.sort(fscore_select(x_tr, y_tr, max_features))
        x_tr, x_te = x_tr[:, idx], x_te[:, idx]

    return Dataset(
        name=name, x_train=x_tr, y_train=y_tr, x_test=x_te, y_test=y_te,
        n_classes=int(y.max()) + 1, feature_idx=idx,
    )


DATASETS = ("balance", "seeds", "vertebral")

#: Scale-out workloads (ROADMAP item 4).  Deliberately NOT in ``DATASETS``:
#: the Table-II cost-model calibration and the paper-parity benchmarks
#: iterate that tuple, and folding a K=12 / n>6k workload into them would
#: both change the documented calibration point and multiply their runtime.
SCALE_DATASETS = ("har12",)
