"""Datasets (paper Sec. V-A1) and the LM token pipeline substrate."""
from repro.data import datasets, pipeline  # noqa: F401
