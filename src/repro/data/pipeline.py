"""Deterministic, shard-aware, checkpoint-resumable LM token pipeline.

Production constraints this satisfies (DESIGN.md §4/§6):

* **Stateless indexing** — batch ``t`` is a pure function of
  (seed, step t, host shard), so resuming from a checkpoint at step t
  replays the exact token stream with NO pipeline state in the checkpoint
  beyond the step counter.  This is the same property MaxText relies on
  for deterministic data order.
* **Shard awareness** — each data-parallel host slice draws a disjoint
  row range of the global batch (``host_index``/``host_count``); elastic
  rescale (repro.distributed.elastic) re-derives the slices for a new
  topology without skewing the stream.
* **Straggler skip-ahead** — ``batch_at`` for any future step is O(1), so
  a restarted/replacement worker jumps directly to the fleet's step.

The corpus is a synthetic-but-structured token source (mixture of Zipfian
unigrams + a repeated-ngram process) making LM losses meaningfully
decrease during the example runs; swap `TokenSource` for a real corpus
reader in production.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.host_count == 0
        return self.global_batch // self.host_count


class TokenSource:
    """Synthetic corpus: Zipf unigrams + copied n-grams => learnable structure."""

    def __init__(self, vocab_size: int, seed: int = 0):
        self.vocab_size = vocab_size
        self.seed = seed
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks ** 1.1
        self._p = p / p.sum()

    def sequence(self, idx: int, length: int) -> np.ndarray:
        """Deterministic sequence for document index ``idx``."""
        rng = np.random.RandomState((self.seed * 1_000_003 + idx) % (2**31 - 1))
        toks = rng.choice(self.vocab_size, size=length + 1, p=self._p)
        # plant copy structure: periodic repeats of a window (induction heads
        # and SSM state both learn this => losses drop visibly)
        period = min(64 + (idx % 64), max(len(toks) // 2, 1))
        if len(toks) > period:
            toks[period:] = np.where(
                rng.rand(len(toks) - period) < 0.5,
                toks[:-period], toks[period:]
            )
        return toks.astype(np.int32)


@dataclasses.dataclass
class TokenPipeline:
    cfg: PipelineConfig

    def __post_init__(self):
        self._source = TokenSource(self.cfg.vocab_size, self.cfg.seed)

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """Host-local batch for global step ``step`` — pure function, O(1) seek."""
        c = self.cfg
        row0 = step * c.global_batch + c.host_index * c.host_batch
        seqs = np.stack(
            [self._source.sequence(row0 + r, c.seq_len) for r in range(c.host_batch)]
        )
        return {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
