"""AdamW from scratch (+ optional 8-bit block-quantized moments).

The 8-bit state keeps per-block (size 256 along the flattened tail)
absmax scales — the standard bitsandbytes-style scheme; at kimi-k2 scale
this is the difference between optimizer states fitting on 512 chips or
not (EXPERIMENTS.md §Dry-run memory table).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    quantize_state: bool = False     # 8-bit moments
    block: int = 256


_LOG_TINY = -36.0  # log(~2e-16): magnitudes below this quantize to exact 0


class Quant8(NamedTuple):
    """Signed log-domain (dynamic-range) 8-bit code, bitsandbytes-style.

    Linear absmax codes zero out the long tail of Adam's second moment
    (most |v| << blockmax) and the update m/sqrt(v) explodes; log-domain
    codes bound the MULTIPLICATIVE error instead (~e^(range/127) per
    entry), which Adam tolerates.  code = sign * round(127 * (log|x| -
    LOG_TINY) / (hi_b - LOG_TINY)) with one f32 ``hi`` per block.
    """

    q: jnp.ndarray          # int8, (n_blocks, block)
    hi: jnp.ndarray         # f32 per-block log-magnitude max
    shape: tuple            # static original shape

    @classmethod
    def encode(cls, x: jnp.ndarray, block: int) -> "Quant8":
        flat = x.reshape(-1)
        pad = (-flat.size) % block
        flat = jnp.pad(flat, (0, pad)).reshape(-1, block)
        mag = jnp.abs(flat)
        logm = jnp.where(mag > 0, jnp.log(jnp.maximum(mag, 1e-300)), _LOG_TINY)
        hi = jnp.maximum(jnp.max(logm, axis=1, keepdims=True),
                         _LOG_TINY + 1e-3)
        code = jnp.round(127.0 * (logm - _LOG_TINY) / (hi - _LOG_TINY))
        code = jnp.clip(code, 0, 127) * jnp.sign(flat)
        return cls(q=code.astype(jnp.int8), hi=hi.astype(jnp.float32),
                   shape=tuple(x.shape))

    def decode(self) -> jnp.ndarray:
        code = self.q.astype(jnp.float32)
        mag = jnp.exp(_LOG_TINY + jnp.abs(code) / 127.0
                      * (self.hi - _LOG_TINY))
        flat = (jnp.where(code == 0, 0.0, mag) * jnp.sign(code)).reshape(-1)
        n = 1
        for d in self.shape:
            n *= d
        return flat[:n].reshape(self.shape)


jax.tree_util.register_pytree_node(
    Quant8,
    lambda t: ((t.q, t.hi), t.shape),
    lambda shape, c: Quant8(q=c[0], hi=c[1], shape=shape),
)


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup -> cosine decay to min_lr_frac."""
    warm = cfg.lr * (step + 1) / max(cfg.warmup_steps, 1)
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.lr * (cfg.min_lr_frac + (1 - cfg.min_lr_frac)
                    * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(cfg: AdamWConfig, params) -> dict:
    def zeros_like_maybe_q(p):
        z = jnp.zeros_like(p, dtype=jnp.float32)
        return Quant8.encode(z, cfg.block) if cfg.quantize_state else z

    return {
        "m": jax.tree.map(zeros_like_maybe_q, params),
        "v": jax.tree.map(zeros_like_maybe_q, params),
        "step": jnp.zeros((), jnp.int32),
    }


def apply_updates(cfg: AdamWConfig, params, opt_state, grads):
    """One AdamW step; returns (new_params, new_opt_state)."""
    step = opt_state["step"] + 1
    lr = lr_schedule(cfg, step)
    sf = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** sf
    bc2 = 1.0 - cfg.b2 ** sf

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_f = m.decode() if isinstance(m, Quant8) else m
        v_f = v.decode() if isinstance(v, Quant8) else v
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * gf
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * gf * gf
        update = (m_f / bc1) / (jnp.sqrt(v_f / bc2) + cfg.eps)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        new_p = (p.astype(jnp.float32)
                 - lr * (update + decay * p.astype(jnp.float32)))
        if isinstance(m, Quant8):
            m_f = Quant8.encode(m_f, cfg.block)
            v_f = Quant8.encode(v_f, cfg.block)
        return new_p.astype(p.dtype), m_f, v_f

    is_q = lambda x: isinstance(x, Quant8)
    p_leaves, treedef = jax.tree.flatten(params)
    g_leaves = treedef.flatten_up_to(grads)
    m_leaves = jax.tree.flatten(opt_state["m"], is_leaf=is_q)[0]
    v_leaves = jax.tree.flatten(opt_state["v"], is_leaf=is_q)[0]
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(p_leaves, g_leaves, m_leaves, v_leaves):
        np_, nm, nv = upd(p, g, m, v)
        new_p.append(np_); new_m.append(nm); new_v.append(nv)
    return (
        jax.tree.unflatten(treedef, new_p),
        {"m": jax.tree.unflatten(treedef, new_m),
         "v": jax.tree.unflatten(treedef, new_v),
         "step": step},
    )
