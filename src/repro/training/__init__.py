"""Training substrate: optimizer, train step, training state."""
from repro.training import optimizer, step  # noqa: F401
