"""Jittable train step: microbatched grad accumulation, clipping, AdamW.

Gradient accumulation is a ``lax.scan`` over microbatches — besides
bounding activation memory, the k-th microbatch's gradient all-reduce can
overlap the (k+1)-th microbatch's compute under XLA's latency-hiding
scheduler (independent dataflow chains), which is the collective/compute
overlap trick recorded in DESIGN.md §6.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.models.common import ModelConfig, ShardRules
from repro.training import optimizer as opt_mod


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), norm


def make_train_step(
    cfg: ModelConfig,
    rules: ShardRules,
    opt_cfg: opt_mod.AdamWConfig,
    grad_accum: int = 1,
    clip_norm: float = 1.0,
    loss_fn: Callable | None = None,
    cast_params_bf16: bool = False,
):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params": ..., "opt": ..., "step": int32}
    batch leaves have leading dim global_batch; with grad_accum > 1 they
    are reshaped to (grad_accum, global_batch // grad_accum, ...).
    """
    loss_fn = loss_fn or (
        lambda params, batch: tfm.forward_train(cfg, params, batch, rules))

    if cast_params_bf16:
        # cast master fp32 matrices to bf16 BEFORE the layer stack: the
        # elementwise cast runs on the fsdp shards, so every parameter
        # all-gather moves bf16 — half the collective bytes (§Perf).
        base_loss = loss_fn

        def loss_fn(params, batch):  # noqa: F811
            cast = jax.tree.map(
                lambda p: p.astype(jnp.bfloat16)
                if (hasattr(p, "dtype") and p.dtype == jnp.float32
                    and p.ndim >= 2) else p, params)
            return base_loss(cast, batch)

    def micro_grads(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def train_step(state, batch):
        params = state["params"]
        if grad_accum == 1:
            loss, metrics, grads = micro_grads(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]), batch)

            def body(carry, mb):
                acc, loss_acc = carry
                loss, metrics, grads = micro_grads(params, mb)
                acc = jax.tree.map(jnp.add, acc, grads)
                return (acc, loss_acc + loss), metrics

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), metrics = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = loss_sum / grad_accum
            metrics = jax.tree.map(lambda m: m[-1], metrics)

        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        new_params, new_opt = opt_mod.apply_updates(
            opt_cfg, params, state["opt"], grads)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm,
                       lr=opt_mod.lr_schedule(opt_cfg, new_opt["step"]))
        return {"params": new_params, "opt": new_opt,
                "step": state["step"] + 1}, metrics

    return train_step


def init_train_state(cfg: ModelConfig, opt_cfg: opt_mod.AdamWConfig, key):
    params = tfm.init_params(cfg, key)
    return {
        "params": params,
        "opt": opt_mod.init_state(opt_cfg, params),
        "step": jnp.zeros((), jnp.int32),
    }
