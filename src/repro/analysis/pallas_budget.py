"""Pass 3 — static Pallas VMEM budget and grid/block divisibility.

PR 5's memory contract — the fused solver keeps each lane's working set
VMEM-resident and never materializes the (lanes, n, n) Gram tensor — was
demonstrated once with ``memory_analysis()`` in the training benchmark.
This pass turns it into a standing gate: every kernel entry point in
``src/repro/kernels`` is traced with a *recording* ``pallas_call`` (the
kernel body never runs), and each program's VMEM footprint is computed
statically from its BlockSpecs:

    footprint = sum over blocked operands of 2 x block_bytes   (the
                Pallas pipeline double-buffers every blocked in/out)
              + sum over VMEM scratch of its full size          (scratch
                persists across grid steps; no double buffer)

Operands placed with ``memory_space=pl.ANY`` stay out of VMEM and are
tallied separately.  ~16 MiB/core is the budget (TPU VMEM); the exact
number matters less than the trajectory — footprints land in the JSON
report so a future block-size bump that silently 4x's a kernel's working
set shows up as a diff, and ``VMEM-BUDGET`` fires before Mosaic would.

Rules
-----
``VMEM-BUDGET``      program's static VMEM footprint exceeds the budget.
``GRID-DIVISIBLE``   an operand's array shape is not divisible by its
                     block shape (Pallas pads the tail block implicitly;
                     every repo kernel is required to pad explicitly
                     upstream so masking stays visible in the code).
``FUSED-VS-ORACLE``  the fused solver's static footprint is not strictly
                     below the materialized-Gram oracle's lane bytes —
                     the PR 5 contract that makes the fused formulation
                     worth having.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
from typing import Optional

import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas

from repro.analysis.report import Finding

#: Per-core VMEM on the TPU generations the kernels target (v4/v5: 16 MiB).
DEFAULT_VMEM_BUDGET = 16 * 1024 * 1024


@dataclasses.dataclass
class PallasRecord:
    """One intercepted ``pl.pallas_call`` launch (never executed)."""

    name: str
    grid: tuple
    in_specs: list
    out_specs: list
    out_shapes: list          # ShapeDtypeStruct per output
    scratch_shapes: list
    arg_shapes: list          # (shape, dtype) per positional operand


def _kernel_name(fn) -> str:
    while hasattr(fn, "func"):    # unwrap functools.partial chains
        fn = fn.func
    return getattr(fn, "__name__", repr(fn))


def _as_list(x) -> list:
    if x is None:
        return []
    if isinstance(x, (list, tuple)):
        return list(x)
    return [x]


@contextlib.contextmanager
def record_pallas_calls():
    """Patch ``pallas.pallas_call`` to record launches and return zeros.

    Kernel modules bind ``from jax.experimental import pallas as pl`` and
    resolve ``pl.pallas_call`` at call time, so patching the module
    attribute intercepts every launch.  The fake returns zeros matching
    ``out_shape`` — downstream slicing/reshaping in the entry point still
    typechecks, but no kernel body ever executes.
    """
    records: list[PallasRecord] = []
    real = pallas.pallas_call

    def fake(kernel, *, grid=None, in_specs=None, out_specs=None,
             out_shape=None, scratch_shapes=(), interpret=False, **kw):
        def launch(*args):
            records.append(PallasRecord(
                name=_kernel_name(kernel),
                grid=tuple(grid) if grid is not None else (),
                in_specs=_as_list(in_specs),
                out_specs=_as_list(out_specs),
                out_shapes=_as_list(out_shape),
                scratch_shapes=_as_list(scratch_shapes),
                arg_shapes=[(tuple(a.shape), jnp.asarray(a).dtype)
                            for a in args],
            ))
            outs = [jnp.zeros(s.shape, s.dtype) for s in _as_list(out_shape)]
            if isinstance(out_shape, (list, tuple)):
                return type(out_shape)(outs)
            return outs[0]
        return launch

    pallas.pallas_call = fake
    try:
        yield records
    finally:
        pallas.pallas_call = real


# ---------------------------------------------------------------------------
# Footprint model
# ---------------------------------------------------------------------------


def _is_any_space(spec) -> bool:
    if spec is None:
        return True
    block = getattr(spec, "block_shape", None)
    if block is None:
        return True   # whole-array operand, no VMEM tiling declared
    return False


def _block_bytes(spec, shape: tuple, dtype) -> int:
    block = spec.block_shape
    itemsize = jnp.dtype(dtype).itemsize
    n = 1
    for dim, b in zip(shape, block):
        n *= dim if b is None else int(b)
    return n * itemsize


def analyze_record(rec: PallasRecord, *, path: str, symbol: str,
                   vmem_budget: int = DEFAULT_VMEM_BUDGET,
                   ) -> tuple[dict, list[Finding]]:
    """Static footprint + divisibility findings for one recorded launch."""
    findings: list[Finding] = []
    vmem = 0
    any_bytes = 0
    operands = []

    out_pairs = [(tuple(s.shape), s.dtype) for s in rec.out_shapes]
    specs = (list(zip(rec.in_specs, rec.arg_shapes, ["in"] * len(rec.in_specs)))
             + list(zip(rec.out_specs, out_pairs,
                        ["out"] * len(rec.out_specs))))
    for idx, (spec, (shape, dtype), role) in enumerate(specs):
        if _is_any_space(spec):
            nbytes = int(np.prod(shape)) * jnp.dtype(dtype).itemsize
            any_bytes += nbytes
            operands.append({"role": role, "index": idx, "shape": shape,
                             "space": "ANY", "bytes": nbytes})
            continue
        bb = _block_bytes(spec, shape, dtype)
        vmem += 2 * bb   # pipeline double buffer
        operands.append({"role": role, "index": idx, "shape": shape,
                         "block": tuple(spec.block_shape), "space": "VMEM",
                         "block_bytes": bb})
        for d, (dim, blk) in enumerate(zip(shape, spec.block_shape)):
            if blk is None:
                continue
            if dim % int(blk) != 0:
                findings.append(Finding(
                    rule="GRID-DIVISIBLE", path=path, symbol=symbol,
                    message=(f"{rec.name}: {role}-operand {idx} dim {d} "
                             f"({dim}) not divisible by block {blk} — pad "
                             f"explicitly upstream; implicit tail blocks "
                             f"hide masking")))

    scratch_bytes = 0
    for s in rec.scratch_shapes:
        shape = tuple(getattr(s, "shape", ()))
        dtype = getattr(s, "dtype", jnp.float32)
        scratch_bytes += int(np.prod(shape)) * jnp.dtype(dtype).itemsize
    vmem += scratch_bytes

    if vmem > vmem_budget:
        findings.append(Finding(
            rule="VMEM-BUDGET", path=path, symbol=symbol,
            message=(f"{rec.name}: static VMEM footprint {vmem:,} B "
                     f"exceeds budget {vmem_budget:,} B "
                     f"(blocks double-buffered + scratch)")))

    info = {
        "kernel": rec.name,
        "grid": rec.grid,
        "num_programs": int(math.prod(rec.grid)) if rec.grid else 1,
        "vmem_bytes": vmem,
        "scratch_bytes": scratch_bytes,
        "any_bytes": any_bytes,
        "operands": operands,
    }
    return info, findings


# ---------------------------------------------------------------------------
# Kernel program registry
# ---------------------------------------------------------------------------

#: PR 5 oracle-comparison configuration (benchmarks/svm_train.py):
#: 2 OvO pairs x 3 gammas x 6 C/fold lanes over n_max=256, d=4.
SOLVER_CONFIG = dict(p=2, g=3, l=6, n=256, d=4)


def _trace_kernel_programs() -> list[tuple[str, str, PallasRecord]]:
    """Launch every kernels/ entry point under the recorder.

    Representative shapes are paper-scale; entry points pad internally, so
    a divisibility finding here means a kernel stopped padding upstream.
    Returns (path, symbol, record) triples.
    """
    from repro.kernels import flash_attention as fa_mod
    from repro.kernels import rbf as rbf_mod
    from repro.kernels import solver as solver_mod
    from repro.kernels import ssd as ssd_mod

    cfg = SOLVER_CONFIG
    traces = []

    def run(path, symbol, fn, *args, **kw):
        with record_pallas_calls() as recs:
            fn(*args, **kw)   # fake pallas_call: body never executes
        for rec in recs:
            traces.append((path, symbol, rec))

    f32 = jnp.float32
    run("src/repro/kernels/rbf.py", "kernel_matrix_pallas[rbf]",
        rbf_mod.kernel_matrix_pallas.__wrapped__,
        jnp.zeros((200, 8), f32), jnp.zeros((150, 8), f32), 0.5,
        kind="rbf", interpret=True)
    run("src/repro/kernels/rbf.py", "kernel_matrix_pallas[sech2]",
        rbf_mod.kernel_matrix_pallas.__wrapped__,
        jnp.zeros((200, 4), f32), jnp.zeros((150, 4), f32), 0.5,
        kind="sech2", interpret=True)
    run("src/repro/kernels/solver.py", "dual_ascent_lanes_pallas",
        solver_mod.dual_ascent_lanes_pallas.__wrapped__,
        jnp.zeros((cfg["p"], cfg["n"], cfg["d"]), f32),
        jnp.ones((cfg["p"], cfg["n"]), f32),
        jnp.ones((cfg["p"], cfg["l"], cfg["n"]), f32),
        jnp.ones((cfg["p"], cfg["g"]), f32),
        kind="rbf", n_epochs=2, interpret=True)
    run("src/repro/kernels/flash_attention.py", "flash_attention",
        fa_mod.flash_attention.__wrapped__,
        jnp.zeros((1, 4, 200, 64), f32), jnp.zeros((1, 2, 200, 64), f32),
        jnp.zeros((1, 2, 200, 64), f32), causal=True, window=None,
        q_offset=0, interpret=True)
    run("src/repro/kernels/ssd.py", "ssd_scan_pallas",
        ssd_mod.ssd_scan_pallas.__wrapped__,
        jnp.zeros((2, 256, 64), f32), jnp.zeros((2, 256), f32),
        jnp.zeros((2, 256, 32), f32), jnp.zeros((2, 256, 32), f32),
        chunk=128, interpret=True)
    return traces


def fused_vs_oracle(solver_info: dict,
                    oracle_bytes: Optional[int] = None,
                    ) -> tuple[dict, list[Finding]]:
    """PR 5 contract: fused solver working set << materialized Gram.

    The XLA oracle (``dual_coordinate_ascent_blocked`` vmapped over
    lanes) materializes a (lanes, n_pad, n_pad) f32 Gram tensor; the
    fused kernel recomputes row slabs and its static footprint must stay
    *strictly* below those lane bytes or the fusion lost its reason to
    exist.  ``oracle_bytes`` is overridable so tests can seed a
    regression (acceptance criterion: the gate fails when seeded).
    """
    cfg = SOLVER_CONFIG
    lanes = cfg["p"] * cfg["g"] * cfg["l"]
    if oracle_bytes is None:
        oracle_bytes = lanes * cfg["n"] * cfg["n"] * 4
    fused = solver_info["vmem_bytes"]
    findings = []
    if not fused < oracle_bytes:
        findings.append(Finding(
            rule="FUSED-VS-ORACLE", path="src/repro/kernels/solver.py",
            symbol="dual_ascent_lanes_pallas",
            message=(f"fused solver static VMEM {fused:,} B is not "
                     f"strictly below the materialized-Gram oracle "
                     f"{oracle_bytes:,} B ({lanes} lanes x "
                     f"{cfg['n']}^2 x f32) — the PR 5 memory contract "
                     f"is broken")))
    info = {
        "config": cfg,
        "lanes": lanes,
        "fused_vmem_bytes": fused,
        "oracle_gram_bytes": oracle_bytes,
        "ratio": fused / oracle_bytes if oracle_bytes else None,
        "holds": bool(fused < oracle_bytes),
    }
    return info, findings


def check_kernels(vmem_budget: int = DEFAULT_VMEM_BUDGET,
                  oracle_bytes: Optional[int] = None,
                  ) -> tuple[list[Finding], dict]:
    """Run the full Pass 3: trace, budget, divisibility, oracle contract."""
    findings: list[Finding] = []
    programs = []
    solver_info = None
    for path, symbol, rec in _trace_kernel_programs():
        info, fnds = analyze_record(rec, path=path, symbol=symbol,
                                    vmem_budget=vmem_budget)
        info["path"] = path
        info["symbol"] = symbol
        programs.append(info)
        findings.extend(fnds)
        if symbol == "dual_ascent_lanes_pallas":
            solver_info = info
    contract = None
    if solver_info is not None:
        contract, fnds = fused_vs_oracle(solver_info,
                                         oracle_bytes=oracle_bytes)
        findings.extend(fnds)
    info = {"vmem_budget": vmem_budget, "programs": programs,
            "fused_vs_oracle": contract}
    return findings, info
