"""Pass 2 — AST convention lint: named, waivable repo-convention rules.

Each rule encodes an invariant that DESIGN.md previously stated only in
prose.  All rules are *heuristic static* checks: they trade completeness
for zero-runtime-cost scanning of the whole tree, and every finding can be
waived in the committed baseline with a justification (report.py).

Rules
-----
``KEY-REUSE``
    The same ``jax.random`` key consumed twice without a ``split`` /
    ``fold_in`` between — the exact bug class PR 4 shipped in
    ``AnalogRBFModel.from_circuit`` (one key feeding both the Gaussian and
    the alpha mismatch sweeps silently correlates the two circuits).  A
    "key" is a variable assigned from ``jax.random.PRNGKey/split/fold_in/
    key/wrap_key_data`` (including tuple-unpacks of ``split``) or a
    parameter named ``key``/``rng``/``*_key``.  *Consumption* is passing
    the key to any call — a draw, a ``split``, or a helper that draws
    internally (what made the PR 4 bug invisible to a jax-only scan).
    Reads that don't consume (``key_data``, ``asarray`` & friends) are
    exempt; consumptions in mutually-exclusive ``if``/``else`` branches
    don't conflict; a single consumption inside a loop the key was defined
    outside of counts as reuse.

``INTERPRET-THREAD``
    Any function reaching ``repro.kernels.ops`` entry points must thread
    the ``interpret`` override: the call must pass ``interpret=...`` and,
    when the value is the caller's own parameter, that parameter must
    exist.  This is the api/compiled.py convention that lets CPU CI force
    the Pallas interpreter end-to-end (DESIGN.md §7.5); an unthreaded call
    silently pins the backend default.

``PYTREE-REG``
    A dataclass with ``jnp.ndarray`` fields must be registered with
    ``jax.tree_util`` somewhere in the scanned tree.  Such classes cross
    jit boundaries (as traced constants today, as arguments tomorrow);
    an unregistered one traces as an opaque object and fails or silently
    retraces.

``BANNED-IN-HOT``
    Inside a ``@jax.jit``-decorated function (or a function nested in
    one): ``np.random.*`` (hidden host RNG state), ``time.time()`` /
    ``perf_counter()`` (host clock in traced code — a constant at best),
    and ``.item()`` (forces a device sync per call).
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Optional

from repro.analysis.report import Finding

#: repro.kernels.ops public entry points (the interpret-dispatch layer).
OPS_ENTRY_NAMES = ("rbf_matrix", "solve_lanes", "flash_attention",
                   "ssd_scan")

#: Callees that *read* a key without consuming it.  ``fold_in`` is here
#: deliberately: folding distinct data into one base key is the canonical
#: per-index derivation pattern (``fold_in(base, i)`` in a loop), not a
#: reuse — only draws and ``split`` consume.
KEY_NONCONSUMING = {"key_data", "_key_data", "asarray", "array", "len",
                    "print", "repr", "str", "format", "append", "device_put",
                    "block_until_ready", "shape", "isinstance", "hash",
                    "fold_in"}

#: jax.random constructors whose results are key variables.
KEY_PRODUCERS = {"PRNGKey", "key", "split", "fold_in", "wrap_key_data",
                 "clone"}

BANNED_TIME = {"time", "perf_counter", "perf_counter_ns", "monotonic",
               "sleep"}


def _qualname(node: ast.expr) -> str:
    """Dotted name of an expression, '' when not a plain attribute chain."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _is_key_param(name: str) -> bool:
    return name in ("key", "rng", "rng_key") or name.endswith("_key")


# ---------------------------------------------------------------------------
# KEY-REUSE
# ---------------------------------------------------------------------------

# A branch signature is a tuple of (id(if_node), side) ancestors; two
# events conflict only if no shared `if` splits them onto different sides.


def _exclusive(sig_a: tuple, sig_b: tuple) -> bool:
    for (na, sa), (nb, sb) in zip(sig_a, sig_b):
        if na != nb:
            return False
        if sa != sb:
            return True
    return False


@dataclasses.dataclass
class _KeyEvent:
    kind: str          # 'assign' | 'consume'
    line: int
    branch: tuple      # ((if_id, side), ...)
    loops: tuple       # (loop_id, ...) ancestors


class _KeyReuseScanner:
    """Linear scan of one function body tracking key-variable lifetimes."""

    def __init__(self, func: ast.FunctionDef, path: str,
                 findings: list[Finding]):
        self.func = func
        self.path = path
        self.findings = findings
        self.events: dict[str, list[_KeyEvent]] = {}
        self.branch: list[tuple] = []
        self.loops: list[int] = []

    def run(self) -> None:
        args = self.func.args
        params = [a.arg for a in (args.posonlyargs + args.args
                                  + args.kwonlyargs)]
        for p in params:
            if _is_key_param(p):
                self._record(p, "assign", self.func.lineno)
        for stmt in self.func.body:
            self._visit(stmt)
        self._check()

    # -- event recording ----------------------------------------------------

    def _record(self, name: str, kind: str, line: int) -> None:
        self.events.setdefault(name, []).append(
            _KeyEvent(kind, line, tuple(self.branch), tuple(self.loops)))

    def _target_names(self, target: ast.expr) -> list[str]:
        if isinstance(target, ast.Name):
            return [target.id]
        if isinstance(target, (ast.Tuple, ast.List)):
            out = []
            for el in target.elts:
                out.extend(self._target_names(el))
            return out
        return []

    def _is_key_producer(self, value: ast.expr) -> bool:
        if isinstance(value, ast.Call):
            qn = _qualname(value.func)
            leaf = qn.rsplit(".", 1)[-1]
            if leaf in KEY_PRODUCERS and ("random" in qn or qn == leaf):
                return True
        if isinstance(value, ast.Subscript):
            return self._is_key_producer(value.value)
        return False

    # -- traversal ----------------------------------------------------------

    def _visit(self, node: ast.stmt) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return  # nested scopes get their own scanner
        if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            value = node.value
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            if value is not None:
                self._scan_expr(value)
                producer = self._is_key_producer(value)
                for t in targets:
                    for name in self._target_names(t):
                        if producer or _is_key_param(name):
                            self._record(name, "assign", node.lineno)
                        elif name in self.events:
                            # overwritten with a non-key value: retire it
                            self._record(name, "assign", node.lineno)
            return
        if isinstance(node, ast.If):
            self._scan_expr(node.test)
            self.branch.append((id(node), 0))
            for s in node.body:
                self._visit(s)
            self.branch[-1] = (id(node), 1)
            for s in node.orelse:
                self._visit(s)
            self.branch.pop()
            return
        if isinstance(node, (ast.For, ast.AsyncFor)):
            self._scan_expr(node.iter)
            self.loops.append(id(node))
            for name in self._target_names(node.target):
                if _is_key_param(name):
                    self._record(name, "assign", node.lineno)
            for s in node.body:
                self._visit(s)
            self.loops.pop()
            for s in node.orelse:
                self._visit(s)
            return
        if isinstance(node, ast.While):
            self._scan_expr(node.test)
            self.loops.append(id(node))
            for s in node.body:
                self._visit(s)
            self.loops.pop()
            for s in node.orelse:
                self._visit(s)
            return
        if isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                self._scan_expr(item.context_expr)
            for s in node.body:
                self._visit(s)
            return
        if isinstance(node, ast.Try):
            for s in node.body:
                self._visit(s)
            for h in node.handlers:
                for s in h.body:
                    self._visit(s)
            for s in node.orelse + node.finalbody:
                self._visit(s)
            return
        # leaf statements: scan expressions for consumptions
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.expr):
                self._scan_expr(child)

    def _scan_expr(self, node: ast.expr) -> None:
        for call in [n for n in ast.walk(node) if isinstance(n, ast.Call)]:
            qn = _qualname(call.func)
            leaf = qn.rsplit(".", 1)[-1]
            if leaf in KEY_NONCONSUMING:
                continue
            consumed = []
            for arg in call.args:
                if isinstance(arg, ast.Name):
                    consumed.append(arg.id)
            for kw in call.keywords:
                if kw.arg == "key" and isinstance(kw.value, ast.Name):
                    consumed.append(kw.value.id)
            for name in consumed:
                if name in self.events:
                    self._record(name, "consume", call.lineno)

    # -- verdicts -----------------------------------------------------------

    def _check(self) -> None:
        for name, events in self.events.items():
            last_assign: Optional[_KeyEvent] = None
            consumed: list[_KeyEvent] = []
            for i, ev in enumerate(events):
                if ev.kind == "assign":
                    last_assign = ev
                    consumed = []
                    continue
                # A consumption inside a loop the key was defined outside
                # of re-consumes every iteration — UNLESS the key is also
                # reassigned inside that loop (the ``key, sub =
                # split(key)`` rotate idiom, which is correct).
                new_loops = [lp for lp in ev.loops
                             if last_assign is None
                             or lp not in last_assign.loops]
                rotated = any(
                    later.kind == "assign"
                    and any(lp in later.loops for lp in new_loops)
                    for later in events[i + 1:])
                loop_reuse = bool(new_loops) and not rotated
                conflict = loop_reuse or any(
                    not _exclusive(prev.branch, ev.branch)
                    for prev in consumed)
                if conflict:
                    where = ("inside a loop" if loop_reuse
                             else f"after line {consumed[-1].line}")
                    self.findings.append(Finding(
                        rule="KEY-REUSE", path=self.path,
                        symbol=self.func.name, line=ev.line,
                        message=(f"key {name!r} consumed again at line "
                                 f"{ev.line} {where} without an intervening "
                                 f"split/fold_in — draws are correlated")))
                    consumed = []  # one finding per reuse chain
                else:
                    consumed.append(ev)


# ---------------------------------------------------------------------------
# INTERPRET-THREAD
# ---------------------------------------------------------------------------


def _ops_bindings(tree: ast.Module) -> tuple[set, set]:
    """How this module reaches ``repro.kernels.ops``: (aliases, bare names).

    Import-aware so a local jnp oracle that happens to be named
    ``rbf_matrix`` (e.g. ``kernels/ref.py``) is not mistaken for the ops
    entry point.
    """
    aliases: set[str] = set()
    bare: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if mod == "repro.kernels":
                for a in node.names:
                    if a.name == "ops":
                        aliases.add(a.asname or "ops")
            elif mod == "repro.kernels.ops":
                for a in node.names:
                    if a.name in OPS_ENTRY_NAMES:
                        bare.add(a.asname or a.name)
        elif isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "repro.kernels.ops":
                    aliases.add(a.asname or "repro")
    return aliases, bare


def _calls_ops_entry(call: ast.Call, aliases: set, bare: set,
                     ) -> Optional[str]:
    """Entry name when ``call`` reaches a kernels.ops entry point."""
    qn = _qualname(call.func)
    if not qn:
        return None
    leaf = qn.rsplit(".", 1)[-1]
    if leaf not in OPS_ENTRY_NAMES:
        return None
    if "." in qn:
        head = qn.split(".", 1)[0]
        if head in aliases or ".ops." in ("." + qn + "."):
            return leaf
        return None
    return leaf if qn in bare else None


def _own_nodes(func: ast.FunctionDef):
    """Walk ``func`` without descending into nested def/class scopes.

    Lambdas stay included — a call inside a lambda is attributed to the
    enclosing named function (e.g. a benchmark's timed closure)."""
    stack = list(ast.iter_child_nodes(func))
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


def _check_interpret_thread(tree: ast.Module, path: str,
                            findings: list[Finding]) -> None:
    aliases, bare = _ops_bindings(tree)
    if not aliases and not bare:
        return
    for func in [n for n in ast.walk(tree)
                 if isinstance(n, ast.FunctionDef)]:
        args = func.args
        params = {a.arg for a in (args.posonlyargs + args.args
                                  + args.kwonlyargs)}
        has_kwargs = args.kwarg is not None
        for call in [n for n in _own_nodes(func)
                     if isinstance(n, ast.Call)]:
            entry = _calls_ops_entry(call, aliases, bare)
            if entry is None:
                continue
            kw_names = {kw.arg for kw in call.keywords}
            forwards = ("interpret" in kw_names
                        or (None in kw_names and has_kwargs))
            if not forwards:
                findings.append(Finding(
                    rule="INTERPRET-THREAD", path=path, symbol=func.name,
                    line=call.lineno,
                    message=(f"call to ops.{entry} does not pass "
                             f"interpret= — the CPU-CI override cannot "
                             f"reach this kernel (api/compiled.py "
                             f"convention)")))
                continue
            # when forwarding a plain name, require it to be threadable
            for kw in call.keywords:
                if kw.arg != "interpret":
                    continue
                if (isinstance(kw.value, ast.Name)
                        and kw.value.id == "interpret"
                        and "interpret" not in params and not has_kwargs):
                    findings.append(Finding(
                        rule="INTERPRET-THREAD", path=path,
                        symbol=func.name, line=call.lineno,
                        message=(f"call to ops.{entry} forwards "
                                 f"'interpret' but {func.name}() has no "
                                 f"such parameter to thread it from")))


# ---------------------------------------------------------------------------
# PYTREE-REG
# ---------------------------------------------------------------------------


def _is_dataclass_decorated(cls: ast.ClassDef) -> bool:
    for dec in cls.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        if _qualname(target).rsplit(".", 1)[-1] == "dataclass":
            return True
    return False


def _jnp_array_fields(cls: ast.ClassDef) -> list[str]:
    out = []
    for stmt in cls.body:
        if not isinstance(stmt, ast.AnnAssign):
            continue
        ann = ast.unparse(stmt.annotation)
        if "jnp.ndarray" in ann or "jax.Array" in ann:
            if isinstance(stmt.target, ast.Name):
                out.append(stmt.target.id)
    return out


def _collect_registered_names(trees: dict[str, ast.Module]) -> set[str]:
    """Class names registered via jax.tree_util anywhere in the tree."""
    registered: set[str] = set()
    for tree in trees.values():
        for call in [n for n in ast.walk(tree) if isinstance(n, ast.Call)]:
            leaf = _qualname(call.func).rsplit(".", 1)[-1]
            if leaf not in ("register_pytree_node", "register_dataclass",
                            "register_static", "register_pytree_node_class",
                            "register_pytree_with_keys"):
                continue
            if call.args:
                name = _qualname(call.args[0]).rsplit(".", 1)[-1]
                if name:
                    registered.add(name)
        # decorator form: @jax.tree_util.register_pytree_node_class
        for cls in [n for n in ast.walk(tree)
                    if isinstance(n, ast.ClassDef)]:
            for dec in cls.decorator_list:
                target = dec.func if isinstance(dec, ast.Call) else dec
                if "register_pytree" in _qualname(target):
                    registered.add(cls.name)
    return registered


def _check_pytree_reg(trees: dict[str, ast.Module],
                      findings: list[Finding]) -> None:
    registered = _collect_registered_names(trees)
    for path, tree in trees.items():
        for cls in [n for n in ast.walk(tree)
                    if isinstance(n, ast.ClassDef)]:
            if not _is_dataclass_decorated(cls):
                continue
            fields = _jnp_array_fields(cls)
            if fields and cls.name not in registered:
                findings.append(Finding(
                    rule="PYTREE-REG", path=path, symbol=cls.name,
                    line=cls.lineno,
                    message=(f"dataclass {cls.name} holds jnp.ndarray "
                             f"fields ({', '.join(fields[:4])}) but is not "
                             f"registered with jax.tree_util — it cannot "
                             f"cross a jit boundary as a pytree")))


# ---------------------------------------------------------------------------
# BANNED-IN-HOT
# ---------------------------------------------------------------------------


def _is_jitted(func: ast.FunctionDef) -> bool:
    for dec in func.decorator_list:
        qn = _qualname(dec.func if isinstance(dec, ast.Call) else dec)
        if qn.endswith("jit"):
            return True
        if isinstance(dec, ast.Call) and qn.rsplit(".", 1)[-1] == "partial":
            for arg in dec.args:
                if _qualname(arg).endswith("jit"):
                    return True
    return False


def _check_banned_in_hot(tree: ast.Module, path: str,
                         findings: list[Finding]) -> None:
    jitted: list[ast.FunctionDef] = []
    for func in [n for n in ast.walk(tree)
                 if isinstance(n, ast.FunctionDef)]:
        if _is_jitted(func):
            jitted.append(func)

    def flag(func, node, what, why):
        findings.append(Finding(
            rule="BANNED-IN-HOT", path=path, symbol=func.name,
            line=node.lineno,
            message=f"{what} inside jitted {func.name}() — {why}"))

    for func in jitted:
        for node in ast.walk(func):
            if isinstance(node, ast.Attribute):
                qn = _qualname(node)
                if qn.startswith(("np.random.", "numpy.random.")):
                    flag(func, node, qn,
                         "hidden host RNG state traced as a constant")
            if isinstance(node, ast.Call):
                qn = _qualname(node.func)
                mod, _, leaf = qn.rpartition(".")
                if mod == "time" and leaf in BANNED_TIME:
                    flag(func, node, f"{qn}()",
                         "host clock in traced code is a trace-time "
                         "constant")
                if (isinstance(node.func, ast.Attribute)
                        and node.func.attr == "item" and not node.args):
                    flag(func, node, ".item()",
                         "forces a device sync per element")


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

DEFAULT_SCAN_DIRS = ("src", "benchmarks", "tests")


def _iter_py_files(root: str, dirs=DEFAULT_SCAN_DIRS) -> list[str]:
    out = []
    for d in dirs:
        top = os.path.join(root, d)
        if not os.path.isdir(top):
            continue
        for dirpath, dirnames, filenames in os.walk(top):
            dirnames[:] = [x for x in dirnames
                           if x not in ("__pycache__", ".git")]
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    out.append(os.path.join(dirpath, fn))
    return sorted(out)


def lint_files(paths: list[str], root: str) -> tuple[list[Finding], dict]:
    """Run all AST rules over ``paths``; returns (findings, info)."""
    findings: list[Finding] = []
    trees: dict[str, ast.Module] = {}
    skipped = []
    for p in paths:
        rel = os.path.relpath(p, root)
        try:
            with open(p, encoding="utf-8") as fh:
                trees[rel] = ast.parse(fh.read(), filename=rel)
        except SyntaxError as e:
            skipped.append({"path": rel, "error": str(e)})
    for rel, tree in trees.items():
        for func in [n for n in ast.walk(tree)
                     if isinstance(n, ast.FunctionDef)]:
            _KeyReuseScanner(func, rel, findings).run()
        _check_interpret_thread(tree, rel, findings)
        _check_banned_in_hot(tree, rel, findings)
    _check_pytree_reg(trees, findings)
    info = {"files_scanned": len(trees), "skipped": skipped}
    return findings, info


def lint_tree(root: str, dirs=DEFAULT_SCAN_DIRS) -> tuple[list[Finding], dict]:
    """Lint every .py file under ``root``'s scan dirs."""
    return lint_files(_iter_py_files(root, dirs), root)
