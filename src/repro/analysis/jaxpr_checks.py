"""Pass 1 — jaxpr contract checks over the public jitted entry points.

Each registered entry point (``analysis.entrypoints``) is traced under
abstract shapes with ``jax.make_jaxpr`` and the closed jaxpr — including
every sub-jaxpr nested in equation params (pjit bodies, scan/while/cond
branches, custom_jvp calls) — is walked for contract violations:

``F64-IN-JIT``
    A float64 abstract value anywhere in jitted compute.  The repo runs
    x64-disabled and every kernel/bank is f32 by design (DESIGN.md §2);
    an f64 aval means a host ``np.float64`` scalar (e.g. from
    ``np.logspace`` grids in core/svm.py) was traced into the graph and
    will silently double every downstream buffer the day x64 is enabled.

``HOST-CALLBACK``
    A host-callback / infeed / debug primitive in a hot path.  These
    serialize the device stream per call; none belong in serving or
    training programs.

``CONST-BAKE``
    A constant larger than ``max_const_bytes`` baked into the jaxpr.
    Closed-over arrays are embedded per-compilation: a captured weight
    bank duplicates into every specialization (the weight-capture blowup
    this rule exists for).  Small captured tables are normal — the limit,
    not the mechanism, is the contract.

``DONATION-DROPPED``
    An entry point declares ``donate_argnames`` but the compiled module
    has no ``input_output_alias`` — XLA accepted the donation and then
    dropped it (dtype/layout mismatch, or the donated buffer is still
    live), so the memory PR 5 promised back is not actually returned.
    Verified on the *compiled* artifact, not the trace.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np

from repro.analysis.report import Finding

#: Constants above this many bytes are flagged as baked-in (CONST-BAKE).
MAX_CONST_BYTES = 1 << 20   # 1 MiB

#: Primitive names that reach back to the host / serialize the stream.
HOST_PRIMITIVES = {
    "pure_callback", "io_callback", "debug_callback", "callback",
    "host_callback_call", "outside_call", "infeed", "outfeed",
    "debug_print", "python_callback",
}


def _iter_jaxprs(jaxpr):
    """Yield ``jaxpr`` and every sub-jaxpr nested in equation params."""
    yield jaxpr
    for eqn in jaxpr.eqns:
        for val in eqn.params.values():
            items = val if isinstance(val, (list, tuple)) else [val]
            for item in items:
                inner = getattr(item, "jaxpr", None)   # ClosedJaxpr
                if inner is not None and hasattr(inner, "eqns"):
                    yield from _iter_jaxprs(inner)
                elif hasattr(item, "eqns"):            # bare Jaxpr
                    yield from _iter_jaxprs(item)


def _iter_consts(closed) -> list:
    """Consts of the top-level closed jaxpr plus nested closed jaxprs."""
    consts = list(getattr(closed, "consts", []))
    for jaxpr in _iter_jaxprs(closed.jaxpr):
        for eqn in jaxpr.eqns:
            for val in eqn.params.values():
                items = val if isinstance(val, (list, tuple)) else [val]
                for item in items:
                    if hasattr(item, "jaxpr") and hasattr(item, "consts"):
                        consts.extend(item.consts)
    return consts


def check_jaxpr(closed, *, path: str, symbol: str,
                max_const_bytes: int = MAX_CONST_BYTES,
                ) -> tuple[list[Finding], dict]:
    """F64-IN-JIT / HOST-CALLBACK / CONST-BAKE over one closed jaxpr."""
    findings: list[Finding] = []
    n_eqns = 0
    f64_seen: set[str] = set()
    host_seen: set[str] = set()

    def _is_f64(dtype) -> bool:
        # Extended dtypes (typed PRNG keys, `key<fry>`) are not numpy
        # dtypes; they are never float64.
        if dtype is None or jax.dtypes.issubdtype(dtype, jax.dtypes.extended):
            return False
        return np.dtype(dtype) == np.float64

    for jaxpr in _iter_jaxprs(closed.jaxpr):
        for var in list(jaxpr.invars) + list(jaxpr.constvars):
            aval = getattr(var, "aval", None)
            if _is_f64(getattr(aval, "dtype", None)):
                f64_seen.add(f"argument/const {aval.str_short()}")
        for eqn in jaxpr.eqns:
            n_eqns += 1
            prim = eqn.primitive.name
            if prim in HOST_PRIMITIVES:
                host_seen.add(prim)
            for var in eqn.outvars:
                aval = getattr(var, "aval", None)
                if _is_f64(getattr(aval, "dtype", None)):
                    f64_seen.add(f"{prim} -> {aval.str_short()}")

    for detail in sorted(f64_seen):
        findings.append(Finding(
            rule="F64-IN-JIT", path=path, symbol=symbol,
            message=(f"float64 value in jitted compute ({detail}) — the "
                     f"repo's kernels are f32-only; cast at the host "
                     f"boundary")))
    for prim in sorted(host_seen):
        findings.append(Finding(
            rule="HOST-CALLBACK", path=path, symbol=symbol,
            message=(f"host primitive '{prim}' inside a jitted entry "
                     f"point — serializes the device stream per call")))

    const_bytes = 0
    for const in _iter_consts(closed):
        nbytes = getattr(const, "nbytes", 0)
        const_bytes += int(nbytes)
        if nbytes > max_const_bytes:
            shape = getattr(const, "shape", ())
            dtype = getattr(const, "dtype", "?")
            findings.append(Finding(
                rule="CONST-BAKE", path=path, symbol=symbol,
                message=(f"constant {shape} {dtype} ({int(nbytes):,} B > "
                         f"{max_const_bytes:,} B) baked into the jaxpr — "
                         f"captured arrays duplicate per specialization; "
                         f"pass it as an argument")))

    info = {"eqns": n_eqns, "const_bytes": const_bytes}
    return findings, info


def check_donation(fn, args: tuple, kwargs: dict, *, path: str, symbol: str,
                   ) -> tuple[list[Finding], dict]:
    """DONATION-DROPPED: declared donation must survive to compiled HLO.

    ``fn`` must be the jit-wrapped callable.  Donation is declared in
    ``lowered.args_info`` (per-arg ``donated`` flags) and honored iff the
    compiled module carries an ``input_output_alias`` directive — the
    empirical signature of XLA actually reusing the buffer.
    """
    lowered = fn.lower(*args, **kwargs)
    flat_info = jax.tree_util.tree_leaves(lowered.args_info)
    donated = [i for i, a in enumerate(flat_info)
               if getattr(a, "donated", False)]
    findings: list[Finding] = []
    honored: Optional[bool] = None
    if donated:
        text = lowered.compile().as_text()
        honored = "input_output_alias" in text
        if not honored:
            findings.append(Finding(
                rule="DONATION-DROPPED", path=path, symbol=symbol,
                message=(f"{len(donated)} argument(s) declared donated "
                         f"but the compiled module has no "
                         f"input_output_alias — XLA dropped the "
                         f"donation; the buffer is copied, not reused")))
    info = {"declared_donated": len(donated), "honored": honored}
    return findings, info


def run_entrypoint(entry) -> tuple[list[Finding], dict]:
    """Trace one registry entry and run every Pass 1 check on it.

    ``entry`` is an ``analysis.entrypoints.EntryPoint``; tracing failures
    are themselves findings (an entry point that stops tracing abstractly
    has broken its contract).
    """
    findings: list[Finding] = []
    info: dict[str, Any] = {"symbol": entry.symbol, "path": entry.path}
    try:
        closed = jax.make_jaxpr(
            entry.traceable(), static_argnums=entry.static_argnums,
        )(*entry.args, **entry.kwargs)
    except Exception as e:   # noqa: BLE001 — any trace failure is a finding
        findings.append(Finding(
            rule="F64-IN-JIT", path=entry.path, symbol=entry.symbol,
            message=f"entry point failed to trace abstractly: {e!r}"))
        info["trace_error"] = repr(e)
        return findings, info

    fnds, jinfo = check_jaxpr(closed, path=entry.path, symbol=entry.symbol,
                              max_const_bytes=entry.max_const_bytes)
    findings.extend(fnds)
    info.update(jinfo)

    if entry.check_donation:
        fnds, dinfo = check_donation(
            entry.jit_fn, entry.donation_args, entry.donation_kwargs,
            path=entry.path, symbol=entry.symbol)
        findings.extend(fnds)
        info["donation"] = dinfo
    return findings, info
