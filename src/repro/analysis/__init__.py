"""Static invariant analyzer (DESIGN.md §8).

Three passes over the repo, all waivable through the committed baseline:

1. ``jaxpr_checks``  — trace the registered jitted entry points and walk
   the closed jaxprs (F64-IN-JIT, HOST-CALLBACK, CONST-BAKE,
   DONATION-DROPPED).
2. ``ast_lint``      — AST convention rules (KEY-REUSE,
   INTERPRET-THREAD, PYTREE-REG, BANNED-IN-HOT).
3. ``pallas_budget`` — static VMEM footprints from BlockSpecs
   (VMEM-BUDGET, GRID-DIVISIBLE, FUSED-VS-ORACLE).

CLI: ``python -m repro.analysis --json report.json --baseline
analysis_baseline.json`` — exit 0 iff every finding is waived.
"""
from repro.analysis.report import (  # noqa: F401
    Finding,
    Report,
    Waiver,
    dump_baseline,
    load_baseline,
)

RULES = (
    "F64-IN-JIT", "HOST-CALLBACK", "CONST-BAKE", "DONATION-DROPPED",
    "KEY-REUSE", "INTERPRET-THREAD", "PYTREE-REG", "BANNED-IN-HOT",
    "VMEM-BUDGET", "GRID-DIVISIBLE", "FUSED-VS-ORACLE",
)
