"""CLI driver: ``python -m repro.analysis`` (DESIGN.md §8).

Runs the three passes, merges findings against the baseline, writes the
JSON report, prints the text summary, and exits non-zero iff any finding
is not covered by a waiver.  ``--update-baseline`` rewrites the baseline
to waive every current finding; NEW waivers take their justification from
the mandatory ``--reason`` flag (prior waivers keep theirs), and
``load_baseline`` rejects empty or ``TODO``-placeholder justifications so
an unedited reason can never pass review silently.
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis import ast_lint, jaxpr_checks, pallas_budget
from repro.analysis.report import Report, Waiver, dump_baseline, load_baseline


def build_report(root: str = ".", *, run_jaxpr: bool = True,
                 run_ast: bool = True, run_pallas: bool = True,
                 max_const_bytes: int | None = None,
                 vmem_budget: int | None = None) -> Report:
    """Run the selected passes over ``root`` and collect one Report."""
    report = Report()

    if run_ast:
        findings, info = ast_lint.lint_tree(root)
        report.extend(findings)
        report.info["ast_lint"] = info

    if run_jaxpr:
        from repro.analysis.entrypoints import build_registry

        entry_infos = []
        for entry in build_registry():
            if max_const_bytes is not None:
                entry.max_const_bytes = max_const_bytes
            findings, info = jaxpr_checks.run_entrypoint(entry)
            report.extend(findings)
            entry_infos.append(info)
        report.info["jaxpr_checks"] = {"entrypoints": entry_infos}

    if run_pallas:
        kw = {} if vmem_budget is None else {"vmem_budget": vmem_budget}
        findings, info = pallas_budget.check_kernels(**kw)
        report.extend(findings)
        report.info["pallas_budget"] = info

    return report


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static invariant analyzer: jaxpr contracts, repo "
                    "convention lint, Pallas VMEM budgets.")
    ap.add_argument("--root", default=".", help="repo root to scan")
    ap.add_argument("--json", metavar="PATH",
                    help="write the full JSON report here")
    ap.add_argument("--baseline", metavar="PATH",
                    help="committed waiver baseline (analysis_baseline.json)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite --baseline waiving every current finding "
                         "(new waivers need --reason)")
    ap.add_argument("--reason", metavar="TEXT", default=None,
                    help="justification recorded on NEW waivers written by "
                         "--update-baseline (prior waivers keep theirs)")
    ap.add_argument("--no-jaxpr", action="store_true",
                    help="skip Pass 1 (entry-point tracing)")
    ap.add_argument("--no-ast", action="store_true",
                    help="skip Pass 2 (AST lint)")
    ap.add_argument("--no-pallas", action="store_true",
                    help="skip Pass 3 (VMEM budgets)")
    ap.add_argument("--max-const-bytes", type=int, default=None,
                    help="CONST-BAKE threshold (default 1 MiB)")
    ap.add_argument("--vmem-budget", type=int, default=None,
                    help="VMEM-BUDGET threshold in bytes (default 16 MiB)")
    args = ap.parse_args(argv)

    report = build_report(
        args.root, run_jaxpr=not args.no_jaxpr, run_ast=not args.no_ast,
        run_pallas=not args.no_pallas, max_const_bytes=args.max_const_bytes,
        vmem_budget=args.vmem_budget)

    if args.update_baseline:
        if not args.baseline:
            ap.error("--update-baseline requires --baseline")
        old = load_baseline(args.baseline)
        waivers, seen = [], set()
        for f in report.findings:
            prior = next((w for w in old if w.covers(f)), None)
            if prior is None:
                reason = (args.reason or "").strip()
                if not reason or reason.upper().startswith("TODO"):
                    ap.error(
                        f"new finding {f.rule}::{f.site} needs a real "
                        "justification: pass --reason \"why this is "
                        "acceptable\" (TODO placeholders are rejected)")
                w = Waiver(rule=f.rule, match=f.site, reason=reason)
            else:
                w = prior
            if (w.rule, w.match) not in seen:
                seen.add((w.rule, w.match))
                waivers.append(w)
        dump_baseline(args.baseline, waivers)
        print(f"wrote {len(waivers)} waiver(s) to {args.baseline}")
        return 0

    report.waivers = load_baseline(args.baseline)
    if args.json:
        report.dump_json(args.json)
    print(report.format_text())
    for w in report.unused_waivers():
        print(f"note: unused waiver {w.rule}::{w.match}")
    return 1 if report.new_findings else 0


if __name__ == "__main__":
    sys.exit(main())
