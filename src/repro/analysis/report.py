"""Findings, baselines and the JSON report (DESIGN.md §8).

Every check in the three passes emits :class:`Finding` records with a
stable identity ``rule::path::symbol`` (no line numbers — findings must
survive unrelated edits above them).  A committed baseline file
(``analysis_baseline.json``) holds *waivers*: deliberate exceptions, each
carrying a one-line justification.  The analyzer exits non-zero only on
findings NOT covered by a waiver, so the baseline is the reviewed debt
ledger and any new finding is a hard CI failure.

Waiver ``match`` patterns are ``fnmatch`` globs against ``path::symbol``
(e.g. ``benchmarks/*.py::solver_bench``), which keeps one waiver stable
across refactors that only move lines around.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import json
from typing import Optional

BASELINE_FORMAT = "repro.analysis.baseline"
REPORT_FORMAT = "repro.analysis.report"
VERSION = 1


@dataclasses.dataclass
class Finding:
    """One rule violation at one site.

    ``path`` is repo-relative; ``symbol`` names the function/class/entry
    the finding anchors to (never a line number — see module docstring).
    """

    rule: str
    path: str
    symbol: str
    message: str
    line: int = 0

    @property
    def key(self) -> str:
        return f"{self.rule}::{self.path}::{self.symbol}"

    @property
    def site(self) -> str:
        return f"{self.path}::{self.symbol}"

    def to_dict(self) -> dict:
        return {
            "rule": self.rule,
            "path": self.path,
            "symbol": self.symbol,
            "line": self.line,
            "message": self.message,
        }


@dataclasses.dataclass(frozen=True)
class Waiver:
    """A deliberate, justified exception recorded in the baseline."""

    rule: str
    match: str       # fnmatch glob against "path::symbol"
    reason: str

    def covers(self, f: Finding) -> bool:
        return self.rule == f.rule and fnmatch.fnmatch(f.site, self.match)


def load_baseline(path: Optional[str]) -> list[Waiver]:
    """Load waivers; a missing/None path is an empty baseline."""
    if path is None:
        return []
    try:
        with open(path) as fh:
            data = json.load(fh)
    except FileNotFoundError:
        return []
    if data.get("format") != BASELINE_FORMAT:
        raise ValueError(f"{path} is not a {BASELINE_FORMAT} file")
    waivers = []
    for w in data.get("waivers", []):
        reason = w.get("reason", "").strip()
        if not reason:
            raise ValueError(
                f"baseline waiver {w.get('rule')}::{w.get('match')} has no "
                "justification — every waiver must say why")
        if reason.upper().startswith("TODO"):
            raise ValueError(
                f"baseline waiver {w.get('rule')}::{w.get('match')} has a "
                f"placeholder justification ({reason!r}) — replace the TODO "
                "with the actual reason this finding is acceptable")
        waivers.append(Waiver(rule=w["rule"], match=w["match"],
                              reason=w["reason"]))
    return waivers


def dump_baseline(path: str, waivers: list[Waiver]) -> None:
    with open(path, "w") as fh:
        json.dump({
            "format": BASELINE_FORMAT,
            "version": VERSION,
            "waivers": [dataclasses.asdict(w) for w in waivers],
        }, fh, indent=2)
        fh.write("\n")


@dataclasses.dataclass
class Report:
    """All findings of one analyzer run plus per-pass structured data."""

    findings: list[Finding] = dataclasses.field(default_factory=list)
    # Pass-specific structured payloads (entry-point inventory, pallas
    # program footprints, ...) — the regression-trajectory part of the
    # report, present even when nothing fires.
    info: dict = dataclasses.field(default_factory=dict)
    waivers: list[Waiver] = dataclasses.field(default_factory=list)

    def extend(self, findings: list[Finding]) -> None:
        self.findings.extend(findings)

    def waiver_for(self, f: Finding) -> Optional[Waiver]:
        for w in self.waivers:
            if w.covers(f):
                return w
        return None

    @property
    def new_findings(self) -> list[Finding]:
        return [f for f in self.findings if self.waiver_for(f) is None]

    @property
    def waived_findings(self) -> list[Finding]:
        return [f for f in self.findings if self.waiver_for(f) is not None]

    def unused_waivers(self) -> list[Waiver]:
        return [w for w in self.waivers
                if not any(w.covers(f) for f in self.findings)]

    def to_dict(self) -> dict:
        entries = []
        for f in self.findings:
            w = self.waiver_for(f)
            e = f.to_dict()
            e["waived"] = w is not None
            if w is not None:
                e["waiver_reason"] = w.reason
            entries.append(e)
        return {
            "format": REPORT_FORMAT,
            "version": VERSION,
            "findings": entries,
            "info": self.info,
            "summary": {
                "total": len(self.findings),
                "waived": len(self.waived_findings),
                "new": len(self.new_findings),
                "unused_waivers": [dataclasses.asdict(w)
                                   for w in self.unused_waivers()],
            },
        }

    def dump_json(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_dict(), fh, indent=2, default=_json_default)
            fh.write("\n")

    def format_text(self) -> str:
        lines = []
        for f in self.findings:
            w = self.waiver_for(f)
            tag = "waived" if w is not None else "NEW"
            loc = f"{f.path}:{f.line}" if f.line else f.path
            lines.append(f"[{tag}] {f.rule} {loc} ({f.symbol}): {f.message}")
            if w is not None:
                lines.append(f"         waiver: {w.reason}")
        lines.append(
            f"{len(self.findings)} finding(s): "
            f"{len(self.new_findings)} new, "
            f"{len(self.waived_findings)} waived.")
        return "\n".join(lines)


def _json_default(o):
    import numpy as np

    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    return str(o)
