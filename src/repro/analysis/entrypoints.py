"""Registry of public jitted entry points for Pass 1 (jaxpr_checks).

Every entry builds the *real* object path — hand-constructed ``SVMModel``
banks, the calibrated analog behavioral model, the PR 3/4 compiled
machines — at tiny deterministic shapes, then exposes the exact traced
callable the production code jits.  No training runs and no kernel
executes: the registry exists so ``jax.make_jaxpr`` can inspect the same
programs users compile.

Registering a new jitted entry point (DESIGN.md §8): append an
:class:`EntryPoint` in :func:`build_registry` whose ``fn`` is the
*unjitted* callable (close over static arguments; arrays go in ``args``)
and, if it declares ``donate_argnames``, set ``check_donation=True`` with
the jit wrapper in ``jit_fn`` — donation is verified on the compiled
artifact, so keep ``donation_args`` small.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.jaxpr_checks import MAX_CONST_BYTES


@dataclasses.dataclass
class EntryPoint:
    """One traceable jitted program plus everything Pass 1 needs."""

    symbol: str
    path: str
    fn: Callable                 # unjitted traceable (statics closed over)
    args: tuple
    kwargs: dict = dataclasses.field(default_factory=dict)
    static_argnums: tuple = ()
    max_const_bytes: int = MAX_CONST_BYTES
    check_donation: bool = False
    jit_fn: Optional[Callable] = None
    donation_args: tuple = ()
    donation_kwargs: dict = dataclasses.field(default_factory=dict)

    def traceable(self) -> Callable:
        return self.fn


def _tiny_models():
    """Deterministic hand-built per-pair models — no training involved."""
    from repro.core.svm import SVMModel

    rng = np.random.default_rng(0)
    d, m = 3, 6
    sx = rng.normal(size=(m, d)).astype(np.float32)
    sy = np.array([1, -1, 1, -1, 1, -1], np.float32)
    alpha = (np.abs(rng.normal(size=m)) + 0.1).astype(np.float32)
    w = ((alpha * sy) @ sx).astype(np.float32)
    lin = SVMModel(kind="linear", support_x=sx, support_y=sy, alpha=alpha,
                   bias=0.1, gamma=1.0, c=1.0, w=w)
    rbf = SVMModel(kind="rbf", support_x=sx, support_y=sy, alpha=alpha,
                   bias=-0.05, gamma=0.7, c=1.0)
    return lin, rbf


def build_registry() -> list[EntryPoint]:
    from repro.api import compiled as api
    from repro.core import trainer
    from repro.core.analog import AnalogBinaryClassifier
    from repro.kernels import solver

    entries: list[EntryPoint] = []
    lin, rbf = _tiny_models()
    d = lin.support_x.shape[1]
    hw_clf = AnalogBinaryClassifier.deploy(rbf, trainer.default_hw(0))
    x_in = jnp.zeros((8, d), jnp.float32)

    machine = api.compile_machine([lin, rbf, hw_clf], n_classes=3)
    entries.append(EntryPoint(
        symbol="CompiledMachine._forward", path="src/repro/api/compiled.py",
        fn=machine._forward, args=(x_in,)))

    cands = [(lin, rbf), (lin, rbf), (lin, hw_clf)]
    cand_machine = api.compile_candidates(cands, n_classes=3)
    entries.append(EntryPoint(
        symbol="CandidateMachine._forward", path="src/repro/api/compiled.py",
        fn=cand_machine._forward, args=(x_in,)))

    mc_machine = api.compile_variants(
        cands, n_classes=3, key=jax.random.PRNGKey(0), n_variants=4)
    entries.append(EntryPoint(
        symbol="MonteCarloMachine._forward",
        path="src/repro/api/compiled.py",
        fn=mc_machine._forward, args=(x_in,)))

    # -- streaming MC chunk step (jit + donate_argnums=(0,)) ----------------
    # The flat-memory variant pipeline (DESIGN.md §10): one fixed-shape
    # donated step folds a generated chunk into the StreamStats pytree.
    from repro.core import mcstream

    sm = api.compile_mc_stream(
        cands, n_classes=3, key=jax.random.PRNGKey(0), mc_chunk=4)
    step_args = (
        mcstream.init_stream(1, mcstream.hist_bins(8)),  # state (donated)
        x_in,
        jnp.arange(4, dtype=jnp.int32),                  # v_idx
        jnp.ones((4,), jnp.float32),                     # valid
        jnp.float32(0.5),                                # floor
        jnp.ones((1, 3), bool),                          # assignments
        jnp.zeros((8,), jnp.int32),                      # y
        jnp.zeros((4, 0), jnp.float32),                  # u (iid: unused)
    )
    entries.append(EntryPoint(
        symbol="StreamingMCMachine._step",
        path="src/repro/api/compiled.py",
        fn=sm._step, args=step_args,
        check_donation=True, jit_fn=sm._step_jit,
        donation_args=step_args))

    # -- fleet serving forward (jit + donate_argnums=(1,)) ------------------
    # Two-member co-batched fleet; the serving hot path donates the
    # model_idx buffer, reused for the i32 label output (DESIGN.md §9).
    from repro.api import fleet as fleet_mod

    machine_b = api.compile_machine([rbf, lin, hw_clf], n_classes=3)
    fleet = fleet_mod.compile_fleet({"m0": machine, "m1": machine_b})
    idx_in = jnp.zeros((8,), jnp.int32)
    entries.append(EntryPoint(
        symbol="FleetMachine._forward", path="src/repro/api/fleet.py",
        fn=fleet._forward, args=(x_in, idx_in),
        check_donation=True, jit_fn=fleet._labels_jit,
        donation_args=(x_in, idx_in)))

    # -- mesh-sharded serving forward (DESIGN.md §12.1) ---------------------
    # Same labels program through the shard_map data-parallel leg on a
    # 1-device serving mesh (valid on single-device CI): re-verifies that
    # sharding preserves the model_idx -> label-output donation.
    from repro.launch.mesh import make_serving_mesh

    sharded = fleet.shard(make_serving_mesh(1))
    entries.append(EntryPoint(
        symbol="FleetMachine._labels[sharded]",
        path="src/repro/api/fleet.py",
        fn=fleet._labels, args=(x_in, idx_in),
        check_donation=True, jit_fn=sharded._labels_jit,
        donation_args=(x_in, idx_in)))

    # -- DAG decision front (O(K) pair evaluations; DESIGN.md §11) ----------
    machine_dag = api.compile_machine([lin, rbf, hw_clf], n_classes=3,
                                      decider="dag")
    entries.append(EntryPoint(
        symbol="CompiledMachine._labels_dag",
        path="src/repro/api/compiled.py",
        fn=machine_dag._labels_dag, args=(x_in,)))

    # -- portfolio / streaming votes scoring (P > MAX_TABLE_BITS) -----------
    # The pair-chunked recombination every large-P scorer shares: the DSE
    # portfolio search, assignment_accuracies past the table limit, and
    # the streaming MC engine's votes path.
    from repro.core import dse

    k6, p15 = 6, 15
    va6, vb6 = dse._vote_matrices(k6)
    rngb = np.random.default_rng(3)
    votes_args = (
        jnp.asarray(rngb.integers(0, 2, size=(2, 8, p15, 2)), jnp.int32),
        jnp.asarray(rngb.integers(0, 2, size=(3, p15)), jnp.int32),
        jnp.zeros((8,), jnp.int32),
        jnp.asarray(va6), jnp.asarray(vb6),
    )
    entries.append(EntryPoint(
        symbol="dse._votes_accuracy_paired", path="src/repro/core/dse.py",
        fn=dse._votes_accuracy_paired, args=votes_args))

    # -- trainer family program (jit + donate_argnames=('y',)) --------------
    p, n, dd, g, c, f = 2, 32, 3, 2, 2, 2
    fam_args = (
        jnp.zeros((p, n, dd), jnp.float32),          # x
        jnp.ones((p, n), jnp.float32),               # y (donated)
        jnp.ones((p, f, n), jnp.float32),            # fold_masks
        jnp.ones((p, n), jnp.float32),               # valid
        jnp.asarray([0.5, 1.0], jnp.float32),        # gammas
        jnp.asarray([1.0, 10.0], jnp.float32),       # cs
    )

    def family_traceable(x, y, fold_masks, valid, gammas, cs):
        return trainer._family_program.__wrapped__(
            x, y, fold_masks, valid, gammas, cs, kind="rbf", cv_epochs=3,
            n_epochs=4, use_pallas=True, interpret=True)

    entries.append(EntryPoint(
        symbol="trainer._family_program", path="src/repro/core/trainer.py",
        fn=family_traceable, args=fam_args,
        check_donation=True, jit_fn=trainer._family_program,
        donation_args=fam_args,
        # donation is a property of the jit signature; verify on the XLA
        # vmap path where tiny-shape compiles are cheap
        donation_kwargs=dict(kind="rbf", cv_epochs=3, n_epochs=4,
                             use_pallas=False)))

    refit_args = (
        jnp.zeros((p, n, dd), jnp.float32),          # x
        jnp.ones((p, n), jnp.float32),               # y (donated)
        jnp.ones((p, n), jnp.float32),               # valid
        jnp.asarray([0.5, 1.0], jnp.float32),        # gamma_sel
        jnp.asarray([1.0, 1.0], jnp.float32),        # c_sel
    )

    def refit_traceable(x, y, valid, gamma_sel, c_sel):
        return trainer._refit_all_pairs.__wrapped__(
            x, y, valid, gamma_sel, c_sel, kind="rbf", n_epochs=4,
            use_pallas=True, interpret=True)

    entries.append(EntryPoint(
        symbol="trainer._refit_all_pairs", path="src/repro/core/trainer.py",
        fn=refit_traceable, args=refit_args,
        check_donation=True, jit_fn=trainer._refit_all_pairs,
        donation_args=refit_args,
        donation_kwargs=dict(kind="rbf", n_epochs=4, use_pallas=False)))

    # -- fused solver lanes (ops.solve_lanes target) ------------------------
    def solver_traceable(x, y, c_box, gamma):
        return solver.dual_ascent_lanes_pallas.__wrapped__(
            x, y, c_box, gamma, kind="rbf", n_epochs=2, interpret=True)

    entries.append(EntryPoint(
        symbol="ops.solve_lanes", path="src/repro/kernels/solver.py",
        fn=solver_traceable,
        args=(jnp.zeros((2, 32, 3), jnp.float32),
              jnp.ones((2, 32), jnp.float32),
              jnp.ones((2, 4, 32), jnp.float32),
              jnp.ones((2, 2), jnp.float32))))

    return entries
