"""Mixed-precision domain assignment — the TPU analogue of "mixed-signal".

DESIGN.md §3: on TPU the paper's domain split (approximate-analog vs
exact-digital) maps to precision domains: int8 ("analog" — cheap,
approximate) vs bf16/fp32 ("digital" — exact).  Algorithm 1's
separation-driven strategy transfers unchanged, at *module* granularity:

    for each module m:
        quality_cheap  = quality(model with m in the cheap domain)
        quality_exact  = quality(model with m in the exact domain)
        assign m to cheap unless the exact domain is strictly better by
        more than `tolerance`

i.e. exactly the paper's "keep RBF only where it buys accuracy", inverted:
keep high precision only where it buys quality.  Used by the qwen2.5-32b
decode hillclimb (int8 weights halve the memory roofline term) and tested
on small models in-container.

Also provides the int8 quantized-weight container (`QuantTensor`) consumed
by ``repro.models`` when a config selects `weight_domains`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class QuantTensor:
    """Symmetric per-channel int8 weight: w ~= q * scale (scale per last dim)."""

    q: jax.Array       # int8, same shape as w
    scale: jax.Array   # (..., 1) broadcastable f32

    @classmethod
    def quantize(cls, w: jax.Array, axis: int = -1) -> "QuantTensor":
        amax = jnp.max(jnp.abs(w), axis=axis, keepdims=True)
        scale = jnp.maximum(amax, 1e-8) / 127.0
        q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
        return cls(q=q, scale=scale.astype(jnp.float32))

    def dequantize(self, dtype=jnp.bfloat16) -> jax.Array:
        return (self.q.astype(jnp.float32) * self.scale).astype(dtype)

    @property
    def nbytes(self) -> int:
        return self.q.size + self.scale.size * 4


jax.tree_util.register_pytree_node(
    QuantTensor,
    lambda t: ((t.q, t.scale), None),
    lambda _, c: QuantTensor(q=c[0], scale=c[1]),
)


def dequant_matmul(x: jax.Array, w: QuantTensor) -> jax.Array:
    """x @ dequant(w) — the pattern XLA fuses into the gather of the matmul."""
    return x @ w.dequantize(x.dtype)


# ---------------------------------------------------------------------------
# Separation-driven domain assignment (Algorithm 1, precision edition)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DomainAssignment:
    modules: list[str]
    domain: dict[str, str]            # module -> 'cheap' | 'exact'
    quality_cheap: dict[str, float]
    quality_exact: dict[str, float]

    @property
    def n_cheap(self) -> int:
        return sum(v == "cheap" for v in self.domain.values())


def assign_domains(
    modules: Sequence[str],
    quality_with_domains: Callable[[dict[str, str]], float],
    tolerance: float = 0.0,
) -> DomainAssignment:
    """Per-module greedy separation, mirroring Algorithm 1's per-pair loop.

    ``quality_with_domains`` evaluates the end-to-end model quality (higher
    is better — accuracy, or -perplexity) under a full module->domain map.
    Each module is probed independently against the all-exact reference
    (the analogue of training both kernels per pair), then the joint cheap
    assignment keeps every module whose independent probe showed no loss
    beyond ``tolerance``.
    """
    base = {m: "exact" for m in modules}
    q_exact_all = quality_with_domains(dict(base))
    q_cheap: dict[str, float] = {}
    q_exact: dict[str, float] = {}
    domain: dict[str, str] = {}
    for m in modules:
        probe = dict(base)
        probe[m] = "cheap"
        q_cheap[m] = quality_with_domains(probe)
        q_exact[m] = q_exact_all
        # keep exact ONLY if it is strictly better beyond tolerance
        domain[m] = "exact" if (q_exact_all - q_cheap[m]) > tolerance else "cheap"
    return DomainAssignment(
        modules=list(modules), domain=domain,
        quality_cheap=q_cheap, quality_exact=q_exact,
    )


def quantize_tree_where(
    params, domain_of_path: Callable[[tuple], str]
):
    """Quantize leaves whose tree path maps to the 'cheap' domain.

    2-D+ float leaves in cheap modules become QuantTensor; everything else
    passes through.  Embedding/norm params should be routed 'exact' by the
    caller's ``domain_of_path``.
    """
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    out = []
    for path, leaf in flat:
        key = tuple(
            getattr(p, "key", getattr(p, "idx", getattr(p, "name", str(p))))
            for p in path
        )
        if (
            isinstance(leaf, jax.Array)
            and leaf.ndim >= 2
            and jnp.issubdtype(leaf.dtype, jnp.floating)
            and domain_of_path(key) == "cheap"
        ):
            out.append(QuantTensor.quantize(leaf))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_param_bytes(params) -> int:
    """Total parameter bytes, QuantTensor-aware (for roofline accounting)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(
        params, is_leaf=lambda x: isinstance(x, QuantTensor)
    ):
        if isinstance(leaf, QuantTensor):
            total += leaf.nbytes
        elif hasattr(leaf, "nbytes"):
            total += leaf.nbytes
    return total
