"""Streaming Monte-Carlo accumulators: flat memory from V=64 to V=10^6.

PR 4's variant axis materializes the full ``(V, n, P, 2)`` bit tensor and
a dense ``(V, S)`` accuracy grid — fine at V ~ 64, impossible at the
ppm-level tail-yield scale a fab signs off on.  This module holds the
in-graph accumulator algebra that replaces the dense grid (DESIGN.md §10):
a chunk of variants is generated, scored and *folded into fixed-shape
state*, then its buffers are reused for the next chunk.  Peak memory is a
function of the chunk size only, never of ``V``.

Contracts
---------
* :class:`StreamStats` — the donated accumulator pytree.  All in-graph
  fields are f32: counts are exact in f32 up to 2^24 (> 10^6 variants),
  and the mean/M2 recursion below keeps the second moment stable without
  f64 (which the F64-IN-JIT analyzer rule bans inside jit).
* :func:`chunk_aggregates` reduces one ``(B, S)`` accuracy chunk to
  per-chunk sums *relative to the running mean* (``state.mean`` is the
  centering point, so the raw-moment cancellation stays benign), all of
  them LINEAR in the variant axis — which is exactly what makes the
  multi-device leg a plain ``psum``/``pmin``/``pmax`` over a
  ``shard_map`` variant axis (``launch.mesh.make_variant_mesh``).
* :func:`merge_stream` is Chan's parallel Welford merge of one aggregate
  into the running state.  ``update_stream`` = aggregates + merge, the
  single-host path.
* Weights: every accumulator is *weighted* (``w = 1`` for iid/QMC
  sampling; self-normalized importance weights for ``method='is'``).
  ``finalize`` converts weighted sums to self-normalized estimates and
  reports the effective sample size ``n_eff = (Σw)² / Σw²`` — the n that
  enters the Wilson/Clopper-Pearson yield bounds, so IS runs cannot claim
  iid-sized confidence.
* Invalid slots (the tail chunk's padding) enter with ``valid = 0`` and
  contribute exactly nothing — one compiled program serves every V.
* The quantile sketch is a fixed-grid histogram over [0, 1]: accuracies
  live on the lattice ``k / n_val``, so with ``n_bins = n_val + 1``
  (up to :data:`MAX_HIST_BINS`) the sketch is *exact*, not approximate.

Host-side helpers: Wilson / Clopper-Pearson binomial bounds (the latter
gated on scipy, with a Wilson fallback) and the scrambled-Sobol /
Latin-hypercube chunk samplers (:class:`QMCSampler`), both seeded
deterministically from stored jax key data.  Sobol chunks are generated
with ``fast_forward`` so draw ``v`` depends only on the *global* variant
index — the streamed sequence is invariant to the chunk size, mirroring
the ``fold_in``-keyed iid draws.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

#: Default two-sided confidence level of the yield interval.
DEFAULT_CONFIDENCE = 0.95

#: Histogram-sketch resolution cap.  Accuracies on n_val <= 1024 samples
#: are resolved exactly (bin lattice == accuracy lattice); beyond that the
#: quantile error is bounded by half a bin width, 1/2048.
MAX_HIST_BINS = 1025

_TINY = jnp.float32(1e-30)


# ---------------------------------------------------------------------------
# The accumulator pytree
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StreamStats:
    """Fixed-shape running statistics over the variant axis (S columns).

    ``count``/``w_sum``/``w2_sum`` are scalars (the weight stream is
    shared by all S assignments); ``mean``/``m2``/``exceed``/``amin``/
    ``amax`` are ``(S,)``; ``hist`` is the ``(S, n_bins)`` fixed-grid
    sketch.  The pytree is DONATED through the streaming chunk step:
    state in, state out, buffers reused across every chunk.
    """

    count: jnp.ndarray    # () f32 — number of valid variants folded in
    w_sum: jnp.ndarray    # () f32 — Σ w over valid variants
    w2_sum: jnp.ndarray   # () f32 — Σ w² over valid variants
    mean: jnp.ndarray     # (S,) f32 — weighted running mean
    m2: jnp.ndarray       # (S,) f32 — weighted Σ w (x - mean)²
    exceed: jnp.ndarray   # (S,) f32 — Σ w · [x >= floor]
    amin: jnp.ndarray     # (S,) f32 — min accuracy seen (+inf when empty)
    amax: jnp.ndarray     # (S,) f32 — max accuracy seen (-inf when empty)
    hist: jnp.ndarray     # (S, n_bins) f32 — weighted fixed-grid counts
    log_ref: jnp.ndarray  # () f32 — log-scale of every weighted sum
    # All weighted sums are stored RELATIVE to exp(log_ref) (a streaming
    # logsumexp): importance-sampling log-weights in high-dimensional
    # mismatch spaces routinely sit hundreds of nats from zero, where a
    # fixed f32 exp either underflows every weight to an exact zero or
    # clips a macroscopic fraction of draws into an artificial tie —
    # both silently corrupt n_eff.  The reference only ever grows
    # (running max); every self-normalized statistic is a ratio of sums
    # at the same scale, so exp(log_ref) cancels in `finalize`.


jax.tree_util.register_dataclass(
    StreamStats,
    data_fields=["count", "w_sum", "w2_sum", "mean", "m2", "exceed",
                 "amin", "amax", "hist", "log_ref"],
    meta_fields=[])


@dataclasses.dataclass
class ChunkAgg:
    """One chunk reduced to mergeable sums (centered on the running mean).

    Every field except ``amin``/``amax`` is a plain sum over the chunk's
    variant rows, so a sharded chunk merges with ``psum`` (and ``pmin``/
    ``pmax`` for the extrema) before one replicated :func:`merge_stream`.
    """

    n_c: jnp.ndarray      # () f32 — valid rows in the chunk
    w_c: jnp.ndarray      # () f32 — Σ w
    w2_c: jnp.ndarray     # () f32 — Σ w²
    s1: jnp.ndarray       # (S,) f32 — Σ w (x - center)
    s2: jnp.ndarray       # (S,) f32 — Σ w (x - center)²
    exceed: jnp.ndarray   # (S,) f32
    amin: jnp.ndarray     # (S,) f32
    amax: jnp.ndarray     # (S,) f32
    hist: jnp.ndarray     # (S, n_bins) f32
    log_ref: jnp.ndarray  # () f32 — log-scale of this chunk's sums


jax.tree_util.register_dataclass(
    ChunkAgg,
    data_fields=["n_c", "w_c", "w2_c", "s1", "s2", "exceed", "amin",
                 "amax", "hist", "log_ref"],
    meta_fields=[])


def hist_bins(n_val: int) -> int:
    """Sketch resolution for a validation set of ``n_val`` rows: the
    accuracy lattice size ``n_val + 1``, capped at :data:`MAX_HIST_BINS`."""
    return min(int(n_val) + 1, MAX_HIST_BINS)


def init_stream(n_assignments: int, n_bins: int) -> StreamStats:
    """All-zero state (extrema at +/- inf) for ``S`` assignment columns."""
    s = int(n_assignments)
    # Each leaf gets its own freshly-allocated buffer: the streaming step
    # donates the whole state pytree, and XLA rejects donating one buffer
    # through two arguments (`f(donate(a), donate(a))`).
    def z():
        return jnp.zeros((s,), jnp.float32) + jnp.float32(0)

    return StreamStats(
        count=jnp.zeros((), jnp.float32) + 0,
        w_sum=jnp.zeros((), jnp.float32) + 0,
        w2_sum=jnp.zeros((), jnp.float32) + 0,
        mean=z(), m2=z(), exceed=z(),
        amin=jnp.full((s,), jnp.inf, jnp.float32),
        amax=jnp.full((s,), -jnp.inf, jnp.float32),
        hist=jnp.zeros((s, int(n_bins)), jnp.float32) + 0,
        log_ref=jnp.full((), -jnp.inf, jnp.float32) + 0)


def chunk_aggregates(center: jnp.ndarray, acc: jnp.ndarray, w: jnp.ndarray,
                     valid: jnp.ndarray, floor: jnp.ndarray,
                     n_bins: int, log_ref=None) -> ChunkAgg:
    """Reduce one accuracy chunk ``acc (B, S)`` to mergeable sums.

    ``w``/``valid`` are ``(B,)`` f32; rows with ``valid = 0`` contribute
    exactly nothing (the tail-chunk padding contract).  ``center (S,)`` is
    the running mean the moments are taken around — after the first chunk
    it tracks the data, so the ``s2 - s1²/W`` cancellation in
    :func:`merge_stream` operates on small residuals.  ``log_ref`` is the
    log-scale the caller computed ``w`` at (importance sampling passes
    ``max(logw)`` over the chunk so ``w`` sits in ``(0, 1]``); ``None``
    means absolute weights (scale 0).
    """
    wv = w * valid                                    # (B,)
    dc = acc - center[None, :]                        # (B, S)
    inf = jnp.float32(jnp.inf)
    masked_lo = jnp.where(valid[:, None] > 0, acc, inf)
    masked_hi = jnp.where(valid[:, None] > 0, acc, -inf)
    bins = jnp.clip(jnp.round(acc * (n_bins - 1)).astype(jnp.int32),
                    0, n_bins - 1)                    # (B, S)
    s = acc.shape[1]
    hist = jnp.zeros((s, n_bins), jnp.float32)
    hist = hist.at[jnp.arange(s)[None, :], bins].add(
        jnp.broadcast_to(wv[:, None], bins.shape))
    if log_ref is None:
        log_ref = jnp.zeros((), jnp.float32)
    return ChunkAgg(
        n_c=jnp.sum(valid), w_c=jnp.sum(wv), w2_c=jnp.sum(wv * wv),
        s1=wv @ dc, s2=wv @ (dc * dc),
        exceed=wv @ (acc >= floor).astype(jnp.float32),
        amin=jnp.min(masked_lo, axis=0), amax=jnp.max(masked_hi, axis=0),
        hist=hist, log_ref=jnp.asarray(log_ref, jnp.float32))


def merge_stream(state: StreamStats, agg: ChunkAgg) -> StreamStats:
    """Chan's parallel merge of one (possibly psum-reduced) aggregate.

    The aggregate's moments are centered on ``state.mean``; with
    ``delta = s1 / w_c`` (the chunk mean minus the running mean) the
    chunk's own M2 is ``s2 - s1 · delta`` and the classic update applies.
    Empty chunks (``w_c = 0``) are exact no-ops.

    Both sides carry a log-scale; the merged state lives at the larger
    one and the *other* side's sums are multiplied down by the ratio
    (never up — no overflow).  When the scales already agree — every
    non-IS method pins them to 0 — the factors are the literal 1.0 and
    each product is bit-exact, so the unweighted paths are unchanged.
    The equal-scale branch also guards the empty ``-inf - -inf = nan``.
    """
    ref = jnp.maximum(state.log_ref, agg.log_ref)
    fs = jnp.where(state.log_ref == ref, jnp.float32(1.0),
                   jnp.exp(state.log_ref - ref))
    fc = jnp.where(agg.log_ref == ref, jnp.float32(1.0),
                   jnp.exp(agg.log_ref - ref))
    w_old = fs * state.w_sum
    w_c = fc * agg.w_c
    w_new = w_old + w_c
    delta = agg.s1 / jnp.maximum(agg.w_c, _TINY)          # (S,) scale-free
    m2_chunk = agg.s2 - agg.s1 * delta
    r = w_c / jnp.maximum(w_new, _TINY)
    return StreamStats(
        count=state.count + agg.n_c,
        w_sum=w_new,
        w2_sum=fs * fs * state.w2_sum + fc * fc * agg.w2_c,
        mean=state.mean + delta * r,
        m2=fs * state.m2 + fc * m2_chunk + delta * delta * w_old * r,
        exceed=fs * state.exceed + fc * agg.exceed,
        amin=jnp.minimum(state.amin, agg.amin),
        amax=jnp.maximum(state.amax, agg.amax),
        hist=fs * state.hist + fc * agg.hist,
        log_ref=ref)


def update_stream(state: StreamStats, acc: jnp.ndarray, w: jnp.ndarray,
                  valid: jnp.ndarray, floor: jnp.ndarray,
                  log_ref=None) -> StreamStats:
    """Single-host chunk update: aggregates + merge, all in-graph."""
    n_bins = state.hist.shape[1]
    return merge_stream(
        state, chunk_aggregates(state.mean, acc, w, valid, floor, n_bins,
                                log_ref=log_ref))


# ---------------------------------------------------------------------------
# Binomial confidence bounds (host side, f64)
# ---------------------------------------------------------------------------


def wilson_bounds(p: np.ndarray, n: np.ndarray,
                  confidence: float = DEFAULT_CONFIDENCE
                  ) -> tuple[np.ndarray, np.ndarray]:
    """Two-sided Wilson score interval for a binomial proportion.

    Closed-form, well-behaved at p = 0 and p = 1 (where the naive Wald
    interval collapses to zero width — the "yield 0.03 ± everything"
    failure mode this PR closes).  ``n`` may be non-integer: the caller
    passes the *effective* sample size of a weighted stream.
    """
    p = np.asarray(p, np.float64)
    n = np.maximum(np.asarray(n, np.float64), 1e-12)
    z = float(_norm_ppf(0.5 + confidence / 2.0))
    z2 = z * z
    denom = 1.0 + z2 / n
    center = (p + z2 / (2.0 * n)) / denom
    half = z * np.sqrt(p * (1.0 - p) / n + z2 / (4.0 * n * n)) / denom
    return np.clip(center - half, 0.0, 1.0), np.clip(center + half, 0.0, 1.0)


def clopper_pearson_bounds(p: np.ndarray, n: np.ndarray,
                           confidence: float = DEFAULT_CONFIDENCE
                           ) -> tuple[np.ndarray, np.ndarray]:
    """Exact (conservative) Clopper-Pearson interval via the beta quantile.

    Needs ``scipy.stats.beta``; when scipy is unavailable the Wilson
    interval is returned instead (a documented, slightly narrower
    fallback — never a crash on a lean container).  Non-integer
    ``k = p n`` (weighted streams) is supported: the beta quantile is
    continuous in its shape parameters.
    """
    try:
        from scipy.stats import beta
    except ImportError:  # pragma: no cover - container ships scipy
        return wilson_bounds(p, n, confidence)
    p = np.asarray(p, np.float64)
    n = np.maximum(np.asarray(n, np.float64), 1e-12)
    k = np.clip(p * n, 0.0, n)
    alpha = 1.0 - confidence
    with np.errstate(invalid="ignore"):
        lo = beta.ppf(alpha / 2.0, k, n - k + 1.0)
        hi = beta.ppf(1.0 - alpha / 2.0, k + 1.0, n - k)
    lo = np.where(k <= 0.0, 0.0, lo)
    hi = np.where(k >= n, 1.0, hi)
    return np.clip(np.nan_to_num(lo, nan=0.0), 0.0, 1.0), \
        np.clip(np.nan_to_num(hi, nan=1.0), 0.0, 1.0)


def _norm_ppf(q: float) -> float:
    """Standard-normal quantile; scipy when present, else Acklam's
    rational approximation (|err| < 1.2e-9 — far below CI tolerances)."""
    try:
        from scipy.stats import norm
        return float(norm.ppf(q))
    except ImportError:  # pragma: no cover - container ships scipy
        return _acklam_ppf(q)


def _acklam_ppf(q: float) -> float:  # pragma: no cover - scipy fallback
    a = (-3.969683028665376e+01, 2.209460984245205e+02,
         -2.759285104469687e+02, 1.383577518672690e+02,
         -3.066479806614716e+01, 2.506628277459239e+00)
    b = (-5.447609879822406e+01, 1.615858368580409e+02,
         -1.556989798598866e+02, 6.680131188771972e+01,
         -1.328068155288572e+01)
    c = (-7.784894002430293e-03, -3.223964580411365e-01,
         -2.400758277161838e+00, -2.549732539343734e+00,
         4.374664141464968e+00, 2.938163982698783e+00)
    d = (7.784695709041462e-03, 3.224671290700398e-01,
         2.445134137142996e+00, 3.754408661907416e+00)
    p_low, p_high = 0.02425, 1 - 0.02425
    if q < p_low:
        u = np.sqrt(-2 * np.log(q))
        return (((((c[0] * u + c[1]) * u + c[2]) * u + c[3]) * u + c[4])
                * u + c[5]) / ((((d[0] * u + d[1]) * u + d[2]) * u + d[3])
                               * u + 1)
    if q > p_high:
        return -_acklam_ppf(1 - q)
    u = q - 0.5
    t = u * u
    return (((((a[0] * t + a[1]) * t + a[2]) * t + a[3]) * t + a[4])
            * t + a[5]) * u / (((((b[0] * t + b[1]) * t + b[2]) * t + b[3])
                                * t + b[4]) * t + 1)


def hist_quantiles(hist: np.ndarray, qs) -> np.ndarray:
    """Type-1 quantiles from the fixed-grid sketch.

    ``hist (S, n_bins)`` weighted counts -> ``(len(qs), S)`` accuracy
    values on the bin lattice.  Exact when the bin lattice contains the
    accuracy lattice (``n_bins = n_val + 1``).
    """
    hist = np.asarray(hist, np.float64)
    qs = np.atleast_1d(np.asarray(qs, np.float64))
    n_bins = hist.shape[1]
    total = np.maximum(hist.sum(axis=1, keepdims=True), 1e-300)
    cdf = np.cumsum(hist, axis=1) / total                   # (S, n_bins)
    out = np.empty((qs.shape[0], hist.shape[0]), np.float64)
    grid = np.arange(n_bins, dtype=np.float64) / (n_bins - 1)
    for i, q in enumerate(qs):
        # Threshold floored above zero so q = 0 returns the MINIMUM (the
        # first bin with any mass), not the empty left tail of the cdf.
        thr = max(min(max(q, 0.0), 1.0) - 1e-12, 1e-300)
        idx = np.argmax(cdf >= thr, axis=1)
        out[i] = grid[idx]
    return out


def finalize(state: StreamStats, confidence: float = DEFAULT_CONFIDENCE,
             ci: str = "wilson") -> dict:
    """Weighted sums -> per-assignment statistics dict (host f64).

    Keys mirror ``dse.mc_statistics`` (``mean``/``std``/``worst``/
    ``yield``) and add ``best``, ``yield_lo``/``yield_hi`` (two-sided
    binomial bounds at ``confidence``, over the *effective* sample size),
    ``count``, ``n_eff`` and the interval config.  ``ci`` selects
    ``'wilson'`` (closed-form score interval) or ``'clopper-pearson'``
    (exact beta quantiles, scipy-gated).
    """
    w = max(float(state.w_sum), 1e-300)
    w2 = max(float(state.w2_sum), 1e-300)
    count = float(state.count)
    n_eff = w * w / w2 if count > 0 else 0.0
    mean = np.asarray(state.mean, np.float64)
    var = np.maximum(np.asarray(state.m2, np.float64), 0.0) / w
    p = np.clip(np.asarray(state.exceed, np.float64) / w, 0.0, 1.0)
    if ci == "clopper-pearson":
        lo, hi = clopper_pearson_bounds(p, n_eff, confidence)
    elif ci == "wilson":
        lo, hi = wilson_bounds(p, n_eff, confidence)
    else:
        raise ValueError(f"unknown ci method {ci!r}; "
                         "use 'wilson' or 'clopper-pearson'")
    amin = np.asarray(state.amin, np.float64)
    amax = np.asarray(state.amax, np.float64)
    return {
        "mean": mean,
        "std": np.sqrt(var),
        "worst": np.where(np.isfinite(amin), amin, np.nan),
        "best": np.where(np.isfinite(amax), amax, np.nan),
        "yield": p,
        "yield_lo": lo,
        "yield_hi": hi,
        "count": count,
        "n_eff": n_eff,
        "confidence": float(confidence),
        "ci": ci,
    }


# ---------------------------------------------------------------------------
# Quasi-Monte-Carlo chunk samplers (host side)
# ---------------------------------------------------------------------------

#: scipy's Sobol direction-number table tops out at this dimension.
SOBOL_MAX_DIM = 21201


class QMCSampler:
    """Deterministic uniform chunks over the reduced mismatch space.

    ``method='sobol'``: scrambled Sobol', rebuilt per chunk and
    ``fast_forward``-ed to the chunk's global start index — draw ``v``
    depends only on ``v`` (chunk-size invariant, exactly like the
    ``fold_in``-keyed iid stream) and on the scramble seed derived from
    the stored jax key data.

    ``method='stratified'``: per-chunk Latin hypercube (each chunk is a
    stratified design on its own; the stream is deterministic in
    ``(key, chunk start)`` but NOT chunk-size invariant — documented
    trade-off for dimensions beyond the Sobol table).
    """

    def __init__(self, method: str, dim: int, key_data) -> None:
        if method not in ("sobol", "stratified"):
            raise ValueError(f"unknown QMC method {method!r}")
        if dim <= 0:
            raise ValueError("QMC sampling needs at least one mismatch dim")
        if method == "sobol" and dim > SOBOL_MAX_DIM:
            raise ValueError(
                f"mismatch space has {dim} dims > Sobol table limit "
                f"{SOBOL_MAX_DIM}; use method='stratified' or 'iid'")
        try:
            from scipy.stats import qmc  # noqa: F401
        except ImportError as e:  # pragma: no cover - container has scipy
            raise RuntimeError(
                "QMC sampling needs scipy.stats.qmc; install scipy or use "
                "method='iid'") from e
        self.method = method
        self.dim = int(dim)
        kd = np.asarray(key_data, np.uint32).ravel()
        # Fold the key words into one 63-bit scramble seed.
        seed = 0
        for word in kd.tolist():
            seed = (seed * 1000003 + int(word)) % (2 ** 63 - 1)
        self.seed = int(seed)

    def chunk(self, start: int, size: int) -> np.ndarray:
        """Uniform ``(size, dim)`` f32 draws for global variants
        ``start .. start + size - 1``."""
        from scipy.stats import qmc

        if self.method == "sobol":
            eng = qmc.Sobol(d=self.dim, scramble=True, seed=self.seed)
            if start:
                eng.fast_forward(int(start))
            u = eng.random(int(size))
        else:
            eng = qmc.LatinHypercube(
                d=self.dim, seed=self.seed + 2 * int(start) + 1)
            u = eng.random(int(size))
        return np.asarray(u, np.float32)


def uniform_to_normal(u: jnp.ndarray) -> jnp.ndarray:
    """In-graph inverse-CDF transform, clipped away from {0, 1} so the
    tails stay finite in f32."""
    eps = jnp.float32(1e-7)
    return jax.scipy.special.ndtri(jnp.clip(u, eps, 1.0 - eps))
