"""One-vs-One multiclass SVM with encoder decision logic (paper Sec. II-A, III-C).

A K-class problem decomposes into K(K-1)/2 binary classifiers, one per
unordered class pair (c_i, c_j), i < j.  Each produces ONE bit:

    bit == 1  ->  the pair's FIRST class (c_i) wins
    bit == 0  ->  the pair's SECOND class (c_j) wins

Decision-making is an *encoder* (paper Fig. 1): the bit vector is mapped
directly to a class label, replacing counter+argmax circuitry.  Behaviorally
the encoder realises vote counting with a lowest-index tiebreak; we provide
both the behavioral decision (`decide_votes`, jit-able) and an explicit
truth-table builder (`build_encoder_table`, used by the hardware cost model
to size the encoder and by tests to prove encoder == votes).

The module also contains the *deployed* digital classifiers — the bespoke
fixed-point realizations whose outputs feed the encoder:

  * ``DigitalLinearClassifier``  — 4-bit ADC inputs x quantized hardwired
    weights, adder tree, bias, sign (paper Fig. 3).
  * ``DigitalRBFClassifier``     — the all-digital RBF baseline the paper
    compares against (quantized SVs/alphas, exact exp in fixed point).

Analog RBF classifiers (``repro.core.analog.AnalogBinaryClassifier``) plug in
through the same ``predict_bits`` protocol: analog-in, digital-out.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Protocol, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core import svm as svm_mod
from repro.core.svm import SVMModel
from repro.core import kernels as kern


def class_pairs(n_classes: int) -> list[tuple[int, int]]:
    """All OvO pairs (i, j), i < j — line 1 of Algorithm 1."""
    return list(itertools.combinations(range(n_classes), 2))


# ---------------------------------------------------------------------------
# Decision logic
# ---------------------------------------------------------------------------


def votes_from_bits(bits: np.ndarray, n_classes: int) -> np.ndarray:
    """bits (..., P) -> votes (..., K).  Pure counting semantics."""
    pairs = class_pairs(n_classes)
    votes = np.zeros(bits.shape[:-1] + (n_classes,), np.int32)
    for p, (i, j) in enumerate(pairs):
        votes[..., i] += bits[..., p]
        votes[..., j] += 1 - bits[..., p]
    return votes


def decide_votes(bits: np.ndarray, n_classes: int) -> np.ndarray:
    """Majority vote with lowest-index tiebreak (the encoder's semantics)."""
    return np.argmax(votes_from_bits(bits, n_classes), axis=-1)


def build_encoder_table(n_classes: int) -> np.ndarray:
    """Explicit truth table of the decision encoder: 2^P entries -> class id.

    This is the combinational function the paper hardwires (Fig. 1).  Entry
    index packs the pair bits little-endian (pair p is bit p).  Used by the
    cost model (literal counting) and by the encoder==votes equivalence test.
    Only practical for K <= 5 (P <= 10, 1024 entries) — exactly the FE regime.
    """
    pairs = class_pairs(n_classes)
    n_bits = len(pairs)
    table = np.zeros((1 << n_bits,), np.int32)
    for code in range(1 << n_bits):
        bits = np.array([(code >> p) & 1 for p in range(n_bits)], np.int32)
        table[code] = decide_votes(bits, n_classes)
    return table


def decide_encoder(bits: np.ndarray, table: np.ndarray) -> np.ndarray:
    """Run the hardware encoder: pack bits -> index the truth table."""
    n_bits = bits.shape[-1]
    weights = (1 << np.arange(n_bits)).astype(np.int64)
    codes = (bits.astype(np.int64) @ weights)
    return table[codes]


#: Packed-encoder regime bound: past P pair bits the 2^P truth table is
#: unbuildable, and every consumer (MulticlassSVM, the compiled machines,
#: the DSE) must route through votes or the DAG front instead.  Kept here —
#: the OvO layer — so ``api.compiled.MAX_TABLE_BITS`` and the streaming MC
#: engine share one definition of "the FE regime".
MAX_TABLE_BITS = 12


def pair_index_matrix(n_classes: int) -> np.ndarray:
    """(K, K) int32: ``[i, j] -> p`` with ``class_pairs(K)[p] == (i, j)``
    for i < j (symmetric; the diagonal is self-pairs and stays 0 — never a
    legal lookup).  Closed form of the ``itertools.combinations`` order:
    ``p = i*K - i*(i+1)/2 + (j - i - 1)``.
    """
    k = int(n_classes)
    m = np.zeros((k, k), np.int32)
    for p, (i, j) in enumerate(class_pairs(k)):
        m[i, j] = p
        m[j, i] = p
    return m


def decide_dag(bits: np.ndarray, n_classes: int) -> np.ndarray:
    """DDAG elimination decision (Platt et al.): host-side reference.

    Maintains a candidate interval ``[lo, hi]`` (initially the full class
    range) and, for exactly K-1 steps, consults the single pair classifier
    ``(lo, hi)``: bit == 1 means the pair's FIRST class (``lo``) wins, so
    ``hi`` is eliminated (``hi -= 1``); bit == 0 eliminates ``lo``
    (``lo += 1``).  After K-1 steps ``lo == hi`` is the label — only
    O(K) of the P = K(K-1)/2 bits are ever consulted.

    **Agreement contract** (tested in ``tests/test_dag.py``): whenever some
    class wins ALL K-1 of its pairs (a Condorcet winner — it then holds
    K-1 votes while every other class lost at least one pair and holds at
    most K-2), the DAG returns exactly ``decide_votes``'s answer: that
    class can never be eliminated (every pair involving it points its
    way), and it is the unique vote argmax, so the lowest-index tiebreak
    never fires.  Without a Condorcet winner (vote cycles, ties) the two
    fronts may differ: votes resolves by total count + lowest index, the
    DAG by its elimination path.  That residual disagreement is a measured
    quantity (reported per dataset in BENCH_9.json), not a silent one.
    """
    bits = np.asarray(bits)
    k = int(n_classes)
    pm = pair_index_matrix(k)
    lead = bits.shape[:-1]
    lo = np.zeros(lead, np.int64)
    hi = np.full(lead, k - 1, np.int64)
    for _ in range(k - 1):
        b = np.take_along_axis(bits, pm[lo, hi][..., None], axis=-1)[..., 0]
        hi = np.where(b == 1, hi - 1, hi)
        lo = np.where(b == 1, lo, lo + 1)
    return lo


def condorcet_mask(bits: np.ndarray, n_classes: int) -> np.ndarray:
    """Boolean mask of samples whose vote winner is unambiguous (some class
    won all K-1 of its pairs) — exactly where votes == DAG is guaranteed."""
    votes = votes_from_bits(bits, n_classes)
    return votes.max(axis=-1) == n_classes - 1


# ---------------------------------------------------------------------------
# Deployed digital classifiers (bit-producing, quantized datapaths)
# ---------------------------------------------------------------------------


class BitClassifier(Protocol):
    def predict_bits(self, x: np.ndarray) -> np.ndarray: ...


class FloatBitClassifier:
    """Adapter: float SVMModel -> 1-bit OvO output (c_i wins iff f >= 0)."""

    def __init__(self, model: SVMModel):
        self.model = model

    def predict_bits(self, x: np.ndarray) -> np.ndarray:
        return (svm_mod.decision_function(self.model, x) >= 0.0).astype(np.int32)


@dataclasses.dataclass(frozen=True)
class DigitalLinearClassifier:
    """Bespoke fully-parallel linear datapath (paper Fig. 3).

    ``w_q``/``b_q`` are the *dequantized* fixed-point constants hardwired in
    the multipliers; inputs pass through the ``input_bits`` ADC model.
    """

    w_q: np.ndarray          # (d,)
    b_q: float
    w_fp: quant.FixedPoint   # weight fixed-point format
    input_bits: int = 4

    @classmethod
    def deploy(
        cls, model: SVMModel, weight_bits: int = 8, input_bits: int = 4
    ) -> "DigitalLinearClassifier":
        if model.kind != "linear" or model.w is None:
            raise ValueError("only linear classifiers are deployed digitally")
        wb = np.concatenate([model.w, [model.bias]])
        wq, fp = quant.quantize_tensor(wb, weight_bits)
        return cls(w_q=wq[:-1], b_q=float(wq[-1]), w_fp=fp, input_bits=input_bits)

    def decision(self, x: np.ndarray) -> np.ndarray:
        xq = np.asarray(quant.quantize_unit(np.asarray(x), self.input_bits))
        return xq @ self.w_q + self.b_q

    def predict_bits(self, x: np.ndarray) -> np.ndarray:
        return (self.decision(x) >= 0.0).astype(np.int32)

    # -- hooks for the hardware cost model ---------------------------------
    def weight_codes(self) -> np.ndarray:
        return np.asarray(self.w_fp.codes(np.append(self.w_q, self.b_q)))

    @property
    def n_features(self) -> int:
        return int(self.w_q.shape[0])


@dataclasses.dataclass(frozen=True)
class DigitalRBFClassifier:
    """All-digital RBF baseline (paper Table II 'RBF (digital)').

    Support vectors and dual coefficients quantized "to ensure sufficient
    precision" (8-bit), inputs 4-bit; distance, exp and MACs computed exactly
    in fixed point (the digital exp unit is exact to output LSB).
    """

    support_x: np.ndarray    # (m, d) quantized
    coef: np.ndarray         # (m,) quantized alpha_j * y_j
    bias: float
    gamma: float
    sv_fp: quant.FixedPoint
    coef_fp: quant.FixedPoint
    input_bits: int = 4

    @classmethod
    def deploy(
        cls, model: SVMModel, sv_bits: int = 8, coef_bits: int = 8,
        input_bits: int = 4,
    ) -> "DigitalRBFClassifier":
        if model.kind != "rbf":
            raise ValueError("expected an RBF model")
        svq, sv_fp = quant.quantize_tensor(model.support_x, sv_bits)
        coef = model.alpha * model.support_y
        coefq, coef_fp = quant.quantize_tensor(
            np.concatenate([coef, [model.bias]]), coef_bits
        )
        return cls(
            support_x=svq, coef=coefq[:-1], bias=float(coefq[-1]),
            gamma=model.gamma, sv_fp=sv_fp, coef_fp=coef_fp,
            input_bits=input_bits,
        )

    def decision(self, x: np.ndarray) -> np.ndarray:
        xq = jnp.asarray(quant.quantize_unit(np.asarray(x), self.input_bits))
        k = kern.rbf_kernel(
            xq.astype(jnp.float32), jnp.asarray(self.support_x, jnp.float32),
            jnp.float32(self.gamma),
        )
        return np.asarray(k @ jnp.asarray(self.coef, jnp.float32)) + self.bias

    def predict_bits(self, x: np.ndarray) -> np.ndarray:
        return (self.decision(x) >= 0.0).astype(np.int32)

    @property
    def n_support(self) -> int:
        return int(self.support_x.shape[0])

    @property
    def n_features(self) -> int:
        return int(self.support_x.shape[1])


# ---------------------------------------------------------------------------
# The full multiclass machine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MulticlassSVM:
    """K-class OvO SVM: a bank of bit classifiers + the decision encoder."""

    n_classes: int
    classifiers: Sequence[BitClassifier]   # one per class_pairs(n_classes)
    kernel_map: Sequence[str]              # 'linear' | 'rbf' per pair

    def __post_init__(self):
        assert len(self.classifiers) == len(class_pairs(self.n_classes))
        # The 2^P packed table only exists in the FE regime; past it the
        # machine decides by the equivalent vote counting (decide_votes) —
        # building the table at K=12 (P=66) would be a 2^66 blowup.
        self._table = (build_encoder_table(self.n_classes)
                       if len(class_pairs(self.n_classes)) <= MAX_TABLE_BITS
                       else None)

    def predict_bits(self, x: np.ndarray) -> np.ndarray:
        return np.stack([c.predict_bits(x) for c in self.classifiers], axis=-1)

    def predict(self, x: np.ndarray) -> np.ndarray:
        bits = self.predict_bits(x)
        if self._table is None:
            return decide_votes(bits, self.n_classes)
        return decide_encoder(bits, self._table)

    def accuracy(self, x: np.ndarray, y: np.ndarray) -> float:
        return float(np.mean(self.predict(x) == np.asarray(y)))

    @property
    def n_rbf(self) -> int:
        return sum(k == "rbf" for k in self.kernel_map)

    @property
    def n_linear(self) -> int:
        return sum(k == "linear" for k in self.kernel_map)
