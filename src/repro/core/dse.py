"""Batched kernel-assignment design-space exploration (DESIGN.md §5).

The paper's co-optimization maps each OvO pair to a kernel/domain —
linear-digital or RBF-analog — to maximize accuracy while minimizing the
costly RBF classifiers.  Algorithm 1 realizes ONE point of that space (the
greedy ``tie_margin`` rule); this module explores the whole space as three
vectorized passes over an ``(S, P)`` boolean assignment matrix:

1. **Bits** — per-pair comparator bits are assignment-independent, so the
   ``CandidateMachine`` (``repro.api.compiled``) evaluates both candidates
   of every pair once: ``pair_bits(x) -> (n, P, 2)``.  One jit compile.

2. **Accuracy** — every candidate assignment is a *bit-recombination*:
   with the packed encoder table, an assignment's label codes are

       ``codes[s] = lin_bits @ w  +  ((rbf_bits - lin_bits) * w) @ A[s]``

   (``w = 2^p`` the encoder bit weights), i.e. one integer GEMM scores ALL
   ``S`` assignments against the validation set.  One more jit compile —
   exhaustive ``2^P`` for the FE regime ``P <= 12``, seeded greedy/flip
   search beyond.

3. **Cost** — ``hwcost.assignment_costs`` prices the same matrix in one
   numpy pass from the per-pair candidate cost table.

``pareto_front`` reduces the swept points to the accuracy/area/power
non-dominated set; ``SweepResult.select`` picks the cheapest front point
meeting an area/power budget (the deployment rule behind
``MixedKernelSVM.deploy(..., area_budget=..., power_budget=...)``).

Monte-Carlo variation (DESIGN.md §6): with a ``MonteCarloMachine`` the
candidate bit tensor gains a leading variant axis ``(V, n, P, 2)`` and the
SAME bit-recombination GEMM, vmapped over it
(``assignment_accuracies_mc``), scores every (variant, assignment) cell in
one program.  Each assignment then carries mean/std/worst-case accuracy
and **yield** — the fraction of fabricated instances meeting an accuracy
floor — ``pareto_front`` gains a robust four-objective mode, and
``SweepResult.select(yield_floor=...)`` picks the cheapest budget-feasible
design meeting the yield spec (the rule behind
``MixedKernelSVM.deploy(..., yield_floor=...)``).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hwcost, mcstream
from repro.core.ovo import build_encoder_table, class_pairs

#: Exhaustive enumeration bound: 2^12 = 4096 assignments, matching the
#: packed-encoder-table regime of the compiled machine (MAX_TABLE_BITS).
MAX_EXHAUSTIVE_PAIRS = 12

#: Assignment chunk of the votes-matmul fallback (P > MAX_EXHAUSTIVE_PAIRS):
#: bounds the (n, CHUNK, P) selected-bits tensor.
VOTES_CHUNK = 256

#: Pair-axis chunk of the streamed votes recombination: bounds the
#: selected-bits temporary at ``(B, n, VOTES_PAIR_CHUNK)`` per assignment
#: row, so memory never scales with P (flat at P = 66).
VOTES_PAIR_CHUNK = 16


def assignment_from_kernel_map(kernel_map: Sequence[str]) -> np.ndarray:
    """``['linear'|'rbf', ...] -> (P,) bool`` (True = RBF candidate)."""
    return np.asarray([k == "rbf" for k in kernel_map], bool)


def kernel_map_from_assignment(assignment: np.ndarray) -> list[str]:
    return ["rbf" if a else "linear" for a in np.asarray(assignment, bool)]


def enumerate_assignments(n_pairs: int) -> np.ndarray:
    """All ``2^P`` assignments, row ``s`` has pair ``p`` RBF iff bit ``p``
    of ``s`` is set (little-endian, matching the encoder bit packing)."""
    if n_pairs > MAX_EXHAUSTIVE_PAIRS:
        raise ValueError(
            f"refusing to enumerate 2^{n_pairs} assignments "
            f"(> 2^{MAX_EXHAUSTIVE_PAIRS}); use the seeded search")
    s = np.arange(1 << n_pairs, dtype=np.int64)
    return ((s[:, None] >> np.arange(n_pairs)) & 1).astype(bool)


# ---------------------------------------------------------------------------
# The jitted sweep programs
# ---------------------------------------------------------------------------


def _encoder_accuracy(bits2, assignments, y, table, weights):
    """Accuracy of ALL assignments through the packed encoder table.

    ``bits2 (n, P, 2)`` int32, ``assignments (S, P)`` int32, ``y (n,)``
    int32, ``table (2^P,)`` int32, ``weights (P,)`` int32 -> ``(S,)`` f32.
    Pure bit-recombination: the linear-candidate code is the base, each
    RBF-assigned pair contributes the (rbf - lin) bit delta at its encoder
    weight — one (n, P) x (P, S) integer GEMM recodes the whole space.
    """
    lin = bits2[:, :, 0]
    diff = (bits2[:, :, 1] - lin) * weights[None, :]       # (n, P)
    codes = (lin @ weights)[:, None] + diff @ assignments.T  # (n, S)
    labels = jnp.take(table, codes)
    return jnp.mean((labels == y[:, None]).astype(jnp.float32), axis=0)


def _votes_accuracy(bits2, assignments, y, vote_a, vote_b):
    """Votes-matmul fallback for machines beyond the encoder-table regime.

    Materializes the selected bits ``(n, S, P)`` — callers chunk the
    assignment axis (``VOTES_CHUNK``) to bound the tensor.
    """
    sel = jnp.where(assignments[None, :, :] == 1,
                    bits2[:, None, :, 1], bits2[:, None, :, 0])
    votes = sel @ vote_a + (1 - sel) @ vote_b               # (n, S, K)
    labels = jnp.argmax(votes, axis=-1)                     # lowest-index tie
    return jnp.mean((labels == y[:, None]).astype(jnp.float32), axis=0)


def _votes_accuracy_paired(bits4, assignments, y, vote_a, vote_b,
                           *, p_chunk: int = VOTES_PAIR_CHUNK):
    """Pair-chunked votes recombination: ``bits4 (B, n, P, 2) -> (B, S)``.

    The flat-memory sibling of ``_votes_accuracy`` for the streaming and
    Monte-Carlo engines: instead of materializing a ``(B, n, S, P)``
    selected-bits tensor, the PAIR axis is folded ``p_chunk`` columns at a
    time into a ``(B, n, K)`` vote accumulator (one ``lax.map`` row per
    assignment, one ``fori_loop`` over pair chunks inside it).  Peak
    temporaries are ``(B, n, p_chunk)`` + the accumulator — independent of
    both S and P.  The pair tail is zero-padded: padded vote rows are
    all-zero, so padded selections are inert regardless of bit values.
    Argmax keeps the lowest-index tiebreak of ``ovo.decide_votes``.
    """
    b, n, p_total = bits4.shape[:3]
    k = vote_a.shape[1]
    lin = bits4[..., 0].astype(jnp.float32)
    rbf = bits4[..., 1].astype(jnp.float32)
    va = vote_a.astype(jnp.float32)
    vb = vote_b.astype(jnp.float32)
    a = assignments
    pad = -p_total % p_chunk
    if pad:
        lin = jnp.pad(lin, ((0, 0), (0, 0), (0, pad)))
        rbf = jnp.pad(rbf, ((0, 0), (0, 0), (0, pad)))
        va = jnp.pad(va, ((0, pad), (0, 0)))
        vb = jnp.pad(vb, ((0, pad), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad)))
    n_chunks = (p_total + pad) // p_chunk
    yy = y[None, :]

    def one(a_row):
        def fold(c, votes):
            lo = c * p_chunk
            lc = jax.lax.dynamic_slice_in_dim(lin, lo, p_chunk, axis=2)
            rc = jax.lax.dynamic_slice_in_dim(rbf, lo, p_chunk, axis=2)
            ac = jax.lax.dynamic_slice_in_dim(a_row, lo, p_chunk)
            sel = jnp.where(ac[None, None, :] == 1, rc, lc)
            vac = jax.lax.dynamic_slice_in_dim(va, lo, p_chunk, axis=0)
            vbc = jax.lax.dynamic_slice_in_dim(vb, lo, p_chunk, axis=0)
            return votes + sel @ vac + (1.0 - sel) @ vbc

        votes = jax.lax.fori_loop(
            0, n_chunks, fold, jnp.zeros((b, n, k), jnp.float32))
        labels = jnp.argmax(votes, axis=-1)                # lowest-index tie
        return jnp.mean((labels == yy).astype(jnp.float32), axis=1)

    return jnp.moveaxis(jax.lax.map(one, a), 0, 1)         # (B, S)


_sweep_encoder = jax.jit(_encoder_accuracy)
_sweep_votes = jax.jit(_votes_accuracy)
_sweep_votes_paired = jax.jit(_votes_accuracy_paired,
                              static_argnames=("p_chunk",))

#: The Monte-Carlo programs vmap the SAME recombination bodies over a
#: leading variant axis of the bit tensor: ``bits3 (V, n, P, 2) -> (V, S)``.
#: One extra jit compile each — the second of the "<= 2 additional
#: compiles" budget of the variant axis (the first is the MC forward).
_sweep_encoder_mc = jax.jit(
    jax.vmap(_encoder_accuracy, in_axes=(0, None, None, None, None)))
_sweep_votes_mc = jax.jit(
    jax.vmap(_votes_accuracy, in_axes=(0, None, None, None, None)))


def _vote_matrices(n_classes: int) -> tuple[np.ndarray, np.ndarray]:
    pairs = class_pairs(n_classes)
    a = np.zeros((len(pairs), n_classes), np.int32)
    b = np.zeros((len(pairs), n_classes), np.int32)
    for p, (i, j) in enumerate(pairs):
        a[p, i] = 1
        b[p, j] = 1
    return a, b


def assignment_accuracies(
    bits2: np.ndarray,
    assignments: np.ndarray,
    y: np.ndarray,
    n_classes: int,
    max_table_bits: int = MAX_EXHAUSTIVE_PAIRS,
    chunk: Optional[int] = None,
) -> np.ndarray:
    """Validation accuracy of every assignment: ``(S,)`` float64.

    ``bits2`` is the ``(n, P, 2)`` candidate-bit tensor of
    ``CandidateMachine.pair_bits``.  For ``P <= max_table_bits`` the packed
    encoder table scores all assignments in one program; beyond that the
    votes matmul runs over ``chunk``-sized assignment chunks (default
    :data:`VOTES_CHUNK`; the portfolio search passes a smaller chunk so
    its P-sized flip batches are not padded 4x).
    """
    bits2 = np.asarray(bits2, np.int32)
    a = np.atleast_2d(np.asarray(assignments)).astype(np.int32)
    y = np.asarray(y, np.int32)
    n_pairs = bits2.shape[1]
    if a.shape[1] != n_pairs:
        raise ValueError(
            f"assignments have {a.shape[1]} pairs, bits tensor has {n_pairs}")
    if n_pairs <= max_table_bits:
        table = build_encoder_table(n_classes)
        weights = (1 << np.arange(n_pairs)).astype(np.int32)
        acc = _sweep_encoder(bits2, a, y, jnp.asarray(table),
                             jnp.asarray(weights))
        return np.asarray(acc, np.float64)
    if chunk is None:
        chunk = VOTES_CHUNK
    va, vb = _vote_matrices(n_classes)
    va, vb = jnp.asarray(va), jnp.asarray(vb)
    out = np.empty(a.shape[0], np.float64)
    # Fixed-size chunks (tail padded with row 0) keep one compiled shape.
    for lo in range(0, a.shape[0], chunk):
        block = a[lo: lo + chunk]
        pad = chunk - block.shape[0]
        if pad:
            block = np.concatenate([block, np.repeat(a[:1], pad, axis=0)])
        acc = np.asarray(_sweep_votes(bits2, block, y, va, vb))
        out[lo: lo + chunk] = acc[: chunk - pad or None]
    return out


#: Default assignment chunk of the Monte-Carlo encoder sweep: bounds the
#: ``(V, n, CHUNK)`` codes tensor when the variant axis multiplies the
#: exhaustive space (64 x 400 x 512 int32 ~ 50 MB).  A config knob, not a
#: law: callers pass ``mc_chunk=`` to ``assignment_accuracies_mc`` (and
#: through ``MixedKernelSVM.monte_carlo(mc_chunk=)``) to trade the
#: in-graph codes-tensor footprint against per-chunk launch overhead.
MC_CHUNK = 512


@functools.partial(jax.jit, static_argnames=("mc_chunk",))
def _sweep_encoder_mc_chunked(bits3, a, y, table, weights, *, mc_chunk):
    """The MC encoder sweep with the assignment axis chunked IN-GRAPH.

    Replaces the old host-side chunk loop (per-chunk ``np.concatenate``
    padding + one device dispatch per chunk): the pad-to-multiple copy and
    the chunk iteration now live inside ONE jitted program.  ``lax.map``
    runs the chunks sequentially with a loop-carried output buffer, so the
    live codes tensor stays ``(V, n, mc_chunk)`` — the same memory bound
    as before, minus S/mc_chunk host round-trips.  (Donating ``a`` here
    would be dropped by XLA — no output shares its shape/dtype — so the
    buffer-reuse story is the ``lax.map`` carry, not argument donation.)
    """
    s = a.shape[0]
    pad = -s % mc_chunk
    if pad:
        a = jnp.concatenate(
            [a, jnp.broadcast_to(a[:1], (pad,) + a.shape[1:])])
    chunks = a.reshape(-1, mc_chunk, a.shape[1])      # (n_chunks, C, P)

    def one(chunk):
        return jax.vmap(_encoder_accuracy,
                        in_axes=(0, None, None, None, None))(
            bits3, chunk, y, table, weights)          # (V, C)

    acc = jax.lax.map(one, chunks)                    # (n_chunks, V, C)
    return jnp.moveaxis(acc, 1, 0).reshape(bits3.shape[0], -1)[:, :s]


def assignment_accuracies_mc(
    bits3: np.ndarray,
    assignments: np.ndarray,
    y: np.ndarray,
    n_classes: int,
    max_table_bits: int = MAX_EXHAUSTIVE_PAIRS,
    mc_chunk: Optional[int] = None,
) -> np.ndarray:
    """Validation accuracy of every (variant, assignment): ``(V, S)`` f64.

    ``bits3`` is the ``(V, n, P, 2)`` per-variant candidate-bit tensor of
    ``MonteCarloMachine.pair_bits``.  The bit-recombination GEMM is batched
    over the leading variant axis — ONE jitted program scores the whole
    ``V x S`` grid.  Beyond ``mc_chunk`` assignments (default
    :data:`MC_CHUNK`) the assignment axis is chunked *inside* the program
    (``_sweep_encoder_mc_chunked``) to bound the codes tensor.
    """
    bits3 = np.asarray(bits3, np.int32)
    if bits3.ndim != 4:
        raise ValueError(f"bits3 must be (V, n, P, 2), got {bits3.shape}")
    a = np.atleast_2d(np.asarray(assignments)).astype(np.int32)
    y = np.asarray(y, np.int32)
    n_pairs = bits3.shape[2]
    if a.shape[1] != n_pairs:
        raise ValueError(
            f"assignments have {a.shape[1]} pairs, bits tensor has {n_pairs}")
    if mc_chunk is None:
        mc_chunk = MC_CHUNK
    if mc_chunk < 1:
        raise ValueError(f"mc_chunk must be >= 1, got {mc_chunk}")
    if n_pairs <= max_table_bits:
        table = jnp.asarray(build_encoder_table(n_classes))
        weights = jnp.asarray((1 << np.arange(n_pairs)).astype(np.int32))
        if a.shape[0] <= mc_chunk:
            return np.asarray(
                _sweep_encoder_mc(bits3, a, y, table, weights), np.float64)
        return np.asarray(
            _sweep_encoder_mc_chunked(bits3, a, y, table, weights,
                                      mc_chunk=mc_chunk), np.float64)
    va, vb = _vote_matrices(n_classes)
    va, vb = jnp.asarray(va), jnp.asarray(vb)
    # Pair-chunked recombination: the selected-bits temporary is bounded
    # at (V, n, VOTES_PAIR_CHUNK) per assignment row — flat in both S and
    # P, where the old vmapped-votes chunking shrank the assignment chunk
    # by V and still scaled with P.
    return np.asarray(
        _sweep_votes_paired(bits3, a, y, va, vb), np.float64)


def mc_statistics(
    acc_vs: np.ndarray,
    accuracy_floor: float,
    confidence: float = mcstream.DEFAULT_CONFIDENCE,
    ci: str = "wilson",
) -> dict:
    """Per-assignment robustness statistics over the variant axis.

    ``acc_vs (V, S)`` -> dict of ``(S,)`` arrays: ``mean``, ``std``
    (population), ``worst`` (min over variants), ``yield`` — the fraction
    of variants whose accuracy meets ``accuracy_floor`` — and
    ``yield_lo``/``yield_hi``, the two-sided binomial bounds on that
    fraction at ``confidence`` (``ci``: ``'wilson'`` score interval or the
    exact ``'clopper-pearson'``).  The bounds are what keep a V=64 run
    honest: a point-estimate yield of 1.0 over 64 draws is compatible with
    a true yield of ~0.94, and the interval says so.
    """
    acc_vs = np.asarray(acc_vs, np.float64)
    p = (acc_vs >= accuracy_floor).mean(axis=0)
    n = acc_vs.shape[0]
    if ci == "clopper-pearson":
        lo, hi = mcstream.clopper_pearson_bounds(p, n, confidence)
    else:
        lo, hi = mcstream.wilson_bounds(p, n, confidence)
    return {
        "mean": acc_vs.mean(axis=0),
        "std": acc_vs.std(axis=0),
        "worst": acc_vs.min(axis=0),
        "yield": p,
        "yield_lo": lo,
        "yield_hi": hi,
        "confidence": float(confidence),
    }


# ---------------------------------------------------------------------------
# Pareto reduction and budget selection
# ---------------------------------------------------------------------------


def pareto_front(
    accuracy: np.ndarray,
    area: np.ndarray,
    power: np.ndarray,
    yield_: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Indices of the non-dominated points (max accuracy, min area/power),
    sorted by ascending area.  A point is dominated if another is at least
    as good on all objectives and strictly better on one.

    Robust mode: passing the per-assignment ``yield_`` of a Monte-Carlo
    sweep adds a fourth maximized objective, so a design that trades a
    little mean accuracy for a markedly higher fraction of in-spec
    fabricated instances stays on the front.
    """
    acc = np.asarray(accuracy, np.float64)
    ar = np.asarray(area, np.float64)
    pw = np.asarray(power, np.float64)
    yd = None if yield_ is None else np.asarray(yield_, np.float64)
    n = acc.shape[0]
    keep = np.ones(n, bool)
    # Chunked O(S^2) bool reduction: at S = 4096 this is a handful of
    # 16M-entry byte matrices — milliseconds, no compile.
    chunk = 1024
    for lo in range(0, n, chunk):
        sl = slice(lo, min(lo + chunk, n))
        ge = (acc[None, :] >= acc[sl, None]) \
            & (ar[None, :] <= ar[sl, None]) \
            & (pw[None, :] <= pw[sl, None])
        strict = (acc[None, :] > acc[sl, None]) | \
            (ar[None, :] < ar[sl, None]) | (pw[None, :] < pw[sl, None])
        if yd is not None:
            ge &= yd[None, :] >= yd[sl, None]
            strict |= yd[None, :] > yd[sl, None]
        keep[sl] &= ~(ge & strict).any(axis=1)
    idx = np.flatnonzero(keep)
    return idx[np.argsort(ar[idx], kind="stable")]


@dataclasses.dataclass
class SweepResult:
    """Evaluated design points of one DSE sweep + their Pareto front.

    The Monte-Carlo fields are populated only by variation-aware sweeps
    (``DesignSpace.sweep(mc_machine=...)``): ``accuracy`` then holds the
    *nominal* (zero-offset variant) accuracy, ``accuracy_mc`` the full
    ``(V, S)`` per-variant matrix, and ``acc_mean``/``acc_std``/
    ``acc_worst``/``yield_`` its per-assignment statistics (``yield_`` =
    fraction of variants at or above ``accuracy_floor``).  ``front`` stays
    the nominal three-objective front; ``robust_front`` is the
    four-objective (mean accuracy, area, power, yield) front.
    """

    assignments: np.ndarray   # (S, P) bool — True: pair on the RBF candidate
    accuracy: np.ndarray      # (S,) validation accuracy
    area: np.ndarray          # (S,) mm^2
    power: np.ndarray         # (S,) mW
    front: np.ndarray         # indices of the non-dominated set, area-sorted
    n_classes: int
    exhaustive: bool          # full 2^P enumeration vs seeded search
    elapsed_s: float
    assignments_per_s: float
    # -- Monte-Carlo robustness (None on nominal sweeps) --------------------
    accuracy_mc: Optional[np.ndarray] = None   # (V, S) per-variant accuracy
    acc_mean: Optional[np.ndarray] = None      # (S,)
    acc_std: Optional[np.ndarray] = None       # (S,)
    acc_worst: Optional[np.ndarray] = None     # (S,)
    yield_: Optional[np.ndarray] = None        # (S,) frac >= accuracy_floor
    yield_lo_: Optional[np.ndarray] = None     # (S,) binomial LCB on yield
    yield_hi_: Optional[np.ndarray] = None     # (S,) binomial UCB on yield
    confidence: Optional[float] = None         # two-sided CI level
    accuracy_floor: Optional[float] = None
    n_variants: Optional[int] = None
    sigma_scale: Optional[float] = None
    mc_key_data: Optional[np.ndarray] = None   # raw jax PRNG key data
    robust_front: Optional[np.ndarray] = None  # 4-objective front indices

    @property
    def n_pairs(self) -> int:
        return int(self.assignments.shape[1])

    @property
    def is_monte_carlo(self) -> bool:
        return self.accuracy_mc is not None

    def yield_at(self, accuracy_floor: float) -> np.ndarray:
        """Per-assignment yield against an ad-hoc floor (MC sweeps only)."""
        if not self.is_monte_carlo:
            raise RuntimeError("yield_at requires a Monte-Carlo sweep")
        return (np.asarray(self.accuracy_mc, np.float64)
                >= accuracy_floor).mean(axis=0)

    def kernel_map(self, i: int) -> list[str]:
        return kernel_map_from_assignment(self.assignments[i])

    def find(self, assignment: np.ndarray) -> Optional[int]:
        """Row index of ``assignment`` among the swept points (None if the
        search never visited it)."""
        a = np.asarray(assignment, bool)
        hit = np.flatnonzero((self.assignments == a[None, :]).all(axis=1))
        return int(hit[0]) if hit.size else None

    def domination_margin(self, assignment: np.ndarray) -> float:
        """How much accuracy a no-costlier design gains over ``assignment``.

        max over swept points with area <= and power <= the given point of
        (their accuracy - its accuracy); <= 0 means the point is
        undominated.  The CI gate asserts the Algorithm-1 machine's margin
        stays within the selection tie-epsilon.
        """
        i = self.find(assignment)
        if i is None:
            raise ValueError("assignment was not visited by this sweep")
        cheaper = (self.area <= self.area[i]) & (self.power <= self.power[i])
        return float(np.max(self.accuracy[cheaper]) - self.accuracy[i])

    def select(
        self,
        area_budget: Optional[float] = None,
        power_budget: Optional[float] = None,
        yield_floor: Optional[float] = None,
        confidence: Optional[float] = None,
    ) -> int:
        """Deployment rule.

        Nominal (``yield_floor=None``): the most accurate Pareto point
        within budget, ties broken toward lower area then lower power.

        Robust (``yield_floor=``, requires a Monte-Carlo sweep): the
        CHEAPEST budget-feasible point of the robust front whose yield —
        fraction of fabricated instances at or above the sweep's
        ``accuracy_floor`` — meets the floor; ties broken toward lower
        power then higher mean accuracy.  The different objective order is
        deliberate: once the yield spec is met, a flexible-electronics
        deployment is cost-driven.

        ``confidence``: None (default) gates on the point-estimate yield —
        the historical rule.  A float (e.g. 0.95) gates on the Wilson
        *lower confidence bound* at that level instead, so a small-V sweep
        cannot clear a floor its sample size does not statistically
        support (``MixedKernelSVM.deploy`` passes this by default).
        """
        if yield_floor is None:
            idx = self.front
            ok = np.ones(idx.shape[0], bool)
            if area_budget is not None:
                ok &= self.area[idx] <= area_budget
            if power_budget is not None:
                ok &= self.power[idx] <= power_budget
            if not ok.any():
                cheapest = idx[np.argmin(self.area[idx])]
                raise ValueError(
                    "no Pareto point meets the budget (cheapest front "
                    f"point: area {self.area[cheapest]:.4f} mm^2, power "
                    f"{self.power[cheapest]:.4f} mW)")
            cand = idx[ok]
            order = np.lexsort((self.power[cand], self.area[cand],
                                -self.accuracy[cand]))
            return int(cand[order[0]])
        if not self.is_monte_carlo:
            raise RuntimeError(
                "select(yield_floor=...) needs a Monte-Carlo sweep: run "
                "DesignSpace.sweep(mc_machine=...) / "
                "est.pareto(..., n_variants=...) first")
        idx = self.robust_front
        if confidence is None:
            gate = self.yield_
        else:
            gate, _ = mcstream.wilson_bounds(
                self.yield_, int(self.n_variants), confidence)
        ok = gate[idx] >= yield_floor
        if area_budget is not None:
            ok &= self.area[idx] <= area_budget
        if power_budget is not None:
            ok &= self.power[idx] <= power_budget
        if not ok.any():
            best = idx[np.argmax(gate[idx])]
            bound = ("yield" if confidence is None
                     else f"yield {confidence:.0%}-LCB")
            raise ValueError(
                f"no robust-front point meets {bound} >= {yield_floor} "
                f"within budget (best available {bound} "
                f"{gate[best]:.3f} from {self.n_variants} variants at "
                f"accuracy floor {self.accuracy_floor}, area "
                f"{self.area[best]:.4f} mm^2)")
        cand = idx[ok]
        order = np.lexsort((-self.acc_mean[cand], self.power[cand],
                            self.area[cand]))
        return int(cand[order[0]])

    def front_points(self, robust: bool = False) -> list[dict]:
        """JSON-friendly view of the front (benchmarks/pareto.py,
        benchmarks/montecarlo.py).  ``robust=True`` walks the
        four-objective robust front of a Monte-Carlo sweep instead."""
        idx = self.robust_front if robust else self.front
        out = []
        for i in idx:
            entry = {
                "kernel_map": self.kernel_map(i),
                "n_rbf": int(self.assignments[i].sum()),
                "accuracy": float(self.accuracy[i]),
                "area_mm2": float(self.area[i]),
                "power_mw": float(self.power[i]),
            }
            if self.is_monte_carlo:
                entry.update(
                    acc_mean=float(self.acc_mean[i]),
                    acc_std=float(self.acc_std[i]),
                    acc_worst=float(self.acc_worst[i]),
                    yield_frac=float(self.yield_[i]),
                )
                if self.yield_lo_ is not None:
                    entry.update(yield_lo=float(self.yield_lo_[i]),
                                 yield_hi=float(self.yield_hi_[i]))
            out.append(entry)
        return out


# ---------------------------------------------------------------------------
# Seeded greedy/flip search (beyond the exhaustive regime)
# ---------------------------------------------------------------------------


#: Compiled assignment-chunk of the portfolio search's evaluations (the
#: search submits P-sized flip batches and W-sized walker batches — a
#: full VOTES_CHUNK pad would waste 4x compute per call at P = 66).
SEARCH_CHUNK = 64

#: Front-polish cap: at most this many archive-front points get their full
#: Hamming-1 neighborhood evaluated in the final portfolio stage.
POLISH_FRONT_CAP = 24


def _search_assignments(
    bits2: np.ndarray,
    y: np.ndarray,
    cost_table: hwcost.PairCostTable,
    n_classes: int,
    seeds: Optional[np.ndarray],
    n_random: int,
    rng_seed: int,
    max_rounds: int,
    n_anneal: int = 8,
    anneal_steps: int = 96,
) -> tuple[np.ndarray, np.ndarray]:
    """Seeded search portfolio: greedy/flip + annealing + front polish.

    Three stages over the same scalarized objective (accuracy minus
    ``lam`` x normalized cost; ``lam = 0`` is pure accuracy), all feeding
    ONE deduplicating archive that the caller prices and Pareto-reduces:

    1. **Greedy/flip** — steepest-ascent over single-pair flips from the
       seeded starts (all-linear / all-RBF corners, the caller's seeds —
       typically the Algorithm-1 assignment — and ``n_random`` random
       draws), once per lambda of a small ladder.
    2. **Annealing** — ``n_anneal`` Metropolis walkers stepping in
       lockstep (one batched evaluation per step) under a geometric
       temperature schedule: escapes the single-flip local optima stage 1
       terminates in.
    3. **Front polish** — the archive's accuracy/area/power Pareto front
       (capped at :data:`POLISH_FRONT_CAP` points per round) gets its
       full Hamming-1 neighborhood evaluated, repeated until the front
       stops growing: every returned front point is a verified local
       optimum in all objectives, and on small spaces the closure walks
       the front to the exhaustive one.

    Deterministic given ``rng_seed``.  Returns ``(assignments,
    accuracies)`` for the whole archive.
    """
    p = bits2.shape[1]
    rng = np.random.RandomState(rng_seed)
    starts = [np.zeros(p, bool), np.ones(p, bool)]
    if seeds is not None:
        starts += [np.asarray(s, bool) for s in np.atleast_2d(seeds)]
    starts += [rng.rand(p) < 0.5 for _ in range(n_random)]

    archive: dict[bytes, float] = {}

    def evaluate(batch: np.ndarray) -> np.ndarray:
        fresh = [a for a in batch if a.tobytes() not in archive]
        if fresh:
            accs = assignment_accuracies(bits2, np.stack(fresh), y,
                                         n_classes, chunk=SEARCH_CHUNK)
            for a, acc in zip(fresh, accs):
                archive[a.tobytes()] = float(acc)
        return np.asarray([archive[a.tobytes()] for a in batch])

    # Cost normalization: the all-linear corner anchors the scale.
    a_all, p_all = hwcost.assignment_costs(
        cost_table, np.stack([np.zeros(p, bool), np.ones(p, bool)]))
    a_ref = max(a_all.max(), 1e-12)
    p_ref = max(p_all.max(), 1e-12)

    def scores(batch: np.ndarray, lam: float) -> np.ndarray:
        acc = evaluate(batch)
        ar, pw = hwcost.assignment_costs(cost_table, batch)
        return acc - lam * 0.5 * (ar / a_ref + pw / p_ref)

    for lam in (0.0, 0.05, 0.25, 1.0):
        for start in starts:
            cur = np.asarray(start, bool).copy()
            cur_score = float(scores(cur[None, :], lam)[0])
            for _ in range(max_rounds):
                flips = np.repeat(cur[None, :], p, axis=0)
                flips[np.arange(p), np.arange(p)] ^= True
                s = scores(flips, lam)
                best = int(np.argmax(s))
                if s[best] <= cur_score + 1e-12:
                    break
                cur, cur_score = flips[best], float(s[best])

    if n_anneal > 0 and anneal_steps > 0:
        t0, t1 = 2e-2, 1e-3
        for lam in (0.0, 0.25):
            cur = np.stack([starts[i % len(starts)].copy()
                            for i in range(n_anneal)]).astype(bool)
            # Half the walkers restart from fresh random corners so the
            # two lambda passes do not retrace identical trajectories.
            for i in range(n_anneal // 2, n_anneal):
                cur[i] = rng.rand(p) < 0.5
            cur_s = scores(cur, lam)
            for t in range(anneal_steps):
                temp = t0 * (t1 / t0) ** (t / max(anneal_steps - 1, 1))
                flip = rng.randint(0, p, n_anneal)
                prop = cur.copy()
                prop[np.arange(n_anneal), flip] ^= True
                prop_s = scores(prop, lam)
                accept = (prop_s > cur_s) | (
                    rng.rand(n_anneal) < np.exp(
                        np.minimum(prop_s - cur_s, 0.0) / temp))
                cur[accept] = prop[accept]
                cur_s[accept] = prop_s[accept]

    expanded: set[bytes] = set()
    for _ in range(16):  # closure bound; each round must expand new points
        pts = np.stack([np.frombuffer(k, bool) for k in archive])
        acc = np.asarray([archive[a.tobytes()] for a in pts])
        ar, pw = hwcost.assignment_costs(cost_table, pts)
        front = pareto_front(acc, ar, pw)
        todo = [i for i in front if pts[i].tobytes() not in expanded]
        if not todo:
            break
        todo = sorted(todo, key=lambda i: -acc[i])[:POLISH_FRONT_CAP]
        for i in todo:
            expanded.add(pts[i].tobytes())
            flips = np.repeat(pts[i][None, :], p, axis=0)
            flips[np.arange(p), np.arange(p)] ^= True
            evaluate(flips)

    out = np.stack([np.frombuffer(k, bool) for k in archive])
    return out, np.asarray([archive[a.tobytes()] for a in out])


# ---------------------------------------------------------------------------
# The design space
# ---------------------------------------------------------------------------


class DesignSpace:
    """P candidate pairs as one batched, compiled design space.

    Couples the assignment-independent bit machine (layer 2) with the
    vectorized cost table (layer 1); :meth:`sweep` runs both over a whole
    assignment matrix.  Build from live per-pair candidates with
    :meth:`from_candidates`, or directly from a prebuilt machine + table
    (anything with a ``pair_bits(x) -> (n, P, 2)`` method works).
    """

    def __init__(self, machine, cost_table: hwcost.PairCostTable,
                 n_classes: int):
        if cost_table.n_pairs != len(class_pairs(n_classes)):
            raise ValueError(
                f"cost table has {cost_table.n_pairs} pairs; "
                f"{n_classes} classes need {len(class_pairs(n_classes))}")
        self.machine = machine
        self.cost_table = cost_table
        self.n_classes = int(n_classes)
        self.n_pairs = cost_table.n_pairs

    @classmethod
    def from_candidates(
        cls,
        candidates: Sequence,
        n_classes: int,
        cm: Optional[hwcost.CostModel] = None,
        use_pallas: Optional[bool] = None,
    ) -> "DesignSpace":
        """``candidates``: per-pair ``(linear_clf, rbf_clf)`` deployed
        classifier objects in ``class_pairs`` order."""
        from repro.api.compiled import compile_candidates  # deferred: api layers above core

        cm = cm or hwcost.CostModel()
        machine = compile_candidates(candidates, n_classes,
                                     use_pallas=use_pallas)
        table = hwcost.pair_cost_table(candidates, cm, n_classes=n_classes)
        return cls(machine, table, n_classes)

    def sweep(
        self,
        x_val: np.ndarray,
        y_val: np.ndarray,
        assignments: Optional[np.ndarray] = None,
        max_exhaustive: int = MAX_EXHAUSTIVE_PAIRS,
        seeds: Optional[np.ndarray] = None,
        n_random: int = 16,
        rng_seed: int = 0,
        max_rounds: int = 64,
        n_anneal: int = 8,
        anneal_steps: int = 96,
        mc_machine=None,
        accuracy_floor: Optional[float] = None,
    ) -> SweepResult:
        """Evaluate accuracy + cost over the assignment space.

        With ``assignments=None``: exhaustive ``2^P`` when ``P <=
        max_exhaustive`` (two jit compiles total: candidate bits + the
        recombination program), else the seeded greedy/flip + annealing
        portfolio (``seeds`` typically carries the Algorithm-1
        assignment; ``n_anneal``/``anneal_steps`` size the annealing
        stage, 0 disables it).  Passing ``max_exhaustive=0`` forces the
        portfolio even at small P — the CI smoke uses that to check the
        portfolio front covers the exhaustive oracle's.

        Monte-Carlo mode: pass an ``mc_machine``
        (``repro.api.compiled.MonteCarloMachine``, sampled with
        ``include_nominal``) and an ``accuracy_floor``.  The per-variant
        bit tensor is recombined in ONE batched program — every assignment
        gets mean/std/worst-case accuracy and yield (fraction of variants
        at or above the floor) for the cost of the same bit-recombination
        GEMM batched over V, and the result carries the robust
        four-objective front.  Still exactly two jit compiles on the
        exhaustive path: the MC forward and the MC recombination (the
        nominal ``accuracy`` column is the zero-offset variant's row).
        """
        t0 = time.perf_counter()
        acc_vs = None
        if mc_machine is not None:
            if accuracy_floor is None:
                raise ValueError(
                    "a Monte-Carlo sweep needs an explicit accuracy_floor "
                    "(the yield spec); pass accuracy_floor=...")
            if not mc_machine.include_nominal:
                raise ValueError(
                    "the MC machine must be sampled with include_nominal "
                    "so the sweep carries the nominal accuracy column")
            bits3 = mc_machine.pair_bits(x_val)
            bits2 = bits3[0]
        else:
            bits2 = self.machine.pair_bits(x_val)
        search_acc = None
        if assignments is not None:
            assignments = np.atleast_2d(np.asarray(assignments, bool))
            exhaustive = False
        elif self.n_pairs <= max_exhaustive:
            assignments = enumerate_assignments(self.n_pairs)
            exhaustive = True
        else:
            assignments, search_acc = _search_assignments(
                bits2, y_val, self.cost_table, self.n_classes,
                seeds, n_random, rng_seed, max_rounds,
                n_anneal=n_anneal, anneal_steps=anneal_steps)
            exhaustive = False
        if mc_machine is not None:
            acc_vs = assignment_accuracies_mc(
                bits3, assignments, y_val, self.n_classes)
            acc = acc_vs[0]
        elif search_acc is not None:
            acc = search_acc
        else:
            acc = assignment_accuracies(bits2, assignments, y_val,
                                        self.n_classes)
        area, power = hwcost.assignment_costs(self.cost_table, assignments)
        front = pareto_front(acc, area, power)
        elapsed = time.perf_counter() - t0
        result = SweepResult(
            assignments=assignments, accuracy=acc, area=area, power=power,
            front=front, n_classes=self.n_classes, exhaustive=exhaustive,
            elapsed_s=elapsed,
            assignments_per_s=assignments.shape[0] / max(elapsed, 1e-9),
        )
        if acc_vs is not None:
            stats = mc_statistics(acc_vs, accuracy_floor)
            result.accuracy_mc = acc_vs
            result.acc_mean = stats["mean"]
            result.acc_std = stats["std"]
            result.acc_worst = stats["worst"]
            result.yield_ = stats["yield"]
            result.yield_lo_ = stats["yield_lo"]
            result.yield_hi_ = stats["yield_hi"]
            result.confidence = stats["confidence"]
            result.accuracy_floor = float(accuracy_floor)
            result.n_variants = int(mc_machine.n_variants)
            result.sigma_scale = float(mc_machine.sigma_scale)
            result.mc_key_data = mc_machine.key_data
            result.robust_front = pareto_front(
                result.acc_mean, area, power, yield_=result.yield_)
        return result
