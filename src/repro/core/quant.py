"""Fixed-point quantization for the digital datapath (paper Sec. V-A2).

The paper's digital side is bespoke fixed-point hardware:
  * sensory inputs are uniformly quantized to 4-bit by the ADC,
  * linear-classifier weights/biases are quantized per [12] "to preserve
    accuracy" (we implement the standard bespoke flow: symmetric per-weight
    fixed-point with a shared power-of-two scale chosen to minimise the
    decision-function perturbation),
  * digital-RBF support vectors / dual coefficients are quantized "to ensure
    sufficient precision" (8-bit in our model).

Everything here is pure JAX so quantized inference can be jitted/vmapped and
property-tested with hypothesis (bounds, idempotence, monotonicity).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Uniform affine quantization in [0, 1] — the ADC model
# ---------------------------------------------------------------------------


def quantize_unit(x, bits: int = 4):
    """Uniformly quantize values in [0, 1] to ``bits`` (ADC of Fig. 1).

    Returns the *dequantized* (reconstructed) value, i.e. what the digital
    datapath actually computes with.  Values outside [0, 1] saturate, like a
    real ADC against its reference rails.
    """
    levels = (1 << bits) - 1
    xq = jnp.round(jnp.clip(x, 0.0, 1.0) * levels)
    return xq / levels


def quantize_unit_codes(x, bits: int = 4):
    """Integer ADC codes in [0, 2^bits - 1]."""
    levels = (1 << bits) - 1
    return jnp.round(jnp.clip(x, 0.0, 1.0) * levels).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Symmetric fixed-point for weights / support vectors / coefficients
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FixedPoint:
    """Symmetric fixed-point code: value = code * 2^-frac_bits, |code| < 2^(bits-1)."""

    bits: int
    frac_bits: int

    @property
    def scale(self) -> float:
        return 2.0 ** (-self.frac_bits)

    def quantize(self, x):
        qmax = (1 << (self.bits - 1)) - 1
        code = jnp.clip(jnp.round(jnp.asarray(x) / self.scale), -qmax, qmax)
        return code * self.scale

    def codes(self, x):
        qmax = (1 << (self.bits - 1)) - 1
        return jnp.clip(
            jnp.round(jnp.asarray(x) / self.scale), -qmax, qmax
        ).astype(jnp.int32)


def best_frac_bits(x: np.ndarray, bits: int) -> int:
    """Pick frac_bits so the largest |x| just fits (bespoke per-classifier scale)."""
    amax = float(np.max(np.abs(x))) if np.size(x) else 1.0
    if amax <= 0:
        return bits - 1
    qmax = (1 << (bits - 1)) - 1
    # need qmax * 2^-frac >= amax  =>  frac <= log2(qmax / amax);
    # clamped to the float32-safe exponent range (codes are computed in
    # f32 on device — extreme scales would under/overflow there).
    frac = int(np.floor(np.log2(qmax / amax) + 1e-9))
    return int(np.clip(frac, -(126 - bits), 126))


def quantize_tensor(x: np.ndarray, bits: int) -> tuple[np.ndarray, FixedPoint]:
    fp = FixedPoint(bits=bits, frac_bits=best_frac_bits(x, bits))
    return np.asarray(fp.quantize(x)), fp


# ---------------------------------------------------------------------------
# Bespoke-hardware weight analysis (drives the cost model of hwcost.py)
# ---------------------------------------------------------------------------


def csd_nonzero_digits(code: int) -> int:
    """Number of non-zero digits in the canonical signed digit form of ``code``.

    A bespoke constant multiplier costs one adder per CSD non-zero digit minus
    one; zero / power-of-two weights cost NO multiplier at all — this is
    exactly the effect the paper observes on Balance ("digital linear
    component converged to zero or power of 2 weights").
    """
    c = abs(int(code))
    count = 0
    while c:
        if c & 1:
            # canonical recoding: runs of 1s become +/- pair
            if (c & 3) == 3:
                c += 1  # use a -1 digit
            count += 1
        c >>= 1
    return count


def weight_hardware_class(code: int) -> str:
    """'zero' | 'pow2' | 'general' — cost classes of a hardwired weight."""
    c = abs(int(code))
    if c == 0:
        return "zero"
    if (c & (c - 1)) == 0:
        return "pow2"
    return "general"
