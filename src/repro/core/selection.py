"""Separation-driven mixed-kernel exploration — Algorithm 1 of the paper.

For every OvO pair:
  1. extract the binary subset,
  2. train a linear SVM and an RBF SVM (each with its own CV'd (C, gamma)),
  3. keep the RBF kernel ONLY if it is strictly more accurate than linear
     (`A_rbf > A_lin`, line 8) — this minimises the number of costly RBF
     (analog) classifiers while preserving accuracy where it matters.

The selected float classifiers are then *deployed* to hardware:
  linear -> DigitalLinearClassifier (4-bit ADC inputs, quantized weights)
  rbf    -> AnalogBinaryClassifier  (behavioral model of Sec. IV-A)
and wrapped in a ``MulticlassSVM`` with the encoder decision logic.

Module layout (post batched-trainer refactor, DESIGN.md §1 and §4):

  * ``train_pairs``   — the Algorithm-1 training entry point: a thin
                        wrapper over ``repro.core.trainer.train_pairs``,
                        the batched engine that runs all pairs x CV folds
                        x (C, gamma) grid cells in one compiled program
                        per kernel family,
  * ``train_pairs_sequential`` — the original per-pair host loop, kept as
                        the reference path (equivalence tests, benchmark
                        baseline); O(pairs) jit compiles,
  * ``build_banks``   — assemble every Table-II design point (float and
                        deployed) as ``MulticlassSVM`` object banks,
  * ``explore``       — DEPRECATED thin shim kept for old call sites; new
                        code uses ``repro.api.MixedKernelSVM`` which wraps
                        the two functions above and compiles the banks to a
                        single batched JAX inference path.

``PairResult``, ``binary_subset``, ``default_hw`` and ``hw_gamma_grid``
now live in ``repro.core.trainer`` and are re-exported here unchanged.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

import numpy as np

from repro.core import svm as svm_mod
from repro.core import trainer as trainer_mod
from repro.core.analog import AnalogBinaryClassifier, AnalogRBFModel
from repro.core.ovo import (
    DigitalLinearClassifier,
    DigitalRBFClassifier,
    FloatBitClassifier,
    MulticlassSVM,
    class_pairs,
)
from repro.core.trainer import (  # noqa: F401  (re-exported, see docstring)
    PairResult,
    binary_subset,
    default_hw,
    hw_gamma_grid,
)

#: Design points produced by ``build_banks``: mixed float/circuit plus the
#: all-linear and all-RBF baselines of Table II (both float and deployed).
BANK_TARGETS = ("float", "circuit", "linear", "rbf", "linear_float",
                "rbf_float")


#: Kept under the old private name for any straggler call sites.
_binary_subset = binary_subset


def train_pairs(
    x_train: np.ndarray,
    y_train: np.ndarray,
    n_classes: int,
    hw: Optional[AnalogRBFModel] = None,
    n_epochs: int = 200,
    seed: int = 0,
    tie_margin: float = 0.005,
    cv_epochs: Optional[int] = None,
    n_folds: int = 5,
    mesh=None,
    hw_all: bool = False,
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> list[PairResult]:
    """Run Algorithm 1: one PairResult per OvO pair (batched engine).

    Thin wrapper over :func:`repro.core.trainer.train_pairs`, which runs
    all pairs x CV folds x (C, gamma) cells in ONE compiled program per
    kernel family (O(1) jit compiles per family instead of O(pairs); see
    DESIGN.md §4).  ``train_pairs_sequential`` keeps the original per-pair
    loop as the reference path.

    ``tie_margin`` realizes line 8's "RBF only when strictly better" under
    finite-sample CV accuracy: RBF must win by more than the margin (the
    paper gauges sufficiency at integer-percent reporting granularity).

    Pairs assigned to RBF are then *co-optimized for the hardware*: retrained
    with the sech2 hardware kernel on a hardware-realizable gamma grid, so the
    deployed analog classifier computes with the same kernel it was trained
    with (the paper's "co-optimization approach that trains our mixed-kernel
    SVMs") — this is what keeps circuit accuracy within ~1% of software.
    """
    return trainer_mod.train_pairs(
        x_train, y_train, n_classes, hw=hw, n_epochs=n_epochs, seed=seed,
        tie_margin=tie_margin, cv_epochs=cv_epochs, n_folds=n_folds,
        mesh=mesh, hw_all=hw_all, use_pallas=use_pallas,
        interpret=interpret)


def train_pairs_sequential(
    x_train: np.ndarray,
    y_train: np.ndarray,
    n_classes: int,
    hw: Optional[AnalogRBFModel] = None,
    n_epochs: int = 200,
    seed: int = 0,
    tie_margin: float = 0.005,
    cv_epochs: Optional[int] = None,
) -> list[PairResult]:
    """The original Algorithm-1 host loop: 2-3 ``fit_best`` per pair.

    Kept as the reference implementation: every pair's unique subset size
    forces fresh jit compilations (O(pairs) compiles), which is what
    ``benchmarks/svm_train.py`` measures the batched engine against.
    Selections and accuracies agree with ``train_pairs`` up to the
    comparator-tie epsilon (DESIGN.md §1.4/§4.5).
    """
    if hw is None:
        hw = default_hw(seed)

    # One shared callable => one jit cache entry across pairs/grids.
    hw_kernel = hw.kernel_response

    pairs: list[PairResult] = []
    for (ci, cj) in class_pairs(n_classes):
        xb, yb = binary_subset(x_train, y_train, ci, cj)
        m_lin, a_lin = svm_mod.fit_best(xb, yb, "linear", n_epochs=n_epochs,
                                        seed=seed, cv_epochs=cv_epochs)
        m_rbf, a_rbf = svm_mod.fit_best(xb, yb, "rbf", n_epochs=n_epochs,
                                        seed=seed, cv_epochs=cv_epochs)
        # Line 8: RBF only when STRICTLY better (beyond the CV-noise margin).
        kind = "rbf" if a_rbf > a_lin + tie_margin else "linear"
        m_hw = None
        if kind == "rbf":
            # Hardware-in-the-loop co-optimization: train with the calibrated
            # behavioral model as the kernel, on a realizable gamma grid.
            m_hw, _ = svm_mod.fit_best(
                xb, yb, hw_kernel, gammas=hw_gamma_grid(hw),
                n_epochs=n_epochs, seed=seed, cv_epochs=cv_epochs,
            )
        pairs.append(
            PairResult(
                pair=(ci, cj), kernel=kind,
                model=m_hw if kind == "rbf" else m_lin,
                acc_linear=a_lin, acc_rbf=a_rbf,
                model_linear=m_lin, model_rbf=m_rbf, model_hw=m_hw,
            )
        )
    return pairs


def build_banks(
    pairs: list[PairResult],
    n_classes: int,
    hw: Optional[AnalogRBFModel] = None,
    weight_bits: int = 8,
    input_bits: int = 4,
    seed: int = 0,
    alpha_floor_rel: float = 1.0 / 256.0,
) -> dict[str, MulticlassSVM]:
    """Deploy every design point of Table II as an object bank.

    Returns a dict keyed by ``BANK_TARGETS``:

      float        mixed, software float models (Algorithm-1 selection)
      circuit      mixed, deployed: digital linear + ANALOG rbf
      linear       all-linear, deployed digital
      rbf          all-RBF, deployed DIGITAL (the costly baseline)
      linear_float / rbf_float   float counterparts of the baselines
    """
    if hw is None:
        hw = default_hw(seed)
    kmap = [p.kernel for p in pairs]

    def multi(classifiers, kernel_map):
        return MulticlassSVM(n_classes=n_classes, classifiers=classifiers,
                             kernel_map=kernel_map)

    def deploy_linear(m):
        return DigitalLinearClassifier.deploy(m, weight_bits, input_bits)

    def deploy_digital_rbf(m):
        return DigitalRBFClassifier.deploy(m, input_bits=input_bits)

    def deploy_analog_rbf(m):
        return AnalogBinaryClassifier.deploy(m, hw, alpha_floor_rel=alpha_floor_rel)

    return {
        "float": multi([FloatBitClassifier(p.model) for p in pairs], kmap),
        "linear_float": multi(
            [FloatBitClassifier(p.model_linear) for p in pairs],
            ["linear"] * len(pairs)),
        "rbf_float": multi(
            [FloatBitClassifier(p.model_rbf) for p in pairs],
            ["rbf"] * len(pairs)),
        "circuit": multi(
            [
                deploy_analog_rbf(p.model) if p.kernel == "rbf"
                else deploy_linear(p.model)
                for p in pairs
            ],
            kmap),
        "linear": multi([deploy_linear(p.model_linear) for p in pairs],
                        ["linear"] * len(pairs)),
        "rbf": multi([deploy_digital_rbf(p.model_rbf) for p in pairs],
                     ["rbf"] * len(pairs)),
    }


# ---------------------------------------------------------------------------
# Deprecated shim (pre-redesign API)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ExplorationResult:
    """DEPRECATED grab-bag result of the old ``explore`` API.

    New code should use ``repro.api.MixedKernelSVM`` (estimator) and
    ``repro.api.compile_machine`` (single batched inference path).  This
    container is kept so old call sites keep working; it is assembled from
    ``train_pairs`` + ``build_banks``.
    """

    n_classes: int
    pairs: list[PairResult]
    kernel_map: list[str]
    # float (software) models
    mixed_float: MulticlassSVM
    linear_float: MulticlassSVM
    rbf_float: MulticlassSVM
    # deployed (circuit) models
    mixed_circuit: MulticlassSVM     # digital linear + ANALOG rbf
    linear_circuit: MulticlassSVM    # all digital linear
    rbf_circuit: MulticlassSVM       # all DIGITAL rbf (the costly baseline)

    @property
    def n_rbf(self) -> int:
        return sum(k == "rbf" for k in self.kernel_map)


def explore(
    x_train: np.ndarray,
    y_train: np.ndarray,
    n_classes: int,
    hw: Optional[AnalogRBFModel] = None,
    weight_bits: int = 8,
    input_bits: int = 4,
    n_epochs: int = 200,
    seed: int = 0,
    tie_margin: float = 0.005,
    alpha_floor_rel: float = 1.0 / 256.0,
) -> ExplorationResult:
    """DEPRECATED: run Algorithm 1 and deploy every design point of Table II.

    Use ``repro.api.MixedKernelSVM(...).fit(x, y)`` instead; it exposes the
    same design points through ``bank(target)`` / ``deploy(target)`` and adds
    the compiled batched inference path and serialization.
    """
    warnings.warn(
        "selection.explore / ExplorationResult are deprecated; use "
        "repro.api.MixedKernelSVM (see DESIGN.md §1).",
        DeprecationWarning, stacklevel=2,
    )
    if hw is None:
        hw = default_hw(seed)
    pairs = train_pairs(x_train, y_train, n_classes, hw=hw,
                        n_epochs=n_epochs, seed=seed, tie_margin=tie_margin)
    banks = build_banks(pairs, n_classes, hw=hw, weight_bits=weight_bits,
                        input_bits=input_bits, seed=seed,
                        alpha_floor_rel=alpha_floor_rel)
    return ExplorationResult(
        n_classes=n_classes, pairs=pairs, kernel_map=[p.kernel for p in pairs],
        mixed_float=banks["float"], linear_float=banks["linear_float"],
        rbf_float=banks["rbf_float"], mixed_circuit=banks["circuit"],
        linear_circuit=banks["linear"], rbf_circuit=banks["rbf"],
    )
