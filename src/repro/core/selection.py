"""Separation-driven mixed-kernel exploration — Algorithm 1 of the paper.

For every OvO pair:
  1. extract the binary subset,
  2. train a linear SVM and an RBF SVM (each with its own CV'd (C, gamma)),
  3. keep the RBF kernel ONLY if it is strictly more accurate than linear
     (`A_rbf > A_lin`, line 8) — this minimises the number of costly RBF
     (analog) classifiers while preserving accuracy where it matters.

The selected float classifiers are then *deployed* to hardware:
  linear -> DigitalLinearClassifier (4-bit ADC inputs, quantized weights)
  rbf    -> AnalogBinaryClassifier  (behavioral model of Sec. IV-A)
and wrapped in a ``MulticlassSVM`` with the encoder decision logic.

Module layout (post API redesign, DESIGN.md §1):

  * ``train_pairs``   — the Algorithm-1 per-pair training loop,
  * ``build_banks``   — assemble every Table-II design point (float and
                        deployed) as ``MulticlassSVM`` object banks,
  * ``explore``       — DEPRECATED thin shim kept for old call sites; new
                        code uses ``repro.api.MixedKernelSVM`` which wraps
                        the two functions above and compiles the banks to a
                        single batched JAX inference path.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

import jax
import numpy as np

from repro.core import svm as svm_mod
from repro.core.analog import AnalogBinaryClassifier, AnalogRBFModel
from repro.core.ovo import (
    DigitalLinearClassifier,
    DigitalRBFClassifier,
    FloatBitClassifier,
    MulticlassSVM,
    class_pairs,
)

#: Design points produced by ``build_banks``: mixed float/circuit plus the
#: all-linear and all-RBF baselines of Table II (both float and deployed).
BANK_TARGETS = ("float", "circuit", "linear", "rbf", "linear_float",
                "rbf_float")


@dataclasses.dataclass
class PairResult:
    pair: tuple[int, int]
    kernel: str                      # selected kernel kind
    model: svm_mod.SVMModel          # selected float model
    acc_linear: float                # CV accuracy of the linear candidate
    acc_rbf: float                   # CV accuracy of the RBF candidate
    model_linear: svm_mod.SVMModel   # both candidates kept for baselines
    model_rbf: svm_mod.SVMModel
    # Hardware-aware co-optimized model (sech2 kernel) for analog deployment;
    # only trained for pairs that Algorithm 1 assigns to RBF.
    model_hw: Optional[svm_mod.SVMModel] = None


def _binary_subset(
    x: np.ndarray, y: np.ndarray, ci: int, cj: int
) -> tuple[np.ndarray, np.ndarray]:
    """Line 5: D_ij = {(x, y) in D | y in {c_i, c_j}}, labels -> {+1, -1}.

    +1 encodes c_i (the pair's first class) so bit==1 <=> c_i wins.
    """
    mask = (y == ci) | (y == cj)
    yy = np.where(y[mask] == ci, 1.0, -1.0)
    return x[mask], yy


def hw_gamma_grid(hw: AnalogRBFModel, n: int = 7) -> np.ndarray:
    """Hardware-realizable gamma* grid for the sech2 co-optimized training.

    The input scaling of Eq. (8) must keep the scaled differential voltage
    within the cell's usable range: s * v_scale * max|dx| <= v_range with
    max|dx| = 1 for [0,1]-normalized features.  Everything below that cap is
    realizable; we search log-uniformly under it.
    """
    g_cap = hw.gamma0_feature() * (hw.params.v_range / hw.v_scale) ** 2
    return np.logspace(-1.0, np.log10(g_cap), n)


def default_hw(seed: int = 0) -> AnalogRBFModel:
    """The default calibrated analog behavioral model (one fabricated core)."""
    return AnalogRBFModel.from_circuit(key=jax.random.PRNGKey(seed))


def train_pairs(
    x_train: np.ndarray,
    y_train: np.ndarray,
    n_classes: int,
    hw: Optional[AnalogRBFModel] = None,
    n_epochs: int = 200,
    seed: int = 0,
    tie_margin: float = 0.005,
) -> list[PairResult]:
    """Run the Algorithm-1 training loop: one PairResult per OvO pair.

    ``tie_margin`` realizes line 8's "RBF only when strictly better" under
    finite-sample CV accuracy: RBF must win by more than the margin (the
    paper gauges sufficiency at integer-percent reporting granularity).

    Pairs assigned to RBF are then *co-optimized for the hardware*: retrained
    with the sech2 hardware kernel on a hardware-realizable gamma grid, so the
    deployed analog classifier computes with the same kernel it was trained
    with (the paper's "co-optimization approach that trains our mixed-kernel
    SVMs") — this is what keeps circuit accuracy within ~1% of software.
    """
    if hw is None:
        hw = default_hw(seed)

    # One shared callable => one jit cache entry across pairs/grids.
    hw_kernel = hw.kernel_response

    pairs: list[PairResult] = []
    for (ci, cj) in class_pairs(n_classes):
        xb, yb = _binary_subset(x_train, y_train, ci, cj)
        m_lin, a_lin = svm_mod.fit_best(xb, yb, "linear", n_epochs=n_epochs, seed=seed)
        m_rbf, a_rbf = svm_mod.fit_best(xb, yb, "rbf", n_epochs=n_epochs, seed=seed)
        # Line 8: RBF only when STRICTLY better (beyond the CV-noise margin).
        kind = "rbf" if a_rbf > a_lin + tie_margin else "linear"
        m_hw = None
        if kind == "rbf":
            # Hardware-in-the-loop co-optimization: train with the calibrated
            # behavioral model as the kernel, on a realizable gamma grid.
            m_hw, _ = svm_mod.fit_best(
                xb, yb, hw_kernel, gammas=hw_gamma_grid(hw),
                n_epochs=n_epochs, seed=seed,
            )
        pairs.append(
            PairResult(
                pair=(ci, cj), kernel=kind,
                model=m_hw if kind == "rbf" else m_lin,
                acc_linear=a_lin, acc_rbf=a_rbf,
                model_linear=m_lin, model_rbf=m_rbf, model_hw=m_hw,
            )
        )
    return pairs


def build_banks(
    pairs: list[PairResult],
    n_classes: int,
    hw: Optional[AnalogRBFModel] = None,
    weight_bits: int = 8,
    input_bits: int = 4,
    seed: int = 0,
    alpha_floor_rel: float = 1.0 / 256.0,
) -> dict[str, MulticlassSVM]:
    """Deploy every design point of Table II as an object bank.

    Returns a dict keyed by ``BANK_TARGETS``:

      float        mixed, software float models (Algorithm-1 selection)
      circuit      mixed, deployed: digital linear + ANALOG rbf
      linear       all-linear, deployed digital
      rbf          all-RBF, deployed DIGITAL (the costly baseline)
      linear_float / rbf_float   float counterparts of the baselines
    """
    if hw is None:
        hw = default_hw(seed)
    kmap = [p.kernel for p in pairs]

    def multi(classifiers, kernel_map):
        return MulticlassSVM(n_classes=n_classes, classifiers=classifiers,
                             kernel_map=kernel_map)

    def deploy_linear(m):
        return DigitalLinearClassifier.deploy(m, weight_bits, input_bits)

    def deploy_digital_rbf(m):
        return DigitalRBFClassifier.deploy(m, input_bits=input_bits)

    def deploy_analog_rbf(m):
        return AnalogBinaryClassifier.deploy(m, hw, alpha_floor_rel=alpha_floor_rel)

    return {
        "float": multi([FloatBitClassifier(p.model) for p in pairs], kmap),
        "linear_float": multi(
            [FloatBitClassifier(p.model_linear) for p in pairs],
            ["linear"] * len(pairs)),
        "rbf_float": multi(
            [FloatBitClassifier(p.model_rbf) for p in pairs],
            ["rbf"] * len(pairs)),
        "circuit": multi(
            [
                deploy_analog_rbf(p.model) if p.kernel == "rbf"
                else deploy_linear(p.model)
                for p in pairs
            ],
            kmap),
        "linear": multi([deploy_linear(p.model_linear) for p in pairs],
                        ["linear"] * len(pairs)),
        "rbf": multi([deploy_digital_rbf(p.model_rbf) for p in pairs],
                     ["rbf"] * len(pairs)),
    }


# ---------------------------------------------------------------------------
# Deprecated shim (pre-redesign API)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ExplorationResult:
    """DEPRECATED grab-bag result of the old ``explore`` API.

    New code should use ``repro.api.MixedKernelSVM`` (estimator) and
    ``repro.api.compile_machine`` (single batched inference path).  This
    container is kept so old call sites keep working; it is assembled from
    ``train_pairs`` + ``build_banks``.
    """

    n_classes: int
    pairs: list[PairResult]
    kernel_map: list[str]
    # float (software) models
    mixed_float: MulticlassSVM
    linear_float: MulticlassSVM
    rbf_float: MulticlassSVM
    # deployed (circuit) models
    mixed_circuit: MulticlassSVM     # digital linear + ANALOG rbf
    linear_circuit: MulticlassSVM    # all digital linear
    rbf_circuit: MulticlassSVM       # all DIGITAL rbf (the costly baseline)

    @property
    def n_rbf(self) -> int:
        return sum(k == "rbf" for k in self.kernel_map)


def explore(
    x_train: np.ndarray,
    y_train: np.ndarray,
    n_classes: int,
    hw: Optional[AnalogRBFModel] = None,
    weight_bits: int = 8,
    input_bits: int = 4,
    n_epochs: int = 200,
    seed: int = 0,
    tie_margin: float = 0.005,
    alpha_floor_rel: float = 1.0 / 256.0,
) -> ExplorationResult:
    """DEPRECATED: run Algorithm 1 and deploy every design point of Table II.

    Use ``repro.api.MixedKernelSVM(...).fit(x, y)`` instead; it exposes the
    same design points through ``bank(target)`` / ``deploy(target)`` and adds
    the compiled batched inference path and serialization.
    """
    warnings.warn(
        "selection.explore / ExplorationResult are deprecated; use "
        "repro.api.MixedKernelSVM (see DESIGN.md §1).",
        DeprecationWarning, stacklevel=2,
    )
    if hw is None:
        hw = default_hw(seed)
    pairs = train_pairs(x_train, y_train, n_classes, hw=hw,
                        n_epochs=n_epochs, seed=seed, tie_margin=tie_margin)
    banks = build_banks(pairs, n_classes, hw=hw, weight_bits=weight_bits,
                        input_bits=input_bits, seed=seed,
                        alpha_floor_rel=alpha_floor_rel)
    return ExplorationResult(
        n_classes=n_classes, pairs=pairs, kernel_map=[p.kernel for p in pairs],
        mixed_float=banks["float"], linear_float=banks["linear_float"],
        rbf_float=banks["rbf_float"], mixed_circuit=banks["circuit"],
        linear_circuit=banks["linear"], rbf_circuit=banks["rbf"],
    )
