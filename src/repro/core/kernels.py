"""Kernel functions for the mixed-kernel SVM (paper Eq. 2-6).

Three kernel families:
  * linear      — K(x, z) = x.z                              (digital domain)
  * rbf         — K(x, z) = exp(-gamma ||x - z||^2)          (ideal Gaussian)
  * sech2 (hw)  — the hardware transfer of the cascaded subthreshold
                  differential pairs, Eq. (4):
                      I_out/I_in = 1/((1+e^{-x})(1+e^{x})) = (1/4) sech^2(x/2)
                  with x = dv / (n * V_T).  Near the origin this matches the
                  Gaussian with gamma0 = 1 / (4 n^2 V_T^2)  (Eq. 5).

All kernels operate on the squared-distance decomposition
``||x - z||^2 = ||x||^2 + ||z||^2 - 2 x.z`` so the dominant term is a matmul
(MXU-friendly); the Pallas kernel in ``repro.kernels.rbf`` implements the
tiled version and is validated against these functions.
"""
from __future__ import annotations

import jax.numpy as jnp

# Thermal voltage at 300 K (V) and typical IGZO subthreshold slope factor.
V_T: float = 0.02585
N_SLOPE: float = 1.38


def pairwise_sq_dists(x: jnp.ndarray, z: jnp.ndarray) -> jnp.ndarray:
    """||x_i - z_j||^2 for x:(n,d), z:(m,d) -> (n,m), matmul-dominant form."""
    xx = jnp.sum(x * x, axis=-1)[:, None]
    zz = jnp.sum(z * z, axis=-1)[None, :]
    xz = x @ z.T
    return jnp.maximum(xx + zz - 2.0 * xz, 0.0)


def linear_kernel(x: jnp.ndarray, z: jnp.ndarray) -> jnp.ndarray:
    """K(x, z) = x.z  (paper Sec. II-A)."""
    return x @ z.T


def rbf_kernel(x: jnp.ndarray, z: jnp.ndarray, gamma: jnp.ndarray) -> jnp.ndarray:
    """Ideal Gaussian RBF kernel, Eq. (2)."""
    return jnp.exp(-gamma * pairwise_sq_dists(x, z))


def gamma_subthreshold(n: float = N_SLOPE, v_t: float = V_T) -> float:
    """gamma0 of the un-scaled hardware cell, Eq. (5): 1 / (4 n^2 V_T^2)."""
    return 1.0 / (4.0 * n * n * v_t * v_t)


def sech2_cell(dv: jnp.ndarray, n: float = N_SLOPE, v_t: float = V_T) -> jnp.ndarray:
    """Single-dimension hardware Gaussian cell transfer I_out/I_in, Eq. (4).

    Normalised so that sech2_cell(0) == 1 (the 1/4 peak factor and the 1/4^D
    product attenuation of Eq. (6) cancel in the decision function because the
    comparator only sees the *difference* of rail currents; absolute current
    scale is carried by the bias current in the behavioural model).
    """
    x = dv / (n * v_t)
    # sech^2(x/2) == 4 / (2 + e^x + e^-x); write it in the cascaded-pair form
    # of Eq. (4) times 4 for the normalisation described above.
    return 4.0 / ((1.0 + jnp.exp(-x)) * (1.0 + jnp.exp(x)))


def sech2_kernel(
    x: jnp.ndarray,
    z: jnp.ndarray,
    gamma: jnp.ndarray,
    v_scale: float = 1.0,
    n: float = N_SLOPE,
    v_t: float = V_T,
) -> jnp.ndarray:
    """Hardware separable kernel, Eq. (6) + input scaling of Eq. (8).

    Features are mapped to voltages by ``dv = v_scale * (x_d - z_d)`` and the
    requested ``gamma`` (in feature units) is realised by scaling the input
    relative to the native cell gamma:  s = sqrt(gamma / gamma0_feature)
    where gamma0_feature = gamma0_volts * v_scale^2.
    """
    gamma0_feat = gamma_subthreshold(n, v_t) * v_scale * v_scale
    s = jnp.sqrt(gamma / gamma0_feat)
    # (n, m, d) differences; D <= 5 in hardware so this stays tiny for the
    # paper's workloads.  The product across dimensions is Eq. (6).
    dv = v_scale * (x[:, None, :] - z[None, :, :]) * s
    return jnp.prod(sech2_cell(dv, n, v_t), axis=-1)


# ---------------------------------------------------------------------------
# Uniform-grid interpolation (measured transfer curves)
# ---------------------------------------------------------------------------


def _grid_is_uniform(grid, rel_tol: float = 1e-3) -> bool:
    """True when ``grid`` is a cast linspace (the DC-sweep abscissa)."""
    import numpy as np

    steps = np.diff(np.asarray(grid, np.float64))
    if steps.size == 0 or np.any(steps <= 0):
        return False
    mean = steps.mean()
    return bool(np.max(np.abs(steps - mean)) <= rel_tol * abs(mean))


def _uniform_interp(v, curve, lo, hi, left, right, inv_step):
    """``jnp.interp`` on a uniform ascending grid: O(1) bin location.

    The DC-sweep abscissa is a linspace, so the segment index and the
    interpolation fraction come from one multiply (``u = (v-lo)*inv_step``)
    instead of a per-query binary search, and only the two bracketing curve
    values are gathered.  The result tracks ``jnp.interp`` to ~1e-6 (the
    fraction's f32 rounding times the max segment slope; same order as the
    eager-vs-jit fusion noise the compiled path already carries);
    out-of-range queries clamp to ``left``/``right`` exactly like the
    behavioral model's ``kernel_1d``.
    """
    n_seg = curve.shape[0] - 1
    u = (v - lo) * inv_step
    i = jnp.clip(jnp.floor(u).astype(jnp.int32), 0, n_seg - 1)
    t = u - i.astype(jnp.float32)
    f0 = jnp.take(curve, i)
    f1 = jnp.take(curve, i + 1)
    f = f0 + t * (f1 - f0)
    f = jnp.where(v < lo, left, f)
    f = jnp.where(v > hi, right, f)
    return f


def measured_cell(v, grid, curve, left, right, uniform: bool, inv_step):
    """ONE measured-transfer-curve cell evaluation, shared by every
    consumer of a calibrated analog sweep (the nominal compiled machine,
    the Monte-Carlo variant lanes, hardware-in-the-loop training).

    ``uniform`` is a static Python bool: a linspace abscissa takes the
    O(1) ``_uniform_interp`` fast path, anything else falls back to
    ``jnp.interp``.  Keeping this a single function is part of the
    nominal-equivalence contract (DESIGN.md §6.3): the variant path runs
    the *same* interpolation code as the nominal path, so a zero-offset
    variant cannot drift from it.
    """
    if uniform:
        return _uniform_interp(v, curve, grid[0], grid[-1], left, right,
                               inv_step)
    return jnp.interp(v, grid, curve, left=left, right=right)


def _grid_fast_path(grid) -> dict:
    """{'uniform_grid': bool, 'inv_step': float} for a sweep abscissa."""
    import numpy as np

    if grid is None or not _grid_is_uniform(grid):
        return {"uniform_grid": False, "inv_step": 0.0}
    g = np.asarray(grid, np.float64)
    return {"uniform_grid": True,
            "inv_step": float((g.shape[0] - 1) / (g[-1] - g[0]))}


def kernel_matrix(
    kind, x: jnp.ndarray, z: jnp.ndarray, gamma: jnp.ndarray | float = 1.0
) -> jnp.ndarray:
    """Dispatch on kernel kind; ``kind`` may also be a callable
    (x, z, gamma) -> K, e.g. the calibrated analog behavioral model for
    hardware-in-the-loop training."""
    if callable(kind):
        return kind(x, z, jnp.asarray(gamma))
    if kind == "linear":
        return linear_kernel(x, z)
    if kind == "rbf":
        return rbf_kernel(x, z, jnp.asarray(gamma))
    if kind == "sech2":
        return sech2_kernel(x, z, jnp.asarray(gamma))
    raise ValueError(f"unknown kernel kind: {kind!r}")
