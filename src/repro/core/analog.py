"""Analog RBF classifier: circuit surrogate + behavioral model (paper III-B, IV-A).

Two layers, mirroring the paper's methodology exactly:

1. ``CircuitParams`` + the ``*_circuit`` functions — a transistor-level
   *surrogate simulator* standing in for Cadence Spectre.  It evaluates the
   actual subthreshold device equations of the FlexIC cells (exponential I-V
   with slope factor n, threshold mismatch, mirror ratio error, finite input
   range) rather than the ideal math.  DC sweeps of this surrogate play the
   role of the paper's SPICE sweeps.

2. ``AnalogRBFModel`` — the high-level *behavioral model* of Sec. IV-A: the
   measured transfer curve is kept as sampled data, an ideal Gaussian
   ``A0 exp(-gamma0 (dv - mu)^2)`` is fitted to it (Eq. 7) to extract gamma0,
   kernel widths gamma* are realised by input scaling s = sqrt(gamma*/gamma0)
   (Eq. 8), and the alpha multiplier is a logistic fitted as (x0, s) with the
   software-side inverse mapping  dV_alpha = x0 + s ln(1/alpha - 1)  (Eq. 9).

``AnalogBinaryClassifier`` deploys a trained RBF ``SVMModel`` onto this
hardware model: alpha normalisation into the (0,1) multiplier range, signed
accumulation of per-SV currents on +/- rails, and a comparator producing the
1-bit digital output (analog-in digital-out — no ADC).

Monte-Carlo variation (DESIGN.md §6): printed/flexible devices carry large
process variation, so a single nominal behavioral model under-reports the
deployed accuracy distribution.  ``VariantSet`` holds per-SV-cell mismatch
draws for ``V`` fabricated instances (4 Gaussian-cell offsets + 2 alpha-
multiplier offsets per cell, plus one comparator offset per instance);
``variant_transfer_params`` reduces the raw draws to per-cell perturbations
of the *measured* transfer curves (a horizontal threshold shift, a gain
factor, an alpha control-voltage shift/slope scale, a comparator offset),
so the zero-offset variant evaluates the exact same interpolation the
nominal path runs — bit-identical by construction.  ``AnalogRBFModel``,
``AnalogBinaryClassifier`` and ``VariantSet`` are registered pytrees, so
the variant axis vmaps end-to-end through one compiled program
(``repro.api.compiled.compile_variants``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernels import N_SLOPE, V_T
from repro.core.svm import SVMModel

# --------------------------------------------------------------------------
# Circuit surrogate ("SPICE")
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CircuitParams:
    """Process/bias parameters of the FlexIC subthreshold cells."""

    n: float = N_SLOPE            # subthreshold slope factor
    v_t: float = V_T              # thermal voltage (V)
    i_bias: float = 150e-9        # kernel chain bias current I_in (A)
    v_supply: float = 1.0         # analog supply (V), regulated from 1.5 V
    v_range: float = 0.40         # usable differential input range (V)
    sigma_vth: float = 3.0e-3     # per-device threshold mismatch (V)
    mirror_err: float = 0.02      # readout mirror ratio error (rel.)
    lambda_ds: float = 0.01       # residual V_DS sensitivity (rel.)
    comparator_offset: float = 1.0e-10  # comparator input offset (A)
    comparator_sigma: float = 1.0e-10   # comparator offset mismatch (A, 1-sigma)


def _pair_fraction(x: jnp.ndarray) -> jnp.ndarray:
    """Subthreshold differential-pair current split: I1/I_tail."""
    return 1.0 / (1.0 + jnp.exp(-x))


def gaussian_cell_circuit(
    dv: jnp.ndarray,
    p: CircuitParams,
    offsets: Optional[jnp.ndarray] = None,  # (4,) vth offsets + mirror/ds errs
) -> jnp.ndarray:
    """I_out/I_in of one Gaussian cell (Q1..Q6 of Fig. 2) with non-idealities.

    Ideal limit (offsets = 0): Eq. (4),
      I_out/I_in = 1 / ((1+e^-x)(1+e^x)) = (1/4) sech^2(x/2),  x = dv/(n V_T).
    """
    if offsets is None:
        offsets = jnp.zeros((4,))
    nvt = p.n * p.v_t
    dvc = jnp.clip(dv, -p.v_range, p.v_range)  # input rails
    x = (dvc - offsets[0] * p.sigma_vth) / nvt
    x2 = (dvc - offsets[1] * p.sigma_vth) / nvt
    f1 = _pair_fraction(x)            # (Q1, Q2) pair
    f2 = 1.0 - _pair_fraction(x2)     # cascaded complementary (Q3, Q4) pair
    mirror = 1.0 + offsets[2] * p.mirror_err      # Q6/Q4 readout ratioing
    vds_mod = 1.0 + offsets[3] * p.lambda_ds      # weak V_DS dependence
    return f1 * f2 * mirror * vds_mod


def alpha_multiplier_circuit(
    dva: jnp.ndarray,
    p: CircuitParams,
    offsets: Optional[jnp.ndarray] = None,  # (2,) vth offset, slope error
) -> jnp.ndarray:
    """I_out/I_in of the alpha multiplier: logistic in the control voltage."""
    if offsets is None:
        offsets = jnp.zeros((2,))
    nvt = p.n * p.v_t * (1.0 + offsets[1] * 0.02)
    return 1.0 / (1.0 + jnp.exp((dva - offsets[0] * p.sigma_vth) / nvt))


def dc_sweep_gaussian(
    p: CircuitParams, key: Optional[jax.Array] = None, n_points: int = 257
) -> tuple[np.ndarray, np.ndarray]:
    """DC sweep of the Gaussian cell: (dv, I_out/I_in). Plays SPICE's role."""
    dv = jnp.linspace(-p.v_range, p.v_range, n_points)
    offsets = jax.random.normal(key, (4,)) if key is not None else jnp.zeros((4,))
    out = gaussian_cell_circuit(dv, p, offsets)
    return np.asarray(dv), np.asarray(out)


def dc_sweep_alpha(
    p: CircuitParams, key: Optional[jax.Array] = None, n_points: int = 257
) -> tuple[np.ndarray, np.ndarray]:
    dva = jnp.linspace(-0.25, 0.25, n_points)
    offsets = jax.random.normal(key, (2,)) if key is not None else jnp.zeros((2,))
    return np.asarray(dva), np.asarray(alpha_multiplier_circuit(dva, p, offsets))


# --------------------------------------------------------------------------
# Monte-Carlo process variation (DESIGN.md §6)
# --------------------------------------------------------------------------

#: Raw mismatch draws per 1-D Gaussian cell (two pair vth offsets, mirror
#: ratio error, V_DS modulation) and per alpha multiplier (vth offset,
#: slope error) — the same offset vectors the circuit surrogate consumes.
N_GAUSS_OFFSETS = 4
N_ALPHA_OFFSETS = 2


@dataclasses.dataclass(frozen=True)
class VariantSet:
    """Standard-normal mismatch draws for ``V`` instances of one classifier.

    Shapes: ``gauss (V, m, d, 4)`` — per SV x feature Gaussian cell,
    ``alpha (V, m, 2)`` — per-SV alpha multiplier, ``comparator (V,)`` —
    one comparator per instance.  Row 0 is the all-zero *nominal* instance
    when sampled with ``include_nominal=True`` (the default everywhere):
    its evaluation is bit-identical to the un-varied path.
    """

    gauss: jnp.ndarray
    alpha: jnp.ndarray
    comparator: jnp.ndarray

    @property
    def n_variants(self) -> int:
        return int(self.gauss.shape[0])

    @property
    def n_support(self) -> int:
        return int(self.gauss.shape[1])

    @property
    def n_features(self) -> int:
        return int(self.gauss.shape[2])

    def iter_chunks(self, chunk_size: int):
        """Yield ``(start, VariantSet)`` slices of at most ``chunk_size``
        variants — the host-side streaming view of a materialized set.

        The tail chunk keeps its natural (smaller) length; callers that
        need one compiled shape pad it themselves (the streaming machine
        never materializes a ``VariantSet`` this large in the first place
        — it draws chunks on the fly with :func:`sample_variant_chunk`).
        """
        if chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
        for start in range(0, self.n_variants, chunk_size):
            sl = slice(start, start + chunk_size)
            yield start, VariantSet(gauss=self.gauss[sl],
                                    alpha=self.alpha[sl],
                                    comparator=self.comparator[sl])


jax.tree_util.register_dataclass(
    VariantSet, data_fields=["gauss", "alpha", "comparator"], meta_fields=[])


def variant_dim(n_support: int, n_features: int) -> int:
    """Flat mismatch dimension of ONE classifier circuit instance:
    ``m*d`` Gaussian cells x 4 offsets + ``m`` alpha multipliers x 2
    offsets + 1 comparator offset.  The per-pair slice width of the
    QMC/importance-sampling uniform block (DESIGN.md §10)."""
    return (n_support * n_features * N_GAUSS_OFFSETS
            + n_support * N_ALPHA_OFFSETS + 1)


def variant_set_from_flat(
    z: jnp.ndarray, n_support: int, n_features: int,
    sigma_scale: float = 1.0,
) -> VariantSet:
    """Reshape flat standard-normal draws ``z (..., D)`` into a
    :class:`VariantSet` with the same leading dims.

    ``D = variant_dim(n_support, n_features)``; the layout is
    ``[gauss (m*d*4) | alpha (m*2) | comparator (1)]``.  This is how the
    QMC path turns one scrambled-Sobol row into a variant: every mismatch
    dimension owns a fixed coordinate of the low-discrepancy point set.
    """
    m, d = int(n_support), int(n_features)
    ng = m * d * N_GAUSS_OFFSETS
    na = m * N_ALPHA_OFFSETS
    if z.shape[-1] != ng + na + 1:
        raise ValueError(
            f"flat mismatch block has {z.shape[-1]} dims, expected "
            f"{ng + na + 1} for m={m}, d={d}")
    lead = z.shape[:-1]
    s = jnp.float32(sigma_scale)
    return VariantSet(
        gauss=s * z[..., :ng].reshape(lead + (m, d, N_GAUSS_OFFSETS)),
        alpha=s * z[..., ng:ng + na].reshape(lead + (m, N_ALPHA_OFFSETS)),
        comparator=s * z[..., ng + na])


def sample_variant_chunk(
    key: jax.Array,
    v_idx: jnp.ndarray,
    n_support: int,
    n_features: int,
    sigma_scale: float = 1.0,
) -> VariantSet:
    """Draw mismatch for the *global* variant indices ``v_idx (B,)`` only.

    The streaming generation contract (DESIGN.md §10): variant ``v``'s
    offsets are a pure function of ``(key, v)`` via
    ``fold_in(key, v) -> split(3)`` — never of the chunk size or position —
    so a V=10^6 run never materializes more than one chunk of draws and
    re-chunking the same key reproduces the identical stream.  ``fold_in``
    derives a fresh key per index (it does not consume ``key``); the
    3-way split mirrors :func:`sample_variant_offsets`'s independent
    gauss/alpha/comparator streams.  Traceable: ``v_idx`` may be a traced
    int array inside the streaming machine's jitted chunk step.
    """
    s = jnp.float32(sigma_scale)

    def draw(idx):
        kg, ka, kc = jax.random.split(jax.random.fold_in(key, idx), 3)
        return VariantSet(
            gauss=s * jax.random.normal(
                kg, (n_support, n_features, N_GAUSS_OFFSETS)),
            alpha=s * jax.random.normal(
                ka, (n_support, N_ALPHA_OFFSETS)),
            comparator=s * jax.random.normal(kc, ()))

    return jax.vmap(draw)(jnp.asarray(v_idx))


def sample_variant_offsets(
    key: jax.Array,
    n_variants: int,
    n_support: int,
    n_features: int,
    include_nominal: bool = True,
    sigma_scale: float = 1.0,
) -> VariantSet:
    """Draw mismatch offsets for ``n_variants`` fabricated instances.

    ``key`` is an explicit ``jax.random`` key — there is no hidden global
    RNG state anywhere in the Monte-Carlo path.  ``sigma_scale`` multiplies
    the standard-normal draws, i.e. scales every process sigma
    (``sigma_vth``, ``mirror_err``, ``lambda_ds``, ``comparator_sigma``)
    jointly — the knob behind yield-vs-sigma sweeps.  With
    ``include_nominal`` (default) row 0 is the zero-offset instance, so
    ``n_variants`` counts it and ``n_variants - 1`` random instances are
    drawn.
    """
    if n_variants < 1 + int(include_nominal):
        raise ValueError(
            f"n_variants={n_variants} too small (include_nominal="
            f"{include_nominal})")
    v = n_variants - 1 if include_nominal else n_variants
    kg, ka, kc = jax.random.split(key, 3)
    s = jnp.float32(sigma_scale)
    gauss = s * jax.random.normal(kg, (v, n_support, n_features,
                                       N_GAUSS_OFFSETS))
    alpha = s * jax.random.normal(ka, (v, n_support, N_ALPHA_OFFSETS))
    comparator = s * jax.random.normal(kc, (v,))
    if include_nominal:
        gauss = jnp.concatenate([jnp.zeros_like(gauss[:1]), gauss])
        alpha = jnp.concatenate([jnp.zeros_like(alpha[:1]), alpha])
        comparator = jnp.concatenate(
            [jnp.zeros_like(comparator[:1]), comparator])
    return VariantSet(gauss=gauss, alpha=alpha, comparator=comparator)


@dataclasses.dataclass(frozen=True)
class VariantTransferParams:
    """Per-cell perturbations of the measured transfers for ``V`` instances.

    The raw circuit offsets of a :class:`VariantSet` are reduced to the
    four quantities a *calibrated* instance's transfer actually moves by
    (see DESIGN.md §6.2 for the derivation from the surrogate equations):

    * ``shift (V, m, d)``   — Gaussian-cell bell center shift (V): the
      common-mode vth offset of the two differential pairs,
    * ``gain (V, m, d)``    — cell peak gain: mirror-ratio and V_DS errors
      times the peak attenuation ``4 sig(-e)(1 - sig(e))`` of the
      *differential* vth offset ``e`` between the two pairs,
    * ``alpha_shift (V, m)`` / ``alpha_slope (V, m)`` — alpha-multiplier
      control-voltage offset and logistic slope scale,
    * ``comp_offset (V,)``  — comparator offset in units of I_in.

    All-zero draws reduce to shift 0, gain 1, slope 1 and the nominal
    comparator offset *exactly* (0.5 and 1.0 are exact in f32), so the
    nominal variant's arithmetic is bit-identical to the un-varied path.
    """

    shift: jnp.ndarray
    gain: jnp.ndarray
    alpha_shift: jnp.ndarray
    alpha_slope: jnp.ndarray
    comp_offset: jnp.ndarray


jax.tree_util.register_dataclass(
    VariantTransferParams,
    data_fields=["shift", "gain", "alpha_shift", "alpha_slope",
                 "comp_offset"],
    meta_fields=[])


def variant_transfer_params(
    v: VariantSet, p: CircuitParams
) -> VariantTransferParams:
    """Reduce raw mismatch draws to measured-transfer perturbations."""
    nvt = p.n * p.v_t
    g = v.gauss
    shift = (0.5 * (g[..., 0] + g[..., 1])) * p.sigma_vth
    diff = (0.5 * (g[..., 0] - g[..., 1])) * (p.sigma_vth / nvt)
    peak = 4.0 * _pair_fraction(-diff) * (1.0 - _pair_fraction(diff))
    gain = (peak
            * (1.0 + g[..., 2] * p.mirror_err)
            * (1.0 + g[..., 3] * p.lambda_ds))
    alpha_shift = v.alpha[..., 0] * p.sigma_vth
    alpha_slope = 1.0 + v.alpha[..., 1] * 0.02
    # Nominal offset divided in f64 first so variant 0 carries the exact
    # f32 cast of the same number the nominal lowering stores.
    comp_offset = (p.comparator_offset / p.i_bias
                   + v.comparator * (p.comparator_sigma / p.i_bias))
    return VariantTransferParams(
        shift=shift, gain=gain, alpha_shift=alpha_shift,
        alpha_slope=alpha_slope, comp_offset=comp_offset)


# --------------------------------------------------------------------------
# Fits (Sec. IV-A): ideal Gaussian (Eq. 7) and logistic (Eq. 9)
# --------------------------------------------------------------------------


def fit_gaussian(dv: np.ndarray, i_out: np.ndarray) -> tuple[float, float, float]:
    """Weighted LS fit of A0 exp(-g0 (dv-mu)^2) -> (A0, gamma0, mu).

    log I = a + b dv + c dv^2 with weights I^2 (emphasises the bell's core,
    where Eq. 5's Taylor matching holds), then gamma0 = -c, mu = b/(2 gamma0).
    """
    i = np.clip(np.asarray(i_out, np.float64), 1e-12, None)
    w = i * i
    v = np.asarray(dv, np.float64)
    basis = np.stack([np.ones_like(v), v, v * v], axis=1)
    wb = basis * w[:, None]
    coef = np.linalg.solve(basis.T @ wb, wb.T @ np.log(i))
    a, b, c = coef
    gamma0 = max(-c, 1e-9)
    mu = b / (2.0 * gamma0)
    a0 = float(np.exp(a + gamma0 * mu * mu))
    return a0, float(gamma0), float(mu)


def fit_logistic(dva: np.ndarray, ratio: np.ndarray) -> tuple[float, float]:
    """Fit  dV_alpha = x0 + s * ln(1/ratio - 1)  (Eq. 9) -> (x0, s)."""
    r = np.asarray(ratio, np.float64)
    keep = (r > 1e-4) & (r < 1.0 - 1e-4)
    z = np.log(1.0 / r[keep] - 1.0)
    v = np.asarray(dva, np.float64)[keep]
    s, x0 = np.polyfit(z, v, 1)
    return float(x0), float(s)


def nrmse(ref: np.ndarray, meas: np.ndarray) -> float:
    ref = np.asarray(ref, np.float64)
    meas = np.asarray(meas, np.float64)
    rng = float(ref.max() - ref.min()) or 1.0
    return float(np.sqrt(np.mean((ref - meas) ** 2)) / rng)


def pearson_r(a: np.ndarray, b: np.ndarray) -> float:
    a = np.asarray(a, np.float64).ravel()
    b = np.asarray(b, np.float64).ravel()
    return float(np.corrcoef(a, b)[0, 1])


# --------------------------------------------------------------------------
# Behavioral model (Sec. IV-A) and hardware-deployed classifier
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AnalogRBFModel:
    """High-level behavioral model of one fabricated analog RBF core."""

    params: CircuitParams
    dv_grid: np.ndarray          # measured sweep abscissa (V)
    kernel_curve: np.ndarray     # measured I_out/I_in, normalised to peak 1
    a0: float                    # fitted Gaussian amplitude (Eq. 7)
    gamma0: float                # fitted gamma0 (1/V^2)
    mu: float                    # fitted center offset (V)
    alpha_x0: float              # logistic fit (Eq. 9)
    alpha_s: float
    dva_grid: np.ndarray         # measured alpha-sweep abscissa (V)
    alpha_curve: np.ndarray      # measured alpha multiplier ratio
    v_scale: float = 0.5         # feature-unit -> volt mapping

    @classmethod
    def from_circuit(
        cls,
        p: CircuitParams = CircuitParams(),
        key: Optional[jax.Array] = None,
        v_scale: float = 0.5,
    ) -> "AnalogRBFModel":
        """Calibrate the behavioral model from surrogate-SPICE DC sweeps.

        ``key`` seeds the fabricated instance's mismatch draws; the key is
        split so the Gaussian-cell and alpha-multiplier sweeps see
        *independent* offsets (reusing one key for two different draws
        silently correlates the two circuits).
        """
        kg = ka = None
        if key is not None:
            kg, ka = jax.random.split(key)
        dv, curve = dc_sweep_gaussian(p, kg)
        a0, g0, mu = fit_gaussian(dv, curve)
        dva, ratio = dc_sweep_alpha(p, ka)
        x0, s = fit_logistic(dva, ratio)
        return cls(
            params=p, dv_grid=dv, kernel_curve=curve / curve.max(),
            a0=a0, gamma0=g0, mu=mu, alpha_x0=x0, alpha_s=s,
            dva_grid=dva, alpha_curve=ratio, v_scale=v_scale,
        )

    # -- kernel ------------------------------------------------------------
    def gamma0_feature(self) -> float:
        """Fitted cell gamma expressed in (normalised-feature)^-2 units."""
        return self.gamma0 * self.v_scale * self.v_scale

    def input_scale(self, gamma_star) -> jnp.ndarray:
        """Eq. (8): s_gamma = sqrt(gamma*/gamma0).  jnp so it traces under
        vmap'd hyper-parameter grids during hardware-in-the-loop training."""
        return jnp.sqrt(jnp.asarray(gamma_star) / self.gamma0_feature())

    def kernel_1d(self, dv_volts: jnp.ndarray) -> jnp.ndarray:
        """Interpolate the measured transfer curve (paper: 'use the SPICE
        data together with the fitted gamma0').

        The fitted center offset ``mu`` (threshold-mismatch shift, Eq. 7) is
        compensated here: a fabricated core is calibrated so its bell peaks
        at zero differential input, which is exactly what fitting mu enables.
        """
        return jnp.interp(
            dv_volts + self.mu,
            jnp.asarray(self.dv_grid), jnp.asarray(self.kernel_curve),
            left=float(self.kernel_curve[0]), right=float(self.kernel_curve[-1]),
        )

    def kernel_1d_variants(
        self, dv_volts: jnp.ndarray, shift: jnp.ndarray, gain: jnp.ndarray
    ) -> jnp.ndarray:
        """Per-cell measured transfer under mismatch (DESIGN.md §6.2):

            ``gain * curve(dv + mu - shift)``

        ``dv_volts`` broadcasts against the per-cell ``shift``/``gain`` of
        :func:`variant_transfer_params` (typically ``dv (n, m, d)`` against
        ``(V, 1, m, d)`` for the ``(V, n, m, d)`` variant tensor).  With
        zero offsets this is ``curve(dv + mu) * 1.0`` — the exact
        :meth:`kernel_1d` arithmetic, bit for bit.
        """
        return gain * jnp.interp(
            dv_volts + self.mu - shift,
            jnp.asarray(self.dv_grid), jnp.asarray(self.kernel_curve),
            left=float(self.kernel_curve[0]), right=float(self.kernel_curve[-1]),
        )

    def kernel_response_variants(
        self,
        x: jnp.ndarray,
        sv: jnp.ndarray,
        gamma_star,
        shift: jnp.ndarray,
        gain: jnp.ndarray,
    ) -> jnp.ndarray:
        """Separable kernel of ``V`` mismatched instances: ``(V, n, m)``.

        ``x (n, d)``, ``sv (m, d)``, ``shift``/``gain (V, m, d)`` — every
        one of the ``V * m * d`` Gaussian cells evaluates its own perturbed
        transfer, vectorized over the whole ``(V, m)`` grid instead of the
        one shared 1-D curve of :meth:`kernel_response`.
        """
        s = self.input_scale(gamma_star)
        dv = self.v_scale * s * (x[:, None, :] - sv[None, :, :])  # (n, m, d)
        k = self.kernel_1d_variants(
            dv[None], shift[:, None], gain[:, None])              # (V, n, m, d)
        return jnp.prod(k, axis=-1)

    def kernel_response(
        self, x: jnp.ndarray, sv: jnp.ndarray, gamma_star
    ) -> jnp.ndarray:
        """Separable D-dim kernel (Eq. 6 + Eq. 8): x (n,d), sv (m,d) -> (n,m).

        This IS the paper's high-level behavioral model, and it is also the
        kernel used to TRAIN analog-bound classifiers (hardware-in-the-loop
        co-optimization) — so the deployed circuit computes with the exact
        kernel it was trained with.
        """
        s = self.input_scale(gamma_star)
        dv = self.v_scale * s * (x[:, None, :] - sv[None, :, :])
        return jnp.prod(self.kernel_1d(dv), axis=-1)

    # -- alpha multiplier ----------------------------------------------------
    def alpha_control_voltage(self, alpha: jnp.ndarray) -> jnp.ndarray:
        """Software mapping Eq. (9): desired alpha -> control differential."""
        a = jnp.clip(alpha, 1e-4, 1.0 - 1e-4)
        return self.alpha_x0 + self.alpha_s * jnp.log(1.0 / a - 1.0)

    def alpha_realized(self, dva: jnp.ndarray) -> jnp.ndarray:
        """Alpha the circuit actually realises for a control voltage —
        interpolated from the *measured* sweep of this fabricated instance
        (the same instance the logistic was fitted to)."""
        grid = jnp.asarray(self.dva_grid)
        curve = jnp.asarray(self.alpha_curve)
        order = jnp.argsort(grid)  # interp needs ascending x
        return jnp.interp(
            dva, grid[order], curve[order],
            left=float(curve[np.argmin(self.dva_grid)]),
            right=float(curve[np.argmax(self.dva_grid)]),
        )


@dataclasses.dataclass(frozen=True)
class AnalogBinaryClassifier:
    """A trained RBF SVM deployed on the analog hardware model (Sec. III-B)."""

    hw: AnalogRBFModel
    support_x: np.ndarray   # (m, d) hardwired SV bias voltages
    support_y: np.ndarray   # (m,) rail routing
    alpha_hw: np.ndarray    # (m,) normalised to (0, 1)
    bias_hw: float          # constant rail current (units of I_in)
    gamma_star: float

    @classmethod
    def deploy(
        cls,
        model: SVMModel,
        hw: AnalogRBFModel,
        alpha_floor_rel: float = 1.0 / 256.0,
    ) -> "AnalogBinaryClassifier":
        """Deploy an RBF-family SVM onto the analog hardware model.

        ``alpha_floor_rel`` prunes support vectors whose normalised dual
        coefficient falls below the alpha-control DAC resolution (8-bit by
        default): such alphas are indistinguishable from switch leakage in
        the fabricated circuit, so their cells are simply not instantiated.
        The pruned mass is bounded by m * floor, keeping the decision
        function perturbation below comparator resolution.
        """
        if model.kind not in ("rbf", "sech2", "hw"):
            raise ValueError("only RBF-family classifiers are deployed in analog")
        alpha = np.asarray(model.alpha, np.float64)
        amax = float(alpha.max()) if alpha.size else 1.0
        keep = np.flatnonzero(alpha >= alpha_floor_rel * amax)
        # Positive rescale (sign-invariant): alphas into the multiplier's (0,1).
        scale = amax * 1.05
        return cls(
            hw=hw,
            support_x=model.support_x[keep],
            support_y=model.support_y[keep],
            alpha_hw=alpha[keep] / scale,
            bias_hw=float(model.bias / scale),
            gamma_star=float(model.gamma),
        )

    def rail_currents(self, x: np.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
        """(I_plus, I_minus) per input row, in units of I_in."""
        xj = jnp.asarray(x, jnp.float32)
        k = self.hw.kernel_response(
            xj, jnp.asarray(self.support_x, jnp.float32), self.gamma_star
        )  # (n, m)
        # Alpha path: desired -> control voltage (Eq. 9) -> realised (circuit).
        dva = self.hw.alpha_control_voltage(jnp.asarray(self.alpha_hw, jnp.float32))
        a = self.hw.alpha_realized(dva)
        cur = k * a[None, :]
        pos = jnp.asarray(self.support_y > 0, jnp.float32)
        i_plus = cur @ pos + jnp.maximum(self.bias_hw, 0.0)
        i_minus = cur @ (1.0 - pos) + jnp.maximum(-self.bias_hw, 0.0)
        return i_plus, i_minus

    def predict_bits(self, x: np.ndarray) -> np.ndarray:
        """Comparator output: 1 if the + rail wins (class i of the pair)."""
        i_plus, i_minus = self.rail_currents(x)
        off = self.hw.params.comparator_offset / self.hw.params.i_bias
        return np.asarray(i_plus - i_minus + off >= 0.0, np.int32)

    # -- Monte-Carlo variation (DESIGN.md §6) --------------------------------

    def sample_variants(
        self,
        key: jax.Array,
        n_variants: int,
        include_nominal: bool = True,
        sigma_scale: float = 1.0,
    ) -> VariantSet:
        """Draw per-SV-cell mismatch for ``n_variants`` instances of THIS
        classifier's circuit (its ``m x d`` Gaussian cells, ``m`` alpha
        multipliers and one comparator)."""
        return sample_variant_offsets(
            key, n_variants, self.n_support, self.n_features,
            include_nominal=include_nominal, sigma_scale=sigma_scale)

    def decision_mc(self, x: np.ndarray, variants: VariantSet) -> jnp.ndarray:
        """Comparator input ``I+ - I- + offset`` per variant: ``(V, n)``.

        Every instance evaluates its own perturbed per-cell transfers
        (Gaussian cells AND alpha multipliers AND comparator) vectorized
        over the ``(V, m)`` grid; the zero-offset row reproduces the
        nominal :meth:`rail_currents`/:meth:`predict_bits` arithmetic
        bit for bit.
        """
        t = variant_transfer_params(variants, self.hw.params)
        xj = jnp.asarray(x, jnp.float32)
        k = self.hw.kernel_response_variants(
            xj, jnp.asarray(self.support_x, jnp.float32), self.gamma_star,
            t.shift, t.gain)                                      # (V, n, m)
        dva = self.hw.alpha_control_voltage(
            jnp.asarray(self.alpha_hw, jnp.float32))              # (m,)
        a = self.hw.alpha_realized(
            (dva[None, :] - t.alpha_shift) / t.alpha_slope)       # (V, m)
        cur = k * a[:, None, :]
        pos = jnp.asarray(self.support_y > 0, jnp.float32)
        neg = 1.0 - pos
        # Rail accumulation per variant with the exact nominal (n, m)@(m,)
        # matvec shapes: batched/reshaped contractions reduce m in a
        # different order on CPU (observed 1-ulp drift), which would break
        # the nominal-variant bit-identity contract.  This is the reference
        # path — the compiled MonteCarloMachine is the throughput path.
        bias_p = jnp.maximum(self.bias_hw, 0.0)
        bias_n = jnp.maximum(-self.bias_hw, 0.0)
        rows = [(cur[i] @ pos + bias_p) - (cur[i] @ neg + bias_n)
                for i in range(cur.shape[0])]
        return jnp.stack(rows) + t.comp_offset[:, None]

    def predict_bits_mc(
        self, x: np.ndarray, variants: VariantSet
    ) -> np.ndarray:
        """Per-variant comparator bits ``(V, n)`` int32."""
        return np.asarray(self.decision_mc(x, variants) >= 0.0, np.int32)

    @property
    def n_support(self) -> int:
        return int(self.support_x.shape[0])

    @property
    def n_features(self) -> int:
        return int(self.support_x.shape[1])


# --------------------------------------------------------------------------
# Pytree registration: the behavioral model and the deployed classifier are
# batchable JAX containers (array/scalar fields are leaves; the frozen
# CircuitParams rides along as static aux data), so a stacked model vmaps
# over a leading variant/instance axis end-to-end.
# --------------------------------------------------------------------------

_RBF_MODEL_LEAVES = ("dv_grid", "kernel_curve", "a0", "gamma0", "mu",
                     "alpha_x0", "alpha_s", "dva_grid", "alpha_curve",
                     "v_scale")
_CLF_LEAVES = ("hw", "support_x", "support_y", "alpha_hw", "bias_hw",
               "gamma_star")


def _rbf_model_flatten(m: "AnalogRBFModel"):
    return tuple(getattr(m, f) for f in _RBF_MODEL_LEAVES), m.params


def _rbf_model_unflatten(params: CircuitParams, leaves) -> "AnalogRBFModel":
    return AnalogRBFModel(params, *leaves)


def _clf_flatten(c: "AnalogBinaryClassifier"):
    return tuple(getattr(c, f) for f in _CLF_LEAVES), None


def _clf_unflatten(_, leaves) -> "AnalogBinaryClassifier":
    return AnalogBinaryClassifier(*leaves)


jax.tree_util.register_pytree_node(
    AnalogRBFModel, _rbf_model_flatten, _rbf_model_unflatten)
jax.tree_util.register_pytree_node(
    AnalogBinaryClassifier, _clf_flatten, _clf_unflatten)
