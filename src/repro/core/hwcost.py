"""FlexIC area/power cost model (paper Sec. V; stands in for Synopsys DC).

Two halves, mirroring the paper's mixed-signal split:

* **Digital** — a gate-equivalent (GE) model of the bespoke R-NMOS datapaths:
  constant-coefficient multipliers cost one adder per CSD non-zero digit
  (zero / power-of-two weights are FREE — the effect the paper observes on
  Balance), ripple adder trees, exact exp units for the digital-RBF baseline,
  the decision encoder (literal count of its truth table), and per-feature
  ADCs.  FE power is 99% static [23], so power is proportional to device
  count: both area and power scale with GE through two unit constants.

* **Analog** — a component-level model built from the Table I device
  geometries: each 1-D Gaussian cell is Q1..Q6 + R1 + R2, each alpha
  multiplier is 4 transistors, plus rail switches, a comparator (sized from
  [34]) and a layout/wiring overhead factor.  Power is bias-current x supply
  per subthreshold branch.

Calibration (documented in EXPERIMENTS.md): the two digital unit constants
(`area_per_ge`, `power_per_ge`) are fitted once against the *linear digital*
column of Table II; the two analog constants (`layout_factor`,
`comparator_*`) against the paper's stated analog-vs-digital-linear ratios
(2.5x area / 12.4x power).  Every OTHER number — digital-RBF totals, the
108x/17x mixed-vs-RBF gains, Fig. 5 breakdowns — is emergent.

Layering (DESIGN.md §5.1): the module is split into *pure per-classifier
primitives* (``classifier_cost`` and the GE counters it dispatches to) and
two consumers of them:

  * ``system_cost``        — the legacy object-bank walk, now a thin shim
                             that sums ``classifier_cost`` over a deployed
                             ``MulticlassSVM`` (plus encoder + ADC);
  * ``pair_cost_table`` /
    ``assignment_costs``   — the vectorized design-space path: price the
                             per-pair candidate classifiers ONCE, then cost
                             any ``(S, P)`` boolean assignment matrix
                             (pair -> linear-digital vs RBF-analog) in one
                             numpy pass.  Proven equal to ``system_cost``
                             on the corresponding object banks to f64
                             round-off (tests/test_dse.py).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import quant
from repro.core.analog import AnalogBinaryClassifier
from repro.core.ovo import (
    MAX_TABLE_BITS,
    DigitalLinearClassifier,
    DigitalRBFClassifier,
    MulticlassSVM,
    build_encoder_table,
)

# ---------------------------------------------------------------------------
# Gate-equivalent counts for digital blocks
# ---------------------------------------------------------------------------

FA_GE = 4.5          # full adder in R-NMOS unipolar logic
AND_GE = 1.0
ROM_BIT_GE = 0.25
ADC_GE = 110.0       # 4-bit SAR ADC digital part + comparator/DAC equivalent
EXP_GE = 450.0       # exact fixed-point exp unit (PWL, to-LSB-exact)


def adder_ge(width: int) -> float:
    return FA_GE * max(width, 1)


def adder_tree_ge(n_terms: int, width: int) -> float:
    """Balanced tree of (n_terms-1) ripple adders; width grows by level."""
    if n_terms <= 1:
        return 0.0
    total, level, terms = 0.0, 0, n_terms
    while terms > 1:
        pairs = terms // 2
        total += pairs * adder_ge(width + level)
        terms = terms - pairs
        level += 1
    return total


def const_mult_ge(code: int, in_bits: int, w_bits: int) -> float:
    """Bespoke constant multiplier: (CSD digits - 1) adders; 0/pow2 free."""
    cls = quant.weight_hardware_class(code)
    if cls in ("zero", "pow2"):
        return 0.0
    digits = quant.csd_nonzero_digits(code)
    return max(digits - 1, 1) * adder_ge(in_bits + w_bits)


def array_mult_ge(b1: int, b2: int) -> float:
    """General array multiplier."""
    return AND_GE * b1 * b2 + (b1 - 1) * adder_ge(b2)


def squarer_ge(bits: int) -> float:
    """Dedicated squarer ~ half an array multiplier (symmetry folding)."""
    return 0.55 * array_mult_ge(bits, bits)


def encoder_ge(n_classes: int) -> float:
    """Decision encoder (Fig. 1): 2-level AND-OR from its truth table.

    Past the packed-table regime (P > MAX_TABLE_BITS, i.e. K > 5) the
    hardwired AND-OR plane is unbuildable (2^P minterms); the deployed
    decision logic is then a votes realisation — K population counters
    over each class's K-1 pair bits plus a log2(K)-deep argmax comparator
    tree — costed from the same adder primitives.
    """
    n_in = int(math.comb(n_classes, 2))
    out_bits = max(int(np.ceil(np.log2(max(n_classes, 2)))), 1)
    if n_in > MAX_TABLE_BITS:
        cnt_bits = out_bits  # ceil(log2(K)) >= ceil(log2(K-1+1)) counter width
        counters = n_classes * adder_tree_ge(n_classes - 1, 1)
        argmax = (n_classes - 1) * (
            adder_ge(cnt_bits)             # magnitude comparator ~ subtractor
            + AND_GE * (cnt_bits + out_bits))  # index/count muxes
        return counters + argmax + out_bits * AND_GE
    table = build_encoder_table(n_classes)
    # minterms where each output bit is 1; each minterm = one n_in-input AND.
    literals = 0
    for b in range(out_bits):
        on = int(np.sum((table >> b) & 1))
        literals += min(on, len(table) - on) * n_in
    return literals * AND_GE * 0.5 + out_bits * AND_GE  # crude 2-level logic


# ---------------------------------------------------------------------------
# Per-classifier GE
# ---------------------------------------------------------------------------


def linear_classifier_ge(clf: DigitalLinearClassifier) -> float:
    codes = clf.weight_codes()
    w_codes, b_code = codes[:-1], codes[-1]
    in_b, w_b = clf.input_bits, clf.w_fp.bits
    ge = 0.0
    nonzero_products = 0
    for c in w_codes:
        ge += const_mult_ge(int(c), in_b, w_b)
        if int(c) != 0:
            nonzero_products += 1
    prod_width = in_b + w_b
    ge += adder_tree_ge(nonzero_products, prod_width)
    if int(b_code) != 0:
        ge += adder_ge(prod_width + 2)  # bias addition
    ge += 1.0  # sign = MSB tap + buffer
    return ge


def digital_rbf_classifier_ge(clf: DigitalRBFClassifier) -> float:
    m, d = clf.n_support, clf.n_features
    in_b = clf.input_bits + 1           # signed difference
    sq_b = 2 * clf.input_bits + 1
    ge_sv = (
        d * (adder_ge(in_b) + squarer_ge(in_b))      # (x_d - s_d)^2
        + adder_tree_ge(d, sq_b)                     # sum over dims
        + array_mult_ge(clf.sv_fp.bits, sq_b)        # * gamma (fixed point)
        + EXP_GE                                     # exp(-.)
        + array_mult_ge(clf.coef_fp.bits, clf.sv_fp.bits)  # * alpha_j y_j
    )
    ge = m * ge_sv + adder_tree_ge(m, clf.coef_fp.bits + clf.sv_fp.bits)
    ge += adder_ge(clf.coef_fp.bits + clf.sv_fp.bits + int(np.ceil(np.log2(max(m, 2)))))
    ge += 1.0
    return ge


# ---------------------------------------------------------------------------
# Analog component-level model (Table I geometries)
# ---------------------------------------------------------------------------

# Device areas in um^2 straight from Table I.
_GAUSS_CELL_UM2 = (
    4 * (40.0 * 0.6)      # Q1-Q3, Q6
    + (1.0 * 0.6)         # Q4
    + (20.0 * 1.2)        # Q5
    + (0.6 * 28.5)        # R1 = 10 MOhm
    + (0.6 * 12.2)        # R2 = 4.28 MOhm
)
_ALPHA_MULT_UM2 = 4 * (40.0 * 0.6)   # Q1-Q4
_RAIL_SWITCH_UM2 = 2 * (10.0 * 0.6)  # y_j routing switch


@dataclasses.dataclass
class CostModel:
    """Unit constants; see module docstring for the calibration protocol."""

    # digital units (calibrated on Table II linear column)
    area_per_ge_um2: float = 28.0
    power_per_ge_nw: float = 4.6
    # analog units
    layout_factor: float = 1.6           # wiring/bias-distribution overhead
    i_bias_na: float = 150.0             # per-branch subthreshold bias (nA)
    v_analog: float = 1.0                # analog supply (V)
    branches_per_cell: float = 2.0       # kernel chain + readout branch
    comparator_area_um2: float = 5200.0  # from [34]
    comparator_power_nw: float = 580.0

    # -- digital ------------------------------------------------------------
    def digital(self, ge: float) -> tuple[float, float]:
        """GE -> (area mm^2, power mW)."""
        return (
            ge * self.area_per_ge_um2 * 1e-6,
            ge * self.power_per_ge_nw * 1e-6,
        )

    def adc(self, n_features: int) -> tuple[float, float]:
        return self.digital(n_features * ADC_GE)

    # -- analog -------------------------------------------------------------
    def analog_rbf(self, clf: AnalogBinaryClassifier) -> tuple[float, float]:
        m, d = clf.n_support, clf.n_features
        dev_um2 = m * (d * _GAUSS_CELL_UM2 + _ALPHA_MULT_UM2 + _RAIL_SWITCH_UM2)
        area_mm2 = (dev_um2 * self.layout_factor + self.comparator_area_um2) * 1e-6
        branches = m * (d * self.branches_per_cell + 1.0)  # + alpha multiplier
        power_mw = (
            branches * self.i_bias_na * 1e-9 * self.v_analog * 1e3
            + self.comparator_power_nw * 1e-6
        )
        return area_mm2, power_mw


@dataclasses.dataclass
class SystemCost:
    area_mm2: float
    power_mw: float
    area_analog_mm2: float
    power_analog_mw: float
    area_digital_mm2: float
    power_digital_mw: float

    @property
    def analog_area_frac(self) -> float:
        return self.area_analog_mm2 / self.area_mm2 if self.area_mm2 else 0.0

    @property
    def analog_power_frac(self) -> float:
        return self.power_analog_mw / self.power_mw if self.power_mw else 0.0


def classifier_cost(clf, cm: CostModel) -> tuple[float, float, str]:
    """Pure per-classifier cost primitive: ``(area mm^2, power mW, domain)``.

    ``domain`` is ``'digital'`` (the classifier consumes digitized inputs —
    it forces the per-feature ADC bank to exist) or ``'analog'`` (reads the
    sensor rails directly).  Every cost consumer — the object-bank shim
    ``system_cost`` and the vectorized ``assignment_costs`` path — prices
    classifiers through this single dispatch, so the two paths cannot drift.
    """
    if isinstance(clf, DigitalLinearClassifier):
        a, p = cm.digital(linear_classifier_ge(clf))
        return a, p, "digital"
    if isinstance(clf, DigitalRBFClassifier):
        a, p = cm.digital(digital_rbf_classifier_ge(clf))
        return a, p, "digital"
    if isinstance(clf, AnalogBinaryClassifier):
        a, p = cm.analog_rbf(clf)
        return a, p, "analog"
    # float adapters — no hardware
    raise TypeError(f"cannot cost a non-deployed classifier: {type(clf)}")


def system_cost(svm: MulticlassSVM, cm: CostModel) -> SystemCost:
    """Total cost of a deployed multiclass SVM incl. encoder and ADCs.

    Thin shim over :func:`classifier_cost` (DESIGN.md §5.1): walks the
    object bank once, summing the per-classifier primitives, then adds the
    encoder and — only if at least one digital classifier consumes
    digitized inputs — the per-feature ADC bank (analog RBF reads the
    sensor rails directly; that is the point of the mixed-signal
    architecture).  The vectorized ``assignment_costs`` path prices the
    same quantities from a precomputed per-pair table and is proven equal
    to this walk to f64 round-off.
    """
    a_d = p_d = a_a = p_a = 0.0
    needs_adc_features = 0
    for clf in svm.classifiers:
        a, p, domain = classifier_cost(clf, cm)
        if domain == "digital":
            a_d += a; p_d += p
            needs_adc_features = max(needs_adc_features, clf.n_features)
        else:
            a_a += a; p_a += p
    a, p = cm.digital(encoder_ge(svm.n_classes))
    a_d += a; p_d += p
    if needs_adc_features:
        a, p = cm.adc(needs_adc_features)
        a_d += a; p_d += p
    return SystemCost(
        area_mm2=a_d + a_a, power_mw=p_d + p_a,
        area_analog_mm2=a_a, power_analog_mw=p_a,
        area_digital_mm2=a_d, power_digital_mw=p_d,
    )


# ---------------------------------------------------------------------------
# Vectorized assignment costing (the DSE cost path, DESIGN.md §5.2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PairCostTable:
    """Per-pair candidate costs, priced once for a whole design space.

    Column 0 is the pair's linear-digital candidate, column 1 its
    RBF-analog candidate (any deployed classifier type is accepted — each
    candidate is priced by its actual domain).  All arrays are ``(P, 2)``
    float64; ``assignment_costs`` contracts them against an ``(S, P)``
    boolean assignment matrix in one numpy pass.  ``n_features`` is the
    candidate's ADC feature demand — its feature count for digital
    candidates, 0 for analog ones (which read the sensor rails directly).
    """

    area: np.ndarray          # (P, 2) per-candidate area mm^2
    power: np.ndarray         # (P, 2) per-candidate power mW
    n_features: np.ndarray    # (P, 2) ADC feature demand (0 for analog)
    encoder_area: float
    encoder_power: float
    adc_area_per_feature: float
    adc_power_per_feature: float

    @property
    def n_pairs(self) -> int:
        return int(self.area.shape[0])


def _n_classes_from_pairs(n_pairs: int) -> int:
    """Invert P = K(K-1)/2 (raises if P is not a valid pair count)."""
    k = int(round((1.0 + math.sqrt(1.0 + 8.0 * n_pairs)) / 2.0))
    if k * (k - 1) // 2 != n_pairs:
        raise ValueError(f"{n_pairs} is not K(K-1)/2 for any integer K")
    return k


def pair_cost_table(
    candidates, cm: CostModel, n_classes: int | None = None
) -> PairCostTable:
    """Price every per-pair candidate once: the DSE cost-table builder.

    ``candidates`` is a sequence of ``(linear_clf, rbf_clf)`` deployed
    classifier pairs in ``class_pairs`` order.  The shared system terms
    (decision encoder; ADC bank per digitized feature) are priced here too
    so ``assignment_costs`` is pure array arithmetic.
    """
    if n_classes is None:
        n_classes = _n_classes_from_pairs(len(candidates))
    p = len(candidates)
    area = np.zeros((p, 2))
    power = np.zeros((p, 2))
    n_features = np.zeros((p, 2))
    for i, pair_cands in enumerate(candidates):
        for j, clf in enumerate(pair_cands):
            a, pw, domain = classifier_cost(clf, cm)
            area[i, j], power[i, j] = a, pw
            if domain == "digital":
                n_features[i, j] = clf.n_features
    enc_a, enc_p = cm.digital(encoder_ge(n_classes))
    adc_a, adc_p = cm.adc(1)
    return PairCostTable(
        area=area, power=power, n_features=n_features,
        encoder_area=enc_a, encoder_power=enc_p,
        adc_area_per_feature=adc_a, adc_power_per_feature=adc_p,
    )


def assignment_costs(
    pairs, assignments: np.ndarray, cm: CostModel | None = None
) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized system cost of ``S`` candidate assignments: one numpy pass.

    ``pairs`` is either a prebuilt :class:`PairCostTable` or a sequence of
    per-pair ``(linear_clf, rbf_clf)`` candidates (then ``cm`` is
    required).  ``assignments`` is an ``(S, P)`` boolean matrix — entry
    ``[s, p]`` True assigns pair ``p`` to its RBF(-analog) candidate,
    False to its linear-digital candidate.  Returns ``(area (S,),
    power (S,))`` in mm^2 / mW, each exactly equal (to f64 round-off) to
    ``system_cost`` on the object bank assembled from the same candidates.
    """
    if not isinstance(pairs, PairCostTable):
        if cm is None:
            raise ValueError(
                "assignment_costs needs a CostModel when given raw "
                "candidate pairs (pass cm=...)")
        pairs = pair_cost_table(pairs, cm)
    t = pairs
    a = np.atleast_2d(np.asarray(assignments, bool))
    if a.shape[1] != t.n_pairs:
        raise ValueError(
            f"assignment matrix has {a.shape[1]} pairs, table has "
            f"{t.n_pairs}")
    sel = a.astype(np.float64)                       # (S, P): 1 -> rbf col
    area = sel @ t.area[:, 1] + (1.0 - sel) @ t.area[:, 0]
    power = sel @ t.power[:, 1] + (1.0 - sel) @ t.power[:, 0]
    # ADC bank: sized by the widest digitized classifier actually selected
    # (matches system_cost's max over digital classifiers; 0 features ->
    # no ADC at all, e.g. the all-analog corner).
    nf_sel = np.where(a, t.n_features[:, 1], t.n_features[:, 0])  # (S, P)
    nf = nf_sel.max(axis=1) if t.n_pairs else np.zeros(a.shape[0])
    area = area + t.encoder_area + nf * t.adc_area_per_feature
    power = power + t.encoder_power + nf * t.adc_power_per_feature
    return area, power


# ---------------------------------------------------------------------------
# Calibration against the Table II linear column
# ---------------------------------------------------------------------------

TABLE2_LINEAR = {  # dataset -> (area mm^2, power mW) of the all-linear design
    "balance": (0.024, 0.004),
    "seeds": (0.067, 0.011),
    "vertebral": (0.092, 0.014),
}

TABLE2 = {  # dataset -> design -> (acc %, area mm^2, power mW, rbf, linear)
    "balance": {
        "linear": (92, 0.024, 0.004, 0, 3),
        "rbf": (93, 13.400, 2.230, 3, 0),
        "mixed": (92, 0.062, 0.081, 1, 2),
    },
    "seeds": {
        "linear": (92, 0.067, 0.011, 0, 3),
        "rbf": (95, 7.000, 1.190, 3, 0),
        "mixed": (95, 0.125, 0.092, 1, 2),
    },
    "vertebral": {
        "linear": (69, 0.092, 0.014, 0, 3),
        "rbf": (83, 5.600, 0.960, 3, 0),
        "mixed": (89, 0.108, 0.088, 2, 1),
    },
}


def calibrate_digital(
    linear_systems: dict[str, MulticlassSVM], cm: CostModel | None = None
) -> CostModel:
    """Least-squares fit of (area_per_ge, power_per_ge) on the linear column.

    One multiplicative constant per metric: unit = sum(ref * ge) / sum(ge^2)
    minimises sum_i (ge_i * unit - ref_i)^2 over the three datasets.
    """
    cm = cm or CostModel()
    ges, areas, powers = [], [], []
    for name, sys in linear_systems.items():
        ge = sum(
            linear_classifier_ge(c) for c in sys.classifiers
        ) + encoder_ge(sys.n_classes) + ADC_GE * max(
            c.n_features for c in sys.classifiers
        )
        ref_a, ref_p = TABLE2_LINEAR[name]
        ges.append(ge); areas.append(ref_a); powers.append(ref_p)
    ges = np.asarray(ges)
    area_unit = float(np.sum(np.asarray(areas) * ges) / np.sum(ges * ges)) * 1e6
    power_unit = float(np.sum(np.asarray(powers) * ges) / np.sum(ges * ges)) * 1e6
    return dataclasses.replace(
        cm, area_per_ge_um2=area_unit, power_per_ge_nw=power_unit
    )
