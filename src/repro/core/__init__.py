"""The paper's primary contribution: mixed-kernel mixed-signal SVMs.

Layout:
  kernels.py          linear / RBF / hardware-sech2 kernel math (Eqs. 2-6)
  svm.py              JAX dual-coordinate-ascent SVM solver + CV grid search
  analog.py           circuit surrogate ("SPICE") + behavioral model (Sec. IV-A)
  quant.py            ADC / fixed-point quantization (Sec. V-A2)
  ovo.py              OvO decomposition, encoder decision logic, digital datapaths
  selection.py        Algorithm 1 - separation-driven mixed-kernel exploration
  hwcost.py           FlexIC area/power cost model (stands in for Synopsys DC)
  mixed_precision.py  TPU analogue: separation-driven precision domains
"""
