"""Batched Algorithm-1 training engine (DESIGN.md §4).

The sequential reference path (``selection.train_pairs_sequential``) runs
2-3 ``svm.fit_best`` calls per OvO pair, and every pair's unique subset
size forces fresh jit compilations of the CV-grid program and the solver:
O(pairs) compiles, each covering a single pair.  This module restructures
the whole exploration as a *fixed-shape batched program*:

1.  **Padding** (`pad_pairs`): every binary subset D_ij is padded to the
    shared ``n_max`` and stacked into ``(P, n_max, d)`` tensors.  Padding
    rows get ``valid = 0``, which zeroes their box constraint (``c_box =
    c * mask * valid``) — the solver's own masking mechanism (alpha frozen
    at 0, see ``svm.dual_coordinate_ascent``) — AND their CV-validation
    weight.  A padded row is therefore a *bit-exact no-op*: its coordinate
    update clips to [0, 0] and contributes an exact 0 to every reduction,
    so the padded solve returns the same alphas as the unpadded one.

2.  **One compile per kernel family** (`family_cv_grid` / `family_refit`):
    all pairs x CV folds x (C, gamma) grid cells run in ONE jitted vmap
    nest per family (linear, rbf, and the sech2 hardware-in-the-loop
    family).  The vmap order is chosen so the Gram matrix is built once
    per (pair, gamma) — ``pairwise_sq_dists`` does not depend on the
    mapped gamma axis, so vmap hoists it to once per pair, and the
    fold x C cells close over the finished Gram — instead of once per
    grid cell as in the sequential path.

3.  **Selection as argmax** (`train_pairs`): Algorithm 1's line-8 keeps
    RBF only when strictly better; here it is an argmax over the
    ``(P, |gamma|, |C|)`` CV-accuracy tensor per family (gamma-major flat
    order, matching ``np.unravel_index`` in ``svm.fit_best``), followed by
    one vmapped full-set refit per family and a host-side extraction of
    the support sets (identical expressions to ``svm.train_binary``).

4.  **Scaling out** (`mesh=`): the same CV-grid program optionally runs
    under ``shard_map`` over the flattened pair x gamma axis
    (``"pairgrid"``, see ``launch.mesh.make_trainer_mesh``) — the work is
    embarrassingly parallel (no collectives), at the cost of recomputing
    the pairwise distances per gamma inside each shard.

The engine reproduces the sequential path's selections and accuracies up
to the documented comparator-tie epsilon (DESIGN.md §1.4): batched-shape
BLAS reductions may differ in the last ulp, which can only matter for a
CV fold whose decision score sits exactly on the comparator threshold.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kernels as kern
from repro.core.analog import AnalogRBFModel
from repro.core.ovo import class_pairs
from repro.core.svm import (
    SVMModel,
    cv_lanes_accuracy_pallas,
    resolve_use_pallas,
)
from repro.kernels import ops as kops

#: fit_best's hyper-parameter grid defaults (paper Sec. V-A2).
DEFAULT_CS = np.logspace(-1, 3, 7)
DEFAULT_RBF_GAMMAS = np.logspace(-1, 2, 7)


@dataclasses.dataclass
class PairResult:
    """Per-OvO-pair outcome of Algorithm 1 (both candidates kept)."""

    pair: tuple[int, int]
    kernel: str                      # selected kernel kind
    model: SVMModel                  # selected float model
    acc_linear: float                # CV accuracy of the linear candidate
    acc_rbf: float                   # CV accuracy of the RBF candidate
    model_linear: SVMModel           # both candidates kept for baselines
    model_rbf: SVMModel
    # Hardware-aware co-optimized model (sech2 kernel) for analog deployment;
    # only trained for pairs that Algorithm 1 assigns to RBF.
    model_hw: Optional[SVMModel] = None


def binary_subset(
    x: np.ndarray, y: np.ndarray, ci: int, cj: int
) -> tuple[np.ndarray, np.ndarray]:
    """Line 5: D_ij = {(x, y) in D | y in {c_i, c_j}}, labels -> {+1, -1}.

    +1 encodes c_i (the pair's first class) so bit==1 <=> c_i wins.
    """
    mask = (y == ci) | (y == cj)
    yy = np.where(y[mask] == ci, 1.0, -1.0)
    return x[mask], yy


def default_hw(seed: int = 0, params=None) -> AnalogRBFModel:
    """The default calibrated analog behavioral model (one fabricated core).

    ``params`` optionally overrides the :class:`CircuitParams` the
    surrogate sweeps run with (sigma sweeps, bias studies) — the
    construction stays deterministic in ``(seed, params)``, which is what
    makes estimators built this way serializable.
    """
    from repro.core.analog import CircuitParams

    return AnalogRBFModel.from_circuit(
        params if params is not None else CircuitParams(),
        key=jax.random.PRNGKey(seed))


def hw_gamma_grid(hw: AnalogRBFModel, n: int = 7) -> np.ndarray:
    """Hardware-realizable gamma* grid for the sech2 co-optimized training.

    The input scaling of Eq. (8) must keep the scaled differential voltage
    within the cell's usable range: s * v_scale * max|dx| <= v_range with
    max|dx| = 1 for [0,1]-normalized features.  Everything below that cap is
    realizable; we search log-uniformly under it.
    """
    g_cap = hw.gamma0_feature() * (hw.params.v_range / hw.v_scale) ** 2
    return np.logspace(-1.0, np.log10(g_cap), n)


# ---------------------------------------------------------------------------
# Padded pair stack
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PaddedPairs:
    """All OvO binary subsets padded to a shared ``n_max`` and stacked.

    Device-facing arrays (f32): ``x (P, n_max, d)``, ``y (P, n_max)``,
    ``valid (P, n_max)`` (1 real / 0 padding), ``fold_masks (P, F, n_max)``
    (1 train / 0 held-out, 0 on padding — validation weight is
    ``(1 - mask) * valid`` so padding rows count for neither side).

    ``subsets`` keeps the unpadded host views (float64, exactly as
    ``binary_subset`` produced them) for the final model extraction.
    """

    pairs: list[tuple[int, int]]
    x: np.ndarray
    y: np.ndarray
    valid: np.ndarray
    fold_masks: np.ndarray
    n_true: list[int]
    subsets: list[tuple[np.ndarray, np.ndarray]]

    @property
    def n_pairs(self) -> int:
        return len(self.pairs)

    @property
    def n_max(self) -> int:
        return int(self.x.shape[1])

    def take(self, idx: Sequence[int]) -> "PaddedPairs":
        """Sub-stack along the pair axis (e.g. the RBF-selected pairs)."""
        idx = list(idx)
        return PaddedPairs(
            pairs=[self.pairs[i] for i in idx],
            x=self.x[idx], y=self.y[idx], valid=self.valid[idx],
            fold_masks=self.fold_masks[idx],
            n_true=[self.n_true[i] for i in idx],
            subsets=[self.subsets[i] for i in idx],
        )

    def trim(self) -> "PaddedPairs":
        """Re-pad to this stack's OWN max subset size.

        ``take`` keeps the original global ``n_max``; a size-sharded
        layout (``shard_lane_layout``) trims each shard so its solver
        lanes pay only the shard's padding, not the global tail's.
        Slicing is exact: rows past a pair's ``n_true`` are padding
        (valid 0, y +1, mask 0) whatever the stack width.
        """
        m = max(self.n_true)
        if m == self.n_max:
            return self
        return PaddedPairs(
            pairs=self.pairs, x=self.x[:, :m], y=self.y[:, :m],
            valid=self.valid[:, :m], fold_masks=self.fold_masks[:, :, :m],
            n_true=self.n_true, subsets=self.subsets)


def cv_fold_assignment(n: int, n_folds: int, seed: int) -> np.ndarray:
    """Fold id per sample — IDENTICAL to ``svm.cv_grid_accuracy`` (each pair
    draws from a fresh ``RandomState(seed)`` over its own subset size)."""
    rng = np.random.RandomState(seed)
    return rng.permutation(n) % n_folds


def pad_pairs(
    x_train: np.ndarray,
    y_train: np.ndarray,
    n_classes: int,
    n_folds: int = 5,
    seed: int = 0,
) -> PaddedPairs:
    """Extract every OvO binary subset and stack them padded to ``n_max``."""
    x_train = np.asarray(x_train)
    y_train = np.asarray(y_train)
    pairs = class_pairs(n_classes)
    subsets = [binary_subset(x_train, y_train, ci, cj) for ci, cj in pairs]
    n_true = [len(yb) for _, yb in subsets]
    n_max = max(n_true)
    p, d = len(pairs), x_train.shape[1]

    x = np.zeros((p, n_max, d), np.float32)
    y = np.ones((p, n_max), np.float32)     # +1 on padding: inert either way
    valid = np.zeros((p, n_max), np.float32)
    masks = np.zeros((p, n_folds, n_max), np.float32)
    for i, (xb, yb) in enumerate(subsets):
        n = n_true[i]
        x[i, :n] = xb
        y[i, :n] = yb
        valid[i, :n] = 1.0
        fold_of = cv_fold_assignment(n, n_folds, seed)
        for f in range(n_folds):
            masks[i, f, :n] = (fold_of != f)
    return PaddedPairs(pairs=pairs, x=x, y=y, valid=valid, fold_masks=masks,
                       n_true=n_true, subsets=subsets)


# ---------------------------------------------------------------------------
# Blocked Gauss-Seidel solver: the batched engine's inner loop
# ---------------------------------------------------------------------------

#: Coordinate-block size of the batched solver: block-local traffic grows
#: with the block while the per-block margin GEMM amortizes as 1/block;
#: ~sqrt(n) balances the two for the paper's subset sizes.
SOLVER_BLOCK = 16


def dual_coordinate_ascent_blocked(
    kp: jnp.ndarray,
    y: jnp.ndarray,
    c_box: jnp.ndarray,
    n_epochs: int,
    block: int = SOLVER_BLOCK,
) -> jnp.ndarray:
    """``svm.dual_coordinate_ascent`` restructured for batched lanes.

    The reference solver maintains the full margin vector ``f`` with one
    O(n) read+write per coordinate: under a vmap over hundreds of
    (C, fold) lanes that streams the whole (lanes, n) state n_epochs * n
    times and the program becomes memory-bound.  Here coordinates are
    processed in blocks of ``block``, and no margin state is carried at
    all: entering a block, its margins are computed *fresh* from the
    current alphas with ONE GEMM (``(alpha * y) @ kp[:, blk]`` — the Gram
    operand is shared by every lane that closes over it), and the
    Gauss-Seidel recurrence inside the block only touches the block-local
    ``kp[blk, blk]`` tile and (lanes, block) state.

    The coordinate *update sequence* is identical to the reference solver
    (same visit order; every coordinate's margin reflects all prior
    updates); only the summation association of the margins differs
    (fresh contraction vs incremental accumulation), so results agree to
    f32 round-off rather than bit-exactly (DESIGN.md §4.5).  Masked
    samples (``c_box = 0``) remain exact no-ops — their alphas stay 0 and
    contribute exact zeros to the margin GEMM — which is what makes
    trailing padding rows inert.
    """
    n = kp.shape[0]
    block = int(min(block, n))
    n_pad = -(-n // block) * block
    if n_pad != n:
        kp = jnp.pad(kp, ((0, n_pad - n), (0, n_pad - n)))
        y = jnp.pad(y, (0, n_pad - n), constant_values=1.0)
        c_box = jnp.pad(c_box, (0, n_pad - n))
    qdiag = jnp.clip(jnp.diag(kp), 1e-12, None)
    n_blocks = n_pad // block

    def block_body(b, alpha):
        j0 = b * block
        # Row slice, NOT columns: the reference margin is f_j = sum_i
        # K'[j, i] a_i y_i, and the hardware measured-curve kernel is not
        # exactly symmetric (the fitted center offset mu shifts the bell),
        # so rows and columns differ at the ~1e-4 level there.
        rows = jax.lax.dynamic_slice(kp, (j0, 0), (block, n_pad))
        kbb = jax.lax.dynamic_slice(rows, (0, j0), (block, block))
        yb = jax.lax.dynamic_slice(y, (j0,), (block,))
        cb = jax.lax.dynamic_slice(c_box, (j0,), (block,))
        qb = jax.lax.dynamic_slice(qdiag, (j0,), (block,))
        ab = jax.lax.dynamic_slice(alpha, (j0,), (block,))
        fb = rows @ (alpha * y)                # fresh block margins, one GEMM

        def coord(i, c2):
            ab, fb = c2
            g = 1.0 - yb[i] * fb[i]
            a_new = jnp.clip(ab[i] + g / qb[i], 0.0, cb[i])
            d = a_new - ab[i]
            fb = fb + d * yb[i] * kbb[:, i]
            return ab.at[i].set(a_new), fb

        ab, _ = jax.lax.fori_loop(0, block, coord, (ab, fb))
        return jax.lax.dynamic_update_slice(alpha, ab, (j0,))

    def epoch(_, alpha):
        return jax.lax.fori_loop(0, n_blocks, block_body, alpha)

    alpha = jax.lax.fori_loop(0, n_epochs, epoch,
                              jnp.zeros((n_pad,), kp.dtype))
    return alpha[:n]


# ---------------------------------------------------------------------------
# Hardware-in-the-loop training kernel: uniform-grid fast interpolation
# ---------------------------------------------------------------------------

# id(hw) -> (hw, fast kernel fn), insertion-ordered.  Keyed by identity
# (the behavioral model's ndarray fields make it unhashable); a stable
# function object per hw instance keeps one jit cache entry per model.
# Bounded FIFO: every default-constructed estimator calibrates a fresh
# AnalogRBFModel, so without eviction a long-lived sweep process would pin
# models (and their compiled programs) forever.
_HW_KERNEL_CACHE: dict[int, tuple] = {}
_HW_KERNEL_CACHE_MAX = 8


def _training_kernel(kind):
    """Resolve the kernel used *inside* the compiled training programs.

    A bound ``AnalogRBFModel.kernel_response`` is swapped for an equivalent
    closure that interpolates the measured transfer curve with the O(1)
    uniform-grid bin location of ``kernels._uniform_interp`` (the DC-sweep
    abscissa is a linspace) instead of ``jnp.interp``'s per-query binary
    search — the same substitution the compiled inference path makes,
    tracking the behavioral model to ~1e-6 (within the comparator-tie
    epsilon the training contract already carries, DESIGN.md §4.5).
    """
    hw = getattr(kind, "__self__", None)
    if not isinstance(hw, AnalogRBFModel):
        return kind
    hit = _HW_KERNEL_CACHE.get(id(hw))
    if hit is not None and hit[0] is hw:
        return hit[1]
    fp = kern._grid_fast_path(np.asarray(hw.dv_grid))
    if not fp["uniform_grid"]:
        return kind
    curve = jnp.asarray(hw.kernel_curve, jnp.float32)
    lo = float(np.asarray(hw.dv_grid, np.float32)[0])
    hi = float(np.asarray(hw.dv_grid, np.float32)[-1])
    left = float(hw.kernel_curve[0])
    right = float(hw.kernel_curve[-1])
    inv_step = jnp.float32(fp["inv_step"])

    def fast_hw_kernel(x, sv, gamma_star):
        s = hw.input_scale(gamma_star)
        dv = hw.v_scale * s * (x[:, None, :] - sv[None, :, :]) + hw.mu
        return jnp.prod(
            kern._uniform_interp(dv, curve, lo, hi, left, right, inv_step),
            axis=-1)

    while len(_HW_KERNEL_CACHE) >= _HW_KERNEL_CACHE_MAX:
        _HW_KERNEL_CACHE.pop(next(iter(_HW_KERNEL_CACHE)))
    _HW_KERNEL_CACHE[id(hw)] = (hw, fast_hw_kernel)
    return fast_hw_kernel


# ---------------------------------------------------------------------------
# Jitted cores: ONE compile per (kernel family, shape)
# ---------------------------------------------------------------------------


def _cell_cv_accuracy(kp, yp, mask, vp, c, n_epochs):
    """Train on (mask & valid), validate on (~mask & valid) — the padded
    counterpart of ``svm._train_eval_masked``.

    The fused-solver twin is ``svm.cv_lanes_accuracy_pallas``: same
    train/validate weighting, but the prediction margins come out of the
    Pallas solver's fused ``f`` output instead of a ``kp @ (alpha * y)``
    against a materialized Gram (DESIGN.md §7.1)."""
    alpha = dual_coordinate_ascent_blocked(kp, yp, c * mask * vp, n_epochs)
    f = kp @ (alpha * yp)
    pred = jnp.where(f >= 0.0, 1.0, -1.0)
    val = (1.0 - mask) * vp
    return jnp.sum((pred == yp) * val) / jnp.clip(jnp.sum(val), 1.0, None)


#: Gram-footprint gate for the batched CV grid: when the vmapped
#: per-gamma Gram stack (P * G * n_max^2 f32 bytes) of one program would
#: exceed this, the gamma axis runs sequentially under ``lax.map`` so at
#: most one gamma's Grams are live per pair.  At the scale-out workload
#: (P=66, G=7, n_max=1582) the vmapped stack is ~4.6 GB; sequential
#: gammas bring it under 700 MB for the same lane math.
CV_GRID_VMAP_BYTES = 1 << 30


def _pair_cv_grid(xp, yp, fm, vp, gammas, cs, kind, n_epochs,
                  seq_gamma=False):
    """(G, C) mean CV accuracy of one pair; all folds x cells vmapped.

    The Gram matrix is built inside the gamma vmap, so the
    gamma-independent work (pairwise distances / feature products) is
    hoisted to once per pair, and every fold x C lane closes over the
    finished per-gamma Gram.  The C x folds lanes are flattened into one
    vmap axis (smaller jaxpr, one fused solver loop nest).

    ``seq_gamma`` trades the gamma vmap for ``lax.map`` — identical
    results, one live Gram per gamma instead of G (see
    :data:`CV_GRID_VMAP_BYTES`).
    """
    n_c, n_f = cs.shape[0], fm.shape[0]
    c_lanes = jnp.repeat(cs, n_f)                      # (C*F,)
    m_lanes = jnp.tile(fm, (n_c, 1))                   # (C*F, n)

    def per_gamma(g):
        kp = kern.kernel_matrix(kind, xp, xp, g) + 1.0  # bias-as-feature
        accs = jax.vmap(
            lambda c, m: _cell_cv_accuracy(kp, yp, m, vp, c, n_epochs)
        )(c_lanes, m_lanes)
        return accs.reshape(n_c, n_f).mean(axis=1)      # (C,)

    if seq_gamma:
        return jax.lax.map(per_gamma, gammas).reshape(gammas.shape[0], n_c)
    return jax.vmap(per_gamma)(gammas).reshape(gammas.shape[0], n_c)


def _seq_gamma(x, gammas) -> bool:
    """Trace-time choice of the sequential-gamma CV grid from shapes."""
    p, n = x.shape[0], x.shape[1]
    return p * gammas.shape[0] * n * n * 4 > CV_GRID_VMAP_BYTES


@partial(jax.jit, static_argnames=("kind", "n_epochs", "use_pallas",
                                   "interpret"))
def _cv_grid_all_pairs(x, y, fold_masks, valid, gammas, cs, kind, n_epochs,
                       use_pallas=False, interpret=None):
    """CV grid only, (P, G, C) — the utility/shard-path entry point.

    ``train_pairs`` itself uses `_family_program` (grid + argmax + refit
    fused); this standalone program backs `family_cv_grid` so callers that
    only want the accuracy tensor don't pay a discarded refit.
    """
    if use_pallas and isinstance(kind, str):
        gammas_pg = jnp.broadcast_to(gammas[None], (x.shape[0],
                                                    gammas.shape[0]))
        return cv_lanes_accuracy_pallas(
            x, y, fold_masks, valid, gammas_pg, cs, kind=kind,
            n_epochs=n_epochs, interpret=interpret, block=SOLVER_BLOCK)
    seq = _seq_gamma(x, gammas)
    return jax.vmap(
        lambda xp, yp, fm, vp: _pair_cv_grid(xp, yp, fm, vp, gammas, cs,
                                             kind, n_epochs, seq_gamma=seq)
    )(x, y, fold_masks, valid)


@partial(jax.jit, static_argnames=("kind", "cv_epochs", "n_epochs",
                                   "use_pallas", "interpret"),
         donate_argnames=("y",))
def _family_program(x, y, fold_masks, valid, gammas, cs, kind, cv_epochs,
                    n_epochs, use_pallas=False, interpret=None):
    """The whole family in ONE program: CV grid -> argmax -> full refit.

    Returns ``(acc (P, G, C), gi (P,), ci (P,), alpha (P, n))``.  The
    argmax runs on device over the gamma-major flattened grid — the same
    first-maximum tie-break as ``np.unravel_index(np.argmax(...))`` in
    ``svm.fit_best``.

    ``use_pallas`` (string kinds) swaps both the CV grid and the refit
    onto the fused Gram-free solver lanes (``repro.kernels.solver``); the
    per-lane Gram matrices the vmap path materializes disappear from the
    program entirely.  ``y`` is donated: its buffer is dead by the time
    the refit alphas are produced, so XLA reuses it for the (P, n) output
    instead of growing the peak.
    """
    n_c = cs.shape[0]

    if use_pallas and isinstance(kind, str):
        gammas_pg = jnp.broadcast_to(gammas[None], (x.shape[0],
                                                    gammas.shape[0]))
        acc = cv_lanes_accuracy_pallas(
            x, y, fold_masks, valid, gammas_pg, cs, kind=kind,
            n_epochs=cv_epochs, interpret=interpret, block=SOLVER_BLOCK)
        flat = jnp.argmax(acc.reshape(acc.shape[0], -1), axis=1)
        gi, ci = flat // n_c, flat % n_c
        c_box = (cs[ci][:, None] * valid)[:, None, :]      # (P, 1, n)
        alpha, _ = kops.solve_lanes(
            x, y, c_box, gammas[gi][:, None], kind=kind,
            n_epochs=n_epochs, block=SOLVER_BLOCK, interpret=interpret)
        return acc, gi, ci, alpha[:, 0, 0]

    seq = _seq_gamma(x, gammas)

    def per_pair(xp, yp, fm, vp):
        acc = _pair_cv_grid(xp, yp, fm, vp, gammas, cs, kind, cv_epochs,
                            seq_gamma=seq)
        flat = jnp.argmax(acc)                         # gamma-major order
        gi, ci = flat // n_c, flat % n_c
        kp = kern.kernel_matrix(kind, xp, xp, gammas[gi]) + 1.0
        alpha = dual_coordinate_ascent_blocked(kp, yp, cs[ci] * vp, n_epochs)
        return acc, gi, ci, alpha

    return jax.vmap(per_pair)(x, y, fold_masks, valid)


@partial(jax.jit, static_argnames=("kind", "n_epochs", "use_pallas",
                                   "interpret"),
         donate_argnames=("y",))
def _refit_all_pairs(x, y, valid, gamma_sel, c_sel, kind, n_epochs,
                     use_pallas=False, interpret=None):
    """Full-set refit of every pair at its selected (gamma, C): (P, n).

    Only used by the shard_map path, where selection happens on host
    between the sharded CV grid and the refit.  ``y`` is donated (see
    ``_family_program``).
    """
    if use_pallas and isinstance(kind, str):
        c_box = (c_sel[:, None] * valid)[:, None, :]       # (P, 1, n)
        alpha, _ = kops.solve_lanes(
            x, y, c_box, gamma_sel[:, None], kind=kind,
            n_epochs=n_epochs, block=SOLVER_BLOCK, interpret=interpret)
        return alpha[:, 0, 0]

    def one(xp, yp, vp, g, c):
        kp = kern.kernel_matrix(kind, xp, xp, g) + 1.0
        return dual_coordinate_ascent_blocked(kp, yp, c * vp, n_epochs)

    return jax.vmap(one)(x, y, valid, gamma_sel, c_sel)


def _family_use_pallas(use_pallas, kind) -> bool:
    """Pallas solver applies to the stateless string kinds only; the
    hardware-in-the-loop measured-curve kernel keeps the blocked path."""
    return bool(use_pallas) and isinstance(kind, str) and \
        kind in ("linear", "rbf", "sech2")


def family_cv_grid(
    padded: PaddedPairs,
    kind,
    gammas: np.ndarray,
    cs: np.ndarray,
    n_epochs: int,
    mesh=None,
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> np.ndarray:
    """CV-accuracy tensor ``(P, |gammas|, |cs|)`` for one kernel family.

    ``kind`` is a kernel name or a callable (hardware-in-the-loop).  With a
    ``mesh`` the grid runs under shard_map over the pair x gamma axis.
    ``use_pallas`` (string kinds) runs the fused Gram-free solver lanes.
    """
    kind = _training_kernel(kind)
    use_pallas = _family_use_pallas(resolve_use_pallas(use_pallas), kind)
    if mesh is not None:
        return _cv_grid_sharded(padded, kind, gammas, cs, n_epochs, mesh,
                                use_pallas=use_pallas, interpret=interpret)
    return np.asarray(_cv_grid_all_pairs(
        jnp.asarray(padded.x), jnp.asarray(padded.y),
        jnp.asarray(padded.fold_masks), jnp.asarray(padded.valid),
        jnp.asarray(gammas, jnp.float32), jnp.asarray(cs, jnp.float32),
        kind=kind, n_epochs=n_epochs, use_pallas=use_pallas,
        interpret=interpret))


def family_refit(
    padded: PaddedPairs,
    kind,
    gamma_sel: np.ndarray,
    c_sel: np.ndarray,
    n_epochs: int,
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> np.ndarray:
    """Vmapped full-set solve at the selected hyper-parameters: (P, n_max)."""
    kind = _training_kernel(kind)
    return np.asarray(_refit_all_pairs(
        jnp.asarray(padded.x), jnp.asarray(padded.y),
        jnp.asarray(padded.valid),
        jnp.asarray(gamma_sel, jnp.float32),
        jnp.asarray(c_sel, jnp.float32),
        kind=kind, n_epochs=n_epochs,
        use_pallas=_family_use_pallas(resolve_use_pallas(use_pallas), kind),
        interpret=interpret))


# ---------------------------------------------------------------------------
# shard_map variant: the pair x gamma axis across devices
# ---------------------------------------------------------------------------

#: Mesh axis the sharded CV grid distributes over (DESIGN.md §4.4).
PAIRGRID_AXIS = "pairgrid"


def _cv_grid_sharded(padded, kind, gammas, cs, n_epochs, mesh,
                     use_pallas=False, interpret=None):
    """The same (P, G, C) CV grid, shard_mapped over flattened pair x gamma.

    Each (pair, gamma) entry is independent (no collectives), so the only
    cost of distribution is that the pairwise-distance hoisting happens per
    entry instead of per pair.  The flattened axis is padded with repeats
    of entry 0 up to a device-count multiple; padded outputs are dropped.
    With ``use_pallas`` each shard's cells run through the fused solver
    lanes (P=cells, G=1) instead of the vmapped blocked solver.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    if PAIRGRID_AXIS not in mesh.axis_names:
        raise ValueError(
            f"mesh must carry a {PAIRGRID_AXIS!r} axis (see "
            "launch.mesh.make_trainer_mesh); got axes {mesh.axis_names}")
    n_dev = mesh.shape[PAIRGRID_AXIS]
    p, g = padded.n_pairs, len(gammas)
    total = p * g

    def rep(a):  # (P, ...) -> (P*G, ...), pair-major like the output reshape
        return np.repeat(a, g, axis=0)

    xg, yg = rep(padded.x), rep(padded.y)
    fmg, vg = rep(padded.fold_masks), rep(padded.valid)
    gg = np.tile(np.asarray(gammas, np.float32), p)
    n_pad = (-total) % n_dev
    if n_pad:
        pad = slice(0, 1)
        xg = np.concatenate([xg] + [xg[pad]] * n_pad)
        yg = np.concatenate([yg] + [yg[pad]] * n_pad)
        fmg = np.concatenate([fmg] + [fmg[pad]] * n_pad)
        vg = np.concatenate([vg] + [vg[pad]] * n_pad)
        gg = np.concatenate([gg] + [gg[pad]] * n_pad)

    def local(xs, ys, fs, vs, gs, cs_rep):
        if use_pallas and isinstance(kind, str):
            acc = cv_lanes_accuracy_pallas(
                xs, ys, fs, vs, gs[:, None], cs_rep, kind=kind,
                n_epochs=n_epochs, interpret=interpret, block=SOLVER_BLOCK)
            return acc[:, 0, :]

        def cell(xp, yp, fm, vp, gamma):
            kp = kern.kernel_matrix(kind, xp, xp, gamma) + 1.0
            accs = jax.vmap(
                lambda c: jax.vmap(
                    lambda m: _cell_cv_accuracy(kp, yp, m, vp, c, n_epochs)
                )(fm)
            )(cs_rep)
            return accs.mean(axis=1)
        return jax.vmap(cell)(xs, ys, fs, vs, gs)

    sharded = P(PAIRGRID_AXIS)
    fn = jax.jit(shard_map(
        local, mesh=mesh,
        in_specs=(sharded, sharded, sharded, sharded, sharded, P()),
        out_specs=sharded, check_rep=False))
    out = fn(jnp.asarray(xg), jnp.asarray(yg), jnp.asarray(fmg),
             jnp.asarray(vg), jnp.asarray(gg),
             jnp.asarray(cs, jnp.float32))
    return np.asarray(out)[:total].reshape(p, g, len(cs))


# ---------------------------------------------------------------------------
# Size-sharded lane layout: per-device programs padded to their own shard max
# ---------------------------------------------------------------------------


def shard_lane_layout(n_true: Sequence[int], n_shards: int
                      ) -> list[np.ndarray]:
    """Partition pairs into ``<= n_shards`` contiguous size-sorted shards.

    The global-pad layout (`pad_pairs` + one program) makes every solver
    lane pay ``n_max^2`` work; with the long-tailed subset sizes of a
    K>=10 OvO grid (har12: 198..1582) most of that is padding.  This
    layout sorts pairs by true subset size and chooses shard boundaries
    by dynamic programming to minimize the MAKESPAN of padded work,
    modeling a shard's cost as ``count * shard_max^2`` (the blocked
    solver's dominant term).  Each shard is then trimmed to its own max
    (``PaddedPairs.trim``) and dispatched as its own program, so the
    padding waste is bounded by the within-shard size spread rather than
    the global one.

    Returns a list of index arrays into the ORIGINAL pair order; their
    concatenation is a permutation of ``range(len(n_true))``.  At
    ``n_shards=1`` this degenerates to the seed layout (one shard, global
    max).  O(n_shards * P^2) — trivial at P=66.
    """
    p = len(n_true)
    if p == 0:
        return []
    n_shards = max(1, min(int(n_shards), p))
    order = np.argsort(np.asarray(n_true), kind="stable")
    sizes = np.asarray(n_true)[order].astype(np.int64)

    def cost(i, j):  # shard = sorted pairs [i, j)
        return int(j - i) * int(sizes[j - 1]) ** 2

    inf = float("inf")
    best = [[inf] * (n_shards + 1) for _ in range(p + 1)]
    cut = [[0] * (n_shards + 1) for _ in range(p + 1)]
    best[0][0] = 0.0
    for j in range(1, p + 1):
        for s in range(1, min(n_shards, j) + 1):
            for i in range(s - 1, j):
                if best[i][s - 1] == inf:
                    continue
                c = max(best[i][s - 1], cost(i, j))
                if c < best[j][s]:
                    best[j][s], cut[j][s] = c, i
    s_best = min(range(1, n_shards + 1), key=lambda s: best[p][s])
    bounds, j = [], p
    for s in range(s_best, 0, -1):
        i = cut[j][s]
        bounds.append((i, j))
        j = i
    bounds.reverse()
    return [order[i:j] for i, j in bounds]


def family_cv_grid_size_sharded(
    padded: PaddedPairs,
    kind,
    gammas: np.ndarray,
    cs: np.ndarray,
    n_epochs: int,
    devices=None,
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> np.ndarray:
    """(P, G, C) CV grid via size-sharded per-device lane programs.

    Where `_cv_grid_sharded` shard_maps ONE program padded to the global
    ``n_max`` over the pair x gamma axis, this driver partitions pairs by
    subset size (`shard_lane_layout`), trims each shard to its own max,
    and dispatches one `_cv_grid_all_pairs` program per shard to its
    device.  Dispatch is asynchronous (jit returns before completion), so
    on a multi-device host shards overlap; results are gathered back into
    the original pair order.  Compile budget: one compile per distinct
    shard shape, i.e. <= len(devices) programs.

    On a single-device host the win is the padded-work saving alone —
    har12's size spread makes the summed ``count * shard_max^2`` roughly
    3.9x smaller at 8 shards than the global pad, independent of device
    count.
    """
    kind = _training_kernel(kind)
    use_pallas = _family_use_pallas(resolve_use_pallas(use_pallas), kind)
    if devices is None:
        devices = jax.devices()
    shards = shard_lane_layout(padded.n_true, len(devices))
    g_host = np.asarray(gammas, np.float32)
    c_host = np.asarray(cs, np.float32)
    out = np.empty((padded.n_pairs, len(g_host), len(c_host)), np.float32)
    pending = []
    for shard_idx, dev in zip(shards, devices):
        sub = padded.take([int(i) for i in shard_idx]).trim()
        put = lambda a: jax.device_put(jnp.asarray(a), dev)
        acc = _cv_grid_all_pairs(
            put(sub.x), put(sub.y), put(sub.fold_masks), put(sub.valid),
            put(g_host), put(c_host), kind=kind, n_epochs=n_epochs,
            use_pallas=use_pallas, interpret=interpret)
        pending.append((shard_idx, acc))
    for shard_idx, acc in pending:
        out[np.asarray(shard_idx)] = np.asarray(acc)
    return out


# ---------------------------------------------------------------------------
# Selection + model extraction (host-side, replicates svm.train_binary)
# ---------------------------------------------------------------------------


def _argmax_grid(acc: np.ndarray, gammas: np.ndarray, cs: np.ndarray
                 ) -> tuple[float, float, float]:
    """fit_best's line-8 pick: first flat argmax, gamma-major order."""
    gi, ci = np.unravel_index(np.argmax(acc), acc.shape)
    return float(gammas[gi]), float(cs[ci]), float(acc[gi, ci])


def _extract_model(
    kind,
    xb: np.ndarray,
    yb: np.ndarray,
    alpha_row: np.ndarray,
    gamma: float,
    c: float,
    sv_tol: float = 1e-6,
) -> SVMModel:
    """Support-set extraction — the exact tail of ``svm.train_binary``."""
    alpha = np.asarray(alpha_row[: len(yb)])
    sv = alpha > sv_tol
    bias = float(np.sum(alpha[sv] * yb[sv]))
    w = None
    if kind == "linear":
        w = np.asarray((alpha[sv] * yb[sv]) @ xb[sv], np.float64)
    return SVMModel(
        kind=kind if isinstance(kind, str) else "hw",
        support_x=np.asarray(xb[sv], np.float64),
        support_y=np.asarray(yb[sv], np.float64),
        alpha=np.asarray(alpha[sv], np.float64),
        bias=bias,
        gamma=float(gamma),
        c=float(c),
        w=w,
        kernel_fn=None if isinstance(kind, str) else kind,
    )


def _train_family(
    padded: PaddedPairs,
    kind,
    gammas: np.ndarray,
    cs: np.ndarray,
    n_epochs: int,
    cv_epochs: int,
    mesh=None,
    use_pallas: bool = False,
    interpret: Optional[bool] = None,
) -> tuple[list[SVMModel], list[float]]:
    """CV-grid + select + refit one family for every pair in ``padded``.

    Without a mesh this is ONE compiled program (`_family_program`); the
    shard_map path splits into the sharded CV grid, a host-side argmax and
    the (small) vmapped refit program.
    """
    if mesh is not None:
        acc = family_cv_grid(padded, kind, gammas, cs, cv_epochs, mesh=mesh,
                             use_pallas=use_pallas, interpret=interpret)
        sel = [_argmax_grid(acc[i], gammas, cs)
               for i in range(padded.n_pairs)]
        g_sel = np.asarray([s[0] for s in sel], np.float32)
        c_sel = np.asarray([s[1] for s in sel], np.float32)
        alphas = family_refit(padded, kind, g_sel, c_sel, n_epochs,
                              use_pallas=use_pallas, interpret=interpret)
    else:
        kind_t = _training_kernel(kind)
        acc, gi, ci, alphas = _family_program(
            jnp.asarray(padded.x), jnp.asarray(padded.y),
            jnp.asarray(padded.fold_masks), jnp.asarray(padded.valid),
            jnp.asarray(gammas, jnp.float32), jnp.asarray(cs, jnp.float32),
            kind=kind_t, cv_epochs=int(cv_epochs),
            n_epochs=int(n_epochs),
            use_pallas=_family_use_pallas(use_pallas, kind_t),
            interpret=interpret)
        acc, alphas = np.asarray(acc), np.asarray(alphas)
        sel = [(float(gammas[g]), float(cs[c]), float(acc[p, g, c]))
               for p, (g, c) in enumerate(zip(np.asarray(gi),
                                              np.asarray(ci)))]
    models = [
        _extract_model(kind, xb, yb, alphas[i], sel[i][0], sel[i][1])
        for i, (xb, yb) in enumerate(padded.subsets)
    ]
    return models, [s[2] for s in sel]


def train_pairs(
    x_train: np.ndarray,
    y_train: np.ndarray,
    n_classes: int,
    hw: Optional[AnalogRBFModel] = None,
    n_epochs: int = 200,
    seed: int = 0,
    tie_margin: float = 0.005,
    cv_epochs: Optional[int] = None,
    n_folds: int = 5,
    mesh=None,
    hw_all: bool = False,
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> list[PairResult]:
    """Algorithm 1, batched: one compiled program per kernel family.

    Semantics match ``selection.train_pairs_sequential`` (same CV folds,
    grids, tie margin and hardware-in-the-loop retraining), with
    ``cv_epochs`` controlling the fold-training epochs (default: the
    historical ``max(60, n_epochs // 2)``).  ``mesh`` optionally runs the
    CV grids under shard_map (see :data:`PAIRGRID_AXIS`).

    ``use_pallas`` routes the linear/rbf families through the fused
    Gram-free Pallas solver (``repro.kernels.solver``); ``None`` follows
    the ``api/compiled.py`` convention (on only where the tiles compile
    to Mosaic, i.e. TPU), and ``interpret`` forces the Pallas interpreter
    so CPU CI can exercise the code path deliberately.  The
    hardware-in-the-loop family always keeps the blocked XLA solver
    (measured-curve kernels have no tile body).  Selections agree with
    the blocked path to the documented comparator-tie epsilon.

    ``hw_all=True`` keeps the hardware co-optimized ``model_hw`` for EVERY
    pair instead of only the RBF-selected ones.  The engine trains the hw
    family for all pairs anyway (see the jobs comment below), so this is
    free — it is what gives the kernel-assignment design space
    (``repro.core.dse``) an RBF-analog candidate per pair.  The default
    ``False`` preserves the sequential path's deployment contract.
    """
    if hw is None:
        hw = default_hw(seed)
    if cv_epochs is None:
        cv_epochs = max(60, n_epochs // 2)
    cv_epochs = int(cv_epochs)
    use_pallas = resolve_use_pallas(use_pallas)

    padded = pad_pairs(x_train, y_train, n_classes, n_folds=n_folds,
                       seed=seed)
    cs = DEFAULT_CS

    # The three families (linear, rbf, sech2 hardware-in-the-loop) are
    # data-independent, so their compiled programs are dispatched from
    # worker threads: XLA compilation and execution overlap across cores.
    # The hw family is trained for EVERY pair up front (rather than a
    # sub-stack of the RBF-selected pairs afterwards) — a little wasted
    # compute on linear-bound pairs (the paper's regime is P <= 10) buys
    # full three-way concurrency and a sub-stack-shape-independent compile.
    jobs = {
        "linear": (padded, "linear", np.array([1.0]), cs),
        "rbf": (padded, "rbf", DEFAULT_RBF_GAMMAS, cs),
        "hw": (padded, hw.kernel_response, hw_gamma_grid(hw), cs),
    }
    if mesh is None:
        import os
        from concurrent.futures import ThreadPoolExecutor

        workers = max(1, min(len(jobs), os.cpu_count() or 1))
        with ThreadPoolExecutor(max_workers=workers) as ex:
            futs = {k: ex.submit(_train_family, *a, n_epochs, cv_epochs,
                                 None, use_pallas, interpret)
                    for k, a in jobs.items()}
            out = {k: f.result() for k, f in futs.items()}
    else:
        # shard_map programs already span every device; run them in turn.
        out = {k: _train_family(*a, n_epochs, cv_epochs, mesh,
                                use_pallas, interpret)
               for k, a in jobs.items()}
    lin_models, lin_accs = out["linear"]
    rbf_models, rbf_accs = out["rbf"]
    hw_models, _ = out["hw"]

    # Line 8: RBF only when STRICTLY better (beyond the CV-noise margin).
    kinds = ["rbf" if a_r > a_l + tie_margin else "linear"
             for a_l, a_r in zip(lin_accs, rbf_accs)]

    results = []
    for i, pair in enumerate(padded.pairs):
        kind = kinds[i]
        # model_hw is only *kept* for RBF-assigned pairs (the deployment
        # contract of the sequential path) unless hw_all opts into keeping
        # every pair's analog candidate for the DSE.
        m_hw = hw_models[i] if (hw_all or kind == "rbf") else None
        results.append(PairResult(
            pair=pair, kernel=kind,
            model=m_hw if kind == "rbf" else lin_models[i],
            acc_linear=lin_accs[i], acc_rbf=rbf_accs[i],
            model_linear=lin_models[i], model_rbf=rbf_models[i],
            model_hw=m_hw,
        ))
    return results
