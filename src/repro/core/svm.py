"""Binary SVM training in JAX (paper Sec. II-A).

Solver
------
Dual coordinate ascent on the box-constrained dual.  The bias is folded into
the kernel ("bias-as-feature": K' = K + 1), which removes the equality
constraint ``sum(alpha * y) == 0`` and makes every coordinate update an
independent 1-D clip — ideal for ``lax.fori_loop`` and for ``vmap`` over
(C, gamma) hyper-parameter grids and CV folds.

    max_a  sum(a) - 1/2 aT Q a,   Q_ij = y_i y_j K'(x_i, x_j),  0 <= a_i <= C_i

Per-sample box ``C_i`` doubles as a *mask*: setting ``C_i = 0`` freezes a
sample at alpha 0, which is how CV folds and padded batches are trained
without data-dependent shapes.

The recovered model is  f(x) = sum_j a_j y_j (K(x_j, x) + 1)  so the bias is
``b = sum_j a_j y_j``; for the linear kernel the primal weight vector is
``w = sum_j a_j y_j x_j`` (paper Eq. 3).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kernels as kern
from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class SVMModel:
    """A trained binary SVM. Arrays are host numpy for easy serialization."""

    kind: str  # 'linear' | 'rbf' | 'sech2' | 'hw'
    support_x: np.ndarray  # (m, d)
    support_y: np.ndarray  # (m,) in {-1, +1}
    alpha: np.ndarray  # (m,) > 0
    bias: float
    gamma: float  # only meaningful for RBF-family kernels
    c: float
    # Linear primal view (paper Eq. 3); None for rbf.
    w: Optional[np.ndarray] = None
    # Callable kernel for kind == 'hw' (hardware-in-the-loop training);
    # excluded from equality/serialization concerns by compare=False.
    kernel_fn: Optional[object] = dataclasses.field(default=None, compare=False)

    @property
    def n_support(self) -> int:
        return int(self.support_x.shape[0])


# --------------------------------------------------------------------------
# Core solver
# --------------------------------------------------------------------------


def resolve_use_pallas(use_pallas: Optional[bool]) -> bool:
    """The ``api/compiled.py`` convention: None -> Pallas only on TPU
    (the CPU container would run the interpreter; pass ``interpret=True``
    alongside ``use_pallas=True`` to exercise that path deliberately)."""
    if use_pallas is None:
        return jax.default_backend() == "tpu"
    return bool(use_pallas)


def cv_lanes_accuracy_pallas(
    x: jnp.ndarray,           # (P, n, d)
    y: jnp.ndarray,           # (P, n)
    fold_masks: jnp.ndarray,  # (P, F, n) 1 train / 0 held-out
    valid: jnp.ndarray,       # (P, n) 1 real / 0 padding
    gammas_pg: jnp.ndarray,   # (P, G)
    cs: jnp.ndarray,          # (C,)
    kind: str,
    n_epochs: int,
    interpret: Optional[bool] = None,
    block: int = 16,
) -> jnp.ndarray:
    """(P, G, C) mean CV accuracy through the fused Pallas solver.

    The Gram-free twin of the ``_cell_cv_accuracy`` reduction: lanes are
    the C-major flattening of (C, fold) — matching ``jnp.repeat(cs,
    n_f)`` in the blocked path — the box folds train-mask and validity
    in, and validation consumes the solver's fused margin output ``f``
    directly (``kp @ (alpha * y)`` never materializes a Gram).
    """
    p, n_f, n = fold_masks.shape
    n_c = cs.shape[0]
    m_lanes = jnp.tile(fold_masks, (1, n_c, 1))          # (P, C*F, n)
    c_lanes = jnp.repeat(cs, n_f)                        # (C*F,)
    c_box = c_lanes[None, :, None] * m_lanes * valid[:, None, :]
    _, f = kops.solve_lanes(x, y, c_box, gammas_pg, kind=kind,
                            n_epochs=n_epochs, block=block,
                            interpret=interpret)
    pred = jnp.where(f >= 0.0, 1.0, -1.0)                # (P, G, C*F, n)
    val = (1.0 - m_lanes) * valid[:, None, :]            # (P, C*F, n)
    hit = ((pred == y[:, None, None, :]) * val[:, None]).sum(-1)
    acc = hit / jnp.clip(val.sum(-1), 1.0, None)[:, None]
    return acc.reshape(p, gammas_pg.shape[1], n_c, n_f).mean(-1)


@partial(jax.jit, static_argnames=("n_epochs",))
def dual_coordinate_ascent(
    kp: jnp.ndarray,  # (n, n) kernel matrix WITH bias term folded in (K + 1)
    y: jnp.ndarray,  # (n,) in {-1, +1}
    c_box: jnp.ndarray,  # (n,) per-sample box (0 masks the sample out)
    n_epochs: int = 200,
) -> jnp.ndarray:
    """Gauss-Seidel dual coordinate ascent; returns alpha (n,)."""
    n = kp.shape[0]
    qdiag = jnp.clip(jnp.diag(kp), 1e-12, None)

    def body(t, carry):
        alpha, f = carry  # f_i = sum_j alpha_j y_j K'_ij  (margin pre-y)
        i = t % n
        g = 1.0 - y[i] * f[i]
        a_new = jnp.clip(alpha[i] + g / qdiag[i], 0.0, c_box[i])
        delta = a_new - alpha[i]
        f = f + delta * y[i] * kp[:, i]
        alpha = alpha.at[i].set(a_new)
        return alpha, f

    alpha0 = jnp.zeros((n,), kp.dtype)
    f0 = jnp.zeros((n,), kp.dtype)
    alpha, _ = jax.lax.fori_loop(0, n_epochs * n, body, (alpha0, f0))
    return alpha


def _gram(kind: str, x: jnp.ndarray, gamma) -> jnp.ndarray:
    return kern.kernel_matrix(kind, x, x, gamma) + 1.0  # bias-as-feature


def train_binary(
    x: np.ndarray,
    y: np.ndarray,
    kind="linear",
    gamma: float = 1.0,
    c: float = 1.0,
    n_epochs: int = 200,
    sv_tol: float = 1e-6,
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> SVMModel:
    """Train one binary SVM and extract its support set (host-side).

    ``kind`` may be a callable kernel (hardware-in-the-loop), recorded as
    kind='hw' with the callable kept on the model.  ``use_pallas`` routes
    the solve through the fused Gram-free Pallas kernel for the string
    kinds (alphas agree with the reference to f32 round-off); callables
    always take the materialized-Gram path.
    """
    xj = jnp.asarray(x, jnp.float32)
    yj = jnp.asarray(y, jnp.float32)
    if resolve_use_pallas(use_pallas) and isinstance(kind, str):
        a_lanes, _ = kops.solve_lanes(
            xj[None], yj[None],
            jnp.full((1, 1, x.shape[0]), float(c), jnp.float32),
            jnp.full((1, 1), float(gamma), jnp.float32),
            kind=kind, n_epochs=n_epochs, interpret=interpret)
        alpha = np.asarray(a_lanes[0, 0, 0])
    else:
        kp = _gram(kind, xj, gamma)
        alpha = np.asarray(dual_coordinate_ascent(
            kp, yj, jnp.full((x.shape[0],), float(c)), n_epochs))
    sv = alpha > sv_tol
    bias = float(np.sum(alpha[sv] * y[sv]))
    w = None
    if kind == "linear":
        w = np.asarray((alpha[sv] * y[sv]) @ x[sv], np.float64)
    return SVMModel(
        kind=kind if isinstance(kind, str) else "hw",
        support_x=np.asarray(x[sv], np.float64),
        support_y=np.asarray(y[sv], np.float64),
        alpha=np.asarray(alpha[sv], np.float64),
        bias=bias,
        gamma=float(gamma),
        c=float(c),
        w=w,
        kernel_fn=None if isinstance(kind, str) else kind,
    )


def decision_function(model: SVMModel, x: np.ndarray) -> np.ndarray:
    """f(x) without the sign (paper Eq. 1)."""
    if model.kind == "linear" and model.w is not None:
        return np.asarray(x, np.float64) @ model.w + model.bias
    kind = model.kernel_fn if model.kernel_fn is not None else model.kind
    k = np.asarray(
        kern.kernel_matrix(
            kind, jnp.asarray(x, jnp.float32),
            jnp.asarray(model.support_x, jnp.float32), model.gamma,
        ),
        np.float64,
    )
    return k @ (model.alpha * model.support_y) + model.bias


def predict(model: SVMModel, x: np.ndarray) -> np.ndarray:
    """Hard labels in {-1, +1}; zeros break toward +1 (comparator convention)."""
    return np.where(decision_function(model, x) >= 0.0, 1.0, -1.0)


def accuracy(model: SVMModel, x: np.ndarray, y: np.ndarray) -> float:
    return float(np.mean(predict(model, x) == y))


# --------------------------------------------------------------------------
# Batched training: hyper-parameter grids and CV folds via vmap
# --------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("kind", "n_epochs"))
def _train_eval_masked(
    x: jnp.ndarray,
    y: jnp.ndarray,
    train_mask: jnp.ndarray,  # (n,) 1.0 train / 0.0 held-out
    gamma: jnp.ndarray,
    c: jnp.ndarray,
    kind: str,
    n_epochs: int,
):
    """Train on masked subset, return (alpha, val_acc on the complement)."""
    kp = kern.kernel_matrix(kind, x, x, gamma) + 1.0
    alpha = dual_coordinate_ascent(kp, y, c * train_mask, n_epochs)
    f = kp @ (alpha * y)
    pred = jnp.where(f >= 0.0, 1.0, -1.0)
    val = 1.0 - train_mask
    val_acc = jnp.sum((pred == y) * val) / jnp.clip(jnp.sum(val), 1.0, None)
    return alpha, val_acc


def cv_grid_accuracy(
    x: np.ndarray,
    y: np.ndarray,
    kind: str,
    gammas: np.ndarray,
    cs: np.ndarray,
    n_folds: int = 5,
    n_epochs: int = 120,
    seed: int = 0,
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> np.ndarray:
    """(len(gammas), len(cs)) mean CV accuracy — all folds x grid in one vmap.

    With ``use_pallas`` (string kinds) the whole grid runs through the
    fused solver lanes instead: no Gram is ever materialized, and the
    blocked update sequence replaces the reference solver's (accuracies
    agree to f32 round-off, DESIGN.md §7).
    """
    n = x.shape[0]
    rng = np.random.RandomState(seed)
    fold_of = rng.permutation(n) % n_folds
    masks = np.stack([(fold_of != f).astype(np.float32) for f in range(n_folds)])

    if resolve_use_pallas(use_pallas) and isinstance(kind, str):
        acc = cv_lanes_accuracy_pallas(
            jnp.asarray(x, jnp.float32)[None],
            jnp.asarray(y, jnp.float32)[None],
            jnp.asarray(masks)[None], jnp.ones((1, n), jnp.float32),
            jnp.asarray(gammas, jnp.float32)[None],
            jnp.asarray(cs, jnp.float32),
            kind=kind, n_epochs=n_epochs, interpret=interpret)
        return np.asarray(acc[0])

    gg, cc = np.meshgrid(np.asarray(gammas, np.float32),
                         np.asarray(cs, np.float32), indexing="ij")
    gflat, cflat = gg.ravel(), cc.ravel()

    fn = jax.vmap(  # over grid
        jax.vmap(  # over folds
            lambda m, g, c: _train_eval_masked(
                jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.float32),
                m, g, c, kind, n_epochs,
            )[1],
            in_axes=(0, None, None),
        ),
        in_axes=(None, 0, 0),
    )
    accs = fn(jnp.asarray(masks), jnp.asarray(gflat), jnp.asarray(cflat))
    return np.asarray(accs.mean(axis=1)).reshape(len(gammas), len(cs))


def fit_best(
    x: np.ndarray,
    y: np.ndarray,
    kind,
    gammas: np.ndarray | None = None,
    cs: np.ndarray | None = None,
    n_folds: int = 5,
    n_epochs: int = 200,
    seed: int = 0,
    cv_epochs: int | None = None,
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> tuple[SVMModel, float]:
    """Grid-search (gamma, C) by CV, refit on the full set. Returns (model, cv_acc).

    ``cv_epochs`` sets the solver epochs used while training CV folds;
    the default keeps the historical policy ``max(60, n_epochs // 2)``
    (fold models only need to rank hyper-parameters, not converge fully).
    The final full-set refit always runs the full ``n_epochs``.
    ``use_pallas``/``interpret`` route both the CV grid and the refit
    through the fused Gram-free solver (string kinds only).
    """
    if cs is None:
        cs = np.logspace(-1, 3, 7)
    if kind == "linear":
        gammas = np.array([1.0])
    elif gammas is None:
        gammas = np.logspace(-1, 2, 7)
    if cv_epochs is None:
        cv_epochs = max(60, n_epochs // 2)
    acc = cv_grid_accuracy(x, y, kind, gammas, cs, n_folds, int(cv_epochs),
                           seed, use_pallas=use_pallas, interpret=interpret)
    gi, ci = np.unravel_index(np.argmax(acc), acc.shape)
    model = train_binary(x, y, kind, float(gammas[gi]), float(cs[ci]),
                         n_epochs, use_pallas=use_pallas,
                         interpret=interpret)
    return model, float(acc[gi, ci])
