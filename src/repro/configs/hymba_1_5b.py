"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attn+mamba heads, SWA everywhere
except 3 global layers [arXiv:2411.13676; hf]."""
from repro.models.common import ModelConfig
from repro.configs.base import reduced_common

ARCH = "hymba-1.5b"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="hybrid",
        n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
        d_ff=5504, vocab_size=32001, d_head=64,
        norm="rmsnorm", act="silu",
        window=1024, global_layers=(0, 15, 31),
        ssm_state=16, ssm_head_dim=64, ssm_expand=2,
    )


def reduced() -> ModelConfig:
    return reduced_common(make_config())
