"""starcoder2-7b [dense]: 32L d_model=4608 36H (GQA kv=4) d_ff=18432
vocab=49152 — GQA, RoPE [arXiv:2402.19173; hf]."""
from repro.models.common import ModelConfig
from repro.configs.base import reduced_common

ARCH = "starcoder2-7b"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense",
        n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4,
        d_ff=18432, vocab_size=49152, d_head=128,
        qkv_bias=True, out_bias=True, mlp_bias=True,
        norm="layernorm", act="gelu", rope_theta=1e5,
    )


def reduced() -> ModelConfig:
    return reduced_common(make_config())
