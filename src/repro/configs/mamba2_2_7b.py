"""mamba2-2.7b [ssm]: 64L d_model=2560 (attn-free) vocab=50280,
ssm_state=128 — SSD (state-space duality) [arXiv:2405.21060; unverified]."""
from repro.models.common import ModelConfig
from repro.configs.base import reduced_common

ARCH = "mamba2-2.7b"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="ssm",
        n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab_size=50280,
        norm="rmsnorm", act="silu",
        ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_groups=1,
    )


def reduced() -> ModelConfig:
    return reduced_common(make_config(), n_heads=0, n_kv_heads=0, d_ff=0)


