"""qwen2.5-32b [dense]: 64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064 — GQA, QKV bias [hf:Qwen/Qwen2.5-0.5B; hf]."""
from repro.models.common import ModelConfig
from repro.configs.base import reduced_common

ARCH = "qwen2.5-32b"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense",
        n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=27648, vocab_size=152064, d_head=128,
        qkv_bias=True, norm="rmsnorm", act="silu", rope_theta=1e6,
    )


def reduced() -> ModelConfig:
    return reduced_common(make_config())
