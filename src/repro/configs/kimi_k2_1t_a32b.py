"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384 experts top-8 + 1 shared — trillion-param MoE
(paper-table) [arXiv:2501.kimi2; unverified]."""
from repro.models.common import ModelConfig
from repro.configs.base import reduced_common

ARCH = "kimi-k2-1t-a32b"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="moe",
        n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
        d_ff=2048, vocab_size=163840, d_head=112,
        norm="rmsnorm", act="silu",
        n_experts=384, top_k=8, n_shared_experts=1,
        capacity_factor=1.0,
    )


def reduced() -> ModelConfig:
    return reduced_common(make_config())
