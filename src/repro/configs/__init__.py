"""Architecture registry: --arch <id> -> config module.

All 10 assigned architectures plus the paper's own SVM workload config.
"""
from __future__ import annotations

import importlib

_MODULES = {
    "starcoder2-7b": "starcoder2_7b",
    "granite-20b": "granite_20b",
    "qwen2.5-32b": "qwen2_5_32b",
    "command-r-35b": "command_r_35b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "hymba-1.5b": "hymba_1_5b",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "mamba2-2.7b": "mamba2_2_7b",
    "whisper-medium": "whisper_medium",
}

ARCHS = tuple(_MODULES)

# long_500k needs sub-quadratic attention: SSM + hybrid (SWA) only.
SUBQUADRATIC = ("mamba2-2.7b", "hymba-1.5b")


def get(name: str):
    """Return the config module for an arch id."""
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {list(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def shapes_for(name: str) -> list[str]:
    """Assigned input shapes for this arch (incl. mandated skips)."""
    base = ["train_4k", "prefill_32k", "decode_32k"]
    if name in SUBQUADRATIC:
        base.append("long_500k")
    return base
