"""granite-20b [dense]: 52L d_model=6144 48H (GQA kv=1, i.e. MQA)
d_ff=24576 vocab=49152 — llama-arch, code [arXiv:2405.04324; hf]."""
from repro.models.common import ModelConfig
from repro.configs.base import reduced_common

ARCH = "granite-20b"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense",
        n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
        d_ff=24576, vocab_size=49152, d_head=128,
        norm="rmsnorm", act="silu",
    )


def reduced() -> ModelConfig:
    return reduced_common(make_config(), n_kv_heads=1)
