"""command-r-35b [dense]: 40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000 — GQA, no-bias, parallel attn+mlp block
[hf:CohereForAI/c4ai-command-r-v01; unverified]."""
from repro.models.common import ModelConfig
from repro.configs.base import reduced_common

ARCH = "command-r-35b"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense",
        n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=22528, vocab_size=256000, d_head=128,
        norm="layernorm", act="silu", parallel_block=True,
        tie_embeddings=True, rope_theta=8e6,
    )


def reduced() -> ModelConfig:
    return reduced_common(make_config())
