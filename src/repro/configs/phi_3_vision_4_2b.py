"""phi-3-vision-4.2b [vlm]: 32L d_model=3072 32H (GQA kv=32, i.e. MHA)
d_ff=8192 vocab=32064 — phi3-mini backbone + CLIP frontend (STUB:
precomputed patch embeddings) [hf:microsoft/Phi-3-vision-128k-instruct; hf]."""
from repro.models.common import ModelConfig
from repro.configs.base import reduced_common

ARCH = "phi-3-vision-4.2b"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="vlm",
        n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab_size=32064, d_head=96,
        norm="rmsnorm", act="silu",
        n_patches=576,
    )


def reduced() -> ModelConfig:
    return reduced_common(make_config(), n_kv_heads=4)
