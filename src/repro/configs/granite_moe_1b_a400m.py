"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
from repro.models.common import ModelConfig
from repro.configs.base import reduced_common

ARCH = "granite-moe-1b-a400m"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="moe",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
        d_ff=512, vocab_size=49155, d_head=64,
        norm="rmsnorm", act="silu",
        n_experts=32, top_k=8,
    )


def reduced() -> ModelConfig:
    return reduced_common(make_config())
