"""Shared helpers for architecture configs: shape grid + input specs.

Each ``src/repro/configs/<id>.py`` exposes:
  make_config()          full assigned config (dims verbatim from the table)
  reduced()              tiny same-family config for CPU smoke tests
  ARCH                   the arch id string

The four assigned input shapes (seq_len, global_batch):
  train_4k     lowers train_step
  prefill_32k  lowers prefill_step
  decode_32k   lowers serve_step (1 token vs a seq_len cache)
  long_500k    lowers serve_step; sub-quadratic archs only
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.serving import engine

SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    sh = SHAPES[shape_name]
    s, b, kind = sh["seq_len"], sh["global_batch"], sh["kind"]
    i32 = jnp.int32

    if kind == "train":
        if cfg.family == "audio":
            se, sd = s // cfg.enc_seq_divisor, s // cfg.dec_seq_divisor
            batch = {
                "frames": _sds((b, se, cfg.d_model), cfg.compute_dtype),
                "tokens": _sds((b, sd), i32),
                "labels": _sds((b, sd), i32),
            }
        elif cfg.family == "vlm":
            batch = {
                "tokens": _sds((b, s - cfg.n_patches), i32),
                "labels": _sds((b, s - cfg.n_patches), i32),
                "patch_embeds": _sds((b, cfg.n_patches, cfg.d_model),
                                     cfg.compute_dtype),
            }
        else:
            batch = {"tokens": _sds((b, s), i32), "labels": _sds((b, s), i32)}
        return {"batch": batch}

    if kind == "prefill":
        if cfg.family == "audio":
            se, sd = s // cfg.enc_seq_divisor, s // cfg.dec_seq_divisor
            batch = {"frames": _sds((b, se, cfg.d_model), cfg.compute_dtype),
                     "tokens": _sds((b, sd), i32)}
        elif cfg.family == "vlm":
            batch = {
                "tokens": _sds((b, s - cfg.n_patches), i32),
                "patch_embeds": _sds((b, cfg.n_patches, cfg.d_model),
                                     cfg.compute_dtype),
            }
        else:
            batch = {"tokens": _sds((b, s), i32)}
        return {"batch": batch}

    # decode: one new token against a seq_len cache
    state = engine.state_shapes(cfg, b, s)
    return {"state": state, "tokens": _sds((b, 1), i32)}


def reduced_common(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    small = dict(
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2),
        d_ff=256,
        vocab_size=512,
        d_head=32,
        dtype="float32",
        remat="none",
        attn_block=64,
    )
    if cfg.family == "moe":
        small.update(n_experts=8, top_k=2, d_ff=64,
                     n_shared_experts=min(cfg.n_shared_experts, 1))
    if cfg.family in ("ssm", "hybrid"):
        small.update(ssm_state=16, ssm_head_dim=16, ssm_heads=0)
    if cfg.family == "hybrid":
        small.update(window=32, global_layers=(0,))
    if cfg.family == "vlm":
        small.update(n_patches=16)
    if cfg.family == "audio":
        small.update(n_enc_layers=2)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
