"""whisper-medium [audio]: 24L d_model=1024 16H d_ff=4096 vocab=51865 —
enc-dec; conv frontend STUBBED to precomputed frame embeddings
(frames = seq_len/2, decoder tokens = seq_len/8) [arXiv:2212.04356;
unverified]."""
from repro.models.common import ModelConfig
from repro.configs.base import reduced_common

ARCH = "whisper-medium"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="audio",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=4096, vocab_size=51865, d_head=64,
        norm="layernorm", act="gelu",
        n_enc_layers=24, enc_seq_divisor=2, dec_seq_divisor=8,
    )


def reduced() -> ModelConfig:
    return reduced_common(make_config(), n_kv_heads=4)
