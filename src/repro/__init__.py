"""repro — Mixed-Kernel Mixed-Signal SVMs for Flexible Electronics, in JAX.

A production-grade JAX framework reproducing and extending
"Design and Optimization of Mixed-Kernel Mixed-Signal SVMs for Flexible
Electronics" (Afentaki et al., 2025), plus the multi-pod LM substrate for the
assigned architecture pool (see DESIGN.md).

Subsystems:

  repro.api          public estimator + compiled-machine API (MixedKernelSVM,
                     compile_machine) — start here
  repro.core         paper's contribution (SVM, analog model, selection, cost)
  repro.data         datasets + token pipeline
  repro.models       LM architectures
  repro.training     optimizer / train_step
  repro.serving      KV cache / prefill / decode
  repro.distributed  sharding rules, mesh utils, PP, elastic, compression
  repro.checkpoint   fault-tolerant checkpointing
  repro.kernels      Pallas TPU kernels (+ refs)
  repro.configs      architecture configs
  repro.launch       mesh / dryrun / train / serve entrypoints
"""

__version__ = "1.1.0"

_API_EXPORTS = ("MixedKernelSVM", "CompiledMachine", "compile_machine")


def __getattr__(name):
    """Lazy re-export of the public API (keeps `import repro` dependency-free)."""
    if name in _API_EXPORTS:
        from repro import api

        return getattr(api, name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
