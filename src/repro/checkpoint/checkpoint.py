"""Atomic, resumable, corruption-detecting checkpoints.

Protocol (the boring-but-critical part of fault tolerance):

  save():    write everything into  <dir>/step_<n>.tmp/
             (one .npy per leaf + manifest.json with the treedef, shapes,
             and a content checksum), fsync, then atomically rename to
             <dir>/step_<n>/.  A crash mid-save leaves only a .tmp dir
             that restore() ignores and the next save() replaces.
  restore(): picks the LATEST complete step dir, verifies the manifest
             checksum of every leaf before handing anything back; a
             corrupted leaf fails loudly (the trainer then falls back to
             the previous step dir).
  latest_step(): discovery for auto-resume (train.py --resume auto).

Leaves are host numpy (global logical arrays).  Multi-host sharded save
writes per-host leaf slices with the same manifest; restore reassembles
via jax.make_array_from_callback — the single-host code path below is
the one exercised in-container.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil

import jax
import numpy as np


def _leaf_files(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in flat:
        name = "__".join(
            re.sub(r"[^A-Za-z0-9_.-]", "_",
                   str(getattr(p, "key", getattr(p, "idx", p))))
            for p in path
        ) or "root"
        yield name, leaf


def _checksum(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()[:16]


def save(ckpt_dir: str, step: int, tree) -> str:
    """Atomic save; returns the final directory path."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    manifest = {"step": int(step), "leaves": {}}
    for name, leaf in _leaf_files(tree):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, name + ".npy"), arr)
        manifest["leaves"][name] = {
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "sha": _checksum(arr),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    """Largest complete step; .tmp dirs (crashed saves) are ignored."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore(ckpt_dir: str, tree_like, step: int | None = None):
    """Restore into the structure of ``tree_like``; verifies checksums.

    Returns (step, tree).  Raises on corruption or missing leaves.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    leaves = []
    flat = jax.tree_util.tree_flatten_with_path(tree_like)[0]
    treedef = jax.tree_util.tree_structure(tree_like)
    for (name, _ref) in _leaf_files(tree_like):
        meta = manifest["leaves"].get(name)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        arr = np.load(os.path.join(d, name + ".npy"))
        if _checksum(arr) != meta["sha"]:
            raise IOError(f"checkpoint corruption detected in leaf {name!r}")
        leaves.append(arr)
    assert len(leaves) == len(flat)
    return step, jax.tree_util.tree_unflatten(treedef, leaves)
