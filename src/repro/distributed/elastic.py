"""Elastic rescaling: move a checkpoint onto a different mesh topology.

Checkpoints store logically-global arrays (repro.checkpoint); rescaling
to a new mesh is therefore: rebuild partition specs against the new mesh
axes and ``jax.device_put`` each leaf with its new NamedSharding.  This
covers both shrink (node loss -> restart on fewer hosts) and grow
(hot-spare promotion) without any resharding maths in user code — the
specs are *logical* (dp/tp/fsdp names), so a (16, 16) -> (8, 16) or
(2, 16, 16) change only re-derives shard extents.

At 1000+ node scale the same flow runs with per-host file shards: each
host device_puts only the index slices it owns (jax.make_array_from_
callback), so no host materialises the full 1T-param tree.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding


def reshard(tree, specs, mesh: Mesh):
    """device_put every leaf with NamedSharding(mesh, spec)."""
    def put(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree.map(put, tree, specs)


def rescale_checkpoint(ckpt_tree, specs, new_mesh: Mesh):
    """Checkpoint (host arrays) -> new mesh. Alias of reshard, named for
    the operational flow (restore -> rescale -> resume)."""
    return reshard(ckpt_tree, specs, new_mesh)
