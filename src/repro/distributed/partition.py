"""Parameter partition rules per architecture family (DESIGN.md §6).

Builds a PartitionSpec pytree matching the param tree of
``repro.models.transformer.init_params``:

  TP   ('model'): q heads / kv proj out-dim / ffn hidden / vocab / experts
  FSDP (rules.fsdp, usually 'data'): the remaining large dim of every
        matrix (ZeRO-3); None -> replicate over data
  layer-stacked leaves get None prepended for the L dim

Optimizer-state specs mirror param specs (same shapes); Quant8 moments
shard their flat-block dims over fsdp only.
"""
from __future__ import annotations


import jax
from jax.sharding import PartitionSpec as P

from repro.models.common import ModelConfig, ShardRules
from repro.training.optimizer import Quant8


def fit_spec(spec: P, shape: tuple, axis_sizes: dict) -> P:
    """Drop sharding on any dim the mesh does not evenly divide.

    jit in_shardings require exact divisibility (uneven GSPMD padding is
    not allowed for arguments), so specs are fitted against the actual
    mesh: e.g. granite-moe's vocab 49155 over tp=16 falls back to
    replicated-vocab, sharded-d_model.
    """
    fitted = []
    for i, ax in enumerate(spec):
        if ax is None or i >= len(shape):
            fitted.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = 1
        for a in axes:
            size *= axis_sizes.get(a, 1)
        fitted.append(ax if size and shape[i] % size == 0 else None)
    return P(*fitted)


def fit_tree(specs, sds_tree, axis_sizes: dict):
    """fit_spec over a whole (spec, ShapeDtypeStruct) tree pair."""
    return jax.tree.map(
        lambda spec, sd: fit_spec(spec, sd.shape, axis_sizes),
        specs, sds_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _spec_for(path: tuple[str, ...], leaf, rules: ShardRules) -> P:
    tp, fs = rules.tp, rules.fsdp
    name = path[-1]
    joined = "/".join(path)
    nd = getattr(leaf, "ndim", 0)

    def stacked(spec: P) -> P:
        """Prepend None for the layer dim when the leaf is stacked."""
        if path[0] in ("layers", "enc_layers") and nd == len(spec) + 1:
            return P(None, *spec)
        return spec

    # embeddings
    if path[0] in ("embed", "unembed"):
        return P(tp, fs)
    # norms, scalars, small per-head params
    if nd <= 1 or name in ("scale", "b", "conv_b", "a_log", "dt_bias",
                           "d_skip", "norm", "bias"):
        if name == "b" and nd >= 1:
            pass  # bias vectors fall through to replicate below
        return stacked(P()) if nd else P()
    # attention
    if "attn" in joined or "xattn" in joined:
        if name == "w":
            if path[-2] == "wo":
                return stacked(P(tp, fs))
            return stacked(P(fs, tp))          # wq, wk, wv
    # dense mlp
    if name == "w":
        if path[-2] == "wd":
            return stacked(P(tp, fs))
        if path[-2] in ("wg", "wu", "in_proj"):
            return stacked(P(fs, tp))
        if path[-2] == "out_proj":
            return stacked(P(tp, fs))
        if path[-2] == "router":
            return stacked(P(fs, None))
    # moe expert banks (E, D, F) / (E, F, D): EP over tp
    if name in ("wg", "wu") and nd >= 3:
        return stacked(P(tp, fs, None))
    if name == "wd" and nd >= 3:
        return stacked(P(tp, None, fs))
    # ssm conv (k, C)
    if name == "conv_w":
        return stacked(P(None, tp))
    return stacked(P(*([None] * nd)))


def param_specs(cfg: ModelConfig, params, rules: ShardRules):
    """PartitionSpec tree for a param pytree."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    specs = []
    for path, leaf in flat:
        keys = tuple(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        specs.append(_spec_for(keys, leaf, rules))
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_specs(cfg: ModelConfig, p_specs, opt_sds, rules: ShardRules):
    """Optimizer-state specs: moments mirror params; Quant8 moments shard
    their flat (n_blocks, block) payload over fsdp."""
    def mom(spec, sd):
        if isinstance(sd, Quant8):
            return Quant8(q=P(rules.fsdp, None), hi=P(rules.fsdp, None),
                          shape=sd.shape)
        return spec

    is_leaf = lambda x: isinstance(x, (P, Quant8))
    return {
        "m": jax.tree.map(mom, p_specs, opt_sds["m"], is_leaf=is_leaf),
        "v": jax.tree.map(mom, p_specs, opt_sds["v"], is_leaf=is_leaf),
        "step": P(),
    }


def batch_specs(batch_shapes: dict, rules: ShardRules) -> dict:
    """Batch dims shard over dp; everything else replicated."""
    def spec(sds):
        return P(rules.dp, *([None] * (len(sds.shape) - 1)))

    return jax.tree.map(spec, batch_shapes)


def serve_state_specs(cfg: ModelConfig, state_shapes: dict,
                      rules: ShardRules, dp_size: int, tp_size: int,
                      kv_len_tp: bool = False) -> dict:
    """Serve-cache specs, divisibility-aware.

    Caches are (L, B, H, cap, dh)-like: batch shards over dp when it
    divides (decode_32k, B=128); at B=1 (long_500k) the cache length
    shards over dp instead (sequence-sharded cache) and heads over tp
    when divisible (mamba2 nh=80 over 16; hymba kv=5 replicates)."""

    def spec(sds):
        shape = sds.shape
        if len(shape) == 0:
            return P()
        # leading dim is a layer stack -> dims shift by one
        axes: list = [None] * len(shape)
        b_dim = 1
        if shape[b_dim] % dp_size == 0 and shape[b_dim] >= dp_size:
            axes[b_dim] = rules.dp
        else:
            # B too small: shard the longest remaining dim over dp
            cand = max(range(2, len(shape)), key=lambda i: shape[i],
                       default=None) if len(shape) > 2 else None
            if cand is not None and shape[cand] % dp_size == 0:
                axes[cand] = rules.dp
        # heads (dim 2 in kv caches, 5-dim arrays) over tp when divisible
        if rules.tp and len(shape) == 5 and axes[2] is None \
                and shape[2] % tp_size == 0 and shape[2] >= tp_size:
            axes[2] = rules.tp
        # kv_len_tp: shard the cache-length dim over tp (decode variant —
        # attention against the cache becomes a tp-partial softmax)
        if kv_len_tp and rules.tp and len(shape) == 5 and axes[3] is None \
                and rules.tp not in axes and shape[3] % tp_size == 0:
            axes[3] = rules.tp
        return P(*axes)

    return jax.tree.map(spec, state_shapes)
