"""GPipe-style pipeline-parallel stage executor over collective_permute.

Opt-in (the default production mesh uses DP x TP; a 'stage' axis composes
with it when configured).  The executor runs under shard_map over the
stage axis: each device group holds one stage's params; microbatches
stream through via ``jax.lax.ppermute`` with the classic GPipe schedule
(fill, steady state, drain) expressed as a ``lax.scan`` over
n_micro + n_stages - 1 ticks.

Correctness (== running the stages sequentially on one device) is tested
on 8 fake CPU devices in tests/test_distributed.py.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def pipeline_forward(
    mesh,
    stage_fn: Callable,      # stage_fn(stage_params, x) -> x
    axis: str = "stage",
):
    """Returns f(stacked_params, microbatches) -> outputs.

    stacked_params leaves: (n_stages, ...) sharded over `axis`.
    microbatches: (n_micro, mb, d) replicated; outputs likewise.
    """
    n_stages = mesh.shape[axis]

    def local(params, mbs):
        # params: (1, ...) local stage params; mbs: (n_micro, mb, d) replicated
        stage = jax.lax.axis_index(axis)
        p = jax.tree.map(lambda a: a[0], params)
        n_micro = mbs.shape[0]
        ticks = n_micro + n_stages - 1
        buf = jnp.zeros_like(mbs[0])                 # current stage input
        outs = jnp.zeros_like(mbs)                   # only last stage writes

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (when valid)
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            x_in = jnp.where(stage == 0, mbs[mb_idx], buf)
            # valid window for this stage at tick t: stage <= t < stage+n_micro
            live = (t >= stage) & (t < stage + n_micro)
            y = stage_fn(p, x_in)
            y = jnp.where(live, y, x_in)
            # pass to next stage (ring; last->0 wraps but is ignored)
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            nxt = jax.lax.ppermute(y, axis, perm)
            # last stage emits microbatch t - (n_stages - 1)
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            emit = (stage == n_stages - 1) & (t >= n_stages - 1)
            outs = jnp.where(
                emit,
                jax.lax.dynamic_update_index_in_dim(outs, y, out_idx, 0),
                outs,
            )
            return (nxt, outs), None

        (_, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(ticks))
        # broadcast last stage's outputs to every stage member
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis,
        )
        return outs

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_rep=False,
    )
