"""Distribution substrate: partition rules, compression, PP, elastic."""
from repro.distributed import compression, elastic, partition, pipeline  # noqa: F401
