"""int8 gradient compression with error feedback (shard_map all-reduce).

The data-parallel gradient all-reduce is the dominant cross-pod (DCN)
collective; compressing it 4x (f32 -> int8 blockwise) cuts the collective
roofline term proportionally.  Error feedback keeps the scheme unbiased
over time: the quantization residual of step t is added back into step
t+1's gradient before quantization (Karimireddy et al., 2019) — SGD/Adam
convergence is preserved (validated by the convergence test in
tests/test_distributed.py).

Layout: ``compressed_psum`` runs under shard_map over the dp axis —
each shard quantizes its local gradient, the int8 payload is all-reduced
(sum of int32-accumulated int8), and the result is dequantized with the
max block scale.  Exposed both standalone (for the shard_map DP step in
training/dp_step) and as a pure local quantize/dequant pair used by the
pjit path's collective-bytes accounting.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_block(x: jnp.ndarray, block: int = 256):
    """f32 -> (int8 blocks, f32 scales). Returns padded block view."""
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad)).reshape(-1, block)
    scale = jnp.maximum(jnp.max(jnp.abs(flat), 1, keepdims=True), 1e-12) / 127.0
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_block(q: jnp.ndarray, scale: jnp.ndarray, shape) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def compress_with_feedback(grad: jnp.ndarray, err: jnp.ndarray,
                           block: int = 256):
    """Quantize (grad + err); return (q, scale, new_err)."""
    g = grad.astype(jnp.float32) + err
    q, scale = quantize_block(g, block)
    deq = dequantize_block(q, scale, g.shape)
    return q, scale, g - deq


def compressed_psum_fn(axis_name: str, block: int = 256):
    """Returns f(grad, err) -> (mean_grad, new_err) for use INSIDE shard_map.

    All shards must agree on ONE per-block scale before encoding (pmax of
    the local absmaxes) — summing int8 codes produced under per-shard
    scales is not a linear operation and destroys the mean.
    """

    def f(grad: jnp.ndarray, err: jnp.ndarray):
        g = grad.astype(jnp.float32) + err
        flat = g.reshape(-1)
        pad = (-flat.size) % block
        flat = jnp.pad(flat, (0, pad)).reshape(-1, block)
        local_max = jnp.max(jnp.abs(flat), 1, keepdims=True)
        scale = jnp.maximum(jax.lax.pmax(local_max, axis_name), 1e-12) / 127.0
        q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
        new_err = g - dequantize_block(q, scale, g.shape)
        # int8 payload summed in int32 across the axis (4x fewer wire
        # bytes than an f32 ring all-reduce; scales are 1/block overhead)
        qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        mean = dequantize_block(qsum.astype(jnp.float32) / n, scale, g.shape)
        return mean.astype(grad.dtype), new_err

    return f


def make_compressed_allreduce(mesh, axis: str = "data", block: int = 256):
    """shard_map'd tree all-reduce: (grads, errs) -> (mean grads, errs).

    Per-shard gradients carry an explicit leading shard dim: leaves are
    (n_shards, ...) sharded over ``axis`` (the usual DP pattern — each dp
    shard computed grads on its own microbatch).  Outputs: mean grads
    replicated, error-feedback buffers still per-shard.
    """
    from jax.experimental.shard_map import shard_map

    f = compressed_psum_fn(axis, block)

    def inner(gl, el):            # local views: (1, ...)
        mean, new_err = f(gl[0], el[0])
        return mean, new_err[None]

    def one(g, e):
        return shard_map(
            inner, mesh=mesh,
            in_specs=(P(axis), P(axis)),
            out_specs=(P(), P(axis)),
            check_rep=False,
        )(g, e)

    def tree_fn(grads, errs):
        flat_g, treedef = jax.tree.flatten(grads)
        flat_e = treedef.flatten_up_to(errs)
        out = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return (jax.tree.unflatten(treedef, [o[0] for o in out]),
                jax.tree.unflatten(treedef, [o[1] for o in out]))

    return tree_fn
