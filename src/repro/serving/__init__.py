"""Serving substrate.

Two engines live here: the LLM prefill/decode substrate (``engine``, the
seed's shape template) and the SVM fleet streaming engine
(``svm_engine``): micro-batched, padding-bucketed, multi-model co-batched
serving for compiled SVM fleets, with deadline/priority continuous
batching, admission control, and mesh-sharded dispatch (DESIGN.md §9,
§12).
"""
from repro.serving import engine  # noqa: F401
from repro.serving.svm_engine import (  # noqa: F401
    BucketPolicy,
    ServingStats,
    ShedError,
    SVMEngine,
)
