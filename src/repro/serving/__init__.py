"""Serving substrate: KV caches, prefill/decode steps, batching engine."""
from repro.serving import engine  # noqa: F401
