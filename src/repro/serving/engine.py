"""Prefill / decode for every architecture family.

State layout (a plain dict pytree so pjit shardings are easy to derive):

  kv_k / kv_v     (L, B, Hkv, cap, dh)    full causal caches
  ring_k / ring_v (L, B, Hkv, W, dh)      SWA ring buffers (hybrid)
  glob_k / glob_v (nG, B, Hkv, cap, dh)   full caches for the global layers
  ssm / conv      (L, B, nh, dh, ds) / (L, B, w-1, conv_dim)
  xk / xv         (L, B, Hkv, Se, dh)     whisper cross-attention kv
  pos             ()                      absolute decode position (int32)

Decode unrolls the layer loop (static per-layer cache wiring — ring vs
full vs recurrent), while prefill reuses the scanned full-sequence stack
and then packs its collected kv into the cache layout.  The ring buffers
are what make hybrid long-context decode O(W) in memory for SWA layers —
only the cfg.global_layers carry full-length caches (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models import ssm as ssm_mod
from repro.models.common import ModelConfig, ShardRules, dense_apply, norm_apply
from repro.models import transformer as tfm


# ---------------------------------------------------------------------------
# State construction
# ---------------------------------------------------------------------------


def state_shapes(cfg: ModelConfig, batch: int, cap: int) -> dict:
    """Shape/dtype skeleton of the serve state (also used by the dry-run)."""
    import jax.numpy as _jnp
    dt = _jnp.dtype(cfg.kv_dtype) if cfg.kv_dtype else cfg.compute_dtype
    dh = cfg.head_dim
    s: dict[str, Any] = {"pos": jax.ShapeDtypeStruct((), jnp.int32)}
    L, B, Hkv = cfg.n_layers, batch, cfg.n_kv_heads

    def sds(shape, dtype=dt):
        return jax.ShapeDtypeStruct(shape, dtype)

    if cfg.family in ("dense", "vlm", "moe"):
        s["kv_k"] = sds((L, B, Hkv, cap, dh))
        s["kv_v"] = sds((L, B, Hkv, cap, dh))
    elif cfg.family == "audio":
        cap_dec = max(cap // cfg.dec_seq_divisor, 64)
        se = cap // cfg.enc_seq_divisor
        s["kv_k"] = sds((L, B, Hkv, cap_dec, dh))
        s["kv_v"] = sds((L, B, Hkv, cap_dec, dh))
        s["xk"] = sds((L, B, Hkv, se, dh))
        s["xv"] = sds((L, B, Hkv, se, dh))
    elif cfg.family == "ssm":
        s["ssm"] = sds((L, B, cfg.n_ssm_heads, cfg.ssm_head_dim,
                        cfg.ssm_state), jnp.float32)
        s["conv"] = sds((L, B, cfg.conv_width - 1,
                         cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state))
    elif cfg.family == "hybrid":
        w = min(cfg.window or cap, cap)
        ng = max(len(cfg.global_layers), 1)
        s["ring_k"] = sds((L, B, Hkv, w, dh))
        s["ring_v"] = sds((L, B, Hkv, w, dh))
        s["glob_k"] = sds((ng, B, Hkv, cap, dh))
        s["glob_v"] = sds((ng, B, Hkv, cap, dh))
        s["ssm"] = sds((L, B, cfg.n_ssm_heads, cfg.ssm_head_dim,
                        cfg.ssm_state), jnp.float32)
        s["conv"] = sds((L, B, cfg.conv_width - 1,
                         cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state))
    return s


def init_state(cfg: ModelConfig, batch: int, cap: int) -> dict:
    return jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype),
                        state_shapes(cfg, batch, cap))


# ---------------------------------------------------------------------------
# Decode step (one new token, unrolled layers)
# ---------------------------------------------------------------------------


def _decode_attn(cfg, p, x, pos, k_cache, v_cache, ring: bool, window):
    """Shared attention decode: returns (attn_out, new_k_cache, new_v_cache)."""
    h = norm_apply(cfg, x, p["norm1"])
    q, k, v = attn.qkv(cfg, p["attn"], h, jnp.reshape(pos, (1,)))
    cache = attn.KVCache(k=k_cache, v=v_cache, ring=ring)
    cache = attn.cache_update(cache, k, v, pos)
    out = attn.attend_decode(cfg, q, cache, pos, window=window)
    b, hq, _, dh = out.shape
    out = out.transpose(0, 2, 1, 3).reshape(b, 1, hq * dh)
    return dense_apply(p["attn"]["wo"], out), cache.k, cache.v, h


def decode_step(cfg: ModelConfig, params: dict, state: dict,
                tokens: jnp.ndarray, rules: ShardRules | None = None
                ) -> tuple[dict, jnp.ndarray]:
    """tokens: (B, 1) -> (new_state, logits (B, vocab))."""
    rules = rules or ShardRules()
    pos = state["pos"]
    x = tfm.embed_tokens(cfg, params, tokens)
    new_state = dict(state)
    g_idx = 0

    for i in range(cfg.n_layers):
        p = jax.tree.map(lambda a: a[i], params["layers"])

        if cfg.family in ("dense", "vlm", "moe"):
            a_out, nk, nv, _ = _decode_attn(
                cfg, p, x, pos, state["kv_k"][i], state["kv_v"][i],
                ring=False, window=cfg.window)
            new_state["kv_k"] = new_state["kv_k"].at[i].set(nk)
            new_state["kv_v"] = new_state["kv_v"].at[i].set(nv)
            x = x + a_out
            h = norm_apply(cfg, x, p["norm2"])
            if cfg.family == "moe":
                m_out, _ = mlp_mod.apply_moe(cfg, rules, p["moe"], h)
            else:
                m_out = mlp_mod.apply_dense(cfg, p["mlp"], h)
            if cfg.parallel_block:
                x = x + m_out  # command-r folds into same residual anyway
            else:
                x = x + m_out

        elif cfg.family == "audio":
            a_out, nk, nv, _ = _decode_attn(
                cfg, p, x, pos, state["kv_k"][i], state["kv_v"][i],
                ring=False, window=None)
            new_state["kv_k"] = new_state["kv_k"].at[i].set(nk)
            new_state["kv_v"] = new_state["kv_v"].at[i].set(nv)
            x = x + a_out
            # cross attention against static encoder kv
            h = norm_apply(cfg, x, p["norm_x"])
            q, _, _ = attn.qkv(cfg, p["xattn"], h, jnp.reshape(pos, (1,)))
            xc = attn.KVCache(k=state["xk"][i], v=state["xv"][i], ring=False)
            se = xc.k.shape[2]
            out = attn.attend_decode(cfg, q, xc, jnp.int32(se - 1), window=None)
            b, hq, _, dh = out.shape
            out = out.transpose(0, 2, 1, 3).reshape(b, 1, hq * dh)
            x = x + dense_apply(p["xattn"]["wo"], out)
            x = x + mlp_mod.apply_dense(cfg, p["mlp"],
                                        norm_apply(cfg, x, p["norm2"]))

        elif cfg.family == "ssm":
            h = norm_apply(cfg, x, p["norm1"])
            st = ssm_mod.SSMState(ssm=state["ssm"][i], conv=state["conv"][i])
            y, st2 = ssm_mod.apply_step(cfg, p["ssm"], h, st)
            new_state["ssm"] = new_state["ssm"].at[i].set(st2.ssm)
            new_state["conv"] = new_state["conv"].at[i].set(st2.conv)
            x = x + y

        elif cfg.family == "hybrid":
            is_global = i in cfg.global_layers
            h = norm_apply(cfg, x, p["norm1"])
            q, k, v = attn.qkv(cfg, p["attn"], h, jnp.reshape(pos, (1,)))
            if is_global:
                cache = attn.KVCache(k=state["glob_k"][g_idx],
                                     v=state["glob_v"][g_idx], ring=False)
                cache = attn.cache_update(cache, k, v, pos)
                new_state["glob_k"] = new_state["glob_k"].at[g_idx].set(cache.k)
                new_state["glob_v"] = new_state["glob_v"].at[g_idx].set(cache.v)
                out = attn.attend_decode(cfg, q, cache, pos, window=None)
                g_idx += 1
            else:
                cache = attn.KVCache(k=state["ring_k"][i],
                                     v=state["ring_v"][i], ring=True)
                cache = attn.cache_update(cache, k, v, pos)
                new_state["ring_k"] = new_state["ring_k"].at[i].set(cache.k)
                new_state["ring_v"] = new_state["ring_v"].at[i].set(cache.v)
                out = attn.attend_decode(cfg, q, cache, pos, window=cfg.window)
            b, hq, _, dh = out.shape
            a_out = dense_apply(p["attn"]["wo"],
                                out.transpose(0, 2, 1, 3).reshape(b, 1, hq * dh))
            st = ssm_mod.SSMState(ssm=state["ssm"][i], conv=state["conv"][i])
            y, st2 = ssm_mod.apply_step(cfg, p["ssm"], h, st)
            new_state["ssm"] = new_state["ssm"].at[i].set(st2.ssm)
            new_state["conv"] = new_state["conv"].at[i].set(st2.conv)
            x = x + 0.5 * (a_out + y)
            x = x + mlp_mod.apply_dense(cfg, p["mlp"],
                                        norm_apply(cfg, x, p["norm2"]))

    logits = tfm.logits_from_x(cfg, params, x, rules)[:, -1]
    new_state["pos"] = pos + 1
    return new_state, logits


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------


def prefill(cfg: ModelConfig, params: dict, batch: dict, cap: int,
            rules: ShardRules | None = None) -> tuple[dict, jnp.ndarray]:
    """Run the full-sequence stack, pack collected kv/ssm into serve state.

    batch: {tokens (B, S)} (+ patch_embeds / frames per family).
    Returns (state at pos=S, last-token logits (B, vocab)).
    """
    rules = rules or ShardRules()
    if cfg.family == "audio":
        raise NotImplementedError(
            "audio prefill uses examples/serve path with encode_audio + "
            "cross-kv packing; see tests/test_serving.py::test_whisper_decode")
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = tfm.embed_tokens(cfg, params, tokens)
    if cfg.family == "vlm" and "patch_embeds" in batch:
        x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype), x], axis=1)
    pos = jnp.arange(x.shape[1])
    x, stacked = tfm.run_stack(cfg, rules, params["layers"], x, pos,
                               collect_kv=True)
    state = init_state(cfg, b, cap)
    s_eff = x.shape[1]

    if cfg.family in ("dense", "vlm", "moe"):
        k, v = stacked["kv"]                     # (L, B, Hkv, S, dh)
        state["kv_k"] = state["kv_k"].at[:, :, :, :s_eff].set(k.astype(state["kv_k"].dtype))
        state["kv_v"] = state["kv_v"].at[:, :, :, :s_eff].set(v.astype(state["kv_v"].dtype))
    elif cfg.family == "ssm":
        st = stacked["ssm"]
        state["ssm"] = st.ssm
        state["conv"] = st.conv.astype(state["conv"].dtype)
    elif cfg.family == "hybrid":
        k, v = stacked["kv"]
        w = state["ring_k"].shape[3]
        n_fill = min(s_eff, w)
        src = slice(s_eff - n_fill, s_eff)
        slots = (jnp.arange(s_eff - n_fill, s_eff)) % w
        state["ring_k"] = state["ring_k"].at[:, :, :, slots].set(
            k[:, :, :, src].astype(state["ring_k"].dtype))
        state["ring_v"] = state["ring_v"].at[:, :, :, slots].set(
            v[:, :, :, src].astype(state["ring_v"].dtype))
        for g, li in enumerate(cfg.global_layers):
            state["glob_k"] = state["glob_k"].at[g, :, :, :s_eff].set(
                k[li].astype(state["glob_k"].dtype))
            state["glob_v"] = state["glob_v"].at[g, :, :, :s_eff].set(
                v[li].astype(state["glob_v"].dtype))
        st = stacked["ssm"]
        state["ssm"] = st.ssm
        state["conv"] = st.conv.astype(state["conv"].dtype)

    state["pos"] = jnp.int32(s_eff)
    logits = tfm.logits_from_x(cfg, params, x[:, -1:], rules)[:, -1]
    return state, logits


def prefill_audio(cfg: ModelConfig, params: dict, batch: dict, cap: int,
                  rules: ShardRules | None = None):
    """Whisper: encode frames, pack cross-kv, prefill decoder prompt."""
    rules = rules or ShardRules()
    enc_out = tfm.encode_audio(cfg, rules, params, batch["frames"])
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = tfm.embed_tokens(cfg, params, tokens)
    pos = jnp.arange(s)
    x, stacked = _audio_dec_collect(cfg, rules, params, x, pos, enc_out)
    state = init_state(cfg, b, cap)
    k, v = stacked["kv"]
    state["kv_k"] = state["kv_k"].at[:, :, :, :s].set(k.astype(state["kv_k"].dtype))
    state["kv_v"] = state["kv_v"].at[:, :, :, :s].set(v.astype(state["kv_v"].dtype))
    xk, xv = stacked["xkv"]
    se = min(xk.shape[3], state["xk"].shape[3])
    state["xk"] = state["xk"].at[:, :, :, :se].set(xk[:, :, :, :se].astype(state["xk"].dtype))
    state["xv"] = state["xv"].at[:, :, :, :se].set(xv[:, :, :, :se].astype(state["xv"].dtype))
    state["pos"] = jnp.int32(s)
    logits = tfm.logits_from_x(cfg, params, x[:, -1:], rules)[:, -1]
    return state, logits


def _audio_dec_collect(cfg, rules, params, x, positions, enc_out):
    dh = cfg.head_dim

    def body(x, p):
        a_out, kv = tfm._attn_sub(cfg, rules, p, x, positions, causal=True)
        x = x + a_out
        h = norm_apply(cfg, x, p["norm_x"])
        q, _, _ = attn.qkv(cfg, p["xattn"], h, positions)
        b, se, _ = enc_out.shape
        kx = dense_apply(p["xattn"]["wk"], enc_out).reshape(
            b, se, cfg.n_kv_heads, dh).transpose(0, 2, 1, 3)
        vx = dense_apply(p["xattn"]["wv"], enc_out).reshape(
            b, se, cfg.n_kv_heads, dh).transpose(0, 2, 1, 3)
        out = attn.attend(cfg, q, kx, vx, causal=False)
        bq, hq, sq, _ = out.shape
        x = x + dense_apply(p["xattn"]["wo"],
                            out.transpose(0, 2, 1, 3).reshape(bq, sq, hq * dh))
        x = x + mlp_mod.apply_dense(cfg, p["mlp"],
                                    norm_apply(cfg, x, p["norm2"]))
        return x, {"kv": kv, "xkv": (kx, vx)}

    return jax.lax.scan(body, x, params["layers"])
