"""Streaming inference engine for compiled SVM fleets.

The compiled predict path (``repro.api``) is fast but batch-synchronous:
one caller, one batch, one dispatch.  A deployed fleet instead sees a
continuous stream of small queries from many tenants.  This engine turns
that stream back into efficient device batches:

* **Micro-batching** — requests accumulate in an async queue under a
  max-wait / max-batch policy: a batch dispatches as soon as it is full
  OR the oldest request has waited ``max_wait_ms``, trading a bounded
  latency floor for device efficiency.

* **Padding buckets** — every dispatch is padded up to a power-of-two
  batch size (:class:`BucketPolicy`), so the engine touches at most
  ``log2(max_batch / min_bucket) + 1`` distinct shapes and each bucket
  hits ONE pre-compiled XLA program (``warmup()`` compiles them all
  eagerly; the benchmark gates ``<= 1`` compile per bucket).  Padded rows
  carry zeros and model 0 — their labels are computed and discarded.

* **Co-batching** — the engine serves a :class:`~repro.api.FleetMachine`,
  so one dispatch carries rows for ANY mix of member models, routed by
  model index in-graph and un-padded/re-split per request on return.  A
  bare :class:`~repro.api.CompiledMachine` is wrapped into a one-member
  fleet.

* **Double-buffered donated staging** — each bucket owns TWO pinned host
  staging buffers used alternately, and the jitted forward donates the
  ``model_idx`` device buffer (reused for the label output, the alias the
  static analyzer verifies).  Dispatch is asynchronous: after launching
  batch *t* the batcher immediately stages batch *t+1* while the device
  computes, and only blocks on batch *t*'s result when the pipeline is
  ``pipeline_depth`` deep (default 1 = classic double buffering).

* **Observability** — per-request enqueue -> dispatch -> complete
  timestamps feed a :class:`ServingStats` accumulator: queries/s, batch
  occupancy and p50/p95/p99 latency (``benchmarks/serving.py`` turns
  these into the BENCH trajectory numbers).

Usage::

    from repro.serving import SVMEngine
    with SVMEngine(fleet, max_batch=256, max_wait_ms=2.0) as eng:
        fut = eng.submit(x_row, model="balance")   # returns a Future
        label = fut.result()
        print(eng.stats.summary())
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Optional, Union

import jax.numpy as jnp
import numpy as np

from repro.api.compiled import CompiledMachine
from repro.api.fleet import FleetMachine, compile_fleet

DEFAULT_MAX_BATCH = 256
DEFAULT_MIN_BUCKET = 8
DEFAULT_MAX_WAIT_MS = 2.0


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


class BucketPolicy:
    """Powers-of-two padding buckets between ``min_bucket`` and ``max_batch``.

    ``bucket_for(n)`` returns the smallest bucket holding ``n`` rows; the
    bucket set IS the engine's compiled-program set, so its size bounds
    compile count and warm-up cost.
    """

    def __init__(self, max_batch: int = DEFAULT_MAX_BATCH,
                 min_bucket: int = DEFAULT_MIN_BUCKET):
        if not (_is_pow2(max_batch) and _is_pow2(min_bucket)):
            raise ValueError(
                f"buckets must be powers of two, got min={min_bucket} "
                f"max={max_batch}")
        if min_bucket > max_batch:
            raise ValueError(f"min_bucket {min_bucket} > max_batch {max_batch}")
        self.max_batch = int(max_batch)
        self.min_bucket = int(min_bucket)
        buckets, b = [], min_bucket
        while b <= max_batch:
            buckets.append(b)
            b <<= 1
        self.buckets: tuple[int, ...] = tuple(buckets)

    def bucket_for(self, n_rows: int) -> int:
        if not 0 < n_rows <= self.max_batch:
            raise ValueError(
                f"{n_rows} rows outside (0, {self.max_batch}]")
        for b in self.buckets:
            if n_rows <= b:
                return b
        raise AssertionError("unreachable")  # pragma: no cover


class ServingStats:
    """Per-request latency + per-batch occupancy accumulator.

    Timestamps (``time.perf_counter`` seconds) are recorded by the engine:
    ``t_enqueue`` at ``submit``, ``t_dispatch`` when the batch launches on
    device, ``t_complete`` when the request's future resolves.  Queries
    are counted in ROWS (a k-row request is k queries).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self.reset()

    def reset(self) -> None:
        with self._lock:
            self._req: list[tuple[float, float, float, int]] = []
            self._batch: list[tuple[int, int]] = []   # (rows, bucket)

    def observe_batch(self, rows: int, bucket: int,
                      requests) -> None:
        with self._lock:
            self._batch.append((rows, bucket))
            for r in requests:
                self._req.append(
                    (r.t_enqueue, r.t_dispatch, r.t_complete, r.n_rows))

    @property
    def n_requests(self) -> int:
        with self._lock:
            return len(self._req)

    def summary(self) -> dict:
        with self._lock:
            req = list(self._req)
            bat = list(self._batch)
        if not req:
            return {"n_requests": 0, "n_queries": 0, "n_batches": 0}
        lat_ms = np.asarray([(done - enq) * 1e3
                             for enq, _, done, _ in req])
        wait_ms = np.asarray([(disp - enq) * 1e3
                              for enq, disp, _, _ in req])
        rows = sum(r[3] for r in req)
        span = max(r[2] for r in req) - min(r[0] for r in req)
        occ = np.asarray([r / b for r, b in bat])
        return {
            "n_requests": len(req),
            "n_queries": int(rows),
            "n_batches": len(bat),
            "queries_per_s": round(rows / span, 1) if span > 0 else None,
            "batch_occupancy": round(float(occ.mean()), 4),
            "mean_batch_rows": round(rows / len(bat), 2),
            "latency_ms": {
                "p50": round(float(np.percentile(lat_ms, 50)), 3),
                "p95": round(float(np.percentile(lat_ms, 95)), 3),
                "p99": round(float(np.percentile(lat_ms, 99)), 3),
                "mean": round(float(lat_ms.mean()), 3),
                "max": round(float(lat_ms.max()), 3),
            },
            "queue_wait_ms_p50": round(float(np.percentile(wait_ms, 50)), 3),
        }


@dataclasses.dataclass
class _Request:
    x: np.ndarray            # (k, d) f32, d <= fleet.n_features
    model_idx: int
    n_rows: int
    scalar: bool             # 1-D submit -> scalar label result
    future: Future
    t_enqueue: float
    t_dispatch: float = 0.0
    t_complete: float = 0.0


class SVMEngine:
    """Micro-batched, padding-bucketed, multi-model co-batched serving.

    See the module docstring for the design.  The engine owns ONE batcher
    thread; ``submit`` is thread-safe and non-blocking, returning a
    :class:`concurrent.futures.Future` that resolves to the request's
    label(s).  Use as a context manager, or ``start()``/``stop()``.
    """

    def __init__(self, machine: Union[FleetMachine, CompiledMachine], *,
                 max_batch: int = DEFAULT_MAX_BATCH,
                 max_wait_ms: float = DEFAULT_MAX_WAIT_MS,
                 min_bucket: int = DEFAULT_MIN_BUCKET,
                 pipeline_depth: int = 1,
                 stats: Optional[ServingStats] = None,
                 decider: Optional[str] = None):
        if isinstance(machine, CompiledMachine):
            machine = compile_fleet({"default": machine},
                                    decider=decider or machine.decider)
        elif decider is not None and decider != machine.decider:
            machine = FleetMachine(machine.model_ids, machine._members,
                                   use_pallas=machine.use_pallas,
                                   interpret=machine.interpret,
                                   decider=decider)
        if not isinstance(machine, FleetMachine):
            raise TypeError(f"cannot serve a {type(machine).__name__}")
        self.fleet = machine
        self.policy = BucketPolicy(max_batch=max_batch, min_bucket=min_bucket)
        self.max_wait_s = float(max_wait_ms) * 1e-3
        self.pipeline_depth = int(pipeline_depth)
        self.stats = stats if stats is not None else ServingStats()

        d = self.fleet.n_features
        # Two pinned host staging buffers per bucket, used alternately:
        # buffer A is refilled for batch t+1 while batch t (staged from
        # buffer B) is still in flight on device.
        self._staging = {
            b: [(np.zeros((b, d), np.float32), np.zeros((b,), np.int32))
                for _ in range(2)]
            for b in self.policy.buckets
        }
        self._flip = {b: 0 for b in self.policy.buckets}

        self._queue: queue.Queue[_Request] = queue.Queue()
        self._inflight: deque = deque()
        self._carry: Optional[_Request] = None
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "SVMEngine":
        if self._thread is not None:
            raise RuntimeError("engine already started")
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop,
                                        name="svm-engine-batcher",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        """Drain the queue, resolve every future, join the batcher."""
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None

    def __enter__(self) -> "SVMEngine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def warmup(self) -> None:
        """Compile every bucket's program eagerly (blocking)."""
        d = self.fleet.n_features
        for b in self.policy.buckets:
            out = self.fleet._labels_jit(
                jnp.zeros((b, d), jnp.float32), jnp.zeros((b,), jnp.int32))
            out.block_until_ready()

    @property
    def n_buckets(self) -> int:
        return len(self.policy.buckets)

    # -- request ingress -----------------------------------------------------

    def submit(self, x: np.ndarray, model: Union[str, int] = 0) -> Future:
        """Enqueue one request (``(d,)`` row or ``(k, d)`` mini-batch).

        The returned future resolves to a scalar ``int`` label for a 1-D
        input, else an ``(k,)`` int32 array.  ``model`` is a fleet member
        id or index.
        """
        if self._thread is None:
            raise RuntimeError("engine not started (use `with SVMEngine(...)`)")
        if self._stop.is_set():
            raise RuntimeError("engine is stopping")
        x = np.asarray(x, np.float32)
        scalar = x.ndim == 1
        if scalar:
            x = x[None, :]
        if x.ndim != 2 or x.shape[1] > self.fleet.n_features:
            raise ValueError(
                f"expected (k, <= {self.fleet.n_features}) features, "
                f"got {x.shape}")
        if not 0 < x.shape[0] <= self.policy.max_batch:
            raise ValueError(
                f"request rows {x.shape[0]} outside "
                f"(0, {self.policy.max_batch}]")
        req = _Request(x=x, model_idx=self.fleet.model_index(model),
                       n_rows=x.shape[0], scalar=scalar, future=Future(),
                       t_enqueue=time.perf_counter())
        self._queue.put(req)
        return req.future

    def predict(self, x: np.ndarray, model: Union[str, int] = 0):
        """Synchronous convenience wrapper: ``submit(...).result()``."""
        return self.submit(x, model).result()

    # -- batcher thread ------------------------------------------------------

    def _loop(self) -> None:
        while True:
            batch: list[_Request] = []
            rows = 0
            if self._carry is not None:
                batch.append(self._carry)
                rows = self._carry.n_rows
                self._carry = None
            if not batch:
                try:
                    r = self._queue.get(timeout=0.005)
                    batch.append(r)
                    rows = r.n_rows
                except queue.Empty:
                    # Idle: complete any in-flight batch, then exit once
                    # stopped and fully drained.
                    self._resolve(all_pending=True)
                    if self._stop.is_set() and self._queue.empty() \
                            and self._carry is None:
                        return
                    continue
            deadline = batch[0].t_enqueue + self.max_wait_s
            while rows < self.policy.max_batch:
                timeout = deadline - time.perf_counter()
                try:
                    # Past the deadline we stop *waiting* but still drain
                    # the immediately-available backlog — a burst that
                    # outruns the batcher forms full batches instead of
                    # degrading to per-request dispatch.
                    r = self._queue.get(timeout=timeout) if timeout > 0 \
                        else self._queue.get_nowait()
                except queue.Empty:
                    break
                if rows + r.n_rows > self.policy.max_batch:
                    self._carry = r       # held for the next batch
                    break
                batch.append(r)
                rows += r.n_rows
            self._dispatch(batch, rows)

    def _dispatch(self, batch: list[_Request], rows: int) -> None:
        bucket = self.policy.bucket_for(rows)
        xbuf, ibuf = self._staging[bucket][self._flip[bucket]]
        self._flip[bucket] ^= 1
        off = 0
        for r in batch:
            k, d = r.x.shape
            xbuf[off:off + k, :d] = r.x
            if d < xbuf.shape[1]:
                xbuf[off:off + k, d:] = 0.0
            ibuf[off:off + k] = r.model_idx
            off += k
        if off < bucket:                   # padded rows: zeros, model 0
            xbuf[off:] = 0.0
            ibuf[off:] = 0
        t_disp = time.perf_counter()
        for r in batch:
            r.t_dispatch = t_disp
        try:
            labels = self.fleet._labels_jit(
                jnp.asarray(xbuf), jnp.asarray(ibuf))   # async dispatch
        except Exception as e:             # pragma: no cover - defensive
            for r in batch:
                r.future.set_exception(e)
            return
        self._inflight.append((labels, batch, rows, bucket))
        # Double buffering: block on the OLDEST batch only once the
        # pipeline is full, so staging batch t+1 overlapped device compute
        # of batch t.
        while len(self._inflight) > self.pipeline_depth:
            self._resolve()

    def _resolve(self, all_pending: bool = False) -> None:
        while self._inflight:
            labels, batch, rows, bucket = self._inflight.popleft()
            out = np.asarray(labels)       # blocks until device completes
            t_done = time.perf_counter()
            off = 0
            for r in batch:
                lab = out[off:off + r.n_rows]
                off += r.n_rows
                r.t_complete = t_done
                r.future.set_result(int(lab[0]) if r.scalar else lab.copy())
            self.stats.observe_batch(rows, bucket, batch)
            if not all_pending:
                return
